"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes and values; fixed-size smoke tests pin the exact
artifact shapes used by the Rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

f32s = st.floats(
    min_value=-1e3, max_value=1e3, allow_nan=False, allow_infinity=False, width=32
)


def arr(n, seed, lo=-100.0, hi=100.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=n).astype(np.float32))


# ---------------------------------------------------------------- vecadd

@settings(max_examples=25, deadline=None)
@given(
    logn=st.integers(min_value=0, max_value=14),
    seed=st.integers(min_value=0, max_value=2**31),
    block=st.sampled_from([1, 16, 256, 2048]),
)
def test_vecadd_matches_ref(logn, seed, block):
    n = 2**logn
    block = min(block, n)
    x, y = arr(n, seed), arr(n, seed + 1)
    got = kernels.vecadd(x, y, block=block)
    assert_allclose(np.asarray(got), np.asarray(ref.vecadd(x, y)), rtol=0, atol=0)


def test_vecadd_rejects_nondivisible_block():
    with pytest.raises(ValueError):
        kernels.vecadd(arr(10, 0), arr(10, 1), block=3)


# ---------------------------------------------------------------- saxpy

@settings(max_examples=20, deadline=None)
@given(
    logn=st.integers(min_value=0, max_value=12),
    alpha=f32s,
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_saxpy_matches_ref(logn, alpha, seed):
    n = 2**logn
    x, y = arr(n, seed), arr(n, seed + 1)
    a = jnp.asarray([alpha], dtype=jnp.float32)
    got = kernels.saxpy(a, x, y, block=min(256, n))
    assert_allclose(np.asarray(got), np.asarray(ref.saxpy(a[0], x, y)), rtol=1e-6)


# ---------------------------------------------------------------- relu

@settings(max_examples=20, deadline=None)
@given(
    logn=st.integers(min_value=0, max_value=13),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_relu_matches_ref(logn, seed):
    n = 2**logn
    x = arr(n, seed)
    got = kernels.relu(x, block=min(512, n))
    expect = np.asarray(ref.relu(x))
    assert_allclose(np.asarray(got), expect, rtol=0, atol=0)
    assert (np.asarray(got) >= 0).all()


# ---------------------------------------------------------------- gemm

@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 2, 8, 32, 64, 96]),
    k=st.sampled_from([1, 4, 16, 64, 128]),
    n=st.sampled_from([1, 2, 8, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_gemm_matches_ref(m, k, n, seed):
    a = arr((m, k), seed, -2.0, 2.0)
    b = arr((k, n), seed + 7, -2.0, 2.0)
    got = kernels.gemm(a, b, bm=32, bn=32, bk=32)
    assert_allclose(
        np.asarray(got), np.asarray(ref.gemm(a, b)), rtol=1e-5, atol=1e-4
    )


def test_gemm_exact_mxu_tiles():
    # 256x256 with 128-tiles: the artifact configuration.
    a, b = arr((256, 256), 3, -1.0, 1.0), arr((256, 256), 4, -1.0, 1.0)
    got = kernels.gemm(a, b)
    assert_allclose(np.asarray(got), np.asarray(ref.gemm(a, b)), rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 4, 32, 128, 192]),
    n=st.sampled_from([1, 8, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_matvec_matches_ref(m, n, seed):
    a = arr((m, n), seed, -2.0, 2.0)
    x = arr(n, seed + 13, -2.0, 2.0)
    got = kernels.matvec(a, x, bm=64)
    assert_allclose(
        np.asarray(got), np.asarray(ref.matvec(a, x)), rtol=1e-5, atol=1e-4
    )


# ---------------------------------------------------------------- fir

@settings(max_examples=15, deadline=None)
@given(
    logn=st.sampled_from([6, 8, 10, 12]),
    taps=st.sampled_from([1, 2, 4, 16]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_fir_matches_ref(logn, taps, seed):
    n = 2**logn
    x = arr(n + taps - 1, seed)
    h = arr(taps, seed + 3, -1.0, 1.0)
    got = kernels.fir(x, h, block=min(256, n))
    assert_allclose(np.asarray(got), np.asarray(ref.fir(x, h)), rtol=1e-5, atol=1e-4)


def test_fir_identity_tap():
    # One tap with value 1 is the identity filter.
    x = arr(128, 11)
    h = jnp.asarray([1.0], dtype=jnp.float32)
    assert_allclose(np.asarray(kernels.fir(x, h, block=64)), np.asarray(x))


# ---------------------------------------------------------------- maxpool

@settings(max_examples=15, deadline=None)
@given(
    h=st.sampled_from([2, 4, 16, 64, 128]),
    w=st.sampled_from([2, 8, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_maxpool_matches_ref(h, w, seed):
    x = arr((h, w), seed)
    got = kernels.maxpool2x2(x, bm=32, bn=32)
    assert_allclose(np.asarray(got), np.asarray(ref.maxpool2x2(x)), rtol=0, atol=0)


def test_maxpool_rejects_odd():
    with pytest.raises(ValueError):
        kernels.maxpool2x2(arr((3, 4), 0))


# ------------------------------------------------------- composite oracles

@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([16, 64, 256]), seed=st.integers(min_value=0, max_value=2**31))
def test_atax_composition(n, seed):
    """atax == matvec(A.T, matvec(A, x)) built from the Pallas matvec."""
    a = arr((n, n), seed, -1.0, 1.0)
    x = arr(n, seed + 1, -1.0, 1.0)
    got = kernels.matvec(a.T, kernels.matvec(a, x))
    assert_allclose(np.asarray(got), np.asarray(ref.atax(a, x)), rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([4, 32, 128]), seed=st.integers(min_value=0, max_value=2**31))
def test_conv3x3_composition(n, seed):
    img = arr((n, n), seed, -1.0, 1.0)
    k = arr((3, 3), seed + 5, -1.0, 1.0)
    cols = ref.im2col3x3(img)
    got = kernels.matvec(cols, k.reshape(9)).reshape(n, n)
    assert_allclose(np.asarray(got), np.asarray(ref.conv3x3(img, k)), rtol=1e-5, atol=1e-4)
