"""compile.aot command-line behaviour: artifact selection, manifest
completeness, and idempotence of the build-time entry point."""

from __future__ import annotations

import os

import pytest

from compile import model
from compile.aot import main as aot_main


def test_only_flag_writes_subset(tmp_path):
    rc = aot_main(["--out-dir", str(tmp_path), "--only", "vecadd_4096,sgemm_64"])
    assert rc == 0
    files = sorted(os.listdir(tmp_path))
    assert files == ["manifest.txt", "sgemm_64.hlo.txt", "vecadd_4096.hlo.txt"]
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 2
    assert all(";in=" in line and ";out=" in line for line in manifest)


def test_full_run_covers_registry(tmp_path):
    rc = aot_main(["--out-dir", str(tmp_path)])
    assert rc == 0
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    names = {line.split(";")[0] for line in manifest}
    assert names == set(model.AOT_ENTRIES)
    for n in names:
        path = tmp_path / f"{n}.hlo.txt"
        assert path.exists()
        head = path.read_text()[:64]
        assert head.startswith("HloModule"), f"{n}: {head!r}"


def test_back_compat_out_flag(tmp_path):
    # The scaffold Makefile used `--out FILE`; its directory is honoured.
    out = tmp_path / "sub" / "model.hlo.txt"
    os.makedirs(out.parent)
    rc = aot_main(["--out", str(out), "--only", "relu_16384"])
    assert rc == 0
    assert (out.parent / "relu_16384.hlo.txt").exists()


@pytest.mark.parametrize("name", ["fir_65536", "xtreme_round_65536", "sgemm_256"])
def test_simulation_scale_artifacts_registered(name):
    """The Rust workloads' default sizes must have matching artifacts."""
    assert name in model.AOT_ENTRIES
