"""L2 model + AOT lowering tests.

Checks every AOT registry entry: output shapes, numerics of the jitted
model against the composed oracles, HLO text generation (structure only —
execution is tested end-to-end from Rust), and manifest formatting.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.aot import lower_entry, to_hlo_text
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(spec, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1, 1, size=spec.shape).astype(np.float32))


@pytest.mark.parametrize("name", sorted(model.AOT_ENTRIES))
def test_entry_shapes(name):
    fn, args_spec = model.AOT_ENTRIES[name]
    args = [_rand(s, i) for i, s in enumerate(args_spec)]
    outs = fn(*args)
    assert isinstance(outs, tuple), "models must return tuples for AOT"
    shaped = jax.eval_shape(fn, *args_spec)
    for got, spec in zip(outs, shaped):
        assert got.shape == spec.shape
        assert got.dtype == spec.dtype


@pytest.mark.parametrize("name", sorted(model.AOT_ENTRIES))
def test_entry_lowers_to_hlo_text(name):
    fn, args_spec = model.AOT_ENTRIES[name]
    text, line = lower_entry(name, fn, args_spec)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    assert line.startswith(f"{name};in=")
    assert ";out=" in line


def test_manifest_line_format():
    fn, args_spec = model.AOT_ENTRIES["sgemm_64"]
    _, line = lower_entry("sgemm_64", fn, args_spec)
    assert line == "sgemm_64;in=float32[64x64];float32[64x64];out=float32[64x64]"


def test_xtreme_step_numerics():
    a, b = _rand(jax.ShapeDtypeStruct((4096,), jnp.float32), 0), _rand(
        jax.ShapeDtypeStruct((4096,), jnp.float32), 1
    )
    (c,) = model.xtreme_step(a, b)
    assert_allclose(np.asarray(c), np.asarray(a) + np.asarray(b))


def test_xtreme_round_fixed_point():
    spec = jax.ShapeDtypeStruct((2048,), jnp.float32)
    a, b = _rand(spec, 0), _rand(spec, 1)
    a2, c2 = model.xtreme_round(a, b)
    assert_allclose(np.asarray(c2), np.asarray(a + b))
    assert_allclose(np.asarray(a2), np.asarray((a + b) + b))


def test_sgemm_vs_ref():
    spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a, b = _rand(spec, 3), _rand(spec, 4)
    (c,) = model.sgemm(a, b)
    assert_allclose(np.asarray(c), np.asarray(ref.gemm(a, b)), rtol=1e-5, atol=1e-4)


def test_atax_vs_ref():
    a = _rand(jax.ShapeDtypeStruct((256, 256), jnp.float32), 5)
    x = _rand(jax.ShapeDtypeStruct((256,), jnp.float32), 6)
    (y,) = model.atax(a, x)
    assert_allclose(np.asarray(y), np.asarray(ref.atax(a, x)), rtol=1e-4, atol=1e-3)


def test_bicg_vs_ref():
    a = _rand(jax.ShapeDtypeStruct((256, 256), jnp.float32), 7)
    r = _rand(jax.ShapeDtypeStruct((256,), jnp.float32), 8)
    p = _rand(jax.ShapeDtypeStruct((256,), jnp.float32), 9)
    s, q = model.bicg(a, r, p)
    es, eq = ref.bicg(a, r, p)
    assert_allclose(np.asarray(s), np.asarray(es), rtol=1e-4, atol=1e-3)
    assert_allclose(np.asarray(q), np.asarray(eq), rtol=1e-4, atol=1e-3)


def test_conv3x3_vs_ref():
    img = _rand(jax.ShapeDtypeStruct((64, 64), jnp.float32), 10)
    k = _rand(jax.ShapeDtypeStruct((3, 3), jnp.float32), 11)
    (out,) = model.conv3x3(img, k)
    assert_allclose(np.asarray(out), np.asarray(ref.conv3x3(img, k)), rtol=1e-5, atol=1e-4)


def test_fir_vs_ref():
    x = _rand(jax.ShapeDtypeStruct((1024 + 15,), jnp.float32), 12)
    h = _rand(jax.ShapeDtypeStruct((16,), jnp.float32), 13)
    (y,) = model.fir(x, h)
    assert_allclose(np.asarray(y), np.asarray(ref.fir(x, h)), rtol=1e-5, atol=1e-4)


def test_hlo_text_roundtrip_stability():
    """Same entry lowered twice produces identical text (deterministic AOT)."""
    fn, args_spec = model.AOT_ENTRIES["vecadd_4096"]
    t1 = to_hlo_text(jax.jit(fn).lower(*args_spec))
    t2 = to_hlo_text(jax.jit(fn).lower(*args_spec))
    assert t1 == t2
