"""L2: JAX compute graphs for each benchmark's kernel, calling the L1
Pallas kernels.

Each public function is the *functional golden model* of one simulated GPU
kernel: the Rust coordinator replays the same math through the simulated
coherent memory hierarchy and then checks the final memory image against
the output of the AOT-compiled artifact of the function (executed via the
PJRT runtime — Python never runs on the simulation path).

All functions return tuples: the HLO-text interchange lowers with
``return_tuple=True`` and the Rust side unwraps with ``to_tupleN()``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import fir as _fir
from .kernels import gemm as _gemm
from .kernels import matvec as _matvec
from .kernels import maxpool2x2 as _maxpool2x2
from .kernels import relu as _relu
from .kernels import vecadd as _vecadd
from .kernels.ref import im2col3x3


def xtreme_step(a: jnp.ndarray, b: jnp.ndarray):
    """One Xtreme step: C = A + B (Pallas vecadd)."""
    return (_vecadd(a, b),)


def xtreme_round(a: jnp.ndarray, b: jnp.ndarray):
    """One full Xtreme1 round per slice: C = A + B ten times, then
    A = C + B ten times. Repeating an add with unchanged inputs is a
    fixed point, so the round's final state is (A', C') with C' = A + B
    and A' = C' + B."""
    c2 = _vecadd(a, b)
    a2 = _vecadd(c2, b)
    return (a2, c2)


def sgemm(a: jnp.ndarray, b: jnp.ndarray):
    """SGEMM C = A @ B (Fig. 2 motivation + mm workload) via Pallas GEMM."""
    return (_gemm(a, b),)


def fir(x: jnp.ndarray, h: jnp.ndarray):
    """FIR filter over padded input (Hetero-Mark fir) via Pallas kernel."""
    return (_fir(x, h),)


def atax(a: jnp.ndarray, x: jnp.ndarray):
    """PolyBench ATAX y = A^T (A x) via two Pallas matvecs."""
    t = _matvec(a, x)
    return (_matvec(a.T, t),)


def bicg(a: jnp.ndarray, r: jnp.ndarray, p: jnp.ndarray):
    """PolyBench BICG (s, q) = (A^T r, A p) via Pallas matvecs."""
    return (_matvec(a.T, r), _matvec(a, p))


def relu(x: jnp.ndarray):
    """DNNMark rl: ReLU via Pallas elementwise kernel."""
    return (_relu(x),)


def maxpool(x: jnp.ndarray):
    """DNNMark mp: 2x2 max-pool via Pallas kernel."""
    return (_maxpool2x2(x),)


def conv3x3(img: jnp.ndarray, k: jnp.ndarray):
    """AMDAPPSDK simple convolution: 3x3 'same' conv as im2col (jnp — XLA
    fuses the gather) + Pallas matvec (the MXU hot spot)."""
    h, w = img.shape
    cols = im2col3x3(img)
    return (_matvec(cols, k.reshape(9)).reshape(h, w),)


def _f32(*shape: int) -> jnp.ndarray:
    import jax

    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


#: AOT registry: artifact name -> (function, example args). ``compile.aot``
#: lowers each entry to ``artifacts/<name>.hlo.txt`` and records its
#: signature in ``artifacts/manifest.txt`` for the Rust loader.
AOT_ENTRIES = {
    "xtreme_step_16384": (xtreme_step, (_f32(16384), _f32(16384))),
    "xtreme_round_16384": (xtreme_round, (_f32(16384), _f32(16384))),
    "xtreme_round_65536": (xtreme_round, (_f32(65536), _f32(65536))),
    "vecadd_4096": (xtreme_step, (_f32(4096), _f32(4096))),
    "sgemm_64": (sgemm, (_f32(64, 64), _f32(64, 64))),
    "sgemm_128": (sgemm, (_f32(128, 128), _f32(128, 128))),
    "sgemm_256": (sgemm, (_f32(256, 256), _f32(256, 256))),
    "fir_16384": (fir, (_f32(16384 + 15), _f32(16))),
    "fir_65536": (fir, (_f32(65536 + 15), _f32(16))),
    "atax_256": (atax, (_f32(256, 256), _f32(256))),
    "atax_512": (atax, (_f32(512, 512), _f32(512))),
    "bicg_256": (bicg, (_f32(256, 256), _f32(256), _f32(256))),
    "bicg_512": (bicg, (_f32(512, 512), _f32(512), _f32(512))),
    "relu_16384": (relu, (_f32(16384),)),
    "relu_65536": (relu, (_f32(65536),)),
    "maxpool_128": (maxpool, (_f32(128, 128),)),
    "maxpool_256": (maxpool, (_f32(256, 256),)),
    "conv3x3_128": (conv3x3, (_f32(128, 128), _f32(3, 3))),
    "conv3x3_256": (conv3x3, (_f32(256, 256), _f32(3, 3))),
}
