"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth for the L1 kernels: pytest (and the
hypothesis sweeps in ``python/tests``) compare each Pallas kernel against
the function of the same name here via ``assert_allclose``.

Everything here is written with plain ``jax.numpy`` ops only — no Pallas,
no custom calls — so the oracle lowers to straightforward HLO on any
backend.
"""

from __future__ import annotations

import jax.numpy as jnp


def vecadd(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Elementwise float add: the Xtreme benchmarks' C = A + B step."""
    return x + y


def saxpy(alpha, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """alpha * x + y (used by the Xtreme read-modify-write chains)."""
    return alpha * x + y


def gemm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Dense matmul with f32 accumulation (SGEMM; Fig. 2 and the mm workload)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def matvec(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Dense matrix-vector product (atax / bicg building block)."""
    return jnp.dot(a, x, preferred_element_type=jnp.float32)


def fir(x: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """FIR filter: y[i] = sum_t h[t] * x[i + t].

    ``x`` is the already-padded signal of length ``n + taps - 1``; the
    output has length ``n``.
    """
    taps = h.shape[0]
    n = x.shape[0] - taps + 1
    acc = jnp.zeros((n,), dtype=jnp.float32)
    for t in range(taps):
        acc = acc + h[t] * x[t : t + n]
    return acc


def relu(x: jnp.ndarray) -> jnp.ndarray:
    """Rectified linear unit (DNNMark rl workload)."""
    return jnp.maximum(x, 0.0)


def maxpool2x2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max-pooling with stride 2 (DNNMark mp workload).

    ``x`` is (H, W) with even H and W; output is (H/2, W/2).
    """
    h, w = x.shape
    r = x.reshape(h // 2, 2, w // 2, 2)
    return r.max(axis=(1, 3))


def atax(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """PolyBench ATAX: A^T (A x)."""
    return matvec(a.T, matvec(a, x))


def bicg(a: jnp.ndarray, r: jnp.ndarray, p: jnp.ndarray):
    """PolyBench BICG: (s, q) = (A^T r, A p)."""
    return matvec(a.T, r), matvec(a, p)


def im2col3x3(img: jnp.ndarray) -> jnp.ndarray:
    """Unfold a (H, W) image into (H*W, 9) patches for a 3x3 'same' conv.

    Zero padding of 1 on each border. Row-major patch order matches
    ``conv3x3``'s kernel flattening.
    """
    h, w = img.shape
    p = jnp.pad(img, 1)
    cols = []
    for di in range(3):
        for dj in range(3):
            cols.append(p[di : di + h, dj : dj + w].reshape(-1))
    return jnp.stack(cols, axis=1)


def conv3x3(img: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """3x3 'same' convolution (AMDAPPSDK simple-convolution workload).

    Implemented as im2col + matvec so the Pallas GEMM path and this oracle
    share reduction semantics.
    """
    h, w = img.shape
    return matvec(im2col3x3(img), k.reshape(9)).reshape(h, w)
