"""Pallas FIR filter kernel (Hetero-Mark ``fir`` workload compute body).

``y[i] = sum_t h[t] * x[i + t]`` over an already-padded input of length
``n + taps - 1``.

TPU mapping (§Hardware-Adaptation): the GPU version assigns one output
element per thread with the sliding window read from shared memory. Output
tiles are blocked on a 1-D grid; the padded input stays VMEM-resident
(our simulated signals are <= a few MB) and each grid step reads its
overlapping window with a dynamic slice — overlap is not expressible as a
non-overlapping ``BlockSpec``, so the window select happens inside the
kernel. The ``taps``-step loop unrolls into ``taps`` VPU saxpy ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _fir_kernel(x_ref, h_ref, o_ref, *, block: int, taps: int):
    i = pl.program_id(0)
    x = x_ref[...]
    h = h_ref[...]
    window = jax.lax.dynamic_slice(x, (i * block,), (block + taps - 1,))
    acc = jnp.zeros((block,), dtype=jnp.float32)
    for t in range(taps):
        acc = acc + h[t] * jax.lax.dynamic_slice(window, (t,), (block,))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block",))
def fir(x: jnp.ndarray, h: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """FIR over padded input ``x`` (length n + taps - 1) with taps ``h``."""
    taps = h.shape[0]
    n = x.shape[0] - taps + 1
    block = min(block, n)
    if n % block != 0:
        raise ValueError(f"output length {n} must be a multiple of block {block}")
    full_x = pl.BlockSpec(x.shape, lambda i: tuple(0 for _ in x.shape))
    full_h = pl.BlockSpec(h.shape, lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_fir_kernel, block=block, taps=taps),
        grid=(n // block,),
        in_specs=[full_x, full_h],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, h)
