"""L1 Pallas kernels (build-time only; lowered to HLO by compile.aot).

Each kernel has a pure-jnp oracle of the same name in :mod:`.ref`.
"""

from . import ref  # noqa: F401
from .elementwise import relu, saxpy, vecadd  # noqa: F401
from .fir import fir  # noqa: F401
from .gemm import gemm, matvec  # noqa: F401
from .pool import maxpool2x2  # noqa: F401
