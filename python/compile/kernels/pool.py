"""Pallas 2x2 max-pooling kernel (DNNMark ``mp`` workload compute body).

TPU mapping (§Hardware-Adaptation): pooling windows are non-overlapping, so
the HBM->VMEM schedule is a clean 2-D ``BlockSpec`` grid — each output tile
of (bm, bn) pulls exactly the (2*bm, 2*bn) input tile, reshapes inside VMEM
and reduces on the VPU. No shared-memory halo exchange needed, unlike the
CUDA formulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 64


def _maxpool_kernel(x_ref, o_ref):
    x = x_ref[...]
    bm2, bn2 = x.shape
    r = x.reshape(bm2 // 2, 2, bn2 // 2, 2)
    o_ref[...] = r.max(axis=(1, 3))


def _pick_tile(dim: int, want: int) -> int:
    t = min(want, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def maxpool2x2(x: jnp.ndarray, bm: int = DEFAULT_TILE, bn: int = DEFAULT_TILE) -> jnp.ndarray:
    """2x2/stride-2 max-pool of an (H, W) f32 array with even H, W."""
    h, w = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2x2 needs even dims, got {x.shape}")
    oh, ow = h // 2, w // 2
    bm, bn = _pick_tile(oh, bm), _pick_tile(ow, bn)
    return pl.pallas_call(
        _maxpool_kernel,
        grid=(oh // bm, ow // bn),
        in_specs=[pl.BlockSpec((2 * bm, 2 * bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((oh, ow), jnp.float32),
        interpret=True,
    )(x)
