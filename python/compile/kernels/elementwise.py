"""Pallas elementwise kernels: vecadd, saxpy, relu.

These are the compute bodies of the Xtreme suite (C = A + B and the
read-modify-write A = C + B step) and the DNNMark ``rl`` workload.

TPU mapping (§Hardware-Adaptation in DESIGN.md): the paper's workloads are
SIMT vector loops over HBM-resident arrays. On TPU the same insight —
stream cache-block-sized chunks through fast local memory — maps to a 1-D
``BlockSpec`` grid where each grid step stages one VMEM-resident block and
applies a fully-vectorized VPU op. ``interpret=True`` always: the CPU PJRT
client cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM block: 2048 f32 = 8 KB per operand; three operands stay well
# under a 16 MB VMEM budget and the block is a multiple of the 8x128 VPU
# tile.
DEFAULT_BLOCK = 2048


def _vecadd_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def _saxpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    # alpha is a (1,) VMEM-resident scalar block shared by every grid step.
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0)


def _grid_1d(n: int, block: int) -> int:
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    return n // block


@functools.partial(jax.jit, static_argnames=("block",))
def vecadd(x: jnp.ndarray, y: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Blocked elementwise add ``x + y`` over 1-D f32 arrays."""
    n = x.shape[0]
    block = min(block, n)
    grid = _grid_1d(n, block)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _vecadd_kernel,
        grid=(grid,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, y)


@functools.partial(jax.jit, static_argnames=("block",))
def saxpy(alpha: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Blocked ``alpha * x + y``; ``alpha`` is a shape-(1,) array."""
    n = x.shape[0]
    block = min(block, n)
    grid = _grid_1d(n, block)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    alpha_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _saxpy_kernel,
        grid=(grid,),
        in_specs=[alpha_spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(alpha, x, y)


@functools.partial(jax.jit, static_argnames=("block",))
def relu(x: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Blocked ReLU over a 1-D f32 array."""
    n = x.shape[0]
    block = min(block, n)
    grid = _grid_1d(n, block)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _relu_kernel,
        grid=(grid,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)
