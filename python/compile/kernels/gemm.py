"""Pallas tiled GEMM / matvec kernels.

The paper's Fig. 2 motivation experiment is cuBLAS SGEMM on a DGX-1; the
``mm`` workload and the conv (im2col) path also reduce to GEMM. This is the
MXU-shaped hot spot of the compute layer.

TPU mapping (§Hardware-Adaptation): instead of CUDA threadblock tiles +
shared-memory staging, we express the HBM->VMEM schedule with a 3-D
``BlockSpec`` grid (i, j, k): each (i, j) output tile stays resident in
VMEM across the k loop while (bm x bk) and (bk x bn) operand tiles stream
through. Tile sizes default to 128 — the MXU systolic-array edge — so a
real-TPU lowering would hit full MXU occupancy; here we run interpret=True
(CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128x128 MXU tile edge.
DEFAULT_TILE = 128


def _gemm_kernel(x_ref, y_ref, o_ref):
    # k is the innermost (sequential) grid axis; the output tile is revisited
    # on every k step, so initialize it on the first and accumulate after.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pick_tile(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= ``want`` (tiles must divide)."""
    t = min(want, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int = DEFAULT_TILE,
    bn: int = DEFAULT_TILE,
    bk: int = DEFAULT_TILE,
) -> jnp.ndarray:
    """Tiled ``a @ b`` for f32 (M, K) x (K, N) with VMEM-resident accumulation."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = _pick_tile(m, bm), _pick_tile(n, bn), _pick_tile(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _matvec_kernel(a_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm",))
def matvec(a: jnp.ndarray, x: jnp.ndarray, bm: int = DEFAULT_TILE) -> jnp.ndarray:
    """Blocked ``a @ x`` for f32 (M, N) x (N,).

    Rows are tiled (bm per grid step); the vector is VMEM-resident for the
    whole sweep (N f32 <= a few hundred KB at our scales).
    """
    m, n = a.shape
    bm = _pick_tile(m, bm)
    return pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(a, x)
