"""Build-time compile path: L2 JAX models + L1 Pallas kernels + AOT lowering.

Nothing in this package is imported at runtime; ``compile.aot`` runs once
(``make artifacts``) and writes HLO text the Rust PJRT runtime loads.
"""
