"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects with
``proto.id() <= INT_MAX``. The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Besides one ``<name>.hlo.txt`` per entry in ``model.AOT_ENTRIES``, this
writes ``manifest.txt`` describing each artifact's signature::

    name;in=f32[16384],f32[16384];out=f32[16384]

which the Rust loader (`rust/src/runtime/manifest.rs`) parses to build and
check input literals.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import AOT_ENTRIES


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(s) -> str:
    shape = "x".join(str(d) for d in s.shape)
    return f"{s.dtype}[{shape}]"


def lower_entry(name: str, fn, args) -> tuple[str, str]:
    """Lower one registry entry; returns (hlo_text, manifest_line)."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    outs = jax.eval_shape(fn, *args)
    # fn returns a tuple by construction
    in_sig = ";".join(_spec_str(a) for a in args)
    out_sig = ";".join(_spec_str(o) for o in outs)
    line = f"{name};in={in_sig};out={out_sig}"
    return text, line


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="AOT-lower JAX/Pallas models to HLO text")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    # Back-compat with the scaffold Makefile invocation (--out FILE): treat
    # the file's directory as out-dir.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    opts = ap.parse_args(argv)

    out_dir = opts.out_dir
    if opts.out is not None:
        out_dir = os.path.dirname(opts.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    names = list(AOT_ENTRIES)
    if opts.only:
        names = [n for n in names if n in set(opts.only.split(","))]

    manifest = []
    for name in names:
        fn, args = AOT_ENTRIES[name]
        text, line = lower_entry(name, fn, args)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(line)
        print(f"  wrote {path} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(names)} artifacts to {out_dir}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
