//! Quickstart: simulate one benchmark on the paper's default MGPU-SM
//! system with HALCONE coherence, and verify the result functionally.
//!
//!     cargo run --release --example quickstart

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::runtime::Runtime;

fn main() {
    // Table 2 defaults: 4 GPUs x 32 CUs, 16 KB L1s, 8 x 256 KB L2 banks,
    // shared HBM, HALCONE with (RdLease, WrLease) = (10, 5).
    let cfg = SystemConfig::preset("SM-WT-C-HALCONE");
    println!("{}\n", cfg.describe());

    // The AOT-compiled JAX/Pallas artifacts are the golden model; the
    // example still works without them (Rust reference checks only).
    let mut rt = Runtime::open("artifacts").ok();
    if rt.is_none() {
        println!("(artifacts missing — run `make artifacts` for the XLA golden model)\n");
    }

    let res = run_workload(&cfg, "fir", rt.as_mut());
    println!("workload   : {} (Hetero-Mark FIR, memory-bound)", res.workload);
    println!("runtime    : {} simulated cycles", res.metrics.cycles);
    println!("L1$ <-> L2$: {} transactions", res.metrics.l1_l2_transactions());
    println!("L2$ <-> MM : {} transactions", res.metrics.l2_mm_transactions());
    println!("TSU lookups: {}", res.metrics.tsu_lookups);
    println!(
        "host       : {:.2}s, {:.1}M events/s",
        res.metrics.host_seconds,
        res.metrics.events as f64 / res.metrics.host_seconds.max(1e-9) / 1e6
    );
    for c in &res.checks {
        println!(
            "check      : [{}] {} (max rel err {:.2e}) — {}",
            c.kind,
            if c.passed { "PASSED" } else { "FAILED" },
            c.max_err,
            c.desc
        );
    }
    assert!(res.all_passed(), "verification failed");
    println!("\nquickstart OK");
}
