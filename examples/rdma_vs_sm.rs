//! The paper's motivation experiment (Fig. 2, recast): how expensive is it
//! to run a kernel whose data lives in *another* GPU's memory?
//!
//! We pin all matrices in GPU0's partition, then run SGEMM either on GPU0
//! (local) or on GPU1 via P2P-direct-access RDMA (remote), exactly like
//! the paper's DGX-1 experiment — then show MGPU-SM making the question
//! moot.
//!
//!     cargo run --release --example rdma_vs_sm

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_built;
use halcone::coordinator::topology::copy_delay;
use halcone::metrics::bench::Table;
use halcone::workloads::{self, Workload};

/// Rebuild `mm`'s work so every op runs on `target_gpu`'s CUs (the data
/// allocation — GPU0's partition — is untouched).
fn pin_to_gpu(mut wl: Workload, target_gpu: usize, n_gpus: usize) -> Workload {
    for ph in &mut wl.phases {
        let per_cu: Vec<Vec<Vec<_>>> = std::mem::take(&mut ph.work);
        let cus = per_cu[0].len();
        let mut merged = vec![vec![Vec::new(); 0]; 0];
        merged.resize_with(n_gpus, || {
            let mut v = Vec::new();
            v.resize_with(cus, Vec::new);
            v
        });
        for gpu_work in per_cu {
            for (cu, wfs) in gpu_work.into_iter().enumerate() {
                for wf in wfs {
                    if !wf.is_empty() {
                        merged[target_gpu][cu].push(wf);
                    }
                }
            }
        }
        // Pad idle CUs/wavefronts so the grid stays rectangular enough.
        for gw in merged.iter_mut() {
            for cw in gw.iter_mut() {
                if cw.is_empty() {
                    cw.push(Vec::new());
                }
            }
        }
        ph.work = merged;
    }
    wl
}

fn main() {
    let t = Table::new(
        &["size", "placement", "config", "cycles", "vs local"],
        &[6, 10, 16, 12, 9],
    );
    println!("(matrices allocated in GPU0's partition; kernel runs on GPU0 or GPU1)");

    for scale in [0.125f64, 0.25, 0.5] {
        let mut local = None;
        for (label, gpu, preset) in [
            ("local", 0usize, "RDMA-WB-NC"),
            ("remote", 1usize, "RDMA-WB-NC"),
            ("shared", 0usize, "SM-WT-C-HALCONE"),
        ] {
            let mut cfg = SystemConfig::preset(preset);
            cfg.n_gpus = 2;
            cfg.scale = scale;
            let params = cfg.workload_params();
            let wl = workloads::build("mm", &params);
            let n = (256.0 * scale) as usize;
            let wl = pin_to_gpu(wl, gpu, 2);
            // Exclude the host-copy phase: the paper measures kernel time.
            let delay = copy_delay(&cfg, &wl);
            let res = run_built(&cfg, wl, None);
            assert!(res.all_passed(), "{label} checks failed: {:?}", res.checks);
            let kernel_cycles = res.metrics.cycles - delay;
            let base = *local.get_or_insert(kernel_cycles);
            t.row(&[
                format!("{n}^2"),
                label.into(),
                preset.into(),
                kernel_cycles.to_string(),
                format!("{:.2}x", kernel_cycles as f64 / base as f64),
            ]);
        }
    }
    println!(
        "\npaper Fig. 2 reference: remote SGEMM 12.4x (32768^2) to 2895x (512^2) slower than \
         local on a DGX-1; the gap shrinks with size as compute amortizes the NUMA cost.\n\
         MGPU-SM ('shared') removes the placement question entirely."
    );
}
