//! End-to-end driver (the harness's required E2E example): exercises every
//! layer of the stack on a real workload mix —
//!
//!   L1: Pallas kernels (vecadd / tiled GEMM / FIR) AOT-lowered to HLO,
//!   L2: JAX models composing them,
//!   L3: the Rust MGPU-SM simulator running the same math through the
//!       HALCONE-coherent memory hierarchy,
//!   runtime: the PJRT client executing the artifacts as golden models.
//!
//! For each workload the simulated 4-GPU system's final memory image is
//! checked against the XLA artifact's output (plus a Rust reference), and
//! throughput/latency-style metrics are reported.
//!
//!     make artifacts && cargo run --release --example end_to_end

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::metrics::bench::Table;
use halcone::runtime::Runtime;

fn main() {
    let mut rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("end_to_end requires the AOT artifacts: {e:#}");
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT runtime up; {} artifacts available\n", rt.artifacts().len());

    let cfg = SystemConfig::preset("SM-WT-C-HALCONE");
    println!("{}\n", cfg.describe());

    // The workload mix: one elementwise DNN kernel, the GEMM, the FIR
    // filter, the PolyBench solvers and the full Xtreme sharing stress.
    let mix = ["rl", "mm", "fir", "atax", "bicg", "mp", "conv", "xtreme1"];

    let t = Table::new(
        &["workload", "cycles", "sim-ops", "ops/cycle", "checks", "artifact"],
        &[9, 12, 10, 10, 7, 10],
    );
    let mut all_ok = true;
    let mut artifact_checks = 0;
    for wl in mix {
        let res = run_workload(&cfg, wl, Some(&mut rt));
        let ops = res.metrics.l1.reqs_in;
        let art = res
            .checks
            .iter()
            .find(|c| c.kind == "artifact")
            .map(|c| if c.passed { "ok" } else { "FAIL" })
            .unwrap_or("-");
        if art == "ok" {
            artifact_checks += 1;
        }
        t.row(&[
            wl.into(),
            res.metrics.cycles.to_string(),
            ops.to_string(),
            format!("{:.3}", ops as f64 / res.metrics.cycles as f64),
            if res.all_passed() { "pass".into() } else { "FAIL".into() },
            art.into(),
        ]);
        all_ok &= res.all_passed();
    }

    println!();
    assert!(all_ok, "some checks failed");
    assert!(
        artifact_checks >= 6,
        "expected >= 6 XLA-artifact-verified workloads, got {artifact_checks}"
    );
    println!(
        "end_to_end OK: {artifact_checks} workloads verified bit-for-bit (elementwise) or \
         within FP-reduction tolerance (dot products) against the AOT Pallas/XLA golden models"
    );
}
