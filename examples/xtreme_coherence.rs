//! Xtreme suite (paper §4.3.2/§5.3): stress hardware coherence with
//! enforced read-write sharing, and quantify HALCONE's cost against the
//! non-coherent baseline plus the G-TSC traffic ablation (DESIGN.md E10).
//!
//!     cargo run --release --example xtreme_coherence

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::metrics::bench::Table;

fn main() {
    let t = Table::new(
        &["benchmark", "config", "cycles", "slowdown", "coh-misses", "l1->l2 B"],
        &[10, 18, 12, 9, 11, 12],
    );
    for wl in ["xtreme1", "xtreme2", "xtreme3"] {
        let nc = run_workload(&SystemConfig::preset("SM-WT-NC"), wl, None);
        assert!(nc.all_passed(), "{wl} NC checks failed");

        let hc = run_workload(&SystemConfig::preset("SM-WT-C-HALCONE"), wl, None);
        assert!(hc.all_passed(), "{wl} HALCONE checks failed");

        let mut gtsc = SystemConfig::preset("SM-WT-C-HALCONE");
        gtsc.set("coherence", "gtsc").unwrap();
        gtsc.name = "GTSC-ablation".into();
        let gt = run_workload(&gtsc, wl, None);

        for res in [&nc, &hc, &gt] {
            t.row(&[
                wl.into(),
                res.config.clone(),
                res.metrics.cycles.to_string(),
                format!("{:.3}x", res.metrics.cycles as f64 / nc.metrics.cycles as f64),
                res.metrics.l1.coherency_misses.to_string(),
                res.metrics.l1.bytes_down.to_string(),
            ]);
        }

        // E10: cache-level clocks (HALCONE) vs CU-level warpts (G-TSC
        // style) — same protocol decisions, less request traffic.
        let saved = 100.0 * (gt.metrics.l1.bytes_down - hc.metrics.l1.bytes_down) as f64
            / gt.metrics.l1.bytes_down as f64;
        println!(
            "  -> {wl}: HALCONE removes {saved:.1}% of L1->L2 request bytes vs CU-level timestamps\n"
        );
    }
    println!("paper §5.3 reference: worst-case Xtreme slowdown 16.8% (Xtreme3, smallest vectors)");
}
