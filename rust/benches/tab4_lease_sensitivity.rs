//! E9/E10 — §5.4 lease sensitivity + the G-TSC traffic ablation.
//!
//! Sweeps (RdLease, WrLease) over the paper's six points on the Xtreme
//! suite (the only workloads sensitive to leases) and reports runtime
//! relative to the default (10, 5). Paper: widening |RdLease - WrLease|
//! from 5 to 10 costs up to ~3%.
//!
//! The second table is the footnote-2 ablation: HALCONE's cache-level
//! clocks vs G-TSC-style CU-level timestamps carried in every request
//! (same protocol decisions; pure wire-traffic delta; paper: request
//! traffic reduced by up to 41.7%, response traffic by up to 3.1% — theirs
//! counts CU<->L1 links too, ours reports the L1->L2 and L2->MM request
//! bytes).
//!
//!     cargo bench --bench tab4_lease_sensitivity

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::metrics::bench::Table;

fn main() {
    println!("== §5.4: (RdLease, WrLease) sensitivity on Xtreme ==\n");
    let pairs = [(10u64, 5u64), (2, 10), (10, 2), (5, 10), (10, 5), (20, 10), (10, 20)];
    let t = Table::new(
        &["rd/wr", "xtreme1", "xtreme2", "xtreme3"],
        &[9, 10, 10, 10],
    );
    let mut base = [0u64; 3];
    for (pi, &(rd, wr)) in pairs.iter().enumerate() {
        let mut cells = vec![format!("({rd},{wr})")];
        for (wi, wl) in ["xtreme1", "xtreme2", "xtreme3"].iter().enumerate() {
            let mut cfg = SystemConfig::preset("SM-WT-C-HALCONE");
            cfg.set("rd_lease", &rd.to_string()).unwrap();
            cfg.set("wr_lease", &wr.to_string()).unwrap();
            let res = run_workload(&cfg, wl, None);
            assert!(res.all_passed(), "({rd},{wr})/{wl} failed");
            if pi == 0 {
                base[wi] = res.metrics.cycles;
                cells.push(format!("{} cy", res.metrics.cycles));
            } else {
                cells.push(format!(
                    "{:+.1}%",
                    100.0 * (res.metrics.cycles as f64 / base[wi] as f64 - 1.0)
                ));
            }
        }
        t.row(&cells);
    }
    println!("\npaper: default (10,5); doubling the Rd/Wr gap degrades Xtreme by up to ~3%\n");

    println!("== fn.2 ablation: request-traffic saved by cache-level clocks ==\n");
    let t = Table::new(
        &["bench", "L1->L2 req B", "+warpts", "saved", "L2->MM req B", "+warpts", "saved"],
        &[8, 13, 13, 7, 13, 13, 7],
    );
    for wl in ["xtreme1", "xtreme2", "xtreme3", "fir", "mm"] {
        let hc = run_workload(&SystemConfig::preset("SM-WT-C-HALCONE"), wl, None);
        let mut gcfg = SystemConfig::preset("SM-WT-C-HALCONE");
        gcfg.set("coherence", "gtsc").unwrap();
        let gt = run_workload(&gcfg, wl, None);
        assert_eq!(hc.metrics.l1.reqs_down, gt.metrics.l1.reqs_down, "{wl}: decisions differ");
        let save = |a: u64, b: u64| format!("{:.1}%", 100.0 * (b - a) as f64 / b as f64);
        t.row(&[
            wl.to_string(),
            hc.metrics.l1.bytes_down.to_string(),
            gt.metrics.l1.bytes_down.to_string(),
            save(hc.metrics.l1.bytes_down, gt.metrics.l1.bytes_down),
            hc.metrics.l2.bytes_down.to_string(),
            gt.metrics.l2.bytes_down.to_string(),
            save(hc.metrics.l2.bytes_down, gt.metrics.l2.bytes_down),
        ]);
    }
    println!(
        "\npaper fn.2: up to 41.7% request / 3.1% response traffic saved (incl. CU<->L1 wires,\n\
         which carry a warpts on *every* op under G-TSC — our CU<->L1 hop is unmetered, so\n\
         the wire-level saving shown here is the L1->L2/L2->MM share only)."
    );
}
