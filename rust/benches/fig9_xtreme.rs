//! E8 — Fig. 9: Xtreme stress tests across vector sizes.
//!
//! SM-WT-C-HALCONE vs SM-WT-NC for Xtreme1/2/3 while the per-vector
//! footprint sweeps from cache-resident to far-beyond-L2 (the paper sweeps
//! 192 KB ... 96 MB; we sweep 192 KB ... 3 MB, covering the same three
//! miss regimes — see DESIGN.md scaling note). Paper: worst-case slowdowns
//! 14.3% (X1) / 12.1% (X2) / 16.8% (X3) at the smallest size, decaying as
//! capacity/conflict misses displace coherency misses.
//!
//!     cargo bench --bench fig9_xtreme

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::metrics::bench::Table;

fn main() {
    // scale -> per-vector footprint: 65536 * scale * 4 bytes.
    let sweeps = [(0.75f64, "192KB"), (3.0, "768KB"), (12.0, "3MB")];
    for (idx, wl) in ["xtreme1", "xtreme2", "xtreme3"].iter().enumerate() {
        println!("== Fig. 9({}): {wl} ==\n", ["a", "b", "c"][idx]);
        let t = Table::new(
            &["vector", "SM-WT-NC cy", "HALCONE cy", "slowdown", "coh-misses"],
            &[8, 13, 13, 9, 11],
        );
        for &(scale, label) in &sweeps {
            let mut nc_cfg = SystemConfig::preset("SM-WT-NC");
            nc_cfg.scale = scale;
            let nc = run_workload(&nc_cfg, wl, None);
            assert!(nc.all_passed(), "{wl}@{label} NC failed");

            let mut hc_cfg = SystemConfig::preset("SM-WT-C-HALCONE");
            hc_cfg.scale = scale;
            let hc = run_workload(&hc_cfg, wl, None);
            assert!(hc.all_passed(), "{wl}@{label} HALCONE failed");

            t.row(&[
                label.into(),
                nc.metrics.cycles.to_string(),
                hc.metrics.cycles.to_string(),
                format!(
                    "{:+.1}%",
                    100.0 * (hc.metrics.cycles as f64 / nc.metrics.cycles as f64 - 1.0)
                ),
                hc.metrics.l1.coherency_misses.to_string(),
            ]);
        }
        println!();
    }
    println!(
        "paper Fig. 9: degradation peaks at the smallest vectors (coherency misses dominate)\n\
         and decays toward ~0.6% once capacity/conflict misses take over."
    );
}
