//! E6/E7 — Fig. 8: strong-scaling studies of SM-WT-C-HALCONE.
//!
//! (a) GPU count 1/2/4/8/16 at 32 CUs each, runtimes normalized to 1 GPU
//!     (paper means: 1.76x / 2.74x / 4.05x / 5.43x);
//! (b) CU count 32/48/64 at 4 GPUs (paper means: 1.12x / 1.24x);
//! (c) L2$<->MM transactions across CU counts (flat for the L2-bottlenecked
//!     benchmarks bfs/bs — the reason they do not scale).
//!
//! Both grids run through the sweep executor as the built-in `fig8`
//! (GPU-count axis) and `fig8cu` (CU-count axis) campaigns, in parallel
//! across all cores.
//!
//!     cargo bench --bench fig8_scalability

use halcone::metrics::bench::Table;
use halcone::metrics::geomean;
use halcone::sweep::exec::{run_campaign, CampaignResult, ExecOptions};
use halcone::sweep::spec::CampaignSpec;
use halcone::workloads::STANDARD;

fn campaign(name: &str) -> CampaignResult {
    let spec = CampaignSpec::builtin(name).unwrap();
    let res = run_campaign(&spec, &ExecOptions::default())
        .unwrap_or_else(|e| panic!("{name} campaign: {e}"));
    assert!(res.all_passed(), "{name} campaign cells failed");
    res
}

fn main() {
    // ---- (a) GPU-count scaling (`fig8` campaign).
    let gpus = campaign("fig8");
    println!("== Fig. 8(a): speed-up vs 1 coherent GPU (32 CUs/GPU) ==\n");
    let gpu_counts = [1u32, 2, 4, 8, 16];
    let t = Table::new(&["bench", "1", "2", "4", "8", "16"], &[8, 7, 7, 7, 7, 7]);
    let mut per_count: Vec<Vec<f64>> = vec![Vec::new(); gpu_counts.len()];
    for wl in STANDARD {
        let base = gpus.expect_metrics("SM-WT-C-HALCONE+n_gpus=1", wl).cycles as f64;
        let mut cells = vec![wl.to_string()];
        for (i, &g) in gpu_counts.iter().enumerate() {
            let m = gpus.expect_metrics(&format!("SM-WT-C-HALCONE+n_gpus={g}"), wl);
            let s = base / m.cycles as f64;
            per_count[i].push(s);
            cells.push(format!("{s:.2}x"));
        }
        t.row(&cells);
    }
    let mut cells = vec!["mean".to_string()];
    for s in &per_count {
        cells.push(format!("{:.2}x", geomean(s)));
    }
    t.row(&cells);
    println!("\npaper Fig. 8(a) means: 1.00x / 1.76x / 2.74x / 4.05x / 5.43x\n");

    // ---- (b) + (c) CU-count scaling at 4 GPUs (`fig8cu` campaign).
    let cus = campaign("fig8cu");
    println!("== Fig. 8(b): speed-up vs 32 CUs/GPU (4 GPUs) ==");
    println!("== Fig. 8(c): L2$<->MM transactions, normalized to 32 CUs ==\n");
    let cu_counts = [32u32, 48, 64];
    let t = Table::new(
        &["bench", "s@32", "s@48", "s@64", "tx@32", "tx@48", "tx@64"],
        &[8, 7, 7, 7, 8, 8, 8],
    );
    let mut per_cu: Vec<Vec<f64>> = vec![Vec::new(); cu_counts.len()];
    for wl in STANDARD {
        let base = cus.expect_metrics("SM-WT-C-HALCONE+cus_per_gpu=32", wl);
        let (base_cy, base_tx) = (base.cycles as f64, base.l2_mm_transactions() as f64);
        let mut speed = vec![];
        let mut tx = vec![];
        for (i, &c) in cu_counts.iter().enumerate() {
            let m = cus.expect_metrics(&format!("SM-WT-C-HALCONE+cus_per_gpu={c}"), wl);
            let s = base_cy / m.cycles as f64;
            per_cu[i].push(s);
            speed.push(format!("{s:.2}x"));
            tx.push(format!("{:.2}", m.l2_mm_transactions() as f64 / base_tx));
        }
        let mut cells = vec![wl.to_string()];
        cells.extend(speed);
        cells.extend(tx);
        t.row(&cells);
    }
    let mut cells = vec!["mean".to_string()];
    for s in &per_cu {
        cells.push(format!("{:.2}x", geomean(s)));
    }
    cells.extend(["-".into(), "-".into(), "-".into()]);
    t.row(&cells);
    println!("\npaper Fig. 8(b) means: 1.00x / 1.12x / 1.24x;");
    println!("paper Fig. 8(c): bfs/bs transactions flat across CU counts (L2 bottleneck)");
}
