//! E6/E7 — Fig. 8: strong-scaling studies of SM-WT-C-HALCONE.
//!
//! (a) GPU count 1/2/4/8/16 at 32 CUs each, runtimes normalized to 1 GPU
//!     (paper means: 1.76x / 2.74x / 4.05x / 5.43x);
//! (b) CU count 32/48/64 at 4 GPUs (paper means: 1.12x / 1.24x);
//! (c) L2$<->MM transactions across CU counts (flat for the L2-bottlenecked
//!     benchmarks bfs/bs — the reason they do not scale).
//!
//!     cargo bench --bench fig8_scalability

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::metrics::bench::Table;
use halcone::metrics::geomean;
use halcone::workloads::STANDARD;

fn main() {
    // ---- (a) GPU-count scaling.
    println!("== Fig. 8(a): speed-up vs 1 coherent GPU (32 CUs/GPU) ==\n");
    let gpu_counts = [1u32, 2, 4, 8, 16];
    let t = Table::new(&["bench", "1", "2", "4", "8", "16"], &[8, 7, 7, 7, 7, 7]);
    let mut per_count: Vec<Vec<f64>> = vec![Vec::new(); gpu_counts.len()];
    for wl in STANDARD {
        let mut base = None;
        let mut cells = vec![wl.to_string()];
        for (i, &g) in gpu_counts.iter().enumerate() {
            let mut cfg = SystemConfig::preset("SM-WT-C-HALCONE");
            cfg.n_gpus = g;
            let res = run_workload(&cfg, wl, None);
            assert!(res.all_passed(), "{wl}@{g}gpus failed");
            let b = *base.get_or_insert(res.metrics.cycles as f64);
            let s = b / res.metrics.cycles as f64;
            per_count[i].push(s);
            cells.push(format!("{s:.2}x"));
        }
        t.row(&cells);
    }
    let mut cells = vec!["mean".to_string()];
    for s in &per_count {
        cells.push(format!("{:.2}x", geomean(s)));
    }
    t.row(&cells);
    println!("\npaper Fig. 8(a) means: 1.00x / 1.76x / 2.74x / 4.05x / 5.43x\n");

    // ---- (b) + (c) CU-count scaling at 4 GPUs.
    println!("== Fig. 8(b): speed-up vs 32 CUs/GPU (4 GPUs) ==");
    println!("== Fig. 8(c): L2$<->MM transactions, normalized to 32 CUs ==\n");
    let cu_counts = [32u32, 48, 64];
    let t = Table::new(
        &["bench", "s@32", "s@48", "s@64", "tx@32", "tx@48", "tx@64"],
        &[8, 7, 7, 7, 8, 8, 8],
    );
    let mut per_cu: Vec<Vec<f64>> = vec![Vec::new(); cu_counts.len()];
    for wl in STANDARD {
        let mut base_cy = None;
        let mut base_tx = None;
        let mut speed = vec![];
        let mut tx = vec![];
        for (i, &c) in cu_counts.iter().enumerate() {
            let mut cfg = SystemConfig::preset("SM-WT-C-HALCONE");
            cfg.cus_per_gpu = c;
            let res = run_workload(&cfg, wl, None);
            assert!(res.all_passed(), "{wl}@{c}cus failed");
            let bc = *base_cy.get_or_insert(res.metrics.cycles as f64);
            let bt = *base_tx.get_or_insert(res.metrics.l2_mm_transactions() as f64);
            let s = bc / res.metrics.cycles as f64;
            per_cu[i].push(s);
            speed.push(format!("{s:.2}x"));
            tx.push(format!("{:.2}", res.metrics.l2_mm_transactions() as f64 / bt));
        }
        let mut cells = vec![wl.to_string()];
        cells.extend(speed);
        cells.extend(tx);
        t.row(&cells);
    }
    let mut cells = vec!["mean".to_string()];
    for s in &per_cu {
        cells.push(format!("{:.2}x", geomean(s)));
    }
    cells.extend(["-".into(), "-".into(), "-".into()]);
    t.row(&cells);
    println!("\npaper Fig. 8(b) means: 1.00x / 1.12x / 1.24x;");
    println!("paper Fig. 8(c): bfs/bs transactions flat across CU counts (L2 bottleneck)");
}
