//! E1 / Fig. 2: SGEMM kernel time, local vs remote (P2P RDMA) placement.
//!
//! The paper ran cuBLAS SGEMM on a DGX-1 with matrices pinned in GPU0's
//! HBM, executing on GPU0 (local) vs GPU1 over NVLink (remote): remote was
//! 12.4x (32768^2) ... 2895x (512^2) slower. We reproduce the *shape* —
//! remote catastrophically slower, the ratio shrinking as size grows — on
//! the simulated 2-GPU system (sizes scaled ~256x down; see DESIGN.md E1).
//!
//!     cargo bench --bench fig2_sgemm_rdma

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_built;
use halcone::coordinator::topology::copy_delay;
use halcone::metrics::bench::Table;
use halcone::workloads::{self, Workload};

fn pin_to_gpu(mut wl: Workload, target: usize, n_gpus: usize) -> Workload {
    for ph in &mut wl.phases {
        let per_cu: Vec<Vec<Vec<_>>> = std::mem::take(&mut ph.work);
        let cus = per_cu[0].len();
        let mut merged: Vec<Vec<Vec<Vec<halcone::gpu::CuOp>>>> = Vec::new();
        merged.resize_with(n_gpus, || {
            let mut v = Vec::new();
            v.resize_with(cus, Vec::new);
            v
        });
        for gpu_work in per_cu {
            for (cu, wfs) in gpu_work.into_iter().enumerate() {
                for wf in wfs {
                    if !wf.is_empty() {
                        merged[target][cu].push(wf);
                    }
                }
            }
        }
        for gw in merged.iter_mut() {
            for cw in gw.iter_mut() {
                if cw.is_empty() {
                    cw.push(Vec::new());
                }
            }
        }
        ph.work = merged;
    }
    wl
}

fn main() {
    println!("== Fig. 2: SGEMM kernel time, matrices resident in GPU0's memory ==\n");
    let t = Table::new(
        &["matrix", "local cy", "remote cy", "remote/local", "paper"],
        &[8, 12, 12, 13, 18],
    );
    let paper = ["~2895x (512^2)", "...", "~12.4x (32768^2)"];
    for (idx, scale) in [0.125f64, 0.25, 0.5].into_iter().enumerate() {
        let mut cycles = Vec::new();
        for target in [0usize, 1] {
            let mut cfg = SystemConfig::preset("RDMA-WB-NC");
            cfg.n_gpus = 2;
            cfg.scale = scale;
            let params = cfg.workload_params();
            let wl = pin_to_gpu(workloads::build("mm", &params), target, 2);
            let delay = copy_delay(&cfg, &wl);
            let res = run_built(&cfg, wl, None);
            assert!(res.all_passed(), "checks failed");
            cycles.push(res.metrics.cycles - delay);
        }
        let n = (256.0 * scale) as usize;
        t.row(&[
            format!("{n}^2"),
            cycles[0].to_string(),
            cycles[1].to_string(),
            format!("{:.2}x", cycles[1] as f64 / cycles[0] as f64),
            paper[idx].into(),
        ]);
    }
    println!(
        "\nshape check: remote >> local, ratio decreasing with matrix size (compute amortizes \
         the NUMA penalty) — matching the paper's trend on scaled-down sizes."
    );
}
