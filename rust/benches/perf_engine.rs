//! Simulator performance (EXPERIMENTS.md §Perf, L3): events/second on the
//! hot paths. Not a paper figure — the §Perf before/after numbers come
//! from here, and every run appends a machine-readable snapshot to
//! `BENCH_perf.json` so the perf trajectory accumulates (docs/PERF.md).
//!
//!     cargo bench --bench perf_engine                       # full suite
//!     cargo bench --bench perf_engine -- rl fir             # workload subset (CI smoke)
//!     cargo bench --bench perf_engine -- rl --shards 1,4    # sharded-engine axis
//!     cargo bench --bench perf_engine -- rl --shards 1,4 --fabric ports,hub \
//!         --preset SM-WT-C-HALCONE --preset RDMA-WB-NC      # hub-split before/after rows

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::metrics::bench::{measure, Table};
use halcone::sim::{CompId, Component, Ctx, Cycle, Engine, Link, Msg};
use halcone::sweep::json::Value;

/// Raw engine throughput: a ping-pong pair exchanging N messages.
struct Pinger {
    name: String,
    peer: CompId,
    link: halcone::sim::LinkId,
    remaining: u32,
}
impl Component for Pinger {
    halcone::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }
    fn handle(&mut self, _now: Cycle, _msg: Msg, ctx: &mut Ctx) {
        if self.remaining > 0 {
            self.remaining -= 1;
            ctx.send(self.link, self.peer, 64, Msg::Tick);
        }
    }
}

fn engine_throughput(n: u32) -> f64 {
    let m = measure(1, 5, || {
        let mut e = Engine::new();
        let l = e.add_link(Link::new("l", 3, 64));
        e.add(Box::new(Pinger { name: "a".into(), peer: CompId(1), link: l, remaining: n }));
        e.add(Box::new(Pinger { name: "b".into(), peer: CompId(0), link: l, remaining: n }));
        e.post(0, CompId(0), Msg::Tick);
        e.run_to_completion();
        e.events_processed()
    });
    2.0 * n as f64 / m.median_s
}

const ALL_WORKLOADS: [&str; 5] = ["rl", "fir", "bfs", "mm", "xtreme1"];

fn main() {
    // `cargo bench -- rl fir` restricts the full-system rows (the CI
    // perf-smoke step runs a fast subset) and `--shards 1,4` adds a
    // sharded-engine axis; cargo may also pass harness flags like
    // `--bench`, which we ignore.
    let mut selected: Vec<String> = Vec::new();
    let mut shards_axis: Vec<u32> = vec![1];
    let mut fabric_axis: Vec<String> = vec!["ports".into()];
    let mut presets: Vec<String> = Vec::new();
    let mut argv = std::env::args().skip(1);
    while let Some(arg) = argv.next() {
        if arg == "--fabric" {
            let list = argv.next().unwrap_or_else(|| {
                eprintln!("error: --fabric wants a comma-separated list, e.g. ports,hub");
                std::process::exit(2)
            });
            fabric_axis = list
                .split(',')
                .map(|s| {
                    let s = s.trim().to_string();
                    if s != "ports" && s != "hub" {
                        eprintln!("error: --fabric {list}: '{s}' is not ports|hub");
                        std::process::exit(2);
                    }
                    s
                })
                .collect();
        } else if arg == "--preset" {
            let p = argv.next().unwrap_or_else(|| {
                eprintln!("error: --preset wants a configuration name");
                std::process::exit(2)
            });
            if let Err(e) = SystemConfig::try_preset(&p) {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
            presets.push(p);
        } else if arg == "--shards" {
            let list = argv.next().unwrap_or_else(|| {
                eprintln!("error: --shards wants a comma-separated list, e.g. 1,4");
                std::process::exit(2)
            });
            shards_axis = list
                .split(',')
                .map(|s| match s.trim().parse::<u32>() {
                    Ok(n) if n >= 1 => n,
                    _ => {
                        eprintln!("error: --shards {list}: '{s}' is not a thread count >= 1");
                        std::process::exit(2)
                    }
                })
                .collect();
        } else if !arg.starts_with('-') {
            selected.push(arg);
        }
    }
    for s in &selected {
        if !ALL_WORKLOADS.contains(&s.as_str()) {
            eprintln!(
                "error: unknown workload '{s}' (available: {})",
                ALL_WORKLOADS.join(", ")
            );
            std::process::exit(2);
        }
    }
    let workloads: Vec<&str> = if selected.is_empty() {
        ALL_WORKLOADS.to_vec()
    } else {
        ALL_WORKLOADS
            .iter()
            .copied()
            .filter(|w| selected.iter().any(|s| s == w))
            .collect()
    };
    if presets.is_empty() {
        presets.push("SM-WT-C-HALCONE".into());
    }

    println!("== L3 simulator performance ==\n");
    let ping_pong = engine_throughput(2_000_000);
    println!("raw event loop (ping-pong): {:.1} M events/s\n", ping_pong / 1e6);

    let t = Table::new(
        &[
            "preset", "fabric", "workload", "shards", "events", "sim cycles", "host s", "Mev/s",
            "sim-ops/s",
        ],
        &[16, 6, 9, 6, 11, 12, 8, 8, 11],
    );
    let mut rows: Vec<Value> = Vec::new();
    for preset in &presets {
        for wl in &workloads {
            for fabric in &fabric_axis {
                for &shards in &shards_axis {
                    let mut cfg = SystemConfig::preset(preset);
                    cfg.set("fabric", fabric).unwrap();
                    cfg.shards = shards;
                    // Timed externally of run_workload's own clock for a
                    // median of 3.
                    let mut last = None;
                    let m = measure(0, 3, || {
                        let res = run_workload(&cfg, wl, None);
                        let r =
                            (res.metrics.events, res.metrics.cycles, res.metrics.l1.reqs_in);
                        last = Some(r);
                        r
                    });
                    let (events, cycles, ops) = last.unwrap();
                    let mev_s = events as f64 / m.median_s / 1e6;
                    t.row(&[
                        preset.clone(),
                        fabric.clone(),
                        (*wl).into(),
                        shards.to_string(),
                        events.to_string(),
                        cycles.to_string(),
                        format!("{:.3}", m.median_s),
                        format!("{:.1}", mev_s),
                        format!("{:.1}M", ops as f64 / m.median_s / 1e6),
                    ]);
                    rows.push(Value::Obj(vec![
                        ("preset".into(), Value::str(preset)),
                        ("fabric".into(), Value::str(fabric)),
                        ("workload".into(), Value::str(*wl)),
                        ("shards".into(), Value::u64(shards as u64)),
                        ("events".into(), Value::u64(events)),
                        ("cycles".into(), Value::u64(cycles)),
                        ("host_seconds".into(), Value::f64(m.median_s)),
                        ("mev_per_s".into(), Value::f64(mev_s)),
                        ("events_per_sec".into(), Value::f64(events as f64 / m.median_s)),
                    ]));
                }
            }
        }
    }

    // Machine-readable artifact for the perf log (appended-to by each
    // run via overwrite; history lives in docs/PERF.md + CI summaries).
    let doc = Value::Obj(vec![
        ("bench".into(), Value::str("perf_engine")),
        ("ping_pong_events_per_sec".into(), Value::f64(ping_pong)),
        (
            "shards_axis".into(),
            Value::Arr(shards_axis.iter().map(|&s| Value::u64(s as u64)).collect()),
        ),
        (
            "fabric_axis".into(),
            Value::Arr(fabric_axis.iter().map(Value::str).collect()),
        ),
        ("workloads".into(), Value::Arr(rows)),
    ]);
    let mut out = doc.to_pretty();
    out.push('\n');
    match std::fs::write("BENCH_perf.json", &out) {
        Ok(()) => println!("\nwrote BENCH_perf.json"),
        Err(e) => eprintln!("\nwarning: could not write BENCH_perf.json: {e}"),
    }

    println!("\ntargets (DESIGN.md §Perf): > 2 M events/s on full-system workloads,");
    println!("no allocation in the event hot loop (tests/alloc_discipline.rs)");
}
