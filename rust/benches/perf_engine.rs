//! Simulator performance (EXPERIMENTS.md §Perf, L3): events/second on the
//! hot paths. Not a paper figure — the §Perf before/after numbers come
//! from here.
//!
//!     cargo bench --bench perf_engine

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::metrics::bench::{measure, Table};
use halcone::sim::{CompId, Component, Ctx, Cycle, Engine, Link, Msg};

/// Raw engine throughput: a ping-pong pair exchanging N messages.
struct Pinger {
    name: String,
    peer: CompId,
    link: halcone::sim::LinkId,
    remaining: u32,
}
impl Component for Pinger {
    halcone::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }
    fn handle(&mut self, _now: Cycle, _msg: Msg, ctx: &mut Ctx) {
        if self.remaining > 0 {
            self.remaining -= 1;
            ctx.send(self.link, self.peer, 64, Msg::Tick);
        }
    }
}

fn engine_throughput(n: u32) -> f64 {
    let m = measure(1, 5, || {
        let mut e = Engine::new();
        let l = e.add_link(Link::new("l", 3, 64));
        e.add(Box::new(Pinger { name: "a".into(), peer: CompId(1), link: l, remaining: n }));
        e.add(Box::new(Pinger { name: "b".into(), peer: CompId(0), link: l, remaining: n }));
        e.post(0, CompId(0), Msg::Tick);
        e.run_to_completion();
        e.events_processed()
    });
    2.0 * n as f64 / m.median_s
}

fn main() {
    println!("== L3 simulator performance ==\n");
    println!(
        "raw event loop (ping-pong): {:.1} M events/s\n",
        engine_throughput(2_000_000) / 1e6
    );

    let t = Table::new(
        &["workload", "events", "sim cycles", "host s", "Mev/s", "sim-ops/s"],
        &[9, 11, 12, 8, 8, 11],
    );
    for wl in ["rl", "fir", "bfs", "mm", "xtreme1"] {
        let cfg = SystemConfig::preset("SM-WT-C-HALCONE");
        // Timed externally of run_workload's own clock for a median of 3.
        let mut last = None;
        let m = measure(0, 3, || {
            let res = run_workload(&cfg, wl, None);
            let r = (res.metrics.events, res.metrics.cycles, res.metrics.l1.reqs_in);
            last = Some(r);
            r
        });
        let (events, cycles, ops) = last.unwrap();
        t.row(&[
            wl.into(),
            events.to_string(),
            cycles.to_string(),
            format!("{:.3}", m.median_s),
            format!("{:.1}", events as f64 / m.median_s / 1e6),
            format!("{:.1}M", ops as f64 / m.median_s / 1e6),
        ]);
    }
    println!("\ntargets (DESIGN.md §Perf): > 2 M events/s on full-system workloads,");
    println!("no allocation in the event hot loop (validated by flamegraph, see EXPERIMENTS.md)");
}
