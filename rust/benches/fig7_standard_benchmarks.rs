//! E3/E4/E5/E11 — Fig. 7: the paper's main result.
//!
//! (a) speed-up of the five §4.1 configurations over RDMA-WB-NC for the 11
//!     standard benchmarks (paper means: HMG 1.5x, SM-WB 3.9x, SM-WT 4.6x,
//!     HALCONE 4.6x — i.e. <=1% coherence overhead);
//! (b) L2$<->MM transactions normalized to SM-WB-NC (paper: WT ~ +22.7%);
//! (c) L1$<->L2$ transactions normalized to SM-WB-NC (HALCONE ~ +1%).
//!
//! The grid itself is the built-in `fig7` campaign, driven through the
//! sweep executor (all cores; equivalent to
//! `halcone sweep --campaign fig7`); the tables below post-process the
//! campaign result.
//!
//!     cargo bench --bench fig7_standard_benchmarks

use halcone::config::SystemConfig;
use halcone::metrics::bench::Table;
use halcone::metrics::geomean;
use halcone::sweep::exec::{run_campaign, ExecOptions};
use halcone::sweep::spec::CampaignSpec;
use halcone::workloads::STANDARD;

fn main() {
    let spec = CampaignSpec::builtin("fig7").unwrap();
    let campaign = run_campaign(&spec, &ExecOptions::default())
        .unwrap_or_else(|e| panic!("fig7 campaign: {e}"));
    assert!(campaign.all_passed(), "fig7 campaign cells failed");

    let presets = SystemConfig::PRESETS;

    // ---- Fig. 7(a): speed-up vs RDMA-WB-NC.
    println!("== Fig. 7(a): speed-up vs RDMA-WB-NC ==\n");
    let mut headers = vec!["bench"];
    headers.extend(presets.iter().copied());
    let widths = [8usize, 11, 15, 9, 9, 16];
    let t = Table::new(&headers, &widths);
    let mut per_cfg: Vec<Vec<f64>> = vec![Vec::new(); presets.len()];
    for wl in STANDARD {
        let base = campaign.expect_metrics(presets[0], wl).cycles as f64;
        let mut cells = vec![wl.to_string()];
        for (c, p) in presets.iter().enumerate() {
            let s = base / campaign.expect_metrics(p, wl).cycles as f64;
            per_cfg[c].push(s);
            cells.push(format!("{s:.2}x"));
        }
        t.row(&cells);
    }
    let mut cells = vec!["mean".to_string()];
    for s in &per_cfg {
        cells.push(format!("{:.2}x", geomean(s)));
    }
    t.row(&cells);
    println!("\npaper Fig. 7(a) means: 1.00x / 1.5x / 3.9x / 4.6x / 4.6x\n");

    // ---- Fig. 7(b): L2<->MM transactions normalized to SM-WB-NC (idx 2).
    println!("== Fig. 7(b): L2$<->MM transactions (normalized to SM-WB-NC) ==\n");
    let t = Table::new(&["bench", "SM-WB-NC", "SM-WT-NC", "SM-WT-C-HALCONE"], &[8, 12, 12, 16]);
    let mut wt_ratio = Vec::new();
    let mut hc_ratio = Vec::new();
    for wl in STANDARD {
        let wb = campaign.expect_metrics("SM-WB-NC", wl).l2_mm_transactions() as f64;
        let wt = campaign.expect_metrics("SM-WT-NC", wl).l2_mm_transactions() as f64 / wb;
        let hc = campaign.expect_metrics("SM-WT-C-HALCONE", wl).l2_mm_transactions() as f64 / wb;
        wt_ratio.push(wt);
        hc_ratio.push(hc);
        t.row(&[wl.to_string(), "1.00".into(), format!("{wt:.2}"), format!("{hc:.2}")]);
    }
    t.row(&[
        "mean".into(),
        "1.00".into(),
        format!("{:.2}", geomean(&wt_ratio)),
        format!("{:.2}", geomean(&hc_ratio)),
    ]);
    println!("\npaper: WT issues ~22.7% more L2<->MM transactions than WB; HALCONE ~ +1% over WT\n");

    // ---- Fig. 7(c): L1<->L2 transactions normalized to SM-WB-NC.
    println!("== Fig. 7(c): L1$<->L2$ transactions (normalized to SM-WB-NC) ==\n");
    let t = Table::new(&["bench", "SM-WB-NC", "SM-WT-NC", "SM-WT-C-HALCONE"], &[8, 12, 12, 16]);
    let mut hc1 = Vec::new();
    for wl in STANDARD {
        let wb = campaign.expect_metrics("SM-WB-NC", wl).l1_l2_transactions() as f64;
        let wt = campaign.expect_metrics("SM-WT-NC", wl).l1_l2_transactions() as f64 / wb;
        let hc = campaign.expect_metrics("SM-WT-C-HALCONE", wl).l1_l2_transactions() as f64 / wb;
        hc1.push(hc);
        t.row(&[wl.to_string(), "1.00".into(), format!("{wt:.2}"), format!("{hc:.2}")]);
    }
    t.row(&["mean".into(), "1.00".into(), "-".into(), format!("{:.2}", geomean(&hc1))]);
    println!("\npaper: L1<->L2 transactions identical for WB/WT; HALCONE adds ~1% (coherency re-fetches)");

    // ---- E11 headline claims.
    let hc_mean = geomean(&per_cfg[4]);
    let hmg_mean = geomean(&per_cfg[1]);
    println!("\nclaims: HALCONE/RDMA = {hc_mean:.2}x (paper 4.6x);");
    println!("        HALCONE/HMG  = {:.2}x (paper 3.0x);", hc_mean / hmg_mean);
    println!(
        "        HALCONE overhead vs SM-WT-NC = {:+.2}% (paper ~1%)",
        100.0 * (geomean(&per_cfg[3]) / hc_mean - 1.0)
    );
}
