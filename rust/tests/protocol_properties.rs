//! Property-based integration tests over the full simulated stack:
//! random micro-programs executed through every cache hierarchy must
//! produce exactly the memory image a sequential shadow interpreter
//! predicts. These are the coordinator-invariant sweeps DESIGN.md S20
//! promises: any coherence/routing/batching bug that corrupts or loses a
//! write shows up as a shadow divergence.

use std::collections::HashMap;

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_built;
use halcone::gpu::cu::LANES;
use halcone::gpu::CuOp;
use halcone::prop_assert;
use halcone::proptools::{check_with, Rng};
use halcone::workloads::{empty_work, owners, Phase, Verify, Workload, WorkloadParams};

fn small_cfg(preset: &str) -> SystemConfig {
    let mut cfg = SystemConfig::preset(preset);
    cfg.n_gpus = 2;
    cfg.cus_per_gpu = 2;
    cfg.wavefronts_per_cu = 2;
    cfg.l2_banks = 2;
    cfg.stacks_per_gpu = 2;
    cfg.gpu_mem_bytes = 64 << 20;
    cfg
}

/// Sequential shadow interpreter for a single wavefront's program.
fn shadow_exec(ops: &[CuOp], mem: &mut HashMap<u64, f32>) {
    let mut regs = [[0.0f32; LANES]; 16];
    for op in ops {
        match *op {
            CuOp::Ld { reg, addr } => regs[reg as usize] = [*mem.get(&addr).unwrap_or(&0.0); LANES],
            CuOp::LdV { reg, addr, n } => {
                let mut v = [0.0f32; LANES];
                for (l, vl) in v.iter_mut().enumerate().take(n as usize) {
                    *vl = *mem.get(&(addr + 4 * l as u64)).unwrap_or(&0.0);
                }
                regs[reg as usize] = v;
            }
            CuOp::St { addr, reg } => {
                mem.insert(addr, regs[reg as usize][0]);
            }
            CuOp::StV { addr, reg, n } => {
                for l in 0..n as usize {
                    mem.insert(addr + 4 * l as u64, regs[reg as usize][l]);
                }
            }
            CuOp::MovImm { dst, imm } => regs[dst as usize] = [imm; LANES],
            CuOp::Add { dst, a, b } => {
                for l in 0..LANES {
                    regs[dst as usize][l] = regs[a as usize][l] + regs[b as usize][l];
                }
            }
            CuOp::Sub { dst, a, b } => {
                for l in 0..LANES {
                    regs[dst as usize][l] = regs[a as usize][l] - regs[b as usize][l];
                }
            }
            CuOp::Mul { dst, a, b } => {
                for l in 0..LANES {
                    regs[dst as usize][l] = regs[a as usize][l] * regs[b as usize][l];
                }
            }
            CuOp::Min { dst, a, b } => {
                for l in 0..LANES {
                    regs[dst as usize][l] = regs[a as usize][l].min(regs[b as usize][l]);
                }
            }
            CuOp::Max { dst, a, b } => {
                for l in 0..LANES {
                    regs[dst as usize][l] = regs[a as usize][l].max(regs[b as usize][l]);
                }
            }
            CuOp::Red { dst, src } => {
                let s: f32 = regs[src as usize].iter().sum();
                regs[dst as usize] = [s; LANES];
            }
            CuOp::Pack { dst, lane, src } => {
                let v = regs[src as usize][0];
                regs[dst as usize][lane as usize] = v;
            }
            CuOp::Delay { .. } => {}
        }
    }
}

/// Generate a random single-wavefront program over a private 64-line
/// region starting at `base`, with value provenance through registers.
fn random_program(rng: &mut Rng, base: u64, ops_len: usize) -> Vec<CuOp> {
    let mut ops = vec![CuOp::MovImm { dst: 0, imm: rng.next_f32() }];
    for _ in 0..ops_len {
        let addr = base + 4 * rng.below(16 * 64); // 64 lines of f32
        match rng.below(10) {
            0..=2 => ops.push(CuOp::Ld { reg: (rng.below(4)) as u8, addr }),
            3..=4 => {
                let line_off = (addr / 4) % 16;
                let n = (rng.below(16 - line_off) + 1) as u8;
                ops.push(CuOp::LdV { reg: (rng.below(4)) as u8, addr, n });
            }
            5..=6 => ops.push(CuOp::St { addr, reg: (rng.below(4)) as u8 }),
            7 => {
                let line_off = (addr / 4) % 16;
                let n = (rng.below(16 - line_off) + 1) as u8;
                ops.push(CuOp::StV { addr, reg: (rng.below(4)) as u8, n });
            }
            8 => ops.push(CuOp::Add {
                dst: (rng.below(4)) as u8,
                a: (rng.below(4)) as u8,
                b: (rng.below(4)) as u8,
            }),
            _ => ops.push(CuOp::Mul {
                dst: (rng.below(4)) as u8,
                a: (rng.below(4)) as u8,
                b: (rng.below(4)) as u8,
            }),
        }
    }
    ops
}

/// The big invariant: random programs over *disjoint* per-wavefront
/// regions, run through the full simulated hierarchy, leave memory exactly
/// as the shadow interpreter predicts — for every §4.1 configuration.
fn random_trace_memory_check(preset: &'static str, seed: u64) {
    use halcone::coordinator::topology;
    use halcone::sim::Msg;

    check_with(&format!("random trace memory [{preset}]"), seed, 10, |rng| {
        let cfg = small_cfg(preset);
        let params: WorkloadParams = cfg.workload_params();
        let own = owners(&params);

        let mut work = empty_work(&params);
        let mut shadow: HashMap<u64, f32> = HashMap::new();
        for (s, &(gpu, cu)) in own.iter().enumerate() {
            for w in 0..params.wavefronts_per_cu as usize {
                let base = gpu as u64 * cfg.gpu_mem_bytes
                    + 0x10000
                    + (s * params.wavefronts_per_cu as usize + w) as u64 * 0x1000;
                let prog = random_program(rng, base, 80);
                shadow_exec(&prog, &mut shadow);
                work[gpu as usize][cu][w] = prog;
            }
        }

        let wl = Workload {
            name: "random".into(),
            init: vec![],
            phases: vec![Phase { name: "p0".into(), work }],
            checks: vec![],
            kind: "Synthetic",
        };
        let mut sys = topology::build(&cfg, wl);
        sys.engine.post(0, sys.driver, Msg::Tick);
        sys.engine.run_to_completion();

        let mut mem = sys.mem.borrow_mut();
        for (&addr, &want) in &shadow {
            let got = mem.read_f32(addr);
            prop_assert!(
                got == want,
                "addr {addr:#x}: simulated {got} != shadow {want}"
            );
        }
        Ok(())
    });
}

#[test]
fn random_traces_match_shadow_halcone() {
    random_trace_memory_check("SM-WT-C-HALCONE", 0xB);
}

#[test]
fn random_traces_match_shadow_sm_wt_nc() {
    random_trace_memory_check("SM-WT-NC", 0xC);
}

#[test]
fn random_traces_match_shadow_sm_wb_nc() {
    random_trace_memory_check("SM-WB-NC", 0xD);
}

#[test]
fn random_traces_match_shadow_rdma_nc() {
    random_trace_memory_check("RDMA-WB-NC", 0xE);
}

#[test]
fn random_traces_match_shadow_hmg() {
    random_trace_memory_check("RDMA-WB-C-HMG", 0xF);
}

/// Cross-phase producer/consumer visibility: phase 0 writes a region from
/// one GPU, phase 1 reads it from the *other* GPU and copies it; the copy
/// must equal the original under every protocol (the fence contract).
#[test]
fn cross_gpu_producer_consumer_all_presets() {
    for preset in SystemConfig::PRESETS {
        check_with(&format!("producer/consumer [{preset}]"), 0x77, 8, |rng| {
            let cfg = small_cfg(preset);
            let params: WorkloadParams = cfg.workload_params();

            let src = 0x40000u64; // GPU0 partition
            let dst = cfg.gpu_mem_bytes + 0x40000; // GPU1 partition
            let n = 64usize;
            let vals: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();

            // Phase 0: GPU0/CU0 writes vals to src.
            let mut w0 = empty_work(&params);
            let mut ops = vec![];
            for (i, v) in vals.iter().enumerate() {
                ops.push(CuOp::MovImm { dst: 0, imm: *v });
                ops.push(CuOp::St { addr: src + 4 * i as u64, reg: 0 });
            }
            w0[0][0][0] = ops;

            // Phase 1: GPU1/CU1 copies src -> dst.
            let mut w1 = empty_work(&params);
            let mut ops = vec![];
            for i in 0..n {
                ops.push(CuOp::Ld { reg: 1, addr: src + 4 * i as u64 });
                ops.push(CuOp::St { addr: dst + 4 * i as u64, reg: 1 });
            }
            w1[1][1][0] = ops;

            let wl = Workload {
                name: "pc".into(),
                init: vec![],
                phases: vec![
                    Phase { name: "produce".into(), work: w0 },
                    Phase { name: "consume".into(), work: w1 },
                ],
                checks: vec![],
                kind: "Synthetic",
            };

            use halcone::coordinator::topology;
            use halcone::sim::Msg;
            let mut sys = topology::build(&cfg, wl);
            sys.engine.post(0, sys.driver, Msg::Tick);
            sys.engine.run_to_completion();
            let mut mem = sys.mem.borrow_mut();
            for (i, v) in vals.iter().enumerate() {
                let got = mem.read_f32(dst + 4 * i as u64);
                prop_assert!(
                    got == *v,
                    "[{preset}] copy[{i}]: {got} != {v} (stale cross-GPU read)"
                );
            }
            Ok(())
        });
    }
}

/// Determinism: identical configs + programs give identical cycle counts.
#[test]
fn simulation_is_deterministic_property() {
    check_with("determinism", 0x5EED, 6, |rng| {
        let seed = rng.next_u64();
        let run = |seed: u64| {
            let cfg = small_cfg("SM-WT-C-HALCONE");
            let params: WorkloadParams = cfg.workload_params();
            let mut r = Rng(seed);
            let mut work = empty_work(&params);
            work[0][0][0] = random_program(&mut r, 0x20000, 100);
            work[1][1][1] = random_program(&mut r, cfg.gpu_mem_bytes + 0x20000, 100);
            let wl = Workload {
                name: "det".into(),
                init: vec![],
                phases: vec![Phase { name: "p".into(), work }],
                checks: vec![],
                kind: "Synthetic",
            };
            let res = run_built(&cfg, wl, None);
            (res.metrics.cycles, res.metrics.events)
        };
        prop_assert!(run(seed) == run(seed), "same seed diverged");
        Ok(())
    });
}
