//! The sharded-engine determinism contract (ISSUE 3): the logical
//! partition — not the thread count — defines event order, so any
//! `shards` level must reproduce the sequential engine bit for bit.
//!
//! Three layers:
//! * campaign level: canonical `campaign.json` byte-identity between
//!   `shards=1` and `shards=4` on the smoke grid and on a shrunken
//!   fig7-shaped grid (all five §4.1 presets — SM, RDMA and HMG
//!   partitions all cross shards differently);
//! * engine level: a toy multi-shard system's per-component delivery
//!   traces (including window-quantized control hops) are identical
//!   across worker-thread counts;
//! * queue level: the ordering property lives in
//!   `tests/unit_properties.rs` (`calendar_queue_orders_shard_tagged_seqs`).

use halcone::sim::{CompId, Component, Ctx, Cycle, Engine, Link, LinkId, Msg};
use halcone::sweep::exec::{run_campaign, ExecOptions};
use halcone::sweep::report;
use halcone::sweep::spec::CampaignSpec;

fn canonical_with_shards(spec: &CampaignSpec, shards: usize) -> String {
    let opts = ExecOptions { jobs: 2, progress: false, shards: Some(shards), ..Default::default() };
    let res = run_campaign(spec, &opts).unwrap();
    assert!(res.all_passed(), "campaign {} failed under shards={shards}", spec.name);
    report::to_json_canonical(&res)
}

#[test]
fn smoke_campaign_is_byte_identical_across_shards() {
    let spec = CampaignSpec::builtin("smoke").unwrap();
    let serial = canonical_with_shards(&spec, 1);
    let parallel = canonical_with_shards(&spec, 4);
    assert_eq!(serial, parallel, "canonical campaign.json differs between shards=1 and shards=4");
}

#[test]
fn fig7_grid_is_byte_identical_across_shards() {
    // The fig7 grid shape (all five §4.1 presets) at CI-friendly
    // geometry: the SM partitions cross shards at the switch complex,
    // the RDMA/HMG ones at the PCIe switch, with per-GPU memory stacks
    // inside the GPU shards — every partition flavor in one grid.
    let mut spec = CampaignSpec::builtin("fig7").unwrap();
    spec.workloads = vec!["rl".into(), "fir".into()];
    spec.fixed.extend(
        [
            ("n_gpus", "2"),
            ("cus_per_gpu", "2"),
            ("wavefronts_per_cu", "2"),
            ("l2_banks", "2"),
            ("stacks_per_gpu", "2"),
            ("gpu_mem_bytes", "67108864"),
            ("scale", "0.05"),
        ]
        .map(|(k, v)| (k.to_string(), v.to_string())),
    );
    let serial = canonical_with_shards(&spec, 1);
    let parallel = canonical_with_shards(&spec, 4);
    assert_eq!(serial, parallel, "fig7-shaped canonical artifact differs across shards");
}

/// Ring node: forwards link traffic to the next shard, emits a
/// zero-delay control hop every third forward (exercising barrier
/// quantization) and keeps its own shard busy with local echo events.
struct Node {
    name: String,
    next: CompId,
    link: LinkId,
    hops: u32,
    pub trace: Vec<(Cycle, u64)>,
}

impl Component for Node {
    halcone::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }
    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match msg {
            Msg::Tick => {
                self.trace.push((now, 0));
                if self.hops > 0 {
                    self.hops -= 1;
                    let next = self.next;
                    ctx.send(self.link, next, 64, Msg::Tick);
                    if self.hops % 3 == 0 {
                        // Linkless cross-shard hop: lands at the window
                        // barrier, deterministically.
                        ctx.schedule(0, next, Msg::DmaDone { bytes: self.hops as u64 });
                    }
                    ctx.schedule(2, ctx.self_id, Msg::StartPhase { phase: self.hops });
                }
            }
            Msg::DmaDone { bytes } => self.trace.push((now, 1000 + bytes)),
            Msg::StartPhase { phase } => self.trace.push((now, 2000 + phase as u64)),
            other => panic!("{}: unexpected {other:?}", self.name),
        }
    }
}

fn run_ring(threads: usize) -> (Cycle, u64, Vec<Vec<(Cycle, u64)>>) {
    const N: u32 = 3;
    // Ring links declared per shard pair: latency 9 + 1 serialization
    // cycle = the per-pair lookahead 10, which equals the base, so the
    // adaptive window matrix reproduces the fixed-lookahead schedule.
    let mut e = Engine::sharded(N, 10);
    let links: Vec<LinkId> = (0..N)
        .map(|i| e.add_link_between(i, (i + 1) % N, Link::new(format!("l{i}"), 9, 64)))
        .collect();
    for i in 0..N {
        let next = CompId((i + 1) % N);
        e.add_to(
            i,
            Box::new(Node {
                name: format!("n{i}"),
                next,
                link: links[i as usize],
                hops: 40,
                trace: Vec::new(),
            }),
        );
    }
    e.set_threads(threads);
    e.post(0, CompId(0), Msg::Tick);
    let end = e.run_to_completion();
    let traces = (0..N).map(|i| e.downcast::<Node>(CompId(i)).trace.clone()).collect();
    (end, e.events_processed(), traces)
}

#[test]
fn windowed_merge_is_invariant_to_worker_threads() {
    let reference = run_ring(1);
    assert!(reference.1 > 100, "ring must generate real traffic: {} events", reference.1);
    for threads in [2, 3, 8] {
        let got = run_ring(threads);
        assert_eq!(got.0, reference.0, "end time differs at threads={threads}");
        assert_eq!(got.1, reference.1, "event count differs at threads={threads}");
        assert_eq!(got.2, reference.2, "delivery traces differ at threads={threads}");
    }
}

#[test]
fn protocol_smokes_are_byte_identical_at_g_plus_1_shards() {
    // shards = G+1 = 3 for the smoke geometry (n_gpus = 2): one worker
    // per logical shard of the partitioned ports fabric. Each protocol
    // crosses shards differently (HALCONE through per-GPU fabric ports
    // to remote MCs/TSUs, HMG/NC over per-GPU PCIe ports).
    for name in ["smoke-halcone", "smoke-hmg", "smoke-none", "smoke-tardis", "smoke-hlc"] {
        let spec = CampaignSpec::builtin(name).unwrap();
        let serial = canonical_with_shards(&spec, 1);
        let parallel = canonical_with_shards(&spec, 3);
        assert_eq!(serial, parallel, "{name} differs between shards=1 and shards=3");
    }
}

#[test]
fn faulted_run_is_byte_identical_across_shards() {
    // Fault-link ordinals are assigned in configuration order, which now
    // includes the inter-port fabric links — the schedule must replay
    // identically at every worker-thread count.
    let spec = CampaignSpec::parse(
        "name = faulted-shards\n\
         presets = SM-WT-C-HALCONE,RDMA-WB-NC\n\
         workloads = fir\n\
         set.n_gpus = 2\n\
         set.cus_per_gpu = 2\n\
         set.wavefronts_per_cu = 2\n\
         set.l2_banks = 2\n\
         set.stacks_per_gpu = 2\n\
         set.gpu_mem_bytes = 67108864\n\
         set.scale = 0.05\n\
         set.faults = seed=7;window=200;degrade=0.5;latmul=3;bwdiv=2;outage=0.4\n",
    )
    .unwrap();
    let serial = canonical_with_shards(&spec, 1);
    let parallel = canonical_with_shards(&spec, 3);
    assert_eq!(serial, parallel, "faulted canonical artifact differs across shards");
}

#[test]
fn single_run_metrics_are_identical_across_shards_setting() {
    // Full-system single cell: the runner path (not the sweep executor)
    // honors `cfg.shards` the same way.
    use halcone::config::SystemConfig;
    use halcone::coordinator::runner::run_workload;
    let run = |shards: u32| {
        let mut cfg = SystemConfig::preset("SM-WT-C-HALCONE");
        cfg.n_gpus = 2;
        cfg.cus_per_gpu = 2;
        cfg.wavefronts_per_cu = 2;
        cfg.l2_banks = 2;
        cfg.stacks_per_gpu = 2;
        cfg.gpu_mem_bytes = 64 << 20;
        cfg.scale = 0.05;
        cfg.shards = shards;
        let res = run_workload(&cfg, "fir", None);
        assert!(res.all_passed(), "shards={shards}: {:?}", res.checks);
        (
            res.metrics.cycles,
            res.metrics.events,
            res.metrics.l1_l2_transactions(),
            res.metrics.l2_mm_transactions(),
            res.metrics.mem_bytes,
            res.metrics.pool_fresh_boxes,
            res.metrics.pool_reused_boxes,
        )
    };
    let a = run(1);
    let b = run(3);
    assert_eq!(a, b, "metrics differ between shards=1 and shards=3");
}
