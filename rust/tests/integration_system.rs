//! End-to-end integration tests: full workloads on full topologies,
//! including the PJRT golden-model path when artifacts are present.

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::runtime::Runtime;
use halcone::workloads::{STANDARD, XTREME};

fn small(preset: &str) -> SystemConfig {
    let mut cfg = SystemConfig::preset(preset);
    cfg.n_gpus = 2;
    cfg.cus_per_gpu = 2;
    cfg.wavefronts_per_cu = 2;
    cfg.l2_banks = 2;
    cfg.stacks_per_gpu = 2;
    cfg.gpu_mem_bytes = 64 << 20;
    cfg.scale = 0.1;
    cfg
}

fn artifacts() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::open(dir).ok()
}

#[test]
fn every_workload_verifies_under_halcone() {
    let cfg = small("SM-WT-C-HALCONE");
    for name in STANDARD.iter().chain(XTREME.iter()) {
        let res = run_workload(&cfg, name, None);
        assert!(res.all_passed(), "{name}: {:?}", res.checks);
        assert!(res.metrics.cycles > 0);
    }
}

#[test]
fn every_workload_verifies_under_every_preset() {
    // The functional contract holds for every §4.1 configuration — the
    // NC configs through fences, HMG through invalidations, HALCONE
    // through leases.
    for preset in SystemConfig::PRESETS {
        let cfg = small(preset);
        for name in ["rl", "fws", "bs", "xtreme1", "xtreme3"] {
            let res = run_workload(&cfg, name, None);
            assert!(res.all_passed(), "{preset}/{name}: {:?}", res.checks);
        }
    }
}

#[test]
fn full_scale_fir_artifact_check() {
    // Default Table 2 config (4 GPUs x 32 CUs) + the AOT Pallas golden
    // model through the PJRT runtime — the complete three-layer loop.
    let Some(mut rt) = artifacts() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let cfg = SystemConfig::preset("SM-WT-C-HALCONE");
    let res = run_workload(&cfg, "fir", Some(&mut rt));
    assert!(
        res.checks.iter().any(|c| c.kind == "artifact" && c.passed),
        "artifact check must run and pass: {:?}",
        res.checks
    );
}

#[test]
fn xtreme1_artifact_roundtrip_full_scale() {
    let Some(mut rt) = artifacts() else {
        eprintln!("skipped: run `make artifacts`");
        return;
    };
    let cfg = SystemConfig::preset("SM-WT-C-HALCONE");
    let res = run_workload(&cfg, "xtreme1", Some(&mut rt));
    assert!(res.all_passed(), "{:?}", res.checks);
    assert!(res.checks.iter().any(|c| c.kind == "artifact"));
    // Xtreme's whole point: hardware coherence absorbs the sharing.
    assert!(res.metrics.l1.coherency_misses > 0);
}

#[test]
fn halcone_overhead_on_standard_benchmarks_is_small() {
    // Paper §5.1: ~1% average overhead vs SM-WT-NC on DRF benchmarks.
    let mut ratios = vec![];
    for name in ["rl", "fir", "aes", "mp"] {
        let nc = run_workload(&small("SM-WT-NC"), name, None);
        let hc = run_workload(&small("SM-WT-C-HALCONE"), name, None);
        assert!(nc.all_passed() && hc.all_passed());
        ratios.push(hc.metrics.cycles as f64 / nc.metrics.cycles as f64);
    }
    let mean = ratios.iter().product::<f64>().powf(1.0 / ratios.len() as f64);
    assert!(
        mean < 1.10,
        "HALCONE geomean overhead {mean:.3} exceeds 10% (paper: ~1%): {ratios:?}"
    );
}

#[test]
fn memory_bound_benchmarks_prefer_shared_memory() {
    // Fig 7(a) shape: SM-WT beats RDMA on memory-bound shared-data
    // workloads.
    for name in ["fir", "mm", "conv"] {
        let rdma = run_workload(&small("RDMA-WB-NC"), name, None);
        let sm = run_workload(&small("SM-WT-NC"), name, None);
        assert!(
            sm.metrics.cycles < rdma.metrics.cycles,
            "{name}: SM {} !< RDMA {}",
            sm.metrics.cycles,
            rdma.metrics.cycles
        );
    }
}

#[test]
fn hmg_beats_plain_rdma_on_reuse() {
    // HMG's L2 caching of remote lines pays off when remote data is
    // re-read (mm streams B repeatedly).
    let rdma = run_workload(&small("RDMA-WB-NC"), "mm", None);
    let hmg = run_workload(&small("RDMA-WB-C-HMG"), "mm", None);
    assert!(rdma.all_passed() && hmg.all_passed());
    assert!(
        hmg.metrics.cycles < rdma.metrics.cycles,
        "HMG {} !< RDMA {}",
        hmg.metrics.cycles,
        rdma.metrics.cycles
    );
}

#[test]
fn gpu_count_scaling_improves_runtime() {
    // Fig 8(a): more GPUs, same total work (strong scaling) — parallel
    // benchmarks speed up.
    let mut prev = u64::MAX;
    for gpus in [1u32, 2, 4] {
        let mut cfg = SystemConfig::preset("SM-WT-C-HALCONE");
        cfg.n_gpus = gpus;
        cfg.cus_per_gpu = 4;
        cfg.l2_banks = 2;
        cfg.stacks_per_gpu = 2;
        cfg.gpu_mem_bytes = 64 << 20;
        cfg.scale = 0.5;
        let res = run_workload(&cfg, "rl", None);
        assert!(res.all_passed());
        assert!(
            res.metrics.cycles < prev,
            "{gpus} GPUs: {} !< {prev}",
            res.metrics.cycles
        );
        prev = res.metrics.cycles;
    }
}

#[test]
fn tsu_only_active_under_halcone() {
    let hc = run_workload(&small("SM-WT-C-HALCONE"), "rl", None);
    let nc = run_workload(&small("SM-WT-NC"), "rl", None);
    assert!(hc.metrics.tsu_lookups > 0);
    assert_eq!(nc.metrics.tsu_lookups, 0);
}

#[test]
fn gtsc_ablation_adds_request_traffic_not_time() {
    // E10: CU-level timestamps (G-TSC style) inflate request bytes; the
    // protocol decisions are unchanged, so cycles stay identical.
    let mut hc = small("SM-WT-C-HALCONE");
    let mut gtsc = small("SM-WT-C-HALCONE");
    gtsc.set("coherence", "gtsc").unwrap();
    gtsc.name = "SM-WT-C-GTSC".into();
    let a = run_workload(&hc, "xtreme1", None);
    let b = run_workload(&gtsc, "xtreme1", None);
    hc.name.clear();
    assert_eq!(a.metrics.l1.reqs_down, b.metrics.l1.reqs_down);
    assert!(
        b.metrics.l1.bytes_down > a.metrics.l1.bytes_down,
        "warpts must add L1->L2 request bytes"
    );
    assert!(
        b.metrics.l2.bytes_down > a.metrics.l2.bytes_down,
        "warpts must add L2->MM request bytes"
    );
}
