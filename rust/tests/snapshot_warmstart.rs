//! The snapshot / warm-start contract (ISSUE 8, docs/SNAPSHOT.md):
//!
//! * **byte-identity oracle** — a warm-started run continues exactly
//!   where the cold run paused: every deterministic metric and every
//!   correctness check matches the uninterrupted cold run, at any
//!   `--shards` level (the snapshot is thread-count-agnostic) and any
//!   `--jobs` level (campaign forks);
//! * **graceful refusal** — truncation, flipped bytes, a bumped format
//!   version, a mismatched config fingerprint and a mismatched workload
//!   each produce a named `Err`, never a panic and never silent drift;
//! * **fork campaigns** — a sweep with a `warmup` prefix produces the
//!   identical canonical `campaign.json` as a cold sweep, both on the
//!   first (save) pass and on a second (disk-forked) pass.

use std::sync::Arc;

use halcone::config::SystemConfig;
use halcone::coordinator::runner::{try_run_workload_snap, SnapMode};
use halcone::metrics::RunMetrics;
use halcone::snapshot;
use halcone::sweep::exec::{run_campaign, ExecOptions};
use halcone::sweep::report;
use halcone::sweep::spec::CampaignSpec;

fn small(preset: &str) -> SystemConfig {
    let mut cfg = SystemConfig::preset(preset);
    cfg.n_gpus = 2;
    cfg.cus_per_gpu = 2;
    cfg.wavefronts_per_cu = 2;
    cfg.l2_banks = 2;
    cfg.stacks_per_gpu = 2;
    cfg.gpu_mem_bytes = 64 << 20;
    cfg.scale = 0.05;
    cfg
}

/// Deterministic fields only (host timing excluded), for cold-vs-warm
/// byte-identity assertions.
fn canon(m: &RunMetrics) -> String {
    format!(
        "cycles={} events={} cu_loads={} cu_stores={} mm_reads={} mm_writes={} \
         tsu_lookups={} tsu_evictions={} pcie_bytes={} mem_bytes={} l1={:?} l2={:?} \
         faults={:?}",
        m.cycles,
        m.events,
        m.cu_loads,
        m.cu_stores,
        m.mm_reads,
        m.mm_writes,
        m.tsu_lookups,
        m.tsu_evictions,
        m.pcie_bytes,
        m.mem_bytes,
        m.l1,
        m.l2,
        m.faults,
    )
}

fn run_cold(cfg: &SystemConfig, wl: &str) -> RunMetrics {
    let (res, _, _) =
        try_run_workload_snap(cfg, wl, None, false, SnapMode::None).unwrap();
    assert!(res.all_passed(), "{wl}: cold run failed checks: {:?}", res.checks);
    res.metrics
}

/// Cold run that pauses at `at`, snapshots, and resumes. Returns the
/// snapshot bytes and the (must-be-uninterrupted-identical) metrics.
fn run_save(cfg: &SystemConfig, wl: &str, at: u64) -> (Vec<u8>, RunMetrics) {
    let (res, _, bytes) =
        try_run_workload_snap(cfg, wl, None, false, SnapMode::Save { at }).unwrap();
    assert!(res.all_passed(), "{wl}: save run failed checks: {:?}", res.checks);
    (bytes.expect("run drained before the snapshot cycle — lower `at`"), res.metrics)
}

fn run_warm(cfg: &SystemConfig, wl: &str, bytes: &Arc<Vec<u8>>) -> Result<RunMetrics, String> {
    let (res, _, _) = try_run_workload_snap(
        cfg,
        wl,
        None,
        false,
        SnapMode::Warm { bytes: bytes.clone() },
    )?;
    assert!(res.all_passed(), "{wl}: warm run failed checks: {:?}", res.checks);
    Ok(res.metrics)
}

#[test]
fn warm_start_is_byte_identical_to_cold_at_any_shard_count() {
    let cfg = small("SM-WT-C-HALCONE");
    let cold = run_cold(&cfg, "fir");
    let (bytes, saved) = run_save(&cfg, "fir", cold.cycles / 2);
    // The pause itself is invisible: pausing + resuming == never pausing.
    assert_eq!(canon(&saved), canon(&cold), "run_until_barrier perturbed the run");
    let bytes = Arc::new(bytes);
    // The fingerprint excludes `shards`, so one snapshot serves every
    // thread count — and every warm run must reproduce the cold bytes.
    for shards in [1u32, 4] {
        let mut warm_cfg = cfg.clone();
        warm_cfg.shards = shards;
        let warm = run_warm(&warm_cfg, "fir", &bytes).unwrap();
        assert_eq!(canon(&warm), canon(&cold), "warm(shards={shards}) diverged from cold");
    }
}

#[test]
fn warm_start_holds_under_every_protocol_and_under_faults() {
    // Each coherence protocol serializes different per-slot metadata;
    // fault schedules add link/rollover state rebuilt from config.
    for preset in ["SM-WT-NC", "SM-WB-NC", "RDMA-WB-NC", "SM-WT-C-HALCONE", "RDMA-WB-C-HMG"] {
        let cfg = small(preset);
        let cold = run_cold(&cfg, "rl");
        let (bytes, _) = run_save(&cfg, "rl", cold.cycles / 2);
        let warm = run_warm(&cfg, "rl", &Arc::new(bytes)).unwrap();
        assert_eq!(canon(&warm), canon(&cold), "{preset}: warm diverged");
    }
    let mut cfg = small("SM-WT-C-HALCONE");
    cfg.set("faults", "seed=7;window=200;degrade=0.5;outage=0.4").unwrap();
    let cold = run_cold(&cfg, "fir");
    let (bytes, _) = run_save(&cfg, "fir", cold.cycles / 2);
    let warm = run_warm(&cfg, "fir", &Arc::new(bytes)).unwrap();
    assert_eq!(canon(&warm), canon(&cold), "faulted warm run diverged");
}

#[test]
fn a_run_that_drains_before_the_snapshot_cycle_yields_no_snapshot() {
    let cfg = small("SM-WT-C-HALCONE");
    let (res, _, bytes) =
        try_run_workload_snap(&cfg, "rl", None, false, SnapMode::Save { at: u64::MAX })
            .unwrap();
    assert!(res.all_passed());
    assert!(bytes.is_none(), "an already-finished run has nothing to snapshot");
}

#[test]
fn truncation_anywhere_is_refused_without_panicking() {
    let cfg = small("SM-WT-C-HALCONE");
    let cold = run_cold(&cfg, "rl");
    let (bytes, _) = run_save(&cfg, "rl", cold.cycles / 2);
    // Sampled cut points (a full per-byte scan re-builds the topology
    // tens of thousands of times): every prefix must fail cleanly. The
    // per-byte exhaustive scan of the section framing lives with the
    // format unit tests.
    let step = (bytes.len() / 97).max(1);
    let mut cuts: Vec<usize> = (0..bytes.len()).step_by(step).collect();
    cuts.extend([0, 1, 7, 8, 9, bytes.len() - 1]);
    for cut in cuts {
        let err = run_warm(&cfg, "rl", &Arc::new(bytes[..cut].to_vec()))
            .expect_err(&format!("truncation at byte {cut} must be refused"));
        assert!(!err.is_empty(), "cut {cut}: empty error message");
    }
}

#[test]
fn a_flipped_payload_byte_is_caught_by_the_section_checksum() {
    let cfg = small("SM-WT-C-HALCONE");
    let cold = run_cold(&cfg, "rl");
    let (bytes, _) = run_save(&cfg, "rl", cold.cycles / 2);
    // The last byte sits inside the final (verify) section payload.
    let mut flipped = bytes.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0x01;
    let err = run_warm(&cfg, "rl", &Arc::new(flipped)).unwrap_err();
    assert!(err.contains("checksum mismatch"), "{err}");
    // A flip in the middle lands in some section's payload: whatever the
    // byte encoded, the restore must refuse with a named error.
    let mut flipped = bytes.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    let err = run_warm(&cfg, "rl", &Arc::new(flipped)).unwrap_err();
    assert!(!err.is_empty());
    // Bad magic is its own named refusal.
    let mut nosnap = bytes.clone();
    nosnap[0] = b'X';
    let err = run_warm(&cfg, "rl", &Arc::new(nosnap)).unwrap_err();
    assert!(err.contains("bad magic"), "{err}");
}

#[test]
fn a_bumped_format_version_is_refused_by_name() {
    let cfg = small("SM-WT-C-HALCONE");
    let cold = run_cold(&cfg, "rl");
    let (bytes, _) = run_save(&cfg, "rl", cold.cycles / 2);
    // Byte 8 is the version varint (small FORMAT_VERSION values encode
    // as one byte); a future version must be refused, not misparsed.
    assert_eq!(bytes[8] as u64, snapshot::FORMAT_VERSION);
    let mut bumped = bytes.clone();
    bumped[8] = (snapshot::FORMAT_VERSION + 1) as u8;
    let err = run_warm(&cfg, "rl", &Arc::new(bumped)).unwrap_err();
    assert!(err.contains("format version"), "{err}");
}

#[test]
fn fingerprint_and_workload_mismatches_are_refused_by_name() {
    let cfg = small("SM-WT-C-HALCONE");
    let cold = run_cold(&cfg, "rl");
    let (bytes, _) = run_save(&cfg, "rl", cold.cycles / 2);
    let bytes = Arc::new(bytes);
    // Same workload, different simulated machine -> fingerprint refusal.
    let mut other = cfg.clone();
    other.set("rd_lease", "20").unwrap();
    let err = run_warm(&other, "rl", &bytes).unwrap_err();
    assert!(err.contains("fingerprint"), "{err}");
    // Different workload -> workload refusal (checked before the
    // fingerprint so the message names the actual conflict).
    let err = run_warm(&cfg, "fir", &bytes).unwrap_err();
    assert!(err.contains("workload"), "{err}");
}

#[test]
fn trace_capture_and_snapshots_refuse_to_combine() {
    let cfg = small("SM-WT-C-HALCONE");
    let err = try_run_workload_snap(&cfg, "rl", None, true, SnapMode::Save { at: 100 })
        .unwrap_err();
    assert!(err.contains("trace capture"), "{err}");
}

// ---- Fork campaigns (`sweep --warmup`).

fn smoke_with_warmup(warmup: Option<u64>) -> CampaignSpec {
    let mut spec = CampaignSpec::builtin("smoke").unwrap();
    spec.warmup = warmup;
    spec
}

#[test]
fn warmup_campaign_matches_the_cold_campaign_at_any_jobs_level() {
    let cold = run_campaign(
        &smoke_with_warmup(None),
        &ExecOptions { jobs: 2, progress: false, ..Default::default() },
    )
    .unwrap();
    assert!(cold.all_passed());
    let cold_canon = report::to_json_canonical(&cold);
    // The spec header legitimately differs (the warm artifact records
    // its `warmup` key); every cell byte must match.
    let cells_of = |s: &str| s[s.find("\"cells\"").unwrap()..].to_string();
    for jobs in [1usize, 8] {
        let warm = run_campaign(
            &smoke_with_warmup(Some(500)),
            &ExecOptions { jobs, progress: false, ..Default::default() },
        )
        .unwrap();
        assert!(warm.all_passed(), "warmup campaign failed at jobs={jobs}");
        let warm_canon = report::to_json_canonical(&warm);
        assert_eq!(
            cells_of(&warm_canon),
            cells_of(&cold_canon),
            "warmup sweep diverged from cold at jobs={jobs}"
        );
    }
}

#[test]
fn a_second_sweep_forks_from_the_journaled_snapshots() {
    let dir = std::env::temp_dir().join(format!("halcone-warmfork-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("campaign.json");
    let spec = smoke_with_warmup(Some(500));
    let opts = || ExecOptions {
        jobs: 2,
        progress: false,
        journal: Some(journal.clone()),
        ..Default::default()
    };
    // Pass 1: every cell runs cold and snapshots its warmup prefix.
    let first = run_campaign(&spec, &opts()).unwrap();
    assert!(first.all_passed());
    let snaps: Vec<_> = std::fs::read_dir(dir.join("snapshots"))
        .expect("snapshot dir created next to the journal")
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    assert_eq!(snaps.len(), 4, "one snapshot per cell fingerprint: {snaps:?}");
    assert!(snaps.iter().all(|n| n.ends_with(".snap")), "{snaps:?}");
    // Pass 2: every cell warm-starts from disk; results are identical.
    let second = run_campaign(&spec, &opts()).unwrap();
    assert!(second.all_passed());
    assert_eq!(
        report::to_json_canonical(&first),
        report::to_json_canonical(&second),
        "disk-forked sweep diverged from its cold pass"
    );
    // A corrupt snapshot file downgrades to a cold run, never a failure.
    let victim = dir.join("snapshots").join(&snaps[0]);
    std::fs::write(&victim, b"HALCSNP\0garbage").unwrap();
    let third = run_campaign(&spec, &opts()).unwrap();
    assert!(third.all_passed(), "corrupt snapshot must fall back to a cold run");
    assert_eq!(
        report::to_json_canonical(&first),
        report::to_json_canonical(&third),
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn warmup_round_trips_through_spec_text_and_artifact() {
    let spec = CampaignSpec::parse(
        "name = t\npresets = SM-WT-NC\nworkloads = rl\nwarmup = 2500\n\
         set.n_gpus = 2\nset.cus_per_gpu = 2\nset.wavefronts_per_cu = 2\n\
         set.l2_banks = 2\nset.stacks_per_gpu = 2\n\
         set.gpu_mem_bytes = 67108864\nset.scale = 0.05\n",
    )
    .unwrap();
    assert_eq!(spec.warmup, Some(2500));
    assert!(CampaignSpec::parse("warmup = soon\n").is_err(), "non-numeric warmup");
    let res = run_campaign(
        &spec,
        &ExecOptions { jobs: 1, progress: false, ..Default::default() },
    )
    .unwrap();
    let doc = halcone::sweep::json::parse(&report::to_json(&res)).unwrap();
    let rebuilt = CampaignSpec::from_artifact(&doc).unwrap();
    assert_eq!(rebuilt.warmup, Some(2500), "warmup must survive the artifact round trip");
    // Warmup-free artifacts carry no key and rebuild to None.
    let cold = run_campaign(
        &CampaignSpec::builtin("smoke").unwrap(),
        &ExecOptions { jobs: 2, progress: false, ..Default::default() },
    )
    .unwrap();
    let text = report::to_json(&cold);
    assert!(!text.contains("warmup"), "cold artifacts must not grow a warmup key");
    let doc = halcone::sweep::json::parse(&text).unwrap();
    assert_eq!(CampaignSpec::from_artifact(&doc).unwrap().warmup, None);
}
