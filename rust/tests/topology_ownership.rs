//! Property tests for the partitioned fabric (ISSUE 9): the engine
//! shard that owns each MC/TSU must match `AddrMap::stack_owner` for
//! every (n_gpus, stacks_per_gpu) geometry, under both topologies and
//! under profile-guided shard grouping.

use halcone::config::{Fabric, SystemConfig};
use halcone::coordinator::topology::{self, plan_shard_groups};
use halcone::mem::addr::Topology;
use halcone::workloads;

fn cfg_for(preset: &str, gpus: u32, spg: u32) -> SystemConfig {
    let mut cfg = SystemConfig::preset(preset);
    cfg.n_gpus = gpus;
    cfg.cus_per_gpu = 1;
    cfg.wavefronts_per_cu = 1;
    cfg.l2_banks = 2;
    cfg.stacks_per_gpu = spg;
    cfg.gpu_mem_bytes = 16 << 20;
    cfg.scale = 0.02;
    cfg
}

fn build(cfg: &SystemConfig) -> topology::System {
    let p = cfg.workload_params();
    topology::build(cfg, workloads::build("rl", &p))
}

#[test]
fn tsu_ownership_matches_addr_map_for_every_geometry() {
    // The TSU lives inside its MemCtrl, so the MC's shard is the TSU's.
    for preset in ["SM-WT-C-HALCONE", "RDMA-WB-NC"] {
        for gpus in [1u32, 2, 3, 4] {
            for spg in [1u32, 2, 4] {
                let cfg = cfg_for(preset, gpus, spg);
                let map = cfg.addr_map();
                let sys = build(&cfg);
                assert_eq!(sys.mcs.len() as u32, map.total_stacks());
                for (si, &mc) in sys.mcs.iter().enumerate() {
                    assert_eq!(
                        sys.engine.shard_of(mc),
                        map.stack_owner(si as u32),
                        "{preset} gpus={gpus} spg={spg} mm{si}"
                    );
                }
                // The hub shard holds only the driver: no MC may land on
                // it under the ports fabric.
                let hub = sys.engine.n_shards() - 1;
                assert!(sys.mcs.iter().all(|&mc| sys.engine.shard_of(mc) != hub));
                assert_eq!(sys.engine.shard_of(sys.driver), hub);
            }
        }
    }
}

#[test]
fn hub_fabric_parks_sm_stacks_on_the_hub_only() {
    for (preset, topo) in [("SM-WT-NC", Topology::SharedMem), ("RDMA-WB-NC", Topology::Rdma)] {
        let mut cfg = cfg_for(preset, 2, 2);
        cfg.fabric = Fabric::Hub;
        let map = cfg.addr_map();
        let sys = build(&cfg);
        let hub = sys.engine.n_shards() - 1;
        for (si, &mc) in sys.mcs.iter().enumerate() {
            let expect = match topo {
                Topology::SharedMem => hub,
                Topology::Rdma => map.stack_owner(si as u32),
            };
            assert_eq!(sys.engine.shard_of(mc), expect, "{preset} mm{si}");
        }
    }
}

#[test]
fn grouped_partition_respects_planned_ownership() {
    // A profile-guided grouping folds GPUs — stack ownership must follow
    // the owning GPU into its group.
    let groups = plan_shard_groups(&[100, 10, 90, 20], 2);
    assert_eq!(groups.len(), 4);
    let mut cfg = cfg_for("SM-WT-C-HALCONE", 4, 2);
    cfg.shard_groups = groups.clone();
    let map = cfg.addr_map();
    let sys = build(&cfg);
    let n_groups = groups.iter().max().unwrap() + 1;
    assert_eq!(sys.engine.n_shards(), n_groups + 1);
    for (si, &mc) in sys.mcs.iter().enumerate() {
        let owner = map.stack_owner(si as u32) as usize;
        assert_eq!(sys.engine.shard_of(mc), groups[owner], "mm{si}");
    }
}
