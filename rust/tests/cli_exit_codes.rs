//! The CLI exit-code contract (see the module doc in `rust/src/main.rs`
//! and `halcone --help`):
//!
//!   0  success
//!   1  generic failure (failed checks, divergence, failed cells)
//!   2  usage, configuration or I/O error
//!   3  gate regression (the gate judged the run and failed it)
//!   4  sweep partial: some cells hit the watchdog timeout
//!
//! CI scripts branch on these, so each code is pinned here against the
//! real binary.

use std::path::PathBuf;
use std::process::{Command, Output};

fn halcone(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_halcone")).args(args).output().unwrap()
}

fn code(out: &Output) -> i32 {
    out.status.code().unwrap_or(-1)
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("halcone_exit_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A minimal but schema-valid campaign artifact for gate tests.
fn doc(cycles: u64, status: &str) -> String {
    format!(
        r#"{{"schema_version": 1, "campaign": "t", "cells": [
             {{"config": "A", "workload": "rl", "status": "{status}",
               "metrics": {{"cycles": {cycles}}}}}
           ]}}"#
    )
}

#[test]
fn success_paths_exit_zero() {
    let out = halcone(&["print-config", "--preset", "SM-WT-C-HALCONE"]);
    assert_eq!(code(&out), 0, "{}", String::from_utf8_lossy(&out.stderr));
    let out = halcone(&["list"]);
    assert_eq!(code(&out), 0);
}

#[test]
fn usage_errors_exit_two() {
    assert_eq!(code(&halcone(&[])), 2, "no command");
    assert_eq!(code(&halcone(&["frobnicate"])), 2, "unknown command");
    assert_eq!(code(&halcone(&["run", "--no-such-flag"])), 2, "unknown flag");
    assert_eq!(code(&halcone(&["sweep"])), 2, "sweep without a campaign");
    assert_eq!(code(&halcone(&["sweep", "--jobs", "0"])), 2, "rejected flag value");
}

#[test]
fn run_configuration_errors_exit_two() {
    let out = halcone(&["run", "--workload", "no-such-workload"]);
    assert_eq!(code(&out), 2, "{}", String::from_utf8_lossy(&out.stderr));
    let out = halcone(&["run", "--workload", "fir", "--set", "no_such_key=1"]);
    assert_eq!(code(&out), 2);
    let out = halcone(&["run", "--workload", "fir", "--config", "/no/such/file.cfg"]);
    assert_eq!(code(&out), 2);
}

#[test]
fn gate_exit_codes_separate_regression_from_unjudgeable() {
    let dir = tmpdir("gate");
    let base = dir.join("baseline.json");
    let same = dir.join("same.json");
    let drift = dir.join("drift.json");
    let worse = dir.join("worse.json");
    std::fs::write(&base, doc(1000, "ok")).unwrap();
    std::fs::write(&same, doc(1000, "ok")).unwrap();
    std::fs::write(&drift, doc(1200, "ok")).unwrap();
    std::fs::write(&worse, doc(1000, "error")).unwrap();
    let gate = |current: &PathBuf| {
        let out = Command::new(env!("CARGO_BIN_EXE_halcone"))
            .arg("gate")
            .arg("--baseline")
            .arg(&base)
            .arg("--current")
            .arg(current)
            .args(["--tolerance", "0.05"])
            .output()
            .unwrap();
        code(&out)
    };
    // Identical artifacts pass; drift and status regressions are the
    // distinct regression code; a missing file means the gate could not
    // judge at all.
    assert_eq!(gate(&same), 0);
    assert_eq!(gate(&drift), 3);
    assert_eq!(gate(&worse), 3);
    assert_eq!(gate(&dir.join("missing.json")), 2);
    std::fs::remove_dir_all(&dir).ok();
}

/// The smoke geometry as repeated `--set` flags (seconds-long runs).
const SMOKE_SETS: [&str; 14] = [
    "--set", "n_gpus=2",
    "--set", "cus_per_gpu=2",
    "--set", "wavefronts_per_cu=2",
    "--set", "l2_banks=2",
    "--set", "stacks_per_gpu=2",
    "--set", "gpu_mem_bytes=67108864",
    "--set", "scale=0.05",
];

#[test]
fn snapshot_refusals_exit_two() {
    // Half a flag pair is a usage error.
    let out = halcone(&["run", "--workload", "rl", "--snapshot-at", "100"]);
    assert_eq!(code(&out), 2, "{}", String::from_utf8_lossy(&out.stderr));
    let out = halcone(&["run", "--workload", "rl", "--snapshot-out", "x.snap"]);
    assert_eq!(code(&out), 2);
    // Saving and warm-starting in one run makes no sense.
    let out = halcone(&[
        "run", "--workload", "rl",
        "--warm-start", "x.snap", "--snapshot-at", "1", "--snapshot-out", "y.snap",
    ]);
    assert_eq!(code(&out), 2);
    // A missing snapshot file is an I/O refusal, not a panic.
    let out = halcone(&["run", "--workload", "rl", "--warm-start", "/no/such/file.snap"]);
    assert_eq!(code(&out), 2, "{}", String::from_utf8_lossy(&out.stderr));
    // An unknown preset routes through try_preset: clean exit 2.
    let out = halcone(&["run", "--workload", "rl", "--preset", "NO-SUCH-PRESET"]);
    assert_eq!(code(&out), 2, "{}", String::from_utf8_lossy(&out.stderr));
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("--preset"),
        "preset refusal names the flag"
    );
}

#[test]
fn snapshot_save_warm_and_mismatch_round_trip_through_the_cli() {
    let dir = tmpdir("snapshot");
    let snap = dir.join("warm.snap");
    let snap_s = snap.to_str().unwrap();
    let mut save = vec!["run", "--workload", "rl"];
    save.extend(SMOKE_SETS);
    save.extend(["--snapshot-at", "500", "--snapshot-out", snap_s]);
    let out = halcone(&save);
    assert_eq!(code(&out), 0, "{}", String::from_utf8_lossy(&out.stderr));
    assert!(snap.exists(), "snapshot file written");
    assert!(!dir.join("warm.snap.tmp").exists(), "temp renamed away");

    // Warm-starting the identical run succeeds.
    let mut warm = vec!["run", "--workload", "rl"];
    warm.extend(SMOKE_SETS);
    warm.extend(["--warm-start", snap_s]);
    let out = halcone(&warm);
    assert_eq!(code(&out), 0, "{}", String::from_utf8_lossy(&out.stderr));

    // A different config (scale changed) is a fingerprint refusal: 2.
    let mut other = vec!["run", "--workload", "rl"];
    other.extend(SMOKE_SETS);
    other.extend(["--set", "scale=0.1", "--warm-start", snap_s]);
    let out = halcone(&other);
    assert_eq!(code(&out), 2, "{}", String::from_utf8_lossy(&out.stderr));
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("fingerprint"),
        "mismatch names the fingerprint: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    // A corrupt file (flipped tail byte) is a checksum refusal: 2.
    let mut bytes = std::fs::read(&snap).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    std::fs::write(&snap, &bytes).unwrap();
    let out = halcone(&warm);
    assert_eq!(code(&out), 2, "{}", String::from_utf8_lossy(&out.stderr));
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("checksum"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );

    // A snapshot cycle past the end of the run: nothing to save, exit 2.
    let mut late = vec!["run", "--workload", "rl"];
    late.extend(SMOKE_SETS);
    late.extend(["--snapshot-at", "999999999999", "--snapshot-out", snap_s]);
    let out = halcone(&late);
    assert_eq!(code(&out), 2, "{}", String::from_utf8_lossy(&out.stderr));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn watchdog_timeout_partial_sweep_exits_four() {
    let dir = tmpdir("watchdog");
    let spec = dir.join("slow.spec");
    // A full-scale cell (no smoke overrides) takes far longer than the
    // 1-second watchdog, so the sweep must drain as a partial result.
    std::fs::write(
        &spec,
        "name = watchdog\n\
         presets = SM-WT-C-HALCONE\n\
         workloads = fir\n\
         set.scale = 1.0\n",
    )
    .unwrap();
    let journal = dir.join("campaign.json");
    let out = Command::new(env!("CARGO_BIN_EXE_halcone"))
        .arg("sweep")
        .arg("--spec")
        .arg(&spec)
        .args(["--timeout", "1", "--jobs", "1", "--out"])
        .arg(&journal)
        .output()
        .unwrap();
    assert_eq!(code(&out), 4, "{}", String::from_utf8_lossy(&out.stderr));
    // The journal records the timed-out cell, ready for --resume.
    let text = std::fs::read_to_string(&journal).unwrap();
    assert!(text.contains("\"status\": \"timeout\""), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}
