//! The CLI exit-code contract (see the module doc in `rust/src/main.rs`
//! and `halcone --help`):
//!
//!   0  success
//!   1  generic failure (failed checks, divergence, failed cells)
//!   2  usage, configuration or I/O error
//!   3  gate regression (the gate judged the run and failed it)
//!   4  sweep partial: some cells hit the watchdog timeout
//!
//! CI scripts branch on these, so each code is pinned here against the
//! real binary.

use std::path::PathBuf;
use std::process::{Command, Output};

fn halcone(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_halcone")).args(args).output().unwrap()
}

fn code(out: &Output) -> i32 {
    out.status.code().unwrap_or(-1)
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("halcone_exit_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A minimal but schema-valid campaign artifact for gate tests.
fn doc(cycles: u64, status: &str) -> String {
    format!(
        r#"{{"schema_version": 1, "campaign": "t", "cells": [
             {{"config": "A", "workload": "rl", "status": "{status}",
               "metrics": {{"cycles": {cycles}}}}}
           ]}}"#
    )
}

#[test]
fn success_paths_exit_zero() {
    let out = halcone(&["print-config", "--preset", "SM-WT-C-HALCONE"]);
    assert_eq!(code(&out), 0, "{}", String::from_utf8_lossy(&out.stderr));
    let out = halcone(&["list"]);
    assert_eq!(code(&out), 0);
}

#[test]
fn usage_errors_exit_two() {
    assert_eq!(code(&halcone(&[])), 2, "no command");
    assert_eq!(code(&halcone(&["frobnicate"])), 2, "unknown command");
    assert_eq!(code(&halcone(&["run", "--no-such-flag"])), 2, "unknown flag");
    assert_eq!(code(&halcone(&["sweep"])), 2, "sweep without a campaign");
    assert_eq!(code(&halcone(&["sweep", "--jobs", "0"])), 2, "rejected flag value");
}

#[test]
fn run_configuration_errors_exit_two() {
    let out = halcone(&["run", "--workload", "no-such-workload"]);
    assert_eq!(code(&out), 2, "{}", String::from_utf8_lossy(&out.stderr));
    let out = halcone(&["run", "--workload", "fir", "--set", "no_such_key=1"]);
    assert_eq!(code(&out), 2);
    let out = halcone(&["run", "--workload", "fir", "--config", "/no/such/file.cfg"]);
    assert_eq!(code(&out), 2);
}

#[test]
fn gate_exit_codes_separate_regression_from_unjudgeable() {
    let dir = tmpdir("gate");
    let base = dir.join("baseline.json");
    let same = dir.join("same.json");
    let drift = dir.join("drift.json");
    let worse = dir.join("worse.json");
    std::fs::write(&base, doc(1000, "ok")).unwrap();
    std::fs::write(&same, doc(1000, "ok")).unwrap();
    std::fs::write(&drift, doc(1200, "ok")).unwrap();
    std::fs::write(&worse, doc(1000, "error")).unwrap();
    let gate = |current: &PathBuf| {
        let out = Command::new(env!("CARGO_BIN_EXE_halcone"))
            .arg("gate")
            .arg("--baseline")
            .arg(&base)
            .arg("--current")
            .arg(current)
            .args(["--tolerance", "0.05"])
            .output()
            .unwrap();
        code(&out)
    };
    // Identical artifacts pass; drift and status regressions are the
    // distinct regression code; a missing file means the gate could not
    // judge at all.
    assert_eq!(gate(&same), 0);
    assert_eq!(gate(&drift), 3);
    assert_eq!(gate(&worse), 3);
    assert_eq!(gate(&dir.join("missing.json")), 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn watchdog_timeout_partial_sweep_exits_four() {
    let dir = tmpdir("watchdog");
    let spec = dir.join("slow.spec");
    // A full-scale cell (no smoke overrides) takes far longer than the
    // 1-second watchdog, so the sweep must drain as a partial result.
    std::fs::write(
        &spec,
        "name = watchdog\n\
         presets = SM-WT-C-HALCONE\n\
         workloads = fir\n\
         set.scale = 1.0\n",
    )
    .unwrap();
    let journal = dir.join("campaign.json");
    let out = Command::new(env!("CARGO_BIN_EXE_halcone"))
        .arg("sweep")
        .arg("--spec")
        .arg(&spec)
        .args(["--timeout", "1", "--jobs", "1", "--out"])
        .arg(&journal)
        .output()
        .unwrap();
    assert_eq!(code(&out), 4, "{}", String::from_utf8_lossy(&out.stderr));
    // The journal records the timed-out cell, ready for --resume.
    let text = std::fs::read_to_string(&journal).unwrap();
    assert!(text.contains("\"status\": \"timeout\""), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}
