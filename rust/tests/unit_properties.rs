//! Property sweeps over the substrate invariants (DESIGN.md S20):
//! cache-array vs shadow model, TSU monotonicity, link causality, address
//! map consistency, write-combining byte-exactness, and the lease
//! scale-invariance the §5.4 sweep exposed.

use std::collections::HashMap;

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::mem::cache::{CacheArray, CacheParams};
use halcone::mem::AddrMap;
use halcone::prop_assert;
use halcone::proptools::{check, check_with, Rng};
use halcone::sim::Link;
use halcone::tsu::{Leases, Tsu};

#[test]
fn cache_array_matches_shadow_model() {
    check("cache vs shadow", 0xCACE, |rng| {
        let mut cache = CacheArray::<u32>::new(CacheParams::new(1 << 10, 2)); // 8 sets
        let mut shadow: HashMap<u64, (u8, bool, u32)> = HashMap::new(); // (fill, dirty, meta)
        for step in 0..300u32 {
            let addr = rng.below(64) * 64; // 64 distinct lines over 8 sets
            match rng.below(4) {
                0 | 1 => {
                    let fill = (step % 251) as u8;
                    let dirty = rng.below(2) == 0;
                    if let Some(ev) = cache.insert(addr, &[fill; 64], dirty, step) {
                        // Evicted line must have been resident with the
                        // exact bytes/flags the shadow recorded.
                        let (f, d, m) = shadow
                            .remove(&ev.addr)
                            .ok_or_else(|| format!("evicted non-resident {:#x}", ev.addr))?;
                        prop_assert!(ev.data[0] == f, "evicted data mismatch");
                        prop_assert!(ev.dirty == d, "evicted dirty mismatch");
                        prop_assert!(ev.meta == m, "evicted meta mismatch");
                    }
                    shadow.insert(addr, (fill, dirty, step));
                }
                2 => {
                    let hit = cache.lookup(addr).is_some();
                    prop_assert!(
                        hit == shadow.contains_key(&addr),
                        "lookup({addr:#x}) = {hit}, shadow disagrees"
                    );
                    if let Some(line) = cache.lookup(addr) {
                        let (f, _, m) = shadow[&addr];
                        prop_assert!(line.data[0] == f, "hit data mismatch");
                        prop_assert!(*line.meta == m, "hit meta mismatch");
                    }
                }
                _ => {
                    let evicted = cache.invalidate(addr).is_some();
                    prop_assert!(
                        evicted == shadow.remove(&addr).is_some(),
                        "invalidate({addr:#x}) disagreed with shadow"
                    );
                }
            }
            prop_assert!(
                cache.occupancy() == shadow.len(),
                "occupancy {} != shadow {}",
                cache.occupancy(),
                shadow.len()
            );
        }
        Ok(())
    });
}

#[test]
fn tsu_timestamps_are_monotone_per_block() {
    check("tsu monotone", 0x75, |rng| {
        let mut tsu = Tsu::new(256, Leases { rd: 1 + rng.below(30), wr: 1 + rng.below(30) });
        let mut last_rts: HashMap<u64, u64> = HashMap::new();
        for _ in 0..400 {
            let addr = rng.below(512) * 64;
            let ts = if rng.below(2) == 0 { tsu.on_read(addr) } else { tsu.on_write(addr) };
            prop_assert!(ts.wts < ts.rts, "lease must be non-empty: {ts:?}");
            if let Some(&prev) = last_rts.get(&addr) {
                prop_assert!(
                    ts.rts > prev,
                    "memts must advance per access: {} -> {}",
                    prev,
                    ts.rts
                );
                prop_assert!(ts.wts >= prev - 0, "wts is the previous memts floor");
            }
            last_rts.insert(addr, ts.rts);
            prop_assert!(tsu.max_memts >= ts.rts, "max_memts is a high-water mark");
        }
        Ok(())
    });
}

#[test]
fn links_deliver_in_causal_fifo_order() {
    check("link causality", 0x11, |rng| {
        let mut link = Link::new("p", rng.below(100), 1 + rng.below(300));
        let mut now = 0u64;
        let mut last_delivery = 0u64;
        for _ in 0..200 {
            now += rng.below(50);
            let bytes = 1 + rng.below(256);
            let d = link.accept(now, bytes);
            prop_assert!(d > now, "delivery {d} must be after send {now}");
            prop_assert!(
                d >= last_delivery,
                "FIFO violated: {d} < previous delivery {last_delivery}"
            );
            last_delivery = d;
        }
        Ok(())
    });
}

#[test]
fn addr_map_is_consistent() {
    use halcone::mem::addr::Topology;
    check("addr map", 0xADD8, |rng| {
        let gpus = 1 + rng.below(16) as u32;
        let stacks = 1 << rng.below(4);
        let banks = 1 << rng.below(4);
        for topo in [Topology::SharedMem, Topology::Rdma] {
            let m = AddrMap::new(topo, gpus, stacks, banks, 64 << 20);
            for _ in 0..50 {
                let addr = rng.below(m.total_bytes());
                let stack = m.stack_of(addr);
                prop_assert!(stack < m.total_stacks(), "stack {stack} out of range");
                let home = m.home_gpu(addr);
                prop_assert!(home < gpus, "home {home} out of range");
                prop_assert!(
                    m.is_local(home, addr),
                    "an address must be local to its home GPU"
                );
                // Same line -> same stack and same bank.
                let lb = m.line_base(addr);
                prop_assert!(m.stack_of(lb) == stack, "line split across stacks");
                prop_assert!(
                    m.l2_bank_of(addr) == m.l2_bank_of(lb),
                    "line split across banks"
                );
                if topo == Topology::Rdma {
                    // RDMA stacks stay inside the owner's range.
                    prop_assert!(
                        stack / stacks == home,
                        "stack {stack} not owned by home {home}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn lease_scaling_leaves_protocol_decisions_invariant() {
    // Doubling both leases scales every timestamp uniformly; hit/miss
    // decisions (cts <= rts comparisons) are order-preserved, so cycle
    // counts must be identical. (Found via the §5.4 sweep: (20,10) ≡
    // (10,5) exactly.)
    check_with("lease scale invariance", 0x5CA1E, 3, |rng| {
        let k = 1 + rng.below(3); // scale factor 1..4
        let run = |rd: u64, wr: u64| {
            let mut cfg = SystemConfig::preset("SM-WT-C-HALCONE");
            cfg.n_gpus = 2;
            cfg.cus_per_gpu = 2;
            cfg.wavefronts_per_cu = 2;
            cfg.l2_banks = 2;
            cfg.stacks_per_gpu = 2;
            cfg.gpu_mem_bytes = 64 << 20;
            cfg.scale = 0.05;
            cfg.set("rd_lease", &rd.to_string()).unwrap();
            cfg.set("wr_lease", &wr.to_string()).unwrap();
            let res = run_workload(&cfg, "xtreme1", None);
            assert!(res.all_passed());
            (res.metrics.cycles, res.metrics.l2_mm_transactions())
        };
        let base = run(10, 5);
        let scaled = run(10 * k, 5 * k);
        prop_assert!(
            base == scaled,
            "lease scaling by {k} changed behaviour: {base:?} vs {scaled:?}"
        );
        Ok(())
    });
}

#[test]
fn mshr_capacity_is_respected_under_load() {
    use halcone::mem::mshr::{Mshr, MshrKind};
    use halcone::sim::msg::{MemReq, ReqKind};
    use halcone::sim::CompId;
    check("mshr bounded", 0x3348, |rng| {
        let cap = 1 + rng.below(16) as usize;
        let mut mshr = Mshr::new(cap);
        let mut live: Vec<u64> = Vec::new();
        for i in 0..200u64 {
            if mshr.has_free() && (rng.below(3) != 0 || live.is_empty()) {
                let addr = i * 64;
                mshr.allocate(
                    addr,
                    MshrKind::Fill,
                    MemReq {
                        id: i,
                        kind: ReqKind::Read,
                        addr,
                        size: 4,
                        src: CompId(0),
                        dst: CompId(1),
                        data: halcone::mem::LineBuf::empty(),
                        warpts: None,
                        tenant: 0,
                    },
                );
                live.push(addr);
            } else if !live.is_empty() {
                let idx = rng.below(live.len() as u64) as usize;
                let addr = live.swap_remove(idx);
                let e = mshr.retire(addr);
                prop_assert!(e.primary.addr == addr, "retire returned wrong entry");
            }
            prop_assert!(mshr.len() <= cap, "MSHR exceeded capacity");
            prop_assert!(mshr.peak <= cap, "peak exceeded capacity");
        }
        Ok(())
    });
}

#[test]
fn workload_programs_touch_only_their_partitions() {
    // Placement contract: under RDMA, partitioned arrays' addresses fall
    // inside their owner GPU's range (what the copy-phase and NUMA
    // modelling rely on).
    use halcone::gpu::CuOp;
    use halcone::workloads::{self, WorkloadParams};
    let cfg = {
        let mut c = SystemConfig::preset("RDMA-WB-NC");
        c.n_gpus = 2;
        c.cus_per_gpu = 2;
        c.wavefronts_per_cu = 2;
        c.gpu_mem_bytes = 64 << 20;
        c.scale = 0.05;
        c
    };
    let params: WorkloadParams = cfg.workload_params();
    for name in ["rl", "xtreme1", "aes", "bfs"] {
        let wl = workloads::build(name, &params);
        for ph in &wl.phases {
            for (gpu, gw) in ph.work.iter().enumerate() {
                for ops in gw.iter().flatten() {
                    for op in ops {
                        if let CuOp::StV { addr, .. } | CuOp::St { addr, .. } = op {
                            // Stores of partitioned outputs are local to
                            // the executing GPU for these benchmarks.
                            assert_eq!(
                                params.map.home_gpu(*addr),
                                gpu as u32,
                                "{name}: gpu{gpu} stores to a remote partition"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn calendar_queue_matches_reference_heap_order() {
    // The engine's bucketed calendar queue must dequeue ANY event
    // sequence in exactly the `(time, seq)` order the old global
    // `BinaryHeap<Event>` produced — the determinism contract behind the
    // cycle-exactness gate. Random interleaves of pushes (short, medium
    // and far-future delays, including same-cycle ties) and pops are
    // replayed against a reference heap.
    use halcone::sim::msg::{Event, Msg};
    use halcone::sim::{CompId, EventQueue};
    use std::collections::BinaryHeap;

    let ev = |time: u64, seq: u64| Event { time, seq, target: CompId(0), msg: Msg::Tick };
    check("calendar queue vs heap", 0xCA1E, |rng| {
        let mut q = EventQueue::new();
        let mut h: BinaryHeap<Event> = BinaryHeap::new();
        let mut seq = 0u64;
        let mut now = 0u64;
        for _ in 0..500 {
            if rng.below(3) != 2 {
                // Push: mostly near-future, sometimes same-cycle bursts,
                // occasionally far beyond the ring window.
                let delay = match rng.below(12) {
                    0..=4 => rng.below(8),
                    5..=6 => 0,
                    7..=9 => rng.below(400),
                    10 => 3000 + rng.below(3000),
                    _ => 100_000 + rng.below(1_000_000),
                };
                for _ in 0..1 + rng.below(3) {
                    q.push(ev(now + delay, seq));
                    h.push(ev(now + delay, seq));
                    seq += 1;
                }
            } else {
                let a = q.pop().map(|e| (e.time, e.seq));
                let b = h.pop().map(|e| (e.time, e.seq));
                prop_assert!(a == b, "pop mismatch: calendar {a:?} vs heap {b:?}");
                if let Some((t, _)) = a {
                    now = t; // pushes never schedule into the past
                }
            }
            prop_assert!(q.len() == h.len(), "len drifted: {} vs {}", q.len(), h.len());
        }
        // Full drain must agree too.
        loop {
            let a = q.pop().map(|e| (e.time, e.seq));
            let b = h.pop().map(|e| (e.time, e.seq));
            prop_assert!(a == b, "drain mismatch: calendar {a:?} vs heap {b:?}");
            if a.is_none() {
                break;
            }
        }
        prop_assert!(q.is_empty(), "queue must report empty after drain");
        Ok(())
    });
}

#[test]
fn calendar_queue_orders_shard_tagged_seqs() {
    // The sharded engine tags sequence numbers with the origin shard
    // (`seq = shard << SEQ_SHARD_BITS | counter`), so same-cycle pushes
    // are no longer seq-monotone: a window barrier can merge in a
    // lower-tagged event after higher-tagged local pushes, and a shard
    // whose cursor overshot can receive deliveries behind it. The queue
    // must still dequeue in exact `(time, seq)` reference-heap order.
    use halcone::sim::msg::{Event, Msg};
    use halcone::sim::{CompId, EventQueue, SEQ_SHARD_BITS};
    use std::collections::BinaryHeap;

    let ev = |time: u64, seq: u64| Event { time, seq, target: CompId(0), msg: Msg::Tick };
    check("calendar queue vs heap (shard tags)", 0x5A9D, |rng| {
        let mut q = EventQueue::new();
        let mut h: BinaryHeap<Event> = BinaryHeap::new();
        const SHARDS: u64 = 4;
        let mut counters = [0u64; SHARDS as usize];
        let mut now = 0u64;
        for _ in 0..500 {
            if rng.below(3) != 2 {
                let delay = match rng.below(12) {
                    0..=4 => rng.below(8),
                    5..=6 => 0,
                    7..=9 => rng.below(400),
                    10 => 3000 + rng.below(3000),
                    _ => 100_000 + rng.below(1_000_000),
                };
                for _ in 0..1 + rng.below(3) {
                    // Random origin shard: seq values interleave out of
                    // push order, exactly like barrier merges.
                    let shard = rng.below(SHARDS);
                    let seq = (shard << SEQ_SHARD_BITS) | counters[shard as usize];
                    counters[shard as usize] += 1;
                    q.push(ev(now + delay, seq));
                    h.push(ev(now + delay, seq));
                }
            } else {
                let a = q.pop().map(|e| (e.time, e.seq));
                let b = h.pop().map(|e| (e.time, e.seq));
                prop_assert!(a == b, "pop mismatch: calendar {a:?} vs heap {b:?}");
                if let Some((t, _)) = a {
                    now = t; // pushes never schedule into the past
                }
            }
            prop_assert!(q.len() == h.len(), "len drifted: {} vs {}", q.len(), h.len());
        }
        loop {
            let a = q.pop().map(|e| (e.time, e.seq));
            let b = h.pop().map(|e| (e.time, e.seq));
            prop_assert!(a == b, "drain mismatch: calendar {a:?} vs heap {b:?}");
            if a.is_none() {
                break;
            }
        }
        prop_assert!(q.is_empty(), "queue must report empty after drain");
        Ok(())
    });
}

#[test]
fn engine_time_never_goes_backwards() {
    use halcone::sim::{CompId, Component, Ctx, Cycle, Engine, Msg};
    struct RandomScheduler {
        name: String,
        rng: Rng,
        remaining: u32,
        pub last: Cycle,
    }
    impl Component for RandomScheduler {
        halcone::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, _msg: Msg, ctx: &mut Ctx) {
            assert!(now >= self.last, "time went backwards: {} < {}", now, self.last);
            self.last = now;
            if self.remaining > 0 {
                self.remaining -= 1;
                // Random fan-out of future events, including same-cycle.
                for _ in 0..1 + self.rng.below(3) {
                    ctx.schedule(self.rng.below(20), ctx.self_id, Msg::Tick);
                }
            }
        }
    }
    check("engine causality", 0xE4617E, |rng| {
        let mut e = Engine::new();
        let id = CompId(0);
        e.add(Box::new(RandomScheduler {
            name: "r".into(),
            rng: Rng(rng.next_u64()),
            remaining: 500,
            last: 0,
        }));
        e.post(0, id, Msg::Tick);
        e.run_to_completion();
        Ok(())
    });
}
