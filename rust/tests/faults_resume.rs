//! Fault-injection + resilient-executor properties (docs/ROBUSTNESS.md):
//!
//! * a fault schedule is part of the configuration, so a faulted
//!   campaign's canonical `campaign.json` is byte-identical at every
//!   `--shards` and `--jobs` level;
//! * perf-only faults (degraded links, outages) slow the run but leave
//!   the final memory state untouched — every correctness check still
//!   passes;
//! * finite-width timestamps (`ts_bits`) roll over via epoch flushes at
//!   8/12/16 bits under HALCONE and are inert under HMG;
//! * an interrupted campaign resumed with `sweep --resume` — whether
//!   interrupted logically (journaled cells still pending) or by a real
//!   SIGKILL mid-run — converges to the same canonical bytes as an
//!   uninterrupted run.

use std::path::PathBuf;
use std::process::Command;

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::sweep::exec::{run_campaign, ExecOptions};
use halcone::sweep::spec::CampaignSpec;
use halcone::sweep::{json, report};

/// An aggressive perf-only schedule: short windows so a smoke run spans
/// many of them, high degrade/outage rates so the counters must move.
const SCHEDULE: &str = "seed=7;window=200;degrade=0.5;latmul=3;bwdiv=2;outage=0.4";

fn faulted_campaign() -> String {
    format!(
        "name = faults-smoke\n\
         presets = SM-WT-C-HALCONE,SM-WT-NC\n\
         workloads = fir,rl\n\
         set.n_gpus = 2\n\
         set.cus_per_gpu = 2\n\
         set.wavefronts_per_cu = 2\n\
         set.l2_banks = 2\n\
         set.stacks_per_gpu = 2\n\
         set.gpu_mem_bytes = 67108864\n\
         set.scale = 0.05\n\
         set.faults = {SCHEDULE}\n"
    )
}

fn spec() -> CampaignSpec {
    CampaignSpec::parse(&faulted_campaign()).unwrap()
}

fn small(preset: &str) -> SystemConfig {
    let mut cfg = SystemConfig::preset(preset);
    cfg.n_gpus = 2;
    cfg.cus_per_gpu = 2;
    cfg.wavefronts_per_cu = 2;
    cfg.l2_banks = 2;
    cfg.stacks_per_gpu = 2;
    cfg.gpu_mem_bytes = 64 << 20;
    cfg.scale = 0.05;
    cfg
}

/// Per-test temp dir (tests share one process and may run in parallel).
fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("halcone_faults_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Canonical bytes of an on-disk artifact: reconstruct every cell and
/// re-render. Fails if any cell is still pending/timed out.
fn canonical_of_artifact(text: &str) -> String {
    let doc = json::parse(text).unwrap();
    let spec = CampaignSpec::from_artifact(&doc).unwrap();
    let preloaded = report::outcomes_from_artifact(&doc).unwrap();
    let total = spec.config_labels().len() * spec.workloads.len();
    assert_eq!(preloaded.len(), total, "artifact still has non-terminal cells");
    let res = run_campaign(
        &spec,
        &ExecOptions { jobs: 1, progress: false, preloaded, ..Default::default() },
    )
    .unwrap();
    report::to_json_canonical(&res)
}

#[test]
fn fault_schedule_is_byte_identical_across_shards_levels() {
    let run = |shards: usize| {
        let res = run_campaign(
            &spec(),
            &ExecOptions { jobs: 1, progress: false, shards: Some(shards), ..Default::default() },
        )
        .unwrap();
        assert!(res.all_passed(), "faulted campaign failed at shards={shards}");
        report::to_json_canonical(&res)
    };
    assert_eq!(run(1), run(4), "faulted campaign.json differs between --shards 1 and 4");
}

#[test]
fn fault_schedule_is_byte_identical_across_jobs_levels() {
    let run = |jobs: usize| {
        let res = run_campaign(
            &spec(),
            &ExecOptions { jobs, progress: false, ..Default::default() },
        )
        .unwrap();
        assert!(res.all_passed(), "faulted campaign failed at jobs={jobs}");
        report::to_json_canonical(&res)
    };
    assert_eq!(run(1), run(8), "faulted campaign.json differs between --jobs 1 and 8");
}

#[test]
fn perf_faults_slow_the_run_but_preserve_the_final_state() {
    let clean = run_workload(&small("SM-WT-C-HALCONE"), "fir", None);
    assert!(clean.all_passed());

    let mut cfg = small("SM-WT-C-HALCONE");
    cfg.set("faults", SCHEDULE).unwrap();
    let faulted = run_workload(&cfg, "fir", None);
    // The divergence oracle: perf-only faults reorder nothing the
    // references can see — every correctness check still passes.
    assert!(faulted.all_passed(), "{:?}", faulted.checks);
    assert!(
        faulted.metrics.cycles >= clean.metrics.cycles,
        "faults may only slow the run ({} -> {})",
        clean.metrics.cycles,
        faulted.metrics.cycles,
    );
    let f = faulted.metrics.faults.as_ref().expect("faulted run must report fault counters");
    assert!(
        f.link_outage_cycles + f.link_degraded_msgs > 0,
        "an aggressive schedule must actually perturb some link"
    );
    assert!(clean.metrics.faults.is_none(), "clean runs carry no fault section");
}

#[test]
fn finite_timestamps_roll_over_at_every_width_and_stay_correct() {
    for bits in [8u32, 12, 16] {
        for preset in ["SM-WT-C-HALCONE", "RDMA-WB-C-HMG"] {
            let mut cfg = small(preset);
            cfg.set("faults", &format!("ts_bits={bits}")).unwrap();
            let res = run_workload(&cfg, "fir", None);
            assert!(res.all_passed(), "{preset}/ts_bits={bits}: {:?}", res.checks);
            let f = res.metrics.faults.as_ref().expect("ts_bits run must report fault counters");
            if preset.contains("HMG") {
                // HMG carries no timestamps: the width knob is inert.
                assert_eq!(f.rollover_flushes, 0, "{preset}/ts_bits={bits}");
                assert_eq!(f.tsu_rollovers, 0, "{preset}/ts_bits={bits}");
            } else if bits == 8 {
                // A smoke run spans far more than 2^8 cycles, so the
                // narrowest width must actually cross epochs.
                assert!(
                    f.rollover_flushes + f.tsu_rollovers > 0,
                    "ts_bits=8 run never rolled over"
                );
            }
        }
    }
}

#[test]
fn journaled_pending_cells_resume_to_identical_bytes() {
    let dir = tmpdir("pending");
    let reference = run_campaign(
        &spec(),
        &ExecOptions { jobs: 2, progress: false, ..Default::default() },
    )
    .unwrap();
    assert!(reference.all_passed());
    let reference_canonical = report::to_json_canonical(&reference);

    // Reproduce a campaign interrupted after two cells: the journal
    // holds two terminal cells and two still pending.
    let interrupted = report::to_json(&reference)
        .replacen("\"status\": \"ok\"", "\"status\": \"pending\"", 2);
    let journal = dir.join("campaign.json");
    std::fs::write(&journal, interrupted).unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_halcone"))
        .args(["sweep", "--resume"])
        .arg(&dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "resume failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let resumed = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(
        canonical_of_artifact(&resumed),
        reference_canonical,
        "resumed artifact diverges from the uninterrupted run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sigkill_mid_campaign_then_resume_is_byte_identical() {
    let dir = tmpdir("sigkill");
    let spec_path = dir.join("faults.spec");
    std::fs::write(&spec_path, faulted_campaign()).unwrap();
    let journal = dir.join("campaign.json");

    let reference = run_campaign(
        &spec(),
        &ExecOptions { jobs: 1, progress: false, ..Default::default() },
    )
    .unwrap();
    let reference_canonical = report::to_json_canonical(&reference);

    // Start the campaign, wait for the journal to exist (it is written
    // before any worker starts), then SIGKILL mid-run. Whenever the kill
    // lands, the atomic-rename journal is a complete, valid artifact.
    let mut child = Command::new(env!("CARGO_BIN_EXE_halcone"))
        .arg("sweep")
        .arg("--spec")
        .arg(&spec_path)
        .args(["--jobs", "2", "--out"])
        .arg(&journal)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();
    let mut waited = 0u64;
    while !journal.exists() && waited < 20_000 {
        std::thread::sleep(std::time::Duration::from_millis(5));
        waited += 5;
    }
    assert!(journal.exists(), "sweep never journaled its initial state");
    child.kill().ok(); // SIGKILL on unix; a no-op if it already finished
    child.wait().unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_halcone"))
        .args(["sweep", "--resume"])
        .arg(&journal)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "resume after SIGKILL failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let resumed = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(
        canonical_of_artifact(&resumed),
        reference_canonical,
        "post-SIGKILL resume diverges from the uninterrupted run"
    );
    std::fs::remove_dir_all(&dir).ok();
}
