//! Multi-tenant mix properties (docs/TENANCY.md).
//!
//! The three pillars the subsystem guarantees:
//!
//! 1. **Determinism** — a mix campaign's canonical `campaign.json` is
//!    byte-identical at every `--shards` and `--jobs` level, because the
//!    scheduler's admission decisions depend only on simulated time and
//!    the logical shard partition is fixed by the topology.
//! 2. **Fold conservation** — per-tenant attribution tables sum exactly
//!    to the untagged counters (the tenant tag rides the same bump
//!    sites, so nothing is double-counted or dropped).
//! 3. **Fairness metrics** — the Jain index behaves per its definition
//!    at the boundary cases the per-tenant report exercises.

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::metrics::tenancy::jain;
use halcone::sweep::exec::{run_campaign, ExecOptions};
use halcone::sweep::spec::CampaignSpec;
use halcone::sweep::{gate, report};

/// The CI mix cell: a read-mostly tenant sharing the machine with a
/// false-sharing tenant that arrives 64 cycles late — under HALCONE and
/// with coherence off, at the smoke geometry.
const MIX_CAMPAIGN: &str = "name = tenancy-ci\n\
     presets = SM-WT-C-HALCONE,SM-WT-NC\n\
     workloads = mix:read-mostly+false-sharing@64\n\
     set.n_gpus = 2\n\
     set.cus_per_gpu = 2\n\
     set.wavefronts_per_cu = 2\n\
     set.l2_banks = 2\n\
     set.stacks_per_gpu = 2\n\
     set.gpu_mem_bytes = 67108864\n\
     set.scale = 0.05\n";

fn mix_spec() -> CampaignSpec {
    CampaignSpec::parse(MIX_CAMPAIGN).unwrap()
}

fn small(preset: &str) -> SystemConfig {
    let mut cfg = SystemConfig::preset(preset);
    cfg.n_gpus = 2;
    cfg.cus_per_gpu = 2;
    cfg.wavefronts_per_cu = 2;
    cfg.l2_banks = 2;
    cfg.stacks_per_gpu = 2;
    cfg.gpu_mem_bytes = 64 << 20;
    cfg.scale = 0.05;
    cfg
}

#[test]
fn mix_campaign_is_byte_identical_across_shards_levels() {
    let serial = run_campaign(
        &mix_spec(),
        &ExecOptions { jobs: 1, progress: false, shards: Some(1), ..Default::default() },
    )
    .unwrap();
    let sharded = run_campaign(
        &mix_spec(),
        &ExecOptions { jobs: 1, progress: false, shards: Some(4), ..Default::default() },
    )
    .unwrap();
    assert!(serial.all_passed() && sharded.all_passed());
    assert_eq!(
        report::to_json_canonical(&serial),
        report::to_json_canonical(&sharded),
        "mix campaign.json differs between --shards 1 and --shards 4"
    );
}

#[test]
fn mix_campaign_is_byte_identical_across_jobs_levels() {
    let serial = run_campaign(
        &mix_spec(),
        &ExecOptions { jobs: 1, progress: false, ..Default::default() },
    )
    .unwrap();
    let parallel = run_campaign(
        &mix_spec(),
        &ExecOptions { jobs: 8, progress: false, ..Default::default() },
    )
    .unwrap();
    assert_eq!(
        report::to_json_canonical(&serial),
        report::to_json_canonical(&parallel),
        "mix campaign.json differs between --jobs 1 and --jobs 8"
    );
}

#[test]
fn mix_gate_round_trip_passes_at_zero_tolerance() {
    let opts = ExecOptions { jobs: 2, progress: false, shards: None, ..Default::default() };
    let baseline = report::to_json(&run_campaign(&mix_spec(), &opts).unwrap());
    let current = report::to_json(&run_campaign(&mix_spec(), &opts).unwrap());
    let rep = gate::diff(&baseline, &current, 0.0).unwrap();
    assert!(rep.passed(), "{}", rep.describe());
    assert_eq!(rep.compared, 2);
}

#[test]
fn per_tenant_attribution_conserves_the_untagged_totals() {
    for preset in ["SM-WT-C-HALCONE", "SM-WT-NC"] {
        let res = run_workload(&small(preset), "mix:read-mostly+false-sharing@64", None);
        let m = &res.metrics;
        let t = m.tenancy.as_ref().expect("mix run must carry a tenancy report");
        assert_eq!(t.tenants.len(), 2, "{preset}");
        let sum = |f: fn(&halcone::metrics::tenancy::TenantMetrics) -> u64| {
            t.tenants.iter().map(f).sum::<u64>()
        };
        assert_eq!(sum(|tm| tm.loads), m.cu_loads, "{preset}: loads leak");
        assert_eq!(sum(|tm| tm.stores), m.cu_stores, "{preset}: stores leak");
        assert_eq!(sum(|tm| tm.l1_hits), m.l1.hits, "{preset}: hits leak");
        assert_eq!(sum(|tm| tm.l1_misses), m.l1.misses, "{preset}: misses leak");
        assert_eq!(
            sum(|tm| tm.l1_coherency_misses),
            m.l1.coherency_misses,
            "{preset}: coherency misses leak"
        );
        // Both tenants actually ran and finished exactly their one job.
        assert!(t.tenants.iter().all(|tm| tm.jobs == 1 && tm.turnaround_sum > 0));
    }
}

#[test]
fn tab_tenant_builtin_runs_end_to_end_with_per_tenant_metrics() {
    let spec = CampaignSpec::builtin("tab-tenant").unwrap();
    let res = run_campaign(
        &spec,
        &ExecOptions { jobs: 4, progress: false, shards: None, ..Default::default() },
    )
    .unwrap();
    assert!(res.all_passed());
    let doc = halcone::sweep::json::parse(&report::to_json_canonical(&res)).unwrap();
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 6);
    for cell in cells {
        let t = cell.get("metrics").unwrap().get("tenancy").unwrap();
        let j = t.get("jain_turnaround").unwrap().as_f64().unwrap();
        assert!(j > 0.0 && j <= 1.0 + 1e-12, "jain out of range: {j}");
        let tenants = t.get("tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), 2);
        for tm in tenants {
            assert!(tm.get("jobs").unwrap().as_f64().unwrap() >= 1.0);
            assert!(tm.get("turnaround_mean").unwrap().as_f64().unwrap() > 0.0);
        }
    }
}

#[test]
fn round_robin_spec_file_runs_and_reports_its_policy() {
    let dir = std::env::temp_dir()
        .join(format!("halcone-tenancy-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rr.mix");
    std::fs::write(
        &path,
        "policy = rr\n\
         tenant.a.stream = synth:private\n\
         tenant.a.replicas = 3\n\
         tenant.a.spacing = 32\n\
         tenant.b.stream = synth:migratory\n\
         tenant.b.arrival = 16\n\
         tenant.b.replicas = 2\n",
    )
    .unwrap();
    let name = format!("mix:{}", path.display());
    let res = run_workload(&small("SM-WT-NC"), &name, None);
    let t = res.metrics.tenancy.as_ref().unwrap();
    assert_eq!(t.scheduler, "rr");
    assert_eq!(t.tenants.len(), 2);
    assert_eq!(t.tenants[0].jobs, 3);
    assert_eq!(t.tenants[1].jobs, 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn jain_index_boundary_cases() {
    // Equal allocations are perfectly fair.
    assert!((jain(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
    // One hog among n tenants approaches 1/n.
    assert!((jain(&[9.0, 0.0, 0.0]) - 1.0 / 3.0).abs() < 1e-12);
    // Degenerate inputs read as fair rather than dividing by zero.
    assert_eq!(jain(&[]), 1.0);
    assert_eq!(jain(&[0.0, 0.0]), 1.0);
    // Always within (0, 1].
    let j = jain(&[1.0, 2.0, 3.0, 4.0]);
    assert!(j > 0.0 && j <= 1.0);
}
