//! Cross-protocol conformance suite (docs/PROTOCOLS.md): every
//! timestamp protocol the framework speaks — halcone, tardis, hlc —
//! plus the hmg and no-coherence references must honor the same
//! engine-level contracts: byte-determinism across `--shards` and
//! `--jobs`, per-shard event folds that conserve the engine total,
//! snapshot warm-starts that reproduce the cold run exactly, and
//! (for the timestamp protocols) finite-width `ts_bits` epochs that
//! roll over without breaking correctness.

use std::sync::Arc;

use halcone::config::SystemConfig;
use halcone::coordinator::runner::{run_workload, try_run_workload_snap, SnapMode};
use halcone::sweep::exec::{run_campaign, ExecOptions};
use halcone::sweep::report;
use halcone::sweep::spec::CampaignSpec;

/// One preset per protocol arm of the frontier sweep.
const PROTOCOL_PRESETS: [&str; 5] = [
    "SM-WT-C-HALCONE",
    "SM-WT-C-TARDIS",
    "SM-WT-C-HLC",
    "RDMA-WB-C-HMG",
    "SM-WT-NC",
];

fn small(preset: &str) -> SystemConfig {
    let mut cfg = SystemConfig::preset(preset);
    cfg.n_gpus = 2;
    cfg.cus_per_gpu = 2;
    cfg.wavefronts_per_cu = 2;
    cfg.l2_banks = 2;
    cfg.stacks_per_gpu = 2;
    cfg.gpu_mem_bytes = 64 << 20;
    cfg.scale = 0.05;
    cfg
}

fn conformance_spec() -> CampaignSpec {
    CampaignSpec::parse(&format!(
        "name = conformance\n\
         presets = {}\n\
         workloads = rl,fir\n\
         set.n_gpus = 2\n\
         set.cus_per_gpu = 2\n\
         set.wavefronts_per_cu = 2\n\
         set.l2_banks = 2\n\
         set.stacks_per_gpu = 2\n\
         set.gpu_mem_bytes = 67108864\n\
         set.scale = 0.05\n",
        PROTOCOL_PRESETS.join(","),
    ))
    .unwrap()
}

fn canonical(spec: &CampaignSpec, jobs: usize, shards: usize) -> String {
    let opts =
        ExecOptions { jobs, progress: false, shards: Some(shards), ..Default::default() };
    let res = run_campaign(spec, &opts).unwrap();
    assert!(res.all_passed(), "conformance grid failed at jobs={jobs} shards={shards}");
    report::to_json_canonical(&res)
}

#[test]
fn every_protocol_is_byte_identical_across_shards_and_jobs() {
    let spec = conformance_spec();
    let reference = canonical(&spec, 1, 1);
    assert_eq!(
        reference,
        canonical(&spec, 1, 4),
        "canonical artifact differs between shards=1 and shards=4"
    );
    assert_eq!(
        reference,
        canonical(&spec, 8, 1),
        "canonical artifact differs between jobs=1 and jobs=8"
    );
}

#[test]
fn per_shard_event_folds_conserve_the_engine_total() {
    // The host-side per-shard occupancy profile must fold back to the
    // engine's event count under every protocol — a protocol that lost
    // or double-counted events across the shard boundary would break
    // the conservation here before anything else notices.
    for preset in PROTOCOL_PRESETS {
        let mut cfg = small(preset);
        cfg.shards = 3; // one worker per logical shard (2 GPUs + hub)
        let res = run_workload(&cfg, "fir", None);
        assert!(res.all_passed(), "{preset}: {:?}", res.checks);
        let m = &res.metrics;
        assert!(!m.shard_events.is_empty(), "{preset}: no shard profile");
        let folded: u64 = m.shard_events.iter().sum();
        assert_eq!(folded, m.events, "{preset}: shard events fold != engine total");
        let has_tsu = cfg.coherence.ts_policy().is_some();
        assert_eq!(
            m.tsu_lookups > 0,
            has_tsu,
            "{preset}: TSU traffic must exist iff the protocol carries timestamps"
        );
    }
}

#[test]
fn snapshot_warm_start_round_trips_for_every_protocol() {
    for preset in PROTOCOL_PRESETS {
        let cfg = small(preset);
        let key = |r: &halcone::coordinator::runner::RunResult| {
            (
                r.metrics.cycles,
                r.metrics.events,
                r.metrics.l1.hits,
                r.metrics.l1.misses,
                r.metrics.l1.coherency_misses,
                r.metrics.l2.hits,
                r.metrics.l2.misses,
                r.metrics.tsu_lookups,
                r.metrics.mem_bytes,
            )
        };
        let cold = run_workload(&cfg, "fir", None);
        assert!(cold.all_passed(), "{preset}: {:?}", cold.checks);
        let (saving, _, bytes) =
            try_run_workload_snap(&cfg, "fir", None, false, SnapMode::Save { at: 500 })
                .unwrap_or_else(|e| panic!("{preset}: save run failed: {e}"));
        let bytes = bytes.unwrap_or_else(|| panic!("{preset}: run never reached cycle 500"));
        assert_eq!(key(&saving), key(&cold), "{preset}: saving a snapshot changed the run");
        let warm_mode = SnapMode::Warm { bytes: Arc::new(bytes) };
        let (warm, _, _) = try_run_workload_snap(&cfg, "fir", None, false, warm_mode)
            .unwrap_or_else(|e| panic!("{preset}: warm start refused: {e}"));
        assert!(warm.all_passed(), "{preset}: {:?}", warm.checks);
        assert_eq!(key(&warm), key(&cold), "{preset}: warm start diverged from cold run");
    }
}

#[test]
fn new_protocols_roll_over_finite_timestamps_and_stay_correct() {
    // Finite ts_bits epochs under the two new protocols: the epoch
    // flush must preserve correctness at every width, actually fire at
    // the narrowest width (timestamps grow with lease grants, so a
    // smoke run crosses 2^8 many times), and stay deterministic.
    for preset in ["SM-WT-C-TARDIS", "SM-WT-C-HLC"] {
        for bits in [8u32, 12, 16] {
            let run = || {
                let mut cfg = small(preset);
                cfg.set("faults", &format!("ts_bits={bits}")).unwrap();
                run_workload(&cfg, "fir", None)
            };
            let res = run();
            assert!(res.all_passed(), "{preset}/ts_bits={bits}: {:?}", res.checks);
            let f = res.metrics.faults.as_ref().expect("ts_bits run must report fault counters");
            if bits == 8 {
                assert!(
                    f.rollover_flushes + f.tsu_rollovers > 0,
                    "{preset}: ts_bits=8 run never rolled over"
                );
            }
            let again = run();
            assert_eq!(
                (res.metrics.cycles, res.metrics.events, f.rollover_flushes, f.tsu_rollovers),
                (
                    again.metrics.cycles,
                    again.metrics.events,
                    again.metrics.faults.as_ref().unwrap().rollover_flushes,
                    again.metrics.faults.as_ref().unwrap().tsu_rollovers,
                ),
                "{preset}/ts_bits={bits}: rollover behavior is not deterministic"
            );
        }
    }
}
