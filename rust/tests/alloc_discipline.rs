//! The zero-allocation contract of the event hot loop (ISSUE 2 /
//! docs/PERF.md): once the engine, calendar-queue buckets and message
//! pool are warm, a steady-state run of memory transactions performs no
//! heap allocation — boxes recycle through the pool, payloads are inline
//! `LineBuf`s, and queue buckets reuse their capacity.
//!
//! A counting global allocator measures the steady-state window. This
//! file holds exactly one `#[test]` so no concurrent test thread can
//! pollute the counters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use halcone::mem::LineBuf;
use halcone::sim::{CompId, Component, Ctx, Cycle, Engine, MemReq, MemRsp, Msg};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static FREES: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        FREES.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        FREES.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn counters() -> (u64, u64) {
    (ALLOCS.load(Ordering::SeqCst), FREES.load(Ordering::SeqCst))
}

/// Issues a pooled request every time it is poked; consumes responses.
struct Requester {
    name: String,
    responder: CompId,
    remaining: u64,
}
impl Component for Requester {
    halcone::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }
    fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
        if let Msg::Rsp(b) = msg {
            let rsp = ctx.reclaim_rsp(b);
            assert_eq!(rsp.data.len(), 64);
        }
        if self.remaining > 0 {
            self.remaining -= 1;
            let req = MemReq {
                id: self.remaining,
                addr: (self.remaining % 64) * 64,
                size: 4,
                src: ctx.self_id,
                dst: self.responder,
                data: LineBuf::from_slice(&[1, 2, 3, 4]),
                ..MemReq::default()
            };
            let target = self.responder;
            let msg = ctx.req_msg(req);
            ctx.schedule(3, target, msg);
        }
    }
}

/// Answers every request with a full-line pooled response.
struct Responder {
    name: String,
}
impl Component for Responder {
    halcone::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }
    fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
        let Msg::Req(b) = msg else { unreachable!() };
        let req = ctx.reclaim_req(b);
        let rsp = MemRsp {
            id: req.id,
            kind: req.kind,
            addr: req.addr,
            dst: req.src,
            data: LineBuf::zeroed(64),
            ts: None,
        };
        let target = req.src;
        let msg = ctx.rsp_msg(rsp);
        ctx.schedule(5, target, msg);
    }
}

#[test]
fn steady_state_event_loop_is_allocation_free() {
    let mut e = Engine::new();
    let rq = CompId(0);
    let rs = CompId(1);
    e.add(Box::new(Requester {
        name: "rq".into(),
        responder: rs,
        remaining: 2_000_000,
    }));
    e.add(Box::new(Responder { name: "rs".into() }));
    e.post(0, rq, Msg::Tick);

    // Warm-up: populate the message pool and bucket capacities. One
    // round trip is 8 cycles, so this drives ~12.5k transactions.
    e.run(100_000);
    assert!(!e.is_idle(), "warm-up must pause mid-run");

    // Steady state: every transaction must reuse pooled boxes and
    // pre-sized queue buckets — zero net allocations across the window.
    let (a0, f0) = counters();
    let evs0 = e.events_processed();
    e.run(4_100_000);
    let (a1, f1) = counters();
    let events = e.events_processed() - evs0;
    assert!(events > 500_000, "measured window too small: {events} events");

    let allocs = a1 - a0;
    let frees = f1 - f0;
    assert_eq!(
        allocs, 0,
        "event hot loop allocated {allocs} times over {events} events"
    );
    assert_eq!(frees, 0, "event hot loop freed {frees} times (churn)");

    // Pool accounting: exactly one box of each kind was ever taken from
    // the allocator; every other transaction reused it.
    e.run_to_completion();
    let p = e.pool();
    assert_eq!(p.fresh_reqs, 1, "req boxes must recycle ({})", p.fresh_reqs);
    assert_eq!(p.fresh_rsps, 1, "rsp boxes must recycle ({})", p.fresh_rsps);
    assert!(p.reused_reqs >= 1_000_000, "reuse counter: {}", p.reused_reqs);
    assert_eq!(p.idle(), (1, 1), "both boxes parked in the pool at drain");
}
