//! Campaign-level determinism and gate round-trip.
//!
//! Piggybacks on the single-run determinism guarantee
//! (`deterministic_across_runs` in `coordinator::runner`): since every
//! cell is deterministic and artifacts are ordered by spec expansion,
//! the whole `campaign.json` must be byte-identical across `--jobs`
//! levels once the host-timing fields are excluded.

use halcone::sweep::exec::{run_campaign, ExecOptions};
use halcone::sweep::spec::CampaignSpec;
use halcone::sweep::{gate, json, report};

#[test]
fn campaign_json_is_byte_identical_across_jobs_levels() {
    let spec = CampaignSpec::builtin("smoke").unwrap();
    let opts = ExecOptions { jobs: 1, progress: false, ..Default::default() };
    let serial = run_campaign(&spec, &opts).unwrap();
    let opts = ExecOptions { jobs: 8, progress: false, ..Default::default() };
    let parallel = run_campaign(&spec, &opts).unwrap();
    assert!(serial.all_passed(), "smoke campaign failed serially");
    assert!(parallel.all_passed(), "smoke campaign failed in parallel");

    // Canonical artifacts (host timing excluded) are byte-identical.
    let a = report::to_json_canonical(&serial);
    let b = report::to_json_canonical(&parallel);
    assert_eq!(a, b, "campaign.json differs between --jobs 1 and --jobs 8");

    // The full artifacts differ only on the host-dependent lines
    // (host_seconds and the events_per_sec derived from it).
    let strip = |s: &str| {
        s.lines()
            .filter(|l| {
                !l.contains("\"host_seconds\"") && !l.contains("\"events_per_sec\"")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(
        strip(&report::to_json(&serial)),
        strip(&report::to_json(&parallel)),
        "non-host fields differ between --jobs 1 and --jobs 8"
    );
}

#[test]
fn same_commit_gate_round_trip_passes_at_zero_tolerance() {
    let spec = CampaignSpec::builtin("smoke").unwrap();
    let opts = ExecOptions { jobs: 4, progress: false, ..Default::default() };
    let run = run_campaign(&spec, &opts).unwrap();
    let baseline = report::to_json(&run);
    // A fresh artifact from the same commit must gate cleanly even with
    // zero tolerance (cycles are deterministic).
    let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
    let rerun = run_campaign(&spec, &opts).unwrap();
    let current = report::to_json(&rerun);
    let rep = gate::diff(&baseline, &current, 0.0).unwrap();
    assert!(rep.passed(), "{}", rep.describe());
    assert_eq!(rep.compared, 4);
}

#[test]
fn artifact_is_wellformed_json_with_expected_shape() {
    let spec = CampaignSpec::builtin("smoke").unwrap();
    let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
    let run = run_campaign(&spec, &opts).unwrap();
    let doc = json::parse(&report::to_json(&run)).unwrap();
    assert_eq!(doc.get("campaign").unwrap().as_str(), Some("smoke"));
    let spec_obj = doc.get("spec").unwrap();
    assert_eq!(
        spec_obj.get("baseline").unwrap().as_str(),
        Some("SM-WT-NC")
    );
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 4);
    for cell in cells {
        let m = cell.get("metrics").unwrap();
        for key in [
            "cycles",
            "events",
            "host_seconds",
            "events_per_sec",
            "cu_loads",
            "cu_stores",
            "l1_l2_transactions",
            "l2_mm_transactions",
        ] {
            assert!(m.get(key).is_some(), "metrics missing '{key}'");
        }
        assert!(cell.get("checks").unwrap().as_arr().unwrap().len() > 0);
        // Baseline column reports speedup 1.0, others a finite number.
        let s = cell.get("speedup").unwrap().as_f64().unwrap();
        assert!(s.is_finite() && s > 0.0);
    }
}
