//! The trace-subsystem contract (ISSUE 5):
//!
//! * record → serialize → parse → replay reproduces direct execution's
//!   cycle count, event count and traffic counters exactly, per
//!   protocol (HALCONE, HMG/RDMA, no-coherence);
//! * the per-access oracle: a replay's re-recording is byte-identical
//!   to the input trace ([`halcone::metrics::divergence`]);
//! * recording is `--shards`-invariant (the CI golden-trace premise);
//! * a campaign with a `trace:<file>` workload axis produces canonical
//!   `campaign.json` byte-identical across jobs/shards levels;
//! * synthetic patterns replay on multiple protocols, and every error
//!   path (missing/corrupt file, partition mismatch) is a clean error.

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload_traced;
use halcone::metrics::divergence::diff_traces;
use halcone::sweep::exec::{run_campaign, ExecOptions};
use halcone::sweep::report;
use halcone::sweep::spec::CampaignSpec;
use halcone::trace::{self, SharingPattern, SynthSpec};
use halcone::workloads;

fn small(preset: &str) -> SystemConfig {
    let mut cfg = SystemConfig::preset(preset);
    cfg.n_gpus = 2;
    cfg.cus_per_gpu = 2;
    cfg.wavefronts_per_cu = 2;
    cfg.l2_banks = 2;
    cfg.stacks_per_gpu = 2;
    cfg.gpu_mem_bytes = 64 << 20;
    cfg.scale = 0.05;
    cfg
}

/// Unique temp path per test (tests share one process).
fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir();
    format!("{}/halcone_trace_{}_{name}.trc", dir.display(), std::process::id())
}

fn record_replay_roundtrip(preset: &str, workload: &str, tag: &str) {
    let cfg = small(preset);
    let (direct, captured) = run_workload_traced(&cfg, workload, None, true);
    assert!(direct.all_passed(), "{preset}/{workload}: {:?}", direct.checks);
    let t = captured.expect("capture was requested");
    assert!(direct.metrics.cycles > 0);
    assert_eq!(t.meta.cycles, direct.metrics.cycles);
    assert_eq!(t.meta.events, direct.metrics.events);
    assert_eq!(t.total_ops(), direct.metrics.cu_ops(), "every CU op is recorded");

    let path = tmp(tag);
    trace::save(&t, &path).unwrap();
    let loaded = trace::load(&path).unwrap();
    assert_eq!(loaded, t, "serialize -> parse must round-trip bit-exactly");

    let (replayed, rerec) = run_workload_traced(&cfg, &format!("trace:{path}"), None, true);
    std::fs::remove_file(&path).ok();
    let d = &direct.metrics;
    let r = &replayed.metrics;
    assert_eq!(r.cycles, d.cycles, "{preset}: replay must reproduce cycles exactly");
    assert_eq!(r.events, d.events, "{preset}: replay must reproduce the event count");
    assert_eq!(r.cu_loads, d.cu_loads);
    assert_eq!(r.cu_stores, d.cu_stores);
    assert_eq!(r.l1_l2_transactions(), d.l1_l2_transactions());
    assert_eq!(r.l2_mm_transactions(), d.l2_mm_transactions());
    assert_eq!(r.mm_reads, d.mm_reads);
    assert_eq!(r.mm_writes, d.mm_writes);
    assert_eq!(r.mem_bytes, d.mem_bytes);
    assert_eq!(r.pcie_bytes, d.pcie_bytes);
    assert_eq!(r.tsu_lookups, d.tsu_lookups);

    // The per-access oracle: replaying re-records the identical stream.
    let rep = diff_traces(&t, &rerec.unwrap());
    assert!(rep.identical(), "{preset}: replay diverged:\n{}", rep.describe());
}

#[test]
fn record_replay_is_exact_under_halcone() {
    record_replay_roundtrip("SM-WT-C-HALCONE", "fir", "hc");
}

#[test]
fn record_replay_is_exact_under_hmg_rdma() {
    // RDMA also exercises the host-copy delay, reproduced from the
    // recorded init layout.
    record_replay_roundtrip("RDMA-WB-C-HMG", "rl", "hmg");
}

#[test]
fn record_replay_is_exact_without_coherence() {
    record_replay_roundtrip("SM-WT-NC", "bs", "nc");
}

#[test]
fn rdma_replay_charges_the_recorded_copy_delay() {
    let cfg = small("RDMA-WB-NC");
    let (_, t) = run_workload_traced(&cfg, "rl", None, true);
    let t = t.unwrap();
    assert!(!t.meta.init.is_empty(), "recorded init layout must survive");
    let homed: u64 = t.meta.init.iter().map(|&(_, n)| 4 * n).sum();
    assert!(homed > 0, "rl has a real initial image");
}

#[test]
fn recording_is_byte_identical_across_shards() {
    // The CI golden-trace premise: the tap buffers per CU, so the
    // assembled (and serialized) trace is a pure function of the
    // simulated configuration, not of the engine thread count.
    let run = |shards: u32| {
        let mut cfg = small("SM-WT-C-HALCONE");
        cfg.shards = shards;
        let (_, t) = run_workload_traced(&cfg, "fir", None, true);
        trace::encode(&t.unwrap())
    };
    assert_eq!(run(1), run(4), "recorded trace differs between shards=1 and shards=4");
}

#[test]
fn trace_campaign_canonical_json_is_byte_identical_across_jobs_and_shards() {
    let cfg = small("SM-WT-C-HALCONE");
    let (direct, t) = run_workload_traced(&cfg, "rl", None, true);
    let path = tmp("campaign");
    trace::save(&t.unwrap(), &path).unwrap();
    let spec = CampaignSpec::parse(&format!(
        "name = trace-smoke\n\
         presets = SM-WT-C-HALCONE\n\
         workloads = trace:{path}\n\
         set.n_gpus = 2\n\
         set.cus_per_gpu = 2\n\
         set.wavefronts_per_cu = 2\n\
         set.l2_banks = 2\n\
         set.stacks_per_gpu = 2\n\
         set.gpu_mem_bytes = 67108864\n\
         set.scale = 0.05\n"
    ))
    .unwrap();
    let run = |jobs: usize, shards: usize| {
        let opts =
            ExecOptions { jobs, progress: false, shards: Some(shards), ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert!(res.all_passed(), "trace campaign failed (jobs={jobs}, shards={shards})");
        let cycles = res
            .expect_metrics("SM-WT-C-HALCONE", &format!("trace:{path}"))
            .cycles;
        (report::to_json_canonical(&res), cycles)
    };
    let (serial, cycles_serial) = run(1, 1);
    let (parallel, cycles_parallel) = run(2, 4);
    std::fs::remove_file(&path).ok();
    assert_eq!(serial, parallel, "canonical artifact differs across jobs/shards");
    assert_eq!(cycles_serial, cycles_parallel);
    assert_eq!(
        cycles_serial, direct.metrics.cycles,
        "replay cell must reproduce direct execution's cycles"
    );
}

#[test]
fn synthetic_patterns_replay_on_multiple_protocols() {
    for (i, pat) in SharingPattern::NAMES.iter().enumerate() {
        let spec = SynthSpec {
            pattern: SharingPattern::parse(pat).unwrap(),
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            gpu_mem_bytes: 64 << 20,
            ops_per_wavefront: 16,
            lines: 8,
            gap: 1,
            phases: 1,
            seed: 3,
        };
        let t = trace::generate(&spec).unwrap();
        let path = tmp(&format!("synth{i}"));
        trace::save(&t, &path).unwrap();
        for preset in ["SM-WT-C-HALCONE", "SM-WT-NC"] {
            let cfg = small(preset);
            let (res, rerec) = run_workload_traced(&cfg, &format!("trace:{path}"), None, true);
            assert!(res.metrics.cycles > 0, "{pat}/{preset}");
            assert_eq!(
                res.metrics.cu_ops(),
                t.total_ops(),
                "{pat}/{preset}: every synthetic op must be issued"
            );
            // The CI synthetic leg's oracle: the re-recorded stream is
            // structurally the generated one (timing is fresh, synthetic
            // baselines carry none).
            let rep = diff_traces(&t, &rerec.unwrap());
            assert!(
                rep.structural_identical(),
                "{pat}/{preset}: {}",
                rep.describe()
            );
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn gpu_fold_replay_conserves_ops() {
    // A 4-GPU synthetic trace folded onto the 2-GPU smoke config: every
    // op still issues, rehomed into the surviving partitions.
    let spec = SynthSpec {
        pattern: SharingPattern::AllToAll,
        n_gpus: 4,
        cus_per_gpu: 2,
        wavefronts_per_cu: 2,
        gpu_mem_bytes: 64 << 20,
        ops_per_wavefront: 16,
        lines: 8,
        gap: 2,
        phases: 2,
        seed: 7,
    };
    let t = trace::generate(&spec).unwrap();
    let path = tmp("fold");
    trace::save(&t, &path).unwrap();
    let cfg = small("SM-WT-C-HALCONE");
    let (res, _) = run_workload_traced(&cfg, &format!("trace:{path}"), None, false);
    std::fs::remove_file(&path).ok();
    assert!(res.metrics.cycles > 0);
    assert_eq!(res.metrics.cu_ops(), t.total_ops());
}

#[test]
fn bad_trace_paths_and_partition_mismatch_are_clean_errors() {
    // Missing file: rejected at campaign-spec validation, not mid-run.
    let e = CampaignSpec::parse("workloads = trace:/no/such/halcone.trc\n").unwrap_err();
    assert!(e.contains("halcone.trc"), "{e}");

    // Corrupt file: same.
    let path = tmp("corrupt");
    std::fs::write(&path, b"not a trace").unwrap();
    let e = CampaignSpec::parse(&format!("workloads = trace:{path}\n")).unwrap_err();
    assert!(e.contains("magic"), "{e}");

    // Partition-size mismatch: a clean build error naming the knob.
    let cfg = small("SM-WT-C-HALCONE");
    let (_, t) = run_workload_traced(&cfg, "rl", None, true);
    trace::save(&t.unwrap(), &path).unwrap();
    let mut shrunk = small("SM-WT-C-HALCONE");
    shrunk.gpu_mem_bytes = 32 << 20;
    let e = workloads::try_build(&format!("trace:{path}"), &shrunk.workload_params()).unwrap_err();
    std::fs::remove_file(&path).ok();
    assert!(e.contains("gpu_mem_bytes"), "{e}");
}
