//! Deterministic fault injection (docs/ROBUSTNESS.md).
//!
//! A [`FaultSpec`] describes degraded hardware as a *pure function of
//! simulated time*: the timeline is divided into fixed-width windows and
//! each (link, window) pair is hashed — SplitMix64 over the seed, the
//! link's registration ordinal and the window index — into one of three
//! states: healthy, degraded (latency × `latmul`, bandwidth ÷ `bwdiv`)
//! or outage (traffic queues until the window ends; nothing is ever
//! dropped). Because the state depends only on `(seed, link, window)`
//! and every effect can only *delay* a delivery, injection preserves
//! both the sharded engine's conservative-window contract and full
//! byte-determinism across `--shards`/`--jobs`.
//!
//! `ts_bits` additionally enables the finite-width timestamp mode: the
//! HALCONE cache clocks and the TSU treat logical time as N-bit
//! counters and conservatively flush on every epoch (2^N) crossing —
//! see [`epoch_of`] and the rollover counters in
//! [`crate::metrics::FaultReport`].

use crate::sim::Cycle;

/// SplitMix64: the standard 64-bit finalizer-style mixer. Used as a
/// stateless hash so fault decisions never depend on call order.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// What a link experiences during one fault window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowState {
    Healthy,
    /// Latency multiplied by `latmul`, bandwidth divided by `bwdiv`.
    Degraded,
    /// The link accepts nothing; traffic queues and drains on recovery.
    Outage,
}

/// Hard cap on consecutive outage windows a deferral scan will skip.
/// With `outage <= MAX_OUTAGE` the probability of hitting it is ~0;
/// it guarantees termination regardless of parameters.
const MAX_OUTAGE_SCAN: u64 = 1024;

/// Upper bound for the `outage` probability: a link must be able to
/// drain, so a permanently-down link is not expressible.
pub const MAX_OUTAGE: f64 = 0.9;

/// A seeded, fully deterministic fault schedule (`--faults`, config key
/// `faults`). Grammar: semicolon-separated `key=value` pairs —
/// semicolons, because commas separate axis values in campaign specs:
///
/// ```text
/// faults = seed=7;window=20000;degrade=0.2;latmul=4;bwdiv=4;outage=0.05;ts_bits=12
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Root seed: same seed ⇒ byte-identical run at any shard/job count.
    pub seed: u64,
    /// Fault-window width in cycles.
    pub window: Cycle,
    /// Probability a (link, window) pair is degraded.
    pub degrade: f64,
    /// Latency multiplier inside degraded windows (≥ 1).
    pub latmul: u64,
    /// Bandwidth divisor inside degraded windows (≥ 1).
    pub bwdiv: u64,
    /// Probability a (link, window) pair is a full outage (≤ 0.9).
    pub outage: f64,
    /// Finite timestamp width in bits; 0 keeps unbounded `u64` time.
    pub ts_bits: u32,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 0xFA17,
            window: 20_000,
            degrade: 0.0,
            latmul: 4,
            bwdiv: 4,
            outage: 0.0,
            ts_bits: 0,
        }
    }
}

impl FaultSpec {
    /// Parse the `key=value;...` grammar. `"none"`/`"off"` parse to
    /// `None` so specs can switch faults off per axis value.
    pub fn parse(text: &str) -> Result<Option<FaultSpec>, String> {
        let text = text.trim();
        if text.is_empty() || text == "none" || text == "off" {
            return Ok(None);
        }
        let mut f = FaultSpec::default();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("faults: '{part}': expected key=value"))?;
            let (k, v) = (k.trim(), v.trim());
            let uerr = |e: &dyn std::fmt::Display| format!("faults: {k}={v}: {e}");
            match k {
                "seed" => f.seed = v.parse().map_err(|e| uerr(&e))?,
                "window" => f.window = v.parse().map_err(|e| uerr(&e))?,
                "degrade" => f.degrade = v.parse().map_err(|e| uerr(&e))?,
                "latmul" => f.latmul = v.parse().map_err(|e| uerr(&e))?,
                "bwdiv" => f.bwdiv = v.parse().map_err(|e| uerr(&e))?,
                "outage" => f.outage = v.parse().map_err(|e| uerr(&e))?,
                "ts_bits" => f.ts_bits = v.parse().map_err(|e| uerr(&e))?,
                other => {
                    return Err(format!(
                        "faults: unknown key '{other}' \
                         (want seed|window|degrade|latmul|bwdiv|outage|ts_bits)"
                    ))
                }
            }
        }
        f.validate()?;
        Ok(Some(f))
    }

    fn validate(&self) -> Result<(), String> {
        if self.window == 0 {
            return Err("faults: window=0: window width must be positive".into());
        }
        if self.latmul == 0 || self.bwdiv == 0 {
            return Err("faults: latmul/bwdiv must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.degrade) {
            return Err(format!("faults: degrade={} out of [0,1]", self.degrade));
        }
        if !(0.0..=MAX_OUTAGE).contains(&self.outage) {
            return Err(format!(
                "faults: outage={} out of [0,{MAX_OUTAGE}] (a link must be able to drain)",
                self.outage
            ));
        }
        if self.degrade + self.outage > 1.0 {
            return Err(format!(
                "faults: degrade+outage={} exceeds 1",
                self.degrade + self.outage
            ));
        }
        if self.ts_bits != 0 && !(4..=62).contains(&self.ts_bits) {
            return Err(format!("faults: ts_bits={}: want 0 (unbounded) or 4..=62", self.ts_bits));
        }
        Ok(())
    }

    /// True when the spec perturbs link behavior at all (a pure
    /// `ts_bits` spec leaves every link healthy).
    pub fn perturbs_links(&self) -> bool {
        self.degrade > 0.0 || self.outage > 0.0
    }

    /// The deterministic state of `(link ordinal, window index)`.
    pub fn window_state(&self, link_ord: u32, window: u64) -> WindowState {
        // Stateless 53-bit uniform draw; integer thresholds keep the
        // comparison exact and platform-independent.
        let h = splitmix64(
            self.seed
                ^ (link_ord as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ window.wrapping_mul(0xbf58_476d_1ce4_e5b9),
        ) >> 11;
        let unit = (1u64 << 53) as f64;
        if h < (self.outage * unit) as u64 {
            WindowState::Outage
        } else if h < ((self.outage + self.degrade) * unit) as u64 {
            WindowState::Degraded
        } else {
            WindowState::Healthy
        }
    }
}

impl std::fmt::Display for FaultSpec {
    fn fmt(&self, w: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            w,
            "seed={};window={};degrade={};latmul={};bwdiv={};outage={};ts_bits={}",
            self.seed, self.window, self.degrade, self.latmul, self.bwdiv, self.outage, self.ts_bits
        )
    }
}

/// Per-link fault view: the spec plus the link's registration ordinal
/// (LinkIds are assigned in topology-construction order, which is a
/// pure function of the configuration — never of the host).
#[derive(Clone, Debug)]
pub struct LinkFaults {
    spec: FaultSpec,
    ord: u32,
}

impl LinkFaults {
    pub fn new(spec: FaultSpec, ord: u32) -> Self {
        LinkFaults { spec, ord }
    }

    fn state_at(&self, t: Cycle) -> WindowState {
        self.spec.window_state(self.ord, t / self.spec.window)
    }

    /// Earliest cycle `>= now` outside an outage window. Caps the scan
    /// at [`MAX_OUTAGE_SCAN`] consecutive outage windows so the model
    /// terminates under any parameters.
    pub fn available_at(&self, now: Cycle) -> Cycle {
        let mut t = now;
        for _ in 0..MAX_OUTAGE_SCAN {
            let w = t / self.spec.window;
            if self.spec.window_state(self.ord, w) != WindowState::Outage {
                return t;
            }
            t = (w + 1) * self.spec.window;
        }
        t
    }

    /// `(latency multiplier, bandwidth divisor)` for the window holding
    /// `t`. Both are 1 in healthy windows.
    pub fn perf_at(&self, t: Cycle) -> (u64, u64) {
        match self.state_at(t) {
            WindowState::Degraded => (self.spec.latmul, self.spec.bwdiv),
            _ => (1, 1),
        }
    }
}

/// Epoch index of a logical timestamp under an N-bit counter: the
/// number of 2^N rollovers the hardware would have performed. `bits=0`
/// (unbounded) pins everything to epoch 0.
pub fn epoch_of(ts: u64, bits: u32) -> u64 {
    if bits == 0 {
        0
    } else {
        ts >> bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_and_validates() {
        let f = FaultSpec::parse("seed=7;degrade=0.25;outage=0.1;ts_bits=12")
            .unwrap()
            .unwrap();
        assert_eq!(f.seed, 7);
        assert_eq!(f.window, 20_000);
        assert_eq!(f.degrade, 0.25);
        assert_eq!(f.outage, 0.1);
        assert_eq!(f.ts_bits, 12);
        // Display output re-parses to the same spec.
        assert_eq!(FaultSpec::parse(&f.to_string()).unwrap().unwrap(), f);
        assert_eq!(FaultSpec::parse("none").unwrap(), None);
        assert_eq!(FaultSpec::parse("off").unwrap(), None);
        assert_eq!(FaultSpec::parse("").unwrap(), None);
    }

    #[test]
    fn bad_specs_are_rejected_with_the_offending_key() {
        for (spec, needle) in [
            ("bogus=1", "unknown key 'bogus'"),
            ("degrade", "expected key=value"),
            ("degrade=1.5", "degrade=1.5"),
            ("outage=0.95", "outage=0.95"),
            ("degrade=0.6;outage=0.6", "exceeds 1"),
            ("window=0", "window=0"),
            ("latmul=0", "latmul/bwdiv"),
            ("ts_bits=2", "ts_bits=2"),
            ("seed=x", "seed=x"),
        ] {
            let err = FaultSpec::parse(spec).unwrap_err();
            assert!(err.contains(needle), "{spec}: {err}");
        }
    }

    #[test]
    fn window_state_is_a_pure_function() {
        let f = FaultSpec { degrade: 0.3, outage: 0.2, ..FaultSpec::default() };
        for link in 0..4 {
            for w in 0..64 {
                assert_eq!(f.window_state(link, w), f.window_state(link, w));
            }
        }
        // Distinct links see distinct schedules (overwhelmingly likely
        // for any reasonable hash; this seed is fixed).
        let a: Vec<_> = (0..64).map(|w| f.window_state(0, w)).collect();
        let b: Vec<_> = (0..64).map(|w| f.window_state(1, w)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn state_frequencies_track_probabilities() {
        let f = FaultSpec { degrade: 0.25, outage: 0.1, ..FaultSpec::default() };
        let n = 20_000u64;
        let mut deg = 0;
        let mut out = 0;
        for w in 0..n {
            match f.window_state(3, w) {
                WindowState::Degraded => deg += 1,
                WindowState::Outage => out += 1,
                WindowState::Healthy => {}
            }
        }
        let (dp, op) = (deg as f64 / n as f64, out as f64 / n as f64);
        assert!((dp - 0.25).abs() < 0.02, "degraded fraction {dp}");
        assert!((op - 0.10).abs() < 0.02, "outage fraction {op}");
    }

    #[test]
    fn zero_probability_specs_leave_links_healthy() {
        let f = FaultSpec { ts_bits: 12, ..FaultSpec::default() };
        assert!(!f.perturbs_links());
        for w in 0..256 {
            assert_eq!(f.window_state(0, w), WindowState::Healthy);
        }
        let lf = LinkFaults::new(f, 0);
        assert_eq!(lf.available_at(12345), 12345);
        assert_eq!(lf.perf_at(12345), (1, 1));
    }

    #[test]
    fn available_at_skips_outage_windows_forward_only() {
        let f = FaultSpec { outage: 0.5, window: 100, ..FaultSpec::default() };
        let lf = LinkFaults::new(f, 2);
        for now in [0u64, 37, 555, 12_345, 999_999] {
            let t = lf.available_at(now);
            assert!(t >= now, "deferral may only move forward");
            assert_ne!(lf.state_at(t), WindowState::Outage);
            // Every skipped window really was an outage.
            let mut w = now / f.window;
            while w < t / f.window {
                assert_eq!(f.window_state(2, w), WindowState::Outage);
                w += 1;
            }
        }
    }

    #[test]
    fn epochs_count_rollovers() {
        assert_eq!(epoch_of(255, 8), 0);
        assert_eq!(epoch_of(256, 8), 1);
        assert_eq!(epoch_of(1 << 13, 12), 2);
        assert_eq!(epoch_of(u64::MAX, 0), 0);
    }

    #[test]
    fn splitmix_matches_reference_vector() {
        // Reference value for seed 1234567 from the SplitMix64 paper's
        // public-domain implementation.
        assert_eq!(splitmix64(0), 0xe220a8397b1dcdaf);
        assert_ne!(splitmix64(1), splitmix64(2));
    }
}
