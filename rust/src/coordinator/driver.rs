//! The leader/driver component: kernel-launch orchestration.
//!
//! Dispatches each workload phase (= kernel launch) to every CU, waits for
//! all `PhaseDone`s, then runs the two-stage kernel-boundary fence:
//!
//! 1. `FenceQuery` -> every cache reports its logical clock (`cts`).
//! 2. `FenceApply { logical_max = max(cts) + 1 }` -> protocol-specific
//!    action (HALCONE: clock advance, NC: flush+invalidate, HMG/WB: dirty
//!    write-back) — see DESIGN.md §6 for the `+1` correctness argument.
//!
//! The final phase is also fenced so write-back configurations drain dirty
//! data to MM before the coordinator verifies the memory image.
//!
//! Under RDMA the driver models the paper's host-to-GPU copy phase as an
//! initial delay (bytes over the per-GPU PCIe links); MGPU-SM skips it —
//! "shared memory eliminates this traffic" (§5.1).

use crate::sim::{CompId, Component, Ctx, Cycle, Msg};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum State {
    Idle,
    Running,
    FenceQuery,
    FenceApply,
    Finished,
}

/// Kernel-launch coordinator.
pub struct Driver {
    name: String,
    cus: Vec<CompId>,
    caches: Vec<CompId>,
    n_phases: u32,
    phase: u32,
    state: State,
    pending: usize,
    logical_max: u64,
    /// Host->GPU copy time charged before phase 0 (RDMA only).
    initial_delay: Cycle,
    /// Completion time of each phase (diagnostics).
    pub phase_end: Vec<Cycle>,
    /// Total cycles when everything (incl. final fence) finished.
    pub done_at: Option<Cycle>,
}

impl Driver {
    pub fn new(
        name: impl Into<String>,
        cus: Vec<CompId>,
        caches: Vec<CompId>,
        n_phases: u32,
        initial_delay: Cycle,
    ) -> Self {
        Driver {
            name: name.into(),
            cus,
            caches,
            n_phases,
            phase: 0,
            state: State::Idle,
            pending: 0,
            logical_max: 0,
            initial_delay,
            phase_end: Vec::new(),
            done_at: None,
        }
    }

    fn dispatch(&mut self, delay: Cycle, ctx: &mut Ctx) {
        self.state = State::Running;
        self.pending = self.cus.len();
        let phase = self.phase;
        for &cu in &self.cus {
            ctx.schedule(delay, cu, Msg::StartPhase { phase });
        }
    }

    fn start_fence(&mut self, ctx: &mut Ctx) {
        self.state = State::FenceQuery;
        self.pending = self.caches.len();
        let me = ctx.self_id;
        for &c in &self.caches {
            ctx.schedule(0, c, Msg::FenceQuery { reply_to: me });
        }
    }
}

impl Component for Driver {
    crate::impl_component_any!();

    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match (self.state, msg) {
            (State::Idle, Msg::Tick) => {
                if self.n_phases == 0 {
                    self.state = State::Finished;
                    self.done_at = Some(now);
                    return;
                }
                self.dispatch(self.initial_delay, ctx);
            }
            (State::Running, Msg::PhaseDone { .. }) => {
                self.pending -= 1;
                if self.pending == 0 {
                    self.phase_end.push(now);
                    self.start_fence(ctx);
                }
            }
            (State::FenceQuery, Msg::FenceInfo { cts, .. }) => {
                self.logical_max = self.logical_max.max(cts);
                self.pending -= 1;
                if self.pending == 0 {
                    self.state = State::FenceApply;
                    self.pending = self.caches.len();
                    // +1 so every stale lease provably expires (DESIGN §6).
                    let lm = self.logical_max + 1;
                    let me = ctx.self_id;
                    for &c in &self.caches {
                        ctx.schedule(0, c, Msg::FenceApply { reply_to: me, logical_max: lm });
                    }
                }
            }
            (State::FenceApply, Msg::FenceDone { .. }) => {
                self.pending -= 1;
                if self.pending == 0 {
                    self.phase += 1;
                    if self.phase < self.n_phases {
                        self.dispatch(0, ctx);
                    } else {
                        self.state = State::Finished;
                        self.done_at = Some(now);
                    }
                }
            }
            (s, m) => panic!("{}: message {m:?} in state {s:?}", self.name),
        }
    }

    // CU/cache wiring, phase count and the copy delay are rebuilt from
    // config; only the launch progress is serialized.
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format::{put, put_bool};
        put(out, self.phase as u64);
        out.push(match self.state {
            State::Idle => 0,
            State::Running => 1,
            State::FenceQuery => 2,
            State::FenceApply => 3,
            State::Finished => 4,
        });
        put(out, self.pending as u64);
        put(out, self.logical_max);
        put(out, self.phase_end.len() as u64);
        for &t in &self.phase_end {
            put(out, t);
        }
        put_bool(out, self.done_at.is_some());
        if let Some(t) = self.done_at {
            put(out, t);
        }
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        self.phase = cur.u64("driver phase")? as u32;
        self.state = match cur.byte("driver state tag")? {
            0 => State::Idle,
            1 => State::Running,
            2 => State::FenceQuery,
            3 => State::FenceApply,
            4 => State::Finished,
            t => return Err(format!("driver has unknown state tag {t}")),
        };
        self.pending = cur.u64("driver pending count")? as usize;
        self.logical_max = cur.u64("driver logical max")?;
        let n = cur.u64("driver phase-end count")? as usize;
        if n > cur.b.len() {
            return Err(format!("driver phase-end count {n} exceeds snapshot size"));
        }
        self.phase_end.clear();
        for i in 0..n {
            self.phase_end.push(cur.u64(&format!("driver phase-end {i}"))?);
        }
        self.done_at = if cur.bool("driver done flag")? {
            Some(cur.u64("driver done cycle")?)
        } else {
            None
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Engine;

    /// CU stub: completes each phase after a fixed delay.
    struct StubCu {
        name: String,
        driver: CompId,
        delay: Cycle,
        pub phases_seen: Vec<u32>,
    }
    impl Component for StubCu {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
            if let Msg::StartPhase { phase } = msg {
                self.phases_seen.push(phase);
                let d = self.driver;
                ctx.schedule(self.delay, d, Msg::PhaseDone { cu: ctx.self_id });
            }
        }
    }

    /// Cache stub: reports a fixed cts, acks fences after a delay.
    struct StubCache {
        name: String,
        cts: u64,
        pub fences: Vec<u64>,
    }
    impl Component for StubCache {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
            match msg {
                Msg::FenceQuery { reply_to } => {
                    let cts = self.cts;
                    ctx.schedule(1, reply_to, Msg::FenceInfo { from: ctx.self_id, cts });
                }
                Msg::FenceApply { reply_to, logical_max } => {
                    self.fences.push(logical_max);
                    ctx.schedule(3, reply_to, Msg::FenceDone { from: ctx.self_id });
                }
                _ => {}
            }
        }
    }

    #[test]
    fn runs_phases_with_fences_between() {
        let mut e = Engine::new();
        let driver = CompId(0);
        let cu0 = CompId(1);
        let cu1 = CompId(2);
        let c0 = CompId(3);
        let c1 = CompId(4);
        e.add(Box::new(Driver::new("drv", vec![cu0, cu1], vec![c0, c1], 3, 0)));
        e.add(Box::new(StubCu { name: "cu0".into(), driver, delay: 100, phases_seen: vec![] }));
        e.add(Box::new(StubCu { name: "cu1".into(), driver, delay: 70, phases_seen: vec![] }));
        e.add(Box::new(StubCache { name: "c0".into(), cts: 15, fences: vec![] }));
        e.add(Box::new(StubCache { name: "c1".into(), cts: 9, fences: vec![] }));
        e.post(0, driver, Msg::Tick);
        e.run_to_completion();
        let d = e.downcast::<Driver>(driver);
        assert_eq!(d.phase_end.len(), 3);
        assert!(d.done_at.is_some());
        // All CUs saw phases 0, 1, 2 in order.
        assert_eq!(e.downcast::<StubCu>(cu0).phases_seen, vec![0, 1, 2]);
        // Fences carried max(cts) + 1 = 16.
        assert_eq!(e.downcast::<StubCache>(c0).fences, vec![16, 16, 16]);
    }

    #[test]
    fn initial_delay_charges_copy_phase() {
        let mut e = Engine::new();
        let driver = CompId(0);
        let cu = CompId(1);
        let c = CompId(2);
        e.add(Box::new(Driver::new("drv", vec![cu], vec![c], 1, 5000)));
        e.add(Box::new(StubCu { name: "cu".into(), driver, delay: 10, phases_seen: vec![] }));
        e.add(Box::new(StubCache { name: "c".into(), cts: 0, fences: vec![] }));
        e.post(0, driver, Msg::Tick);
        e.run_to_completion();
        let d = e.downcast::<Driver>(driver);
        assert!(d.done_at.unwrap() >= 5010);
    }

    #[test]
    fn zero_phases_finishes_immediately() {
        let mut e = Engine::new();
        let driver = CompId(0);
        e.add(Box::new(Driver::new("drv", vec![], vec![], 0, 0)));
        e.post(0, driver, Msg::Tick);
        e.run_to_completion();
        assert_eq!(e.downcast::<Driver>(driver).done_at, Some(0));
    }
}
