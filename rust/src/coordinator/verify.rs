//! Golden-model verification (DESIGN.md S19): after a simulation, the
//! final memory image must match what the workload's math says — computed
//! either by an AOT-compiled JAX/Pallas artifact through the PJRT runtime
//! or by a Rust reference. A coherence bug that leaks a stale value
//! anywhere in the hierarchy fails these checks.

use crate::dram::SharedMemory;
use crate::runtime::Runtime;
use crate::workloads::Verify;

/// Result of one check.
#[derive(Clone, Debug)]
pub struct CheckOutcome {
    pub desc: String,
    /// "artifact" | "rust" | "skipped".
    pub kind: &'static str,
    pub passed: bool,
    pub max_err: f32,
}

/// Relative-or-absolute closeness: |got - want| <= tol * max(1, |want|).
/// `tol = 0` demands bit-equal f32.
fn max_err(got: &[f32], want: &[f32]) -> f32 {
    got.iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs() / 1f32.max(w.abs()))
        .fold(0.0, f32::max)
}

/// Snapshot the input arrays of every check (call after init, before run).
pub fn snapshot_inputs(checks: &[Verify], mem: &SharedMemory) -> Vec<Vec<Vec<f32>>> {
    checks
        .iter()
        .map(|chk| match chk {
            Verify::Artifact { inputs, .. } | Verify::Rust { inputs, .. } => {
                inputs.iter().map(|a| a.read(mem)).collect()
            }
            Verify::None => vec![],
        })
        .collect()
}

/// Run all checks against the post-run memory image.
pub fn run_checks(
    checks: &[Verify],
    snapshots: &[Vec<Vec<f32>>],
    mem: &SharedMemory,
    mut runtime: Option<&mut Runtime>,
) -> Vec<CheckOutcome> {
    let mut out = Vec::new();
    for (chk, snap) in checks.iter().zip(snapshots) {
        match chk {
            Verify::None => {}
            Verify::Rust { outputs, golden, tol, .. } => {
                let want = golden(snap);
                let mut worst = 0.0f32;
                let mut pass = true;
                for (arr, w) in outputs.iter().zip(&want) {
                    let got = arr.read(mem);
                    let e = max_err(&got, w);
                    worst = worst.max(e);
                    pass &= got.len() == w.len() && e <= *tol;
                }
                out.push(CheckOutcome {
                    desc: format!(
                        "rust golden ({})",
                        outputs.iter().map(|a| a.name.as_str()).collect::<Vec<_>>().join(",")
                    ),
                    kind: "rust",
                    passed: pass,
                    max_err: worst,
                });
            }
            Verify::Artifact { artifact, outputs, tol, .. } => {
                let Some(rt) = runtime.as_deref_mut() else {
                    out.push(CheckOutcome {
                        desc: format!("artifact {artifact} (no runtime)"),
                        kind: "skipped",
                        passed: true,
                        max_err: 0.0,
                    });
                    continue;
                };
                match rt.exec_f32(artifact, snap) {
                    Ok(want) => {
                        let mut worst = 0.0f32;
                        let mut pass = true;
                        for (arr, w) in outputs.iter().zip(&want) {
                            let got = arr.read(mem);
                            let e = max_err(&got, w);
                            worst = worst.max(e);
                            pass &= got.len() == w.len() && e <= *tol;
                        }
                        out.push(CheckOutcome {
                            desc: format!("XLA artifact {artifact}"),
                            kind: "artifact",
                            passed: pass,
                            max_err: worst,
                        });
                    }
                    Err(e) => out.push(CheckOutcome {
                        desc: format!("artifact {artifact}: {e}"),
                        kind: "skipped",
                        passed: true,
                        max_err: 0.0,
                    }),
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::GlobalMemory;
    use crate::workloads::Array;

    #[test]
    fn max_err_is_relative_above_one() {
        assert_eq!(max_err(&[1.0], &[1.0]), 0.0);
        assert!(max_err(&[100.1], &[100.0]) < 0.01);
        assert!(max_err(&[0.1], &[0.0]) >= 0.1); // absolute below 1
    }

    #[test]
    fn rust_check_passes_and_fails_correctly() {
        let mem = GlobalMemory::new_shared();
        let input = Array::contiguous("in", 0x100, 4);
        let output = Array::contiguous("out", 0x200, 4);
        input.write(&mem, &[1.0, 2.0, 3.0, 4.0]);
        output.write(&mem, &[2.0, 4.0, 6.0, 8.0]);
        let checks = vec![Verify::Rust {
            inputs: vec![input.clone()],
            outputs: vec![output.clone()],
            golden: Box::new(|ins| vec![ins[0].iter().map(|x| 2.0 * x).collect()]),
            tol: 0.0,
        }];
        let snaps = snapshot_inputs(&checks, &mem);
        let res = run_checks(&checks, &snaps, &mem, None);
        assert!(res[0].passed, "{res:?}");

        // Corrupt one output word: the check must fail.
        mem.borrow_mut().write_f32(0x204, 99.0);
        let res = run_checks(&checks, &snaps, &mem, None);
        assert!(!res[0].passed);
        assert!(res[0].max_err > 1.0);
    }

    #[test]
    fn artifact_without_runtime_is_skipped_not_failed() {
        let mem = GlobalMemory::new_shared();
        let arr = Array::contiguous("x", 0, 4);
        let checks = vec![Verify::Artifact {
            artifact: "whatever".into(),
            inputs: vec![arr.clone()],
            outputs: vec![arr.clone()],
            tol: 0.0,
        }];
        let snaps = snapshot_inputs(&checks, &mem);
        let res = run_checks(&checks, &snaps, &mem, None);
        assert_eq!(res[0].kind, "skipped");
        assert!(res[0].passed);
    }
}
