//! Topology builder (DESIGN.md S13): turns a [`SystemConfig`] + workload
//! into a fully wired simulation.
//!
//! * **SharedMem** (Fig. 3): every GPU's L2 banks connect through a
//!   per-GPU uplink (256 GB/s, the paper's per-GPU L2-to-MM budget) into
//!   one switch complex, which fans out to all HBM stacks (341 GB/s each;
//!   4 GPUs x 256 ~ 1 TB/s aggregate, §4.1).
//! * **Rdma** (Fig. 1): each GPU owns its stacks behind a local memory
//!   switch; inter-GPU traffic (NC remote L1 access, HMG peer/home and
//!   invalidations) crosses per-GPU PCIe links (32 GB/s) through one PCIe
//!   switch.
//!
//! The *fabric* knob picks the engine partition. `fabric = ports`
//! (default) splits the central switch into one fabric/PCIe port per
//! GPU, places each MC/TSU in the shard of the GPU whose address range
//! owns its HBM stack ([`crate::mem::AddrMap::stack_owner`]), meshes the
//! ports with explicit inter-port links (declared to the engine so its
//! per-shard-pair lookahead matrix sizes windows from the links actually
//! in play), and leaves only the driver/kernel-scheduler on a slim hub
//! shard. `fabric = hub` keeps the pre-partition layout (one central
//! switch; under SM every MC on the hub) as the before/after perf
//! comparator. `shard_groups` folds several GPUs into one shard —
//! profile-guided static rebalancing via [`plan_shard_groups`].

use std::collections::HashMap;

use crate::coherence::halcone::{HalconeL1, HalconeL2};
use crate::coherence::hmg::HmgL2;
use crate::coherence::none::{PlainL1, PlainL2};
use crate::coherence::{L1Routes, L2Routes};
use crate::config::{Coherence, Fabric, SystemConfig};
use crate::coordinator::driver::Driver;
use crate::coordinator::scheduler::KernelScheduler;
use crate::dram::{GlobalMemory, MemCtrl, SharedMemory};
use crate::tenancy::MixPlan;
use crate::gpu::Cu;
use crate::interconnect::Switch;
use crate::mem::addr::Topology;
use crate::mem::cache::CacheParams;
use crate::sim::{CompId, Cycle, Engine, Link, LinkId};
use crate::tsu::Tsu;
use crate::workloads::Workload;

/// A built system ready to run.
pub struct System {
    pub engine: Engine,
    pub mem: SharedMemory,
    pub driver: CompId,
    pub cus: Vec<CompId>,
    pub l1s: Vec<CompId>,
    pub l2s: Vec<CompId>,
    pub mcs: Vec<CompId>,
    /// PCIe link ids (RDMA traffic accounting).
    pub pcie_links: Vec<LinkId>,
    /// L2<->MM network link ids.
    pub mem_links: Vec<LinkId>,
    pub coherence: Coherence,
    /// The fault schedule the system was built under (metrics section
    /// presence must be a pure function of the configuration).
    pub faults: Option<crate::faults::FaultSpec>,
}

/// Compute the RDMA host->GPU copy delay for a workload's initial image:
/// each GPU receives the bytes homed in its partition over its own PCIe
/// link; copies proceed in parallel, so the delay is the slowest GPU's.
pub fn copy_delay(cfg: &SystemConfig, wl: &Workload) -> Cycle {
    if cfg.topology != Topology::Rdma {
        return 0;
    }
    let map = cfg.addr_map();
    let mut per_gpu = vec![0u64; cfg.n_gpus as usize];
    for (addr, vals) in &wl.init {
        per_gpu[map.home_gpu(*addr) as usize] += vals.len() as u64 * 4;
    }
    per_gpu.iter().map(|b| b.div_ceil(cfg.pcie_bw)).max().unwrap_or(0)
}

/// Profile-guided static rebalancing: fold `gpu_events.len()` GPUs into
/// `target_groups` shard groups by greedy LPT (longest processing time)
/// over recorded per-GPU shard event counts (the host-only
/// `shard_events` occupancy profile from a prior run). Deterministic:
/// GPUs are placed in descending-events order (ties by index) onto the
/// least-loaded group (ties by group id). The result feeds the
/// `shard_groups` config key.
pub fn plan_shard_groups(gpu_events: &[u64], target_groups: usize) -> Vec<u32> {
    assert!(target_groups >= 1, "plan_shard_groups: need at least one group");
    let n = gpu_events.len();
    let groups = target_groups.min(n.max(1));
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| gpu_events[b].cmp(&gpu_events[a]).then(a.cmp(&b)));
    let mut load = vec![0u64; groups];
    let mut out = vec![0u32; n];
    for gi in order {
        let target = (0..groups).min_by_key(|&k| (load[k], k)).unwrap();
        load[target] += gpu_events[gi];
        out[gi] = target as u32;
    }
    out
}

/// Render a grouping as the `shard_groups` config value (`0,0,1,...`).
pub fn shard_groups_value(groups: &[u32]) -> String {
    groups.iter().map(|g| g.to_string()).collect::<Vec<_>>().join(",")
}

/// Build the full system and load the workload's programs into the CUs.
/// (Computes the RDMA copy delay from `wl.init`; see [`copy_delay`].)
pub fn build(cfg: &SystemConfig, wl: Workload) -> System {
    let initial_delay = copy_delay(cfg, &wl);
    build_with_delay(cfg, wl, initial_delay)
}

/// [`build`] with an explicit initial (host-copy) delay.
pub fn build_with_delay(cfg: &SystemConfig, wl: Workload, initial_delay: Cycle) -> System {
    build_inner(cfg, wl, initial_delay, None)
}

/// Build a multi-tenant mix system: the root component (`CompId(0)`) is a
/// [`KernelScheduler`] admitting the plan's tenant kernels instead of the
/// barrier [`Driver`], and each CU carries the plan's phase->tenant map so
/// memory requests are tenant-tagged at issue.
pub fn build_mix(
    cfg: &SystemConfig,
    wl: Workload,
    plan: &MixPlan,
    initial_delay: Cycle,
) -> System {
    build_inner(cfg, wl, initial_delay, Some(plan))
}

fn build_inner(
    cfg: &SystemConfig,
    mut wl: Workload,
    initial_delay: Cycle,
    mix: Option<&MixPlan>,
) -> System {
    if cfg.coherence.ts_policy().is_some() {
        assert_eq!(
            cfg.topology,
            Topology::SharedMem,
            "timestamp protocols are defined for MGPU-SM systems (paper §3)"
        );
    }
    if cfg.coherence == Coherence::Hmg {
        assert_eq!(cfg.topology, Topology::Rdma, "HMG comparator runs on RDMA topology");
    }

    let map = cfg.addr_map();
    let g = cfg.n_gpus as usize;
    let c = cfg.cus_per_gpu as usize;
    let b = cfg.l2_banks as usize;
    let stacks = map.total_stacks() as usize;

    // ---- Id layout (components are added in exactly this order).
    let driver = CompId(0);
    let mut next = 1u32;
    let mut cu_ids = vec![vec![CompId::NONE; c]; g];
    let mut l1_ids = vec![vec![CompId::NONE; c]; g];
    let mut l2_ids = vec![vec![CompId::NONE; b]; g];
    for gi in 0..g {
        for ci in 0..c {
            cu_ids[gi][ci] = CompId(next);
            next += 1;
        }
        for ci in 0..c {
            l1_ids[gi][ci] = CompId(next);
            next += 1;
        }
        for bi in 0..b {
            l2_ids[gi][bi] = CompId(next);
            next += 1;
        }
    }
    let rdma = cfg.topology == Topology::Rdma;
    let ports = cfg.fabric == Fabric::Ports;
    // Switches. Hub fabric: SM -> one central switch complex, RDMA ->
    // per-GPU local memory switch + one PCIe switch. Ports fabric: the
    // central switch is replaced by one fabric (SM) or PCIe (RDMA) port
    // per GPU, each owned by its GPU's shard.
    let swc = CompId(next); // SM hub fabric only
    let port_ids: Vec<CompId> = (0..g).map(|i| CompId(next + i as u32)).collect(); // SM ports
    let lsw_ids: Vec<CompId> = (0..g).map(|i| CompId(next + i as u32)).collect(); // RDMA
    let psw = CompId(next + g as u32); // RDMA hub fabric
    let pport_ids: Vec<CompId> =
        (0..g).map(|i| CompId(next + (g + i) as u32)).collect(); // RDMA ports
    next += match (rdma, ports) {
        (false, false) => 1,
        (false, true) => g as u32,
        (true, false) => g as u32 + 1,
        (true, true) => 2 * g as u32,
    };
    let mc_ids: Vec<CompId> = (0..stacks).map(|s| CompId(next + s as u32)).collect();

    let all_banks: Vec<Vec<CompId>> = l2_ids.clone();

    // ---- Engine: one logical shard per GPU shard-group plus a hub.
    //
    // Ports fabric (default): GPU shard `gi` owns that GPU's CUs, L1s,
    // L2 banks, its fabric/PCIe port (and local memory switch under
    // RDMA), plus the MCs/TSUs of the HBM stacks its address range owns.
    // The hub shard holds only the driver/kernel-scheduler, whose
    // linkless kernel-launch/fence hops quantize to window barriers.
    // Cross-shard traffic rides the declared inter-port links, so the
    // engine's per-shard-pair lookahead matrix sizes windows from the
    // links actually in play (see `sim::shard`). Hub fabric keeps the
    // pre-partition layout (central switch; under SM every MC/TSU on the
    // hub) as the before/after perf comparator.
    //
    // `shard_groups` folds several GPUs into one shard (profile-guided
    // rebalancing, [`plan_shard_groups`]). The partition depends only on
    // the configuration — never the `shards` thread count — so every
    // thread count reproduces the identical event order (campaign
    // byte-identity).
    let group_of: Vec<u32> = if cfg.shard_groups.is_empty() {
        (0..g as u32).collect()
    } else {
        assert_eq!(
            cfg.shard_groups.len(),
            g,
            "shard_groups must name one group per GPU ({} entries for {} GPUs)",
            cfg.shard_groups.len(),
            g,
        );
        cfg.shard_groups.clone()
    };
    let n_groups = group_of.iter().max().map_or(1, |m| m + 1);
    for gid in 0..n_groups {
        assert!(
            group_of.contains(&gid),
            "shard_groups: group ids must be contiguous (0..{n_groups} is missing {gid})"
        );
    }
    let hub = n_groups;
    let lookahead = if rdma { cfg.pcie_lat + 1 } else { cfg.swc_lat + 1 };
    let mut engine = Engine::sharded(n_groups + 1, lookahead);
    // Fault injection must be armed before any link registration so the
    // per-link ordinals — the fault hash key — cover the whole
    // interconnect in configuration order (docs/ROBUSTNESS.md).
    engine.set_fault_spec(cfg.faults);
    let ts_bits = cfg.faults.map_or(0, |f| f.ts_bits);
    // A stack's shard: its owner GPU's group, except under the legacy SM
    // hub fabric where every MC parks on the hub.
    let stack_shard = |s: usize| {
        if rdma || ports {
            group_of[map.stack_owner(s as u32) as usize]
        } else {
            hub
        }
    };
    let mem = GlobalMemory::new_shared();
    let mut pcie_links = Vec::new();
    let mut mem_links = Vec::new();

    // Per-L1 tx toward local banks (shared across banks: one on-chip port).
    let mut l1_tx = vec![vec![LinkId(u32::MAX); c]; g];
    // Per-bank tx up (shared across its GPU's L1s).
    let mut l2_up_tx = vec![vec![LinkId(u32::MAX); b]; g];
    // Per-GPU uplink/downlink to the memory network.
    let mut gpu_up = vec![LinkId(u32::MAX); g];
    let mut gpu_down = vec![LinkId(u32::MAX); g];
    // Per-GPU PCIe up/down (RDMA only).
    let mut pcie_up = vec![LinkId(u32::MAX); g];
    let mut pcie_down = vec![LinkId(u32::MAX); g];
    // Per-stack links to/from the memory network switch.
    let mut mc_rx = vec![LinkId(u32::MAX); stacks];
    let mut mc_tx = vec![LinkId(u32::MAX); stacks];

    // Every link is registered to the shard of its *senders* (link state
    // mutates on each send): uplinks with the GPU shard, downlinks with
    // the switch that drives them.
    for gi in 0..g {
        let gs = group_of[gi];
        for ci in 0..c {
            l1_tx[gi][ci] =
                engine.add_link_to(gs, Link::wire(format!("g{gi}.l1_{ci}.tx"), cfg.onchip_lat));
        }
        for bi in 0..b {
            l2_up_tx[gi][bi] =
                engine.add_link_to(gs, Link::wire(format!("g{gi}.l2_{bi}.up"), cfg.onchip_lat));
        }
        gpu_up[gi] = engine.add_link_to(
            gs,
            Link::new(format!("g{gi}.mmnet.up"), cfg.swc_lat, cfg.gpu_uplink_bw),
        );
        // Driven by the switch on its far end: the hub switch complex
        // under the SM hub fabric, the GPU-local port/memory switch
        // otherwise.
        gpu_down[gi] = engine.add_link_to(
            if rdma || ports { gs } else { hub },
            Link::new(format!("g{gi}.mmnet.down"), cfg.swc_lat, cfg.gpu_uplink_bw),
        );
        mem_links.push(gpu_up[gi]);
        mem_links.push(gpu_down[gi]);
        if rdma {
            if ports {
                // Up: L1/L2 into the GPU's own PCIe port (same shard —
                // the PCIe serialization cost still applies); the
                // cross-GPU hop is the inter-port link below. Down:
                // port -> local destination delivery wire.
                pcie_up[gi] = engine.add_link_to(
                    gs,
                    Link::new(format!("g{gi}.pcie.up"), cfg.pcie_lat, cfg.pcie_bw),
                );
                pcie_down[gi] = engine
                    .add_link_to(gs, Link::wire(format!("g{gi}.pcie.down"), cfg.onchip_lat));
                pcie_links.push(pcie_up[gi]);
            } else {
                pcie_up[gi] = engine.add_link_to(
                    gs,
                    Link::new(format!("g{gi}.pcie.up"), cfg.pcie_lat, cfg.pcie_bw),
                );
                pcie_down[gi] = engine.add_link_to(
                    hub,
                    Link::new(format!("g{gi}.pcie.down"), cfg.pcie_lat, cfg.pcie_bw),
                );
                pcie_links.push(pcie_up[gi]);
                pcie_links.push(pcie_down[gi]);
            }
        }
    }
    // Inter-port fabric links (ports fabric): one explicit link per
    // ordered GPU pair. Cross-shard pairs are *declared* with
    // `add_link_between`, feeding the engine's lookahead matrix;
    // same-group pairs (shard_groups rebalancing) are ordinary local
    // links.
    let mut xbar = vec![vec![LinkId(u32::MAX); g]; g];
    if ports {
        for i in 0..g {
            for j in 0..g {
                if i == j {
                    continue;
                }
                let (si, sj) = (group_of[i], group_of[j]);
                let l = if rdma {
                    Link::new(format!("g{i}.pcie.to{j}"), cfg.pcie_lat, cfg.pcie_bw)
                } else {
                    Link::new(format!("g{i}.fab.to{j}"), cfg.swc_lat, cfg.gpu_uplink_bw)
                };
                xbar[i][j] = if si == sj {
                    engine.add_link_to(si, l)
                } else {
                    engine.add_link_between(si, sj, l)
                };
                if rdma {
                    pcie_links.push(xbar[i][j]);
                } else {
                    mem_links.push(xbar[i][j]);
                }
            }
        }
    }
    for s in 0..stacks {
        // rx is driven by the switch in front of the stack, tx by the MC;
        // both live in the stack's shard.
        let rx = Link::new(format!("mm{s}.rx"), cfg.swc_lat, cfg.hbm_bw);
        let tx = Link::new(format!("mm{s}.tx"), cfg.swc_lat, cfg.hbm_bw);
        mc_rx[s] = engine.add_link_to(stack_shard(s), rx);
        mc_tx[s] = engine.add_link_to(stack_shard(s), tx);
        mem_links.push(mc_rx[s]);
        mem_links.push(mc_tx[s]);
    }

    // ---- Components (order must match the id layout above).
    let flat_cus: Vec<CompId> = cu_ids.iter().flatten().copied().collect();
    let flat_l1s: Vec<CompId> = l1_ids.iter().flatten().copied().collect();
    let flat_l2s: Vec<CompId> = l2_ids.iter().flatten().copied().collect();
    let mut caches = flat_l1s.clone();
    caches.extend(&flat_l2s);

    // Root component: the barrier driver for ordinary workloads, the
    // inter-kernel scheduler for multi-tenant mixes.
    let root: Box<dyn crate::sim::Component> = match mix {
        Some(plan) => {
            Box::new(KernelScheduler::new("scheduler", flat_cus.clone(), plan, initial_delay))
        }
        None => Box::new(Driver::new(
            "driver",
            flat_cus.clone(),
            caches,
            wl.phases.len() as u32,
            initial_delay,
        )),
    };
    let id = engine.add_to(hub, root);
    assert_eq!(id, driver);

    for gi in 0..g {
        // CUs (taking each CU's program out of the workload).
        for ci in 0..c {
            let program: Vec<Vec<Vec<crate::gpu::CuOp>>> = wl
                .phases
                .iter_mut()
                .map(|ph| std::mem::take(&mut ph.work[gi][ci]))
                .collect();
            let mut cu =
                Cu::new(format!("g{gi}.cu{ci}"), l1_ids[gi][ci], driver, program, cfg.alu_lat);
            if let Some(plan) = mix {
                cu.set_phase_tenants(plan.phase_tenants.clone());
            }
            let id = engine.add_to(group_of[gi], Box::new(cu));
            assert_eq!(id, cu_ids[gi][ci]);
        }
        // L1s.
        for ci in 0..c {
            let routes = L1Routes {
                map: map.clone(),
                gpu: gi as u32,
                local_links: vec![l1_tx[gi][ci]; b],
                local_banks: l2_ids[gi].clone(),
                // NC-RDMA: L1 reaches remote GPUs' L2 through PCIe (Fig. 1).
                // HMG: L1 stays local; the L2 handles remote traffic.
                remote_hop: (rdma && cfg.coherence == Coherence::None)
                    .then(|| (pcie_up[gi], if ports { pport_ids[gi] } else { psw })),
                all_banks: all_banks.clone(),
            };
            let params = CacheParams::new(cfg.l1_bytes, cfg.l1_ways);
            let name = format!("g{gi}.l1_{ci}");
            let id = match cfg.coherence.ts_policy() {
                Some(policy) => {
                    let carry_warpts =
                        matches!(cfg.coherence, Coherence::Halcone { carry_warpts: true, .. });
                    let mut l1 =
                        HalconeL1::new(name, routes, params, cfg.mshr_l1, cfg.l1_lat, carry_warpts)
                            .with_policy(policy);
                    l1.set_ts_bits(ts_bits);
                    engine.add_to(group_of[gi], Box::new(l1))
                }
                None => engine.add_to(
                    group_of[gi],
                    Box::new(PlainL1::new(name, routes, params, cfg.mshr_l1, cfg.l1_lat)),
                ),
            };
            assert_eq!(id, l1_ids[gi][ci]);
        }
        // L2 banks.
        for bi in 0..b {
            let mut up_routes = HashMap::new();
            for ci in 0..c {
                up_routes.insert(l1_ids[gi][ci], l2_up_tx[gi][bi]);
            }
            let mm_hop = if rdma {
                (gpu_up[gi], lsw_ids[gi])
            } else if ports {
                (gpu_up[gi], port_ids[gi])
            } else {
                (gpu_up[gi], swc)
            };
            let pcie_hop = || (pcie_up[gi], if ports { pport_ids[gi] } else { psw });
            let routes = L2Routes {
                map: map.clone(),
                gpu: gi as u32,
                mm_hop,
                mcs: mc_ids.clone(),
                up_routes,
                up_default: rdma.then(pcie_hop),
                peer_hop: rdma.then(pcie_hop),
                all_banks: all_banks.clone(),
            };
            let params = CacheParams::new(cfg.l2_bank_bytes, cfg.l2_ways);
            let name = format!("g{gi}.l2_{bi}");
            let id = match cfg.coherence {
                Coherence::Halcone { carry_warpts, .. } => {
                    let mut l2 =
                        HalconeL2::new(name, routes, params, cfg.mshr_l2, cfg.l2_lat, carry_warpts);
                    l2.set_ts_bits(ts_bits);
                    engine.add_to(group_of[gi], Box::new(l2))
                }
                Coherence::Tardis { .. } | Coherence::Hlc { .. } => {
                    let policy = cfg.coherence.ts_policy().expect("timestamp coherence variant");
                    let mut l2 =
                        HalconeL2::new(name, routes, params, cfg.mshr_l2, cfg.l2_lat, false)
                            .with_policy(policy);
                    l2.set_ts_bits(ts_bits);
                    engine.add_to(group_of[gi], Box::new(l2))
                }
                Coherence::None => engine.add_to(
                    group_of[gi],
                    Box::new(PlainL2::new(
                        name,
                        routes,
                        cfg.l2_policy,
                        params,
                        cfg.mshr_l2,
                        cfg.l2_lat,
                    )),
                ),
                Coherence::Hmg => engine.add_to(
                    group_of[gi],
                    Box::new(HmgL2::new(
                        name,
                        routes,
                        gi as u32,
                        bi as u32,
                        params,
                        cfg.mshr_l2,
                        cfg.l2_lat,
                    )),
                ),
            };
            assert_eq!(id, l2_ids[gi][bi]);
        }
    }

    // Switches.
    if rdma {
        for gi in 0..g {
            let mut lsw = Switch::new(format!("g{gi}.memsw"));
            // Local stacks live at global indices [gi*spg, (gi+1)*spg).
            let spg = cfg.stacks_per_gpu as usize;
            for s in gi * spg..(gi + 1) * spg {
                lsw.add_route(mc_ids[s], (mc_rx[s], mc_ids[s]));
            }
            for bi in 0..b {
                lsw.add_route(l2_ids[gi][bi], (gpu_down[gi], l2_ids[gi][bi]));
            }
            let id = engine.add_to(group_of[gi], Box::new(lsw));
            assert_eq!(id, lsw_ids[gi]);
        }
        if ports {
            // Per-GPU PCIe ports: local caches over the delivery wire,
            // every remote cache via the inter-port link to its GPU's
            // port.
            for gi in 0..g {
                let mut p = Switch::new(format!("g{gi}.pcie_port"));
                for gj in 0..g {
                    for bi in 0..b {
                        let hop = if gj == gi {
                            (pcie_down[gi], l2_ids[gj][bi])
                        } else {
                            (xbar[gi][gj], pport_ids[gj])
                        };
                        p.add_route(l2_ids[gj][bi], hop);
                    }
                    for ci in 0..c {
                        let hop = if gj == gi {
                            (pcie_down[gi], l1_ids[gj][ci])
                        } else {
                            (xbar[gi][gj], pport_ids[gj])
                        };
                        p.add_route(l1_ids[gj][ci], hop);
                    }
                }
                let id = engine.add_to(group_of[gi], Box::new(p));
                assert_eq!(id, pport_ids[gi]);
            }
        } else {
            let mut p = Switch::new("pcie_sw");
            for gi in 0..g {
                for bi in 0..b {
                    p.add_route(l2_ids[gi][bi], (pcie_down[gi], l2_ids[gi][bi]));
                }
                for ci in 0..c {
                    p.add_route(l1_ids[gi][ci], (pcie_down[gi], l1_ids[gi][ci]));
                }
            }
            let id = engine.add_to(hub, Box::new(p));
            assert_eq!(id, psw);
        }
    } else if ports {
        // Per-GPU fabric ports: locally owned stacks and resident L2
        // banks directly, everything else via the inter-port link toward
        // its owner GPU's port.
        for gi in 0..g {
            let mut p = Switch::new(format!("g{gi}.fab_port"));
            for (si, &mc) in mc_ids.iter().enumerate() {
                let owner = map.stack_owner(si as u32) as usize;
                let hop =
                    if owner == gi { (mc_rx[si], mc) } else { (xbar[gi][owner], port_ids[owner]) };
                p.add_route(mc, hop);
            }
            for gj in 0..g {
                for bi in 0..b {
                    let hop = if gj == gi {
                        (gpu_down[gi], l2_ids[gj][bi])
                    } else {
                        (xbar[gi][gj], port_ids[gj])
                    };
                    p.add_route(l2_ids[gj][bi], hop);
                }
            }
            let id = engine.add_to(group_of[gi], Box::new(p));
            assert_eq!(id, port_ids[gi]);
        }
    } else {
        let mut s = Switch::new("switch_complex");
        for (si, &mc) in mc_ids.iter().enumerate() {
            s.add_route(mc, (mc_rx[si], mc));
        }
        for gi in 0..g {
            for bi in 0..b {
                s.add_route(l2_ids[gi][bi], (gpu_down[gi], l2_ids[gi][bi]));
            }
        }
        let id = engine.add_to(hub, Box::new(s));
        assert_eq!(id, swc);
    }

    // Memory controllers (+ TSUs for the timestamp protocols).
    for (si, &mc) in mc_ids.iter().enumerate() {
        let up = if rdma {
            let owner = si / cfg.stacks_per_gpu as usize;
            (mc_tx[si], lsw_ids[owner])
        } else if ports {
            (mc_tx[si], port_ids[map.stack_owner(si as u32) as usize])
        } else {
            (mc_tx[si], swc)
        };
        let tsu = cfg.coherence.ts_policy().map(|policy| {
            let leases = cfg.coherence.leases().expect("timestamp protocols carry leases");
            let mut t = Tsu::new(cfg.tsu_entries, leases).with_policy(policy);
            t.set_ts_bits(ts_bits);
            t
        });
        let id = engine.add_to(
            stack_shard(si),
            Box::new(MemCtrl::new(format!("mm{si}"), mem.clone(), up, cfg.mc_lat, tsu)),
        );
        assert_eq!(id, mc);
    }

    System {
        engine,
        mem,
        driver,
        cus: flat_cus,
        l1s: flat_l1s,
        l2s: flat_l2s,
        mcs: mc_ids,
        pcie_links,
        mem_links,
        coherence: cfg.coherence,
        faults: cfg.faults,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{self, WorkloadParams};

    fn small_cfg(preset: &str) -> SystemConfig {
        let mut cfg = SystemConfig::preset(preset);
        cfg.n_gpus = 2;
        cfg.cus_per_gpu = 2;
        cfg.wavefronts_per_cu = 2;
        cfg.l2_banks = 2;
        cfg.stacks_per_gpu = 2;
        cfg.gpu_mem_bytes = 64 << 20;
        cfg.scale = 0.05;
        cfg
    }

    fn wl(cfg: &SystemConfig, name: &str) -> Workload {
        let p: WorkloadParams = cfg.workload_params();
        workloads::build(name, &p)
    }

    #[test]
    fn builds_all_presets() {
        for preset in SystemConfig::PRESETS {
            let cfg = small_cfg(preset);
            let w = wl(&cfg, "rl");
            let sys = build(&cfg, w);
            assert_eq!(sys.cus.len(), 4);
            assert_eq!(sys.l1s.len(), 4);
            assert_eq!(sys.l2s.len(), 4);
            assert_eq!(sys.mcs.len(), 4);
        }
    }

    #[test]
    fn partition_is_per_gpu_plus_hub() {
        for preset in SystemConfig::PRESETS {
            let cfg = small_cfg(preset);
            let sys = build(&cfg, wl(&cfg, "rl"));
            assert_eq!(sys.engine.n_shards(), cfg.n_gpus + 1, "{preset}");
        }
    }

    #[test]
    fn ports_fabric_places_mcs_with_their_owner_gpu() {
        for preset in SystemConfig::PRESETS {
            let cfg = small_cfg(preset);
            let map = cfg.addr_map();
            let sys = build(&cfg, wl(&cfg, "rl"));
            for (si, &mc) in sys.mcs.iter().enumerate() {
                assert_eq!(
                    sys.engine.shard_of(mc),
                    map.stack_owner(si as u32),
                    "{preset} mm{si}"
                );
            }
        }
    }

    #[test]
    fn hub_fabric_keeps_sm_mcs_on_the_hub() {
        let mut cfg = small_cfg("SM-WT-C-HALCONE");
        cfg.fabric = Fabric::Hub;
        let sys = build(&cfg, wl(&cfg, "rl"));
        for &mc in &sys.mcs {
            assert_eq!(sys.engine.shard_of(mc), cfg.n_gpus);
        }
        // RDMA stacks sit with their owner GPU under both fabrics.
        let mut cfg = small_cfg("RDMA-WB-NC");
        cfg.fabric = Fabric::Hub;
        let sys = build(&cfg, wl(&cfg, "rl"));
        assert_eq!(sys.engine.shard_of(sys.mcs[3]), 1);
    }

    #[test]
    fn shard_groups_fold_gpus_into_one_shard() {
        let mut cfg = small_cfg("SM-WT-C-HALCONE");
        cfg.shard_groups = vec![0, 0];
        let sys = build(&cfg, wl(&cfg, "rl"));
        assert_eq!(sys.engine.n_shards(), 2); // one fused group + hub
        for &mc in &sys.mcs {
            assert_eq!(sys.engine.shard_of(mc), 0);
        }
    }

    #[test]
    #[should_panic(expected = "one group per GPU")]
    fn shard_groups_length_mismatch_is_rejected() {
        let mut cfg = small_cfg("SM-WT-NC");
        cfg.shard_groups = vec![0];
        build(&cfg, wl(&cfg, "rl"));
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn shard_groups_with_a_gap_are_rejected() {
        let mut cfg = small_cfg("SM-WT-NC");
        cfg.shard_groups = vec![0, 2];
        build(&cfg, wl(&cfg, "rl"));
    }

    #[test]
    fn plan_shard_groups_is_lpt_balanced_and_deterministic() {
        // Descending-events placement onto the least-loaded group:
        // 10 -> g0, 9 -> g1, 2 -> g1 (9 < 10), 1 -> g0.
        assert_eq!(plan_shard_groups(&[10, 1, 9, 2], 2), vec![0, 0, 1, 1]);
        // Uniform loads with one group per GPU degrade to identity.
        assert_eq!(plan_shard_groups(&[5, 5, 5, 5], 4), vec![0, 1, 2, 3]);
        // More groups than GPUs clamps.
        assert_eq!(plan_shard_groups(&[3], 5), vec![0]);
        assert_eq!(shard_groups_value(&[0, 0, 1, 1]), "0,0,1,1");
    }

    #[test]
    fn rdma_has_pcie_links_sm_does_not() {
        let cfg = small_cfg("RDMA-WB-NC");
        let sys = build(&cfg, wl(&cfg, "rl"));
        assert!(!sys.pcie_links.is_empty());
        let cfg = small_cfg("SM-WT-NC");
        let sys = build(&cfg, wl(&cfg, "rl"));
        assert!(sys.pcie_links.is_empty());
    }

    #[test]
    fn copy_delay_only_for_rdma() {
        let cfg_r = small_cfg("RDMA-WB-NC");
        let w = wl(&cfg_r, "rl");
        assert!(copy_delay(&cfg_r, &w) > 0);
        let cfg_s = small_cfg("SM-WT-NC");
        let w = wl(&cfg_s, "rl");
        assert_eq!(copy_delay(&cfg_s, &w), 0);
    }

    #[test]
    #[should_panic(expected = "MGPU-SM")]
    fn halcone_on_rdma_is_rejected() {
        let mut cfg = small_cfg("SM-WT-C-HALCONE");
        cfg.topology = Topology::Rdma;
        let w = wl(&cfg, "rl");
        build(&cfg, w);
    }
}
