//! Experiment coordination (DESIGN.md S13/S19): the kernel-launch driver,
//! the topology builder for the paper's five MGPU configurations, the
//! runner and the golden-model verifier.

pub mod driver;
pub mod runner;
pub mod scheduler;
pub mod topology;
pub mod verify;

pub use driver::Driver;
pub use scheduler::KernelScheduler;
pub use runner::{run_workload, RunResult, SnapMode};
pub use topology::{build, System};
pub use verify::CheckOutcome;
