//! The inter-kernel scheduler: the mix-run replacement for the
//! phase-sequential [`super::Driver`].
//!
//! Where the driver runs one application's phases back to back with a
//! fence between each, the scheduler serves a *queue* of tenant kernels
//! ([`JobSpec`]s from the mix composer) onto CU **slots** as they free
//! up: admit a job -> `StartPhase { template * n_slots + slot }` to the
//! slot's CUs -> count their `PhaseDone`s -> record turnaround, free the
//! slot, admit the next. Which job is next is the pluggable
//! [`SchedPolicy`] (FIFO or tenant round-robin).
//!
//! Mix runs are fence-free by construction: tenants own disjoint
//! address windows (see `tenancy/compose.rs`), so there is no
//! cross-kernel visibility to order, and a kernel-boundary fence while
//! other slots are mid-kernel would be meaningless anyway. Admission is
//! eager and happens inside `PhaseDone`/`Tick` handling; since event
//! order over the fixed logical shard partition is identical at every
//! `--shards`/jobs level, so is every scheduling decision.

use crate::mem::FxHashMap;
use crate::sim::{CompId, Component, Ctx, Cycle, Msg};
use crate::tenancy::{JobSpec, MixPlan, Policy};

/// Admission policy: pick the next job among the eligible set.
/// `eligible` is non-empty, ascending, and indexes the composer-sorted
/// job list (arrival, then tenant, then spec order).
pub trait SchedPolicy: Send {
    fn name(&self) -> &'static str;
    fn pick(&mut self, eligible: &[usize], jobs: &[JobSpec]) -> usize;

    /// Mutable policy state as a single word (snapshots). Stateless
    /// policies keep the defaults.
    fn state(&self) -> u64 {
        0
    }

    /// Restore the word captured by [`SchedPolicy::state`].
    fn set_state(&mut self, _state: u64) {}
}

/// Earliest arrival first — the composer's sort order makes this simply
/// the lowest eligible index.
struct Fifo;

impl SchedPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&mut self, eligible: &[usize], _jobs: &[JobSpec]) -> usize {
        eligible[0]
    }
}

/// Rotate across tenants: each admission starts scanning from the
/// tenant after the last one served, so a backlogged tenant cannot
/// starve the others (the noisy-neighbor countermeasure).
struct RoundRobin {
    next: u32,
    n: u32,
}

impl SchedPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn pick(&mut self, eligible: &[usize], jobs: &[JobSpec]) -> usize {
        for off in 0..self.n {
            let tenant = (self.next + off) % self.n;
            if let Some(&j) = eligible.iter().find(|&&j| jobs[j].tenant == tenant) {
                self.next = (tenant + 1) % self.n;
                return j;
            }
        }
        eligible[0] // unreachable while tenants cover all jobs
    }

    fn state(&self) -> u64 {
        self.next as u64
    }

    fn set_state(&mut self, state: u64) {
        self.next = state as u32;
    }
}

fn make_policy(p: Policy, n_tenants: u32) -> Box<dyn SchedPolicy> {
    match p {
        Policy::Fifo => Box::new(Fifo),
        Policy::RoundRobin => Box::new(RoundRobin { next: 0, n: n_tenants.max(1) }),
    }
}

/// Per-job outcome, indexed like the plan's job list. All cycles are
/// absolute (the RDMA host-copy delay shifts arrivals like it shifts
/// the driver's first dispatch).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JobRecord {
    pub tenant: u32,
    pub arrival: Cycle,
    pub admitted: Cycle,
    pub finished: Cycle,
}

impl JobRecord {
    /// Queueing + service time — the per-tenant latency metric.
    pub fn turnaround(&self) -> Cycle {
        self.finished - self.arrival
    }
}

/// The scheduler component. Sits at the driver's `CompId` slot in a mix
/// topology and speaks the same CU protocol (`StartPhase`/`PhaseDone`).
pub struct KernelScheduler {
    name: String,
    /// All CUs, flat gpu-major; slot `s` owns `[s*W, (s+1)*W)`.
    cus: Vec<CompId>,
    slot_width: usize,
    n_slots: usize,
    jobs: Vec<JobSpec>,
    policy: Box<dyn SchedPolicy>,
    initial_delay: Cycle,
    cu_slot: FxHashMap<CompId, usize>,
    started: Vec<bool>,
    finished: Vec<bool>,
    /// Free slot indices, ascending (lowest slot admits first).
    free_slots: Vec<usize>,
    /// Job currently running on each slot.
    running: Vec<Option<usize>>,
    /// Outstanding `PhaseDone`s per slot.
    pending: Vec<usize>,
    n_done: usize,
    ticked: bool,
    pub records: Vec<JobRecord>,
    pub done_at: Option<Cycle>,
    pub tenant_names: Vec<String>,
    pub n_tenants: u32,
}

impl KernelScheduler {
    pub fn new(
        name: impl Into<String>,
        cus: Vec<CompId>,
        plan: &MixPlan,
        initial_delay: Cycle,
    ) -> Self {
        let slot_width = plan.slot_width as usize;
        let n_slots = plan.n_slots as usize;
        assert!(
            n_slots * slot_width <= cus.len(),
            "plan wants {n_slots} x {slot_width} CUs but the machine has {}",
            cus.len()
        );
        let cu_slot = cus
            .iter()
            .take(n_slots * slot_width)
            .enumerate()
            .map(|(flat, &id)| (id, flat / slot_width))
            .collect();
        let records = plan
            .jobs
            .iter()
            .map(|j| JobRecord {
                tenant: j.tenant,
                arrival: initial_delay + j.arrival,
                ..JobRecord::default()
            })
            .collect();
        KernelScheduler {
            name: name.into(),
            cus,
            slot_width,
            n_slots,
            jobs: plan.jobs.clone(),
            policy: make_policy(plan.policy, plan.n_tenants),
            initial_delay,
            cu_slot,
            started: vec![false; plan.jobs.len()],
            finished: vec![false; plan.jobs.len()],
            free_slots: (0..n_slots).collect(),
            running: vec![None; n_slots],
            pending: vec![0; n_slots],
            n_done: 0,
            ticked: false,
            records,
            done_at: None,
            tenant_names: plan.tenant_names.clone(),
            n_tenants: plan.n_tenants,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    fn abs_arrival(&self, j: usize) -> Cycle {
        self.initial_delay + self.jobs[j].arrival
    }

    /// Admit eligible jobs onto free slots until either runs out.
    fn try_admit(&mut self, now: Cycle, ctx: &mut Ctx) {
        while !self.free_slots.is_empty() {
            let eligible: Vec<usize> = (0..self.jobs.len())
                .filter(|&j| {
                    !self.started[j]
                        && self.abs_arrival(j) <= now
                        && self.jobs[j].pred.is_none_or(|p| self.finished[p])
                })
                .collect();
            if eligible.is_empty() {
                return;
            }
            let job = self.policy.pick(&eligible, &self.jobs);
            let slot = self.free_slots.remove(0);
            self.started[job] = true;
            self.records[job].admitted = now;
            self.running[slot] = Some(job);
            self.pending[slot] = self.slot_width;
            let phase = self.jobs[job].template * self.n_slots as u32 + slot as u32;
            for &cu in &self.cus[slot * self.slot_width..(slot + 1) * self.slot_width] {
                ctx.schedule(0, cu, Msg::StartPhase { phase });
            }
        }
    }
}

impl Component for KernelScheduler {
    crate::impl_component_any!();

    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match msg {
            // The runner's kick-off tick, plus our own arrival wake-ups.
            Msg::Tick => {
                if !self.ticked {
                    self.ticked = true;
                    if self.jobs.is_empty() {
                        self.done_at = Some(now);
                        return;
                    }
                    // One wake-up per distinct future arrival cycle.
                    let mut arrivals: Vec<Cycle> = (0..self.jobs.len())
                        .map(|j| self.abs_arrival(j))
                        .filter(|&a| a > now)
                        .collect();
                    arrivals.sort_unstable();
                    arrivals.dedup();
                    let me = ctx.self_id;
                    for a in arrivals {
                        ctx.schedule(a - now, me, Msg::Tick);
                    }
                }
                self.try_admit(now, ctx);
            }
            Msg::PhaseDone { cu } => {
                let slot = *self
                    .cu_slot
                    .get(&cu)
                    .unwrap_or_else(|| panic!("{}: PhaseDone from unknown CU {cu:?}", self.name));
                self.pending[slot] -= 1;
                if self.pending[slot] > 0 {
                    return;
                }
                let job = self.running[slot]
                    .take()
                    .unwrap_or_else(|| panic!("{}: idle slot {slot} finished", self.name));
                self.finished[job] = true;
                self.records[job].finished = now;
                self.n_done += 1;
                // Sorted re-insert keeps lowest-slot-first admission.
                let at = self.free_slots.partition_point(|&s| s < slot);
                self.free_slots.insert(at, slot);
                if self.n_done == self.jobs.len() {
                    self.done_at = Some(now);
                } else {
                    self.try_admit(now, ctx);
                }
            }
            m => panic!("{}: unexpected message {m:?}", self.name),
        }
    }

    // The job list, slot geometry and CU map are rebuilt from the mix
    // plan; only scheduling progress is serialized.
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format::{put, put_bool};
        put(out, self.policy.state());
        put(out, self.started.len() as u64);
        for (&s, &f) in self.started.iter().zip(&self.finished) {
            put_bool(out, s);
            put_bool(out, f);
        }
        put(out, self.free_slots.len() as u64);
        for &s in &self.free_slots {
            put(out, s as u64);
        }
        put(out, self.running.len() as u64);
        for r in &self.running {
            put_bool(out, r.is_some());
            if let Some(j) = r {
                put(out, *j as u64);
            }
        }
        for &p in &self.pending {
            put(out, p as u64);
        }
        put(out, self.n_done as u64);
        put_bool(out, self.ticked);
        put(out, self.records.len() as u64);
        for r in &self.records {
            put(out, r.tenant as u64);
            put(out, r.arrival);
            put(out, r.admitted);
            put(out, r.finished);
        }
        put_bool(out, self.done_at.is_some());
        if let Some(t) = self.done_at {
            put(out, t);
        }
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        self.policy.set_state(cur.u64("scheduler policy state")?);
        let n_jobs = cur.u64("scheduler job count")? as usize;
        if n_jobs != self.jobs.len() {
            return Err(format!(
                "snapshot schedules {n_jobs} jobs, this mix plan has {} — the workloads differ",
                self.jobs.len()
            ));
        }
        for j in 0..n_jobs {
            self.started[j] = cur.bool(&format!("job {j} started flag"))?;
            self.finished[j] = cur.bool(&format!("job {j} finished flag"))?;
        }
        let n_free = cur.u64("scheduler free-slot count")? as usize;
        if n_free > self.n_slots {
            return Err(format!(
                "snapshot frees {n_free} slots, this mix plan has {}",
                self.n_slots
            ));
        }
        self.free_slots.clear();
        for i in 0..n_free {
            self.free_slots.push(cur.u64(&format!("free slot {i}"))? as usize);
        }
        let n_slots = cur.u64("scheduler slot count")? as usize;
        if n_slots != self.n_slots {
            return Err(format!(
                "snapshot has {n_slots} CU slots, this mix plan has {} — the geometries differ",
                self.n_slots
            ));
        }
        for s in 0..n_slots {
            self.running[s] = if cur.bool(&format!("slot {s} running flag"))? {
                Some(cur.u64(&format!("slot {s} job"))? as usize)
            } else {
                None
            };
        }
        for s in 0..n_slots {
            self.pending[s] = cur.u64(&format!("slot {s} pending count"))? as usize;
        }
        self.n_done = cur.u64("scheduler done count")? as usize;
        self.ticked = cur.bool("scheduler ticked flag")?;
        let n_rec = cur.u64("scheduler record count")? as usize;
        if n_rec != self.records.len() {
            return Err(format!(
                "snapshot records {n_rec} jobs, this mix plan has {}",
                self.records.len()
            ));
        }
        for r in self.records.iter_mut() {
            r.tenant = cur.u64("record tenant")? as u32;
            r.arrival = cur.u64("record arrival")?;
            r.admitted = cur.u64("record admitted")?;
            r.finished = cur.u64("record finished")?;
        }
        self.done_at = if cur.bool("scheduler done flag")? {
            Some(cur.u64("scheduler done cycle")?)
        } else {
            None
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Engine;
    use crate::tenancy::Policy;

    /// CU stub: acks each StartPhase after a fixed delay, recording the
    /// phase indices it saw.
    struct StubCu {
        name: String,
        sched: CompId,
        delay: Cycle,
        pub phases_seen: Vec<u32>,
    }
    impl Component for StubCu {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
            if let Msg::StartPhase { phase } = msg {
                self.phases_seen.push(phase);
                let s = self.sched;
                ctx.schedule(self.delay, s, Msg::PhaseDone { cu: ctx.self_id });
            }
        }
    }

    fn plan(jobs: Vec<JobSpec>, n_tenants: u32, policy: Policy, n_slots: u32) -> MixPlan {
        MixPlan {
            n_tenants,
            tenant_names: (0..n_tenants).map(|t| format!("t{t}")).collect(),
            slot_width: 1,
            n_slots,
            n_templates: 1,
            phase_tenants: vec![0; n_slots as usize],
            jobs,
            policy,
        }
    }

    fn run(plan: &MixPlan, delays: &[Cycle], initial_delay: Cycle) -> (Engine, CompId) {
        let mut e = Engine::new();
        let sched = CompId(0);
        let cus: Vec<CompId> = (1..=delays.len() as u32).map(CompId).collect();
        e.add(Box::new(KernelScheduler::new("sched", cus.clone(), plan, initial_delay)));
        for (i, &cu) in cus.iter().enumerate() {
            e.add(Box::new(StubCu {
                name: format!("cu{i}"),
                sched,
                delay: delays[i],
                phases_seen: vec![],
            }));
        }
        e.post(0, sched, Msg::Tick);
        e.run_to_completion();
        (e, sched)
    }

    fn job(tenant: u32, arrival: Cycle, pred: Option<usize>) -> JobSpec {
        JobSpec { tenant, template: 0, arrival, pred }
    }

    #[test]
    fn fifo_queues_on_one_slot_and_records_turnaround() {
        let p = plan(vec![job(0, 0, None), job(1, 0, None)], 2, Policy::Fifo, 1);
        let (e, sched) = run(&p, &[10], 0);
        let s = e.downcast::<KernelScheduler>(sched);
        assert_eq!(s.policy_name(), "fifo");
        // Job 1 waited for job 0's slot: admitted at 10, finished at 20.
        assert_eq!(s.records[0], JobRecord { tenant: 0, arrival: 0, admitted: 0, finished: 10 });
        assert_eq!(s.records[1], JobRecord { tenant: 1, arrival: 0, admitted: 10, finished: 20 });
        assert_eq!(s.records[1].turnaround(), 20);
        assert_eq!(s.done_at, Some(20));
    }

    #[test]
    fn round_robin_alternates_backlogged_tenants() {
        // Tenant 0 floods the queue; tenant 1 has two jobs. FIFO order
        // would run all of tenant 0 first (same arrival, lower tenant).
        let jobs = vec![
            job(0, 0, None),
            job(0, 0, None),
            job(0, 0, None),
            job(1, 0, None),
            job(1, 0, None),
        ];
        let p = plan(jobs, 2, Policy::RoundRobin, 1);
        let (e, sched) = run(&p, &[5], 0);
        let s = e.downcast::<KernelScheduler>(sched);
        let mut order: Vec<(Cycle, u32)> =
            s.records.iter().map(|r| (r.admitted, r.tenant)).collect();
        order.sort_unstable();
        let tenants: Vec<u32> = order.iter().map(|&(_, t)| t).collect();
        assert_eq!(tenants, vec![0, 1, 0, 1, 0], "alternation, then drain");
    }

    #[test]
    fn chains_wait_for_predecessors_even_with_free_slots() {
        // Two slots, but job 1 depends on job 0: it must not overlap.
        let p = plan(vec![job(0, 0, None), job(0, 0, Some(0))], 1, Policy::Fifo, 2);
        let (e, sched) = run(&p, &[7, 7], 0);
        let s = e.downcast::<KernelScheduler>(sched);
        assert_eq!(s.records[0].finished, 7);
        assert_eq!(s.records[1].admitted, 7);
        assert_eq!(s.done_at, Some(14));
        // Slot 0 freed before the successor was admitted -> reused.
        assert_eq!(e.downcast::<StubCu>(CompId(1)).phases_seen.len(), 2);
        assert_eq!(e.downcast::<StubCu>(CompId(2)).phases_seen.len(), 0);
    }

    #[test]
    fn arrivals_wake_the_scheduler_and_copy_delay_shifts_them() {
        let p = plan(vec![job(0, 100, None)], 1, Policy::Fifo, 1);
        let (e, sched) = run(&p, &[3], 50);
        let s = e.downcast::<KernelScheduler>(sched);
        // Absolute arrival = copy delay + spec arrival.
        assert_eq!(s.records[0].arrival, 150);
        assert_eq!(s.records[0].admitted, 150);
        assert_eq!(s.done_at, Some(153));
    }

    #[test]
    fn parallel_slots_overlap_independent_jobs() {
        let jobs = vec![job(0, 0, None), job(1, 0, None)];
        let p = plan(jobs, 2, Policy::Fifo, 2);
        let (e, sched) = run(&p, &[9, 9], 0);
        let s = e.downcast::<KernelScheduler>(sched);
        assert_eq!(s.records[0].admitted, 0);
        assert_eq!(s.records[1].admitted, 0, "second slot admits concurrently");
        assert_eq!(s.done_at, Some(9));
        // Slot phase encoding: template * n_slots + slot.
        assert_eq!(e.downcast::<StubCu>(CompId(1)).phases_seen, vec![0]);
        assert_eq!(e.downcast::<StubCu>(CompId(2)).phases_seen, vec![1]);
    }

    #[test]
    fn zero_jobs_finish_on_the_kickoff_tick() {
        let p = plan(vec![], 1, Policy::Fifo, 1);
        let (e, sched) = run(&p, &[1], 0);
        assert_eq!(e.downcast::<KernelScheduler>(sched).done_at, Some(0));
    }
}
