//! Experiment runner: config + workload -> simulation -> metrics + checks.

use std::time::Instant;

use crate::coherence::halcone::{HalconeL1, HalconeL2};
use crate::coherence::hmg::HmgL2;
use crate::coherence::none::{PlainL1, PlainL2};
use crate::config::SystemConfig;
use crate::coordinator::driver::Driver;
use crate::coordinator::scheduler::KernelScheduler;
use crate::coordinator::topology::{self, System};
use crate::coordinator::verify::{self, CheckOutcome};
use crate::dram::MemCtrl;
use crate::gpu::Cu;
use crate::metrics::tenancy::{p99_sorted, TenancyReport, TenantMetrics, TenantTraffic};
use crate::metrics::{CacheCtrlStats, RunMetrics};
use crate::runtime::Runtime;
use crate::sim::{CompId, Cycle, Engine, Msg};
use crate::tenancy::{self, MixPlan};
use crate::trace::{Trace, TraceMeta};
use crate::workloads::{self, Workload};

/// How a run interacts with engine snapshots (docs/SNAPSHOT.md).
pub enum SnapMode {
    /// Ordinary run, no snapshot involvement.
    None,
    /// Run cold, pause at the first deterministic barrier at or after
    /// `at`, serialize the full engine state, then resume to completion.
    /// The pause is invisible: the run's results are byte-identical to a
    /// run that never paused ([`Engine::run_until_barrier`]).
    Save { at: Cycle },
    /// Restore a snapshot into a freshly built system and continue to
    /// completion. The bytes are validated (magic, version, checksums,
    /// config fingerprint) before any state is overlaid.
    Warm { bytes: std::sync::Arc<Vec<u8>> },
}

/// Everything one simulation produced.
pub struct RunResult {
    pub config: String,
    pub workload: String,
    pub metrics: RunMetrics,
    pub checks: Vec<CheckOutcome>,
}

impl RunResult {
    pub fn all_passed(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }

    /// One-line summary for CLI output.
    pub fn summary(&self) -> String {
        let verdicts: Vec<String> = self
            .checks
            .iter()
            .map(|c| {
                format!(
                    "{}:{}",
                    c.kind,
                    if c.passed { "ok" } else { "FAIL" }
                )
            })
            .collect();
        format!(
            "{:<18} {:<8} {:>12} cycles  {:>9} events  l1->l2 {:>9}  l2->mm {:>9}  [{}]",
            self.config,
            self.workload,
            self.metrics.cycles,
            self.metrics.events,
            self.metrics.l1_l2_transactions(),
            self.metrics.l2_mm_transactions(),
            verdicts.join(" ")
        )
    }
}

/// Completion time of the root component, whichever kind the topology
/// installed at `CompId(0)` (barrier [`Driver`] or mix
/// [`KernelScheduler`]).
fn root_done_at(engine: &Engine, id: CompId) -> Option<Cycle> {
    let any = engine.component(id).as_any();
    if let Some(d) = any.downcast_ref::<Driver>() {
        return d.done_at;
    }
    if let Some(s) = any.downcast_ref::<KernelScheduler>() {
        return s.done_at;
    }
    panic!("component {id:?} is neither a driver nor a kernel scheduler");
}

fn l1_stats_of(engine: &Engine, id: CompId) -> CacheCtrlStats {
    let any = engine.component(id).as_any();
    if let Some(h) = any.downcast_ref::<HalconeL1>() {
        return h.stats;
    }
    if let Some(p) = any.downcast_ref::<PlainL1>() {
        return p.stats;
    }
    panic!("component {id:?} is not an L1 controller");
}

fn l1_tenant_traffic(engine: &Engine, id: CompId) -> &TenantTraffic {
    let any = engine.component(id).as_any();
    if let Some(h) = any.downcast_ref::<HalconeL1>() {
        return &h.tstats;
    }
    if let Some(p) = any.downcast_ref::<PlainL1>() {
        return &p.tstats;
    }
    panic!("component {id:?} is not an L1 controller");
}

/// Assemble the per-tenant report for a finished mix run: kernel
/// turnarounds from the scheduler's records, issue counters from the CUs
/// and lookup outcomes from the L1s (the attribution tables sum to the
/// untagged totals by construction — see [`crate::metrics::tenancy`]).
fn collect_tenancy(sys: &System) -> TenancyReport {
    let engine = &sys.engine;
    let sched = engine.downcast::<KernelScheduler>(sys.driver);
    let n = sched.n_tenants as usize;
    let mut tenants: Vec<TenantMetrics> = (0..n)
        .map(|t| TenantMetrics {
            tenant: t as u32,
            name: sched.tenant_names.get(t).cloned().unwrap_or_default(),
            ..TenantMetrics::default()
        })
        .collect();
    let mut turnarounds: Vec<Vec<Cycle>> = vec![Vec::new(); n];
    for r in &sched.records {
        turnarounds[r.tenant as usize].push(r.turnaround());
    }
    for (t, ts) in turnarounds.iter_mut().enumerate() {
        ts.sort_unstable();
        tenants[t].jobs = ts.len() as u64;
        tenants[t].turnaround_sum = ts.iter().sum();
        tenants[t].turnaround_p99 = p99_sorted(ts);
    }
    for &id in &sys.cus {
        let cu = engine.downcast::<Cu>(id);
        for (t, s) in cu.tenant_stats.iter().enumerate() {
            if t < n {
                tenants[t].loads += s.loads;
                tenants[t].stores += s.stores;
                tenants[t].cu_bytes += s.bytes;
            }
        }
    }
    let mut l1 = TenantTraffic::default();
    for &id in &sys.l1s {
        l1.accumulate(l1_tenant_traffic(engine, id));
    }
    for (t, tm) in tenants.iter_mut().enumerate() {
        let s = l1.get(t as u32);
        tm.l1_hits = s.hits;
        tm.l1_misses = s.misses;
        tm.l1_coherency_misses = s.coherency_misses;
    }
    TenancyReport { scheduler: sched.policy_name().to_string(), tenants }
}

fn l2_stats_of(engine: &Engine, id: CompId) -> CacheCtrlStats {
    let any = engine.component(id).as_any();
    if let Some(h) = any.downcast_ref::<HalconeL2>() {
        return h.stats;
    }
    if let Some(p) = any.downcast_ref::<PlainL2>() {
        return p.stats;
    }
    if let Some(m) = any.downcast_ref::<HmgL2>() {
        return m.stats;
    }
    panic!("component {id:?} is not an L2 controller");
}

/// Sweep stats from a finished system into [`RunMetrics`].
pub fn collect_metrics(sys: &System, host_seconds: f64) -> RunMetrics {
    let engine = &sys.engine;
    let done_at = root_done_at(engine, sys.driver);
    let pool = engine.pool_counters();
    let mut m = RunMetrics {
        cycles: done_at.unwrap_or(engine.now()),
        // Summed across the engine's logical shards, so throughput stays
        // correct under parallel (`shards > 1`) runs.
        events: engine.events_processed(),
        host_seconds,
        pool_fresh_boxes: pool.fresh(),
        pool_reused_boxes: pool.reused(),
        ..Default::default()
    };
    let occ = engine.shard_occupancy();
    m.shard_events = occ.iter().map(|o| o.events).collect();
    m.shard_windows = occ.iter().map(|o| o.windows).collect();
    m.shard_idle_windows = occ.iter().map(|o| o.idle_windows).collect();
    m.finalize_host_perf();
    for &id in &sys.cus {
        let s = engine.downcast::<Cu>(id).stats;
        m.cu_loads += s.loads;
        m.cu_stores += s.stores;
    }
    for &id in &sys.l1s {
        m.l1.accumulate(&l1_stats_of(engine, id));
    }
    for &id in &sys.l2s {
        m.l2.accumulate(&l2_stats_of(engine, id));
    }
    for &id in &sys.mcs {
        let mc = engine.downcast::<MemCtrl>(id);
        m.mm_reads += mc.stats.reads;
        m.mm_writes += mc.stats.writes;
        if let Some(tsu) = &mc.tsu {
            m.tsu_lookups += tsu.lookups;
            m.tsu_evictions += tsu.evictions;
        }
    }
    for &l in &sys.pcie_links {
        m.pcie_bytes += engine.link(l).bytes_sent;
    }
    for &l in &sys.mem_links {
        m.mem_bytes += engine.link(l).bytes_sent;
    }
    if sys.faults.is_some() {
        // Presence of the section is a pure function of the config (the
        // schedule may legitimately count zero of everything), so the
        // canonical artifact shape never depends on outcomes.
        let mut f = crate::metrics::FaultReport::default();
        for link in engine.links() {
            f.link_outage_cycles += link.outage_cycles;
            f.link_degraded_msgs += link.degraded_msgs;
        }
        for &id in &sys.l1s {
            if let Some(h) = engine.component(id).as_any().downcast_ref::<HalconeL1>() {
                f.rollover_flushes += h.rollover_flushes;
            }
        }
        for &id in &sys.l2s {
            if let Some(h) = engine.component(id).as_any().downcast_ref::<HalconeL2>() {
                f.rollover_flushes += h.rollover_flushes;
            }
        }
        for &id in &sys.mcs {
            if let Some(tsu) = &engine.downcast::<MemCtrl>(id).tsu {
                f.tsu_rollovers += tsu.ts_rollovers;
            }
        }
        m.faults = Some(f);
    }
    m
}

/// Build, run and verify `workload_name` under `cfg`.
pub fn run_workload(
    cfg: &SystemConfig,
    workload_name: &str,
    runtime: Option<&mut Runtime>,
) -> RunResult {
    run_workload_traced(cfg, workload_name, runtime, false).0
}

/// [`run_workload`] with the CU trace tap enabled when `capture` is set:
/// returns the assembled [`Trace`] alongside the result. The tap buffers
/// per CU and is assembled here in CompId order, so the trace — like the
/// simulation itself — is byte-identical at every `--shards` level.
pub fn run_workload_traced(
    cfg: &SystemConfig,
    workload_name: &str,
    runtime: Option<&mut Runtime>,
    capture: bool,
) -> (RunResult, Option<Trace>) {
    try_run_workload_traced(cfg, workload_name, runtime, capture)
        .unwrap_or_else(|e| panic!("workload '{workload_name}': {e}"))
}

/// [`run_workload_traced`] with error reporting instead of panics: a bad
/// `trace:`/`mix:` spec (or unknown name) is a clean `Err`. Multi-tenant
/// `mix:` names route through [`run_with_plan`], everything else through
/// the ordinary barrier-driver path.
pub fn try_run_workload_traced(
    cfg: &SystemConfig,
    workload_name: &str,
    runtime: Option<&mut Runtime>,
    capture: bool,
) -> Result<(RunResult, Option<Trace>), String> {
    let (res, trace, _) =
        try_run_workload_snap(cfg, workload_name, runtime, capture, SnapMode::None)?;
    Ok((res, trace))
}

/// [`try_run_workload_traced`] with snapshot involvement: under
/// [`SnapMode::Save`] the third element carries the serialized snapshot
/// (`None` when the run drained before reaching the requested cycle);
/// under [`SnapMode::Warm`] the run resumes from the given bytes.
pub fn try_run_workload_snap(
    cfg: &SystemConfig,
    workload_name: &str,
    runtime: Option<&mut Runtime>,
    capture: bool,
    snap: SnapMode,
) -> Result<(RunResult, Option<Trace>, Option<Vec<u8>>), String> {
    let params = cfg.workload_params();
    if tenancy::is_mix(workload_name) {
        let (wl, plan) = tenancy::compose(workload_name, &params)
            .map_err(|e| format!("workload '{workload_name}': {e}"))?;
        return run_with_plan_snap(cfg, wl, Some(plan), runtime, capture, snap);
    }
    let wl = workloads::try_build(workload_name, &params)?;
    run_with_plan_snap(cfg, wl, None, runtime, capture, snap)
}

/// Run an already-built workload (callers that pre-tweak phases/checks).
pub fn run_built(
    cfg: &SystemConfig,
    wl: Workload,
    runtime: Option<&mut Runtime>,
) -> RunResult {
    run_built_traced(cfg, wl, runtime, false).0
}

/// [`run_built`] with optional trace capture.
pub fn run_built_traced(
    cfg: &SystemConfig,
    wl: Workload,
    runtime: Option<&mut Runtime>,
    capture: bool,
) -> (RunResult, Option<Trace>) {
    run_with_plan(cfg, wl, None, runtime, capture)
}

/// The shared run core. With a [`MixPlan`] the system is built around the
/// inter-kernel scheduler and the result carries a per-tenant
/// [`TenancyReport`]; without one this is the classic barrier-driver run.
pub fn run_with_plan(
    cfg: &SystemConfig,
    wl: Workload,
    plan: Option<MixPlan>,
    runtime: Option<&mut Runtime>,
    capture: bool,
) -> (RunResult, Option<Trace>) {
    let name = wl.name.clone();
    let (res, trace, _) = run_with_plan_snap(cfg, wl, plan, runtime, capture, SnapMode::None)
        .unwrap_or_else(|e| panic!("workload '{name}': {e}"));
    (res, trace)
}

/// [`run_with_plan`] with snapshot involvement (see [`SnapMode`] and
/// [`try_run_workload_snap`] for the contract of the third element).
pub fn run_with_plan_snap(
    cfg: &SystemConfig,
    mut wl: Workload,
    plan: Option<MixPlan>,
    runtime: Option<&mut Runtime>,
    capture: bool,
    snap: SnapMode,
) -> Result<(RunResult, Option<Trace>, Option<Vec<u8>>), String> {
    if capture && !matches!(snap, SnapMode::None) {
        return Err(
            "trace capture cannot be combined with snapshots (the CU trace tap is \
             not serialized); drop --trace-out"
                .into(),
        );
    }
    let name = wl.name.clone();
    let n_phases = wl.phases.len() as u32;
    let checks = std::mem::take(&mut wl.checks);
    let init = std::mem::take(&mut wl.init);
    let delay = {
        // copy_delay reads wl.init, which we've already taken; recompute
        // from the extracted image.
        let probe = Workload {
            name: String::new(),
            init: init.clone(),
            phases: vec![],
            checks: vec![],
            kind: "",
        };
        topology::copy_delay(cfg, &probe)
    };
    let mut sys = match &plan {
        Some(p) => topology::build_mix(cfg, wl, p, delay),
        None => topology::build_with_delay(cfg, wl, delay),
    };
    // Execution knob only: any thread count produces identical results
    // (the logical partition is fixed by the topology).
    sys.engine.set_threads(cfg.shards as usize);
    if capture {
        for &id in &sys.cus {
            sys.engine.downcast_mut::<Cu>(id).enable_trace();
        }
    }

    // Initial memory image + input snapshots for verification. A warm
    // start restores both from the snapshot file instead: the live image
    // is already mid-run, and the verification inputs must be the ones
    // the cold run captured at t=0.
    let mut snapshots = Vec::new();
    if !matches!(snap, SnapMode::Warm { .. }) {
        {
            let mut mem = sys.mem.borrow_mut();
            for (addr, vals) in &init {
                mem.write_f32_slice(*addr, vals);
            }
        }
        snapshots = verify::snapshot_inputs(&checks, &sys.mem);
    }

    let t0 = Instant::now();
    let mut snap_out = None;
    match snap {
        SnapMode::None => {
            sys.engine.post(0, sys.driver, Msg::Tick);
            sys.engine.run_to_completion();
        }
        SnapMode::Save { at } => {
            sys.engine.post(0, sys.driver, Msg::Tick);
            let paused = sys.engine.run_until_barrier(at);
            if paused {
                let fp = crate::snapshot::config_fingerprint(cfg, &name);
                snap_out = Some(crate::snapshot::save_bytes(
                    &mut sys.engine,
                    &sys.mem,
                    &snapshots,
                    fp,
                    &name,
                )?);
            }
            // Resume: the atomic-window pause guarantees the remainder is
            // byte-identical to a run that never stopped.
            sys.engine.run_to_completion();
        }
        SnapMode::Warm { bytes } => {
            let fp = crate::snapshot::config_fingerprint(cfg, &name);
            let loaded =
                crate::snapshot::restore_bytes(&bytes, &mut sys.engine, &sys.mem, fp, &name)?;
            snapshots = loaded.verify_inputs;
            // No kick-off tick: the restored queues carry the pending
            // events of the paused run.
            sys.engine.run_to_completion();
        }
    }
    let host = t0.elapsed().as_secs_f64();

    assert!(
        root_done_at(&sys.engine, sys.driver).is_some(),
        "simulation drained without finishing all phases (deadlock?)"
    );

    let mut metrics = collect_metrics(&sys, host);
    if plan.is_some() {
        metrics.tenancy = Some(collect_tenancy(&sys));
    }
    let trace = capture.then(|| {
        let c = (cfg.cus_per_gpu as usize).max(1);
        let mut streams = vec![vec![Vec::new(); c]; cfg.n_gpus as usize];
        for (i, &id) in sys.cus.iter().enumerate() {
            streams[i / c][i % c] = sys.engine.downcast_mut::<Cu>(id).take_trace();
        }
        Trace {
            meta: TraceMeta {
                workload: name.clone(),
                n_gpus: cfg.n_gpus,
                cus_per_gpu: cfg.cus_per_gpu,
                wavefronts_per_cu: cfg.wavefronts_per_cu,
                n_phases,
                gpu_mem_bytes: cfg.gpu_mem_bytes,
                cycles: metrics.cycles,
                events: metrics.events,
                init: init.iter().map(|(a, v)| (*a, v.len() as u64)).collect(),
            },
            streams,
        }
    });
    let checks = verify::run_checks(&checks, &snapshots, &sys.mem, runtime);
    Ok((RunResult { config: cfg.name.clone(), workload: name, metrics, checks }, trace, snap_out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(preset: &str) -> SystemConfig {
        let mut cfg = SystemConfig::preset(preset);
        cfg.n_gpus = 2;
        cfg.cus_per_gpu = 2;
        cfg.wavefronts_per_cu = 2;
        cfg.l2_banks = 2;
        cfg.stacks_per_gpu = 2;
        cfg.gpu_mem_bytes = 64 << 20;
        cfg.scale = 0.05;
        cfg
    }

    #[test]
    fn relu_runs_and_verifies_on_all_presets() {
        for preset in SystemConfig::PRESETS {
            let cfg = small(preset);
            let res = run_workload(&cfg, "rl", None);
            assert!(res.metrics.cycles > 0, "{preset}: no cycles");
            assert!(
                res.all_passed(),
                "{preset}: checks failed: {:?}",
                res.checks
            );
        }
    }

    #[test]
    fn xtreme1_verifies_under_halcone() {
        let cfg = small("SM-WT-C-HALCONE");
        let res = run_workload(&cfg, "xtreme1", None);
        assert!(res.all_passed(), "{:?}", res.checks);
        // Xtreme's repeated writes must generate coherency misses.
        assert!(
            res.metrics.l1.coherency_misses > 0,
            "expected coherency misses: {:?}",
            res.metrics.l1
        );
    }

    #[test]
    fn xtreme3_inter_gpu_sharing_verifies_everywhere() {
        for preset in ["SM-WT-NC", "SM-WT-C-HALCONE", "RDMA-WB-C-HMG"] {
            let cfg = small(preset);
            let res = run_workload(&cfg, "xtreme3", None);
            assert!(res.all_passed(), "{preset}: {:?}", res.checks);
        }
    }

    #[test]
    fn rdma_is_slower_than_shared_memory_on_shared_data() {
        // The paper's headline: MGPU-SM >> RDMA when GPUs touch data homed
        // on another GPU. `fir`'s input signal lives in GPU0's partition,
        // so GPU1 streams it over PCIe under RDMA.
        let rdma = run_workload(&small("RDMA-WB-NC"), "fir", None);
        let sm = run_workload(&small("SM-WT-NC"), "fir", None);
        assert!(rdma.all_passed() && sm.all_passed());
        assert!(
            rdma.metrics.cycles > sm.metrics.cycles,
            "RDMA {} should exceed SM {}",
            rdma.metrics.cycles,
            sm.metrics.cycles
        );
        assert!(rdma.metrics.pcie_bytes > 0, "fir under RDMA must cross PCIe");
    }

    #[test]
    fn perf_faults_preserve_correctness_and_only_slow_the_run() {
        let clean = run_workload(&small("SM-WT-C-HALCONE"), "fir", None);
        let mut cfg = small("SM-WT-C-HALCONE");
        cfg.set("faults", "seed=7;degrade=0.3;outage=0.2;window=2000").unwrap();
        let hurt = run_workload(&cfg, "fir", None);
        assert!(clean.all_passed(), "clean run failed");
        assert!(hurt.all_passed(), "degraded hardware must not corrupt memory");
        assert!(
            hurt.metrics.cycles >= clean.metrics.cycles,
            "faults may only slow the run: {} < {}",
            hurt.metrics.cycles,
            clean.metrics.cycles
        );
        assert!(clean.metrics.faults.is_none(), "fault-free runs carry no fault section");
        let f = hurt.metrics.faults.expect("fault section present when faults are armed");
        assert!(
            f.link_outage_cycles > 0 || f.link_degraded_msgs > 0,
            "a 50% fault rate must actually touch traffic: {f:?}"
        );
    }

    #[test]
    fn finite_timestamps_roll_over_and_still_verify() {
        let mut cfg = small("SM-WT-C-HALCONE");
        cfg.set("faults", "ts_bits=4").unwrap();
        let res = run_workload(&cfg, "xtreme1", None);
        assert!(res.all_passed(), "rollover flushes must never lose data");
        let f = res.metrics.faults.expect("ts_bits arms the fault section");
        assert!(
            f.rollover_flushes + f.tsu_rollovers > 0,
            "4-bit counters must roll over under xtreme sharing: {f:?}"
        );
    }

    #[test]
    fn halcone_overhead_vs_nc_is_small_on_streaming() {
        // Paper §5.1: ~1% overhead on standard (DRF) benchmarks.
        let nc = run_workload(&small("SM-WT-NC"), "fir", None);
        let hc = run_workload(&small("SM-WT-C-HALCONE"), "fir", None);
        assert!(nc.all_passed() && hc.all_passed());
        let overhead = hc.metrics.cycles as f64 / nc.metrics.cycles as f64;
        assert!(
            overhead < 1.25,
            "HALCONE overhead too large on streaming workload: {overhead:.3}"
        );
    }

    #[test]
    fn cu_counters_land_in_metrics() {
        let cfg = small("SM-WT-NC");
        let res = run_workload(&cfg, "fir", None);
        assert!(res.metrics.cu_loads > 0, "fir issues loads");
        assert!(res.metrics.cu_stores > 0, "fir issues stores");
        assert!(res.metrics.cycles_per_op().unwrap() > 0.0);
    }

    #[test]
    fn mix_runs_end_to_end_with_a_tenancy_report() {
        let cfg = small("SM-WT-C-HALCONE");
        let (res, _) =
            run_workload_traced(&cfg, "mix:read-mostly+false-sharing@64", None, false);
        assert!(res.all_passed(), "{:?}", res.checks);
        let t = res.metrics.tenancy.as_ref().expect("mix run carries a tenancy report");
        assert_eq!(t.tenants.len(), 2);
        assert_eq!(t.scheduler, "fifo");
        assert!(t.tenants.iter().all(|tm| tm.jobs == 1), "{t:?}");
        // Attribution conserves the untagged totals.
        assert_eq!(res.metrics.cu_loads, t.tenants.iter().map(|tm| tm.loads).sum::<u64>());
        assert_eq!(res.metrics.cu_stores, t.tenants.iter().map(|tm| tm.stores).sum::<u64>());
    }

    #[test]
    fn ordinary_runs_carry_no_tenancy_section() {
        let cfg = small("SM-WT-NC");
        let res = run_workload(&cfg, "rl", None);
        assert!(res.metrics.tenancy.is_none());
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = small("SM-WT-C-HALCONE");
        let a = run_workload(&cfg, "bfs", None);
        let b = run_workload(&cfg, "bfs", None);
        assert_eq!(a.metrics.cycles, b.metrics.cycles);
        assert_eq!(a.metrics.events, b.metrics.events);
        assert_eq!(a.metrics.l2_mm_transactions(), b.metrics.l2_mm_transactions());
    }
}
