//! Byte-level primitives of the snapshot format: LEB128 varints, a
//! bounds-checked read cursor, CRC-32 checksums and serializers for the
//! engine's message types.
//!
//! Same dependency-free idiom as `trace/format.rs`; the helpers are
//! public because component `save_state`/`load_state` implementations
//! all over the crate (and the decode-hardening tests) build on them.

use crate::mem::LineBuf;
use crate::sim::engine::CompId;
use crate::sim::msg::{Event, MemReq, MemRsp, Msg, ReqKind, TsPair};

/// Append `v` as a LEB128 varint.
pub fn put(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Append a length-prefixed UTF-8 string.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

/// Append a bool as one byte.
pub fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(v as u8);
}

/// Append an `f32` bit-exactly (via `to_bits`).
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    put(out, v.to_bits() as u64);
}

/// Bounds-checked read cursor over a snapshot byte slice. Every read
/// names what it was reading, so a truncated or corrupt file produces
/// an actionable error instead of a panic.
pub struct Cur<'a> {
    pub b: &'a [u8],
    pub i: usize,
}

impl<'a> Cur<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Cur { b, i: 0 }
    }

    /// Whether every byte has been consumed.
    pub fn done(&self) -> bool {
        self.i == self.b.len()
    }

    pub fn byte(&mut self, what: &str) -> Result<u8, String> {
        let v = *self
            .b
            .get(self.i)
            .ok_or_else(|| format!("truncated snapshot: EOF reading {what} at byte {}", self.i))?;
        self.i += 1;
        Ok(v)
    }

    pub fn u64(&mut self, what: &str) -> Result<u64, String> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.byte(what)?;
            if shift >= 64 || (shift == 63 && byte > 1) {
                return Err(format!("varint overflow reading {what} at byte {}", self.i));
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn u32(&mut self, what: &str) -> Result<u32, String> {
        let v = self.u64(what)?;
        u32::try_from(v).map_err(|_| format!("{what} value {v} exceeds 32 bits"))
    }

    pub fn bool(&mut self, what: &str) -> Result<bool, String> {
        match self.byte(what)? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(format!("{what} flag byte {v} is neither 0 nor 1")),
        }
    }

    pub fn f32(&mut self, what: &str) -> Result<f32, String> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    /// Borrow the next `n` raw bytes.
    pub fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| format!("truncated snapshot: EOF reading {what} at byte {}", self.i))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    pub fn str(&mut self, what: &str) -> Result<String, String> {
        let n = self.u64(what)? as usize;
        if n > 4096 {
            return Err(format!("{what} string length {n} is absurd"));
        }
        let raw = self.bytes(n, what)?;
        Ok(std::str::from_utf8(raw)
            .map_err(|e| format!("{what} is not UTF-8: {e}"))?
            .to_string())
    }
}

// ---- CRC-32 (IEEE 802.3 polynomial, table-driven).

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) of `bytes` — the per-section integrity check.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---- Message serializers (the engine's queued-event payloads).

fn put_kind(out: &mut Vec<u8>, k: ReqKind) {
    out.push(match k {
        ReqKind::Read => 0,
        ReqKind::Write => 1,
    });
}

fn read_kind(c: &mut Cur, what: &str) -> Result<ReqKind, String> {
    match c.byte(what)? {
        0 => Ok(ReqKind::Read),
        1 => Ok(ReqKind::Write),
        v => Err(format!("{what}: unknown request kind {v}")),
    }
}

/// Serialize an inline line buffer (length + payload bytes).
pub fn put_buf(out: &mut Vec<u8>, b: &LineBuf) {
    put(out, b.len() as u64);
    out.extend_from_slice(b);
}

/// Read a line buffer written by [`put_buf`].
pub fn read_buf(c: &mut Cur, what: &str) -> Result<LineBuf, String> {
    let n = c.u64(what)? as usize;
    if n > LineBuf::CAP {
        return Err(format!("{what}: payload length {n} exceeds a cache line"));
    }
    Ok(LineBuf::from_slice(c.bytes(n, what)?))
}

fn put_comp(out: &mut Vec<u8>, id: CompId) {
    put(out, id.0 as u64);
}

fn read_comp(c: &mut Cur, what: &str) -> Result<CompId, String> {
    Ok(CompId(c.u32(what)?))
}

/// Serialize an in-flight memory request.
pub fn put_req(out: &mut Vec<u8>, r: &MemReq) {
    put(out, r.id);
    put_kind(out, r.kind);
    put(out, r.addr);
    put(out, r.size as u64);
    put_comp(out, r.src);
    put_comp(out, r.dst);
    put_buf(out, &r.data);
    match r.warpts {
        None => out.push(0),
        Some(v) => {
            out.push(1);
            put(out, v);
        }
    }
    put(out, r.tenant as u64);
}

pub fn read_req(c: &mut Cur, what: &str) -> Result<MemReq, String> {
    Ok(MemReq {
        id: c.u64(what)?,
        kind: read_kind(c, what)?,
        addr: c.u64(what)?,
        size: c.u32(what)?,
        src: read_comp(c, what)?,
        dst: read_comp(c, what)?,
        data: read_buf(c, what)?,
        warpts: if c.bool(what)? { Some(c.u64(what)?) } else { None },
        tenant: c.u32(what)?,
    })
}

/// Serialize an in-flight memory response.
pub fn put_rsp(out: &mut Vec<u8>, r: &MemRsp) {
    put(out, r.id);
    put_kind(out, r.kind);
    put(out, r.addr);
    put_comp(out, r.dst);
    put_buf(out, &r.data);
    match r.ts {
        None => out.push(0),
        Some(ts) => {
            out.push(1);
            put(out, ts.rts);
            put(out, ts.wts);
        }
    }
}

pub fn read_rsp(c: &mut Cur, what: &str) -> Result<MemRsp, String> {
    Ok(MemRsp {
        id: c.u64(what)?,
        kind: read_kind(c, what)?,
        addr: c.u64(what)?,
        dst: read_comp(c, what)?,
        data: read_buf(c, what)?,
        ts: if c.bool(what)? {
            Some(TsPair { rts: c.u64(what)?, wts: c.u64(what)? })
        } else {
            None
        },
    })
}

/// Serialize any queued message (tag byte + variant payload).
pub fn put_msg(out: &mut Vec<u8>, m: &Msg) {
    match m {
        Msg::Req(r) => {
            out.push(0);
            put_req(out, r);
        }
        Msg::Rsp(r) => {
            out.push(1);
            put_rsp(out, r);
        }
        Msg::Inv { addr, dir, dst } => {
            out.push(2);
            put(out, *addr);
            put_comp(out, *dir);
            put_comp(out, *dst);
        }
        Msg::InvAck { addr, from, dst } => {
            out.push(3);
            put(out, *addr);
            put_comp(out, *from);
            put_comp(out, *dst);
        }
        Msg::StartPhase { phase } => {
            out.push(4);
            put(out, *phase as u64);
        }
        Msg::PhaseDone { cu } => {
            out.push(5);
            put_comp(out, *cu);
        }
        Msg::FenceQuery { reply_to } => {
            out.push(6);
            put_comp(out, *reply_to);
        }
        Msg::FenceInfo { from, cts } => {
            out.push(7);
            put_comp(out, *from);
            put(out, *cts);
        }
        Msg::FenceApply { reply_to, logical_max } => {
            out.push(8);
            put_comp(out, *reply_to);
            put(out, *logical_max);
        }
        Msg::FenceDone { from } => {
            out.push(9);
            put_comp(out, *from);
        }
        Msg::Tick => out.push(10),
        Msg::DmaDone { bytes } => {
            out.push(11);
            put(out, *bytes);
        }
    }
}

pub fn read_msg(c: &mut Cur, what: &str) -> Result<Msg, String> {
    Ok(match c.byte(what)? {
        0 => Msg::Req(Box::new(read_req(c, what)?)),
        1 => Msg::Rsp(Box::new(read_rsp(c, what)?)),
        2 => Msg::Inv {
            addr: c.u64(what)?,
            dir: read_comp(c, what)?,
            dst: read_comp(c, what)?,
        },
        3 => Msg::InvAck {
            addr: c.u64(what)?,
            from: read_comp(c, what)?,
            dst: read_comp(c, what)?,
        },
        4 => Msg::StartPhase { phase: c.u32(what)? },
        5 => Msg::PhaseDone { cu: read_comp(c, what)? },
        6 => Msg::FenceQuery { reply_to: read_comp(c, what)? },
        7 => Msg::FenceInfo { from: read_comp(c, what)?, cts: c.u64(what)? },
        8 => Msg::FenceApply { reply_to: read_comp(c, what)?, logical_max: c.u64(what)? },
        9 => Msg::FenceDone { from: read_comp(c, what)? },
        10 => Msg::Tick,
        11 => Msg::DmaDone { bytes: c.u64(what)? },
        t => return Err(format!("{what}: unknown message tag {t}")),
    })
}

/// Serialize a queued event (time, seq, target, message).
pub fn put_event(out: &mut Vec<u8>, ev: &Event) {
    put(out, ev.time);
    put(out, ev.seq);
    put_comp(out, ev.target);
    put_msg(out, &ev.msg);
}

pub fn read_event(c: &mut Cur, what: &str) -> Result<Event, String> {
    Ok(Event {
        time: c.u64(what)?,
        seq: c.u64(what)?,
        target: read_comp(c, what)?,
        msg: read_msg(c, what)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_boundaries_roundtrip() {
        let mut out = Vec::new();
        let vals = [0, 1, 127, 128, 16383, 16384, u64::MAX - 1, u64::MAX];
        for &v in &vals {
            put(&mut out, v);
        }
        let mut c = Cur::new(&out);
        for &v in &vals {
            assert_eq!(c.u64("v").unwrap(), v);
        }
        assert!(c.done());
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Any single-bit flip changes the checksum.
        let a = crc32(b"halcone snapshot");
        let b = crc32(b"halcone snapshos");
        assert_ne!(a, b);
    }

    #[test]
    fn messages_roundtrip_every_variant() {
        let req = MemReq {
            id: 42,
            kind: ReqKind::Write,
            addr: 0x1234,
            size: 16,
            src: CompId(3),
            dst: CompId(9),
            data: LineBuf::from_slice(&[1, 2, 3, 4]),
            warpts: Some(77),
            tenant: 2,
        };
        let rsp = MemRsp {
            id: 43,
            kind: ReqKind::Read,
            addr: 0x40,
            dst: CompId(1),
            data: LineBuf::zeroed(64),
            ts: Some(TsPair { rts: 100, wts: 95 }),
        };
        let msgs = vec![
            Msg::Req(Box::new(req)),
            Msg::Rsp(Box::new(rsp)),
            Msg::Inv { addr: 0x80, dir: CompId(2), dst: CompId(5) },
            Msg::InvAck { addr: 0x80, from: CompId(5), dst: CompId(2) },
            Msg::StartPhase { phase: 3 },
            Msg::PhaseDone { cu: CompId(7) },
            Msg::FenceQuery { reply_to: CompId(0) },
            Msg::FenceInfo { from: CompId(4), cts: 999 },
            Msg::FenceApply { reply_to: CompId(0), logical_max: 1000 },
            Msg::FenceDone { from: CompId(4) },
            Msg::Tick,
            Msg::DmaDone { bytes: 1 << 20 },
        ];
        let mut out = Vec::new();
        for m in &msgs {
            put_msg(&mut out, m);
        }
        let mut c = Cur::new(&out);
        for m in &msgs {
            let back = read_msg(&mut c, "msg").unwrap();
            assert_eq!(format!("{back:?}"), format!("{m:?}"));
            if let (Msg::Req(a), Msg::Req(b)) = (m, &back) {
                assert_eq!(&a.data[..], &b.data[..]);
            }
        }
        assert!(c.done());
    }

    #[test]
    fn truncated_reads_name_the_field() {
        let mut out = Vec::new();
        put(&mut out, 300);
        let mut c = Cur::new(&out[..1]);
        let err = c.u64("engine now").unwrap_err();
        assert!(err.contains("engine now"), "{err}");
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn oversized_linebuf_is_rejected() {
        let mut out = Vec::new();
        put(&mut out, 65); // length > CAP
        out.extend_from_slice(&[0u8; 65]);
        let mut c = Cur::new(&out);
        assert!(read_buf(&mut c, "payload").unwrap_err().contains("cache line"));
    }
}
