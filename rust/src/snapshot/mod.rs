//! Integrity-checked engine-state snapshots (docs/SNAPSHOT.md).
//!
//! A snapshot freezes a paused simulation — pending events, in-flight
//! pooled messages, cache arrays, TSU tables, CU wavefront state, link
//! serialization fronts and the shared global memory — into a versioned,
//! dependency-free binary file, so that a warm-started run continues
//! **byte-identically** to the cold run it forked from, at any
//! `--shards`/`--jobs` level.
//!
//! Layout (integers are LEB128 varints; see [`format`]):
//!
//! ```text
//! magic        8 raw bytes  "HALCSNP\0"
//! version      varint       FORMAT_VERSION (readers reject anything else)
//! fingerprint  varint       config_fingerprint(cfg, workload)
//! workload     varint len + UTF-8 bytes
//! at           varint       engine cycle at the snapshot barrier
//! sections     3 x { tag byte, payload len, crc32(payload), payload }
//!              ENGINE (1), MEMORY (2), VERIFY (3)
//! ```
//!
//! Compatibility rules mirror the trace format: the version is bumped on
//! *any* layout change (no in-band extensions), and readers refuse
//! unknown versions, fingerprint mismatches, bad checksums, truncation
//! and trailing garbage with a named error — never a panic, never
//! silent drift. The *immutable* structure (topology, routes, programs,
//! fault schedules) is **not** serialized: a warm start rebuilds it from
//! the configuration, which is why the fingerprint pins every
//! sim-affecting config field plus the workload name.
//!
//! Files are written via write-temp + atomic rename, so a crash mid-write
//! can never leave a half-written checkpoint under the final name.

pub mod format;

use crate::config::{Coherence, SystemConfig};
use crate::dram::storage::SharedMemory;
use crate::sim::{Cycle, Engine};

/// Current snapshot format version. v2: per-shard occupancy counters
/// (windows/idle_windows) joined the engine section, and the canonical
/// configuration gained the `fabric`/`shard_groups` partition keys.
pub const FORMAT_VERSION: u64 = 2;

const MAGIC: &[u8; 8] = b"HALCSNP\0";

const SEC_ENGINE: u8 = 1;
const SEC_MEMORY: u8 = 2;
const SEC_VERIFY: u8 = 3;

/// Per-check verification inputs captured at snapshot time (the memory
/// image is already dirty when the warmup pauses, so warm-started runs
/// must check against the inputs the cold run saw at t=0).
pub type VerifyInputs = Vec<Vec<Vec<f32>>>;

/// Everything a warm start recovers from a snapshot besides the engine
/// and memory state it loads in place.
pub struct Loaded {
    /// Engine cycle at which the snapshot was taken.
    pub at: Cycle,
    /// Workload the snapshotted run was executing.
    pub workload: String,
    /// Captured verification inputs (see [`VerifyInputs`]).
    pub verify_inputs: VerifyInputs,
}

// ---- Configuration fingerprint.

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Canonical string of every sim-affecting configuration field.
///
/// Excluded on purpose: `name` (a display label) and `shards` (a host
/// thread-count knob) — both are byte-identity-neutral, so a snapshot
/// taken at `--shards 1` warm-starts a `--shards 4` run and vice versa.
fn canonical_config(cfg: &SystemConfig, workload: &str) -> String {
    let coher = match cfg.coherence {
        Coherence::None => "none".to_string(),
        Coherence::Halcone { leases, carry_warpts } => {
            format!("halcone:rd={},wr={},warpts={}", leases.rd, leases.wr, carry_warpts)
        }
        Coherence::Tardis { leases } => format!("tardis:rd={},wr={}", leases.rd, leases.wr),
        Coherence::Hlc { leases } => format!("hlc:rd={},wr={}", leases.rd, leases.wr),
        Coherence::Hmg => "hmg".to_string(),
    };
    let faults = match &cfg.faults {
        None => "none".to_string(),
        Some(f) => f.to_string(),
    };
    format!(
        "topology={:?};n_gpus={};cus_per_gpu={};wavefronts_per_cu={};l2_policy={:?};\
         coherence={coher};l1_bytes={};l1_ways={};l2_banks={};l2_bank_bytes={};l2_ways={};\
         stacks_per_gpu={};gpu_mem_bytes={};l1_lat={};l2_lat={};mc_lat={};alu_lat={};\
         onchip_lat={};swc_lat={};pcie_lat={};gpu_uplink_bw={};hbm_bw={};pcie_bw={};\
         mshr_l1={};mshr_l2={};tsu_entries={};scale={:#x};fabric={fabric:?};\
         shard_groups={groups};faults={faults};workload={workload}",
        cfg.topology,
        cfg.n_gpus,
        cfg.cus_per_gpu,
        cfg.wavefronts_per_cu,
        cfg.l2_policy,
        cfg.l1_bytes,
        cfg.l1_ways,
        cfg.l2_banks,
        cfg.l2_bank_bytes,
        cfg.l2_ways,
        cfg.stacks_per_gpu,
        cfg.gpu_mem_bytes,
        cfg.l1_lat,
        cfg.l2_lat,
        cfg.mc_lat,
        cfg.alu_lat,
        cfg.onchip_lat,
        cfg.swc_lat,
        cfg.pcie_lat,
        cfg.gpu_uplink_bw,
        cfg.hbm_bw,
        cfg.pcie_bw,
        cfg.mshr_l1,
        cfg.mshr_l2,
        cfg.tsu_entries,
        cfg.scale.to_bits(),
        fabric = cfg.fabric,
        groups = crate::coordinator::topology::shard_groups_value(&cfg.shard_groups),
        faults = faults,
        workload = workload,
    )
}

/// FNV-1a fingerprint over the canonical configuration + workload name.
/// Two runs share a fingerprint iff they build the identical simulated
/// system executing the identical workload.
pub fn config_fingerprint(cfg: &SystemConfig, workload: &str) -> u64 {
    fnv1a(canonical_config(cfg, workload).as_bytes())
}

// ---- Section framing.

fn put_section(out: &mut Vec<u8>, tag: u8, payload: &[u8]) {
    out.push(tag);
    format::put(out, payload.len() as u64);
    format::put(out, format::crc32(payload) as u64);
    out.extend_from_slice(payload);
}

fn read_section<'a>(
    cur: &mut format::Cur<'a>,
    tag: u8,
    name: &str,
) -> Result<&'a [u8], String> {
    let t = cur.byte(&format!("{name} section tag"))?;
    if t != tag {
        return Err(format!("expected the {name} section (tag {tag}), found tag {t}"));
    }
    let len = cur.u64(&format!("{name} section length"))? as usize;
    let stored = cur.u32(&format!("{name} section checksum"))?;
    let payload = cur.bytes(len, &format!("{name} section payload"))?;
    let actual = format::crc32(payload);
    if actual != stored {
        return Err(format!(
            "{name} section checksum mismatch (stored {stored:#010x}, computed \
             {actual:#010x}) — the snapshot file is corrupt; regenerate it with \
             `run --snapshot-out`"
        ));
    }
    Ok(payload)
}

// ---- Save / restore.

/// Serialize the full simulation state of a paused engine.
///
/// The engine must sit at a deterministic pause point
/// ([`Engine::run_until_barrier`]); `verify_inputs` are the
/// verification inputs captured before the run mutated memory.
pub fn save_bytes(
    engine: &mut Engine,
    mem: &SharedMemory,
    verify_inputs: &VerifyInputs,
    fingerprint: u64,
    workload: &str,
) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(1 << 16);
    out.extend_from_slice(MAGIC);
    format::put(&mut out, FORMAT_VERSION);
    format::put(&mut out, fingerprint);
    format::put_str(&mut out, workload);
    format::put(&mut out, engine.now());

    let mut buf = Vec::with_capacity(1 << 16);
    engine.save_state(&mut buf)?;
    put_section(&mut out, SEC_ENGINE, &buf);

    buf.clear();
    mem.borrow_mut().save_state(&mut buf);
    put_section(&mut out, SEC_MEMORY, &buf);

    buf.clear();
    format::put(&mut buf, verify_inputs.len() as u64);
    for check in verify_inputs {
        format::put(&mut buf, check.len() as u64);
        for arr in check {
            format::put(&mut buf, arr.len() as u64);
            for &v in arr {
                format::put_f32(&mut buf, v);
            }
        }
    }
    put_section(&mut out, SEC_VERIFY, &buf);
    Ok(out)
}

/// Restore a snapshot into a freshly built (idle) engine + memory.
///
/// `expect_fingerprint`/`expect_workload` come from the warm-starting
/// run's own configuration; any mismatch is refused with an actionable
/// error naming both sides — warm-starting under a different simulated
/// configuration would not be a resumed run, it would be silent drift.
pub fn restore_bytes(
    bytes: &[u8],
    engine: &mut Engine,
    mem: &SharedMemory,
    expect_fingerprint: u64,
    expect_workload: &str,
) -> Result<Loaded, String> {
    let mut cur = format::Cur::new(bytes);
    if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
        return Err("not a HALCONE snapshot (bad magic)".into());
    }
    cur.i = MAGIC.len();
    let version = cur.u64("format version")?;
    if version != FORMAT_VERSION {
        return Err(format!(
            "snapshot format version {version} is not the supported {FORMAT_VERSION}; \
             regenerate the snapshot with this binary (`run --snapshot-out`)"
        ));
    }
    let fingerprint = cur.u64("config fingerprint")?;
    let workload = cur.str("workload name")?;
    if workload != expect_workload {
        return Err(format!(
            "snapshot was taken while running workload '{workload}', this run executes \
             '{expect_workload}'; a warm start must continue the same workload"
        ));
    }
    if fingerprint != expect_fingerprint {
        return Err(format!(
            "snapshot configuration fingerprint {fingerprint:#018x} does not match this \
             run's {expect_fingerprint:#018x}; re-create the snapshot under the same \
             preset/overrides and workload (`run --snapshot-out`)"
        ));
    }
    let at = cur.u64("snapshot cycle")?;

    let engine_bytes = read_section(&mut cur, SEC_ENGINE, "engine")?;
    let mut ec = format::Cur::new(engine_bytes);
    engine.load_state(&mut ec)?;
    if !ec.done() {
        return Err(format!("trailing garbage in the engine section at byte {}", ec.i));
    }

    let mem_bytes = read_section(&mut cur, SEC_MEMORY, "memory")?;
    let mut mc = format::Cur::new(mem_bytes);
    mem.borrow_mut().load_state(&mut mc)?;
    if !mc.done() {
        return Err(format!("trailing garbage in the memory section at byte {}", mc.i));
    }

    let verify_bytes = read_section(&mut cur, SEC_VERIFY, "verify")?;
    let mut vc = format::Cur::new(verify_bytes);
    let n_checks = vc.u64("verify check count")? as usize;
    if n_checks > 4096 {
        return Err(format!("verify check count {n_checks} is absurd"));
    }
    let mut verify_inputs = Vec::with_capacity(n_checks);
    for ci in 0..n_checks {
        let n_arrays = vc.u64(&format!("verify check {ci} array count"))? as usize;
        if n_arrays > 4096 {
            return Err(format!("verify check {ci} array count {n_arrays} is absurd"));
        }
        let mut arrays = Vec::with_capacity(n_arrays);
        for ai in 0..n_arrays {
            let what = format!("verify check {ci} array {ai}");
            let n = vc.u64(&what)? as usize;
            if n > bytes.len() {
                return Err(format!("{what}: element count {n} exceeds the input size"));
            }
            let mut arr = Vec::with_capacity(n);
            for _ in 0..n {
                arr.push(vc.f32(&what)?);
            }
            arrays.push(arr);
        }
        verify_inputs.push(arrays);
    }
    if !vc.done() {
        return Err(format!("trailing garbage in the verify section at byte {}", vc.i));
    }

    if !cur.done() {
        return Err(format!("trailing garbage after the snapshot at byte {}", cur.i));
    }
    Ok(Loaded { at, workload, verify_inputs })
}

// ---- File IO (write-temp + atomic rename; PR 7 journal idiom).

/// Write snapshot bytes to `path` atomically: a crash mid-write leaves
/// at most a `.tmp` file, never a corrupt checkpoint under `path`.
pub fn write_file(path: &str, bytes: &[u8]) -> Result<(), String> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, bytes).map_err(|e| format!("writing snapshot {tmp}: {e}"))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("renaming snapshot {tmp} -> {path}: {e}"))
}

/// Read a snapshot file's raw bytes (validation happens in
/// [`restore_bytes`], against the warm-starting run's configuration).
pub fn read_file(path: &str) -> Result<Vec<u8>, String> {
    std::fs::read(path).map_err(|e| format!("reading snapshot {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_ignores_name_and_shards_only() {
        let base = SystemConfig::preset("SM-WT-C-HALCONE");
        let fp = config_fingerprint(&base, "fir");

        let mut named = base.clone();
        named.name = "renamed".into();
        named.shards = 8;
        assert_eq!(config_fingerprint(&named, "fir"), fp, "name/shards must not matter");

        let mut scaled = base.clone();
        scaled.scale = 0.5;
        assert_ne!(config_fingerprint(&scaled, "fir"), fp);

        let mut regeo = base.clone();
        regeo.n_gpus = 2;
        assert_ne!(config_fingerprint(&regeo, "fir"), fp);

        assert_ne!(config_fingerprint(&base, "rl"), fp, "workload is part of the identity");

        let mut faulted = base.clone();
        faulted.set("faults", "seed=7;degrade=0.2").unwrap();
        assert_ne!(config_fingerprint(&faulted, "fir"), fp);

        // The fabric partition and shard grouping change the event
        // order, so they are part of the identity — unlike `shards`.
        let mut hubbed = base.clone();
        hubbed.set("fabric", "hub").unwrap();
        assert_ne!(config_fingerprint(&hubbed, "fir"), fp, "fabric is sim-affecting");
        let mut grouped = base.clone();
        grouped.set("shard_groups", "0,0,1,1").unwrap();
        assert_ne!(config_fingerprint(&grouped, "fir"), fp, "shard_groups is sim-affecting");
    }

    #[test]
    fn fingerprint_distinguishes_lease_settings() {
        let base = SystemConfig::preset("SM-WT-C-HALCONE");
        let mut tuned = base.clone();
        tuned.set("rd_lease", "20").unwrap();
        assert_ne!(
            config_fingerprint(&base, "fir"),
            config_fingerprint(&tuned, "fir"),
            "lease settings are sim-affecting"
        );
    }

    #[test]
    fn section_framing_detects_corruption() {
        let mut out = Vec::new();
        put_section(&mut out, SEC_ENGINE, b"hello engine state");
        {
            let mut cur = format::Cur::new(&out);
            let p = read_section(&mut cur, SEC_ENGINE, "engine").unwrap();
            assert_eq!(p, b"hello engine state");
            assert!(cur.done());
        }
        // Flip one payload byte: checksum must catch it.
        let mut bad = out.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        let mut cur = format::Cur::new(&bad);
        let err = read_section(&mut cur, SEC_ENGINE, "engine").unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");
        // Wrong tag is a named structural error.
        let mut cur = format::Cur::new(&out);
        let err = read_section(&mut cur, SEC_MEMORY, "memory").unwrap_err();
        assert!(err.contains("memory section"), "{err}");
        // Truncation anywhere inside the section fails cleanly.
        for cut in 1..out.len() {
            let mut cur = format::Cur::new(&out[..cut]);
            assert!(read_section(&mut cur, SEC_ENGINE, "engine").is_err(), "cut {cut}");
        }
    }

    #[test]
    fn atomic_write_leaves_no_temp_on_success() {
        let dir = std::env::temp_dir().join(format!("halcsnap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.snap");
        let path = path.to_str().unwrap();
        write_file(path, b"payload").unwrap();
        assert_eq!(std::fs::read(path).unwrap(), b"payload");
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
