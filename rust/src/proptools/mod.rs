//! Minimal property-testing harness (proptest is unavailable in the
//! offline registry — DESIGN.md S20).
//!
//! A property is a closure from a seeded [`Rng`] to `Result<(), String>`;
//! [`check`] runs it across many derived seeds and reports the first
//! failing seed, which reproduces deterministically (no shrinking — the
//! failing seed plus the generator is enough to replay and debug).

pub use crate::workloads::Rng;

/// Number of cases [`check`] runs by default.
pub const DEFAULT_CASES: u64 = 64;

/// Run `prop` for `cases` seeds derived from `base_seed`; panics with the
/// failing seed and message on the first violation.
pub fn check_with(
    name: &str,
    base_seed: u64,
    cases: u64,
    mut prop: impl FnMut(&mut Rng) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15) | 1);
        let mut rng = Rng(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (seed {seed:#x}, case {case}): {msg}");
        }
    }
}

/// [`check_with`] with the default case count.
pub fn check(name: &str, base_seed: u64, prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    check_with(name, base_seed, DEFAULT_CASES, prop);
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("sum commutes", 1, |rng| {
            let (a, b) = (rng.next_f32(), rng.next_f32());
            prop_assert!(a + b == b + a, "{a} + {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 2, |_| Err("nope".into()));
    }

    #[test]
    fn seeds_vary_across_cases() {
        let mut seen = std::collections::HashSet::new();
        check_with("seed variety", 3, 32, |rng| {
            seen.insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.len(), 32);
    }
}
