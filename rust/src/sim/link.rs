//! Bandwidth + latency modelled point-to-point links.
//!
//! A link serializes messages: a message of `b` bytes occupies the link for
//! `ceil(b / bytes_per_cycle)` cycles after any earlier traffic has
//! drained, then takes `latency` cycles of flight time. This reproduces
//! both the queueing delay the paper models on the L2<->MM network and the
//! PCIe bottleneck of the RDMA configurations.
//!
//! A link may carry a [`LinkFaults`] schedule (docs/ROBUSTNESS.md):
//! degraded windows multiply latency and divide bandwidth, outage
//! windows defer serialization until the link recovers. Every fault
//! effect only *delays* traffic — nothing is dropped and no delivery
//! moves earlier — so the sharded engine's conservative-window check
//! and byte-determinism are preserved by construction. On-chip wires
//! ([`Link::wire`]) are exempt: the fault model targets the
//! interconnect, not intra-GPU wiring.

use crate::faults::LinkFaults;
use crate::sim::Cycle;

/// Serialization bandwidth that marks an on-chip wire (see
/// [`Link::wire`]): effectively infinite, and exempt from faults.
const WIRE_BW: u64 = u64::MAX / 2;

/// Index of a link registered with the [`crate::sim::Engine`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LinkId(pub u32);

/// A directed bandwidth-limited channel.
#[derive(Clone, Debug)]
pub struct Link {
    /// Human-readable name for metrics/debugging (e.g. "gpu0.l2b3->mm5").
    pub name: String,
    /// Flight latency in cycles, applied after serialization.
    pub latency: Cycle,
    /// Serialization bandwidth. At 1 GHz, 32 GB/s = 32 bytes/cycle
    /// (PCIe 4.0 switch), 341 GB/s HBM stack = 341 bytes/cycle.
    pub bytes_per_cycle: u64,
    /// Next cycle at which the head of the link is free.
    next_free: Cycle,
    /// Total bytes accepted (metrics).
    pub bytes_sent: u64,
    /// Total messages accepted (metrics).
    pub msgs_sent: u64,
    /// Cumulative queueing delay in cycles (metrics).
    pub queue_cycles: u64,
    /// Fault schedule, if injection is active for this link.
    faults: Option<LinkFaults>,
    /// Cycles spent waiting out link outages (metrics).
    pub outage_cycles: u64,
    /// Messages accepted inside degraded windows (metrics).
    pub degraded_msgs: u64,
}

impl Link {
    pub fn new(name: impl Into<String>, latency: Cycle, bytes_per_cycle: u64) -> Self {
        assert!(bytes_per_cycle > 0, "link bandwidth must be positive");
        Link {
            name: name.into(),
            latency,
            bytes_per_cycle,
            next_free: 0,
            bytes_sent: 0,
            msgs_sent: 0,
            queue_cycles: 0,
            faults: None,
            outage_cycles: 0,
            degraded_msgs: 0,
        }
    }

    /// Accept a message of `bytes` at `now`; returns its delivery time.
    ///
    /// Under faults, the effective earliest start, latency and
    /// bandwidth come from the window holding the (post-outage)
    /// arrival; all three effects only push the delivery later, never
    /// earlier, which the conservative-window engine relies on.
    pub fn accept(&mut self, now: Cycle, bytes: u64) -> Cycle {
        let (arrive, latency, bpc) = match &self.faults {
            Some(f) => {
                let arrive = f.available_at(now);
                let (latmul, bwdiv) = f.perf_at(arrive);
                self.outage_cycles += arrive - now;
                if (latmul, bwdiv) != (1, 1) {
                    self.degraded_msgs += 1;
                }
                (arrive, self.latency * latmul, (self.bytes_per_cycle / bwdiv).max(1))
            }
            None => (now, self.latency, self.bytes_per_cycle),
        };
        let start = self.next_free.max(arrive);
        self.queue_cycles += start - now;
        let ser = bytes.div_ceil(bpc).max(1);
        self.next_free = start + ser;
        self.bytes_sent += bytes;
        self.msgs_sent += 1;
        self.next_free + latency
    }

    /// An infinite-bandwidth, fixed-latency link (on-chip wires).
    pub fn wire(name: impl Into<String>, latency: Cycle) -> Self {
        Link::new(name, latency, WIRE_BW)
    }

    /// On-chip wires are exempt from fault injection.
    pub fn is_wire(&self) -> bool {
        self.bytes_per_cycle == WIRE_BW
    }

    /// Attach a fault schedule (no-op on wires — see module docs).
    pub fn set_faults(&mut self, faults: LinkFaults) {
        if !self.is_wire() {
            self.faults = Some(faults);
        }
    }

    /// Cycle at which the link becomes idle (testing/metrics).
    pub fn next_free(&self) -> Cycle {
        self.next_free
    }

    /// Serialize the mutable state (docs/SNAPSHOT.md). The fault
    /// schedule is *not* written: it is a pure function of
    /// (seed, link ordinal, window) and is rebuilt from the config on
    /// warm start.
    pub(crate) fn save_state(&self, out: &mut Vec<u8>) {
        use crate::snapshot::format::put;
        put(out, self.next_free);
        put(out, self.bytes_sent);
        put(out, self.msgs_sent);
        put(out, self.queue_cycles);
        put(out, self.outage_cycles);
        put(out, self.degraded_msgs);
    }

    /// Restore the state written by [`Link::save_state`].
    pub(crate) fn load_state(
        &mut self,
        cur: &mut crate::snapshot::format::Cur,
    ) -> Result<(), String> {
        self.next_free = cur.u64("link next_free")?;
        self.bytes_sent = cur.u64("link bytes_sent")?;
        self.msgs_sent = cur.u64("link msgs_sent")?;
        self.queue_cycles = cur.u64("link queue_cycles")?;
        self.outage_cycles = cur.u64("link outage_cycles")?;
        self.degraded_msgs = cur.u64("link degraded_msgs")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialization_delays_back_to_back_messages() {
        let mut l = Link::new("t", 10, 32); // 32 B/cycle, 10cy flight
        // 64-byte message: 2 cycles serialization + 10 flight.
        assert_eq!(l.accept(0, 64), 12);
        // Second message queues behind the first's serialization.
        assert_eq!(l.accept(0, 64), 14);
        assert_eq!(l.queue_cycles, 2);
        assert_eq!(l.bytes_sent, 128);
        assert_eq!(l.msgs_sent, 2);
    }

    #[test]
    fn idle_link_has_no_queueing() {
        let mut l = Link::new("t", 5, 64);
        assert_eq!(l.accept(100, 64), 106);
        assert_eq!(l.queue_cycles, 0);
        // Arrives after the link drained: no queueing either.
        assert_eq!(l.accept(200, 64), 206);
        assert_eq!(l.queue_cycles, 0);
    }

    #[test]
    fn wire_links_only_add_latency() {
        let mut l = Link::wire("w", 3);
        assert_eq!(l.accept(0, 1 << 20), 4); // 1 serialization cycle min
        assert_eq!(l.accept(1000, 8), 1004);
    }

    #[test]
    fn min_one_cycle_serialization() {
        let mut l = Link::new("t", 0, 1024);
        assert_eq!(l.accept(0, 4), 1);
    }

    #[test]
    fn faults_never_deliver_earlier_than_healthy() {
        use crate::faults::FaultSpec;
        let spec = FaultSpec {
            degrade: 0.4,
            outage: 0.2,
            window: 50,
            ..FaultSpec::default()
        };
        for ord in 0..4u32 {
            let mut healthy = Link::new("h", 10, 32);
            let mut faulty = Link::new("f", 10, 32);
            faulty.set_faults(LinkFaults::new(spec, ord));
            for i in 0..200u64 {
                let now = i * 13;
                let h = healthy.accept(now, 64);
                let f = faulty.accept(now, 64);
                assert!(f >= h, "ord {ord} msg {i}: faulty {f} < healthy {h}");
            }
            assert_eq!(healthy.bytes_sent, faulty.bytes_sent, "nothing may be dropped");
            assert_eq!(healthy.msgs_sent, faulty.msgs_sent);
        }
    }

    #[test]
    fn fault_injection_is_deterministic_per_link() {
        use crate::faults::FaultSpec;
        let spec = FaultSpec { degrade: 0.3, outage: 0.3, window: 64, ..FaultSpec::default() };
        let run = || {
            let mut l = Link::new("t", 5, 16);
            l.set_faults(LinkFaults::new(spec, 7));
            (0..300u64).map(|i| l.accept(i * 3, 48)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn wires_are_exempt_from_faults() {
        use crate::faults::FaultSpec;
        let spec = FaultSpec { outage: 0.9, window: 10, ..FaultSpec::default() };
        let mut w = Link::wire("w", 3);
        assert!(w.is_wire());
        w.set_faults(LinkFaults::new(spec, 0));
        assert_eq!(w.accept(0, 1 << 20), 4);
        assert_eq!(w.outage_cycles, 0);
        assert!(!Link::new("t", 1, 32).is_wire());
    }
}
