//! Bandwidth + latency modelled point-to-point links.
//!
//! A link serializes messages: a message of `b` bytes occupies the link for
//! `ceil(b / bytes_per_cycle)` cycles after any earlier traffic has
//! drained, then takes `latency` cycles of flight time. This reproduces
//! both the queueing delay the paper models on the L2<->MM network and the
//! PCIe bottleneck of the RDMA configurations.

use crate::sim::Cycle;

/// Index of a link registered with the [`crate::sim::Engine`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LinkId(pub u32);

/// A directed bandwidth-limited channel.
#[derive(Clone, Debug)]
pub struct Link {
    /// Human-readable name for metrics/debugging (e.g. "gpu0.l2b3->mm5").
    pub name: String,
    /// Flight latency in cycles, applied after serialization.
    pub latency: Cycle,
    /// Serialization bandwidth. At 1 GHz, 32 GB/s = 32 bytes/cycle
    /// (PCIe 4.0 switch), 341 GB/s HBM stack = 341 bytes/cycle.
    pub bytes_per_cycle: u64,
    /// Next cycle at which the head of the link is free.
    next_free: Cycle,
    /// Total bytes accepted (metrics).
    pub bytes_sent: u64,
    /// Total messages accepted (metrics).
    pub msgs_sent: u64,
    /// Cumulative queueing delay in cycles (metrics).
    pub queue_cycles: u64,
}

impl Link {
    pub fn new(name: impl Into<String>, latency: Cycle, bytes_per_cycle: u64) -> Self {
        assert!(bytes_per_cycle > 0, "link bandwidth must be positive");
        Link {
            name: name.into(),
            latency,
            bytes_per_cycle,
            next_free: 0,
            bytes_sent: 0,
            msgs_sent: 0,
            queue_cycles: 0,
        }
    }

    /// Accept a message of `bytes` at `now`; returns its delivery time.
    pub fn accept(&mut self, now: Cycle, bytes: u64) -> Cycle {
        let start = self.next_free.max(now);
        self.queue_cycles += start - now;
        let ser = bytes.div_ceil(self.bytes_per_cycle).max(1);
        self.next_free = start + ser;
        self.bytes_sent += bytes;
        self.msgs_sent += 1;
        self.next_free + self.latency
    }

    /// An infinite-bandwidth, fixed-latency link (on-chip wires).
    pub fn wire(name: impl Into<String>, latency: Cycle) -> Self {
        Link::new(name, latency, u64::MAX / 2)
    }

    /// Cycle at which the link becomes idle (testing/metrics).
    pub fn next_free(&self) -> Cycle {
        self.next_free
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialization_delays_back_to_back_messages() {
        let mut l = Link::new("t", 10, 32); // 32 B/cycle, 10cy flight
        // 64-byte message: 2 cycles serialization + 10 flight.
        assert_eq!(l.accept(0, 64), 12);
        // Second message queues behind the first's serialization.
        assert_eq!(l.accept(0, 64), 14);
        assert_eq!(l.queue_cycles, 2);
        assert_eq!(l.bytes_sent, 128);
        assert_eq!(l.msgs_sent, 2);
    }

    #[test]
    fn idle_link_has_no_queueing() {
        let mut l = Link::new("t", 5, 64);
        assert_eq!(l.accept(100, 64), 106);
        assert_eq!(l.queue_cycles, 0);
        // Arrives after the link drained: no queueing either.
        assert_eq!(l.accept(200, 64), 206);
        assert_eq!(l.queue_cycles, 0);
    }

    #[test]
    fn wire_links_only_add_latency() {
        let mut l = Link::wire("w", 3);
        assert_eq!(l.accept(0, 1 << 20), 4); // 1 serialization cycle min
        assert_eq!(l.accept(1000, 8), 1004);
    }

    #[test]
    fn min_one_cycle_serialization() {
        let mut l = Link::new("t", 0, 1024);
        assert_eq!(l.accept(0, 4), 1);
    }
}
