//! The event loop: components, scheduling context, and the engine itself.

use crate::sim::link::{Link, LinkId};
use crate::sim::msg::{Event, MemReq, MemRsp, Msg};
use crate::sim::pool::MsgPool;
use crate::sim::queue::EventQueue;
use crate::sim::Cycle;

/// Index of a component registered with the [`Engine`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CompId(pub u32);

impl CompId {
    pub const NONE: CompId = CompId(u32::MAX);
}

/// A simulated hardware component (cache, CU, memory controller, ...).
///
/// Components interact exclusively by exchanging [`Msg`]s through the
/// [`Ctx`]: either scheduling a future event on themselves/others
/// (`ctx.schedule`) or sending through a bandwidth-modelled link
/// (`ctx.send`).
pub trait Component {
    /// Stable diagnostic name ("gpu0.cu3.l1", "mm2", ...).
    fn name(&self) -> &str;

    /// Deliver `msg` at cycle `now`.
    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx);

    /// Downcast support (setup and metrics extraction).
    fn as_any(&self) -> &dyn std::any::Any;
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

/// Implements the `as_any`/`as_any_mut` boilerplate for a component type.
#[macro_export]
macro_rules! impl_component_any {
    () => {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    };
}

/// Scheduling context handed to [`Component::handle`].
///
/// Borrow discipline: while a component runs, the engine lends out the
/// event queue, message pool and link table (never other components), so
/// a component can freely mutate itself and schedule traffic without
/// aliasing.
pub struct Ctx<'a> {
    now: Cycle,
    seq: &'a mut u64,
    queue: &'a mut EventQueue,
    pool: &'a mut MsgPool,
    links: &'a mut [Link],
    /// Id of the component currently executing.
    pub self_id: CompId,
}

impl Ctx<'_> {
    /// Current simulation time.
    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Deliver `msg` to `target` after `delay` cycles (no link modelled).
    pub fn schedule(&mut self, delay: Cycle, target: CompId, msg: Msg) {
        let seq = *self.seq;
        *self.seq += 1;
        self.queue.push(Event { time: self.now + delay, seq, target, msg });
    }

    /// Send `msg` of `bytes` to `target` through `link`; delivery time is
    /// determined by the link's serialization + latency model.
    pub fn send(&mut self, link: LinkId, target: CompId, bytes: u64, msg: Msg) {
        let deliver = self.links[link.0 as usize].accept(self.now, bytes);
        let seq = *self.seq;
        *self.seq += 1;
        self.queue.push(Event { time: deliver, seq, target, msg });
    }

    /// Like [`Ctx::send`], but the message enters the link only after
    /// `delay` cycles of local processing (e.g. a memory controller's fixed
    /// access latency before the response starts back across the network).
    pub fn send_delayed(
        &mut self,
        delay: Cycle,
        link: LinkId,
        target: CompId,
        bytes: u64,
        msg: Msg,
    ) {
        let deliver = self.links[link.0 as usize].accept(self.now + delay, bytes);
        let seq = *self.seq;
        *self.seq += 1;
        self.queue.push(Event { time: deliver, seq, target, msg });
    }

    /// Box `req` as a [`Msg::Req`], recycling a pooled box when one is
    /// available (the allocation-free send path).
    pub fn req_msg(&mut self, req: MemReq) -> Msg {
        self.pool.req(req)
    }

    /// Box `rsp` as a [`Msg::Rsp`] through the pool.
    pub fn rsp_msg(&mut self, rsp: MemRsp) -> Msg {
        self.pool.rsp(rsp)
    }

    /// Move a received request out of its box, returning the box to the
    /// pool (the allocation-free receive path).
    pub fn reclaim_req(&mut self, b: Box<MemReq>) -> MemReq {
        self.pool.reclaim_req(b)
    }

    /// Move a received response out of its box, returning the box.
    pub fn reclaim_rsp(&mut self, b: Box<MemRsp>) -> MemRsp {
        self.pool.reclaim_rsp(b)
    }

    /// Inspect a link (e.g. for backpressure decisions).
    pub fn link(&self, link: LinkId) -> &Link {
        &self.links[link.0 as usize]
    }
}

/// The discrete-event engine: owns components, links and the event queue.
pub struct Engine {
    comps: Vec<Option<Box<dyn Component>>>,
    links: Vec<Link>,
    queue: EventQueue,
    pool: MsgPool,
    seq: u64,
    now: Cycle,
    events_processed: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            comps: Vec::new(),
            links: Vec::new(),
            queue: EventQueue::new(),
            pool: MsgPool::new(),
            seq: 0,
            now: 0,
            events_processed: 0,
        }
    }

    /// Register a component; returns its id.
    pub fn add(&mut self, c: Box<dyn Component>) -> CompId {
        let id = CompId(self.comps.len() as u32);
        self.comps.push(Some(c));
        id
    }

    /// Register a link; returns its id.
    pub fn add_link(&mut self, l: Link) -> LinkId {
        let id = LinkId(self.links.len() as u32);
        self.links.push(l);
        id
    }

    /// Schedule an initial event from outside any component.
    pub fn post(&mut self, time: Cycle, target: CompId, msg: Msg) {
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Event { time, seq, target, msg });
    }

    /// Run until the queue drains or `limit` cycles elapse.
    ///
    /// Returns the final simulation time. Panics if an event targets an
    /// unknown component (a wiring bug, not a runtime condition).
    pub fn run(&mut self, limit: Cycle) -> Cycle {
        // Peek before popping: pausing at `limit` must leave the queue
        // untouched so pause/resume cycles do no queue churn.
        while let Some(t) = self.queue.next_time() {
            if t > limit {
                self.now = limit;
                return self.now;
            }
            let ev = self.queue.pop().expect("peeked event vanished");
            debug_assert!(ev.time >= self.now, "time went backwards");
            self.now = ev.time;
            self.events_processed += 1;
            let idx = ev.target.0 as usize;
            let mut comp = self.comps[idx]
                .take()
                .unwrap_or_else(|| panic!("event for unregistered component {idx}"));
            let mut ctx = Ctx {
                now: self.now,
                seq: &mut self.seq,
                queue: &mut self.queue,
                pool: &mut self.pool,
                links: &mut self.links,
                self_id: ev.target,
            };
            comp.handle(self.now, ev.msg, &mut ctx);
            self.comps[idx] = Some(comp);
        }
        self.now
    }

    /// Run until the queue is fully drained (no cycle limit).
    pub fn run_to_completion(&mut self) -> Cycle {
        self.run(Cycle::MAX)
    }

    /// Current simulation time.
    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Total events dispatched so far (perf metric).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Whether any events remain queued.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
    }

    /// Message-pool counters (perf diagnostics / allocation tests).
    pub fn pool(&self) -> &MsgPool {
        &self.pool
    }

    /// Immutable access to a component (downcast by the caller).
    pub fn component(&self, id: CompId) -> &dyn Component {
        self.comps[id.0 as usize].as_deref().expect("component checked out")
    }

    /// Mutable access to a component (setup / result extraction only —
    /// never call from inside `handle`).
    pub fn component_mut(&mut self, id: CompId) -> &mut Box<dyn Component> {
        self.comps[id.0 as usize].as_mut().expect("component checked out")
    }

    /// Typed access to a component (panics on type mismatch — a test or
    /// coordinator wiring bug).
    pub fn downcast<T: 'static>(&self, id: CompId) -> &T {
        self.component(id)
            .as_any()
            .downcast_ref::<T>()
            .unwrap_or_else(|| panic!("component {:?} has unexpected type", id))
    }

    /// Typed mutable access to a component.
    pub fn downcast_mut<T: 'static>(&mut self, id: CompId) -> &mut T {
        self.component_mut(id)
            .as_any_mut()
            .downcast_mut::<T>()
            .unwrap_or_else(|| panic!("component {:?} has unexpected type", id))
    }

    /// Immutable access to a link's counters.
    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0 as usize]
    }

    /// All links (metrics aggregation).
    pub fn links(&self) -> &[Link] {
        &self.links
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ping-pong pair: A sends to B, B replies, N rounds.
    struct Pinger {
        name: String,
        peer: CompId,
        link: LinkId,
        remaining: u32,
        received: u32,
        last_seen: Cycle,
    }

    impl Component for Pinger {
    crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
            match msg {
                Msg::Tick => {
                    self.received += 1;
                    self.last_seen = now;
                    if self.remaining > 0 {
                        self.remaining -= 1;
                        ctx.send(self.link, self.peer, 64, Msg::Tick);
                    }
                }
                _ => unreachable!(),
            }
        }
    }

    fn pinger(name: &str, peer: CompId, link: LinkId, remaining: u32) -> Box<Pinger> {
        Box::new(Pinger {
            name: name.into(),
            peer,
            link,
            remaining,
            received: 0,
            last_seen: 0,
        })
    }

    #[test]
    fn ping_pong_round_trip_timing() {
        let mut e = Engine::new();
        let l_ab = e.add_link(Link::new("a->b", 10, 64));
        let l_ba = e.add_link(Link::new("b->a", 10, 64));
        // Ids are assigned in insertion order; pre-compute them.
        let a_id = CompId(0);
        let b_id = CompId(1);
        e.add(pinger("a", b_id, l_ab, 3));
        e.add(pinger("b", a_id, l_ba, 3));
        e.post(0, a_id, Msg::Tick);
        let end = e.run_to_completion();
        // Each hop: 1 cycle serialization + 10 latency = 11.
        // a@0 -> b@11 -> a@22 -> b@33 -> a@44 -> b@55 -> a@66: a sent 3, b sent 3.
        assert_eq!(end, 66);
        assert_eq!(e.events_processed(), 7);
    }

    #[test]
    fn run_with_limit_pauses_and_resumes() {
        let mut e = Engine::new();
        let l = e.add_link(Link::wire("w", 100));
        let a_id = CompId(0);
        let b_id = CompId(1);
        e.add(pinger("a", b_id, l, 5));
        e.add(pinger("b", a_id, l, 5));
        e.post(0, a_id, Msg::Tick);
        let t = e.run(150);
        assert_eq!(t, 150);
        assert!(!e.is_idle());
        let end = e.run_to_completion();
        assert!(end > 150);
        assert!(e.is_idle());
    }

    #[test]
    fn determinism_same_seed_same_trace() {
        let build_and_run = || {
            let mut e = Engine::new();
            let l = e.add_link(Link::new("l", 7, 16));
            let a_id = CompId(0);
            let b_id = CompId(1);
            e.add(pinger("a", b_id, l, 100));
            e.add(pinger("b", a_id, l, 100));
            e.post(0, a_id, Msg::Tick);
            let end = e.run_to_completion();
            (end, e.events_processed(), e.link(l).bytes_sent)
        };
        assert_eq!(build_and_run(), build_and_run());
    }

    /// Requester/responder pair exercising the pooled Req/Rsp path.
    struct Requester {
        name: String,
        responder: CompId,
        remaining: u32,
    }
    impl Component for Requester {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
            if let Msg::Rsp(b) = msg {
                let rsp = ctx.reclaim_rsp(b);
                assert_eq!(rsp.data.len(), 64);
            }
            if self.remaining > 0 {
                self.remaining -= 1;
                let req = MemReq {
                    id: self.remaining as u64,
                    addr: 0x40,
                    size: 4,
                    src: ctx.self_id,
                    dst: self.responder,
                    ..MemReq::default()
                };
                let target = self.responder;
                let msg = ctx.req_msg(req);
                ctx.schedule(3, target, msg);
            }
        }
    }
    struct Responder {
        name: String,
    }
    impl Component for Responder {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
            let Msg::Req(b) = msg else { unreachable!() };
            let req = ctx.reclaim_req(b);
            let rsp = MemRsp {
                id: req.id,
                kind: req.kind,
                addr: req.addr,
                dst: req.src,
                data: crate::mem::LineBuf::zeroed(64),
                ts: None,
            };
            let target = req.src;
            let msg = ctx.rsp_msg(rsp);
            ctx.schedule(5, target, msg);
        }
    }

    #[test]
    fn pooled_boxes_recycle_across_transactions() {
        let mut e = Engine::new();
        let req_id = CompId(0);
        let rsp_id = CompId(1);
        e.add(Box::new(Requester { name: "rq".into(), responder: rsp_id, remaining: 1000 }));
        e.add(Box::new(Responder { name: "rs".into() }));
        e.post(0, req_id, Msg::Tick);
        e.run_to_completion();
        let p = e.pool();
        // One transaction in flight at a time: one box of each kind,
        // reused for every subsequent round trip.
        assert_eq!(p.fresh_reqs, 1, "req boxes must recycle: {}", p.fresh_reqs);
        assert_eq!(p.fresh_rsps, 1, "rsp boxes must recycle: {}", p.fresh_rsps);
        assert_eq!(p.reused_reqs, 999);
        assert_eq!(p.reused_rsps, 999);
    }
}
