//! The event loop: components, scheduling context, and the engine itself.

use std::collections::BinaryHeap;

use crate::sim::link::{Link, LinkId};
use crate::sim::msg::{Event, Msg};
use crate::sim::Cycle;

/// Index of a component registered with the [`Engine`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CompId(pub u32);

impl CompId {
    pub const NONE: CompId = CompId(u32::MAX);
}

/// A simulated hardware component (cache, CU, memory controller, ...).
///
/// Components interact exclusively by exchanging [`Msg`]s through the
/// [`Ctx`]: either scheduling a future event on themselves/others
/// (`ctx.schedule`) or sending through a bandwidth-modelled link
/// (`ctx.send`).
pub trait Component {
    /// Stable diagnostic name ("gpu0.cu3.l1", "mm2", ...).
    fn name(&self) -> &str;

    /// Deliver `msg` at cycle `now`.
    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx);

    /// Downcast support (setup and metrics extraction).
    fn as_any(&self) -> &dyn std::any::Any;
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

/// Implements the `as_any`/`as_any_mut` boilerplate for a component type.
#[macro_export]
macro_rules! impl_component_any {
    () => {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    };
}

/// Scheduling context handed to [`Component::handle`].
///
/// Borrow discipline: while a component runs, the engine lends out the
/// event queue and link table (never other components), so a component can
/// freely mutate itself and schedule traffic without aliasing.
pub struct Ctx<'a> {
    now: Cycle,
    seq: &'a mut u64,
    queue: &'a mut BinaryHeap<Event>,
    links: &'a mut [Link],
    /// Id of the component currently executing.
    pub self_id: CompId,
}

impl Ctx<'_> {
    /// Current simulation time.
    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Deliver `msg` to `target` after `delay` cycles (no link modelled).
    pub fn schedule(&mut self, delay: Cycle, target: CompId, msg: Msg) {
        let seq = *self.seq;
        *self.seq += 1;
        self.queue.push(Event { time: self.now + delay, seq, target, msg });
    }

    /// Send `msg` of `bytes` to `target` through `link`; delivery time is
    /// determined by the link's serialization + latency model.
    pub fn send(&mut self, link: LinkId, target: CompId, bytes: u64, msg: Msg) {
        let deliver = self.links[link.0 as usize].accept(self.now, bytes);
        let seq = *self.seq;
        *self.seq += 1;
        self.queue.push(Event { time: deliver, seq, target, msg });
    }

    /// Like [`Ctx::send`], but the message enters the link only after
    /// `delay` cycles of local processing (e.g. a memory controller's fixed
    /// access latency before the response starts back across the network).
    pub fn send_delayed(
        &mut self,
        delay: Cycle,
        link: LinkId,
        target: CompId,
        bytes: u64,
        msg: Msg,
    ) {
        let deliver = self.links[link.0 as usize].accept(self.now + delay, bytes);
        let seq = *self.seq;
        *self.seq += 1;
        self.queue.push(Event { time: deliver, seq, target, msg });
    }

    /// Inspect a link (e.g. for backpressure decisions).
    pub fn link(&self, link: LinkId) -> &Link {
        &self.links[link.0 as usize]
    }
}

/// The discrete-event engine: owns components, links and the event queue.
pub struct Engine {
    comps: Vec<Option<Box<dyn Component>>>,
    links: Vec<Link>,
    queue: BinaryHeap<Event>,
    seq: u64,
    now: Cycle,
    events_processed: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            comps: Vec::new(),
            links: Vec::new(),
            queue: BinaryHeap::with_capacity(1 << 16),
            seq: 0,
            now: 0,
            events_processed: 0,
        }
    }

    /// Register a component; returns its id.
    pub fn add(&mut self, c: Box<dyn Component>) -> CompId {
        let id = CompId(self.comps.len() as u32);
        self.comps.push(Some(c));
        id
    }

    /// Register a link; returns its id.
    pub fn add_link(&mut self, l: Link) -> LinkId {
        let id = LinkId(self.links.len() as u32);
        self.links.push(l);
        id
    }

    /// Schedule an initial event from outside any component.
    pub fn post(&mut self, time: Cycle, target: CompId, msg: Msg) {
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Event { time, seq, target, msg });
    }

    /// Run until the queue drains or `limit` cycles elapse.
    ///
    /// Returns the final simulation time. Panics if an event targets an
    /// unknown component (a wiring bug, not a runtime condition).
    pub fn run(&mut self, limit: Cycle) -> Cycle {
        while let Some(ev) = self.queue.pop() {
            if ev.time > limit {
                // Put it back: callers may resume with a higher limit.
                self.queue.push(ev);
                self.now = limit;
                return self.now;
            }
            debug_assert!(ev.time >= self.now, "time went backwards");
            self.now = ev.time;
            self.events_processed += 1;
            let idx = ev.target.0 as usize;
            let mut comp = self.comps[idx]
                .take()
                .unwrap_or_else(|| panic!("event for unregistered component {idx}"));
            let mut ctx = Ctx {
                now: self.now,
                seq: &mut self.seq,
                queue: &mut self.queue,
                links: &mut self.links,
                self_id: ev.target,
            };
            comp.handle(self.now, ev.msg, &mut ctx);
            self.comps[idx] = Some(comp);
        }
        self.now
    }

    /// Run until the queue is fully drained (no cycle limit).
    pub fn run_to_completion(&mut self) -> Cycle {
        self.run(Cycle::MAX)
    }

    /// Current simulation time.
    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Total events dispatched so far (perf metric).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Whether any events remain queued.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
    }

    /// Immutable access to a component (downcast by the caller).
    pub fn component(&self, id: CompId) -> &dyn Component {
        self.comps[id.0 as usize].as_deref().expect("component checked out")
    }

    /// Mutable access to a component (setup / result extraction only —
    /// never call from inside `handle`).
    pub fn component_mut(&mut self, id: CompId) -> &mut Box<dyn Component> {
        self.comps[id.0 as usize].as_mut().expect("component checked out")
    }

    /// Typed access to a component (panics on type mismatch — a test or
    /// coordinator wiring bug).
    pub fn downcast<T: 'static>(&self, id: CompId) -> &T {
        self.component(id)
            .as_any()
            .downcast_ref::<T>()
            .unwrap_or_else(|| panic!("component {:?} has unexpected type", id))
    }

    /// Typed mutable access to a component.
    pub fn downcast_mut<T: 'static>(&mut self, id: CompId) -> &mut T {
        self.component_mut(id)
            .as_any_mut()
            .downcast_mut::<T>()
            .unwrap_or_else(|| panic!("component {:?} has unexpected type", id))
    }

    /// Immutable access to a link's counters.
    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0 as usize]
    }

    /// All links (metrics aggregation).
    pub fn links(&self) -> &[Link] {
        &self.links
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ping-pong pair: A sends to B, B replies, N rounds.
    struct Pinger {
        name: String,
        peer: CompId,
        link: LinkId,
        remaining: u32,
        received: u32,
        last_seen: Cycle,
    }

    impl Component for Pinger {
    crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
            match msg {
                Msg::Tick => {
                    self.received += 1;
                    self.last_seen = now;
                    if self.remaining > 0 {
                        self.remaining -= 1;
                        ctx.send(self.link, self.peer, 64, Msg::Tick);
                    }
                }
                _ => unreachable!(),
            }
        }
    }

    fn pinger(name: &str, peer: CompId, link: LinkId, remaining: u32) -> Box<Pinger> {
        Box::new(Pinger {
            name: name.into(),
            peer,
            link,
            remaining,
            received: 0,
            last_seen: 0,
        })
    }

    #[test]
    fn ping_pong_round_trip_timing() {
        let mut e = Engine::new();
        let l_ab = e.add_link(Link::new("a->b", 10, 64));
        let l_ba = e.add_link(Link::new("b->a", 10, 64));
        // Ids are assigned in insertion order; pre-compute them.
        let a_id = CompId(0);
        let b_id = CompId(1);
        e.add(pinger("a", b_id, l_ab, 3));
        e.add(pinger("b", a_id, l_ba, 3));
        e.post(0, a_id, Msg::Tick);
        let end = e.run_to_completion();
        // Each hop: 1 cycle serialization + 10 latency = 11.
        // a@0 -> b@11 -> a@22 -> b@33 -> a@44 -> b@55 -> a@66: a sent 3, b sent 3.
        assert_eq!(end, 66);
        assert_eq!(e.events_processed(), 7);
    }

    #[test]
    fn run_with_limit_pauses_and_resumes() {
        let mut e = Engine::new();
        let l = e.add_link(Link::wire("w", 100));
        let a_id = CompId(0);
        let b_id = CompId(1);
        e.add(pinger("a", b_id, l, 5));
        e.add(pinger("b", a_id, l, 5));
        e.post(0, a_id, Msg::Tick);
        let t = e.run(150);
        assert_eq!(t, 150);
        assert!(!e.is_idle());
        let end = e.run_to_completion();
        assert!(end > 150);
        assert!(e.is_idle());
    }

    #[test]
    fn determinism_same_seed_same_trace() {
        let build_and_run = || {
            let mut e = Engine::new();
            let l = e.add_link(Link::new("l", 7, 16));
            let a_id = CompId(0);
            let b_id = CompId(1);
            e.add(pinger("a", b_id, l, 100));
            e.add(pinger("b", a_id, l, 100));
            e.post(0, a_id, Msg::Tick);
            let end = e.run_to_completion();
            (end, e.events_processed(), e.link(l).bytes_sent)
        };
        assert_eq!(build_and_run(), build_and_run());
    }
}
