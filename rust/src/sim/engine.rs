//! The event loop: components, scheduling context, and the engine itself.
//!
//! The engine is a set of *logical shards* (see [`crate::sim::shard`]):
//! `Engine::new()` builds the classic single-shard engine with the exact
//! historical semantics; [`Engine::sharded`] partitions the component
//! graph so independent partitions can execute on worker threads
//! ([`Engine::set_threads`]) under conservative time-window
//! synchronization, with results bit-identical to single-threaded
//! execution of the same partition.

use crate::sim::link::{Link, LinkId};
use crate::sim::msg::{Event, MemReq, MemRsp, Msg};
use crate::sim::pool::{MsgPool, PoolCounters};
use crate::sim::queue::EventQueue;
use crate::sim::shard::{self, Loc, Shard, Tables};
use crate::sim::Cycle;

/// Index of a component registered with the [`Engine`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CompId(pub u32);

/// One shard's occupancy profile ([`Engine::shard_occupancy`]):
/// host-only perf counters, excluded from canonical artifacts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardOccupancy {
    /// Events this shard dispatched.
    pub events: u64,
    /// Windows this shard executed.
    pub windows: u64,
    /// Executed windows that dispatched no event.
    pub idle_windows: u64,
}

impl CompId {
    pub const NONE: CompId = CompId(u32::MAX);
}

/// A simulated hardware component (cache, CU, memory controller, ...).
///
/// Components interact exclusively by exchanging [`Msg`]s through the
/// [`Ctx`]: either scheduling a future event on themselves/others
/// (`ctx.schedule`) or sending through a bandwidth-modelled link
/// (`ctx.send`).
///
/// `Send` is a supertrait: a component may be executed by whichever
/// worker thread owns its shard for the current window (never by two
/// threads at once — shards are exclusive).
pub trait Component: Send {
    /// Stable diagnostic name ("gpu0.cu3.l1", "mm2", ...).
    fn name(&self) -> &str;

    /// Deliver `msg` at cycle `now`.
    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx);

    /// Downcast support (setup and metrics extraction).
    fn as_any(&self) -> &dyn std::any::Any;
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;

    /// Serialize this component's *mutable* state (docs/SNAPSHOT.md).
    /// Immutable structure (routes, programs, geometry) is rebuilt from
    /// the configuration on warm start and must not be written here.
    /// The default refuses, so a component type that never implemented
    /// snapshotting fails the save with its name instead of silently
    /// dropping state.
    fn save_state(&self, _out: &mut Vec<u8>) -> Result<(), String> {
        Err(format!("component '{}' does not support snapshots", self.name()))
    }

    /// Restore the state written by [`Component::save_state`] into a
    /// freshly built component.
    fn load_state(&mut self, _cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        Err(format!("component '{}' does not support snapshots", self.name()))
    }
}

/// Implements the `as_any`/`as_any_mut` boilerplate for a component type.
#[macro_export]
macro_rules! impl_component_any {
    () => {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    };
}

/// Scheduling context handed to [`Component::handle`].
///
/// Borrow discipline: while a component runs, its shard lends out the
/// event queue, message pool and link table (never other components), so
/// a component can freely mutate itself and schedule traffic without
/// aliasing. Traffic aimed at another shard is parked in the shard's
/// outbox and routed at the next window barrier.
pub struct Ctx<'a> {
    pub(crate) now: Cycle,
    /// Shard executing this handler.
    pub(crate) shard: u32,
    /// First cycle of the next window (`Cycle::MAX` single-shard).
    pub(crate) window_end: Cycle,
    pub(crate) seq: &'a mut u64,
    pub(crate) queue: &'a mut EventQueue,
    pub(crate) pool: &'a mut MsgPool,
    pub(crate) links: &'a mut Vec<Link>,
    pub(crate) outbox: &'a mut Vec<shard::OutEvent>,
    pub(crate) tables: &'a Tables,
    /// Id of the component currently executing.
    pub self_id: CompId,
}

impl Ctx<'_> {
    /// Current simulation time.
    pub fn now(&self) -> Cycle {
        self.now
    }

    fn next_seq(&mut self) -> u64 {
        let s = *self.seq;
        *self.seq += 1;
        s
    }

    /// Queue a linkless event locally or park it for the barrier router.
    fn push_at(&mut self, time: Cycle, target: CompId, msg: Msg) {
        let seq = self.next_seq();
        let loc = self.tables.comp_loc[target.0 as usize];
        if loc.shard == self.shard {
            self.queue.push(Event { time, seq, target, msg });
            return;
        }
        // Linkless control hop (driver dispatch, fence chatter,
        // directory acks): deliver at its natural time or the next
        // window barrier, whichever is later. The receiving shard
        // has not dispatched anything at or beyond `window_end`, so
        // this is conservative; the quantization is a deterministic
        // function of the window sequence (see sim/shard.rs docs). In
        // solo mode `window_end` is the window start, so the hop keeps
        // its natural time — every peer shard is drained.
        let time = time.max(self.window_end);
        self.outbox.push(shard::OutEvent { dst: loc.shard, ev: Event { time, seq, target, msg } });
    }

    /// Queue a link delivery. Cross-shard deliveries must clear the
    /// conservative window — a violation means the pair's effective
    /// lookahead exceeds this link's minimum delivery delta, which would
    /// corrupt event order silently, so it is a hard error even in
    /// release builds (declared links are additionally validated at
    /// registration — see [`Engine::add_link_between`]).
    fn push_link(&mut self, time: Cycle, target: CompId, msg: Msg, link: LinkId, idx: usize) {
        let seq = self.next_seq();
        let loc = self.tables.comp_loc[target.0 as usize];
        if loc.shard == self.shard {
            self.queue.push(Event { time, seq, target, msg });
            return;
        }
        if time < self.window_end {
            let l = &self.links[idx];
            panic!(
                "cross-shard link delivery inside the conservative window: link '{}' \
                 ({link:?}, latency {}, min delivery delta {}) from shard {} to shard {} \
                 delivers at cycle {time}, before the window ends at {} — the pair's \
                 effective lookahead exceeds the link's latency + 1. Declare cross-shard \
                 links with Engine::add_link_between so the lookahead matrix is derived \
                 from (and validated against) them at registration.",
                l.name,
                l.latency,
                l.latency.saturating_add(1),
                self.shard,
                loc.shard,
                self.window_end
            );
        }
        self.outbox.push(shard::OutEvent { dst: loc.shard, ev: Event { time, seq, target, msg } });
    }

    /// Deliver `msg` to `target` after `delay` cycles (no link modelled).
    pub fn schedule(&mut self, delay: Cycle, target: CompId, msg: Msg) {
        self.push_at(self.now + delay, target, msg);
    }

    /// Send `msg` of `bytes` to `target` through `link`; delivery time is
    /// determined by the link's serialization + latency model.
    pub fn send(&mut self, link: LinkId, target: CompId, bytes: u64, msg: Msg) {
        let now = self.now;
        let idx = self.local_link(link);
        let deliver = self.links[idx].accept(now, bytes);
        self.push_link(deliver, target, msg, link, idx);
    }

    /// Like [`Ctx::send`], but the message enters the link only after
    /// `delay` cycles of local processing (e.g. a memory controller's fixed
    /// access latency before the response starts back across the network).
    pub fn send_delayed(
        &mut self,
        delay: Cycle,
        link: LinkId,
        target: CompId,
        bytes: u64,
        msg: Msg,
    ) {
        let at = self.now + delay;
        let idx = self.local_link(link);
        let deliver = self.links[idx].accept(at, bytes);
        self.push_link(deliver, target, msg, link, idx);
    }

    /// Box `req` as a [`Msg::Req`], recycling a pooled box when one is
    /// available (the allocation-free send path).
    pub fn req_msg(&mut self, req: MemReq) -> Msg {
        self.pool.req(req)
    }

    /// Box `rsp` as a [`Msg::Rsp`] through the pool.
    pub fn rsp_msg(&mut self, rsp: MemRsp) -> Msg {
        self.pool.rsp(rsp)
    }

    /// Move a received request out of its box, returning the box to the
    /// pool (the allocation-free receive path).
    pub fn reclaim_req(&mut self, b: Box<MemReq>) -> MemReq {
        self.pool.reclaim_req(b)
    }

    /// Move a received response out of its box, returning the box.
    pub fn reclaim_rsp(&mut self, b: Box<MemRsp>) -> MemRsp {
        self.pool.reclaim_rsp(b)
    }

    fn local_link(&self, link: LinkId) -> usize {
        let loc = self.tables.link_loc[link.0 as usize];
        assert_eq!(
            loc.shard, self.shard,
            "link {:?} is owned by shard {}, used from shard {} (partition bug: \
             every sender on a link must live in the link's shard)",
            link, loc.shard, self.shard
        );
        loc.idx as usize
    }

    /// Inspect a link (e.g. for backpressure decisions). Only links of
    /// the executing component's shard are visible.
    pub fn link(&self, link: LinkId) -> &Link {
        &self.links[self.local_link(link)]
    }
}

/// The discrete-event engine: owns the logical shards, their components,
/// links and event queues, plus the global id -> shard routing tables.
pub struct Engine {
    shards: Vec<Shard>,
    tables: Tables,
    /// Fallback/ceiling window span: pairs with no declared cross-shard
    /// link use it, and no window ever exceeds it (legacy fixed-lookahead
    /// engines declare nothing and reproduce exactly).
    base_lookahead: Cycle,
    /// Per-shard-pair lookahead matrix, `matrix[src * n + dst]` =
    /// smallest `latency + 1` over the declared `src -> dst` cross-shard
    /// links ([`Engine::add_link_between`]), `Cycle::MAX` when none.
    matrix: Vec<Cycle>,
    /// Worker threads executing the shards (1 = serial).
    threads: usize,
    now: Cycle,
    /// Fault schedule applied to every non-wire link registered after
    /// [`Engine::set_fault_spec`], keyed by the link's registration
    /// ordinal (a pure function of the configuration).
    fault_spec: Option<crate::faults::FaultSpec>,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    /// A classic single-shard engine (tests, micro-benches, tools).
    pub fn new() -> Self {
        Self::sharded(1, 1)
    }

    /// An engine partitioned into `n_shards` logical shards advancing in
    /// conservative windows. `lookahead` is the fallback *and* ceiling
    /// window span: shard pairs connected only by undeclared
    /// (`add_link_to`) links rely on it, so it must not exceed
    /// `min(latency) + 1` over such links (each send is checked at
    /// runtime); pairs declared with [`Engine::add_link_between`] get
    /// their span from the lookahead matrix, validated at registration.
    /// The partition defines event order, so it must depend only on the
    /// simulated configuration — never on the host.
    pub fn sharded(n_shards: u32, lookahead: Cycle) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        assert!(lookahead >= 1, "lookahead must be at least one cycle");
        let n = n_shards as usize;
        Engine {
            shards: (0..n_shards).map(Shard::new).collect(),
            tables: Tables::default(),
            base_lookahead: lookahead,
            matrix: vec![Cycle::MAX; n * n],
            threads: 1,
            now: 0,
            fault_spec: None,
        }
    }

    /// Arm fault injection: every non-wire link registered from now on
    /// carries the schedule. Call before topology construction so link
    /// ordinals cover the whole interconnect.
    pub fn set_fault_spec(&mut self, spec: Option<crate::faults::FaultSpec>) {
        self.fault_spec = spec.filter(|s| s.perturbs_links());
    }

    /// Number of logical shards.
    pub fn n_shards(&self) -> u32 {
        self.shards.len() as u32
    }

    /// Worker threads used by [`Engine::run`] (clamped to the shard
    /// count at run time). Thread count never changes results.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Register a component in shard 0; returns its id.
    pub fn add(&mut self, c: Box<dyn Component>) -> CompId {
        self.add_to(0, c)
    }

    /// Register a component in `shard`; ids are global and assigned in
    /// registration order regardless of the shard.
    pub fn add_to(&mut self, shard: u32, c: Box<dyn Component>) -> CompId {
        let s = &mut self.shards[shard as usize];
        let loc = Loc { shard, idx: s.comps.len() as u32 };
        s.comps.push(Some(c));
        let id = CompId(self.tables.comp_loc.len() as u32);
        self.tables.comp_loc.push(loc);
        id
    }

    /// Register a link in shard 0; returns its id.
    pub fn add_link(&mut self, l: Link) -> LinkId {
        self.add_link_to(0, l)
    }

    /// Register a link owned by `shard`. A link belongs to the shard of
    /// its *senders* (its state mutates on every `Ctx::send`), which is
    /// asserted on use.
    pub fn add_link_to(&mut self, shard: u32, mut l: Link) -> LinkId {
        let id = LinkId(self.tables.link_loc.len() as u32);
        if let Some(spec) = self.fault_spec {
            l.set_faults(crate::faults::LinkFaults::new(spec, id.0));
        }
        let s = &mut self.shards[shard as usize];
        let loc = Loc { shard, idx: s.links.len() as u32 };
        s.links.push(l);
        self.tables.link_loc.push(loc);
        id
    }

    /// Register a *declared* cross-shard link: owned by `src` (its
    /// senders' shard), carrying traffic into `dst`. Declaring lowers
    /// the `src -> dst` lookahead-matrix entry to
    /// `min(entry, latency + 1)`, which sizes the conservative windows
    /// — so the matrix is validated eagerly, here at registration,
    /// instead of on the first send:
    ///
    /// * the pair must be a real cross-shard pair in range;
    /// * the engine must not have dispatched events yet (windows already
    ///   planned against the old matrix could not be revalidated);
    /// * the entry is monotonically tightened, never widened, so every
    ///   previously declared link on the pair stays satisfied.
    pub fn add_link_between(&mut self, src: u32, dst: u32, l: Link) -> LinkId {
        let n = self.shards.len() as u32;
        assert!(
            src < n && dst < n,
            "add_link_between({src}, {dst}): engine has {n} shards (link '{}')",
            l.name
        );
        assert!(
            src != dst,
            "add_link_between: link '{}' declared shard {src} -> itself; use add_link_to \
             for shard-local links",
            l.name
        );
        assert!(
            self.now == 0 && self.shards.iter().all(|s| s.events_processed == 0),
            "add_link_between: link '{}' ({src} -> {dst}) declared after the engine ran; \
             the lookahead matrix is frozen once windows have been planned",
            l.name
        );
        let delta = l.latency.saturating_add(1);
        let id = self.add_link_to(src, l);
        let e = &mut self.matrix[(src as usize) * n as usize + dst as usize];
        *e = (*e).min(delta);
        id
    }

    /// The effective `src -> dst` lookahead: the declared matrix entry,
    /// or `None` when the pair has no declared link (such pairs fall
    /// back to the constructor's base lookahead).
    pub fn pair_lookahead(&self, src: u32, dst: u32) -> Option<Cycle> {
        let n = self.shards.len();
        match self.matrix[(src as usize) * n + dst as usize] {
            Cycle::MAX => None,
            d => Some(d),
        }
    }

    /// Window-planning tables derived from the matrix (row minima).
    fn lookahead_tables(&self) -> shard::Lookahead {
        let n = self.shards.len();
        let row_min = (0..n)
            .map(|s| self.matrix[s * n..(s + 1) * n].iter().copied().min().unwrap_or(Cycle::MAX))
            .collect();
        shard::Lookahead { base: self.base_lookahead, row_min }
    }

    /// Schedule an initial event from outside any component.
    pub fn post(&mut self, time: Cycle, target: CompId, msg: Msg) {
        let loc = self.tables.comp_loc[target.0 as usize];
        let s = &mut self.shards[loc.shard as usize];
        let seq = s.next_seq();
        s.queue.push(Event { time, seq, target, msg });
    }

    /// Run until the queues drain or `limit` cycles elapse.
    ///
    /// Returns the final simulation time. Panics if an event targets an
    /// unknown component (a wiring bug, not a runtime condition).
    pub fn run(&mut self, limit: Cycle) -> Cycle {
        if self.shards.len() == 1 {
            // Single shard: the historical tight loop — no windows, no
            // barriers, nothing can cross.
            self.shards[0].run_window(limit, Cycle::MAX, &self.tables, false);
            let s = &self.shards[0];
            self.now = if s.queue.is_empty() { self.now.max(s.now) } else { limit };
            return self.now;
        }
        let look = self.lookahead_tables();
        let shards = std::mem::take(&mut self.shards);
        let (shards, done) =
            shard::run_windows(shards, &self.tables, &look, self.threads, limit, false);
        self.shards = shards;
        self.now = match done {
            None => limit,
            Some(t) => self.now.max(t),
        };
        self.now
    }

    /// Run until the queues are fully drained (no cycle limit).
    pub fn run_to_completion(&mut self) -> Cycle {
        self.run(Cycle::MAX)
    }

    /// Advance to the first window barrier whose next event lies beyond
    /// `limit`, without ever truncating a window (atomic-window mode —
    /// see `sim::shard`). Returns `true` when paused with events still
    /// queued, `false` when the run drained first.
    ///
    /// Unlike [`Engine::run`]`(limit)`, pausing here is byte-transparent
    /// for multi-shard engines: the window sequence (and with it every
    /// cross-shard quantization target) is identical to an uninterrupted
    /// `run_to_completion`, which is what makes a snapshot taken at this
    /// pause point fork into byte-identical warm-started runs
    /// (docs/SNAPSHOT.md).
    pub fn run_until_barrier(&mut self, limit: Cycle) -> bool {
        if self.shards.len() == 1 {
            // Single shard: no windows, no quantization — pausing on the
            // event boundary at `limit` is inherently transparent.
            self.shards[0].run_window(limit, Cycle::MAX, &self.tables, false);
            let s = &self.shards[0];
            if s.queue.is_empty() {
                self.now = self.now.max(s.now);
                return false;
            }
            self.now = limit;
            return true;
        }
        let look = self.lookahead_tables();
        let shards = std::mem::take(&mut self.shards);
        let (shards, done) =
            shard::run_windows(shards, &self.tables, &look, self.threads, limit, true);
        self.shards = shards;
        match done {
            None => {
                // Paused at a barrier; every dispatched event is burnt
                // into shard state, so the pause time is the max
                // dispatch time (>= limit would overstate idle shards).
                self.now = self.now.max(limit);
                true
            }
            Some(t) => {
                self.now = self.now.max(t);
                false
            }
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Total events dispatched across all shards (perf metric).
    pub fn events_processed(&self) -> u64 {
        self.shards.iter().map(|s| s.events_processed).sum()
    }

    /// Per-shard occupancy profile, indexed by shard id: events
    /// dispatched, windows executed, and executed windows that
    /// dispatched nothing (host-only metrics; never canonical).
    pub fn shard_occupancy(&self) -> Vec<ShardOccupancy> {
        self.shards
            .iter()
            .map(|s| ShardOccupancy {
                events: s.events_processed,
                windows: s.windows,
                idle_windows: s.idle_windows,
            })
            .collect()
    }

    /// The logical shard a component was registered into (ownership
    /// tests and topology diagnostics).
    pub fn shard_of(&self, id: CompId) -> u32 {
        self.tables.comp_loc[id.0 as usize].shard
    }

    /// Whether any events remain queued (in any shard or outbox).
    pub fn is_idle(&self) -> bool {
        self.shards.iter().all(|s| s.queue.is_empty() && s.outbox.is_empty())
    }

    /// Shard-0 message pool (single-shard diagnostics/tests). For the
    /// aggregate across shards use [`Engine::pool_counters`].
    pub fn pool(&self) -> &MsgPool {
        &self.shards[0].pool
    }

    /// Message-pool counters summed over every shard's pool.
    pub fn pool_counters(&self) -> PoolCounters {
        let mut c = PoolCounters::default();
        for s in &self.shards {
            c.add(&s.pool);
        }
        c
    }

    fn comp_slot(&self, id: CompId) -> &Option<Box<dyn Component>> {
        let loc = self.tables.comp_loc[id.0 as usize];
        &self.shards[loc.shard as usize].comps[loc.idx as usize]
    }

    /// Immutable access to a component (downcast by the caller).
    pub fn component(&self, id: CompId) -> &dyn Component {
        self.comp_slot(id).as_deref().expect("component checked out")
    }

    /// Mutable access to a component (setup / result extraction only —
    /// never call from inside `handle`).
    pub fn component_mut(&mut self, id: CompId) -> &mut Box<dyn Component> {
        let loc = self.tables.comp_loc[id.0 as usize];
        self.shards[loc.shard as usize].comps[loc.idx as usize]
            .as_mut()
            .expect("component checked out")
    }

    /// Typed access to a component (panics on type mismatch — a test or
    /// coordinator wiring bug).
    pub fn downcast<T: 'static>(&self, id: CompId) -> &T {
        self.component(id)
            .as_any()
            .downcast_ref::<T>()
            .unwrap_or_else(|| panic!("component {:?} has unexpected type", id))
    }

    /// Typed mutable access to a component.
    pub fn downcast_mut<T: 'static>(&mut self, id: CompId) -> &mut T {
        self.component_mut(id)
            .as_any_mut()
            .downcast_mut::<T>()
            .unwrap_or_else(|| panic!("component {:?} has unexpected type", id))
    }

    /// Immutable access to a link's counters.
    pub fn link(&self, id: LinkId) -> &Link {
        let loc = self.tables.link_loc[id.0 as usize];
        &self.shards[loc.shard as usize].links[loc.idx as usize]
    }

    /// Every registered link, in registration-ordinal order (metrics
    /// sweeps, e.g. the fault counters).
    pub fn links(&self) -> impl Iterator<Item = &Link> {
        (0..self.tables.link_loc.len()).map(|i| self.link(LinkId(i as u32)))
    }

    /// Serialize the engine's mutable state: per-shard scheduler state,
    /// message pools, pending event queues, link fronts and every
    /// component's state, in global registration order
    /// (docs/SNAPSHOT.md). The engine must sit at a deterministic pause
    /// point ([`Engine::run_until_barrier`]), where every outbox is
    /// empty. Queues are drained in exact pop order and re-pushed — the
    /// calendar queue's dequeue order is cursor-invariant, so the
    /// continued run is unaffected.
    pub fn save_state(&mut self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format as f;
        f::put(out, self.now);
        f::put(out, self.shards.len() as u64);
        for s in &self.shards {
            if !s.outbox.is_empty() {
                return Err(format!(
                    "shard {} outbox holds {} events at the snapshot barrier (engine bug)",
                    s.id,
                    s.outbox.len()
                ));
            }
            f::put(out, s.seq);
            f::put(out, s.now);
            f::put(out, s.events_processed);
            f::put(out, s.windows);
            f::put(out, s.idle_windows);
            f::put(out, s.pool.fresh_reqs);
            f::put(out, s.pool.fresh_rsps);
            f::put(out, s.pool.reused_reqs);
            f::put(out, s.pool.reused_rsps);
            let (idle_reqs, idle_rsps) = s.pool.idle();
            f::put(out, idle_reqs as u64);
            f::put(out, idle_rsps as u64);
        }
        for s in &mut self.shards {
            f::put(out, s.queue.len() as u64);
            let mut evs = Vec::with_capacity(s.queue.len());
            while let Some(ev) = s.queue.pop() {
                f::put_event(out, &ev);
                evs.push(ev);
            }
            for ev in evs {
                s.queue.push(ev);
            }
        }
        f::put(out, self.tables.link_loc.len() as u64);
        for i in 0..self.tables.link_loc.len() {
            let l = self.link(LinkId(i as u32));
            f::put_str(out, &l.name);
            l.save_state(out);
        }
        f::put(out, self.tables.comp_loc.len() as u64);
        for i in 0..self.tables.comp_loc.len() {
            let c = self.component(CompId(i as u32));
            f::put_str(out, c.name());
            let mut buf = Vec::new();
            c.save_state(&mut buf)?;
            f::put(out, buf.len() as u64);
            out.extend_from_slice(&buf);
        }
        Ok(())
    }

    /// Restore the state written by [`Engine::save_state`] into a
    /// freshly built, idle engine of the *same* topology (the
    /// configuration fingerprint in the snapshot header guards this;
    /// shard counts, link names and component names are re-validated
    /// here so even a fingerprint collision cannot silently misload).
    pub fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        use crate::snapshot::format as f;
        if !self.is_idle() {
            return Err("warm start into a non-idle engine (coordinator bug)".into());
        }
        self.now = cur.u64("engine now")?;
        let n = cur.u64("shard count")? as usize;
        if n != self.shards.len() {
            return Err(format!(
                "snapshot has {n} logical shards, this topology builds {} — the \
                 configurations differ",
                self.shards.len()
            ));
        }
        for s in &mut self.shards {
            s.seq = cur.u64("shard seq")?;
            s.now = cur.u64("shard now")?;
            s.events_processed = cur.u64("shard events_processed")?;
            s.windows = cur.u64("shard windows")?;
            s.idle_windows = cur.u64("shard idle_windows")?;
            s.pool.fresh_reqs = cur.u64("pool fresh_reqs")?;
            s.pool.fresh_rsps = cur.u64("pool fresh_rsps")?;
            s.pool.reused_reqs = cur.u64("pool reused_reqs")?;
            s.pool.reused_rsps = cur.u64("pool reused_rsps")?;
            let idle_reqs = cur.u64("pool idle_reqs")? as usize;
            let idle_rsps = cur.u64("pool idle_rsps")? as usize;
            // Box contents are irrelevant (overwritten on reuse); only
            // the idle counts drive behavior (barrier rebalancing).
            for _ in 0..idle_reqs {
                s.pool.push_req_box(Box::default());
            }
            for _ in 0..idle_rsps {
                s.pool.push_rsp_box(Box::default());
            }
        }
        for si in 0..n {
            let count = cur.u64("queue event count")? as usize;
            if count > cur.b.len() {
                return Err(format!(
                    "shard {si} queue event count {count} exceeds the input size"
                ));
            }
            let s = &mut self.shards[si];
            for i in 0..count {
                let ev = f::read_event(cur, &format!("shard {si} event {i}"))?;
                s.queue.push(ev);
            }
        }
        let n_links = cur.u64("link count")? as usize;
        if n_links != self.tables.link_loc.len() {
            return Err(format!(
                "snapshot has {n_links} links, this topology wires {} — the \
                 configurations differ",
                self.tables.link_loc.len()
            ));
        }
        for i in 0..n_links {
            let name = cur.str("link name")?;
            let loc = self.tables.link_loc[i];
            let l = &mut self.shards[loc.shard as usize].links[loc.idx as usize];
            if name != l.name {
                return Err(format!(
                    "snapshot link {i} is '{name}', this topology wires '{}' — the \
                     configurations differ",
                    l.name
                ));
            }
            l.load_state(cur)?;
        }
        let n_comps = cur.u64("component count")? as usize;
        if n_comps != self.tables.comp_loc.len() {
            return Err(format!(
                "snapshot has {n_comps} components, this topology registers {} — the \
                 configurations differ",
                self.tables.comp_loc.len()
            ));
        }
        for i in 0..n_comps {
            let name = cur.str("component name")?;
            let len = cur.u64("component state length")? as usize;
            let start = cur.i;
            let c = self.component_mut(CompId(i as u32));
            if name != c.name() {
                return Err(format!(
                    "snapshot component {i} is '{name}', this topology registers '{}' — \
                     the configurations differ",
                    c.name()
                ));
            }
            c.load_state(cur)
                .map_err(|e| format!("restoring component '{name}': {e}"))?;
            if cur.i != start + len {
                return Err(format!(
                    "component '{name}' consumed {} state bytes, the snapshot recorded {len}",
                    cur.i - start
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ping-pong pair: A sends to B, B replies, N rounds.
    struct Pinger {
        name: String,
        peer: CompId,
        link: LinkId,
        remaining: u32,
        received: u32,
        last_seen: Cycle,
    }

    impl Component for Pinger {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
            match msg {
                Msg::Tick => {
                    self.received += 1;
                    self.last_seen = now;
                    if self.remaining > 0 {
                        self.remaining -= 1;
                        ctx.send(self.link, self.peer, 64, Msg::Tick);
                    }
                }
                _ => unreachable!(),
            }
        }
    }

    fn pinger(name: &str, peer: CompId, link: LinkId, remaining: u32) -> Box<Pinger> {
        Box::new(Pinger {
            name: name.into(),
            peer,
            link,
            remaining,
            received: 0,
            last_seen: 0,
        })
    }

    #[test]
    fn ping_pong_round_trip_timing() {
        let mut e = Engine::new();
        let l_ab = e.add_link(Link::new("a->b", 10, 64));
        let l_ba = e.add_link(Link::new("b->a", 10, 64));
        // Ids are assigned in insertion order; pre-compute them.
        let a_id = CompId(0);
        let b_id = CompId(1);
        e.add(pinger("a", b_id, l_ab, 3));
        e.add(pinger("b", a_id, l_ba, 3));
        e.post(0, a_id, Msg::Tick);
        let end = e.run_to_completion();
        // Each hop: 1 cycle serialization + 10 latency = 11.
        // a@0 -> b@11 -> a@22 -> b@33 -> a@44 -> b@55 -> a@66: a sent 3, b sent 3.
        assert_eq!(end, 66);
        assert_eq!(e.events_processed(), 7);
    }

    #[test]
    fn run_with_limit_pauses_and_resumes() {
        let mut e = Engine::new();
        let l = e.add_link(Link::wire("w", 100));
        let a_id = CompId(0);
        let b_id = CompId(1);
        e.add(pinger("a", b_id, l, 5));
        e.add(pinger("b", a_id, l, 5));
        e.post(0, a_id, Msg::Tick);
        let t = e.run(150);
        assert_eq!(t, 150);
        assert!(!e.is_idle());
        let end = e.run_to_completion();
        assert!(end > 150);
        assert!(e.is_idle());
    }

    #[test]
    fn determinism_same_seed_same_trace() {
        let build_and_run = || {
            let mut e = Engine::new();
            let l = e.add_link(Link::new("l", 7, 16));
            let a_id = CompId(0);
            let b_id = CompId(1);
            e.add(pinger("a", b_id, l, 100));
            e.add(pinger("b", a_id, l, 100));
            e.post(0, a_id, Msg::Tick);
            let end = e.run_to_completion();
            (end, e.events_processed(), e.link(l).bytes_sent)
        };
        assert_eq!(build_and_run(), build_and_run());
    }

    /// The same ping-pong wiring split across two shards (link latency
    /// 10 supports lookahead up to 11) must reproduce the single-shard
    /// timing exactly: all traffic is link-modelled, so the conservative
    /// windows are invisible.
    fn sharded_ping_pong(threads: usize) -> (Cycle, u64, u32, u32) {
        let mut e = Engine::sharded(2, 11);
        let l_ab = e.add_link_to(0, Link::new("a->b", 10, 64));
        let l_ba = e.add_link_to(1, Link::new("b->a", 10, 64));
        let a_id = CompId(0);
        let b_id = CompId(1);
        e.add_to(0, pinger("a", b_id, l_ab, 3));
        e.add_to(1, pinger("b", a_id, l_ba, 3));
        e.set_threads(threads);
        e.post(0, a_id, Msg::Tick);
        let end = e.run_to_completion();
        let a = e.downcast::<Pinger>(a_id);
        let b = e.downcast::<Pinger>(b_id);
        (end, e.events_processed(), a.received, b.received)
    }

    #[test]
    fn cross_shard_link_traffic_matches_single_shard_timing() {
        for threads in [1, 2, 4] {
            assert_eq!(sharded_ping_pong(threads), (66, 7, 4, 3), "threads={threads}");
        }
    }

    #[test]
    fn cross_shard_control_message_quantizes_to_window_barrier() {
        /// Schedules a zero-delay hop to a peer in another shard.
        struct Teleporter {
            name: String,
            peer: CompId,
            fire: bool,
            pub got_at: Option<Cycle>,
        }
        impl Component for Teleporter {
            crate::impl_component_any!();
            fn name(&self) -> &str {
                &self.name
            }
            fn handle(&mut self, now: Cycle, _msg: Msg, ctx: &mut Ctx) {
                self.got_at = Some(now);
                if self.fire {
                    self.fire = false;
                    let peer = self.peer;
                    ctx.schedule(0, peer, Msg::Tick);
                }
            }
        }
        let run = |threads: usize| {
            let mut e = Engine::sharded(2, 8);
            let a = CompId(0);
            let b = CompId(1);
            let ta = Teleporter { name: "a".into(), peer: b, fire: true, got_at: None };
            let tb = Teleporter { name: "b".into(), peer: a, fire: false, got_at: None };
            e.add_to(0, Box::new(ta));
            e.add_to(1, Box::new(tb));
            e.set_threads(threads);
            e.post(3, a, Msg::Tick);
            // Seed shard 1 too: with both shards active the planner
            // opens a real window (solo mode would otherwise deliver
            // the hop at its natural time — see the solo test below).
            e.post(3, b, Msg::Tick);
            e.run_to_completion();
            (e.downcast::<Teleporter>(a).got_at, e.downcast::<Teleporter>(b).got_at)
        };
        // The window opens at T=3 and spans the base lookahead of 8
        // cycles (no declared links); the zero-delay cross-shard hop
        // lands at the barrier, cycle 11.
        for threads in [1, 2] {
            assert_eq!(run(threads), (Some(3), Some(11)), "threads={threads}");
        }
    }

    #[test]
    fn solo_shard_delivers_control_hops_at_natural_time() {
        /// Schedules a zero-delay hop to a peer in another shard.
        struct Teleporter {
            name: String,
            peer: CompId,
            fire: bool,
            pub got_at: Option<Cycle>,
        }
        impl Component for Teleporter {
            crate::impl_component_any!();
            fn name(&self) -> &str {
                &self.name
            }
            fn handle(&mut self, now: Cycle, _msg: Msg, ctx: &mut Ctx) {
                self.got_at = Some(now);
                if self.fire {
                    self.fire = false;
                    let peer = self.peer;
                    ctx.schedule(0, peer, Msg::Tick);
                }
            }
        }
        let run = |threads: usize| {
            let mut e = Engine::sharded(2, 8);
            let a = CompId(0);
            let b = CompId(1);
            let ta = Teleporter { name: "a".into(), peer: b, fire: true, got_at: None };
            let tb = Teleporter { name: "b".into(), peer: a, fire: false, got_at: None };
            e.add_to(0, Box::new(ta));
            e.add_to(1, Box::new(tb));
            e.set_threads(threads);
            e.post(3, a, Msg::Tick);
            e.run_to_completion();
            (e.downcast::<Teleporter>(a).got_at, e.downcast::<Teleporter>(b).got_at)
        };
        // Shard 1 is drained, so shard 0 runs solo and its cross-shard
        // hop closes the window early, keeping its natural time 3 — no
        // quantization to the cycle-11 barrier.
        for threads in [1, 2] {
            assert_eq!(run(threads), (Some(3), Some(3)), "threads={threads}");
        }
    }

    #[test]
    #[should_panic(expected = "cross-shard link delivery")]
    fn lookahead_wider_than_a_cross_link_is_rejected() {
        // Undeclared link of latency 2 (delivery delta 3) under base
        // lookahead 10: the first cross-shard send inside a real window
        // must trip the conservative-window check. Both shards are
        // seeded so a window actually opens (a solo shard has no
        // window to violate).
        let mut e = Engine::sharded(2, 10);
        let l = e.add_link_to(0, Link::new("bad", 2, 64));
        let b = CompId(1);
        e.add_to(0, pinger("a", b, l, 1));
        e.add_to(1, pinger("b", CompId(0), l, 0));
        e.post(0, CompId(0), Msg::Tick);
        e.post(0, b, Msg::Tick);
        e.run_to_completion();
    }

    #[test]
    fn declared_links_shrink_windows_to_the_pair_minimum() {
        // Base lookahead is a huge ceiling; the declared links (latency
        // 10, delta 11) alone must size the windows, reproducing the
        // sequential timing exactly. Both shards are seeded so real
        // (non-solo) windows are planned from the matrix.
        let sharded = |threads: usize| {
            let mut e = Engine::sharded(2, 1_000_000);
            let l_ab = e.add_link_between(0, 1, Link::new("a->b", 10, 64));
            let l_ba = e.add_link_between(1, 0, Link::new("b->a", 10, 64));
            assert_eq!(e.pair_lookahead(0, 1), Some(11));
            assert_eq!(e.pair_lookahead(1, 0), Some(11));
            let a_id = CompId(0);
            let b_id = CompId(1);
            e.add_to(0, pinger("a", b_id, l_ab, 3));
            e.add_to(1, pinger("b", a_id, l_ba, 3));
            e.set_threads(threads);
            e.post(0, a_id, Msg::Tick);
            e.post(0, b_id, Msg::Tick);
            let end = e.run_to_completion();
            (end, e.events_processed())
        };
        let sequential = {
            let mut e = Engine::new();
            let l_ab = e.add_link(Link::new("a->b", 10, 64));
            let l_ba = e.add_link(Link::new("b->a", 10, 64));
            let a_id = CompId(0);
            let b_id = CompId(1);
            e.add(pinger("a", b_id, l_ab, 3));
            e.add(pinger("b", a_id, l_ba, 3));
            e.post(0, a_id, Msg::Tick);
            e.post(0, b_id, Msg::Tick);
            let end = e.run_to_completion();
            (end, e.events_processed())
        };
        for threads in [1, 2] {
            assert_eq!(sharded(threads), sequential, "threads={threads}");
        }
    }

    #[test]
    #[should_panic(expected = "use add_link_to for shard-local links")]
    fn declaring_a_link_to_the_same_shard_is_rejected() {
        let mut e = Engine::sharded(2, 10);
        e.add_link_between(0, 0, Link::new("self", 5, 64));
    }

    #[test]
    #[should_panic(expected = "declared after the engine ran")]
    fn declaring_a_link_after_running_is_rejected() {
        let mut e = Engine::sharded(2, 10);
        let a = CompId(0);
        e.add_to(0, pinger("a", CompId(1), LinkId(0), 0));
        e.add_to(1, pinger("b", a, LinkId(0), 0));
        e.post(0, a, Msg::Tick);
        e.run_to_completion();
        e.add_link_between(0, 1, Link::new("late", 10, 64));
    }

    #[test]
    fn occupancy_counters_fold_to_the_engine_totals() {
        let mut e = Engine::sharded(2, 11);
        let l_ab = e.add_link_between(0, 1, Link::new("a->b", 10, 64));
        let l_ba = e.add_link_between(1, 0, Link::new("b->a", 10, 64));
        let a_id = CompId(0);
        let b_id = CompId(1);
        e.add_to(0, pinger("a", b_id, l_ab, 3));
        e.add_to(1, pinger("b", a_id, l_ba, 3));
        e.post(0, a_id, Msg::Tick);
        e.run_to_completion();
        let occ = e.shard_occupancy();
        assert_eq!(occ.len(), 2);
        assert_eq!(occ.iter().map(|o| o.events).sum::<u64>(), e.events_processed());
        assert!(occ.iter().all(|o| o.windows >= 1), "every shard ran windows: {occ:?}");
        assert!(
            occ.iter().all(|o| o.idle_windows <= o.windows),
            "idle windows are a subset: {occ:?}"
        );
    }

    /// Requester/responder pair exercising the pooled Req/Rsp path.
    struct Requester {
        name: String,
        responder: CompId,
        remaining: u32,
    }
    impl Component for Requester {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
            if let Msg::Rsp(b) = msg {
                let rsp = ctx.reclaim_rsp(b);
                assert_eq!(rsp.data.len(), 64);
            }
            if self.remaining > 0 {
                self.remaining -= 1;
                let req = MemReq {
                    id: self.remaining as u64,
                    addr: 0x40,
                    size: 4,
                    src: ctx.self_id,
                    dst: self.responder,
                    ..MemReq::default()
                };
                let target = self.responder;
                let msg = ctx.req_msg(req);
                ctx.schedule(3, target, msg);
            }
        }
    }
    struct Responder {
        name: String,
    }
    impl Component for Responder {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
            let Msg::Req(b) = msg else { unreachable!() };
            let req = ctx.reclaim_req(b);
            let rsp = MemRsp {
                id: req.id,
                kind: req.kind,
                addr: req.addr,
                dst: req.src,
                data: crate::mem::LineBuf::zeroed(64),
                ts: None,
            };
            let target = req.src;
            let msg = ctx.rsp_msg(rsp);
            ctx.schedule(5, target, msg);
        }
    }

    #[test]
    fn pooled_boxes_recycle_across_transactions() {
        let mut e = Engine::new();
        let req_id = CompId(0);
        let rsp_id = CompId(1);
        e.add(Box::new(Requester { name: "rq".into(), responder: rsp_id, remaining: 1000 }));
        e.add(Box::new(Responder { name: "rs".into() }));
        e.post(0, req_id, Msg::Tick);
        e.run_to_completion();
        let p = e.pool();
        // One transaction in flight at a time: one box of each kind,
        // reused for every subsequent round trip.
        assert_eq!(p.fresh_reqs, 1, "req boxes must recycle: {}", p.fresh_reqs);
        assert_eq!(p.fresh_rsps, 1, "rsp boxes must recycle: {}", p.fresh_rsps);
        assert_eq!(p.reused_reqs, 999);
        assert_eq!(p.reused_rsps, 999);
    }

    #[test]
    fn pool_counters_aggregate_across_shards() {
        // Requester/responder in different shards: boxes are pooled at
        // the sender and reclaimed at the receiver, and the barrier
        // rebalancer walks them back — after a short warm-up every
        // transaction reuses boxes instead of allocating.
        let mut e = Engine::sharded(2, 1);
        let req_id = CompId(0);
        let rsp_id = CompId(1);
        e.add_to(0, Box::new(Requester { name: "rq".into(), responder: rsp_id, remaining: 10 }));
        e.add_to(1, Box::new(Responder { name: "rs".into() }));
        e.post(0, req_id, Msg::Tick);
        e.run_to_completion();
        let c = e.pool_counters();
        // 10 requests + 10 responses were boxed in total.
        assert_eq!(c.fresh() + c.reused(), 20);
        assert!(c.fresh() <= 4, "cross-shard boxes must recycle: {c:?}");
        assert!(c.reused() >= 16, "cross-shard boxes must recycle: {c:?}");
        assert_eq!(e.events_processed(), 21);
    }
}
