//! Free-list recycling for boxed memory-transaction messages.
//!
//! `Msg::Req`/`Msg::Rsp` stay boxed so `Msg` remains pointer-sized in the
//! scheduler (see `sim/msg.rs`), but the boxes themselves are recycled
//! through per-shard pools instead of hitting the allocator twice per
//! transaction; the window planner evens the pools out at each barrier
//! so boxes reclaimed on another shard flow back to their senders
//! (`sim/shard.rs`). Combined with the inline [`LineBuf`] payloads
//! (`mem/linebuf.rs`), a steady-state run performs no allocation in the
//! event hot loop (asserted by `tests/alloc_discipline.rs`).
//!
//! Protocol: senders build messages with [`Ctx::req_msg`]/[`Ctx::rsp_msg`]
//! (which fill a pooled box); receivers move the value out with
//! [`Ctx::reclaim_req`]/[`Ctx::reclaim_rsp`] (which return the box to the
//! pool). Plain `Msg::Req(Box::new(..))` still works everywhere — tests
//! and one-shot setup paths simply bypass recycling.
//!
//! [`Ctx::req_msg`]: crate::sim::engine::Ctx::req_msg
//! [`Ctx::rsp_msg`]: crate::sim::engine::Ctx::rsp_msg
//! [`Ctx::reclaim_req`]: crate::sim::engine::Ctx::reclaim_req
//! [`Ctx::reclaim_rsp`]: crate::sim::engine::Ctx::reclaim_rsp
//! [`LineBuf`]: crate::mem::LineBuf

use crate::sim::msg::{MemReq, MemRsp, Msg};

/// Free-list cap per message kind; beyond this, reclaimed boxes are
/// simply dropped (bounds pool memory if a phase bursts).
const POOL_CAP: usize = 4096;

/// Engine-owned free lists for `Box<MemReq>` / `Box<MemRsp>`.
#[derive(Default)]
pub struct MsgPool {
    reqs: Vec<Box<MemReq>>,
    rsps: Vec<Box<MemRsp>>,
    /// Boxes taken from the allocator (perf diagnostics; a healthy
    /// steady state stops growing these).
    pub fresh_reqs: u64,
    pub fresh_rsps: u64,
    /// Boxes served from the free list.
    pub reused_reqs: u64,
    pub reused_rsps: u64,
}

impl MsgPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Box `req` (recycling a pooled box when available) as a `Msg`.
    #[inline]
    pub fn req(&mut self, req: MemReq) -> Msg {
        match self.reqs.pop() {
            Some(mut b) => {
                self.reused_reqs += 1;
                *b = req;
                Msg::Req(b)
            }
            None => {
                self.fresh_reqs += 1;
                Msg::Req(Box::new(req))
            }
        }
    }

    /// Box `rsp` (recycling a pooled box when available) as a `Msg`.
    #[inline]
    pub fn rsp(&mut self, rsp: MemRsp) -> Msg {
        match self.rsps.pop() {
            Some(mut b) => {
                self.reused_rsps += 1;
                *b = rsp;
                Msg::Rsp(b)
            }
            None => {
                self.fresh_rsps += 1;
                Msg::Rsp(Box::new(rsp))
            }
        }
    }

    /// Copy the request out of its box and return the box to the pool.
    /// (`MemReq` is `Copy`, so the deref reads without consuming the box.)
    #[inline]
    pub fn reclaim_req(&mut self, b: Box<MemReq>) -> MemReq {
        let v = *b;
        if self.reqs.len() < POOL_CAP {
            self.reqs.push(b);
        }
        v
    }

    /// Copy the response out of its box and return the box to the pool.
    #[inline]
    pub fn reclaim_rsp(&mut self, b: Box<MemRsp>) -> MemRsp {
        let v = *b;
        if self.rsps.len() < POOL_CAP {
            self.rsps.push(b);
        }
        v
    }

    /// Free boxes currently pooled (tests/diagnostics).
    pub fn idle(&self) -> (usize, usize) {
        (self.reqs.len(), self.rsps.len())
    }

    // ---- Barrier rebalancing (sharded engine).
    //
    // Cross-shard transactions box a message in the sender's pool and
    // reclaim it into the receiver's: request boxes drift toward
    // responders, response boxes toward requesters. The window planner
    // moves idle boxes back between pools at each barrier
    // (`sim::shard`), keeping the steady state allocation-free. The
    // raw box moves below bypass the fresh/reused counters — they are
    // transfers, not (re)uses.

    pub(crate) fn idle_reqs(&self) -> usize {
        self.reqs.len()
    }

    pub(crate) fn idle_rsps(&self) -> usize {
        self.rsps.len()
    }

    pub(crate) fn pop_req_box(&mut self) -> Option<Box<MemReq>> {
        self.reqs.pop()
    }

    pub(crate) fn pop_rsp_box(&mut self) -> Option<Box<MemRsp>> {
        self.rsps.pop()
    }

    pub(crate) fn push_req_box(&mut self, b: Box<MemReq>) {
        if self.reqs.len() < POOL_CAP {
            self.reqs.push(b);
        }
    }

    pub(crate) fn push_rsp_box(&mut self, b: Box<MemRsp>) {
        if self.rsps.len() < POOL_CAP {
            self.rsps.push(b);
        }
    }
}

/// Pool counters summed across the engine's shards
/// ([`crate::sim::Engine::pool_counters`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    pub fresh_reqs: u64,
    pub fresh_rsps: u64,
    pub reused_reqs: u64,
    pub reused_rsps: u64,
}

impl PoolCounters {
    pub fn add(&mut self, p: &MsgPool) {
        self.fresh_reqs += p.fresh_reqs;
        self.fresh_rsps += p.fresh_rsps;
        self.reused_reqs += p.reused_reqs;
        self.reused_rsps += p.reused_rsps;
    }

    /// Boxes taken from the allocator (both kinds).
    pub fn fresh(&self) -> u64 {
        self.fresh_reqs + self.fresh_rsps
    }

    /// Boxes served from a free list (both kinds).
    pub fn reused(&self) -> u64 {
        self.reused_reqs + self.reused_rsps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn some_req(id: u64) -> MemReq {
        MemReq { id, ..MemReq::default() }
    }

    #[test]
    fn reclaimed_boxes_are_reused() {
        let mut p = MsgPool::new();
        let m = p.req(some_req(1));
        assert_eq!(p.fresh_reqs, 1);
        let Msg::Req(b) = m else { panic!() };
        let v = p.reclaim_req(b);
        assert_eq!(v.id, 1);
        assert_eq!(p.idle(), (1, 0));
        let m2 = p.req(some_req(2));
        assert_eq!(p.fresh_reqs, 1, "second box must come from the pool");
        assert_eq!(p.reused_reqs, 1);
        let Msg::Req(b2) = m2 else { panic!() };
        assert_eq!(b2.id, 2);
    }

    #[test]
    fn rsp_pool_is_independent() {
        let mut p = MsgPool::new();
        let m = p.rsp(MemRsp::default());
        let Msg::Rsp(b) = m else { panic!() };
        p.reclaim_rsp(b);
        assert_eq!(p.idle(), (0, 1));
        assert_eq!((p.fresh_reqs, p.fresh_rsps), (0, 1));
    }
}
