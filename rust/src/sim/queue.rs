//! Two-level bucketed calendar queue: the event scheduler.
//!
//! The global `BinaryHeap<Event>` paid an O(log n) sift per push *and*
//! per pop, moving whole `Event` structs each level (§Perf log). Almost
//! every event in this simulator is scheduled a short, bounded distance
//! into the future (link latencies, cache latencies, the 100-cycle MC
//! access), so a calendar layout makes both operations O(1) amortized:
//!
//! * a **near-future ring** of [`RING`] per-cycle FIFO buckets covering
//!   `[cur, cur + RING)` — push appends to `bucket[time % RING]`, pop
//!   reads the front of `bucket[cur % RING]`;
//! * a **sorted overflow** heap for the rare far-future event (fence
//!   posts, RDMA copy-phase delays). As `cur` advances, overflow events
//!   whose time enters the window migrate into their ring bucket
//!   *before* any new same-cycle push can land there.
//!
//! # Ordering contract
//!
//! Pops occur in exactly the `(time, seq)` order of [`Event::cmp`] — the
//! same order the reference `BinaryHeap` produced (property-tested in
//! `tests/unit_properties.rs`). Each per-cycle bucket is kept seq-sorted
//! on insert: a push appends when its seq exceeds the bucket tail (the
//! overwhelmingly common case — a shard assigns its sequence numbers
//! monotonically, so local pushes and overflow migrations arrive in seq
//! order) and otherwise binary-searches its slot. The out-of-order path
//! exists for the sharded engine (`sim/shard.rs`): events merged in at a
//! window barrier carry their *origin* shard's seq tag, which can order
//! before same-cycle events already queued locally.

use std::collections::{BinaryHeap, VecDeque};

use crate::sim::msg::Event;
use crate::sim::Cycle;

/// Ring span in cycles (power of two for cheap modulo). Covers every
/// latency in the system model (max ~300-cycle PCIe hop) with two orders
/// of magnitude to spare.
const RING: usize = 1 << 12;

/// The engine's event queue. See the module docs for the layout.
pub struct EventQueue {
    /// `RING` per-cycle FIFO buckets; `buckets[t % RING]` holds only
    /// events for the single cycle `t` within the current window.
    buckets: Vec<VecDeque<Event>>,
    /// Events currently resident in the ring.
    ring_len: usize,
    /// Window start: no un-popped event precedes this cycle (except
    /// misuse, see `next_time`).
    cur: Cycle,
    /// Far-future events (`time >= cur + RING`), earliest on top.
    overflow: BinaryHeap<Event>,
    /// Total events queued.
    len: usize,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            // Seed each bucket with a little capacity so the steady state
            // allocates nothing (the zero-alloc contract in
            // `tests/alloc_discipline.rs`).
            buckets: (0..RING).map(|_| VecDeque::with_capacity(2)).collect(),
            ring_len: 0,
            cur: 0,
            overflow: BinaryHeap::with_capacity(64),
            len: 0,
        }
    }

    #[inline]
    fn bucket_of(time: Cycle) -> usize {
        (time & (RING as u64 - 1)) as usize
    }

    /// Place an in-window event into its per-cycle bucket, keeping the
    /// bucket seq-sorted. Append is the fast path; the sorted insert
    /// only triggers for cross-shard barrier deliveries whose origin
    /// shard tag orders before already-queued same-cycle events.
    #[inline]
    fn ring_insert(&mut self, ev: Event) {
        self.ring_len += 1;
        let b = &mut self.buckets[Self::bucket_of(ev.time)];
        match b.back() {
            Some(last) if last.seq > ev.seq => {
                let pos = b.partition_point(|e| e.seq < ev.seq);
                b.insert(pos, ev);
            }
            _ => b.push_back(ev),
        }
    }

    /// Enqueue; O(1) for the in-window common case.
    #[inline]
    pub fn push(&mut self, ev: Event) {
        self.len += 1;
        if ev.time >= self.cur && ev.time - self.cur < RING as u64 {
            self.ring_insert(ev);
        } else {
            // Far future — or behind `cur` (a cross-shard delivery into a
            // cycle the local cursor already overshot, or scheduler
            // misuse; the heap keeps reference `(time, seq)` ordering).
            self.overflow.push(ev);
        }
    }

    /// Time of the earliest queued event, positioning the window on it.
    /// Mutates internal cursors/migration state but never the dequeue
    /// order — `run(limit)` peeks with this and pauses without the
    /// pop/push churn the heap version paid.
    pub fn next_time(&mut self) -> Option<Cycle> {
        if self.len == 0 {
            return None;
        }
        loop {
            // An event scheduled into the past (misuse) is the global
            // minimum; serve it straight from the overflow heap.
            if let Some(top) = self.overflow.peek() {
                if top.time < self.cur {
                    return Some(top.time);
                }
            }
            // Migrate overflow events whose cycle entered the window.
            // `ring_insert` keeps each bucket seq-sorted, so migrations
            // and direct pushes interleave in any order.
            while let Some(top) = self.overflow.peek() {
                if top.time - self.cur >= RING as u64 {
                    break;
                }
                let ev = self.overflow.pop().unwrap();
                self.ring_insert(ev);
            }
            if !self.buckets[Self::bucket_of(self.cur)].is_empty() {
                return Some(self.cur);
            }
            if self.ring_len > 0 {
                // Some bucket ahead is non-empty; it is at most RING-1
                // cycles away (all ring events lie inside the window).
                self.cur += 1;
            } else {
                match self.overflow.peek() {
                    // Empty ring: jump straight to the next far event.
                    Some(top) => self.cur = top.time,
                    None => return None,
                }
            }
        }
    }

    /// Dequeue the earliest event (`(time, seq)` order).
    pub fn pop(&mut self) -> Option<Event> {
        // Fast path: the engine's run loop peeks with `next_time()`
        // right before popping, so the window is usually already
        // positioned on a non-empty bucket. `next_time` migrates every
        // in-window overflow event before returning, so a non-empty
        // current bucket holds the global minimum — unless a
        // behind-window event sits in the overflow heap (a cross-shard
        // barrier delivery behind an overshot cursor, or misuse), which
        // the guard preserves in reference-heap order.
        if self.overflow.peek().is_none_or(|top| top.time >= self.cur) {
            if let Some(ev) = self.buckets[Self::bucket_of(self.cur)].pop_front() {
                self.ring_len -= 1;
                self.len -= 1;
                return Some(ev);
            }
        }
        let t = self.next_time()?;
        self.len -= 1;
        if t < self.cur {
            return self.overflow.pop();
        }
        let ev = self.buckets[Self::bucket_of(self.cur)].pop_front();
        debug_assert!(ev.is_some(), "next_time pointed at an empty bucket");
        self.ring_len -= 1;
        ev
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::CompId;
    use crate::sim::msg::Msg;

    fn ev(time: Cycle, seq: u64) -> Event {
        Event { time, seq, target: CompId(0), msg: Msg::Tick }
    }

    fn drain(q: &mut EventQueue) -> Vec<(Cycle, u64)> {
        std::iter::from_fn(|| q.pop().map(|e| (e.time, e.seq))).collect()
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = EventQueue::new();
        q.push(ev(5, 0));
        q.push(ev(3, 2));
        q.push(ev(3, 1));
        q.push(ev(7, 3));
        assert_eq!(drain(&mut q), vec![(3, 1), (3, 2), (5, 0), (7, 3)]);
    }

    #[test]
    fn far_future_overflow_merges_with_ring_events() {
        let mut q = EventQueue::new();
        q.push(ev(1_000_000, 0)); // overflow
        q.push(ev(10, 1)); // ring
        q.push(ev(1_000_000, 2)); // overflow, same cycle as seq 0
        assert_eq!(q.len(), 3);
        assert_eq!(drain(&mut q), vec![(10, 1), (1_000_000, 0), (1_000_000, 2)]);
        assert!(q.is_empty());
    }

    #[test]
    fn same_cycle_push_during_drain_pops_after() {
        let mut q = EventQueue::new();
        q.push(ev(4, 0));
        assert_eq!(q.next_time(), Some(4));
        let first = q.pop().unwrap();
        assert_eq!((first.time, first.seq), (4, 0));
        // A handler scheduling at delay 0 lands behind the cursor.
        q.push(ev(4, 1));
        q.push(ev(4, 2));
        assert_eq!(drain(&mut q), vec![(4, 1), (4, 2)]);
    }

    #[test]
    fn overflow_migrates_before_direct_pushes_same_cycle() {
        let mut q = EventQueue::new();
        let far = RING as u64 + 50;
        q.push(ev(far, 0)); // beyond the initial window -> overflow
        q.push(ev(100, 1));
        // Popping (100, 1) slides the window past cycle 50, so `far`
        // enters it and seq 0 migrates into its bucket.
        let first = q.pop().unwrap();
        assert_eq!((first.time, first.seq), (100, 1));
        // A direct push to the same (now in-window) cycle lands behind
        // the migrated event despite arriving later.
        q.push(ev(far, 2));
        assert_eq!(drain(&mut q), vec![(far, 0), (far, 2)]);
    }

    #[test]
    fn peek_is_stable_and_does_not_reorder() {
        let mut q = EventQueue::new();
        q.push(ev(9, 0));
        q.push(ev(2, 1));
        assert_eq!(q.next_time(), Some(2));
        assert_eq!(q.next_time(), Some(2));
        assert_eq!(q.len(), 2);
        assert_eq!(drain(&mut q), vec![(2, 1), (9, 0)]);
    }

    #[test]
    fn out_of_order_seq_within_a_cycle_sorts_on_insert() {
        // Cross-shard barrier deliveries carry foreign shard tags in the
        // high seq bits, so same-cycle pushes are not seq-monotone.
        let mut q = EventQueue::new();
        let tag = |shard: u64, ctr: u64| (shard << 40) | ctr;
        q.push(ev(10, tag(2, 0)));
        q.push(ev(10, tag(0, 5))); // lower shard tag arrives later
        q.push(ev(10, tag(2, 1)));
        q.push(ev(10, tag(1, 0)));
        q.push(ev(9, tag(3, 0))); // different cycle unaffected
        assert_eq!(
            drain(&mut q),
            vec![
                (9, tag(3, 0)),
                (10, tag(0, 5)),
                (10, tag(1, 0)),
                (10, tag(2, 0)),
                (10, tag(2, 1)),
            ]
        );
    }

    #[test]
    fn delivery_behind_an_overshot_cursor_still_pops_in_order() {
        // A shard's window cursor can run ahead to its next local event
        // (say t=500) before a barrier delivers a cross-shard event for
        // an earlier cycle (t=120 >= the window end). The late arrival
        // must pop first, in exact (time, seq) order.
        let mut q = EventQueue::new();
        q.push(ev(10, 0));
        q.push(ev(500, 1));
        let first = q.pop().unwrap();
        assert_eq!((first.time, first.seq), (10, 0));
        // Peeking positions the cursor on the t=500 event...
        assert_eq!(q.next_time(), Some(500));
        // ...and only then do the barrier deliveries land behind it.
        q.push(ev(120, 3));
        q.push(ev(120, 2));
        assert_eq!(drain(&mut q), vec![(120, 2), (120, 3), (500, 1)]);
    }

    #[test]
    fn empty_queue_reports_none() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.next_time(), None);
        assert!(q.pop().is_none());
        q.push(ev(1, 0));
        q.pop();
        assert_eq!(q.next_time(), None);
    }

    #[test]
    fn sparse_long_gaps_jump_instead_of_scanning() {
        let mut q = EventQueue::new();
        let mut seq = 0;
        let mut t = 0u64;
        for _ in 0..100 {
            t += 123_456; // far beyond the ring every time
            q.push(ev(t, seq));
            seq += 1;
        }
        let order = drain(&mut q);
        assert_eq!(order.len(), 100);
        assert!(order.windows(2).all(|w| w[0].0 < w[1].0));
    }
}
