//! Logical shards and the conservative time-window runner.
//!
//! The component graph is partitioned into *logical shards* (the
//! coordinator uses one per GPU plus a hub for the switch complex, TSU
//! stacks and driver — see `coordinator::topology`). Each shard owns its
//! own [`EventQueue`], [`MsgPool`], link table and sequence counter; the
//! engine advances all shards in lock-step conservative windows sized by
//! a **per-shard-pair lookahead matrix** ([`Lookahead`], derived from the
//! cross-shard links declared with `Engine::add_link_between`):
//!
//! 1. **plan** — route the previous window's cross-shard traffic from
//!    per-shard outboxes into the destination queues, then position the
//!    next window `[T, E)` adaptively: `E = min(T + base, min over
//!    non-empty shards i of (t_i + row_min(i)))`, where `t_i` is shard
//!    `i`'s next event time and `row_min(i)` is the smallest
//!    `latency + 1` over its declared outgoing cross-shard links
//!    (unbounded when it has none — such a shard can only emit
//!    barrier-quantized control hops, which are safe at any window).
//!    A shard whose next event lies at or beyond `E` contributes no
//!    events and therefore no constraint, so e.g. an RDMA topology's
//!    301-cycle PCIe floor only applies while a shard that actually owns
//!    a PCIe link is active — the window shrinks to the per-pair minimum
//!    in play. When exactly **one** shard holds events the planner skips
//!    windows entirely (*solo mode*): that shard runs unbounded until its
//!    first cross-shard send, closing the window early when no
//!    cross-shard traffic is pending at all;
//! 2. **run** — every shard independently dispatches its local events
//!    inside the window. Cross-shard sends land in the outbox: link
//!    traffic keeps its exact delivery time (guaranteed `>= T +
//!    lookahead` because every cross-shard link serializes for at least
//!    one cycle before its flight latency); linkless control traffic
//!    (`Ctx::schedule` to another shard, e.g. the driver's kernel-launch
//!    and fence chatter) is quantized up to the window barrier;
//! 3. **barrier** — repeat.
//!
//! # Determinism
//!
//! Event order is `(time, src_shard, seq)`, encoded as a single `u64`
//! (`seq = shard << SEQ_SHARD_BITS | counter`), and the partition is a
//! function of the *configuration*, never of the thread count: `--shards
//! N` only chooses how many OS threads execute the fixed logical shards.
//! Within a window shards cannot interact (conservative lookahead), so
//! any thread schedule dispatches the same per-shard event sequences and
//! produces bit-identical state — the byte-identity contract of
//! `tests/shard_determinism.rs`.
//!
//! The one semantic knob is control-message quantization (step 2): it
//! shifts driver/fence hops to window boundaries by up to the window
//! span. The shift is itself deterministic (window positions depend
//! only on event times and the configuration-derived matrix), applies
//! identically at every shard/thread count, and only touches linkless
//! cross-shard hops — never the link-modelled memory traffic the
//! paper's figures count. In solo mode those hops deliver at their
//! natural time instead: every other shard is drained, so nothing can
//! have raced past the delivery point, and the mode choice itself is a
//! pure function of the queue states at the barrier.
//!
//! # Pause/resume caveat
//!
//! `Engine::run(limit)` pausing mid-window truncates that window at
//! `limit` while quantization still targets the untruncated barrier, so
//! interleaving different `limit`s with multi-shard engines can shift
//! control hops relative to an uninterrupted `run_to_completion`. All
//! campaign/runner paths run to completion in one call; the
//! single-shard fast path (plain `Engine::new`) is unaffected.
//!
//! The snapshot machinery (docs/SNAPSHOT.md) needs a byte-transparent
//! pause, so [`run_windows`] also has an *atomic-window* mode
//! (`Engine::run_until_barrier`): windows always run to their natural
//! `end - 1` bound — never clipped by `limit` — and the pause fires only
//! at a window barrier whose `t_min` exceeds `limit`. Each window is
//! planned fresh from the global `t_min`, so the window sequence (and
//! with it every quantization target) of a paused-then-resumed run is
//! identical to an uninterrupted one.

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex, MutexGuard};

use crate::sim::engine::{Component, Ctx};
use crate::sim::link::Link;
use crate::sim::msg::{Event, Msg};
use crate::sim::pool::MsgPool;
use crate::sim::queue::EventQueue;
use crate::sim::Cycle;

/// Low bits of an event sequence number hold the per-shard counter; the
/// bits above hold the origin shard id, making `(time, seq)` order
/// equivalent to `(time, src_shard, per_shard_seq)` with globally unique
/// sequence numbers. 2^40 events per shard per run is two orders of
/// magnitude beyond the largest paper-grid cell.
pub const SEQ_SHARD_BITS: u32 = 40;

/// The window-planning view of the per-shard-pair lookahead matrix.
///
/// `base` is the fallback/ceiling span (the constructor's lookahead):
/// windows never exceed it, so engines that declare no cross-shard
/// links reproduce the fixed-lookahead behavior exactly, and mixed
/// engines (declared links plus legacy undeclared `add_link_to` links)
/// never open a window wider than the legacy contract allows.
/// `row_min[s]` is the smallest `latency + 1` over shard `s`'s declared
/// outgoing cross-shard links, `Cycle::MAX` when it has none.
pub(crate) struct Lookahead {
    pub base: Cycle,
    pub row_min: Vec<Cycle>,
}

/// Where a globally-numbered component or link lives.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Loc {
    pub shard: u32,
    pub idx: u32,
}

/// Shared read-only routing tables: global `CompId`/`LinkId` -> [`Loc`].
#[derive(Default)]
pub(crate) struct Tables {
    pub comp_loc: Vec<Loc>,
    pub link_loc: Vec<Loc>,
}

/// A cross-shard event parked until the window barrier.
pub(crate) struct OutEvent {
    pub dst: u32,
    pub ev: Event,
}

/// One partition of the component graph with its private scheduler state.
pub struct Shard {
    pub(crate) id: u32,
    pub(crate) queue: EventQueue,
    pub(crate) pool: MsgPool,
    pub(crate) comps: Vec<Option<Box<dyn Component>>>,
    pub(crate) links: Vec<Link>,
    /// Next sequence number; initialized to `id << SEQ_SHARD_BITS`.
    pub(crate) seq: u64,
    /// Time of the last event this shard dispatched.
    pub(crate) now: Cycle,
    pub(crate) events_processed: u64,
    /// Windows this shard executed (occupancy profiling, host-only).
    pub(crate) windows: u64,
    /// Executed windows that dispatched no event (the shard's next
    /// event lay beyond the bound — pure barrier overhead).
    pub(crate) idle_windows: u64,
    pub(crate) outbox: Vec<OutEvent>,
}

impl Shard {
    pub(crate) fn new(id: u32) -> Self {
        Shard {
            id,
            queue: EventQueue::new(),
            pool: MsgPool::new(),
            comps: Vec::new(),
            links: Vec::new(),
            seq: (id as u64) << SEQ_SHARD_BITS,
            now: 0,
            events_processed: 0,
            windows: 0,
            idle_windows: 0,
            outbox: Vec::new(),
        }
    }

    pub(crate) fn next_seq(&mut self) -> u64 {
        let s = self.seq;
        self.seq += 1;
        debug_assert_eq!(
            s >> SEQ_SHARD_BITS,
            self.id as u64,
            "per-shard sequence counter overflowed its tag"
        );
        s
    }

    /// Dispatch every queued event with `time <= bound`.
    ///
    /// `window_end` is the first cycle of the *next* window: cross-shard
    /// link deliveries must land at or after it (asserted in
    /// [`Ctx::send`]) and cross-shard control messages are quantized up
    /// to it. The single-shard fast path passes `Cycle::MAX` (nothing
    /// can cross).
    ///
    /// `stop_on_cross` is solo mode (see the module docs): the shard is
    /// the only one holding events, `window_end` is the window *start*
    /// (cross-shard traffic keeps its natural time — every peer is
    /// drained, so nothing can have raced past it), and dispatch stops
    /// after the first event that parked cross-shard traffic in the
    /// outbox, which re-plans the window at the next barrier.
    pub(crate) fn run_window(
        &mut self,
        bound: Cycle,
        window_end: Cycle,
        tables: &Tables,
        stop_on_cross: bool,
    ) {
        self.windows += 1;
        let entered = self.events_processed;
        while let Some(t) = self.queue.next_time() {
            if t > bound || (stop_on_cross && !self.outbox.is_empty()) {
                break;
            }
            let ev = self.queue.pop().expect("peeked event vanished");
            debug_assert!(ev.time >= self.now, "time went backwards");
            self.now = ev.time;
            self.events_processed += 1;
            let loc = tables.comp_loc[ev.target.0 as usize];
            debug_assert_eq!(loc.shard, self.id, "event routed to the wrong shard");
            let idx = loc.idx as usize;
            let mut comp = self.comps[idx]
                .take()
                .unwrap_or_else(|| panic!("event for unregistered component {:?}", ev.target));
            let mut ctx = Ctx {
                now: self.now,
                shard: self.id,
                window_end,
                seq: &mut self.seq,
                queue: &mut self.queue,
                pool: &mut self.pool,
                links: &mut self.links,
                outbox: &mut self.outbox,
                tables,
                self_id: ev.target,
            };
            comp.handle(self.now, ev.msg, &mut ctx);
            self.comps[idx] = Some(comp);
        }
        if self.events_processed == entered {
            self.idle_windows += 1;
        }
    }
}

/// What the planner decided for the next window.
enum Plan {
    /// Every queue is empty — the run is complete.
    Idle,
    /// The earliest event lies beyond `limit` — pause.
    Paused,
    /// Execute `[T, end)` clipped to `bound = min(end - 1, limit)`.
    Window { bound: Cycle, end: Cycle },
    /// Exactly one shard holds events: run it alone from `start` until
    /// its first cross-shard send (or `bound`), no window ceiling.
    Solo { shard: usize, bound: Cycle, start: Cycle },
}

/// Poison-tolerant lock: a panicking cell is reported through the panic
/// replay below, not hidden behind a poisoned-mutex panic here.
fn lock(m: &Mutex<Shard>) -> MutexGuard<'_, Shard> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Route every shard's outbox into the destination queues. Runs on the
/// serialized planner path between barriers, so it locks each
/// destination shard once per (src, dst) pair rather than once per
/// event. Neither the source order (ascending shard id) nor the
/// in-place unstable sort affects the result: queue buckets order by
/// the globally-unique seq tag on insert.
///
/// Returns whether any pooled message box (`Msg::Req`/`Msg::Rsp`)
/// crossed shards — the only way pool occupancy can become uneven, and
/// therefore the only windows whose barrier needs a rebalance sweep.
fn drain_outboxes(cells: &[Mutex<Shard>]) -> bool {
    let mut boxes_crossed = false;
    for (src, cell) in cells.iter().enumerate() {
        let mut buf = {
            let mut s = lock(cell);
            if s.outbox.is_empty() {
                continue;
            }
            std::mem::take(&mut s.outbox)
        };
        buf.sort_unstable_by_key(|oe| oe.dst);
        let mut it = buf.drain(..).peekable();
        while let Some(first) = it.next() {
            let dst = first.dst;
            debug_assert_ne!(dst as usize, src, "outbox holds a same-shard event");
            let mut d = lock(&cells[dst as usize]);
            boxes_crossed |= matches!(first.ev.msg, Msg::Req(_) | Msg::Rsp(_));
            d.queue.push(first.ev);
            while let Some(oe) = it.next_if(|oe| oe.dst == dst) {
                boxes_crossed |= matches!(oe.ev.msg, Msg::Req(_) | Msg::Rsp(_));
                d.queue.push(oe.ev);
            }
        }
        drop(it);
        // Hand the emptied buffer back so its capacity is reused.
        lock(cell).outbox = buf;
    }
    boxes_crossed
}

/// Even out idle message boxes across the shard pools.
///
/// Cross-shard transactions pool a box at the sender and reclaim it at
/// the receiver, so boxes drift one way (request boxes toward memory,
/// response boxes toward the GPUs) and each sender would hit the
/// allocator on every transaction once its pool ran dry. Redistributing
/// to an even share at the barrier closes the cycle: the steady state
/// moves a handful of pointers per window and allocates nothing. The
/// rule is a function of pool occupancy only, so it is identical at
/// every thread count.
fn rebalance_pools(cells: &[Mutex<Shard>]) {
    let n = cells.len();
    let (mut req_total, mut rsp_total) = (0usize, 0usize);
    for c in cells {
        let s = lock(c);
        req_total += s.pool.idle_reqs();
        rsp_total += s.pool.idle_rsps();
    }
    // Shards 0..rem keep one extra so the totals are conserved.
    let (req_share, req_rem) = (req_total / n, req_total % n);
    let (rsp_share, rsp_rem) = (rsp_total / n, rsp_total % n);
    let mut spare_reqs = Vec::new();
    let mut spare_rsps = Vec::new();
    for (i, c) in cells.iter().enumerate() {
        let mut s = lock(c);
        let req_keep = req_share + usize::from(i < req_rem);
        while s.pool.idle_reqs() > req_keep {
            spare_reqs.push(s.pool.pop_req_box().expect("counted box missing"));
        }
        let rsp_keep = rsp_share + usize::from(i < rsp_rem);
        while s.pool.idle_rsps() > rsp_keep {
            spare_rsps.push(s.pool.pop_rsp_box().expect("counted box missing"));
        }
    }
    for (i, c) in cells.iter().enumerate() {
        if spare_reqs.is_empty() && spare_rsps.is_empty() {
            break;
        }
        let mut s = lock(c);
        let req_keep = req_share + usize::from(i < req_rem);
        while s.pool.idle_reqs() < req_keep {
            match spare_reqs.pop() {
                Some(b) => s.pool.push_req_box(b),
                None => break,
            }
        }
        let rsp_keep = rsp_share + usize::from(i < rsp_rem);
        while s.pool.idle_rsps() < rsp_keep {
            match spare_rsps.pop() {
                Some(b) => s.pool.push_rsp_box(b),
                None => break,
            }
        }
    }
    debug_assert!(spare_reqs.is_empty() && spare_rsps.is_empty(), "rebalance lost boxes");
}

/// `atomic`: never truncate a window at `limit` — run it to its natural
/// `end - 1` and only pause at a barrier whose `t_min` exceeds `limit`.
/// The window sequence is then a pure function of the event times, so a
/// paused-then-resumed run replays the exact windows (and quantization
/// targets) of an uninterrupted one — the snapshot pause contract.
fn plan_window(cells: &[Mutex<Shard>], limit: Cycle, look: &Lookahead, atomic: bool) -> Plan {
    // Rebalance only when a box actually changed shards: occupancy is
    // untouched by local traffic (boxes return to their own pool), so
    // skipping quiet barriers loses nothing. The condition is a
    // deterministic function of the routed events — identical at every
    // thread count.
    if drain_outboxes(cells) {
        rebalance_pools(cells);
    }
    let mut t_min: Option<Cycle> = None;
    // Adaptive ceiling: a shard with events at `t_i` can emit nothing
    // that lands before `t_i + row_min(i)`, so the window may extend to
    // the minimum of those horizons (capped by `base`). Shards with an
    // all-unbounded row (no declared cross links) impose no ceiling —
    // their cross-shard hops quantize to whatever barrier is chosen.
    let mut horizon = Cycle::MAX;
    let mut non_empty = 0usize;
    let mut last_busy = 0usize;
    for (i, c) in cells.iter().enumerate() {
        if let Some(t) = lock(c).queue.next_time() {
            t_min = Some(t_min.map_or(t, |m: Cycle| m.min(t)));
            horizon = horizon.min(t.saturating_add(look.row_min[i]));
            non_empty += 1;
            last_busy = i;
        }
    }
    match t_min {
        None => Plan::Idle,
        Some(t) if t > limit => Plan::Paused,
        Some(t) if non_empty == 1 => Plan::Solo {
            shard: last_busy,
            // Clipped mode respects `limit`; atomic mode runs to the
            // natural stop (first cross-shard send or drain) so the
            // window sequence stays limit-independent.
            bound: if atomic { Cycle::MAX } else { limit },
            start: t,
        },
        Some(t) => {
            let end = horizon.min(t.saturating_add(look.base));
            // `.max(t)` guards the saturated edge (an event at
            // Cycle::MAX would otherwise sit above bound forever); in
            // the clipped mode t <= limit here, so the clamp order
            // keeps bound <= limit.
            let bound =
                if atomic { (end - 1).max(t) } else { (end - 1).min(limit).max(t) };
            Plan::Window { bound, end }
        }
    }
}

const ST_RUN: u64 = 0;
const ST_PAUSED: u64 = 1;
const ST_DONE: u64 = 2;

/// Run the windowed loop over `shards` on up to `threads` OS threads
/// until the queues drain or `limit` is reached.
///
/// Returns the shards plus `None` when paused at `limit`, or
/// `Some(final_time)` (max dispatch time across shards) when drained.
/// The result is identical for every `threads` value: worker count only
/// changes which thread executes a shard's window, never the window
/// sequence or any shard's event order. `atomic` selects the
/// snapshot-safe pause mode (see [`plan_window`]).
pub(crate) fn run_windows(
    shards: Vec<Shard>,
    tables: &Tables,
    look: &Lookahead,
    threads: usize,
    limit: Cycle,
    atomic: bool,
) -> (Vec<Shard>, Option<Cycle>) {
    let n = shards.len();
    debug_assert_eq!(look.row_min.len(), n, "lookahead matrix built for another shard count");
    let workers = threads.clamp(1, n);
    let cells: Vec<Mutex<Shard>> = shards.into_iter().map(Mutex::new).collect();
    let barrier = Barrier::new(workers);
    let state = AtomicU64::new(ST_RUN);
    let bound = AtomicU64::new(0);
    let end = AtomicU64::new(0);
    // Sentinel in the `solo` atomic: no solo window planned.
    const NO_SOLO: u64 = u64::MAX;
    let solo = AtomicU64::new(NO_SOLO);
    let panicked = AtomicBool::new(false);
    let payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);

    // Record a worker panic and keep the barrier protocol alive so the
    // other workers can exit cleanly; the payload is re-thrown below.
    let record = |r: std::thread::Result<()>| {
        if let Err(p) = r {
            let mut slot = payload.lock().unwrap_or_else(|e| e.into_inner());
            slot.get_or_insert(p);
            panicked.store(true, Ordering::SeqCst);
        }
    };

    let worker = |w: usize| {
        loop {
            if w == 0 {
                // Planner: worker 0 routes cross-shard traffic and
                // positions the window while everyone else waits at the
                // barrier (all shard locks are uncontended here).
                let r = panic::catch_unwind(AssertUnwindSafe(|| {
                    if panicked.load(Ordering::SeqCst) {
                        return ST_DONE;
                    }
                    match plan_window(&cells, limit, look, atomic) {
                        Plan::Idle => ST_DONE,
                        Plan::Paused => ST_PAUSED,
                        Plan::Window { bound: b, end: e } => {
                            bound.store(b, Ordering::SeqCst);
                            end.store(e, Ordering::SeqCst);
                            solo.store(NO_SOLO, Ordering::SeqCst);
                            ST_RUN
                        }
                        Plan::Solo { shard, bound: b, start } => {
                            bound.store(b, Ordering::SeqCst);
                            // `end` carries the window *start* in solo
                            // mode: cross traffic keeps natural times.
                            end.store(start, Ordering::SeqCst);
                            solo.store(shard as u64, Ordering::SeqCst);
                            ST_RUN
                        }
                    }
                }));
                match r {
                    Ok(st) => state.store(st, Ordering::SeqCst),
                    Err(p) => {
                        record(Err(p));
                        state.store(ST_DONE, Ordering::SeqCst);
                    }
                }
            }
            barrier.wait();
            if state.load(Ordering::SeqCst) != ST_RUN {
                return;
            }
            let (b, e) = (bound.load(Ordering::SeqCst), end.load(Ordering::SeqCst));
            let s = solo.load(Ordering::SeqCst);
            record(panic::catch_unwind(AssertUnwindSafe(|| {
                if s != NO_SOLO {
                    // Solo window: only the owning worker runs, with
                    // early close on the first cross-shard send.
                    let i = s as usize;
                    if i % workers == w {
                        lock(&cells[i]).run_window(b, e, tables, true);
                    }
                    return;
                }
                let mut i = w;
                while i < n {
                    lock(&cells[i]).run_window(b, e, tables, false);
                    i += workers;
                }
            })));
            barrier.wait();
        }
    };

    if workers == 1 {
        worker(0);
    } else {
        std::thread::scope(|scope| {
            let worker = &worker;
            for w in 1..workers {
                scope.spawn(move || worker(w));
            }
            worker(0);
        });
    }

    if panicked.load(Ordering::SeqCst) {
        let p = payload
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .expect("panic flagged without payload");
        panic::resume_unwind(p);
    }

    let shards: Vec<Shard> = cells
        .into_iter()
        .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
        .collect();
    let done = match state.load(Ordering::SeqCst) {
        ST_PAUSED => None,
        _ => Some(shards.iter().map(|s| s.now).max().unwrap_or(0)),
    };
    (shards, done)
}
