//! Deterministic discrete-event simulation engine.
//!
//! This is the substrate replacing MGPUSim's Akita engine (DESIGN.md S1/S2):
//! a fully deterministic event loop over *components* (caches, memory
//! controllers, CUs, switches) connected by bandwidth-modelled *links*.
//!
//! The component graph is partitioned into *logical shards* ([`shard`];
//! the coordinator uses one per GPU plus a slim hub) that advance in
//! conservative lock-step time windows sized adaptively from a
//! per-shard-pair lookahead matrix (derived from the cross-shard links
//! declared via [`Engine::add_link_between`], with solo-shard early
//! close), so independent partitions can execute on worker threads
//! ([`Engine::set_threads`]). A single-shard engine ([`Engine::new`]) is
//! the classic sequential event loop.
//!
//! Determinism contract: events fire in `(time, src_shard, seq)` order,
//! encoded in a single sequence number assigned at scheduling time
//! (`seq = shard << SEQ_SHARD_BITS | counter`). The partition is a
//! function of the simulated configuration — never of the thread count —
//! so any `--shards` level produces identical event interleavings, cycle
//! counts and memory images: a requirement for the paper's
//! relative-timing experiments and for reproducible CI. The scheduler
//! behind the contract is a bucketed calendar queue ([`queue`]) with O(1)
//! amortized dispatch; message boxes recycle through per-shard free-list
//! pools ([`pool`], rebalanced at window barriers) so the event hot loop
//! performs no allocation.

pub mod engine;
pub mod link;
pub mod msg;
pub mod pool;
pub mod queue;
pub mod shard;

pub use engine::{CompId, Component, Ctx, Engine, ShardOccupancy};
pub use link::{Link, LinkId};
pub use msg::{MemReq, MemRsp, Msg, ReqId, ReqKind, TsPair};
pub use pool::{MsgPool, PoolCounters};
pub use queue::EventQueue;
pub use shard::SEQ_SHARD_BITS;

/// Simulation time in core clock cycles (1 GHz in the paper's Table 2).
pub type Cycle = u64;
