//! Deterministic discrete-event simulation engine.
//!
//! This is the substrate replacing MGPUSim's Akita engine (DESIGN.md S1/S2):
//! a single-threaded, fully deterministic event loop over *components*
//! (caches, memory controllers, CUs, switches) connected by
//! bandwidth-modelled *links*.
//!
//! Determinism contract: events fire in `(time, sequence)` order, where the
//! sequence number is assigned at scheduling time. Two runs of the same
//! configuration produce identical event interleavings, cycle counts and
//! memory images — a requirement for the paper's relative-timing
//! experiments and for reproducible CI. The scheduler behind the contract
//! is a bucketed calendar queue ([`queue`]) with O(1) amortized dispatch;
//! message boxes recycle through a free-list pool ([`pool`]) so the event
//! hot loop performs no allocation.

pub mod engine;
pub mod link;
pub mod msg;
pub mod pool;
pub mod queue;

pub use engine::{CompId, Component, Ctx, Engine};
pub use link::{Link, LinkId};
pub use msg::{MemReq, MemRsp, Msg, ReqId, ReqKind, TsPair};
pub use pool::MsgPool;
pub use queue::EventQueue;

/// Simulation time in core clock cycles (1 GHz in the paper's Table 2).
pub type Cycle = u64;
