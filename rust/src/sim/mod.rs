//! Deterministic discrete-event simulation engine.
//!
//! This is the substrate replacing MGPUSim's Akita engine (DESIGN.md S1/S2):
//! a single-threaded, fully deterministic event loop over *components*
//! (caches, memory controllers, CUs, switches) connected by
//! bandwidth-modelled *links*.
//!
//! Determinism contract: events fire in `(time, sequence)` order, where the
//! sequence number is assigned at scheduling time. Two runs of the same
//! configuration produce identical event interleavings, cycle counts and
//! memory images — a requirement for the paper's relative-timing
//! experiments and for reproducible CI.

pub mod engine;
pub mod link;
pub mod msg;

pub use engine::{CompId, Component, Ctx, Engine};
pub use link::{Link, LinkId};
pub use msg::{MemReq, MemRsp, Msg, ReqId, ReqKind, TsPair};

/// Simulation time in core clock cycles (1 GHz in the paper's Table 2).
pub type Cycle = u64;
