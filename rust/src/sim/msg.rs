//! Message types exchanged between simulated components.
//!
//! One crate-wide enum keeps dispatch monomorphic and allocation-free on
//! the hot path (no `Box<dyn Any>`); protocol-specific payloads (HALCONE
//! timestamps, HMG invalidations) are inline variants/fields. Payload
//! bytes live in a fixed inline [`LineBuf`] (word accesses carry `size`,
//! the buffer is always line-capacity), so `MemReq`/`MemRsp` own no heap
//! and their boxes recycle cleanly through the engine's `MsgPool`.

use crate::mem::LineBuf;
use crate::sim::engine::CompId;
use crate::sim::Cycle;

/// Unique id of an in-flight memory request (assigned by the issuer).
pub type ReqId = u64;

/// Kind of memory access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqKind {
    Read,
    Write,
}

/// Timestamp pair carried by HALCONE responses (`rts`, `wts`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TsPair {
    pub rts: u64,
    pub wts: u64,
}

/// A memory request travelling *down* the hierarchy (CU -> L1 -> L2 -> MM).
///
/// `src` is the component to respond to; `id` is echoed in the response.
/// Word-granularity accesses (from CUs) carry `size <= line`; cache-line
/// fills use the full line size. `data` carries write payloads.
#[derive(Clone, Copy, Debug)]
pub struct MemReq {
    pub id: ReqId,
    pub kind: ReqKind,
    pub addr: u64,
    pub size: u32,
    pub src: CompId,
    /// Final destination component; switches route on this.
    pub dst: CompId,
    /// Write payload (`size` bytes), empty for reads.
    pub data: LineBuf,
    /// G-TSC ablation: logical timestamp carried with the request
    /// (HALCONE eliminates this field; it exists to account the traffic
    /// delta of CU-level counters, DESIGN.md E10).
    pub warpts: Option<u64>,
    /// Originating tenant (0 in single-application runs): per-tenant
    /// traffic attribution for `mix:` workloads. Rides in spare routing
    /// metadata bits like `src`/`dst`, so it is *not* part of
    /// [`MemReq::wire_bytes`] — changing the wire size would shift every
    /// byte counter the CI gates pin.
    pub tenant: u32,
}

impl Default for MemReq {
    fn default() -> Self {
        MemReq {
            id: 0,
            kind: ReqKind::Read,
            addr: 0,
            size: 0,
            src: CompId::NONE,
            dst: CompId::NONE,
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        }
    }
}

/// A memory response travelling *up* the hierarchy.
#[derive(Clone, Copy, Debug)]
pub struct MemRsp {
    pub id: ReqId,
    pub kind: ReqKind,
    pub addr: u64,
    /// Final destination component (the original requester).
    pub dst: CompId,
    /// Read payload (line or word), empty for write acks.
    pub data: LineBuf,
    /// HALCONE: block timestamps assigned by the level below.
    pub ts: Option<TsPair>,
}

impl Default for MemRsp {
    fn default() -> Self {
        MemRsp {
            id: 0,
            kind: ReqKind::Read,
            addr: 0,
            dst: CompId::NONE,
            data: LineBuf::empty(),
            ts: None,
        }
    }
}

/// All messages understood by simulated components.
#[derive(Clone, Debug)]
pub enum Msg {
    /// Memory request (downward). Boxed: `Event`s live in the scheduler's
    /// buckets, and moves copy the whole struct — keeping `Msg` at
    /// pointer size nearly halved scheduler time (§Perf log). The boxes
    /// recycle through the engine's `MsgPool`.
    Req(Box<MemReq>),
    /// Memory response (upward).
    Rsp(Box<MemRsp>),
    /// HMG: invalidate `addr`'s line at `dst`; reply `InvAck` to `dir`.
    Inv { addr: u64, dir: CompId, dst: CompId },
    /// HMG: invalidation ack for `addr` from sharer `from`, routed to `dst`.
    InvAck { addr: u64, from: CompId, dst: CompId },
    /// Driver -> CU: start executing phase `phase`.
    StartPhase { phase: u32 },
    /// CU -> Driver: all wavefronts of this CU finished the phase.
    PhaseDone { cu: CompId },
    /// Driver -> caches (fence stage 1): report your logical clock.
    FenceQuery { reply_to: CompId },
    /// Cache -> Driver: this cache's current cts (and max block rts seen).
    FenceInfo { from: CompId, cts: u64 },
    /// Driver -> caches (fence stage 2): apply the fence. Semantics depend
    /// on the protocol: HALCONE advances cts to `logical_max`; NC flushes +
    /// invalidates; HMG writes back dirty lines and drops the rest.
    FenceApply { reply_to: CompId, logical_max: u64 },
    /// Cache -> Driver: fence completed (all dirty write-backs drained).
    FenceDone { from: CompId },
    /// Self-scheduled wakeup (component-internal timer).
    Tick,
    /// Bulk DMA transfer completion marker (RDMA copy phases).
    DmaDone { bytes: u64 },
}

impl MemReq {
    /// On-wire size in bytes for link bandwidth accounting: address (8) +
    /// metadata (4) + payload + optional timestamp (2; G-TSC ablation).
    pub fn wire_bytes(&self) -> u64 {
        8 + 4 + self.data.len() as u64 + if self.warpts.is_some() { 2 } else { 0 }
    }
}

impl MemRsp {
    /// On-wire size: ACK (4) + metadata (4) + payload + timestamps
    /// (2 x 16-bit when present).
    pub fn wire_bytes(&self) -> u64 {
        4 + 4 + self.data.len() as u64 + if self.ts.is_some() { 4 } else { 0 }
    }
}

/// An event in the queue: deliver `msg` to `target` at `time`.
#[derive(Debug)]
pub struct Event {
    pub time: Cycle,
    pub seq: u64,
    pub target: CompId,
    pub msg: Msg,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. Ties broken
        // by sequence number => deterministic FIFO among same-cycle
        // events. The calendar queue (`sim/queue.rs`) preserves exactly
        // this order and uses the inversion for its overflow heap.
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_ordering_is_time_then_seq() {
        let e = |time, seq| Event { time, seq, target: CompId(0), msg: Msg::Tick };
        let mut heap = std::collections::BinaryHeap::new();
        heap.push(e(5, 0));
        heap.push(e(3, 2));
        heap.push(e(3, 1));
        heap.push(e(7, 3));
        let order: Vec<(u64, u64)> =
            std::iter::from_fn(|| heap.pop().map(|ev| (ev.time, ev.seq))).collect();
        assert_eq!(order, vec![(3, 1), (3, 2), (5, 0), (7, 3)]);
    }

    #[test]
    fn wire_bytes_match_paper_overheads() {
        // Paper §3.2.6: 64B block + 4B ACK + 4B metadata + 8B address;
        // HALCONE adds 2x16-bit timestamps to responses => +5% read traffic.
        let read_req = MemReq {
            id: 0,
            kind: ReqKind::Read,
            addr: 0,
            size: 64,
            src: CompId(0),
            dst: CompId(1),
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        };
        let rsp_nc = MemRsp {
            id: 0,
            kind: ReqKind::Read,
            addr: 0,
            dst: CompId(0),
            data: LineBuf::zeroed(64),
            ts: None,
        };
        let rsp_c = MemRsp {
            ts: Some(TsPair::default()),
            ..rsp_nc
        };
        let nc = read_req.wire_bytes() + rsp_nc.wire_bytes();
        let c = read_req.wire_bytes() + rsp_c.wire_bytes();
        let overhead = (c - nc) as f64 / nc as f64;
        assert!(overhead < 0.06, "read transaction overhead {overhead} too big");
    }

    #[test]
    fn warpts_adds_request_bytes() {
        let mut req = MemReq {
            id: 0,
            kind: ReqKind::Read,
            addr: 0,
            size: 64,
            src: CompId(0),
            dst: CompId(1),
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        };
        let without = req.wire_bytes();
        req.warpts = Some(7);
        assert_eq!(req.wire_bytes(), without + 2);
    }

    #[test]
    fn messages_carry_no_heap_payload() {
        // The pooling contract: recycling a box must never free or
        // allocate payload storage, so the structs must be `Copy`.
        fn assert_copy<T: Copy>() {}
        assert_copy::<MemReq>();
        assert_copy::<MemRsp>();
    }
}
