//! HALCONE CLI — the leader entrypoint.
//!
//! Subcommands:
//!   run          simulate one workload under one configuration
//!   compare      run every §4.1 preset on a workload, report speedups
//!   verify       run workloads under HALCONE and check against the
//!                XLA/Pallas golden artifacts + Rust references
//!   print-config show the Table 2 configuration (E2)
//!   list         available workloads, presets and artifacts
//!
//! Argument parsing is hand-rolled (no clap in the offline registry).

use std::process::ExitCode;

use halcone::config::SystemConfig;
use halcone::coordinator::runner::run_workload;
use halcone::runtime::Runtime;
use halcone::workloads::{STANDARD, XTREME};

fn usage() -> ! {
    eprintln!(
        "usage: halcone <command> [options]\n\
         \n\
         commands:\n\
           run          --workload NAME [--preset P] [--set k=v ...]\n\
           compare      --workload NAME [--presets A,B,...] [--set k=v ...]\n\
           verify       [--workload NAME|all] [--artifacts DIR] [--set k=v ...]\n\
           print-config [--preset P] [--set k=v ...]\n\
           list\n\
         \n\
         common options:\n\
           --preset P        one of {presets:?}\n\
           --config FILE     key=value config file (preset= line allowed)\n\
           --set key=value   override any config key (repeatable)\n\
           --artifacts DIR   AOT artifact directory (default: artifacts)\n",
        presets = SystemConfig::PRESETS
    );
    std::process::exit(2)
}

struct Args {
    command: String,
    workload: Option<String>,
    preset: Option<String>,
    presets: Option<Vec<String>>,
    config_file: Option<String>,
    sets: Vec<(String, String)>,
    artifacts: String,
}

fn parse_args() -> Args {
    let mut argv = std::env::args().skip(1);
    let command = argv.next().unwrap_or_else(|| usage());
    let mut a = Args {
        command,
        workload: None,
        preset: None,
        presets: None,
        config_file: None,
        sets: vec![],
        artifacts: "artifacts".into(),
    };
    while let Some(flag) = argv.next() {
        let mut val = |name: &str| {
            argv.next().unwrap_or_else(|| {
                eprintln!("missing value for {name}");
                usage()
            })
        };
        match flag.as_str() {
            "--workload" | "-w" => a.workload = Some(val("--workload")),
            "--preset" | "-p" => a.preset = Some(val("--preset")),
            "--presets" => {
                a.presets = Some(val("--presets").split(',').map(String::from).collect())
            }
            "--config" => a.config_file = Some(val("--config")),
            "--artifacts" => a.artifacts = val("--artifacts"),
            "--set" | "-s" => {
                let kv = val("--set");
                match kv.split_once('=') {
                    Some((k, v)) => a.sets.push((k.trim().into(), v.trim().into())),
                    None => {
                        eprintln!("--set wants key=value, got '{kv}'");
                        usage()
                    }
                }
            }
            "--help" | "-h" => usage(),
            other => {
                eprintln!("unknown flag '{other}'");
                usage()
            }
        }
    }
    a
}

fn build_config(a: &Args) -> SystemConfig {
    let mut cfg = if let Some(f) = &a.config_file {
        let text = std::fs::read_to_string(f).unwrap_or_else(|e| {
            eprintln!("reading {f}: {e}");
            std::process::exit(2)
        });
        SystemConfig::parse(&text).unwrap_or_else(|e| {
            eprintln!("{f}: {e}");
            std::process::exit(2)
        })
    } else if let Some(p) = &a.preset {
        SystemConfig::preset(p)
    } else {
        SystemConfig::default()
    };
    for (k, v) in &a.sets {
        if let Err(e) = cfg.set(k, v) {
            eprintln!("--set {k}={v}: {e}");
            std::process::exit(2);
        }
    }
    cfg
}

fn open_runtime(a: &Args) -> Option<Runtime> {
    match Runtime::open(&a.artifacts) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("note: artifact runtime unavailable ({e:#}); artifact checks skipped");
            None
        }
    }
}

fn cmd_run(a: &Args) -> ExitCode {
    let Some(workload) = &a.workload else {
        eprintln!("run: --workload required");
        usage()
    };
    let cfg = build_config(a);
    let mut rt = open_runtime(a);
    let res = run_workload(&cfg, workload, rt.as_mut());
    println!("{}", res.summary());
    println!(
        "  mm reads/writes: {}/{}  pcie bytes: {}  mem-net bytes: {}  host: {:.3}s ({:.1}M events/s)",
        res.metrics.mm_reads,
        res.metrics.mm_writes,
        res.metrics.pcie_bytes,
        res.metrics.mem_bytes,
        res.metrics.host_seconds,
        res.metrics.events as f64 / res.metrics.host_seconds.max(1e-9) / 1e6,
    );
    for c in &res.checks {
        println!(
            "  check[{}] {} max_err={:.2e} {}",
            c.kind,
            if c.passed { "ok  " } else { "FAIL" },
            c.max_err,
            c.desc
        );
    }
    if res.all_passed() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_compare(a: &Args) -> ExitCode {
    let Some(workload) = &a.workload else {
        eprintln!("compare: --workload required");
        usage()
    };
    let presets: Vec<String> = a
        .presets
        .clone()
        .unwrap_or_else(|| SystemConfig::PRESETS.iter().map(|s| s.to_string()).collect());
    let mut rt = open_runtime(a);
    let mut baseline = None;
    let mut ok = true;
    println!(
        "{:<18} {:>14} {:>9} {:>10} {:>10}",
        "config", "cycles", "speedup", "l1->l2", "l2->mm"
    );
    for p in &presets {
        let mut cfg = SystemConfig::preset(p);
        for (k, v) in &a.sets {
            if let Err(e) = cfg.set(k, v) {
                eprintln!("--set {k}={v}: {e}");
                return ExitCode::FAILURE;
            }
        }
        let res = run_workload(&cfg, workload, rt.as_mut());
        let base = *baseline.get_or_insert(res.metrics.cycles);
        println!(
            "{:<18} {:>14} {:>8.2}x {:>10} {:>10}{}",
            p,
            res.metrics.cycles,
            base as f64 / res.metrics.cycles as f64,
            res.metrics.l1_l2_transactions(),
            res.metrics.l2_mm_transactions(),
            if res.all_passed() { "" } else { "  CHECKS FAILED" }
        );
        ok &= res.all_passed();
    }
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_verify(a: &Args) -> ExitCode {
    let names: Vec<&str> = match a.workload.as_deref() {
        None | Some("all") => STANDARD.iter().chain(XTREME.iter()).copied().collect(),
        Some(w) => vec![w],
    };
    let cfg = build_config(a);
    let mut rt = open_runtime(a);
    let mut ok = true;
    for name in names {
        let res = run_workload(&cfg, name, rt.as_mut());
        println!("{}", res.summary());
        ok &= res.all_passed();
    }
    println!("verify: {}", if ok { "ALL CHECKS PASSED" } else { "FAILURES" });
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_list(a: &Args) -> ExitCode {
    println!("workloads (standard): {STANDARD:?}");
    println!("workloads (xtreme):   {XTREME:?}");
    println!("presets:              {:?}", SystemConfig::PRESETS);
    match Runtime::open(&a.artifacts) {
        Ok(rt) => println!("artifacts:            {:?}", rt.artifacts()),
        Err(_) => println!("artifacts:            (none — run `make artifacts`)"),
    }
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let args = parse_args();
    match args.command.as_str() {
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "verify" => cmd_verify(&args),
        "print-config" => {
            println!("{}", build_config(&args).describe());
            ExitCode::SUCCESS
        }
        "list" => cmd_list(&args),
        _ => usage(),
    }
}
