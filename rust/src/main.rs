//! HALCONE CLI — the leader entrypoint.
//!
//! Subcommands:
//!   run          simulate one workload under one configuration
//!   compare      run every §4.1 preset on a workload, report speedups
//!   sweep        run a whole experiment campaign in parallel, write
//!                campaign.json + a speedup table (Figs. 7/8 in one go)
//!   gate         re-run a campaign and diff it against a committed
//!                campaign.json baseline (perf regression gate)
//!   verify       run workloads under HALCONE and check against the
//!                XLA/Pallas golden artifacts + Rust references
//!   replay       re-inject a recorded trace and report divergence
//!                against the recording (docs/TRACE.md)
//!   trace-gen    generate a synthetic sharing-pattern trace
//!   mix-gen      write a multi-tenant mix spec file (docs/TENANCY.md)
//!   print-config show the Table 2 configuration (E2)
//!   list         available workloads, presets, campaigns and artifacts
//!
//! Argument parsing is hand-rolled (no clap in the offline registry).
//!
//! Exit codes are part of the CLI contract (asserted in
//! `tests/cli_exit_codes.rs`): 0 success; 1 generic failure (failed
//! checks, divergence, failed cells); 2 usage, configuration or I/O
//! error; 3 gate regression; 4 sweep completed partially because some
//! cells hit the watchdog timeout.

use std::process::ExitCode;

use halcone::config::SystemConfig;
use halcone::coordinator::runner::{run_built_traced, run_workload, try_run_workload_snap, SnapMode};
use halcone::metrics::divergence;
use halcone::runtime::Runtime;
use halcone::sweep::exec::{self, run_campaign, CellExec, CellOutcome, ExecOptions};
use halcone::sweep::spec::CampaignSpec;
use halcone::sweep::{gate, json, report};
use halcone::trace::{self, SharingPattern, SynthSpec};
use halcone::workloads::{STANDARD, XTREME};

/// Distinct exit codes (see the module doc / `usage()`).
const EXIT_FAILURE: u8 = 1;
const EXIT_CONFIG: u8 = 2;
const EXIT_GATE: u8 = 3;
const EXIT_TIMEOUT: u8 = 4;

fn usage() -> ! {
    eprintln!(
        "usage: halcone <command> [options]\n\
         \n\
         commands:\n\
           run          --workload NAME [--preset P] [--set k=v ...] [--trace-out FILE]\n\
                        [--snapshot-at CYCLE --snapshot-out FILE | --warm-start FILE]\n\
           compare      --workload NAME [--presets A,B,...] [--set k=v ...]\n\
           sweep        --campaign NAME | --spec FILE  [--jobs N] [--out FILE] [--set k=v ...]\n\
                        [--faults SPEC] [--timeout SECS] [--retries N] [--warmup CYCLES]\n\
                        | --resume DIR\n\
           gate         --baseline FILE [--current FILE] [--campaign NAME|--spec FILE]\n\
                        [--tolerance FRAC] [--jobs N] [--out FILE]\n\
           verify       [--workload NAME|all] [--artifacts DIR] [--set k=v ...]\n\
           replay       --trace FILE [--preset P] [--set k=v ...] [--strict]\n\
                        [--trace-out FILE]\n\
           trace-gen    --pattern P --out FILE [--ops N] [--lines N] [--gap N]\n\
                        [--phases N] [--seed N] [--preset P] [--set k=v ...]\n\
           mix-gen      --tenant T [--tenant T ...] [--policy fifo|rr]\n\
                        [--width N] [--spacing N] [--out FILE.mix]\n\
           print-config [--preset P] [--set k=v ...]\n\
           list\n\
         \n\
         a workload NAME may also be the replay form 'trace:<file>' or the\n\
         multi-tenant mix form 'mix:<spec>' (inline tenants or a .mix file\n\
         from mix-gen; docs/TENANCY.md);\n\
         trace-gen patterns: {patterns:?}\n\
         \n\
         common options:\n\
           --preset P        one of {presets:?}\n\
           --config FILE     key=value config file (preset= line allowed)\n\
           --set key=value   override any config key (repeatable)\n\
           --faults SPEC     deterministic fault schedule, e.g.\n\
                             'seed=7;degrade=0.2;outage=0.05;ts_bits=12' — sugar for\n\
                             --set faults=SPEC (docs/ROBUSTNESS.md)\n\
           --shards N        engine worker threads per simulation (parallel\n\
                             sharded engine; any N gives identical results)\n\
           --artifacts DIR   AOT artifact directory (default: artifacts)\n\
         \n\
         sweep/gate options:\n\
           --campaign NAME   built-in campaign, one of {campaigns:?}\n\
           --spec FILE       campaign spec file (key=value; see sweep::spec)\n\
           --jobs N          worker threads (default: all cores)\n\
           --out FILE        write the artifact here (sweep default: campaign.json;\n\
                             gate writes one only when --out is given)\n\
           --baseline FILE   committed campaign.json to gate against\n\
           --current FILE    pre-generated campaign.json (skip re-running)\n\
           --tolerance FRAC  allowed relative cycle drift (default: 0.05)\n\
           --timeout SECS    per-cell wall-clock watchdog; expired cells record\n\
                             status \"timeout\" and the campaign drains on\n\
           --retries N       extra attempts for panicked/timed-out cells (default 0)\n\
           --resume DIR      re-enter an interrupted campaign from its journaled\n\
                             campaign.json (DIR or the file itself); completed cells\n\
                             are reloaded, the rest re-run (docs/ROBUSTNESS.md);\n\
                             campaigns with an `oracle =` line cannot resume —\n\
                             access-stream traces are not journaled (docs/PROTOCOLS.md)\n\
         \n\
         snapshot options (docs/SNAPSHOT.md):\n\
           --snapshot-at N   run: pause at the first deterministic barrier at or\n\
                             after cycle N and checkpoint the full engine state\n\
           --snapshot-out F  run: snapshot file to write (with --snapshot-at)\n\
           --warm-start F    run: restore snapshot F and continue to completion;\n\
                             results are byte-identical to the cold run\n\
           --warmup N        sweep: share a warmed-up engine across cells — run the\n\
                             first N cycles once per distinct configuration, then\n\
                             fork every matching cell from that snapshot\n\
         \n\
         trace options:\n\
           --trace FILE      trace to replay (replay)\n\
           --trace-out FILE  write the captured trace here (run, replay)\n\
           --strict          replay: exit nonzero on any divergence\n\
           --pattern P       trace-gen sharing pattern\n\
           --ops N           trace-gen: memory ops per wavefront (default 64)\n\
           --lines N         trace-gen: working-set cache lines (default 64)\n\
           --gap N           trace-gen: compute cycles between ops (default 0)\n\
           --phases N        trace-gen: kernel phases (default 1)\n\
           --seed N          trace-gen: generator seed\n\
         \n\
         mix options:\n\
           --tenant T        tenant stream term '<pattern|trace:FILE>[@arrival][*replicas]'\n\
                             (repeatable, one per tenant)\n\
           --policy P        inter-kernel scheduling policy: fifo (default) or rr\n\
           --width N         CUs per scheduler slot (default: total/tenants)\n\
           --spacing N       cycles between replica arrivals (all tenants)\n\
         \n\
         exit codes:\n\
           0  success\n\
           1  failure (failed checks, divergence, failed cells)\n\
           2  usage, configuration or I/O error\n\
           3  gate regression (violations found)\n\
           4  sweep partial: some cells hit the watchdog timeout\n",
        presets = SystemConfig::PRESETS,
        campaigns = CampaignSpec::BUILTINS,
        patterns = SharingPattern::NAMES,
    );
    std::process::exit(2)
}

struct Args {
    command: String,
    workload: Option<String>,
    preset: Option<String>,
    presets: Option<Vec<String>>,
    config_file: Option<String>,
    sets: Vec<(String, String)>,
    artifacts: String,
    campaign: Option<String>,
    spec_file: Option<String>,
    jobs: Option<usize>,
    shards: Option<usize>,
    timeout: Option<u64>,
    retries: Option<u32>,
    resume: Option<String>,
    out: Option<String>,
    baseline: Option<String>,
    current: Option<String>,
    tolerance: Option<f64>,
    trace_file: Option<String>,
    trace_out: Option<String>,
    snapshot_at: Option<u64>,
    snapshot_out: Option<String>,
    warm_start: Option<String>,
    warmup: Option<u64>,
    strict: bool,
    pattern: Option<String>,
    ops: Option<u32>,
    lines: Option<u32>,
    gap: Option<u32>,
    phases: Option<u32>,
    seed: Option<u64>,
    tenants: Vec<String>,
    policy: Option<String>,
    width: Option<u32>,
    spacing: Option<u64>,
}

/// Parse a numeric flag value or die with a usage message.
fn parse_num<T: std::str::FromStr>(flag: &str, v: &str) -> T
where
    T::Err: std::fmt::Display,
{
    v.parse::<T>().unwrap_or_else(|e| {
        eprintln!("{flag} {v}: {e}");
        usage()
    })
}

fn parse_args() -> Args {
    let mut argv = std::env::args().skip(1);
    let command = argv.next().unwrap_or_else(|| usage());
    let mut a = Args {
        command,
        workload: None,
        preset: None,
        presets: None,
        config_file: None,
        sets: vec![],
        artifacts: "artifacts".into(),
        campaign: None,
        spec_file: None,
        jobs: None,
        shards: None,
        timeout: None,
        retries: None,
        resume: None,
        out: None,
        baseline: None,
        current: None,
        tolerance: None,
        trace_file: None,
        trace_out: None,
        snapshot_at: None,
        snapshot_out: None,
        warm_start: None,
        warmup: None,
        strict: false,
        pattern: None,
        ops: None,
        lines: None,
        gap: None,
        phases: None,
        seed: None,
        tenants: vec![],
        policy: None,
        width: None,
        spacing: None,
    };
    while let Some(flag) = argv.next() {
        let mut val = |name: &str| {
            argv.next().unwrap_or_else(|| {
                eprintln!("missing value for {name}");
                usage()
            })
        };
        match flag.as_str() {
            "--workload" | "-w" => a.workload = Some(val("--workload")),
            "--preset" | "-p" => a.preset = Some(val("--preset")),
            "--presets" => {
                a.presets = Some(val("--presets").split(',').map(String::from).collect())
            }
            "--config" => a.config_file = Some(val("--config")),
            "--artifacts" => a.artifacts = val("--artifacts"),
            "--campaign" => a.campaign = Some(val("--campaign")),
            "--spec" => a.spec_file = Some(val("--spec")),
            "--jobs" | "-j" => {
                let v = val("--jobs");
                match v.parse::<usize>() {
                    Ok(n) if n >= 1 => a.jobs = Some(n),
                    Ok(_) => {
                        eprintln!("--jobs must be at least 1");
                        usage()
                    }
                    Err(e) => {
                        eprintln!("--jobs {v}: {e}");
                        usage()
                    }
                }
            }
            "--shards" => {
                let v = val("--shards");
                match v.parse::<usize>() {
                    Ok(n) if n >= 1 => a.shards = Some(n),
                    Ok(_) => {
                        eprintln!("--shards must be at least 1");
                        usage()
                    }
                    Err(e) => {
                        eprintln!("--shards {v}: {e}");
                        usage()
                    }
                }
            }
            "--timeout" => {
                let v = val("--timeout");
                match v.parse::<u64>() {
                    Ok(n) if n >= 1 => a.timeout = Some(n),
                    Ok(_) => {
                        eprintln!("--timeout must be at least 1 second");
                        usage()
                    }
                    Err(e) => {
                        eprintln!("--timeout {v}: {e}");
                        usage()
                    }
                }
            }
            "--retries" => a.retries = Some(parse_num("--retries", &val("--retries"))),
            "--resume" => a.resume = Some(val("--resume")),
            // Sugar for --set faults=SPEC: the schedule lands in the
            // config (and thus the artifact's fixed overrides), so gate
            // re-runs and resumed campaigns replay identical faults.
            "--faults" => {
                let v = val("--faults");
                a.sets.push(("faults".into(), v));
            }
            "--out" | "-o" => a.out = Some(val("--out")),
            "--trace" => a.trace_file = Some(val("--trace")),
            "--trace-out" => a.trace_out = Some(val("--trace-out")),
            "--snapshot-at" => {
                a.snapshot_at = Some(parse_num("--snapshot-at", &val("--snapshot-at")))
            }
            "--snapshot-out" => a.snapshot_out = Some(val("--snapshot-out")),
            "--warm-start" => a.warm_start = Some(val("--warm-start")),
            "--warmup" => a.warmup = Some(parse_num("--warmup", &val("--warmup"))),
            "--strict" => a.strict = true,
            "--pattern" => a.pattern = Some(val("--pattern")),
            "--ops" => a.ops = Some(parse_num("--ops", &val("--ops"))),
            "--lines" => a.lines = Some(parse_num("--lines", &val("--lines"))),
            "--gap" => a.gap = Some(parse_num("--gap", &val("--gap"))),
            "--phases" => a.phases = Some(parse_num("--phases", &val("--phases"))),
            "--seed" => a.seed = Some(parse_num("--seed", &val("--seed"))),
            "--tenant" => a.tenants.push(val("--tenant")),
            "--policy" => a.policy = Some(val("--policy")),
            "--width" => a.width = Some(parse_num("--width", &val("--width"))),
            "--spacing" => a.spacing = Some(parse_num("--spacing", &val("--spacing"))),
            "--baseline" => a.baseline = Some(val("--baseline")),
            "--current" => a.current = Some(val("--current")),
            "--tolerance" => {
                let v = val("--tolerance");
                match v.parse::<f64>() {
                    Ok(t) if t.is_finite() && t >= 0.0 => a.tolerance = Some(t),
                    Ok(_) => {
                        eprintln!("--tolerance must be a finite fraction >= 0");
                        usage()
                    }
                    Err(e) => {
                        eprintln!("--tolerance {v}: {e}");
                        usage()
                    }
                }
            }
            "--set" | "-s" => {
                let kv = val("--set");
                match kv.split_once('=') {
                    Some((k, v)) => a.sets.push((k.trim().into(), v.trim().into())),
                    None => {
                        eprintln!("--set wants key=value, got '{kv}'");
                        usage()
                    }
                }
            }
            "--help" | "-h" => usage(),
            other => {
                eprintln!("unknown flag '{other}'");
                usage()
            }
        }
    }
    a
}

fn read_file_or_die(path: &str) -> String {
    std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("reading {path}: {e}");
        std::process::exit(2)
    })
}

fn build_config(a: &Args) -> SystemConfig {
    let mut cfg = if let Some(f) = &a.config_file {
        let text = read_file_or_die(f);
        SystemConfig::parse(&text).unwrap_or_else(|e| {
            eprintln!("{f}: {e}");
            std::process::exit(2)
        })
    } else if let Some(p) = &a.preset {
        // try_preset, not preset: a typoed name must be a clean exit-2
        // with the known presets listed, never a panic.
        SystemConfig::try_preset(p).unwrap_or_else(|e| {
            eprintln!("--preset {p}: {e}");
            std::process::exit(2)
        })
    } else {
        SystemConfig::default()
    };
    for (k, v) in &a.sets {
        if let Err(e) = cfg.set(k, v) {
            eprintln!("--set {k}={v}: {e}");
            std::process::exit(2);
        }
    }
    if let Some(s) = a.shards {
        cfg.shards = s as u32;
    }
    cfg
}

fn open_runtime(a: &Args) -> Option<Runtime> {
    match Runtime::open(&a.artifacts) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("note: artifact runtime unavailable ({e:#}); artifact checks skipped");
            None
        }
    }
}

fn cmd_run(a: &Args) -> ExitCode {
    let Some(workload) = &a.workload else {
        eprintln!("run: --workload required");
        usage()
    };
    let cfg = build_config(a);
    let mut rt = open_runtime(a);
    let capture = a.trace_out.is_some();
    if a.snapshot_at.is_some() != a.snapshot_out.is_some() {
        eprintln!("run: --snapshot-at CYCLE and --snapshot-out FILE go together");
        return ExitCode::from(EXIT_CONFIG);
    }
    if a.warm_start.is_some() && a.snapshot_at.is_some() {
        eprintln!("run: --warm-start cannot be combined with --snapshot-at/--snapshot-out");
        return ExitCode::from(EXIT_CONFIG);
    }
    let snap = if let Some(path) = &a.warm_start {
        match halcone::snapshot::read_file(path) {
            Ok(b) => SnapMode::Warm { bytes: std::sync::Arc::new(b) },
            Err(e) => {
                eprintln!("run: {e}");
                return ExitCode::from(EXIT_CONFIG);
            }
        }
    } else if let Some(at) = a.snapshot_at {
        SnapMode::Save { at }
    } else {
        SnapMode::None
    };
    // The fallible entry keeps a typoed name or bad trace/mix spec a
    // clean error, not a panic — and routes `mix:` through the
    // inter-kernel scheduler.
    let (res, captured, snap_bytes) =
        match try_run_workload_snap(&cfg, workload, rt.as_mut(), capture, snap) {
            Ok(r) => r,
            Err(e) => {
                // Bad workload name / trace path / mix spec / rejected
                // snapshot: a run *configuration* error, distinct from
                // failed checks.
                eprintln!("run: {e}");
                return ExitCode::from(EXIT_CONFIG);
            }
        };
    if let Some(out) = &a.snapshot_out {
        match &snap_bytes {
            Some(bytes) => {
                if let Err(e) = halcone::snapshot::write_file(out, bytes) {
                    eprintln!("run: {e}");
                    return ExitCode::from(EXIT_CONFIG);
                }
                eprintln!("wrote snapshot {out} ({} bytes)", bytes.len());
            }
            None => {
                eprintln!(
                    "run: the simulation finished before cycle {} — nothing left to \
                     snapshot; pick a smaller --snapshot-at",
                    a.snapshot_at.unwrap_or(0),
                );
                return ExitCode::from(EXIT_CONFIG);
            }
        }
    }
    println!("{}", res.summary());
    println!(
        "  cu loads/stores: {}/{}  mm reads/writes: {}/{}  pcie bytes: {}  mem-net bytes: {}  host: {:.3}s ({:.1}M events/s)",
        res.metrics.cu_loads,
        res.metrics.cu_stores,
        res.metrics.mm_reads,
        res.metrics.mm_writes,
        res.metrics.pcie_bytes,
        res.metrics.mem_bytes,
        res.metrics.host_seconds,
        res.metrics.events as f64 / res.metrics.host_seconds.max(1e-9) / 1e6,
    );
    if let Some(t) = &res.metrics.tenancy {
        println!(
            "  tenancy: scheduler {}  jain(turnaround) {:.4}",
            t.scheduler,
            t.jain_turnaround()
        );
        for tm in &t.tenants {
            println!(
                "    t{} {:<20} jobs {:>3}  turnaround mean {:>10.1} p99 {:>8}  \
                 mem share {:.3}  coherence share {:.3}",
                tm.tenant,
                tm.name,
                tm.jobs,
                tm.turnaround_mean(),
                tm.turnaround_p99,
                t.mem_traffic_share(tm.tenant),
                t.coherence_traffic_share(tm.tenant),
            );
        }
    }
    for c in &res.checks {
        println!(
            "  check[{}] {} max_err={:.2e} {}",
            c.kind,
            if c.passed { "ok  " } else { "FAIL" },
            c.max_err,
            c.desc
        );
    }
    if let (Some(out), Some(t)) = (&a.trace_out, &captured) {
        if let Err(e) = trace::save(t, out) {
            eprintln!("run: {e}");
            return ExitCode::FAILURE;
        }
        eprintln!(
            "wrote trace {out}: {} records ({} memory ops) over {} GPUs x {} CUs",
            t.total_records(),
            t.total_ops(),
            t.meta.n_gpus,
            t.meta.cus_per_gpu,
        );
    }
    if res.all_passed() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Replay a trace through the current configuration, re-record it, and
/// report per-access divergence against the input (docs/TRACE.md). With
/// `--strict`, any divergence fails the command — the CI golden-trace
/// oracle (structure-only for synthetic inputs, which carry no timing).
fn cmd_replay(a: &Args) -> ExitCode {
    let Some(path) = &a.trace_file else {
        eprintln!("replay: --trace FILE required");
        usage()
    };
    let baseline = match trace::load(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("replay: {e}");
            return ExitCode::FAILURE;
        }
    };
    let cfg = build_config(a);
    let name = format!("trace:{path}");
    // Build from the trace already in memory (a second `trace:` load
    // could observe a rewritten file and diff against the wrong stream).
    let wl = match trace::replay_workload(&name, &baseline, &cfg.workload_params()) {
        Ok(wl) => wl,
        Err(e) => {
            eprintln!("replay: workload '{name}': {e}");
            return ExitCode::FAILURE;
        }
    };
    let (res, rec) = run_built_traced(&cfg, wl, None, true);
    let rec = rec.expect("replay always captures");
    println!("{}", res.summary());
    let rep = divergence::diff_traces(&baseline, &rec);
    println!("{}", rep.describe());
    if let Some(out) = &a.trace_out {
        if let Err(e) = trace::save(&rec, out) {
            eprintln!("replay: {e}");
            return ExitCode::FAILURE;
        }
        eprintln!("wrote re-recorded trace {out}");
    }
    let synthetic = baseline.meta.cycles == 0;
    let ok = if synthetic { rep.structural_identical() } else { rep.identical() };
    if a.strict && !ok {
        eprintln!("replay: divergence detected (--strict)");
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

/// Generate a synthetic sharing-pattern trace (geometry from the
/// configuration flags, pattern knobs from the trace options).
fn cmd_trace_gen(a: &Args) -> ExitCode {
    let Some(pat) = &a.pattern else {
        eprintln!("trace-gen: --pattern required, one of {:?}", SharingPattern::NAMES);
        usage()
    };
    let pattern = match SharingPattern::parse(pat) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("trace-gen: {e}");
            return ExitCode::FAILURE;
        }
    };
    let cfg = build_config(a);
    let spec = SynthSpec {
        pattern,
        n_gpus: cfg.n_gpus,
        cus_per_gpu: cfg.cus_per_gpu,
        wavefronts_per_cu: cfg.wavefronts_per_cu,
        gpu_mem_bytes: cfg.gpu_mem_bytes,
        ops_per_wavefront: a.ops.unwrap_or(64),
        lines: a.lines.unwrap_or(64),
        gap: a.gap.unwrap_or(0),
        phases: a.phases.unwrap_or(1),
        seed: a.seed.unwrap_or(0xA11CE),
    };
    let out = a.out.clone().unwrap_or_else(|| format!("{pat}.trc"));
    let t = match trace::generate(&spec) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    };
    if let Err(e) = trace::save(&t, &out) {
        eprintln!("trace-gen: {e}");
        return ExitCode::FAILURE;
    }
    println!(
        "wrote {out}: pattern {}, {} memory ops over {} GPUs x {} CUs x {} wavefronts \
         ({} phases); replay with `halcone run --workload trace:{out}`",
        pattern.name(),
        t.total_ops(),
        t.meta.n_gpus,
        t.meta.cus_per_gpu,
        t.meta.wavefronts_per_cu,
        t.meta.n_phases,
    );
    ExitCode::SUCCESS
}

/// Compose the `--tenant` terms into a [`halcone::tenancy::MixSpec`] and
/// write it out in the `.mix` file form, ready for `run --workload
/// mix:<file>.mix` (or for hand-editing per-tenant spacing).
fn cmd_mix_gen(a: &Args) -> ExitCode {
    use halcone::tenancy::{MixSpec, Policy};
    if a.tenants.is_empty() {
        eprintln!(
            "mix-gen: at least one --tenant required \
             (e.g. --tenant read-mostly --tenant 'false-sharing@64*2')"
        );
        usage()
    }
    let inline = format!("mix:{}", a.tenants.join("+"));
    let mut spec = match MixSpec::parse(&inline) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("mix-gen: {e}");
            return ExitCode::FAILURE;
        }
    };
    if let Some(p) = &a.policy {
        spec.policy = match Policy::parse(p) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("mix-gen: {e}");
                return ExitCode::FAILURE;
            }
        };
    }
    if a.width.is_some() {
        spec.width = a.width;
    }
    if let Some(s) = a.spacing {
        for t in &mut spec.tenants {
            t.spacing = s;
        }
    }
    let out = a.out.clone().unwrap_or_else(|| "mix.mix".into());
    if !out.ends_with(".mix") {
        eprintln!("mix-gen: --out must end in .mix (the run form is 'mix:<file>.mix')");
        return ExitCode::FAILURE;
    }
    if let Err(e) = std::fs::write(&out, spec.to_spec_string()) {
        eprintln!("mix-gen: writing {out}: {e}");
        return ExitCode::FAILURE;
    }
    println!(
        "wrote {out}: {} tenants, policy {}; run with `halcone run --workload mix:{out}`",
        spec.tenants.len(),
        spec.policy.name(),
    );
    ExitCode::SUCCESS
}

fn cmd_compare(a: &Args) -> ExitCode {
    let Some(workload) = &a.workload else {
        eprintln!("compare: --workload required");
        usage()
    };
    let presets: Vec<String> = a
        .presets
        .clone()
        .unwrap_or_else(|| SystemConfig::PRESETS.iter().map(|s| s.to_string()).collect());
    // Honor --config FILE like run/verify do: each preset column starts
    // from its own preset, then takes the file's (non-preset) overrides
    // and the --set flags, in that order.
    let file_text = a.config_file.as_ref().map(|f| read_file_or_die(f));
    let mut rt = open_runtime(a);
    let mut baseline: Option<halcone::metrics::RunMetrics> = None;
    let mut ok = true;
    println!(
        "{:<18} {:>14} {:>9} {:>10} {:>10}",
        "config", "cycles", "speedup", "l1->l2", "l2->mm"
    );
    for p in &presets {
        let mut cfg = match SystemConfig::try_preset(p) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{e}");
                return ExitCode::FAILURE;
            }
        };
        if let Some(text) = &file_text {
            if let Err(e) = cfg.apply_overrides(text) {
                eprintln!("{}: {e}", a.config_file.as_deref().unwrap_or("--config"));
                return ExitCode::FAILURE;
            }
        }
        for (k, v) in &a.sets {
            if let Err(e) = cfg.set(k, v) {
                eprintln!("--set {k}={v}: {e}");
                return ExitCode::FAILURE;
            }
        }
        let res = run_workload(&cfg, workload, rt.as_mut());
        let base = baseline.get_or_insert_with(|| res.metrics.clone());
        let speedup = match res.metrics.speedup_vs(base) {
            Some(s) => format!("{s:.2}x"),
            None => "n/a".to_string(), // zero-cycle baseline or cell
        };
        println!(
            "{:<18} {:>14} {:>9} {:>10} {:>10}{}",
            p,
            res.metrics.cycles,
            speedup,
            res.metrics.l1_l2_transactions(),
            res.metrics.l2_mm_transactions(),
            if res.all_passed() { "" } else { "  CHECKS FAILED" }
        );
        ok &= res.all_passed();
    }
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Resolve the campaign spec for sweep/gate: `--spec FILE` wins, then
/// `--campaign NAME`, then `fallback` (gate's baseline-recorded spec);
/// `--set` flags become extra fixed overrides.
fn load_spec(a: &Args, fallback: Option<CampaignSpec>) -> Result<CampaignSpec, String> {
    if a.spec_file.is_some() && a.campaign.is_some() {
        return Err("--campaign and --spec are mutually exclusive".into());
    }
    let mut spec = if let Some(f) = &a.spec_file {
        CampaignSpec::parse(&read_file_or_die(f)).map_err(|e| format!("{f}: {e}"))?
    } else if let Some(name) = &a.campaign {
        CampaignSpec::builtin(name)?
    } else if let Some(spec) = fallback {
        spec
    } else {
        return Err("need --campaign NAME or --spec FILE".into());
    };
    spec.fixed.extend(a.sets.iter().cloned());
    spec.dedup_fixed();
    Ok(spec)
}

/// Campaign verdict summarized for exit-code selection.
struct SweepStatus {
    all_passed: bool,
    any_timed_out: bool,
    oracle_ok: bool,
}

fn sweep_to_json(
    spec: &CampaignSpec,
    a: &Args,
    out: Option<&str>,
    journal: bool,
    preloaded: Vec<(usize, CellOutcome, CellExec)>,
) -> Result<(String, SweepStatus), String> {
    let opts = ExecOptions {
        jobs: a.jobs.unwrap_or_else(exec::default_jobs),
        progress: true,
        shards: a.shards,
        timeout: a.timeout,
        retries: a.retries.unwrap_or(0),
        // Journal into the output artifact itself (sweep only — a gate
        // re-run must not clobber a campaign.json it never owned).
        journal: if journal { out.map(std::path::PathBuf::from) } else { None },
        preloaded,
    };
    // run_campaign expands + validates the grid itself; the count here
    // is arithmetic so the grid is not built twice.
    let total = spec.config_labels().len() * spec.workloads.len();
    eprintln!("campaign {}: {total} cells on {} threads", spec.name, opts.jobs);
    let result = run_campaign(spec, &opts)?;
    report::print_speedup_table(&result);
    report::print_oracle_report(&result);
    let text = report::to_json(&result);
    if let Some(out) = out {
        std::fs::write(out, &text).map_err(|e| format!("writing {out}: {e}"))?;
        eprintln!("wrote {out}");
    }
    let status = SweepStatus {
        all_passed: result.all_passed(),
        any_timed_out: result.any_timed_out(),
        oracle_ok: result.oracle_ok(),
    };
    Ok((text, status))
}

/// Load a `--resume` journal: the spec it records plus the outcomes of
/// every already-terminal cell.
fn load_resume(
    dir: &str,
) -> Result<(CampaignSpec, String, Vec<(usize, CellOutcome, CellExec)>), String> {
    let p = std::path::Path::new(dir);
    let path = if p.is_dir() { p.join("campaign.json") } else { p.to_path_buf() };
    let path = path
        .to_str()
        .ok_or_else(|| "--resume path is not valid UTF-8".to_string())?
        .to_string();
    let text =
        std::fs::read_to_string(&path).map_err(|e| format!("reading {path}: {e}"))?;
    let doc = json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let spec = CampaignSpec::from_artifact(&doc).map_err(|e| format!("{path}: {e}"))?;
    let preloaded = report::outcomes_from_artifact(&doc).map_err(|e| format!("{path}: {e}"))?;
    let total = spec.config_labels().len() * spec.workloads.len();
    eprintln!(
        "resuming campaign {} from {path}: {}/{total} cells already terminal",
        spec.name,
        preloaded.len(),
    );
    Ok((spec, path, preloaded))
}

fn cmd_sweep(a: &Args) -> ExitCode {
    let (spec, out, preloaded) = if let Some(dir) = &a.resume {
        if a.campaign.is_some()
            || a.spec_file.is_some()
            || !a.sets.is_empty()
            || a.out.is_some()
            || a.warmup.is_some()
        {
            eprintln!(
                "sweep: --resume re-runs the journaled campaign in place; it conflicts \
                 with --campaign/--spec/--set/--faults/--out/--warmup"
            );
            return ExitCode::from(EXIT_CONFIG);
        }
        match load_resume(dir) {
            Ok(x) => {
                // Oracle campaigns compare captured access streams, and
                // traces are never journaled — a resumed grid would mix
                // traced and trace-less cells. run_campaign refuses too;
                // catching it here gives the usage exit code.
                if x.0.oracle.is_some() {
                    eprintln!(
                        "sweep: cannot --resume an oracle campaign: access-stream \
                         traces are not journaled; rerun the campaign from scratch"
                    );
                    return ExitCode::from(EXIT_CONFIG);
                }
                x
            }
            Err(e) => {
                eprintln!("sweep: {e}");
                return ExitCode::from(EXIT_CONFIG);
            }
        }
    } else {
        let mut spec = match load_spec(a, None) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("sweep: {e}");
                return ExitCode::from(EXIT_CONFIG);
            }
        };
        // --warmup overrides (or supplies) the spec's warm-start fork
        // prefix; it is journaled with the spec, so --resume keeps it.
        if let Some(w) = a.warmup {
            spec.warmup = Some(w);
        }
        // Default artifact path (gate reads it back later).
        let out = a.out.clone().unwrap_or_else(|| "campaign.json".into());
        (spec, out, Vec::new())
    };
    match sweep_to_json(&spec, a, Some(&out), true, preloaded) {
        Ok((_, status)) => {
            if status.all_passed && status.oracle_ok {
                ExitCode::SUCCESS
            } else if status.any_timed_out {
                eprintln!(
                    "sweep: partial results — cells hit the {}s watchdog \
                     (rerun with `sweep --resume {out}`)",
                    a.timeout.unwrap_or(0),
                );
                ExitCode::from(EXIT_TIMEOUT)
            } else if !status.oracle_ok {
                eprintln!("sweep: access-stream oracle found divergence (see table / artifact)");
                ExitCode::from(EXIT_FAILURE)
            } else {
                eprintln!("sweep: some cells failed (see table / artifact)");
                ExitCode::from(EXIT_FAILURE)
            }
        }
        Err(e) => {
            eprintln!("sweep: {e}");
            ExitCode::from(EXIT_CONFIG)
        }
    }
}

fn cmd_gate(a: &Args) -> ExitCode {
    let Some(bpath) = &a.baseline else {
        eprintln!("gate: --baseline FILE required");
        usage()
    };
    if a.current.is_some()
        && (a.campaign.is_some()
            || a.spec_file.is_some()
            || !a.sets.is_empty()
            || a.jobs.is_some()
            || a.shards.is_some()
            || a.out.is_some())
    {
        eprintln!(
            "gate: --current conflicts with --campaign/--spec/--set/--jobs/--shards/--out \
             (nothing is re-run in --current mode)"
        );
        return ExitCode::from(EXIT_CONFIG);
    }
    let baseline_text = read_file_or_die(bpath);
    let tolerance = a.tolerance.unwrap_or(0.05);
    let current_text = if let Some(cpath) = &a.current {
        read_file_or_die(cpath)
    } else {
        // Re-run the exact campaign the baseline artifact records —
        // including its fixed overrides and custom axes, which a plain
        // name lookup would lose (overridable with --campaign/--spec).
        let fallback = if a.campaign.is_none() && a.spec_file.is_none() {
            match json::parse(&baseline_text).and_then(|v| CampaignSpec::from_artifact(&v)) {
                Ok(spec) => Some(spec),
                Err(e) => {
                    eprintln!(
                        "gate: cannot reconstruct the campaign from {bpath} ({e}); \
                         pass --campaign NAME or --spec FILE"
                    );
                    return ExitCode::from(EXIT_CONFIG);
                }
            }
        } else {
            None
        };
        let spec = match load_spec(a, fallback) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("gate: {e}");
                return ExitCode::from(EXIT_CONFIG);
            }
        };
        match sweep_to_json(&spec, a, a.out.as_deref(), false, Vec::new()) {
            Ok((text, _)) => text,
            Err(e) => {
                eprintln!("gate: {e}");
                return ExitCode::from(EXIT_CONFIG);
            }
        }
    };
    match gate::diff(&baseline_text, &current_text, tolerance) {
        Ok(rep) => {
            println!("{}", rep.describe());
            if rep.passed() {
                ExitCode::SUCCESS
            } else {
                // The distinct regression code: CI can tell "the gate
                // judged and failed the run" (3) from "the gate could
                // not judge at all" (2).
                ExitCode::from(EXIT_GATE)
            }
        }
        Err(e) => {
            eprintln!("gate: {e}");
            ExitCode::from(EXIT_CONFIG)
        }
    }
}

fn cmd_verify(a: &Args) -> ExitCode {
    let names: Vec<&str> = match a.workload.as_deref() {
        None | Some("all") => STANDARD.iter().chain(XTREME.iter()).copied().collect(),
        Some(w) => vec![w],
    };
    let cfg = build_config(a);
    for name in &names {
        if let Err(e) = halcone::workloads::validate_name(name) {
            eprintln!("verify: {e}");
            return ExitCode::from(EXIT_CONFIG);
        }
    }
    let mut rt = open_runtime(a);
    let mut ok = true;
    for name in names {
        let res = run_workload(&cfg, name, rt.as_mut());
        println!("{}", res.summary());
        ok &= res.all_passed();
    }
    println!("verify: {}", if ok { "ALL CHECKS PASSED" } else { "FAILURES" });
    if ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_list(a: &Args) -> ExitCode {
    println!("workloads (standard): {STANDARD:?}");
    println!("workloads (xtreme):   {XTREME:?}");
    println!("workloads (replay):   trace:<file> (recorded via --trace-out or trace-gen)");
    println!("workloads (mix):      mix:<t0>+<t1>+... or mix:<file>.mix (mix-gen; docs/TENANCY.md)");
    println!("trace-gen patterns:   {:?}", SharingPattern::NAMES);
    println!("presets:              {:?}", SystemConfig::PRESETS);
    println!("campaigns:            {:?}", CampaignSpec::BUILTINS);
    match Runtime::open(&a.artifacts) {
        Ok(rt) => println!("artifacts:            {:?}", rt.artifacts()),
        Err(_) => println!("artifacts:            (none — run `make artifacts`)"),
    }
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let args = parse_args();
    match args.command.as_str() {
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "sweep" => cmd_sweep(&args),
        "gate" => cmd_gate(&args),
        "verify" => cmd_verify(&args),
        "replay" => cmd_replay(&args),
        "trace-gen" => cmd_trace_gen(&args),
        "mix-gen" => cmd_mix_gen(&args),
        "print-config" => {
            println!("{}", build_config(&args).describe());
            ExitCode::SUCCESS
        }
        "list" => cmd_list(&args),
        _ => usage(),
    }
}
