//! HALCONE: a hardware-level timestamp-based cache coherence scheme for
//! multi-GPU systems — full-system reproduction.
//!
//! This crate contains:
//! - a cycle-level discrete-event MGPU simulator ([`sim`], [`mem`], [`gpu`],
//!   [`interconnect`], [`dram`]),
//! - the HALCONE timestamp coherence protocol and its baselines
//!   ([`coherence`], [`tsu`]),
//! - workload models for the paper's standard + Xtreme benchmarks
//!   ([`workloads`]),
//! - a PJRT runtime that executes AOT-compiled JAX/Pallas kernels as the
//!   functional golden model ([`runtime`]),
//! - the experiment coordinator, config system and metrics
//!   ([`coordinator`], [`config`], [`metrics`]),
//! - the campaign engine: declarative experiment grids, a parallel
//!   executor, JSON artifacts and a perf regression gate ([`sweep`]),
//! - deterministic trace capture, replay and synthesis: record CU memory
//!   streams, re-inject them on any protocol, generate sharing patterns
//!   ([`trace`], divergence oracle in [`metrics::divergence`]),
//! - multi-tenant serving: tenant-tagged requests, the `mix:` composer,
//!   an inter-kernel scheduler and per-tenant fairness metrics
//!   ([`tenancy`], [`coordinator::scheduler`], [`metrics::tenancy`]),
//! - deterministic fault injection: seeded link degradation/outage
//!   schedules and finite-width timestamp rollover ([`faults`],
//!   docs/ROBUSTNESS.md),
//! - integrity-checked engine snapshots and warm-start forking: freeze a
//!   paused simulation into a versioned checksummed file and continue it
//!   byte-identically ([`snapshot`], docs/SNAPSHOT.md).

pub mod coherence;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod faults;
pub mod gpu;
pub mod interconnect;
pub mod mem;
pub mod metrics;
pub mod proptools;
pub mod runtime;
pub mod sim;
pub mod snapshot;
pub mod sweep;
pub mod tenancy;
pub mod trace;
pub mod tsu;
pub mod workloads;
