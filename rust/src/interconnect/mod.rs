//! Switches and network fabric (DESIGN.md S3).
//!
//! Two instances matter for the paper:
//!
//! * the **PCIe switch** of the RDMA topologies (Fig. 1): 16 GT/s x 16 bit
//!   per transfer = 32 GB/s unidirectional, high latency;
//! * the **switch complex** of MGPU-SM (§3.1/§4.1): connects every GPU's
//!   L2 banks to every HBM stack; per-GPU L2-to-MM bandwidth 256 GB/s,
//!   aggregate capped by the per-stack HBM links (341 GB/s each).
//!
//! A [`Switch`] is a pure router: messages carry their final destination
//! (`dst`), the switch looks up the next hop and forwards, paying the next
//! hop link's serialization + latency. Multi-hop paths compose switches.

use std::collections::HashMap;

use crate::sim::{CompId, Component, Ctx, Cycle, LinkId, Msg};

/// Next hop for a destination: (link to traverse, component to deliver to).
pub type Hop = (LinkId, CompId);

/// A crossbar switch with a static routing table.
pub struct Switch {
    name: String,
    routes: HashMap<CompId, Hop>,
    default_route: Option<Hop>,
    /// Messages forwarded (metrics).
    pub forwarded: u64,
    /// Bytes forwarded (metrics).
    pub bytes: u64,
}

impl Switch {
    pub fn new(name: impl Into<String>) -> Self {
        Switch {
            name: name.into(),
            routes: HashMap::new(),
            default_route: None,
            forwarded: 0,
            bytes: 0,
        }
    }

    /// Route traffic destined for `dst` through `hop`.
    pub fn add_route(&mut self, dst: CompId, hop: Hop) {
        self.routes.insert(dst, hop);
    }

    /// Fallback next hop for unknown destinations (e.g. "toward the other
    /// switch" in multi-hop fabrics).
    pub fn set_default_route(&mut self, hop: Hop) {
        self.default_route = Some(hop);
    }

    fn hop_for(&self, dst: CompId) -> Hop {
        self.routes
            .get(&dst)
            .copied()
            .or(self.default_route)
            .unwrap_or_else(|| panic!("{}: no route to {:?}", self.name, dst))
    }

    fn forward(&mut self, dst: CompId, bytes: u64, msg: Msg, ctx: &mut Ctx) {
        let (link, next) = self.hop_for(dst);
        self.forwarded += 1;
        self.bytes += bytes;
        ctx.send(link, next, bytes, msg);
    }
}

impl Component for Switch {
    crate::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match &msg {
            Msg::Req(req) => {
                let (dst, bytes) = (req.dst, req.wire_bytes());
                self.forward(dst, bytes, msg, ctx);
            }
            Msg::Rsp(rsp) => {
                let (dst, bytes) = (rsp.dst, rsp.wire_bytes());
                self.forward(dst, bytes, msg, ctx);
            }
            Msg::Inv { dst, .. } => {
                let dst = *dst;
                self.forward(dst, 16, msg, ctx);
            }
            Msg::InvAck { dst, .. } => {
                let dst = *dst;
                self.forward(dst, 8, msg, ctx);
            }
            other => panic!("{}: cannot route {:?}", self.name, other),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format::put;
        put(out, self.forwarded);
        put(out, self.bytes);
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        self.forwarded = cur.u64("switch forwarded")?;
        self.bytes = cur.u64("switch bytes")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::LineBuf;
    use crate::sim::msg::{MemReq, MemRsp, ReqKind};
    use crate::sim::{Engine, Link};

    /// Sink that records deliveries.
    struct Sink {
        name: String,
        pub got: Vec<(Cycle, u64)>, // (time, req id)
    }
    impl Component for Sink {
    crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, _ctx: &mut Ctx) {
            match msg {
                Msg::Req(r) => self.got.push((now, r.id)),
                Msg::Rsp(r) => self.got.push((now, r.id)),
                _ => {}
            }
        }
    }

    fn req(id: u64, dst: CompId) -> Msg {
        Msg::Req(Box::new(MemReq {
            id,
            kind: ReqKind::Read,
            addr: 0x40,
            size: 64,
            src: CompId(0),
            dst,
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        }))
    }

    #[test]
    fn routes_by_destination() {
        let mut e = Engine::new();
        let l_a = e.add_link(Link::new("sw->a", 5, 32));
        let l_b = e.add_link(Link::new("sw->b", 50, 32));
        let sw_id = CompId(0);
        let a_id = CompId(1);
        let b_id = CompId(2);
        let mut sw = Switch::new("sw");
        sw.add_route(a_id, (l_a, a_id));
        sw.add_route(b_id, (l_b, b_id));
        e.add(Box::new(sw));
        e.add(Box::new(Sink { name: "a".into(), got: vec![] }));
        e.add(Box::new(Sink { name: "b".into(), got: vec![] }));
        e.post(0, sw_id, req(1, a_id));
        e.post(0, sw_id, req(2, b_id));
        e.run_to_completion();
        // 12-byte read request: 1 serialization cycle + latency.
        let a = e.component(a_id);
        let _ = a; // sinks checked via downcast-free approach below
        // Instead verify link counters.
        assert_eq!(e.link(l_a).msgs_sent, 1);
        assert_eq!(e.link(l_b).msgs_sent, 1);
    }

    #[test]
    fn default_route_used_for_unknown_dst() {
        let mut e = Engine::new();
        let l = e.add_link(Link::wire("sw->hub", 3));
        let sw_id = CompId(0);
        let hub_id = CompId(1);
        let mut sw = Switch::new("sw");
        sw.set_default_route((l, hub_id));
        e.add(Box::new(sw));
        e.add(Box::new(Sink { name: "hub".into(), got: vec![] }));
        e.post(0, sw_id, req(9, CompId(77)));
        e.run_to_completion();
        assert_eq!(e.link(l).msgs_sent, 1);
    }

    #[test]
    #[should_panic(expected = "no route")]
    fn unroutable_panics() {
        let mut e = Engine::new();
        let sw_id = CompId(0);
        e.add(Box::new(Switch::new("sw")));
        e.post(0, sw_id, req(1, CompId(5)));
        e.run_to_completion();
    }

    #[test]
    fn responses_route_on_rsp_dst() {
        let mut e = Engine::new();
        let l = e.add_link(Link::new("sw->a", 2, 64));
        let sw_id = CompId(0);
        let a_id = CompId(1);
        let mut sw = Switch::new("sw");
        sw.add_route(a_id, (l, a_id));
        e.add(Box::new(sw));
        e.add(Box::new(Sink { name: "a".into(), got: vec![] }));
        e.post(
            0,
            sw_id,
            Msg::Rsp(Box::new(MemRsp {
                id: 3,
                kind: ReqKind::Read,
                addr: 0,
                dst: a_id,
                data: LineBuf::zeroed(64),
                ts: None,
            })),
        );
        e.run_to_completion();
        assert_eq!(e.link(l).msgs_sent, 1);
        assert_eq!(e.link(l).bytes_sent, 72); // 64 payload + 8 header
    }
}
