//! The mix composer: turn a parsed [`MixSpec`] into an ordinary
//! [`Workload`] plus the [`MixPlan`] the inter-kernel scheduler executes.
//!
//! # Slots and templates
//!
//! The machine's `total_cus` CUs are partitioned into `n_slots`
//! scheduling slots of `slot_width` flat gpu-major CUs each (slot `s` =
//! flat CUs `[s*W, (s+1)*W)`; a remainder `total_cus % W` idles). Each
//! tenant's stream is folded to the 1-GPU × `W`-CU slot geometry by the
//! replay remap (`trace/replay.rs`), yielding one **template** per
//! stream phase: the register programs a kernel launch runs on whichever
//! slot it is admitted to. The composed workload has
//! `n_templates * n_slots` phases — phase `k*n_slots + s` runs template
//! `k` on slot `s` and leaves every other CU idle — so the scheduler
//! dispatches any kernel to any slot with the stock
//! `StartPhase`/`PhaseDone` machinery and zero new message kinds.
//!
//! # Tenant windows
//!
//! Tenant `t` owns the disjoint window at partition-relative offset
//! `[t*wsize, (t+1)*wsize)` of GPU partition `t % n_gpus`, where
//! `wsize = (gpu_mem_bytes / n_tenants)` aligned down to 4 KiB. Folded
//! stream addresses (all in `[0, gpu_mem_bytes)` after the replay
//! remap) are shifted by `rehome(t*gmb + t*wsize + addr, gmb, n_gpus)`
//! — the same partition-relative fold replay uses — which both spreads
//! tenants across partitions and guarantees streams never alias.
//! Disjoint windows are also what makes the mix **fence-free**: with no
//! cross-tenant sharing, inter-kernel visibility is vacuous, and
//! intra-stream coherence still exercises the protocols (HALCONE lease
//! expiry against the TSU's per-access `memts` advance) exactly as a
//! replayed trace does.

use crate::sim::Cycle;
use crate::tenancy::{MixSpec, Policy, StreamSpec};
use crate::trace::{generate, replay_workload, SynthSpec, Trace};
use crate::workloads::{Phase, Workload, WorkloadParams};

/// Tenant-window alignment (one page).
const WINDOW_ALIGN: u64 = 4096;

/// Backstop against replica-count typos: a mix enqueueing more kernel
/// launches than this is almost certainly a mistake.
const MAX_JOBS: usize = 1 << 20;

/// One queued kernel launch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobSpec {
    pub tenant: u32,
    /// Template to run (composed phase = `template * n_slots + slot`).
    pub template: u32,
    /// Cycle at which the job becomes eligible for admission.
    pub arrival: Cycle,
    /// Index (into the plan's job list) of the same replica's previous
    /// kernel: stream phases run in order, so a job is eligible only
    /// once its predecessor finished. Always precedes the job in the
    /// sorted list.
    pub pred: Option<usize>,
}

/// Everything the scheduler and the metrics sweep need beyond the
/// [`Workload`] itself. The `Workload` struct is untouched — mix-aware
/// callers carry the plan alongside it.
#[derive(Clone, Debug)]
pub struct MixPlan {
    pub n_tenants: u32,
    pub tenant_names: Vec<String>,
    /// CUs per scheduling slot.
    pub slot_width: u32,
    pub n_slots: u32,
    pub n_templates: u32,
    /// Owning tenant of each composed phase (`n_templates * n_slots`
    /// entries) — the CU issue path tags requests with this.
    pub phase_tenants: Vec<u32>,
    /// Queued kernels sorted by (arrival, tenant, spec order); `pred`
    /// indices refer to this order.
    pub jobs: Vec<JobSpec>,
    pub policy: Policy,
}

/// Compose the `mix:` workload `name` under geometry `p`. Returns the
/// schedulable workload plus its plan; all validation (spec grammar,
/// stream probing, window-fit) happens here, never mid-run.
pub fn compose(name: &str, p: &WorkloadParams) -> Result<(Workload, MixPlan), String> {
    let spec = MixSpec::parse(name)?;
    let n_tenants = spec.tenants.len();
    let total = p.total_cus();

    let width = spec.width.unwrap_or(((total / n_tenants).max(1)) as u32);
    let w = width as usize;
    if w > total {
        return Err(format!(
            "mix slot width {w} exceeds the machine's {total} CUs \
             ({} GPUs x {} CUs)",
            p.n_gpus, p.cus_per_gpu
        ));
    }
    let n_slots = total / w;

    let gmb = p.map.gpu_mem_bytes;
    let wsize = (gmb / n_tenants as u64) / WINDOW_ALIGN * WINDOW_ALIGN;
    if wsize < WINDOW_ALIGN {
        return Err(format!(
            "{n_tenants} tenants leave under {WINDOW_ALIGN} B of private window \
             per tenant in a {gmb} B GPU partition; use fewer tenants or a \
             larger gpu_mem_bytes"
        ));
    }

    // Fold every tenant's stream to the slot geometry: 1 GPU x `w` CUs
    // over the same partition size, so folded addresses land in
    // [0, gmb) and the window shift below can place them.
    let slot_params = WorkloadParams {
        n_gpus: 1,
        cus_per_gpu: width,
        wavefronts_per_cu: p.wavefronts_per_cu,
        map: crate::mem::AddrMap::new(
            crate::mem::addr::Topology::SharedMem,
            1,
            p.map.stacks_per_gpu,
            p.map.l2_banks,
            gmb,
        ),
        scale: p.scale,
    };

    // Templates across all tenants, each `w` per-CU wavefront-program
    // lists, plus the composed init image.
    let mut templates: Vec<Vec<Vec<Vec<crate::gpu::CuOp>>>> = Vec::new();
    let mut template_tenant: Vec<u32> = Vec::new();
    let mut tenant_template_base: Vec<u32> = Vec::new();
    let mut init: Vec<(u64, Vec<f32>)> = Vec::new();

    for (ti, t) in spec.tenants.iter().enumerate() {
        let terr = |e: String| format!("mix tenant '{}' (t{ti}): {e}", t.name);
        let trace = tenant_trace(&t.stream, ti, &slot_params)?;
        let mut folded = replay_workload(&format!("{name}#{}", t.name), &trace, &slot_params)
            .map_err(&terr)?;

        let window = TenantWindow { tenant: ti as u64, gmb, wsize, n_gpus: p.n_gpus as u64 };
        tenant_template_base.push(templates.len() as u32);
        for ph in &mut folded.phases {
            let cus = std::mem::take(&mut ph.work[0]);
            let mut remapped = Vec::with_capacity(cus.len());
            for wfs in cus {
                let wfs = wfs
                    .into_iter()
                    .map(|ops| window.remap_ops(ops).map_err(&terr))
                    .collect::<Result<Vec<_>, _>>()?;
                remapped.push(wfs);
            }
            templates.push(remapped);
            template_tenant.push(ti as u32);
        }
        for (addr, vals) in folded.init {
            let at = window.remap(addr, 4 * vals.len() as u64).map_err(&terr)?;
            init.push((at, vals));
        }
    }

    let n_templates = templates.len() as u32;

    // Composed phase grid: phase `k * n_slots + s` runs template `k` on
    // slot `s` (flat gpu-major CUs [s*w, (s+1)*w)); every other CU gets
    // an empty program and reports PhaseDone immediately.
    let mut phases = Vec::with_capacity(templates.len() * n_slots);
    let mut phase_tenants = Vec::with_capacity(templates.len() * n_slots);
    for (k, tmpl) in templates.iter().enumerate() {
        for s in 0..n_slots {
            let mut work: Vec<Vec<Vec<Vec<crate::gpu::CuOp>>>> = (0..p.n_gpus)
                .map(|_| vec![Vec::new(); p.cus_per_gpu as usize])
                .collect();
            for (j, cu_prog) in tmpl.iter().enumerate() {
                let flat = s * w + j;
                work[flat / p.cus_per_gpu as usize][flat % p.cus_per_gpu as usize] =
                    cu_prog.clone();
            }
            let ti = template_tenant[k] as usize;
            phases.push(Phase {
                name: format!("{}.k{k}@slot{s}", spec.tenants[ti].name),
                work,
            });
            phase_tenants.push(template_tenant[k]);
        }
    }

    // Queued kernels: each replica of a tenant's stream is a chain of
    // its templates in order, arriving `spacing` cycles after the
    // previous replica.
    let mut jobs: Vec<JobSpec> = Vec::new();
    for (ti, t) in spec.tenants.iter().enumerate() {
        let base = tenant_template_base[ti];
        let n_ph = if (ti + 1) < tenant_template_base.len() {
            tenant_template_base[ti + 1] - base
        } else {
            n_templates - base
        };
        for r in 0..t.replicas {
            let arrival = t.arrival + r as Cycle * t.spacing;
            for j in 0..n_ph {
                let pred = (j > 0).then(|| jobs.len() - 1);
                jobs.push(JobSpec { tenant: ti as u32, template: base + j, arrival, pred });
            }
        }
    }
    if jobs.len() > MAX_JOBS {
        return Err(format!(
            "mix enqueues {} kernel launches (cap {MAX_JOBS}); lower the \
             replica counts",
            jobs.len()
        ));
    }

    // Admission order: (arrival, tenant, spec order). The stable sort
    // keeps chain predecessors ahead of their successors (same arrival
    // and tenant, earlier spec order), so `pred` always points backward.
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&i| (jobs[i].arrival, jobs[i].tenant));
    let mut new_index = vec![0usize; jobs.len()];
    for (pos, &old) in order.iter().enumerate() {
        new_index[old] = pos;
    }
    let jobs: Vec<JobSpec> = order
        .iter()
        .map(|&old| JobSpec { pred: jobs[old].pred.map(|p| new_index[p]), ..jobs[old] })
        .collect();

    let wl = Workload {
        name: name.to_string(),
        init,
        phases,
        checks: Vec::new(),
        kind: "Mix",
    };
    let plan = MixPlan {
        n_tenants: n_tenants as u32,
        tenant_names: spec.tenants.iter().map(|t| t.name.clone()).collect(),
        slot_width: width,
        n_slots: n_slots as u32,
        n_templates,
        phase_tenants,
        jobs,
        policy: spec.policy,
    };
    Ok((wl, plan))
}

/// Produce tenant `ti`'s trace: generate the synthetic pattern directly
/// at the slot geometry, or load a recorded file (folded later).
fn tenant_trace(stream: &StreamSpec, ti: usize, slot: &WorkloadParams) -> Result<Trace, String> {
    match stream {
        StreamSpec::Synth(pat) => {
            let spec = SynthSpec {
                pattern: *pat,
                n_gpus: 1,
                cus_per_gpu: slot.cus_per_gpu,
                wavefronts_per_cu: slot.wavefronts_per_cu.max(1),
                gpu_mem_bytes: slot.map.gpu_mem_bytes,
                // Same ops scaling as `halcone trace-gen --scale`.
                ops_per_wavefront: ((64.0 * slot.scale).ceil() as u32).max(4),
                // Tenant-salted seed: replicas of one tenant share a
                // stream; different tenants get decorrelated ones.
                seed: SynthSpec::default().seed ^ ti as u64,
                ..SynthSpec::default()
            };
            generate(&spec)
        }
        StreamSpec::Trace(path) => crate::trace::load(path),
    }
}

/// Tenant `t`'s private window: partition `t % n_gpus`, offsets
/// `[t*wsize, (t+1)*wsize)` — applied via replay's partition-relative
/// `rehome`, of which this is a pure shift for in-window addresses.
struct TenantWindow {
    tenant: u64,
    gmb: u64,
    wsize: u64,
    n_gpus: u64,
}

impl TenantWindow {
    fn remap(&self, addr: u64, size: u64) -> Result<u64, String> {
        if addr + size > self.wsize {
            return Err(format!(
                "folded stream touches {addr:#x}+{size} B, beyond the tenant's \
                 {} B window (gpu_mem_bytes {} / {} tenants, {WINDOW_ALIGN} B \
                 aligned); use fewer tenants, a smaller stream, or a larger \
                 gpu_mem_bytes",
                self.wsize,
                self.gmb,
                self.gmb / self.wsize.max(1)
            ));
        }
        Ok(crate::trace::replay::rehome(
            self.tenant * self.gmb + self.tenant * self.wsize + addr,
            self.gmb,
            self.n_gpus,
        ))
    }

    fn remap_ops(
        &self,
        ops: Vec<crate::gpu::CuOp>,
    ) -> Result<Vec<crate::gpu::CuOp>, String> {
        use crate::gpu::CuOp;
        ops.into_iter()
            .map(|op| {
                Ok(match op {
                    CuOp::Ld { reg, addr } => CuOp::Ld { reg, addr: self.remap(addr, 4)? },
                    CuOp::LdV { reg, addr, n } => {
                        CuOp::LdV { reg, addr: self.remap(addr, 4 * n as u64)?, n }
                    }
                    CuOp::St { addr, reg } => CuOp::St { addr: self.remap(addr, 4)?, reg },
                    CuOp::StV { addr, reg, n } => {
                        CuOp::StV { addr: self.remap(addr, 4 * n as u64)?, reg, n }
                    }
                    other => other,
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::CuOp;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;

    const GMB: u64 = 1 << 22;

    fn params() -> WorkloadParams {
        WorkloadParams {
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, 2, 2, 2, GMB),
            scale: 0.1,
        }
    }

    fn op_extents(ops: &[CuOp]) -> Vec<(u64, u64)> {
        ops.iter()
            .filter_map(|op| match *op {
                CuOp::Ld { addr, .. } => Some((addr, 4)),
                CuOp::LdV { addr, n, .. } => Some((addr, 4 * n as u64)),
                CuOp::St { addr, .. } => Some((addr, 4)),
                CuOp::StV { addr, n, .. } => Some((addr, 4 * n as u64)),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn two_tenant_mix_composes_slots_and_windows() {
        let p = params();
        let (wl, plan) = compose("mix:read-mostly+false-sharing@64", &p).unwrap();
        assert_eq!(wl.kind, "Mix");
        assert!(wl.checks.is_empty(), "mix runs are replay-style: no checks");
        // Default width: 4 CUs / 2 tenants = 2; two slots.
        assert_eq!(plan.slot_width, 2);
        assert_eq!(plan.n_slots, 2);
        assert_eq!(plan.n_templates, 2, "one phase per synth tenant");
        assert_eq!(wl.phases.len(), 4, "templates x slots");
        assert_eq!(plan.phase_tenants, vec![0, 0, 1, 1]);
        // Phase k*n_slots+s populates exactly slot s's CUs.
        let ph = &wl.phases[1]; // template 0, slot 1 = flat CUs 2..4
        assert!(ph.work[0][0].is_empty() && ph.work[0][1].is_empty());
        assert!(!ph.work[1][0].is_empty() && !ph.work[1][1].is_empty());
        // Tenant windows: wsize = GMB/2 (4 KiB aligned); tenant 0 in
        // partition 0 offsets [0, wsize), tenant 1 in partition 1
        // offsets [wsize, 2*wsize).
        let wsize = (GMB / 2) / 4096 * 4096;
        for (k, tmpl_phase) in [(0usize, 0usize), (2, 0)] {
            let _ = tmpl_phase;
            let tenant = plan.phase_tenants[k] as u64;
            let lo = (tenant % 2) * GMB + tenant * wsize;
            for gpu in &wl.phases[k].work {
                for cu in gpu {
                    for wf in cu {
                        for (a, sz) in op_extents(wf) {
                            assert!(
                                a >= lo && a + sz <= lo + wsize,
                                "tenant {tenant} op at {a:#x} outside window"
                            );
                        }
                    }
                }
            }
        }
        // Init slices land in the windows too.
        for (addr, vals) in &wl.init {
            let end = addr + 4 * vals.len() as u64;
            let in_t0 = *addr < wsize;
            let in_t1 = *addr >= GMB + wsize && end <= GMB + 2 * wsize;
            assert!(in_t0 && end <= wsize || in_t1, "init at {addr:#x} stray");
        }
        // Jobs: tenant 0 arrives at 0, tenant 1 at 64.
        assert_eq!(plan.jobs.len(), 2);
        assert_eq!(plan.jobs[0], JobSpec { tenant: 0, template: 0, arrival: 0, pred: None });
        assert_eq!(plan.jobs[1], JobSpec { tenant: 1, template: 1, arrival: 64, pred: None });
    }

    #[test]
    fn replicas_chain_and_sort_keeps_preds_backward() {
        let p = params();
        let (_, plan) = compose("mix:private*3+read-mostly@5", &p).unwrap();
        assert_eq!(plan.jobs.len(), 4);
        // Burst replicas of tenant 0 chain in order at arrival 0.
        assert_eq!(plan.jobs[0].pred, None);
        assert_eq!(plan.jobs[1], JobSpec { tenant: 0, template: 0, arrival: 0, pred: None });
        // Single-template replicas have no intra-chain pred, but spec
        // order is preserved among equal keys (stable sort).
        assert!(plan.jobs[..3].iter().all(|j| j.tenant == 0));
        assert_eq!(plan.jobs[3].tenant, 1);
        assert_eq!(plan.jobs[3].arrival, 5);
        for (i, j) in plan.jobs.iter().enumerate() {
            if let Some(pr) = j.pred {
                assert!(pr < i, "pred points backward");
            }
        }
    }

    #[test]
    fn file_spec_spacing_spreads_replica_arrivals() {
        let dir = std::env::temp_dir().join("halcone_mix_spec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("two.mix");
        std::fs::write(
            &path,
            "policy = rr\nwidth = 2\n\
             tenant.victim.stream = read-mostly\n\
             tenant.noisy.stream = false-sharing\n\
             tenant.noisy.replicas = 3\n\
             tenant.noisy.spacing = 10\n",
        )
        .unwrap();
        let (_, plan) = compose(&format!("mix:{}", path.display()), &params()).unwrap();
        assert_eq!(plan.policy, Policy::RoundRobin);
        assert_eq!(plan.tenant_names, vec!["victim", "noisy"]);
        let noisy: Vec<Cycle> =
            plan.jobs.iter().filter(|j| j.tenant == 1).map(|j| j.arrival).collect();
        assert_eq!(noisy, vec![0, 10, 20]);
    }

    #[test]
    fn oversized_width_and_shattered_windows_are_compose_errors() {
        let p = params();
        let e = compose("mix:private+private", &{
            let mut q = p.clone();
            q.map.gpu_mem_bytes = 4096;
            q
        })
        .unwrap_err();
        assert!(e.contains("tenants"), "{e}");

        let dir = std::env::temp_dir().join("halcone_mix_spec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wide.mix");
        std::fs::write(&path, "width = 64\ntenant.a.stream = private\n").unwrap();
        let e = compose(&format!("mix:{}", path.display()), &p).unwrap_err();
        assert!(e.contains("exceeds"), "{e}");
    }

    #[test]
    fn footprint_past_the_window_names_the_tenant() {
        // 512 tenants in a 4 MiB partition -> 4 KiB windows, far below
        // the synth footprint.
        let spec = "mix:".to_string() + &vec!["private"; 512].join("+");
        let e = compose(&spec, &params()).unwrap_err();
        assert!(e.contains("window") || e.contains("tenants"), "{e}");
    }

    #[test]
    fn composition_is_deterministic() {
        let p = params();
        let (a, pa) = compose("mix:read-mostly+false-sharing@64", &p).unwrap();
        let (b, pb) = compose("mix:read-mostly+false-sharing@64", &p).unwrap();
        assert_eq!(pa.jobs, pb.jobs);
        assert_eq!(pa.phase_tenants, pb.phase_tenants);
        assert_eq!(a.phases.len(), b.phases.len());
        for (x, y) in a.phases.iter().zip(&b.phases) {
            assert_eq!(x.work, y.work);
        }
        assert_eq!(a.init, b.init);
    }
}
