//! Multi-tenant serving: the tenant model, mix-spec parsing and the mix
//! composer (docs/TENANCY.md).
//!
//! A **tenant** is one user's stream of kernels — recorded
//! (`trace:<file>`) or synthetic (`trace/synth.rs` sharing patterns) —
//! rehomed into a private, disjoint window of every GPU partition. The
//! `mix:` pseudo-workload composes N tenant streams into one deterministic
//! multi-tenant serving scenario: kernels queue with per-tenant arrival
//! offsets and replication counts, and the inter-kernel scheduler
//! (`coordinator/scheduler.rs`) admits them onto CU slots as capacity
//! frees up. Like every workload, a mix is byte-identical at any
//! `--shards`/jobs level.
//!
//! Two spec forms exist behind the `mix:` prefix:
//!
//! * **Inline**: `mix:<pattern>[@<arrival>][*<replicas>]+<tenant2>+...`
//!   where `<pattern>` is a synthetic sharing pattern
//!   (`trace/synth.rs`), `@<arrival>` a queue-arrival cycle (default 0)
//!   and `*<replicas>` a replication count (default 1). Example:
//!   `mix:read-mostly@0*4+false-sharing@512`.
//! * **File**: `mix:<path>` where the path contains a separator or ends
//!   in `.mix` — a key=value spec (written by `halcone mix-gen`) that
//!   additionally supports recorded-trace tenants, arrival spacing, a
//!   scheduler policy and a slot width. See [`MixSpec::to_spec_string`].

pub mod compose;

pub use compose::{compose, JobSpec, MixPlan};

use crate::sim::Cycle;
use crate::trace::SharingPattern;

/// Tenant identifier: the index of the tenant in its mix spec. Ordinary
/// (single-application) runs use tenant 0 implicitly.
pub type TenantId = u32;

/// Prefix of the multi-tenant mix pseudo-workload form.
pub const MIX_PREFIX: &str = "mix:";

/// Whether `name` is syntactically a mix workload.
pub fn is_mix(name: &str) -> bool {
    name.starts_with(MIX_PREFIX)
}

/// Inter-kernel scheduling policy (see `coordinator/scheduler.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Policy {
    /// Earliest arrival first (ties: lowest tenant, then spec order).
    #[default]
    Fifo,
    /// Rotate admission across tenants with eligible kernels.
    RoundRobin,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy, String> {
        match s {
            "fifo" => Ok(Policy::Fifo),
            "rr" | "round-robin" => Ok(Policy::RoundRobin),
            other => Err(format!("unknown scheduler policy '{other}': use 'fifo' or 'rr'")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::RoundRobin => "rr",
        }
    }
}

/// Where a tenant's kernel stream comes from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamSpec {
    /// Generated sharing pattern (`trace/synth.rs`), sized to the slot
    /// geometry at compose time.
    Synth(SharingPattern),
    /// Recorded or pre-generated trace file, folded to the slot geometry
    /// by the replay remap (`trace/replay.rs`).
    Trace(String),
}

impl StreamSpec {
    fn parse(s: &str) -> Result<StreamSpec, String> {
        if let Some(path) = s.strip_prefix("trace:") {
            if path.is_empty() {
                return Err("empty trace path in tenant stream".into());
            }
            return Ok(StreamSpec::Trace(path.to_string()));
        }
        let pat = s.strip_prefix("synth:").unwrap_or(s);
        SharingPattern::parse(pat).map(StreamSpec::Synth)
    }

    fn spec_string(&self) -> String {
        match self {
            StreamSpec::Synth(p) => format!("synth:{}", p.name()),
            StreamSpec::Trace(path) => format!("trace:{path}"),
        }
    }
}

/// One tenant's row in a mix spec.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    pub stream: StreamSpec,
    /// Cycle at which the tenant's kernels join the queue.
    pub arrival: Cycle,
    /// How many copies of the stream's kernel chain to enqueue.
    pub replicas: u32,
    /// Arrival gap between consecutive replicas (0 = burst arrival).
    pub spacing: Cycle,
}

/// A parsed mix spec (inline or file form).
#[derive(Clone, Debug)]
pub struct MixSpec {
    pub tenants: Vec<TenantSpec>,
    pub policy: Policy,
    /// CUs per scheduling slot; defaults to `total_cus / n_tenants`.
    pub width: Option<u32>,
}

fn form_error(detail: &str) -> String {
    format!(
        "{detail}; valid mix forms are \
         'mix:<pattern>[@<arrival>][*<replicas>]+<tenant2>+...' with \
         patterns {:?}, or 'mix:<file>.mix' for a spec file \
         (docs/TENANCY.md)",
        SharingPattern::NAMES
    )
}

impl MixSpec {
    /// Parse the full `mix:...` workload name (either form).
    pub fn parse(name: &str) -> Result<MixSpec, String> {
        let body = name
            .strip_prefix(MIX_PREFIX)
            .ok_or_else(|| form_error(&format!("'{name}' lacks the '{MIX_PREFIX}' prefix")))?;
        if body.is_empty() {
            return Err(form_error("empty mix spec"));
        }
        // Only the `.mix` suffix selects the file form: inline tenant
        // streams may themselves be `trace:<path>` with separators.
        if body.ends_with(".mix") {
            let text = std::fs::read_to_string(body)
                .map_err(|e| format!("cannot read mix spec '{body}': {e}"))?;
            Self::parse_file(&text).map_err(|e| format!("mix spec '{body}': {e}"))
        } else {
            Self::parse_inline(body)
        }
    }

    /// Inline form: `+`-separated `<pattern>[@<arrival>][*<replicas>]`.
    fn parse_inline(body: &str) -> Result<MixSpec, String> {
        let mut tenants = Vec::new();
        for (i, term) in body.split('+').enumerate() {
            if term.is_empty() {
                return Err(form_error(&format!("empty tenant term in 'mix:{body}'")));
            }
            let (head, replicas) = match term.split_once('*') {
                Some((h, r)) => (
                    h,
                    r.parse::<u32>()
                        .map_err(|_| form_error(&format!("bad replica count '{r}' in '{term}'")))?,
                ),
                None => (term, 1),
            };
            let (pat, arrival) = match head.split_once('@') {
                Some((p, a)) => (
                    p,
                    a.parse::<Cycle>()
                        .map_err(|_| form_error(&format!("bad arrival cycle '{a}' in '{term}'")))?,
                ),
                None => (head, 0),
            };
            let stream = StreamSpec::parse(pat).map_err(|e| form_error(&e))?;
            if replicas == 0 {
                return Err(form_error(&format!("'{term}' asks for zero replicas")));
            }
            // '-' separator, not '.': tenant names must survive the file
            // form's dotted `tenant.<name>.<field>` keys (mix-gen writes
            // inline-parsed specs out as files).
            let name = match &stream {
                StreamSpec::Synth(p) => format!("t{i}-{}", p.name()),
                StreamSpec::Trace(_) => format!("t{i}-trace"),
            };
            tenants.push(TenantSpec { name, stream, arrival, replicas, spacing: 0 });
        }
        Ok(MixSpec { tenants, policy: Policy::Fifo, width: None })
    }

    /// File form: key=value lines, `#` comments. Tenant order is
    /// first-mention order, which fixes the `TenantId` assignment.
    fn parse_file(text: &str) -> Result<MixSpec, String> {
        let mut spec = MixSpec { tenants: Vec::new(), policy: Policy::Fifo, width: None };
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .map(|(k, v)| (k.trim(), v.trim()))
                .ok_or_else(|| format!("line {}: expected 'key = value'", lineno + 1))?;
            let err = |e: String| format!("line {}: {e}", lineno + 1);
            match key {
                "policy" => spec.policy = Policy::parse(value).map_err(err)?,
                "width" => {
                    let w: u32 = value
                        .parse()
                        .map_err(|_| err(format!("bad slot width '{value}'")))?;
                    if w == 0 {
                        return Err(err("slot width must be at least 1".into()));
                    }
                    spec.width = Some(w);
                }
                _ => {
                    let mut parts = key.splitn(3, '.');
                    let (kind, tname, field) =
                        (parts.next().unwrap_or(""), parts.next(), parts.next());
                    let (Some(tname), Some(field)) = (tname, field) else {
                        return Err(err(form_error(&format!("unknown key '{key}'"))));
                    };
                    if kind != "tenant" || tname.is_empty() {
                        return Err(err(form_error(&format!("unknown key '{key}'"))));
                    }
                    let t = match spec.tenants.iter_mut().find(|t| t.name == tname) {
                        Some(t) => t,
                        None => {
                            spec.tenants.push(TenantSpec {
                                name: tname.to_string(),
                                stream: StreamSpec::Synth(SharingPattern::Private),
                                arrival: 0,
                                replicas: 1,
                                spacing: 0,
                            });
                            spec.tenants.last_mut().unwrap()
                        }
                    };
                    match field {
                        "stream" => t.stream = StreamSpec::parse(value).map_err(err)?,
                        "arrival" => {
                            t.arrival = value
                                .parse()
                                .map_err(|_| err(format!("bad arrival '{value}'")))?
                        }
                        "replicas" => {
                            t.replicas = value
                                .parse()
                                .map_err(|_| err(format!("bad replicas '{value}'")))?;
                            if t.replicas == 0 {
                                return Err(err("replicas must be at least 1".into()));
                            }
                        }
                        "spacing" => {
                            t.spacing = value
                                .parse()
                                .map_err(|_| err(format!("bad spacing '{value}'")))?
                        }
                        other => {
                            return Err(err(format!(
                                "unknown tenant field '{other}' (stream/arrival/replicas/spacing)"
                            )))
                        }
                    }
                }
            }
        }
        if spec.tenants.is_empty() {
            return Err(form_error("spec file declares no tenants"));
        }
        Ok(spec)
    }

    /// Serialize to the file form (`halcone mix-gen` output). Parsing the
    /// result reproduces the spec exactly.
    pub fn to_spec_string(&self) -> String {
        let mut out = String::from("# halcone mix spec (docs/TENANCY.md)\n");
        out.push_str(&format!("policy = {}\n", self.policy.name()));
        if let Some(w) = self.width {
            out.push_str(&format!("width = {w}\n"));
        }
        for t in &self.tenants {
            out.push_str(&format!("tenant.{}.stream = {}\n", t.name, t.stream.spec_string()));
            out.push_str(&format!("tenant.{}.arrival = {}\n", t.name, t.arrival));
            out.push_str(&format!("tenant.{}.replicas = {}\n", t.name, t.replicas));
            out.push_str(&format!("tenant.{}.spacing = {}\n", t.name, t.spacing));
        }
        out
    }
}

/// Deep validation for campaign specs (`workloads::validate_name`):
/// parse the spec and probe every recorded-trace tenant's header, so a
/// bad mix fails at spec-parse time, never mid-campaign.
pub fn validate(name: &str) -> Result<(), String> {
    let spec = MixSpec::parse(name)?;
    for t in &spec.tenants {
        if let StreamSpec::Trace(path) = &t.stream {
            crate::trace::load_meta(path)
                .map(|_| ())
                .map_err(|e| format!("tenant '{}': {e}", t.name))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_form_parses_defaults_and_modifiers() {
        let s = MixSpec::parse("mix:read-mostly@0*4+false-sharing@512").unwrap();
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants[0].stream, StreamSpec::Synth(SharingPattern::ReadMostly));
        assert_eq!(s.tenants[0].arrival, 0);
        assert_eq!(s.tenants[0].replicas, 4);
        assert_eq!(s.tenants[1].arrival, 512);
        assert_eq!(s.tenants[1].replicas, 1);
        assert_eq!(s.policy, Policy::Fifo);
        let one = MixSpec::parse("mix:private").unwrap();
        assert_eq!(one.tenants.len(), 1);
        assert_eq!(one.tenants[0].name, "t0-private");
    }

    #[test]
    fn inline_form_rejects_malformed_terms_with_the_form_list() {
        for bad in [
            "mix:",
            "mix:notapattern",
            "mix:read-mostly@x",
            "mix:read-mostly*0",
            "mix:read-mostly++private",
            "mix:read-mostly*many",
        ] {
            let e = MixSpec::parse(bad).unwrap_err();
            assert!(e.contains("mix:<pattern>"), "{bad}: {e}");
            assert!(e.contains("read-mostly"), "{bad} error lists patterns: {e}");
        }
    }

    #[test]
    fn file_form_round_trips_through_spec_string() {
        let spec = MixSpec {
            tenants: vec![
                TenantSpec {
                    name: "victim".into(),
                    stream: StreamSpec::Synth(SharingPattern::ReadMostly),
                    arrival: 0,
                    replicas: 2,
                    spacing: 100,
                },
                TenantSpec {
                    name: "noisy".into(),
                    stream: StreamSpec::Synth(SharingPattern::FalseSharing),
                    arrival: 64,
                    replicas: 5,
                    spacing: 0,
                },
            ],
            policy: Policy::RoundRobin,
            width: Some(2),
        };
        let text = spec.to_spec_string();
        let back = MixSpec::parse_file(&text).unwrap();
        assert_eq!(back.policy, Policy::RoundRobin);
        assert_eq!(back.width, Some(2));
        assert_eq!(back.tenants.len(), 2);
        assert_eq!(back.tenants[0].name, "victim");
        assert_eq!(back.tenants[0].spacing, 100);
        assert_eq!(back.tenants[1].arrival, 64);
        assert_eq!(back.tenants[1].replicas, 5);
        assert_eq!(back.tenants[1].stream, StreamSpec::Synth(SharingPattern::FalseSharing));
    }

    #[test]
    fn file_form_rejects_unknown_keys_and_fields() {
        let e = MixSpec::parse_file("bogus = 1\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        let e = MixSpec::parse_file("tenant.a.color = red\n").unwrap_err();
        assert!(e.contains("unknown tenant field"), "{e}");
        let e = MixSpec::parse_file("policy = lifo\n").unwrap_err();
        assert!(e.contains("fifo"), "{e}");
        let e = MixSpec::parse_file("# only comments\n").unwrap_err();
        assert!(e.contains("no tenants"), "{e}");
    }

    #[test]
    fn validate_probes_missing_trace_tenants() {
        validate("mix:read-mostly+private").unwrap();
        let e = validate("mix:trace:/definitely/missing.trc+private").unwrap_err();
        assert!(e.contains("missing.trc"), "{e}");
        // A missing spec file fails with its path.
        let e = validate("mix:/no/such/file.mix").unwrap_err();
        assert!(e.contains("file.mix"), "{e}");
    }

    #[test]
    fn policy_parse_and_names() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("rr").unwrap(), Policy::RoundRobin);
        assert_eq!(Policy::parse("round-robin").unwrap(), Policy::RoundRobin);
        assert!(Policy::parse("lifo").is_err());
        assert_eq!(Policy::RoundRobin.name(), "rr");
    }
}
