//! Deterministic trace capture & replay (ISSUE 5).
//!
//! HALCONE's evaluation replays identical memory-access streams across
//! protocols and topologies; MGSim/MGMark showed a multi-GPU framework
//! becomes far more useful once workload *execution* is decoupled from
//! *traffic generation*. This module is that decoupling layer:
//!
//! * **Record** — `halcone run --trace-out FILE` captures every CU-issued
//!   memory operation (phase, wavefront, kind, address, size, issue cycle
//!   and the compute *gap* since the wavefront's previous memory op) into
//!   a compact dependency-free binary format ([`format`]) with a
//!   versioned header and per-GPU/per-CU streams. The tap lives in the
//!   CU issue path and buffers per component, so the assembled trace is
//!   a pure function of the simulated configuration — byte-identical at
//!   every `--shards` level, exactly like the simulation itself.
//! * **Replay** — the `trace:<file>` pseudo-workload
//!   ([`replay`]) reconstructs per-wavefront register programs from the
//!   stream (compute gaps become [`crate::gpu::CuOp::Delay`] ops, which
//!   contribute identical issue latency and zero events) and re-injects
//!   them through the unmodified coherence/cache/TSU stack, on any
//!   protocol and — partition sizes permitting — a folded GPU/CU count.
//!   Replaying a trace under its recording configuration reproduces the
//!   original cycle count and event count *exactly*: timing in this
//!   simulator depends on addresses, sizes, ordering and issue gaps,
//!   never on data values.
//! * **Synthesize** — `halcone trace-gen` ([`synth`]) emits parameterized
//!   sharing patterns (private, read-mostly, migratory, false-sharing,
//!   all-to-all) that the hand-written workload models cannot express,
//!   opening protocol stress scenarios without writing Rust.
//!
//! Divergence between two traces (e.g. a recording and its replay) is
//! quantified by [`crate::metrics::divergence`] — the per-access
//! regression oracle behind the CI golden-trace gate.

pub mod format;
pub mod replay;
pub mod synth;

pub use format::{decode, encode, load, load_meta, save, FORMAT_VERSION};
pub use replay::replay_workload;
pub use synth::{generate, SharingPattern, SynthSpec};

/// What kind of record a [`TraceOp`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// CU load (scalar or coalesced vector; `size` distinguishes).
    Load,
    /// CU store.
    Store,
    /// Wavefront retirement marker: carries the trailing compute gap and
    /// flags that the wavefront had a (possibly compute-only) program.
    End,
}

/// One captured CU event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceOp {
    /// Kernel-launch phase the op was issued in.
    pub phase: u32,
    /// Wavefront slot within the issuing CU.
    pub wf: u32,
    pub kind: TraceKind,
    /// Byte address (0 for `End`).
    pub addr: u64,
    /// Access bytes: 4 for scalar ops, `4*n` for coalesced vector ops
    /// (0 for `End`).
    pub size: u32,
    /// Issue-latency cycles the wavefront accumulated (ALU ops, explicit
    /// delays) since its previous memory op in the same phase. Replay
    /// re-inserts this as a `Delay` op, reproducing issue timing exactly.
    pub gap: u64,
    /// CU-local issue cycle in the recorded run. Pure metadata for the
    /// divergence report — replay timing derives from `gap`, never from
    /// this field.
    pub cycle: u64,
}

/// Trace-wide header: recording geometry plus recorded-run totals.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceMeta {
    /// Workload the trace was recorded from (or `synth-<pattern>`).
    pub workload: String,
    pub n_gpus: u32,
    pub cus_per_gpu: u32,
    pub wavefronts_per_cu: u32,
    pub n_phases: u32,
    /// Bytes per GPU partition at recording time. Replay requires the
    /// same partition size (addresses are rehomed partition-relative on
    /// GPU-count folds).
    pub gpu_mem_bytes: u64,
    /// End-to-end cycles of the recording run (0 = unknown/synthetic).
    pub cycles: u64,
    /// Engine events of the recording run (0 = unknown/synthetic).
    pub events: u64,
    /// Initial-image layout as (address, f32 count). Values are not
    /// recorded — they never affect timing — but the layout reproduces
    /// the RDMA host-copy delay, which is charged per homed byte.
    pub init: Vec<(u64, u64)>,
}

/// A complete trace: header plus `[gpu][cu]` record streams, each in
/// that CU's local issue order (deterministic at every shard count).
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub meta: TraceMeta,
    pub streams: Vec<Vec<Vec<TraceOp>>>,
}

impl TraceMeta {
    /// Cheap header sanity: bounds every count that sizes an allocation,
    /// so a corrupt header fails before the decoder reserves anything.
    pub fn check_bounds(&self) -> Result<(), String> {
        if self.n_gpus == 0 || self.cus_per_gpu == 0 {
            return Err("trace header has a zero GPU or CU count".into());
        }
        if self.gpu_mem_bytes == 0 {
            return Err("trace header has gpu_mem_bytes = 0".into());
        }
        if self.n_gpus > MAX_GEOMETRY
            || self.cus_per_gpu > MAX_GEOMETRY
            || self.wavefronts_per_cu > MAX_GEOMETRY
            || self.n_phases > MAX_GEOMETRY
        {
            return Err(format!(
                "trace header geometry {}x{}x{} / {} phases is absurd",
                self.n_gpus, self.cus_per_gpu, self.wavefronts_per_cu, self.n_phases
            ));
        }
        // Bound the partition size so `gpu_mem_bytes * n_gpus` (the
        // address-space extent every access is validated against) cannot
        // overflow on a crafted header.
        if self.gpu_mem_bytes > MAX_PARTITION_BYTES {
            return Err(format!(
                "trace header gpu_mem_bytes {} is absurd",
                self.gpu_mem_bytes
            ));
        }
        Ok(())
    }
}

impl Trace {
    /// Total records across all streams (including `End` markers).
    pub fn total_records(&self) -> u64 {
        self.streams
            .iter()
            .flat_map(|g| g.iter())
            .map(|cu| cu.len() as u64)
            .sum()
    }

    /// Total memory operations (loads + stores, excluding `End`).
    pub fn total_ops(&self) -> u64 {
        self.streams
            .iter()
            .flat_map(|g| g.iter())
            .flat_map(|cu| cu.iter())
            .filter(|op| op.kind != TraceKind::End)
            .count() as u64
    }

    /// Structural sanity shared by the decoder and the replayer (synthetic
    /// traces are built in memory and never pass through `decode`).
    pub fn validate(&self) -> Result<(), String> {
        let m = &self.meta;
        m.check_bounds()?;
        if self.streams.len() != m.n_gpus as usize {
            return Err(format!(
                "trace has {} GPU streams but the header says {}",
                self.streams.len(),
                m.n_gpus
            ));
        }
        let total = m.gpu_mem_bytes * m.n_gpus as u64;
        for (g, gpu) in self.streams.iter().enumerate() {
            if gpu.len() != m.cus_per_gpu as usize {
                return Err(format!(
                    "gpu {g} has {} CU streams but the header says {}",
                    gpu.len(),
                    m.cus_per_gpu
                ));
            }
            for (c, ops) in gpu.iter().enumerate() {
                for (i, op) in ops.iter().enumerate() {
                    let at = format!("gpu{g}.cu{c} record {i}");
                    if op.phase >= m.n_phases {
                        return Err(format!(
                            "{at}: phase {} out of range (header has {} phases)",
                            op.phase, m.n_phases
                        ));
                    }
                    if op.wf >= MAX_WAVEFRONT {
                        return Err(format!("{at}: wavefront {} is absurd", op.wf));
                    }
                    match op.kind {
                        TraceKind::End => {
                            if op.addr != 0 || op.size != 0 {
                                return Err(format!("{at}: End record carries addr/size"));
                            }
                        }
                        TraceKind::Load | TraceKind::Store => {
                            if op.size == 0 || op.size > 64 || op.size % 4 != 0 {
                                return Err(format!("{at}: bad access size {}", op.size));
                            }
                            let end = match op.addr.checked_add(op.size as u64) {
                                Some(end) if end <= total => end,
                                _ => {
                                    return Err(format!(
                                        "{at}: address {:#x} is outside the recorded \
                                         {} x {} B space",
                                        op.addr, m.n_gpus, m.gpu_mem_bytes
                                    ))
                                }
                            };
                            if op.addr / 64 != (end - 1) / 64 {
                                return Err(format!(
                                    "{at}: access at {:#x}+{} crosses a cache line",
                                    op.addr, op.size
                                ));
                            }
                        }
                    }
                }
            }
        }
        for (i, &(addr, n)) in m.init.iter().enumerate() {
            if n > MAX_INIT_F32 {
                return Err(format!("init slice {i}: {n} f32s is absurd"));
            }
            match addr.checked_add(4 * n) {
                Some(end) if end <= total => {}
                _ => {
                    return Err(format!(
                        "init slice {i} at {addr:#x}+{n} f32s is outside the address space"
                    ))
                }
            }
        }
        Ok(())
    }
}

/// Upper bound on a sane wavefront index (guards slot-grid allocation).
pub const MAX_WAVEFRONT: u32 = 1 << 16;

/// Upper bound on any header geometry count (guards decoder allocation).
pub const MAX_GEOMETRY: u32 = 1 << 12;

/// Upper bound on one GPU partition (256 TB — keeps the address-space
/// extent `gpu_mem_bytes * n_gpus` far from u64 overflow).
pub const MAX_PARTITION_BYTES: u64 = 1 << 48;

/// Upper bound on one init slice (guards replay's zero-fill allocation).
pub const MAX_INIT_F32: u64 = 1 << 28;

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Trace {
        Trace {
            meta: TraceMeta {
                workload: "t".into(),
                n_gpus: 1,
                cus_per_gpu: 1,
                wavefronts_per_cu: 1,
                n_phases: 1,
                gpu_mem_bytes: 1 << 20,
                cycles: 10,
                events: 5,
                init: vec![(0x1000, 4)],
            },
            streams: vec![vec![vec![
                TraceOp {
                    phase: 0,
                    wf: 0,
                    kind: TraceKind::Load,
                    addr: 0x1000,
                    size: 64,
                    gap: 2,
                    cycle: 3,
                },
                TraceOp {
                    phase: 0,
                    wf: 0,
                    kind: TraceKind::End,
                    addr: 0,
                    size: 0,
                    gap: 0,
                    cycle: 9,
                },
            ]]],
        }
    }

    #[test]
    fn valid_trace_passes_and_counts_ops() {
        let t = tiny();
        t.validate().unwrap();
        assert_eq!(t.total_records(), 2);
        assert_eq!(t.total_ops(), 1);
    }

    #[test]
    fn validate_rejects_structural_corruption() {
        let mut t = tiny();
        t.streams[0][0][0].phase = 7;
        assert!(t.validate().unwrap_err().contains("phase"));

        let mut t = tiny();
        t.streams[0][0][0].size = 6;
        assert!(t.validate().unwrap_err().contains("size"));

        let mut t = tiny();
        t.streams[0][0][0].addr = 0x1020; // 64B starting mid-line
        assert!(t.validate().unwrap_err().contains("crosses"));

        let mut t = tiny();
        t.streams[0][0][0].addr = (1 << 20) - 4;
        t.streams[0][0][0].size = 64;
        assert!(t.validate().is_err());

        let mut t = tiny();
        t.streams.push(Vec::new());
        assert!(t.validate().unwrap_err().contains("GPU streams"));

        let mut t = tiny();
        t.meta.init[0] = (0, MAX_INIT_F32 + 1);
        assert!(t.validate().unwrap_err().contains("init"));

        let mut t = tiny();
        t.streams[0][0][1].size = 4; // End with payload
        assert!(t.validate().unwrap_err().contains("End"));

        // A crafted partition size must be a clean error, not an
        // address-space-extent overflow.
        let mut t = tiny();
        t.meta.gpu_mem_bytes = u64::MAX / 2;
        assert!(t.validate().unwrap_err().contains("gpu_mem_bytes"));
    }
}
