//! The on-disk trace format: dependency-free, compact, versioned.
//!
//! Layout (all integers are LEB128 varints unless noted):
//!
//! ```text
//! magic      8 raw bytes  "HALCTRC\0"
//! version    varint       FORMAT_VERSION (readers reject anything else)
//! workload   varint len + UTF-8 bytes
//! geometry   n_gpus, cus_per_gpu, wavefronts_per_cu, n_phases
//! space      gpu_mem_bytes
//! totals     cycles, events          (0 = unknown, e.g. synthetic)
//! init       count, then (addr, f32 count) pairs
//! streams    n_gpus x { cus_per_gpu x { count, then records } }
//! record     tag byte (0 load / 1 store / 2 end),
//!            phase, wf, gap, cycle, then addr + size for load/store
//! ```
//!
//! Compatibility rules: the version is bumped on *any* layout change —
//! there are no in-band extensions — and readers reject unknown versions
//! with a regenerate hint rather than guessing (docs/TRACE.md).

use crate::trace::{Trace, TraceKind, TraceMeta, TraceOp};

/// Current (and only) format version.
pub const FORMAT_VERSION: u64 = 1;

const MAGIC: &[u8; 8] = b"HALCTRC\0";

fn put(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn tag(kind: TraceKind) -> u8 {
    match kind {
        TraceKind::Load => 0,
        TraceKind::Store => 1,
        TraceKind::End => 2,
    }
}

/// Serialize a trace (the writer assumes a [`Trace::validate`]-clean
/// input; the recorder and generator only produce such traces).
pub fn encode(t: &Trace) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + 8 * t.total_records() as usize);
    out.extend_from_slice(MAGIC);
    put(&mut out, FORMAT_VERSION);
    let m = &t.meta;
    put_str(&mut out, &m.workload);
    put(&mut out, m.n_gpus as u64);
    put(&mut out, m.cus_per_gpu as u64);
    put(&mut out, m.wavefronts_per_cu as u64);
    put(&mut out, m.n_phases as u64);
    put(&mut out, m.gpu_mem_bytes);
    put(&mut out, m.cycles);
    put(&mut out, m.events);
    put(&mut out, m.init.len() as u64);
    for &(addr, n) in &m.init {
        put(&mut out, addr);
        put(&mut out, n);
    }
    for gpu in &t.streams {
        for ops in gpu {
            put(&mut out, ops.len() as u64);
            for op in ops {
                out.push(tag(op.kind));
                put(&mut out, op.phase as u64);
                put(&mut out, op.wf as u64);
                put(&mut out, op.gap);
                put(&mut out, op.cycle);
                if op.kind != TraceKind::End {
                    put(&mut out, op.addr);
                    put(&mut out, op.size as u64);
                }
            }
        }
    }
    out
}

struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl Cur<'_> {
    fn byte(&mut self, what: &str) -> Result<u8, String> {
        let v = *self
            .b
            .get(self.i)
            .ok_or_else(|| format!("truncated trace: EOF reading {what} at byte {}", self.i))?;
        self.i += 1;
        Ok(v)
    }

    fn u64(&mut self, what: &str) -> Result<u64, String> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.byte(what)?;
            if shift >= 64 || (shift == 63 && byte > 1) {
                return Err(format!("varint overflow reading {what} at byte {}", self.i));
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        let v = self.u64(what)?;
        u32::try_from(v).map_err(|_| format!("{what} value {v} exceeds 32 bits"))
    }

    fn str(&mut self, what: &str) -> Result<String, String> {
        let n = self.u64(what)? as usize;
        if n > 4096 {
            return Err(format!("{what} string length {n} is absurd"));
        }
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| format!("truncated trace: EOF in {what} string"))?;
        let s = std::str::from_utf8(&self.b[self.i..end])
            .map_err(|e| format!("{what} is not UTF-8: {e}"))?
            .to_string();
        self.i = end;
        Ok(s)
    }
}

/// Parse just the header of a serialized trace (cheap existence /
/// compatibility probe for campaign-spec validation).
pub fn decode_meta(bytes: &[u8]) -> Result<TraceMeta, String> {
    let mut c = Cur { b: bytes, i: 0 };
    read_meta(&mut c)
}

fn read_meta(c: &mut Cur) -> Result<TraceMeta, String> {
    if c.b.len() < MAGIC.len() || &c.b[..MAGIC.len()] != MAGIC {
        return Err("not a HALCONE trace (bad magic)".into());
    }
    c.i = MAGIC.len();
    let version = c.u64("version")?;
    if version != FORMAT_VERSION {
        return Err(format!(
            "trace format version {version} is not the supported {FORMAT_VERSION}; \
             regenerate the trace with this binary"
        ));
    }
    let workload = c.str("workload")?;
    let n_gpus = c.u32("n_gpus")?;
    let cus_per_gpu = c.u32("cus_per_gpu")?;
    let wavefronts_per_cu = c.u32("wavefronts_per_cu")?;
    let n_phases = c.u32("n_phases")?;
    let gpu_mem_bytes = c.u64("gpu_mem_bytes")?;
    let cycles = c.u64("cycles")?;
    let events = c.u64("events")?;
    let n_init = c.u64("init count")? as usize;
    if n_init > 1 << 24 {
        return Err(format!("init slice count {n_init} is absurd"));
    }
    let mut init = Vec::with_capacity(n_init);
    for _ in 0..n_init {
        let addr = c.u64("init addr")?;
        let n = c.u64("init len")?;
        init.push((addr, n));
    }
    Ok(TraceMeta {
        workload,
        n_gpus,
        cus_per_gpu,
        wavefronts_per_cu,
        n_phases,
        gpu_mem_bytes,
        cycles,
        events,
        init,
    })
}

/// Parse a full serialized trace, validating structure on the way in.
pub fn decode(bytes: &[u8]) -> Result<Trace, String> {
    let mut c = Cur { b: bytes, i: 0 };
    let meta = read_meta(&mut c)?;
    meta.check_bounds()?;
    let mut streams = Vec::with_capacity(meta.n_gpus as usize);
    for g in 0..meta.n_gpus {
        let mut gpu = Vec::with_capacity(meta.cus_per_gpu as usize);
        for cu in 0..meta.cus_per_gpu {
            let what = format!("gpu{g}.cu{cu}");
            let n = c.u64(&format!("{what} record count"))? as usize;
            if n > bytes.len() {
                // Each record is at least 5 bytes; a count beyond the
                // input size is corruption, not a big trace.
                return Err(format!("{what}: record count {n} exceeds the input size"));
            }
            let mut ops = Vec::with_capacity(n);
            for i in 0..n {
                let what = format!("{what} record {i}");
                let kind = match c.byte(&what)? {
                    0 => TraceKind::Load,
                    1 => TraceKind::Store,
                    2 => TraceKind::End,
                    t => return Err(format!("{what}: unknown record tag {t}")),
                };
                let phase = c.u32(&what)?;
                let wf = c.u32(&what)?;
                let gap = c.u64(&what)?;
                let cycle = c.u64(&what)?;
                let (addr, size) = if kind == TraceKind::End {
                    (0, 0)
                } else {
                    (c.u64(&what)?, c.u32(&what)?)
                };
                ops.push(TraceOp { phase, wf, kind, addr, size, gap, cycle });
            }
            gpu.push(ops);
        }
        streams.push(gpu);
    }
    if c.i != c.b.len() {
        return Err(format!("trailing garbage after the trace at byte {}", c.i));
    }
    let t = Trace { meta, streams };
    t.validate()?;
    Ok(t)
}

/// Write a trace to `path`.
pub fn save(t: &Trace, path: &str) -> Result<(), String> {
    std::fs::write(path, encode(t)).map_err(|e| format!("writing trace {path}: {e}"))
}

/// Read and parse a trace file.
pub fn load(path: &str) -> Result<Trace, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("reading trace {path}: {e}"))?;
    decode(&bytes).map_err(|e| format!("trace {path}: {e}"))
}

/// Read and parse just a trace file's header.
pub fn load_meta(path: &str) -> Result<TraceMeta, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("reading trace {path}: {e}"))?;
    decode_meta(&bytes).map_err(|e| format!("trace {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Rng;

    fn arbitrary_trace(seed: u64, gpus: u32, cus: u32) -> Trace {
        let mut rng = Rng(seed);
        let gmb = 1u64 << 22;
        let n_phases = 2;
        let streams = (0..gpus)
            .map(|_| {
                (0..cus)
                    .map(|_| {
                        let mut ops = Vec::new();
                        for wf in 0..2u32 {
                            for phase in 0..n_phases {
                                for _ in 0..rng.below(6) {
                                    let line = rng.below(gpus as u64 * gmb / 64 - 1);
                                    let size = 4 * (1 + rng.below(16)) as u32;
                                    let kind = if rng.below(2) == 0 {
                                        TraceKind::Load
                                    } else {
                                        TraceKind::Store
                                    };
                                    ops.push(TraceOp {
                                        phase,
                                        wf,
                                        kind,
                                        addr: line * 64 + (64 - size as u64),
                                        size,
                                        gap: rng.below(1000),
                                        cycle: rng.below(1 << 40),
                                    });
                                }
                                ops.push(TraceOp {
                                    phase,
                                    wf,
                                    kind: TraceKind::End,
                                    addr: 0,
                                    size: 0,
                                    gap: rng.below(10),
                                    cycle: rng.below(1 << 40),
                                });
                            }
                        }
                        ops
                    })
                    .collect()
            })
            .collect();
        Trace {
            meta: TraceMeta {
                workload: format!("arb{seed}"),
                n_gpus: gpus,
                cus_per_gpu: cus,
                wavefronts_per_cu: 2,
                n_phases,
                gpu_mem_bytes: gmb,
                cycles: rng.below(1 << 50),
                events: rng.below(1 << 50),
                init: vec![(0x1000, 64), (gmb + 0x1000, 17)],
            },
            streams,
        }
    }

    #[test]
    fn roundtrip_preserves_arbitrary_traces() {
        for seed in [1u64, 7, 0xDEAD, 0x5EED] {
            let t = arbitrary_trace(seed, 2, 3);
            t.validate().unwrap();
            let bytes = encode(&t);
            let back = decode(&bytes).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(back, t, "seed {seed}");
        }
    }

    #[test]
    fn meta_decodes_without_streams() {
        let t = arbitrary_trace(3, 1, 2);
        let bytes = encode(&t);
        assert_eq!(decode_meta(&bytes).unwrap(), t.meta);
    }

    #[test]
    fn rejects_bad_magic_version_truncation_and_trailing() {
        let t = arbitrary_trace(9, 1, 1);
        let good = encode(&t);

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(decode(&bad).unwrap_err().contains("magic"));

        let mut bad = good.clone();
        bad[8] = 99; // version varint
        assert!(decode(&bad).unwrap_err().contains("version 99"));

        for cut in [4, 12, good.len() / 2, good.len() - 1] {
            assert!(decode(&good[..cut]).is_err(), "cut at {cut} must fail");
        }

        let mut bad = good.clone();
        bad.push(0);
        assert!(decode(&bad).unwrap_err().contains("trailing"));
    }

    #[test]
    fn varint_boundaries_roundtrip() {
        let mut out = Vec::new();
        let vals = [0, 1, 127, 128, 16383, 16384, u64::MAX - 1, u64::MAX];
        for &v in &vals {
            put(&mut out, v);
        }
        let mut c = Cur { b: &out, i: 0 };
        for &v in &vals {
            assert_eq!(c.u64("v").unwrap(), v);
        }
        assert_eq!(c.i, out.len());
    }
}
