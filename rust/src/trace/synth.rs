//! Synthetic trace generation: parameterized sharing patterns the
//! hand-written workload models cannot express.
//!
//! Each pattern emits per-wavefront streams over a shared working set of
//! `lines` cache lines per GPU partition (base [`SHARED_BASE`]) plus
//! per-wavefront private regions, with a configurable compute `gap`
//! between consecutive ops. Generation is a pure function of
//! [`SynthSpec`] (splitmix64-seeded), so a spec is as reproducible as a
//! recorded trace.
//!
//! Patterns:
//! * **private** — every wavefront streams over its own lines; no
//!   sharing, the coherence-free baseline.
//! * **read-mostly** — all wavefronts read GPU 0's shared region; a
//!   single writer wavefront occasionally updates it (lease-friendly).
//! * **migratory** — wavefronts take turns read-modify-writing the same
//!   lines in bursts, so exclusive ownership migrates rank to rank.
//! * **false-sharing** — each wavefront hammers its own 4-byte word of
//!   the *same* lines (word-disjoint, line-shared).
//! * **all-to-all** — every wavefront reads every GPU's region in
//!   rotation and writes its own (the NUMA stress case).

use crate::trace::{Trace, TraceKind, TraceMeta, TraceOp};
use crate::workloads::Rng;

/// Base of each GPU partition's shared region (past the unmapped page 0).
pub const SHARED_BASE: u64 = 0x1000;

/// Which sharing structure to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SharingPattern {
    Private,
    ReadMostly,
    Migratory,
    FalseSharing,
    AllToAll,
}

impl SharingPattern {
    /// CLI names, in presentation order.
    pub const NAMES: [&str; 5] =
        ["private", "read-mostly", "migratory", "false-sharing", "all-to-all"];

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "private" => Ok(SharingPattern::Private),
            "read-mostly" => Ok(SharingPattern::ReadMostly),
            "migratory" => Ok(SharingPattern::Migratory),
            "false-sharing" => Ok(SharingPattern::FalseSharing),
            "all-to-all" => Ok(SharingPattern::AllToAll),
            other => Err(format!("unknown pattern '{other}' (one of {:?})", Self::NAMES)),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SharingPattern::Private => "private",
            SharingPattern::ReadMostly => "read-mostly",
            SharingPattern::Migratory => "migratory",
            SharingPattern::FalseSharing => "false-sharing",
            SharingPattern::AllToAll => "all-to-all",
        }
    }
}

/// Generator parameters (geometry usually copied from a `SystemConfig`).
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub pattern: SharingPattern,
    pub n_gpus: u32,
    pub cus_per_gpu: u32,
    pub wavefronts_per_cu: u32,
    pub gpu_mem_bytes: u64,
    /// Memory ops per wavefront per phase.
    pub ops_per_wavefront: u32,
    /// Shared working-set size in 64 B cache lines (per GPU region).
    pub lines: u32,
    /// Compute cycles between consecutive memory ops.
    pub gap: u32,
    pub phases: u32,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            pattern: SharingPattern::Private,
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            gpu_mem_bytes: 64 << 20,
            ops_per_wavefront: 64,
            lines: 64,
            gap: 0,
            phases: 1,
            seed: 0xA11CE,
        }
    }
}

/// One wavefront's op list for one phase.
fn wavefront_ops(
    s: &SynthSpec,
    phase: u32,
    g: u32,
    c: u32,
    wf: u32,
    rank: u64,
    rng: &mut Rng,
) -> Vec<TraceOp> {
    let lines = s.lines.max(1) as u64;
    let shared = |gpu: u64, line: u64| gpu * s.gpu_mem_bytes + SHARED_BASE + line * 64;
    // Private region: disjoint per rank, homed on the issuing GPU, placed
    // past every GPU's shared region.
    let private_base = g as u64 * s.gpu_mem_bytes
        + SHARED_BASE
        + lines * 64
        + (c as u64 * s.wavefronts_per_cu as u64 + wf as u64) * lines * 64;
    let mut ops = Vec::with_capacity(s.ops_per_wavefront as usize + 1);
    let mut emit = |kind: TraceKind, addr: u64, size: u32| {
        ops.push(TraceOp { phase, wf, kind, addr, size, gap: s.gap as u64, cycle: 0 });
    };
    for i in 0..s.ops_per_wavefront as u64 {
        match s.pattern {
            SharingPattern::Private => {
                let addr = private_base + (i % lines) * 64;
                let kind = if i % 2 == 0 { TraceKind::Load } else { TraceKind::Store };
                emit(kind, addr, 64);
            }
            SharingPattern::ReadMostly => {
                let addr = shared(0, rng.below(lines));
                // One designated writer rank refreshes a line every 16th
                // op; everyone else only reads.
                if rank == 0 && i % 16 == 15 {
                    emit(TraceKind::Store, addr, 64);
                } else {
                    emit(TraceKind::Load, addr, 64);
                }
            }
            SharingPattern::Migratory => {
                // Bursts of read-modify-write on a line set whose owner
                // rotates with the burst index: ownership migrates.
                let burst = 4;
                let line = (rank + i / burst) % lines;
                let addr = shared(0, line);
                let kind = if i % 2 == 0 { TraceKind::Load } else { TraceKind::Store };
                emit(kind, addr, 64);
            }
            SharingPattern::FalseSharing => {
                // Word-disjoint, line-shared: each rank owns word
                // `rank % 16` of every shared line.
                let addr = shared(0, i % lines) + (rank % 16) * 4;
                let kind = if i % 4 == 0 { TraceKind::Load } else { TraceKind::Store };
                emit(kind, addr, 4);
            }
            SharingPattern::AllToAll => {
                if i % 4 == 3 {
                    // Write back into the issuing GPU's own region.
                    emit(TraceKind::Store, shared(g as u64, (rank + i) % lines), 64);
                } else {
                    // Read a rotating remote (or local) GPU's region.
                    let peer = (g as u64 + 1 + i) % s.n_gpus as u64;
                    emit(TraceKind::Load, shared(peer, (rank * 7 + i) % lines), 64);
                }
            }
        }
    }
    ops.push(TraceOp {
        phase,
        wf,
        kind: TraceKind::End,
        addr: 0,
        size: 0,
        gap: 0,
        cycle: 0,
    });
    ops
}

/// Generate a synthetic trace. Errors on geometry that cannot hold the
/// requested working set.
pub fn generate(s: &SynthSpec) -> Result<Trace, String> {
    if s.n_gpus == 0 || s.cus_per_gpu == 0 || s.wavefronts_per_cu == 0 {
        return Err("trace-gen: geometry must have at least one GPU/CU/wavefront".into());
    }
    if s.phases == 0 || s.ops_per_wavefront == 0 {
        return Err("trace-gen: need at least one phase and one op per wavefront".into());
    }
    if s.gpu_mem_bytes % 64 != 0 {
        return Err("trace-gen: gpu_mem_bytes must be a multiple of the 64 B line".into());
    }
    let lines = s.lines.max(1) as u64;
    let ranks_per_gpu = s.cus_per_gpu as u64 * s.wavefronts_per_cu as u64;
    let footprint = SHARED_BASE + lines * 64 * (1 + ranks_per_gpu);
    if footprint > s.gpu_mem_bytes {
        return Err(format!(
            "trace-gen: {lines} lines x {ranks_per_gpu} wavefronts need {footprint} B \
             per GPU partition, but gpu_mem_bytes is {}",
            s.gpu_mem_bytes
        ));
    }
    let mut streams = Vec::with_capacity(s.n_gpus as usize);
    for g in 0..s.n_gpus {
        let mut gpu = Vec::with_capacity(s.cus_per_gpu as usize);
        for c in 0..s.cus_per_gpu {
            let mut ops = Vec::new();
            for phase in 0..s.phases {
                for wf in 0..s.wavefronts_per_cu {
                    let rank = (g as u64 * s.cus_per_gpu as u64 + c as u64)
                        * s.wavefronts_per_cu as u64
                        + wf as u64;
                    // Per-wavefront generator stream: records stay
                    // reproducible under any emission order.
                    let mut rng = Rng(s.seed ^ (rank << 20) ^ (phase as u64));
                    ops.extend(wavefront_ops(s, phase, g, c, wf, rank, &mut rng));
                }
            }
            gpu.push(ops);
        }
        streams.push(gpu);
    }
    // Initial image: every GPU's shared region, so RDMA replays charge a
    // realistic host-copy delay.
    let init = (0..s.n_gpus as u64)
        .map(|g| (g * s.gpu_mem_bytes + SHARED_BASE, lines * 16))
        .collect();
    let t = Trace {
        meta: TraceMeta {
            workload: format!("synth-{}", s.pattern.name()),
            n_gpus: s.n_gpus,
            cus_per_gpu: s.cus_per_gpu,
            wavefronts_per_cu: s.wavefronts_per_cu,
            n_phases: s.phases,
            gpu_mem_bytes: s.gpu_mem_bytes,
            cycles: 0,
            events: 0,
            init,
        },
        streams,
    };
    t.validate()?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(pattern: SharingPattern) -> SynthSpec {
        SynthSpec { pattern, ops_per_wavefront: 32, lines: 8, ..Default::default() }
    }

    #[test]
    fn every_pattern_generates_a_valid_deterministic_trace() {
        for name in SharingPattern::NAMES {
            let s = spec(SharingPattern::parse(name).unwrap());
            let a = generate(&s).unwrap();
            let b = generate(&s).unwrap();
            assert_eq!(a, b, "{name}: generation must be deterministic");
            a.validate().unwrap();
            // 2 GPUs x 2 CUs x 2 wavefronts x 32 ops.
            assert_eq!(a.total_ops(), 8 * 32, "{name}");
            assert_eq!(a.meta.workload, format!("synth-{name}"));
            assert_eq!(a.meta.cycles, 0, "{name}: synthetic totals are unknown");
        }
    }

    #[test]
    fn private_never_shares_lines_false_sharing_always_does() {
        let lines_of = |t: &Trace| {
            let mut per_rank: Vec<std::collections::HashSet<u64>> = Vec::new();
            for gpu in &t.streams {
                for cu in gpu {
                    for wf in 0..2 {
                        let set = cu
                            .iter()
                            .filter(|o| o.wf == wf && o.kind != TraceKind::End)
                            .map(|o| o.addr / 64)
                            .collect();
                        per_rank.push(set);
                    }
                }
            }
            per_rank
        };
        let private = lines_of(&generate(&spec(SharingPattern::Private)).unwrap());
        for (i, a) in private.iter().enumerate() {
            for b in &private[i + 1..] {
                assert!(a.is_disjoint(b), "private ranks must not share lines");
            }
        }
        let fs = lines_of(&generate(&spec(SharingPattern::FalseSharing)).unwrap());
        assert!(
            fs.iter().skip(1).all(|s| s == &fs[0]),
            "false-sharing ranks must touch the same lines"
        );
    }

    #[test]
    fn read_mostly_has_a_single_writer() {
        let t = generate(&spec(SharingPattern::ReadMostly)).unwrap();
        let mut writers = std::collections::HashSet::new();
        for (g, gpu) in t.streams.iter().enumerate() {
            for (c, cu) in gpu.iter().enumerate() {
                for o in cu.iter().filter(|o| o.kind == TraceKind::Store) {
                    writers.insert((g, c, o.wf));
                }
            }
        }
        assert_eq!(writers.len(), 1, "exactly one writer rank: {writers:?}");
    }

    #[test]
    fn all_to_all_touches_every_gpu_partition() {
        let t = generate(&spec(SharingPattern::AllToAll)).unwrap();
        let gmb = t.meta.gpu_mem_bytes;
        let homes: std::collections::HashSet<u64> = t.streams[0][0]
            .iter()
            .filter(|o| o.kind != TraceKind::End)
            .map(|o| o.addr / gmb)
            .collect();
        assert_eq!(homes.len(), 2, "one CU's stream must reach both partitions");
    }

    #[test]
    fn oversized_working_sets_and_bad_names_error() {
        let mut s = spec(SharingPattern::Private);
        s.gpu_mem_bytes = 4096;
        assert!(generate(&s).unwrap_err().contains("partition"));
        assert!(SharingPattern::parse("mesi").is_err());
        let zero = SynthSpec { n_gpus: 0, ..Default::default() };
        assert!(generate(&zero).is_err());
    }
}
