//! Trace replay: reconstruct per-wavefront register programs from a
//! recorded stream and re-inject them through the unmodified
//! coherence/cache/TSU stack.
//!
//! # Exactness
//!
//! Under the recording geometry, replay reproduces the original run's
//! cycle count, event count and cache/MM counters *exactly*: every
//! memory op keeps its address, size, wavefront slot and program order,
//! and the compute between ops is re-inserted as a single
//! [`CuOp::Delay`] carrying the recorded issue-latency gap — which
//! contributes the identical issue delay and, like the ALU ops it
//! replaces, zero engine events. Store payloads are replayed as zeros
//! (data values never influence timing anywhere in the hierarchy), so
//! replayed runs carry no functional checks.
//!
//! # Remap
//!
//! Replaying on fewer GPUs/CUs than recorded *folds* the streams:
//! source GPU `g` maps to `g % n_gpus`, source CU `c` to
//! `c % cus_per_gpu`, and each folded source CU gets its own disjoint
//! block of wavefront slots on the target CU (stagger offsets shift, so
//! folded replays are deterministic but not cycle-comparable to the
//! recording). Addresses homed beyond the new GPU count are rehomed
//! partition-relative, which requires equal `gpu_mem_bytes`; extra
//! GPUs/CUs on the target simply idle.

use crate::gpu::CuOp;
use crate::trace::{Trace, TraceKind};
use crate::workloads::{Phase, Workload, WorkloadParams};

/// Fold `addr` into the first `n_gpus` partitions of `gmb` bytes each,
/// preserving the partition-relative offset. Also the placement
/// primitive of the tenant-window shift in `tenancy/compose.rs`.
pub(crate) fn rehome(addr: u64, gmb: u64, n_gpus: u64) -> u64 {
    let home = addr / gmb;
    if home < n_gpus {
        addr
    } else {
        (home % n_gpus) * gmb + addr % gmb
    }
}

/// Append `gap` cycles of compute as `Delay` ops (split only in the
/// absurd case of a gap beyond `u32::MAX`).
fn push_gap(list: &mut Vec<CuOp>, mut gap: u64) {
    while gap > u32::MAX as u64 {
        list.push(CuOp::Delay { cycles: u32::MAX });
        gap -= u32::MAX as u64;
    }
    if gap > 0 {
        list.push(CuOp::Delay { cycles: gap as u32 });
    }
}

/// Build the replay pseudo-workload for `t` under the target geometry in
/// `p`. `name` becomes the workload's reported name (the CLI passes the
/// `trace:<file>` form through).
pub fn replay_workload(name: &str, t: &Trace, p: &WorkloadParams) -> Result<Workload, String> {
    t.validate()?;
    let gmb = t.meta.gpu_mem_bytes;
    if p.map.gpu_mem_bytes != gmb {
        return Err(format!(
            "partition size mismatch: the trace was recorded with \
             gpu_mem_bytes={gmb} but this config requests {req}; the \
             partition-preserving GPU remap needs equal partition sizes — \
             either re-record the trace under the target geometry or set \
             the config's gpu_mem_bytes to {gmb}",
            req = p.map.gpu_mem_bytes
        ));
    }
    let (tg, tc) = (t.meta.n_gpus as usize, t.meta.cus_per_gpu as usize);
    let (g2, c2) = (p.n_gpus as usize, p.cus_per_gpu as usize);
    if g2 == 0 || c2 == 0 {
        return Err("replay target has no GPUs or no CUs".into());
    }

    // Wavefront-slot layout: each (source gpu fold, source cu fold) rank
    // owns a disjoint block of `stride` slots on its target CU.
    let folds_c = tc.div_ceil(c2);
    let max_wf = t
        .streams
        .iter()
        .flat_map(|g| g.iter())
        .flat_map(|cu| cu.iter())
        .map(|op| op.wf)
        .max()
        .unwrap_or(0) as usize;
    let stride = (max_wf + 1).max(t.meta.wavefronts_per_cu.max(1) as usize);
    let n_slots = tg.div_ceil(g2) * folds_c * stride;

    let n_phases = t.meta.n_phases as usize;
    let mut phases: Vec<Phase> = (0..n_phases)
        .map(|i| Phase {
            name: format!("replay{i}"),
            work: (0..g2)
                .map(|_| (0..c2).map(|_| vec![Vec::new(); n_slots]).collect())
                .collect(),
        })
        .collect();

    for (g, gstream) in t.streams.iter().enumerate() {
        for (c, ops) in gstream.iter().enumerate() {
            let rank = (g / g2) * folds_c + c / c2;
            for op in ops {
                let slot = rank * stride + op.wf as usize;
                let list = &mut phases[op.phase as usize].work[g % g2][c % c2][slot];
                push_gap(list, op.gap);
                match op.kind {
                    TraceKind::End => {
                        // Zero-cost marker: keeps a compute-only wavefront
                        // non-empty so the CU's active count (and with it
                        // the phase-completion timing) matches the
                        // recording.
                        list.push(CuOp::Delay { cycles: 0 });
                    }
                    TraceKind::Load => {
                        let addr = rehome(op.addr, gmb, g2 as u64);
                        if op.size == 4 {
                            list.push(CuOp::Ld { reg: 0, addr });
                        } else {
                            list.push(CuOp::LdV { reg: 0, addr, n: (op.size / 4) as u8 });
                        }
                    }
                    TraceKind::Store => {
                        let addr = rehome(op.addr, gmb, g2 as u64);
                        if op.size == 4 {
                            list.push(CuOp::St { addr, reg: 0 });
                        } else {
                            list.push(CuOp::StV { addr, reg: 0, n: (op.size / 4) as u8 });
                        }
                    }
                }
            }
        }
    }

    let init = t
        .meta
        .init
        .iter()
        .map(|&(addr, n)| (rehome(addr, gmb, g2 as u64), vec![0.0f32; n as usize]))
        .collect();

    Ok(Workload { name: name.to_string(), init, phases, checks: Vec::new(), kind: "Replay" })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;
    use crate::trace::{TraceMeta, TraceOp};

    fn params(n_gpus: u32, cus: u32) -> WorkloadParams {
        WorkloadParams {
            n_gpus,
            cus_per_gpu: cus,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, n_gpus, 2, 2, 1 << 22),
            scale: 1.0,
        }
    }

    fn op(phase: u32, wf: u32, kind: TraceKind, addr: u64, size: u32, gap: u64) -> TraceOp {
        TraceOp { phase, wf, kind, addr, size, gap, cycle: 0 }
    }

    fn two_gpu_trace() -> Trace {
        let gmb = 1u64 << 22;
        Trace {
            meta: TraceMeta {
                workload: "t".into(),
                n_gpus: 2,
                cus_per_gpu: 1,
                wavefronts_per_cu: 2,
                n_phases: 1,
                gpu_mem_bytes: gmb,
                cycles: 0,
                events: 0,
                init: vec![(0x1000, 8), (gmb + 0x1000, 8)],
            },
            streams: vec![
                vec![vec![
                    op(0, 0, TraceKind::Load, 0x1000, 64, 3),
                    op(0, 0, TraceKind::Store, 0x1040, 4, 0),
                    op(0, 0, TraceKind::End, 0, 0, 7),
                    op(0, 1, TraceKind::End, 0, 0, 0),
                ]],
                vec![vec![
                    op(0, 0, TraceKind::Load, gmb + 0x1000, 8, 0),
                    op(0, 0, TraceKind::End, 0, 0, 0),
                ]],
            ],
        }
    }

    #[test]
    fn programs_rebuild_with_gaps_and_end_markers() {
        let t = two_gpu_trace();
        let wl = replay_workload("trace:x", &t, &params(2, 1)).unwrap();
        assert_eq!(wl.name, "trace:x");
        assert_eq!(wl.phases.len(), 1);
        assert!(wl.checks.is_empty());
        let wf0 = &wl.phases[0].work[0][0][0];
        assert_eq!(
            *wf0,
            vec![
                CuOp::Delay { cycles: 3 },
                CuOp::LdV { reg: 0, addr: 0x1000, n: 16 },
                CuOp::St { addr: 0x1040, reg: 0 },
                CuOp::Delay { cycles: 7 },
                CuOp::Delay { cycles: 0 },
            ]
        );
        // Compute-only wavefront stays non-empty via the End marker.
        assert_eq!(wl.phases[0].work[0][0][1], vec![CuOp::Delay { cycles: 0 }]);
        // GPU 1's scalar-sized load is too small for a full line: LdV n=2.
        let g1 = &wl.phases[0].work[1][0][0];
        assert_eq!(g1[0], CuOp::LdV { reg: 0, addr: (1 << 22) + 0x1000, n: 2 });
        // Init layout survives as zero images of the recorded lengths.
        assert_eq!(wl.init.len(), 2);
        assert_eq!(wl.init[0].1.len(), 8);
    }

    #[test]
    fn gpu_fold_rehomes_addresses_and_separates_slots() {
        let t = two_gpu_trace();
        let wl = replay_workload("trace:x", &t, &params(1, 1)).unwrap();
        // GPU 1's stream folds onto GPU 0 in its own slot block.
        let work = &wl.phases[0].work[0][0];
        assert_eq!(work.len(), 4, "2 folds x stride 2");
        let folded = &work[2]; // rank 1, wf 0
        assert_eq!(folded[0], CuOp::LdV { reg: 0, addr: 0x1000, n: 2 });
        // Folded init slice rehomed into partition 0.
        assert_eq!(wl.init[1].0, 0x1000);
    }

    #[test]
    fn partition_size_mismatch_states_both_values_and_the_fix() {
        let t = two_gpu_trace();
        let mut p = params(2, 1);
        p.map.gpu_mem_bytes = 1 << 20;
        let e = replay_workload("trace:x", &t, &p).unwrap_err();
        // Both the recorded and the requested size, plus remediation.
        assert!(e.contains("gpu_mem_bytes=4194304"), "recorded value: {e}");
        assert!(e.contains("requests 1048576"), "requested value: {e}");
        assert!(e.contains("re-record"), "remediation: {e}");
        assert!(e.contains("gpu_mem_bytes to 4194304"), "remediation: {e}");
    }

    #[test]
    fn push_gap_splits_oversized_gaps() {
        let mut list = Vec::new();
        push_gap(&mut list, u32::MAX as u64 + 5);
        assert_eq!(
            list,
            vec![CuOp::Delay { cycles: u32::MAX }, CuOp::Delay { cycles: 5 }]
        );
        push_gap(&mut list, 0);
        assert_eq!(list.len(), 2, "zero gap pushes nothing");
    }
}
