//! Regression gate: diff a fresh `campaign.json` against a committed
//! baseline and fail on cycle-count drift.
//!
//! Simulated cycles are deterministic for a given commit, so any drift
//! against a same-commit baseline is a real behaviour change; the
//! tolerance exists to let intentional small perf deltas land without
//! regenerating the baseline on every PR. The gate is direction-
//! agnostic — an unexplained speed-*up* also means the baseline no
//! longer describes the code and must be refreshed.

use std::collections::BTreeMap;

use crate::sweep::json::{self, Value};
use crate::sweep::report;

/// One gated cell that fell outside the tolerance (or vanished).
pub struct Violation {
    /// `config/workload`.
    pub cell: String,
    pub why: String,
}

/// Outcome of one gate comparison.
pub struct GateReport {
    pub campaign: String,
    pub tolerance: f64,
    /// Cells present in both documents.
    pub compared: usize,
    pub violations: Vec<Violation>,
    /// Informational (e.g. cells new since the baseline).
    pub notes: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    /// Multi-line human rendering.
    pub fn describe(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!("VIOLATION {}: {}\n", v.cell, v.why));
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        let verdict = if self.passed() {
            "PASS".to_string()
        } else {
            format!("FAIL ({} violations)", self.violations.len())
        };
        out.push_str(&format!(
            "gate[{}]: {verdict} ({} cells compared, tolerance ±{:.2}%)",
            self.campaign,
            self.compared,
            100.0 * self.tolerance,
        ));
        out
    }
}

struct CellView {
    status: String,
    cycles: Option<f64>,
}

/// Severity order for status regressions: a cell may not move down
/// this ladder (ok -> checks_failed -> error) relative to its baseline.
/// Watchdog timeouts and never-run `pending` cells (an interrupted
/// journal gated by mistake) rank with `error`.
fn status_rank(status: &str) -> u8 {
    match status {
        "ok" => 0,
        "checks_failed" => 1,
        "error" | "timeout" | "pending" => 2,
        _ => 2,
    }
}

fn fmt_key(key: &(String, String)) -> String {
    format!("{}/{}", key.0, key.1)
}

fn index_cells(root: &Value, which: &str) -> Result<BTreeMap<(String, String), CellView>, String> {
    let cells = root
        .get("cells")
        .and_then(Value::as_arr)
        .ok_or_else(|| format!("{which}: no 'cells' array"))?;
    let mut out = BTreeMap::new();
    for cell in cells {
        let config = cell
            .get("config")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("{which}: cell missing 'config'"))?;
        let workload = cell
            .get("workload")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("{which}: cell missing 'workload'"))?;
        let key = (config.to_string(), workload.to_string());
        let view = CellView {
            status: cell
                .get("status")
                .and_then(Value::as_str)
                .unwrap_or("?")
                .to_string(),
            cycles: cell
                .get("metrics")
                .and_then(|m| m.get("cycles"))
                .and_then(Value::as_f64),
        };
        if out.insert(key.clone(), view).is_some() {
            return Err(format!("{which}: duplicate cell {}", fmt_key(&key)));
        }
    }
    Ok(out)
}

/// Compare two campaign artifacts. `tolerance` is the allowed relative
/// cycle drift per cell (0.05 = ±5%).
pub fn diff(baseline: &str, current: &str, tolerance: f64) -> Result<GateReport, String> {
    let b = json::parse(baseline).map_err(|e| format!("baseline: {e}"))?;
    let c = json::parse(current).map_err(|e| format!("current: {e}"))?;
    report::check_schema(&b, "baseline")?;
    report::check_schema(&c, "current")?;
    let bname = b.get("campaign").and_then(Value::as_str).unwrap_or("?").to_string();
    let cname = c.get("campaign").and_then(Value::as_str).unwrap_or("?").to_string();
    if bname != cname {
        return Err(format!(
            "campaign mismatch: baseline is '{bname}', current is '{cname}'"
        ));
    }
    let bcells = index_cells(&b, "baseline")?;
    let ccells = index_cells(&c, "current")?;
    let mut report = GateReport {
        campaign: bname,
        tolerance,
        compared: 0,
        violations: Vec::new(),
        notes: Vec::new(),
    };
    for (key, bc) in &bcells {
        let Some(cc) = ccells.get(key) else {
            report.violations.push(Violation {
                cell: fmt_key(key),
                why: "missing from current run".into(),
            });
            continue;
        };
        report.compared += 1;
        if status_rank(&cc.status) > status_rank(&bc.status) {
            report.violations.push(Violation {
                cell: fmt_key(key),
                why: format!("status regressed: {} -> {}", bc.status, cc.status),
            });
            continue;
        }
        if let (Some(bcy), Some(ccy)) = (bc.cycles, cc.cycles) {
            if bcy > 0.0 {
                let drift = ccy / bcy - 1.0;
                if drift.abs() > tolerance {
                    report.violations.push(Violation {
                        cell: fmt_key(key),
                        why: format!(
                            "cycles drifted {:+.2}% ({bcy} -> {ccy}), tolerance ±{:.2}%",
                            100.0 * drift,
                            100.0 * tolerance,
                        ),
                    });
                }
            }
        }
    }
    for key in ccells.keys() {
        if !bcells.contains_key(key) {
            report.notes.push(format!("{}: new cell (not in baseline)", fmt_key(key)));
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(cycles: u64, status: &str) -> String {
        format!(
            r#"{{"schema_version": 1, "campaign": "t", "cells": [
                 {{"config": "A", "workload": "rl", "status": "{status}",
                   "metrics": {{"cycles": {cycles}}}}},
                 {{"config": "B", "workload": "rl", "status": "ok",
                   "metrics": {{"cycles": 1000}}}}
               ]}}"#
        )
    }

    #[test]
    fn identical_documents_pass_at_zero_tolerance() {
        let d = doc(500, "ok");
        let rep = diff(&d, &d, 0.0).unwrap();
        assert!(rep.passed());
        assert_eq!(rep.compared, 2);
        assert!(rep.describe().contains("PASS"));
    }

    #[test]
    fn drift_beyond_tolerance_fails_in_both_directions() {
        let base = doc(1000, "ok");
        assert!(!diff(&base, &doc(1100, "ok"), 0.05).unwrap().passed());
        assert!(!diff(&base, &doc(900, "ok"), 0.05).unwrap().passed());
        assert!(diff(&base, &doc(1040, "ok"), 0.05).unwrap().passed());
    }

    #[test]
    fn status_regression_and_missing_cells_fail() {
        let base = doc(1000, "ok");
        let rep = diff(&base, &doc(1000, "checks_failed"), 0.5).unwrap();
        assert_eq!(rep.violations.len(), 1);
        assert!(rep.violations[0].why.contains("status regressed"));

        // Already-failing baseline cells may not degrade further
        // (checks_failed -> error), but recovering is not a violation.
        let failing_base = doc(1000, "checks_failed");
        let rep = diff(&failing_base, &doc(1000, "error"), 0.5).unwrap();
        assert_eq!(rep.violations.len(), 1);
        assert!(diff(&failing_base, &doc(1000, "ok"), 0.5).unwrap().passed());

        let shrunk = r#"{"schema_version": 1, "campaign": "t", "cells": [
            {"config": "B", "workload": "rl", "status": "ok", "metrics": {"cycles": 1000}}
        ]}"#;
        let rep = diff(&base, shrunk, 0.5).unwrap();
        assert!(rep.violations.iter().any(|v| v.why.contains("missing")));

        // New cells are notes, not violations.
        let rep = diff(shrunk, &base, 0.5).unwrap();
        assert!(rep.passed());
        assert_eq!(rep.notes.len(), 1);
    }

    #[test]
    fn campaign_mismatch_is_an_error() {
        let a = r#"{"schema_version": 1, "campaign": "a", "cells": []}"#;
        let b = r#"{"schema_version": 1, "campaign": "b", "cells": []}"#;
        assert!(diff(a, b, 0.1).is_err());
        assert!(diff("not json", a, 0.1).is_err());
    }

    #[test]
    fn unsupported_schema_version_is_an_error() {
        let good = doc(100, "ok");
        let v2 = good.replace("\"schema_version\": 1", "\"schema_version\": 2");
        let none = good.replace("\"schema_version\": 1, ", "");
        assert!(diff(&v2, &good, 0.1).unwrap_err().contains("schema_version"));
        assert!(diff(&good, &v2, 0.1).unwrap_err().contains("schema_version"));
        assert!(diff(&none, &good, 0.1).is_err());
    }
}
