//! Experiment-campaign subsystem: declarative grids over preset ×
//! workload × config overrides, a parallel executor, machine-readable
//! JSON artifacts and a perf regression gate.
//!
//! The paper's evaluation is a large grid (11 benchmarks × 6 presets ×
//! GPU/CU counts, Figs. 7–9 + Tab. 4); `sweep` turns one figure into
//! one command:
//!
//! ```text
//! halcone sweep --campaign fig7 --jobs 8 --out fig7.json
//! halcone gate  --baseline fig7.json
//! ```
//!
//! Modules: [`spec`] (campaign grammar + built-ins), [`exec`]
//! (work-sharing thread pool with panic isolation), [`report`]
//! (`campaign.json` + speedup/geomean tables), [`gate`] (baseline
//! diffing), [`json`] (dependency-free JSON).

pub mod exec;
pub mod gate;
pub mod json;
pub mod report;
pub mod spec;

pub use exec::{run_campaign, CampaignResult, CellOutcome, CellResult, ExecOptions};
pub use spec::{CampaignSpec, Cell};
