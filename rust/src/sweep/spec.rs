//! Declarative campaign specs: the experiment grid as data.
//!
//! A campaign is a cartesian product of three axis families —
//! §4.1 presets × workloads × config-override axes (`n_gpus`,
//! `cus_per_gpu`, `rd_lease`, `scale`, ... any `SystemConfig::set` key)
//! — with optional per-axis include/exclude filters. Specs parse from
//! the same hand-rolled `key = value` format `SystemConfig::parse`
//! uses:
//!
//! ```text
//! name      = lease-sweep
//! presets   = SM-WT-C-HALCONE            # axis (default: all five)
//! workloads = fir,bfs,mm                 # axis (default: STANDARD)
//! axis.rd_lease = 8,16,32                # config-override axis
//! set.scale = 0.5                        # fixed override, every cell
//! exclude.workloads = bfs                # per-axis filter
//! baseline  = SM-WT-C-HALCONE+rd_lease=8 # speedup reference column
//! ```
//!
//! Deterministic fault schedules (docs/ROBUSTNESS.md) ride the same
//! grammar through the `faults` config key — its sub-keys are
//! `;`-separated precisely so a schedule can sit inside a `,`-separated
//! axis value list:
//!
//! ```text
//! set.faults  = seed=7;degrade=0.2;outage=0.05   # every cell
//! axis.faults = none,seed=7;degrade=0.3          # healthy vs degraded
//! axis.faults = ts_bits=8,ts_bits=12,ts_bits=16  # rollover sweep
//! ```
//!
//! Built-ins reproduce the paper's grids: `fig7` (Fig. 7), `fig8` /
//! `fig8cu` (Fig. 8a / 8b-c), `tab4` (§5.4 lease sensitivity) and
//! `smoke` (a seconds-long CI campaign). `frontier` extends `tab4`
//! across every coherence protocol with the `oracle = access-stream`
//! divergence check: each cell's run is traced and every non-baseline
//! protocol's access stream is asserted byte-identical to the
//! baseline's for the same workload (docs/PROTOCOLS.md).

use crate::config::SystemConfig;
use crate::sweep::json::Value;
use crate::workloads;

/// One runnable grid point: preset + workload + config overrides.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Stable position in the expansion (artifact order).
    pub index: usize,
    pub preset: String,
    pub workload: String,
    /// `key=value` overrides applied on top of the preset, in order
    /// (fixed `set.` entries first, then axis values).
    pub overrides: Vec<(String, String)>,
    /// Column label in tables and artifacts: `PRESET+key=value+...`.
    pub config_label: String,
}

impl Cell {
    /// Materialize the cell's `SystemConfig`.
    pub fn config(&self) -> Result<SystemConfig, String> {
        let mut cfg = SystemConfig::try_preset(&self.preset)?;
        for (k, v) in &self.overrides {
            // Cross-protocol sweeps (`frontier`) put lease axes over
            // protocols that have no leases; such a cell is the same
            // config at every lease point — a flat reference line in the
            // table — not an error. The knob still errors on explicit
            // non-sweep use (`SystemConfig::set` stays strict).
            if matches!(k.as_str(), "rd_lease" | "wr_lease")
                && cfg.coherence.leases().is_none()
            {
                continue;
            }
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }
}

/// A declarative experiment campaign.
#[derive(Clone, Debug)]
pub struct CampaignSpec {
    pub name: String,
    /// §4.1 preset axis.
    pub presets: Vec<String>,
    /// Workload axis (paper abbreviations).
    pub workloads: Vec<String>,
    /// Config-override axes, cartesian-expanded in order (last fastest).
    pub axes: Vec<(String, Vec<String>)>,
    /// Overrides applied to every cell before the axis values.
    pub fixed: Vec<(String, String)>,
    /// Config label speedups are computed against (default: first column).
    pub baseline: Option<String>,
    /// Warm-start fork prefix (docs/SNAPSHOT.md): when set, the first
    /// run of each distinct (config, workload) fingerprint snapshots at
    /// this cycle and later runs of the same fingerprint (retries,
    /// gate re-runs against the journal directory) fork from it.
    pub warmup: Option<u64>,
    /// Cross-protocol divergence oracle (`oracle = access-stream`): every
    /// cell is run traced and, per workload, each config's access stream
    /// is asserted structurally identical to the baseline config's. Any
    /// mismatch fails the campaign (docs/PROTOCOLS.md).
    pub oracle: Option<String>,
}

impl CampaignSpec {
    /// Built-in campaign names. The `smoke-*` variants isolate one
    /// coherence protocol each at the smoke geometry — the CI protocol
    /// matrix runs its zero-tolerance gate round-trip per variant.
    pub const BUILTINS: [&str; 12] = [
        "smoke",
        "smoke-halcone",
        "smoke-hmg",
        "smoke-none",
        "smoke-tardis",
        "smoke-hlc",
        "fig7",
        "fig8",
        "fig8cu",
        "tab4",
        "tab-tenant",
        "frontier",
    ];

    /// The smoke geometry: tiny enough that a whole campaign runs in
    /// seconds on CI (the runner tests' "small" configs).
    const SMOKE_GEOMETRY: &str = "set.n_gpus = 2\n\
         set.cus_per_gpu = 2\n\
         set.wavefronts_per_cu = 2\n\
         set.l2_banks = 2\n\
         set.stacks_per_gpu = 2\n\
         set.gpu_mem_bytes = 67108864\n\
         set.scale = 0.05\n";

    /// Look up a built-in campaign.
    pub fn builtin(name: &str) -> Result<CampaignSpec, String> {
        let standard = workloads::STANDARD.join(",");
        // Fig. 7 reproduces the paper's five-way comparison; the extra
        // rival presets (Tardis/HLC) live in `frontier` instead.
        let presets = SystemConfig::PAPER_PRESETS.join(",");
        let text = match name {
            "smoke" => format!(
                "name = smoke\n\
                 presets = SM-WT-NC,SM-WT-C-HALCONE\n\
                 workloads = rl,fir\n\
                 baseline = SM-WT-NC\n{}",
                Self::SMOKE_GEOMETRY
            ),
            "smoke-halcone" => format!(
                "name = smoke-halcone\n\
                 presets = SM-WT-C-HALCONE\n\
                 workloads = rl,fir\n{}",
                Self::SMOKE_GEOMETRY
            ),
            "smoke-hmg" => format!(
                "name = smoke-hmg\n\
                 presets = RDMA-WB-C-HMG\n\
                 workloads = rl,fir\n{}",
                Self::SMOKE_GEOMETRY
            ),
            "smoke-none" => format!(
                "name = smoke-none\n\
                 presets = SM-WT-NC,SM-WB-NC,RDMA-WB-NC\n\
                 workloads = rl,fir\n{}",
                Self::SMOKE_GEOMETRY
            ),
            "smoke-tardis" => format!(
                "name = smoke-tardis\n\
                 presets = SM-WT-C-TARDIS\n\
                 workloads = rl,fir\n{}",
                Self::SMOKE_GEOMETRY
            ),
            "smoke-hlc" => format!(
                "name = smoke-hlc\n\
                 presets = SM-WT-C-HLC\n\
                 workloads = rl,fir\n{}",
                Self::SMOKE_GEOMETRY
            ),
            "fig7" => format!(
                "name = fig7\npresets = {presets}\nworkloads = {standard}\nbaseline = RDMA-WB-NC\n"
            ),
            // Lease-length frontier across every protocol (tab4 widened
            // from the paper's HALCONE-only grid): the timestamp
            // protocols sweep the read lease; NC and HMG have no leases,
            // so their cells repeat one config per lease point as flat
            // reference lines. The oracle asserts every protocol observes
            // the identical access stream — coherence must change
            // timing, never the memory traffic itself.
            "frontier" => format!(
                "name = frontier\n\
                 presets = SM-WT-C-HALCONE,SM-WT-C-TARDIS,SM-WT-C-HLC,RDMA-WB-C-HMG,SM-WT-NC\n\
                 workloads = rl,fir\n\
                 axis.rd_lease = 5,10,20\n\
                 baseline = SM-WT-C-HALCONE+rd_lease=10\n\
                 oracle = access-stream\n{}",
                Self::SMOKE_GEOMETRY
            ),
            "fig8" => format!(
                "name = fig8\n\
                 presets = SM-WT-C-HALCONE\n\
                 workloads = {standard}\n\
                 axis.n_gpus = 1,2,4,8,16\n\
                 baseline = SM-WT-C-HALCONE+n_gpus=1\n"
            ),
            "fig8cu" => format!(
                "name = fig8cu\n\
                 presets = SM-WT-C-HALCONE\n\
                 workloads = {standard}\n\
                 axis.cus_per_gpu = 32,48,64\n\
                 baseline = SM-WT-C-HALCONE+cus_per_gpu=32\n"
            ),
            // Multi-tenant serving grid (docs/TENANCY.md): two-tenant
            // mixes — a noisy-neighbor pair and a replicated backlog —
            // under each coherence protocol, at the smoke geometry. Per-
            // tenant turnaround/traffic/fairness land in campaign.json.
            "tab-tenant" => format!(
                "name = tab-tenant\n\
                 presets = SM-WT-C-HALCONE,RDMA-WB-C-HMG,SM-WT-NC\n\
                 workloads = mix:read-mostly+false-sharing@64,mix:private*2+migratory\n{}",
                Self::SMOKE_GEOMETRY
            ),
            "tab4" => "name = tab4\n\
                 presets = SM-WT-C-HALCONE\n\
                 workloads = xtreme1,xtreme2,xtreme3\n\
                 axis.rd_lease = 5,10,20\n\
                 axis.wr_lease = 5,10,20\n\
                 baseline = SM-WT-C-HALCONE+rd_lease=10+wr_lease=5\n"
                .to_string(),
            other => {
                return Err(format!(
                    "unknown campaign '{other}' (built-ins: {:?})",
                    Self::BUILTINS
                ))
            }
        };
        CampaignSpec::parse(&text)
    }

    /// Parse a spec body (`key = value`, `#` comments, blank lines).
    pub fn parse(text: &str) -> Result<CampaignSpec, String> {
        let mut spec = CampaignSpec {
            name: "custom".into(),
            presets: Vec::new(),
            workloads: Vec::new(),
            axes: Vec::new(),
            fixed: Vec::new(),
            baseline: None,
            warmup: None,
            oracle: None,
        };
        let mut includes: Vec<(String, Vec<String>)> = Vec::new();
        let mut excludes: Vec<(String, Vec<String>)> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key=value", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim());
            let list: Vec<String> = v
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if let Some(axis) = k.strip_prefix("axis.") {
                spec.axes.push((axis.to_string(), list));
            } else if let Some(key) = k.strip_prefix("set.") {
                spec.fixed.push((key.to_string(), v.to_string()));
            } else if let Some(axis) = k.strip_prefix("include.") {
                includes.push((axis.to_string(), list));
            } else if let Some(axis) = k.strip_prefix("exclude.") {
                excludes.push((axis.to_string(), list));
            } else {
                match k {
                    "name" => spec.name = v.to_string(),
                    "presets" | "preset" => spec.presets = list,
                    "workloads" | "workload" => spec.workloads = list,
                    "baseline" => spec.baseline = Some(v.to_string()),
                    "oracle" => spec.oracle = Some(v.to_string()),
                    "warmup" => {
                        spec.warmup = Some(v.parse::<u64>().map_err(|_| {
                            format!("line {}: warmup wants a cycle count, got '{v}'", lineno + 1)
                        })?)
                    }
                    other => return Err(format!("line {}: unknown spec key '{other}'", lineno + 1)),
                }
            }
        }
        if spec.presets.is_empty() {
            // Default stays the paper's five-way comparison; the rival
            // Tardis/HLC presets are opt-in by name.
            spec.presets = SystemConfig::PAPER_PRESETS.iter().map(|s| s.to_string()).collect();
        }
        if spec.workloads.is_empty() {
            spec.workloads = workloads::STANDARD.iter().map(|s| s.to_string()).collect();
        }
        for (axis, keep) in &includes {
            spec.filter(axis, keep, true)?;
        }
        for (axis, drop) in &excludes {
            spec.filter(axis, drop, false)?;
        }
        spec.dedup_fixed();
        spec.validate()?;
        Ok(spec)
    }

    /// Collapse duplicate fixed-override keys: last value wins and sits
    /// at the position of its last occurrence. Execution, the artifact's
    /// `fixed` object and gate reconstruction then all see the identical
    /// list — an interleaved duplicate (e.g. `coherence` set twice
    /// around a lease key) would otherwise run under one order and be
    /// rebuilt under another. Call after extending `fixed` by hand.
    pub fn dedup_fixed(&mut self) {
        let mut out: Vec<(String, String)> = Vec::new();
        for (k, v) in self.fixed.drain(..) {
            if let Some(pos) = out.iter().position(|(k2, _)| *k2 == k) {
                out.remove(pos);
            }
            out.push((k, v));
        }
        self.fixed = out;
    }

    /// Reconstruct the spec recorded in a `campaign.json` artifact, so
    /// `halcone gate` re-runs exactly the campaign its baseline was
    /// generated with — including `set.` overrides, `--set` flags and
    /// custom `--spec` files, none of which a name lookup would recover.
    pub fn from_artifact(doc: &Value) -> Result<CampaignSpec, String> {
        crate::sweep::report::check_schema(doc, "artifact")?;
        fn strings(v: &Value, what: &str) -> Result<Vec<String>, String> {
            v.as_arr()
                .ok_or_else(|| format!("artifact spec: '{what}' is not an array"))?
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| format!("artifact spec: non-string in '{what}'"))
                })
                .collect()
        }
        let name = doc
            .get("campaign")
            .and_then(Value::as_str)
            .ok_or_else(|| "artifact has no 'campaign' name".to_string())?;
        let spec_obj = doc
            .get("spec")
            .ok_or_else(|| "artifact has no 'spec' object".to_string())?;
        let field = |key: &str| {
            spec_obj
                .get(key)
                .ok_or_else(|| format!("artifact spec: missing '{key}'"))
        };
        let presets = strings(field("presets")?, "presets")?;
        let workloads = strings(field("workloads")?, "workloads")?;
        let mut axes = Vec::new();
        let axes_arr = field("axes")?
            .as_arr()
            .ok_or_else(|| "artifact spec: 'axes' is not an array".to_string())?;
        for a in axes_arr {
            let key = a
                .get("key")
                .and_then(Value::as_str)
                .ok_or_else(|| "artifact spec: axis missing 'key'".to_string())?;
            let values = strings(
                a.get("values")
                    .ok_or_else(|| "artifact spec: axis missing 'values'".to_string())?,
                "axis values",
            )?;
            axes.push((key.to_string(), values));
        }
        let mut fixed = Vec::new();
        if let Some(Value::Obj(kvs)) = spec_obj.get("fixed") {
            for (k, v) in kvs {
                let v = v
                    .as_str()
                    .ok_or_else(|| format!("artifact spec: non-string fixed value for '{k}'"))?;
                fixed.push((k.clone(), v.to_string()));
            }
        }
        let baseline = spec_obj.get("baseline").and_then(Value::as_str).map(str::to_string);
        // Optional keys: older artifacts predate (and never carry) them,
        // so absence simply means the feature was off.
        let warmup = spec_obj.get("warmup").and_then(Value::as_f64).map(|w| w as u64);
        let oracle = spec_obj.get("oracle").and_then(Value::as_str).map(str::to_string);
        let spec = CampaignSpec {
            name: name.to_string(),
            presets,
            workloads,
            axes,
            fixed,
            baseline,
            warmup,
            oracle,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Keep (`keep = true`) or drop the listed values of one axis.
    fn filter(&mut self, axis: &str, values: &[String], keep: bool) -> Result<(), String> {
        let list = match axis {
            "presets" | "preset" => &mut self.presets,
            "workloads" | "workload" => &mut self.workloads,
            other => self
                .axes
                .iter_mut()
                .find(|(k, _)| k == other)
                .map(|(_, vs)| vs)
                .ok_or_else(|| format!("filter on unknown axis '{other}'"))?,
        };
        list.retain(|x| values.contains(x) == keep);
        if list.is_empty() {
            return Err(format!("filter on '{axis}' removed every value"));
        }
        Ok(())
    }

    /// Sanity-check axis contents (cheap; full config validation happens
    /// in [`CampaignSpec::cells`]).
    fn validate(&self) -> Result<(), String> {
        // Duplicate axis values would expand to duplicate (config,
        // workload) cells — ambiguous lookups, and an artifact the
        // gate refuses to index.
        fn no_dups(kind: &str, vals: &[String]) -> Result<(), String> {
            for (i, v) in vals.iter().enumerate() {
                if vals[..i].contains(v) {
                    return Err(format!("duplicate {kind} '{v}'"));
                }
            }
            Ok(())
        }
        no_dups("preset", &self.presets)?;
        no_dups("workload", &self.workloads)?;
        for p in &self.presets {
            SystemConfig::try_preset(p)?;
        }
        for w in &self.workloads {
            // Deep validation: registry membership, and for the
            // `trace:<file>` form that the file exists and its header
            // parses — a bad trace path fails the spec here instead of
            // panicking mid-campaign.
            workloads::validate_name(w)?;
        }
        for (k, vs) in &self.axes {
            if vs.is_empty() {
                return Err(format!("axis '{k}' has no values"));
            }
            if self.axes.iter().filter(|(k2, _)| k2 == k).count() > 1 {
                return Err(format!("axis '{k}' listed twice"));
            }
            no_dups(&format!("value for axis '{k}'"), vs)?;
        }
        if let Some(b) = &self.baseline {
            if !self.config_labels().iter().any(|l| l == b) {
                return Err(format!(
                    "baseline '{b}' is not one of the campaign's config labels {:?}",
                    self.config_labels()
                ));
            }
        }
        if let Some(o) = &self.oracle {
            if o != "access-stream" {
                return Err(format!("unknown oracle '{o}' (supported: access-stream)"));
            }
            if self.warmup.is_some() {
                // Trace capture and snapshotting cannot combine (the
                // capture would miss the warmed-up prefix), so an oracle
                // campaign always runs its cells cold.
                return Err("oracle campaigns cannot use warmup (trace capture needs the \
                            full access stream, not a warm-started suffix)"
                    .to_string());
            }
        }
        Ok(())
    }

    /// All axis-override combinations, cartesian, in axis order (last
    /// axis fastest). One empty combo when there are no axes.
    fn axis_combos(&self) -> Vec<Vec<(String, String)>> {
        let mut combos: Vec<Vec<(String, String)>> = vec![Vec::new()];
        for (k, vals) in &self.axes {
            let mut next = Vec::with_capacity(combos.len() * vals.len());
            for c in &combos {
                for v in vals {
                    let mut c2 = c.clone();
                    c2.push((k.clone(), v.clone()));
                    next.push(c2);
                }
            }
            combos = next;
        }
        combos
    }

    fn label(preset: &str, combo: &[(String, String)]) -> String {
        let mut s = preset.to_string();
        for (k, v) in combo {
            s.push('+');
            s.push_str(k);
            s.push('=');
            s.push_str(v);
        }
        s
    }

    /// Distinct config variants (preset × axis combos) in column order.
    pub fn config_labels(&self) -> Vec<String> {
        let combos = self.axis_combos();
        let mut out = Vec::with_capacity(self.presets.len() * combos.len());
        for p in &self.presets {
            for combo in &combos {
                out.push(Self::label(p, combo));
            }
        }
        out
    }

    /// Expand the grid. Every cell's config is built once here so an
    /// invalid key/value fails fast, before any simulation starts.
    pub fn cells(&self) -> Result<Vec<Cell>, String> {
        self.validate()?;
        let combos = self.axis_combos();
        let mut out = Vec::with_capacity(self.workloads.len() * self.presets.len() * combos.len());
        for wl in &self.workloads {
            for p in &self.presets {
                for combo in &combos {
                    let mut overrides = self.fixed.clone();
                    overrides.extend(combo.iter().cloned());
                    let cell = Cell {
                        index: out.len(),
                        preset: p.clone(),
                        workload: wl.clone(),
                        overrides,
                        config_label: Self::label(p, combo),
                    };
                    cell.config()
                        .map_err(|e| format!("cell {}/{wl}: {e}", cell.config_label))?;
                    out.push(cell);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_axes_filters_and_baseline() {
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-NC,SM-WT-C-HALCONE\n\
             workloads = rl,fir,mm\n\
             axis.n_gpus = 2,4\n\
             set.scale = 0.1\n\
             exclude.workloads = mm\n\
             baseline = SM-WT-NC+n_gpus=2\n",
        )
        .unwrap();
        assert_eq!(spec.workloads, ["rl", "fir"]);
        assert_eq!(
            spec.config_labels(),
            [
                "SM-WT-NC+n_gpus=2",
                "SM-WT-NC+n_gpus=4",
                "SM-WT-C-HALCONE+n_gpus=2",
                "SM-WT-C-HALCONE+n_gpus=4",
            ]
        );
        let cells = spec.cells().unwrap();
        assert_eq!(cells.len(), 2 * 4);
        assert_eq!(cells[0].overrides, [
            ("scale".to_string(), "0.1".to_string()),
            ("n_gpus".to_string(), "2".to_string()),
        ]);
        assert_eq!(cells[0].config().unwrap().n_gpus, 2);
        assert!((cells[0].config().unwrap().scale - 0.1).abs() < 1e-12);
        // Indices are the expansion order.
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn duplicate_set_keys_collapse_last_wins() {
        let spec = CampaignSpec::parse(
            "workloads = rl\nset.scale = 0.5\nset.n_gpus = 2\nset.scale = 0.25\n",
        )
        .unwrap();
        assert_eq!(spec.fixed, [
            ("n_gpus".to_string(), "2".to_string()),
            ("scale".to_string(), "0.25".to_string()),
        ]);
    }

    #[test]
    fn include_filter_keeps_only_listed_values() {
        let spec = CampaignSpec::parse(
            "workloads = rl,fir,mm\ninclude.workloads = fir\n",
        )
        .unwrap();
        assert_eq!(spec.workloads, ["fir"]);
        // presets defaulted to all five.
        assert_eq!(spec.presets.len(), 5);
    }

    #[test]
    fn rejects_unknown_names_and_empty_axes() {
        assert!(CampaignSpec::parse("presets = NOPE\n").is_err());
        assert!(CampaignSpec::parse("workloads = nope\n").is_err());
        assert!(CampaignSpec::parse("workloads = rl\nexclude.workloads = rl\n").is_err());
        assert!(CampaignSpec::parse("baseline = NOPE\n").is_err());
        assert!(CampaignSpec::parse("bogus = 1\n").is_err());
        // Duplicates would expand into duplicate cells the gate rejects.
        assert!(CampaignSpec::parse("workloads = fir,fir\n").is_err());
        assert!(CampaignSpec::parse("presets = SM-WT-NC,SM-WT-NC\n").is_err());
        assert!(CampaignSpec::parse("axis.n_gpus = 2,2\n").is_err());
        // Axis values are validated against real configs at expansion.
        assert!(CampaignSpec::parse("axis.bogus_knob = 5\n").unwrap().cells().is_err());
        // Oracle: only access-stream exists, and it cannot combine with
        // warmup (trace capture refuses snapshot runs).
        assert!(CampaignSpec::parse("workloads = rl\noracle = nope\n").is_err());
        assert!(
            CampaignSpec::parse("workloads = rl\noracle = access-stream\nwarmup = 100\n")
                .is_err()
        );
    }

    #[test]
    fn lease_axes_skip_protocols_without_leases() {
        // Cross-protocol lease sweeps expand: lease knobs apply to the
        // timestamp protocols and no-op on the rest (flat reference
        // lines), instead of failing the whole grid.
        let spec = CampaignSpec::parse(
            "presets = SM-WT-NC,SM-WT-C-TARDIS\nworkloads = rl\naxis.rd_lease = 5,20\n",
        )
        .unwrap();
        let cells = spec.cells().unwrap();
        assert_eq!(cells.len(), 4);
        let nc = cells.iter().find(|c| c.config_label == "SM-WT-NC+rd_lease=20").unwrap();
        assert_eq!(nc.config().unwrap().describe(), SystemConfig::preset("SM-WT-NC").describe());
        let tardis =
            cells.iter().find(|c| c.config_label == "SM-WT-C-TARDIS+rd_lease=20").unwrap();
        assert_eq!(tardis.config().unwrap().coherence.leases().unwrap().rd, 20);
    }

    #[test]
    fn builtins_expand_to_valid_cells() {
        for name in CampaignSpec::BUILTINS {
            let spec = CampaignSpec::builtin(name).unwrap();
            let cells = spec.cells().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!cells.is_empty(), "{name}: empty grid");
        }
        assert_eq!(CampaignSpec::builtin("fig7").unwrap().cells().unwrap().len(), 55);
        assert_eq!(CampaignSpec::builtin("fig8").unwrap().cells().unwrap().len(), 55);
        assert_eq!(CampaignSpec::builtin("smoke").unwrap().cells().unwrap().len(), 4);
        assert!(CampaignSpec::builtin("nope").is_err());
    }

    #[test]
    fn frontier_crosses_every_protocol_with_the_divergence_oracle() {
        let spec = CampaignSpec::builtin("frontier").unwrap();
        assert_eq!(spec.presets.len(), 5, "{:?}", spec.presets);
        assert_eq!(spec.oracle.as_deref(), Some("access-stream"));
        assert_eq!(spec.baseline.as_deref(), Some("SM-WT-C-HALCONE+rd_lease=10"));
        // 5 protocols x 2 workloads x 3 lease points.
        assert_eq!(spec.cells().unwrap().len(), 30);
    }

    #[test]
    fn protocol_smoke_variants_cover_one_protocol_each() {
        let hc = CampaignSpec::builtin("smoke-halcone").unwrap();
        assert_eq!(hc.presets, ["SM-WT-C-HALCONE"]);
        assert_eq!(hc.cells().unwrap().len(), 2);
        let hmg = CampaignSpec::builtin("smoke-hmg").unwrap();
        assert_eq!(hmg.presets, ["RDMA-WB-C-HMG"]);
        assert_eq!(hmg.cells().unwrap().len(), 2);
        let none = CampaignSpec::builtin("smoke-none").unwrap();
        assert_eq!(none.cells().unwrap().len(), 6);
        let tardis = CampaignSpec::builtin("smoke-tardis").unwrap();
        assert_eq!(tardis.presets, ["SM-WT-C-TARDIS"]);
        assert_eq!(tardis.cells().unwrap().len(), 2);
        let hlc = CampaignSpec::builtin("smoke-hlc").unwrap();
        assert_eq!(hlc.presets, ["SM-WT-C-HLC"]);
        assert_eq!(hlc.cells().unwrap().len(), 2);
    }

    #[test]
    fn bad_trace_workload_fails_spec_validation_with_a_clear_error() {
        let e = CampaignSpec::parse("workloads = trace:/no/such/file.trc\n").unwrap_err();
        assert!(e.contains("file.trc"), "{e}");
        // Still an error (not a panic) when it sneaks in via a filter-free
        // single-workload spec.
        let e = CampaignSpec::parse("workloads = rl,trace:missing.trc\n").unwrap_err();
        assert!(e.contains("missing.trc"), "{e}");
    }

    #[test]
    fn tab_tenant_sweeps_mixes_across_protocols() {
        let spec = CampaignSpec::builtin("tab-tenant").unwrap();
        assert_eq!(spec.presets, ["SM-WT-C-HALCONE", "RDMA-WB-C-HMG", "SM-WT-NC"]);
        assert!(spec.workloads.iter().all(|w| w.starts_with("mix:")), "{:?}", spec.workloads);
        assert_eq!(spec.cells().unwrap().len(), 2 * 3);
    }

    #[test]
    fn tab4_baseline_is_the_paper_default_lease_pair() {
        let spec = CampaignSpec::builtin("tab4").unwrap();
        assert_eq!(spec.baseline.as_deref(), Some("SM-WT-C-HALCONE+rd_lease=10+wr_lease=5"));
        assert_eq!(spec.cells().unwrap().len(), 3 * 9);
    }
}
