//! Campaign artifacts: `campaign.json` and the human speedup table.
//!
//! The JSON document is fully deterministic except for the per-cell
//! `host_seconds` timing — every other field depends only on the spec
//! and the (deterministic) simulations, never on `--jobs` or load.
//! [`to_json_canonical`] drops the `host_seconds` fields and must be
//! byte-identical across `--jobs` levels (`tests/sweep_campaign.rs`).

use crate::coordinator::verify::CheckOutcome;
use crate::metrics::bench::Table;
use crate::metrics::tenancy::{TenancyReport, TenantMetrics};
use crate::metrics::{geomean, CacheCtrlStats, FaultReport, RunMetrics};
use crate::sweep::exec::{CampaignResult, CellExec, CellOutcome, CellResult};
use crate::sweep::json::Value;

/// Bumped whenever the artifact layout changes shape.
pub const SCHEMA_VERSION: u64 = 1;

/// Reject artifacts written under a different schema (shared by the
/// gate and `CampaignSpec::from_artifact` so the message and the check
/// cannot drift apart).
pub fn check_schema(doc: &Value, what: &str) -> Result<(), String> {
    let sv = doc.get("schema_version").and_then(Value::as_f64);
    if sv != Some(SCHEMA_VERSION as f64) {
        return Err(format!(
            "{what}: artifact schema_version {} is not the supported {SCHEMA_VERSION}; \
             regenerate it with this binary",
            sv.map(|v| v.to_string()).unwrap_or_else(|| "(missing)".into()),
        ));
    }
    Ok(())
}

/// Full artifact, including host timing.
pub fn to_json(result: &CampaignResult) -> String {
    render(result, true)
}

/// Artifact with host-dependent fields removed: the determinism and
/// regression-gate input.
pub fn to_json_canonical(result: &CampaignResult) -> String {
    render(result, false)
}

/// The config label speedups are computed against: the spec's named
/// baseline, or the first config column.
pub fn baseline_label(result: &CampaignResult) -> String {
    result
        .spec
        .baseline
        .clone()
        .or_else(|| result.spec.config_labels().into_iter().next())
        .unwrap_or_default()
}

/// Speed-up of `cr` vs the baseline cell of the same workload. `None`
/// when either cell errored or recorded zero cycles.
pub fn speedup_of(result: &CampaignResult, cr: &CellResult, base_label: &str) -> Option<f64> {
    let m = cr.metrics()?;
    let base = result.get(base_label, &cr.cell.workload)?.metrics()?;
    m.speedup_vs(base)
}

/// Serialize an override list as a JSON object, last value winning on
/// duplicate keys — the same resolution `Cell::config` applies — so
/// external consumers (jq, python) read the value that actually took
/// effect instead of a duplicate-key object.
fn overrides_obj(kvs: &[(String, String)]) -> Value {
    let mut out: Vec<(String, Value)> = Vec::new();
    for (k, v) in kvs {
        if let Some(slot) = out.iter_mut().find(|(k2, _)| k2 == k) {
            slot.1 = Value::str(v);
        } else {
            out.push((k.clone(), Value::str(v)));
        }
    }
    Value::Obj(out)
}

fn render(result: &CampaignResult, include_host: bool) -> String {
    let spec = &result.spec;
    let base_label = baseline_label(result);
    let cells: Vec<Value> = result
        .cells
        .iter()
        .map(|cr| cell_json(result, cr, &base_label, include_host))
        .collect();
    let mut spec_kvs: Vec<(String, Value)> = vec![
        (
            "presets".into(),
            Value::Arr(spec.presets.iter().map(Value::str).collect()),
        ),
        (
            "workloads".into(),
            Value::Arr(spec.workloads.iter().map(Value::str).collect()),
        ),
        (
            "axes".into(),
            Value::Arr(
                spec.axes
                    .iter()
                    .map(|(k, vs)| {
                        Value::Obj(vec![
                            ("key".into(), Value::str(k)),
                            ("values".into(), Value::Arr(vs.iter().map(Value::str).collect())),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("fixed".into(), overrides_obj(&spec.fixed)),
        ("baseline".into(), Value::str(&base_label)),
    ];
    // Warm-start prefix and oracle ride along only when declared, so
    // campaigns without them keep their exact canonical bytes.
    if let Some(w) = spec.warmup {
        spec_kvs.push(("warmup".into(), Value::u64(w)));
    }
    if let Some(o) = &spec.oracle {
        spec_kvs.push(("oracle".into(), Value::str(o)));
    }
    let spec_obj = Value::Obj(spec_kvs);
    let mut root_kvs: Vec<(String, Value)> = vec![
        ("schema_version".into(), Value::u64(SCHEMA_VERSION)),
        ("campaign".into(), Value::str(&spec.name)),
        ("spec".into(), spec_obj),
        ("cells".into(), Value::Arr(cells)),
    ];
    // Oracle verdicts, present only when comparisons ran. Traces are
    // deterministic, so this section is canonical like the cells.
    if !result.oracle.is_empty() {
        let checks: Vec<Value> = result
            .oracle
            .iter()
            .map(|o| {
                Value::Obj(vec![
                    ("workload".into(), Value::str(&o.workload)),
                    ("config".into(), Value::str(&o.config)),
                    ("baseline".into(), Value::str(&o.baseline)),
                    ("matched".into(), Value::Bool(o.matched)),
                    ("detail".into(), Value::str(&o.detail)),
                ])
            })
            .collect();
        root_kvs.push((
            "oracle".into(),
            Value::Obj(vec![
                ("ok".into(), Value::Bool(result.oracle_ok())),
                ("checks".into(), Value::Arr(checks)),
            ]),
        ));
    }
    let root = Value::Obj(root_kvs);
    let mut out = root.to_pretty();
    out.push('\n');
    out
}

fn cell_json(
    result: &CampaignResult,
    cr: &CellResult,
    base_label: &str,
    include_host: bool,
) -> Value {
    let mut o: Vec<(String, Value)> = vec![
        ("index".into(), Value::u64(cr.cell.index as u64)),
        ("config".into(), Value::str(&cr.cell.config_label)),
        ("preset".into(), Value::str(&cr.cell.preset)),
        ("workload".into(), Value::str(&cr.cell.workload)),
        ("overrides".into(), overrides_obj(&cr.cell.overrides)),
        ("status".into(), Value::str(cr.status())),
    ];
    match &cr.outcome {
        CellOutcome::Failed { error } => o.push(("error".into(), Value::str(error))),
        CellOutcome::TimedOut { seconds } => o.push((
            "error".into(),
            Value::str(format!("watchdog timeout after {seconds}s")),
        )),
        CellOutcome::Pending => {}
        CellOutcome::Finished { metrics, checks } => {
            let speedup = match speedup_of(result, cr, base_label) {
                Some(s) => Value::f64(s),
                None => Value::Null,
            };
            o.push(("speedup".into(), speedup));
            o.push(("metrics".into(), metrics_json(metrics, include_host)));
            o.push((
                "checks".into(),
                Value::Arr(
                    checks
                        .iter()
                        .map(|c| {
                            Value::Obj(vec![
                                ("kind".into(), Value::str(c.kind)),
                                ("desc".into(), Value::str(&c.desc)),
                                ("passed".into(), Value::Bool(c.passed)),
                                ("max_err".into(), Value::f64(c.max_err as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
    }
    if include_host {
        // Host-side execution record (wall clock, retries, watchdog) —
        // full artifact only, like host_seconds: the canonical form must
        // stay byte-identical between resumed and uninterrupted runs.
        o.push(("exec".into(), exec_json(&cr.exec)));
    }
    Value::Obj(o)
}

fn exec_json(e: &CellExec) -> Value {
    Value::Obj(vec![
        ("wall_seconds".into(), Value::f64(e.wall_seconds)),
        ("retries".into(), Value::u64(e.retries as u64)),
        ("timed_out".into(), Value::Bool(e.timed_out)),
        ("resumed".into(), Value::Bool(e.resumed)),
    ])
}

fn cache_stats_json(s: &CacheCtrlStats) -> Value {
    Value::Obj(vec![
        ("reqs_in".into(), Value::u64(s.reqs_in)),
        ("rsps_out".into(), Value::u64(s.rsps_out)),
        ("reqs_down".into(), Value::u64(s.reqs_down)),
        ("rsps_down".into(), Value::u64(s.rsps_down)),
        ("hits".into(), Value::u64(s.hits)),
        ("misses".into(), Value::u64(s.misses)),
        ("coherency_misses".into(), Value::u64(s.coherency_misses)),
        ("mshr_merges".into(), Value::u64(s.mshr_merges)),
        ("bytes_down".into(), Value::u64(s.bytes_down)),
        ("bytes_up".into(), Value::u64(s.bytes_up)),
        ("writebacks".into(), Value::u64(s.writebacks)),
        ("invalidations".into(), Value::u64(s.invalidations)),
    ])
}

fn metrics_json(m: &RunMetrics, include_host: bool) -> Value {
    let mut o: Vec<(String, Value)> = vec![
        ("cycles".into(), Value::u64(m.cycles)),
        ("events".into(), Value::u64(m.events)),
    ];
    if include_host {
        // Host-dependent pair: dropped from the canonical form so the
        // determinism/gate comparisons stay byte-stable. The pool
        // counters ride along — deterministic but engine-internal, they
        // belong to the perf trajectory, not the paper metrics.
        o.push(("host_seconds".into(), Value::f64(m.host_seconds)));
        o.push(("events_per_sec".into(), Value::f64(m.events_per_sec)));
        o.push(("pool_fresh_boxes".into(), Value::u64(m.pool_fresh_boxes)));
        o.push(("pool_reused_boxes".into(), Value::u64(m.pool_reused_boxes)));
        // Per-shard occupancy profile (index = engine shard id, hub
        // last). Deterministic like the pool counters, but it describes
        // the engine partition rather than the simulated machine, so it
        // stays with the host section. Input for profile-guided
        // `shard_groups` rebalancing.
        let arr = |v: &[u64]| Value::Arr(v.iter().map(|&x| Value::u64(x)).collect());
        o.push(("shard_events".into(), arr(&m.shard_events)));
        o.push(("shard_windows".into(), arr(&m.shard_windows)));
        o.push(("shard_idle_windows".into(), arr(&m.shard_idle_windows)));
    }
    o.extend([
        ("cu_loads".into(), Value::u64(m.cu_loads)),
        ("cu_stores".into(), Value::u64(m.cu_stores)),
        ("mm_reads".into(), Value::u64(m.mm_reads)),
        ("mm_writes".into(), Value::u64(m.mm_writes)),
        ("tsu_lookups".into(), Value::u64(m.tsu_lookups)),
        ("tsu_evictions".into(), Value::u64(m.tsu_evictions)),
        ("pcie_bytes".into(), Value::u64(m.pcie_bytes)),
        ("mem_bytes".into(), Value::u64(m.mem_bytes)),
        ("l1_l2_transactions".into(), Value::u64(m.l1_l2_transactions())),
        ("l2_mm_transactions".into(), Value::u64(m.l2_mm_transactions())),
        ("l1".into(), cache_stats_json(&m.l1)),
        ("l2".into(), cache_stats_json(&m.l2)),
    ]);
    // Per-tenant section, present only for mix runs: ordinary cells keep
    // their exact canonical bytes. Every value is a pure function of
    // deterministic counters, so the section is byte-stable across
    // `--shards`/`--jobs` like the rest of the artifact.
    if let Some(t) = &m.tenancy {
        o.push(("tenancy".into(), tenancy_json(t)));
    }
    // Fault-injection section, present only when a fault schedule was
    // configured (docs/ROBUSTNESS.md): every counter is a pure function
    // of the fault seed and the simulated configuration, so fault cells
    // stay byte-stable and fault-free cells keep their exact bytes.
    if let Some(f) = &m.faults {
        o.push((
            "faults".into(),
            Value::Obj(vec![
                ("link_outage_cycles".into(), Value::u64(f.link_outage_cycles)),
                ("link_degraded_msgs".into(), Value::u64(f.link_degraded_msgs)),
                ("rollover_flushes".into(), Value::u64(f.rollover_flushes)),
                ("tsu_rollovers".into(), Value::u64(f.tsu_rollovers)),
            ]),
        ));
    }
    Value::Obj(o)
}

fn tenancy_json(t: &crate::metrics::tenancy::TenancyReport) -> Value {
    let tenants: Vec<Value> = t
        .tenants
        .iter()
        .map(|tm| {
            Value::Obj(vec![
                ("tenant".into(), Value::u64(tm.tenant as u64)),
                ("name".into(), Value::str(&tm.name)),
                ("jobs".into(), Value::u64(tm.jobs)),
                ("turnaround_sum".into(), Value::u64(tm.turnaround_sum)),
                ("turnaround_mean".into(), Value::f64(tm.turnaround_mean())),
                ("turnaround_p99".into(), Value::u64(tm.turnaround_p99)),
                ("loads".into(), Value::u64(tm.loads)),
                ("stores".into(), Value::u64(tm.stores)),
                ("cu_bytes".into(), Value::u64(tm.cu_bytes)),
                ("l1_hits".into(), Value::u64(tm.l1_hits)),
                ("l1_misses".into(), Value::u64(tm.l1_misses)),
                ("l1_coherency_misses".into(), Value::u64(tm.l1_coherency_misses)),
                ("mem_traffic_share".into(), Value::f64(t.mem_traffic_share(tm.tenant))),
                (
                    "coherence_traffic_share".into(),
                    Value::f64(t.coherence_traffic_share(tm.tenant)),
                ),
            ])
        })
        .collect();
    Value::Obj(vec![
        ("scheduler".into(), Value::str(&t.scheduler)),
        ("jain_turnaround".into(), Value::f64(t.jain_turnaround())),
        ("tenants".into(), Value::Arr(tenants)),
    ])
}

/// Rebuild per-cell outcomes from a journaled artifact for
/// `sweep --resume`: terminal cells (`ok` / `checks_failed` / `error`)
/// are reloaded verbatim, while `pending` and `timeout` cells are left
/// out so the executor re-runs them. Every canonical metric is an
/// integer that round-trips exactly through the f64 JSON layer (the
/// writer prints integers below 2^53 losslessly), so a resumed
/// campaign's canonical artifact is byte-identical to an uninterrupted
/// run's.
pub fn outcomes_from_artifact(
    doc: &Value,
) -> Result<Vec<(usize, CellOutcome, CellExec)>, String> {
    check_schema(doc, "resume journal")?;
    let cells = doc
        .get("cells")
        .and_then(Value::as_arr)
        .ok_or_else(|| "resume journal: no 'cells' array".to_string())?;
    let mut out = Vec::new();
    for (pos, cell) in cells.iter().enumerate() {
        let index = cell
            .get("index")
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("resume journal: cell {pos} has no numeric 'index'"))?
            as usize;
        let label = format!(
            "cell {index} ({}/{})",
            cell.get("config").and_then(Value::as_str).unwrap_or("?"),
            cell.get("workload").and_then(Value::as_str).unwrap_or("?"),
        );
        let status = cell
            .get("status")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("resume journal: {label} has no 'status'"))?;
        let outcome = match status {
            // Unfinished and watchdogged cells re-run on resume.
            "pending" | "timeout" => continue,
            "error" => CellOutcome::Failed {
                error: cell
                    .get("error")
                    .and_then(Value::as_str)
                    .unwrap_or("unknown error")
                    .to_string(),
            },
            "ok" | "checks_failed" => {
                let m = cell
                    .get("metrics")
                    .ok_or_else(|| format!("resume journal: {label} has no 'metrics'"))?;
                let checks = cell
                    .get("checks")
                    .ok_or_else(|| format!("resume journal: {label} has no 'checks'"))?;
                CellOutcome::Finished {
                    metrics: metrics_from_json(m, &label)?,
                    checks: checks_from_json(checks, &label)?,
                }
            }
            other => {
                return Err(format!("resume journal: {label} has unknown status '{other}'"))
            }
        };
        out.push((index, outcome, exec_from_json(cell)));
    }
    Ok(out)
}

fn req_u64(v: &Value, key: &str, what: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(Value::as_f64)
        .map(|x| x as u64)
        .ok_or_else(|| format!("resume journal: {what} is missing numeric '{key}'"))
}

fn cache_stats_from_json(v: &Value, what: &str) -> Result<CacheCtrlStats, String> {
    Ok(CacheCtrlStats {
        reqs_in: req_u64(v, "reqs_in", what)?,
        rsps_out: req_u64(v, "rsps_out", what)?,
        reqs_down: req_u64(v, "reqs_down", what)?,
        rsps_down: req_u64(v, "rsps_down", what)?,
        hits: req_u64(v, "hits", what)?,
        misses: req_u64(v, "misses", what)?,
        coherency_misses: req_u64(v, "coherency_misses", what)?,
        mshr_merges: req_u64(v, "mshr_merges", what)?,
        bytes_down: req_u64(v, "bytes_down", what)?,
        bytes_up: req_u64(v, "bytes_up", what)?,
        writebacks: req_u64(v, "writebacks", what)?,
        invalidations: req_u64(v, "invalidations", what)?,
    })
}

fn metrics_from_json(m: &Value, what: &str) -> Result<RunMetrics, String> {
    // Host-perf fields are informational; tolerate their absence (a
    // canonical document) with zero defaults.
    let host = |key: &str| m.get(key).and_then(Value::as_f64).unwrap_or(0.0);
    // Host-only per-shard arrays: absent in canonical documents.
    let host_arr = |key: &str| -> Vec<u64> {
        m.get(key)
            .and_then(Value::as_arr)
            .map(|a| a.iter().map(|v| v.as_f64().unwrap_or(0.0) as u64).collect())
            .unwrap_or_default()
    };
    let mut out = RunMetrics {
        cycles: req_u64(m, "cycles", what)?,
        events: req_u64(m, "events", what)?,
        host_seconds: host("host_seconds"),
        events_per_sec: host("events_per_sec"),
        pool_fresh_boxes: host("pool_fresh_boxes") as u64,
        pool_reused_boxes: host("pool_reused_boxes") as u64,
        shard_events: host_arr("shard_events"),
        shard_windows: host_arr("shard_windows"),
        shard_idle_windows: host_arr("shard_idle_windows"),
        cu_loads: req_u64(m, "cu_loads", what)?,
        cu_stores: req_u64(m, "cu_stores", what)?,
        mm_reads: req_u64(m, "mm_reads", what)?,
        mm_writes: req_u64(m, "mm_writes", what)?,
        tsu_lookups: req_u64(m, "tsu_lookups", what)?,
        tsu_evictions: req_u64(m, "tsu_evictions", what)?,
        pcie_bytes: req_u64(m, "pcie_bytes", what)?,
        mem_bytes: req_u64(m, "mem_bytes", what)?,
        l1: cache_stats_from_json(
            m.get("l1").ok_or_else(|| format!("resume journal: {what} has no 'l1'"))?,
            what,
        )?,
        l2: cache_stats_from_json(
            m.get("l2").ok_or_else(|| format!("resume journal: {what} has no 'l2'"))?,
            what,
        )?,
        tenancy: None,
        faults: None,
    };
    if let Some(t) = m.get("tenancy") {
        out.tenancy = Some(tenancy_from_json(t, what)?);
    }
    if let Some(f) = m.get("faults") {
        out.faults = Some(FaultReport {
            link_outage_cycles: req_u64(f, "link_outage_cycles", what)?,
            link_degraded_msgs: req_u64(f, "link_degraded_msgs", what)?,
            rollover_flushes: req_u64(f, "rollover_flushes", what)?,
            tsu_rollovers: req_u64(f, "tsu_rollovers", what)?,
        });
    }
    Ok(out)
}

fn tenancy_from_json(t: &Value, what: &str) -> Result<TenancyReport, String> {
    let scheduler = t
        .get("scheduler")
        .and_then(Value::as_str)
        .ok_or_else(|| format!("resume journal: {what} tenancy has no 'scheduler'"))?
        .to_string();
    let mut tenants = Vec::new();
    for tm in t
        .get("tenants")
        .and_then(Value::as_arr)
        .ok_or_else(|| format!("resume journal: {what} tenancy has no 'tenants'"))?
    {
        // Derived fields (means, shares, jain) are recomputed at render
        // time from these counters, so only the counters are reloaded.
        tenants.push(TenantMetrics {
            tenant: req_u64(tm, "tenant", what)? as u32,
            name: tm
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| format!("resume journal: {what} tenant has no 'name'"))?
                .to_string(),
            jobs: req_u64(tm, "jobs", what)?,
            turnaround_sum: req_u64(tm, "turnaround_sum", what)?,
            turnaround_p99: req_u64(tm, "turnaround_p99", what)?,
            loads: req_u64(tm, "loads", what)?,
            stores: req_u64(tm, "stores", what)?,
            cu_bytes: req_u64(tm, "cu_bytes", what)?,
            l1_hits: req_u64(tm, "l1_hits", what)?,
            l1_misses: req_u64(tm, "l1_misses", what)?,
            l1_coherency_misses: req_u64(tm, "l1_coherency_misses", what)?,
        });
    }
    Ok(TenancyReport { scheduler, tenants })
}

fn checks_from_json(checks: &Value, what: &str) -> Result<Vec<CheckOutcome>, String> {
    let arr = checks
        .as_arr()
        .ok_or_else(|| format!("resume journal: {what} 'checks' is not an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for c in arr {
        let kind = match c.get("kind").and_then(Value::as_str) {
            // The in-memory kind is a &'static str: map through the
            // known vocabulary instead of leaking arbitrary strings.
            Some("artifact") => "artifact",
            Some("rust") => "rust",
            Some("skipped") => "skipped",
            other => {
                return Err(format!(
                    "resume journal: {what} has unknown check kind {other:?}"
                ))
            }
        };
        out.push(CheckOutcome {
            desc: c
                .get("desc")
                .and_then(Value::as_str)
                .ok_or_else(|| format!("resume journal: {what} check has no 'desc'"))?
                .to_string(),
            kind,
            passed: c
                .get("passed")
                .and_then(Value::as_bool)
                .ok_or_else(|| format!("resume journal: {what} check has no 'passed'"))?,
            max_err: c
                .get("max_err")
                .and_then(Value::as_f64)
                .ok_or_else(|| format!("resume journal: {what} check has no 'max_err'"))?
                as f32,
        });
    }
    Ok(out)
}

fn exec_from_json(cell: &Value) -> CellExec {
    let e = cell.get("exec");
    let f = |key: &str| e.and_then(|e| e.get(key)).and_then(Value::as_f64);
    CellExec {
        wall_seconds: f("wall_seconds").unwrap_or(0.0),
        retries: f("retries").unwrap_or(0.0) as u32,
        timed_out: e
            .and_then(|e| e.get("timed_out"))
            .and_then(Value::as_bool)
            .unwrap_or(false),
        // This outcome is being reloaded, not re-run.
        resumed: true,
    }
}

/// Print the paper-style table: workloads × config columns, speed-up vs
/// the baseline column, geomean ("Mean" bars) summary row. `n/a` marks
/// a zero-cycle baseline, `err` a failed cell; `!` flags failed checks.
pub fn print_speedup_table(result: &CampaignResult) {
    let labels = result.spec.config_labels();
    let base_label = baseline_label(result);
    let mut headers: Vec<&str> = vec!["bench"];
    headers.extend(labels.iter().map(|s| s.as_str()));
    let mut widths: Vec<usize> = vec![8];
    widths.extend(labels.iter().map(|l| l.len().max(9)));
    println!(
        "== campaign {}: speed-up vs {} ==\n",
        result.spec.name, base_label
    );
    let t = Table::new(&headers, &widths);
    let mut per_cfg: Vec<Vec<f64>> = vec![Vec::new(); labels.len()];
    for wl in &result.spec.workloads {
        let mut row = vec![wl.clone()];
        for (ci, label) in labels.iter().enumerate() {
            let txt = match result.get(label, wl) {
                None => "-".to_string(),
                Some(cr) => match speedup_of(result, cr, &base_label) {
                    Some(s) => {
                        per_cfg[ci].push(s);
                        format!("{s:.2}x{}", if cr.passed() { "" } else { "!" })
                    }
                    None => match cr.status() {
                        "error" => "err".to_string(),
                        _ => "n/a".to_string(),
                    },
                },
            };
            row.push(txt);
        }
        t.row(&row);
    }
    let mut row = vec!["geomean".to_string()];
    for s in &per_cfg {
        row.push(if s.is_empty() { "-".to_string() } else { format!("{:.2}x", geomean(s)) });
    }
    t.row(&row);
}

/// Print the access-stream oracle verdicts: one line per comparison in
/// spec order, then the overall verdict. Mismatch lines carry the first
/// diverging record so CI logs are actionable without the artifact.
pub fn print_oracle_report(result: &CampaignResult) {
    if result.oracle.is_empty() {
        return;
    }
    let base = &result.oracle[0].baseline;
    println!(
        "\n== access-stream oracle: {} comparisons vs {base} ==",
        result.oracle.len()
    );
    for o in &result.oracle {
        let verdict = if o.matched { "   ok" } else { " FAIL" };
        println!("{verdict}  {:<34} {:<8} {}", o.config, o.workload, o.detail);
    }
    let mismatches = result.oracle.iter().filter(|o| !o.matched).count();
    if mismatches == 0 {
        println!("oracle verdict: OK ({}/{} matched)", result.oracle.len(), result.oracle.len());
    } else {
        println!("oracle verdict: DIVERGED ({mismatches} mismatches)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::exec::{run_campaign, ExecOptions};
    use crate::sweep::json;
    use crate::sweep::spec::CampaignSpec;

    #[test]
    fn artifact_parses_and_carries_the_grid() {
        let spec = CampaignSpec::builtin("smoke").unwrap();
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        let text = to_json(&res);
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.get("campaign").unwrap().as_str(), Some("smoke"));
        assert_eq!(
            doc.get("schema_version").unwrap().as_f64(),
            Some(SCHEMA_VERSION as f64)
        );
        let cells = doc.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        for cell in cells {
            assert_eq!(cell.get("status").unwrap().as_str(), Some("ok"));
            let m = cell.get("metrics").unwrap();
            assert!(m.get("cycles").unwrap().as_f64().unwrap() > 0.0);
            assert!(m.get("host_seconds").is_some());
            assert!(m.get("events_per_sec").is_some());
            assert!(m.get("cu_loads").unwrap().as_f64().is_some());
            // Per-shard occupancy rides in the host section; the shard
            // events fold back to the engine total.
            let occ = m.get("shard_events").unwrap().as_arr().unwrap();
            let total: f64 = occ.iter().map(|v| v.as_f64().unwrap()).sum();
            assert_eq!(total, m.get("events").unwrap().as_f64().unwrap());
            assert!(m.get("shard_windows").is_some());
            assert!(m.get("shard_idle_windows").is_some());
        }
        // Canonical form drops host timing and nothing else.
        let canon = to_json_canonical(&res);
        assert!(!canon.contains("host_seconds"));
        assert!(!canon.contains("events_per_sec"));
        assert!(!canon.contains("shard_events"));
        assert!(!canon.contains("shard_windows"));
        json::parse(&canon).unwrap();
    }

    #[test]
    fn mix_cells_carry_a_tenancy_section_in_the_canonical_form() {
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-NC\n\
             workloads = mix:private+private\n\
             set.n_gpus = 2\nset.cus_per_gpu = 2\nset.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\nset.stacks_per_gpu = 2\n\
             set.gpu_mem_bytes = 67108864\nset.scale = 0.05\n",
        )
        .unwrap();
        let opts = ExecOptions { jobs: 1, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        let doc = json::parse(&to_json_canonical(&res)).unwrap();
        let cells = doc.get("cells").unwrap().as_arr().unwrap();
        let t = cells[0].get("metrics").unwrap().get("tenancy").unwrap();
        assert_eq!(t.get("scheduler").unwrap().as_str(), Some("fifo"));
        assert!(t.get("jain_turnaround").unwrap().as_f64().unwrap() > 0.0);
        let tenants = t.get("tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), 2);
        for tm in tenants {
            assert_eq!(tm.get("jobs").unwrap().as_f64(), Some(1.0));
            assert!(tm.get("turnaround_mean").unwrap().as_f64().unwrap() > 0.0);
        }
        // Ordinary cells stay untouched: no tenancy key anywhere else.
        let smoke = run_campaign(
            &CampaignSpec::builtin("smoke").unwrap(),
            &ExecOptions { jobs: 1, progress: false, ..Default::default() },
        )
        .unwrap();
        assert!(!to_json_canonical(&smoke).contains("tenancy"));
    }

    #[test]
    fn duplicate_overrides_serialize_last_wins() {
        let v = overrides_obj(&[
            ("scale".to_string(), "0.5".to_string()),
            ("n_gpus".to_string(), "2".to_string()),
            ("scale".to_string(), "0.25".to_string()),
        ]);
        assert_eq!(v.get("scale").unwrap().as_str(), Some("0.25"));
        assert_eq!(v.get("n_gpus").unwrap().as_str(), Some("2"));
        match &v {
            Value::Obj(kvs) => assert_eq!(kvs.len(), 2),
            _ => panic!("expected object"),
        }
    }

    #[test]
    fn artifact_spec_roundtrips_for_gate_reruns() {
        // The gate reconstructs the campaign from the artifact; every
        // grid-defining field must survive the round trip.
        let mut spec = CampaignSpec::builtin("smoke").unwrap();
        spec.fixed.push(("l1_bytes".into(), "8192".into())); // like --set
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        let doc = json::parse(&to_json(&res)).unwrap();
        let rebuilt = CampaignSpec::from_artifact(&doc).unwrap();
        assert_eq!(rebuilt.name, spec.name);
        assert_eq!(rebuilt.presets, spec.presets);
        assert_eq!(rebuilt.workloads, spec.workloads);
        assert_eq!(rebuilt.axes, spec.axes);
        assert_eq!(rebuilt.fixed, spec.fixed);
        assert_eq!(rebuilt.baseline.as_deref(), Some("SM-WT-NC"));
    }

    #[test]
    fn oracle_campaigns_render_verdicts_and_roundtrip_the_spec_key() {
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-C-HALCONE,SM-WT-C-TARDIS\n\
             workloads = rl\n\
             baseline = SM-WT-C-HALCONE\n\
             oracle = access-stream\n\
             set.n_gpus = 2\nset.cus_per_gpu = 2\nset.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\nset.stacks_per_gpu = 2\n\
             set.gpu_mem_bytes = 67108864\nset.scale = 0.05\n",
        )
        .unwrap();
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        let doc = json::parse(&to_json_canonical(&res)).unwrap();
        // The spec key survives for gate re-runs...
        let rebuilt = CampaignSpec::from_artifact(&doc).unwrap();
        assert_eq!(rebuilt.oracle.as_deref(), Some("access-stream"));
        // ...and the verdict section is canonical.
        let oracle = doc.get("oracle").unwrap();
        assert_eq!(oracle.get("ok").unwrap().as_bool(), Some(true));
        let checks = oracle.get("checks").unwrap().as_arr().unwrap();
        assert_eq!(checks.len(), 1);
        assert_eq!(checks[0].get("config").unwrap().as_str(), Some("SM-WT-C-TARDIS"));
        assert_eq!(checks[0].get("matched").unwrap().as_bool(), Some(true));
        // Oracle-free campaigns keep their exact bytes: no oracle key.
        let smoke = run_campaign(
            &CampaignSpec::builtin("smoke").unwrap(),
            &ExecOptions { jobs: 1, progress: false, ..Default::default() },
        )
        .unwrap();
        assert!(!to_json_canonical(&smoke).contains("oracle"));
    }

    #[test]
    fn resume_reconstruction_roundtrips_canonical_bytes() {
        // Render -> reload -> re-render must be the identity on the
        // canonical artifact: the foundation of `sweep --resume`.
        let spec = CampaignSpec::builtin("smoke").unwrap();
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        let doc = json::parse(&to_json(&res)).unwrap();
        let preloaded = outcomes_from_artifact(&doc).unwrap();
        assert_eq!(preloaded.len(), 4, "all terminal cells reload");
        let rebuilt = CampaignSpec::from_artifact(&doc).unwrap();
        let resumed = run_campaign(
            &rebuilt,
            &ExecOptions { jobs: 1, progress: false, preloaded, ..Default::default() },
        )
        .unwrap();
        assert!(resumed.cells.iter().all(|c| c.exec.resumed));
        assert_eq!(to_json_canonical(&resumed), to_json_canonical(&res));
    }

    #[test]
    fn pending_cells_rerun_on_resume_and_bytes_still_match() {
        // Flip one journaled cell back to pending (as a mid-campaign
        // kill would leave it): resume re-runs just that cell and the
        // final canonical artifact is still byte-identical.
        let spec = CampaignSpec::builtin("smoke").unwrap();
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        let journal = to_json(&res).replacen("\"status\": \"ok\"", "\"status\": \"pending\"", 1);
        let doc = json::parse(&journal).unwrap();
        let preloaded = outcomes_from_artifact(&doc).unwrap();
        assert_eq!(preloaded.len(), 3, "the pending cell is left to re-run");
        let rebuilt = CampaignSpec::from_artifact(&doc).unwrap();
        let resumed = run_campaign(
            &rebuilt,
            &ExecOptions { jobs: 1, progress: false, preloaded, ..Default::default() },
        )
        .unwrap();
        assert_eq!(to_json_canonical(&resumed), to_json_canonical(&res));
    }

    #[test]
    fn tenancy_sections_survive_the_resume_roundtrip() {
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-NC\n\
             workloads = mix:private+private\n\
             set.n_gpus = 2\nset.cus_per_gpu = 2\nset.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\nset.stacks_per_gpu = 2\n\
             set.gpu_mem_bytes = 67108864\nset.scale = 0.05\n",
        )
        .unwrap();
        let opts = ExecOptions { jobs: 1, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        let doc = json::parse(&to_json(&res)).unwrap();
        let preloaded = outcomes_from_artifact(&doc).unwrap();
        let resumed = run_campaign(
            &CampaignSpec::from_artifact(&doc).unwrap(),
            &ExecOptions { jobs: 1, progress: false, preloaded, ..Default::default() },
        )
        .unwrap();
        assert_eq!(to_json_canonical(&resumed), to_json_canonical(&res));
    }

    #[test]
    fn baseline_cells_report_speedup_one() {
        let spec = CampaignSpec::builtin("smoke").unwrap();
        let opts = ExecOptions { jobs: 1, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        let base = baseline_label(&res);
        assert_eq!(base, "SM-WT-NC");
        for wl in &res.spec.workloads {
            let cr = res.get(&base, wl).unwrap();
            let s = speedup_of(&res, cr, &base).unwrap();
            assert!((s - 1.0).abs() < 1e-12, "{wl}: {s}");
        }
    }
}
