//! Parallel campaign executor.
//!
//! Cells are independent deterministic simulations (see
//! `deterministic_across_runs` in `coordinator::runner`), so the grid is
//! embarrassingly parallel: a pool of `std::thread::scope` workers pulls
//! cell indices off a shared atomic counter (work stealing degenerates
//! to work *sharing* with a single queue, which is optimal here — cells
//! are coarse, milliseconds to minutes each). Each cell runs under
//! `catch_unwind`, so a deadlocked or asserting simulation fails that
//! cell and the campaign keeps draining. Results land in per-cell slots
//! indexed by expansion order, which keeps every artifact byte-stable
//! regardless of `--jobs` (the determinism contract in
//! `tests/sweep_campaign.rs`).
//!
//! Resilience (docs/ROBUSTNESS.md): an optional per-cell wall-clock
//! watchdog fails cells that hang instead of wedging the campaign,
//! failed/timed-out cells can be retried with exponential backoff, and
//! the full artifact can be journaled (write-temp + atomic rename) after
//! every completed cell so a killed campaign resumes with
//! `halcone sweep --resume` — completed cells are reloaded from the
//! journal, only unfinished ones re-run, and the final canonical
//! artifact is byte-identical to an uninterrupted run's.

use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::runner::{
    run_workload, run_workload_traced, try_run_workload_snap, SnapMode,
};
use crate::coordinator::verify::CheckOutcome;
use crate::metrics::RunMetrics;
use crate::sweep::spec::{CampaignSpec, Cell};
use crate::trace::Trace;

/// What happened to one cell.
#[derive(Clone)]
pub enum CellOutcome {
    /// Simulation finished (checks may still have failed).
    Finished { metrics: RunMetrics, checks: Vec<CheckOutcome> },
    /// The simulation panicked (deadlock assert, bad config interaction).
    Failed { error: String },
    /// The watchdog expired on the final attempt; the simulation thread
    /// was abandoned and its eventual result discarded.
    TimedOut { seconds: u64 },
    /// Not yet run — the journal placeholder an interrupted campaign
    /// leaves behind for `--resume` to fill in.
    Pending,
}

/// Host-side execution record for one cell: wall-clock, retry and
/// watchdog bookkeeping. Lives only in the *full* artifact (like
/// `host_seconds`); the canonical form never carries it, so resumed and
/// uninterrupted campaigns stay byte-identical.
#[derive(Clone, Copy, Debug, Default)]
pub struct CellExec {
    /// Wall-clock seconds of the final attempt.
    pub wall_seconds: f64,
    /// Extra attempts consumed by retry-on-failure.
    pub retries: u32,
    /// At least one attempt hit the watchdog.
    pub timed_out: bool,
    /// Outcome was reloaded from a `--resume` journal, not run here.
    pub resumed: bool,
}

/// One cell plus its outcome.
pub struct CellResult {
    pub cell: Cell,
    pub outcome: CellOutcome,
    pub exec: CellExec,
}

impl CellResult {
    pub fn metrics(&self) -> Option<&RunMetrics> {
        match &self.outcome {
            CellOutcome::Finished { metrics, .. } => Some(metrics),
            _ => None,
        }
    }

    pub fn checks(&self) -> &[CheckOutcome] {
        match &self.outcome {
            CellOutcome::Finished { checks, .. } => checks,
            _ => &[],
        }
    }

    pub fn error(&self) -> Option<&str> {
        match &self.outcome {
            CellOutcome::Failed { error } => Some(error),
            _ => None,
        }
    }

    /// Finished with every check green.
    pub fn passed(&self) -> bool {
        matches!(&self.outcome, CellOutcome::Finished { checks, .. }
                 if checks.iter().all(|c| c.passed))
    }

    /// Artifact status tag:
    /// `ok` | `checks_failed` | `error` | `timeout` | `pending`.
    pub fn status(&self) -> &'static str {
        match &self.outcome {
            CellOutcome::Failed { .. } => "error",
            CellOutcome::TimedOut { .. } => "timeout",
            CellOutcome::Pending => "pending",
            CellOutcome::Finished { checks, .. } => {
                if checks.iter().all(|c| c.passed) {
                    "ok"
                } else {
                    "checks_failed"
                }
            }
        }
    }
}

/// Executor knobs.
pub struct ExecOptions {
    /// Worker threads (clamped to the cell count; min 1).
    pub jobs: usize,
    /// Stream one line per finished cell to stderr.
    pub progress: bool,
    /// Engine worker threads per cell (`--shards`): overrides every
    /// cell's `shards` config key without touching the spec, so the
    /// campaign artifact stays byte-identical across `--shards` levels
    /// (`tests/shard_determinism.rs`). `None` keeps the cells' own
    /// settings.
    pub shards: Option<usize>,
    /// Per-cell wall-clock watchdog in seconds (`--timeout`); `None`
    /// disables it. A timed-out attempt abandons its simulation thread —
    /// the cell records `status = "timeout"` and the campaign drains on.
    pub timeout: Option<u64>,
    /// Extra attempts for panicked/timed-out cells (`--retries`), with
    /// exponential backoff between attempts. Simulations are
    /// deterministic, so this only helps host-level flakiness (memory
    /// pressure, a watchdog tripped by a loaded machine).
    pub retries: u32,
    /// Journal file: the *full* artifact is rewritten here (write-temp +
    /// atomic rename) after every completed cell, unfinished cells
    /// marked `pending` — the `--resume` re-entry point.
    pub journal: Option<std::path::PathBuf>,
    /// Outcomes reloaded from a `--resume` journal, by cell index; those
    /// cells are not re-run.
    pub preloaded: Vec<(usize, CellOutcome, CellExec)>,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            jobs: default_jobs(),
            progress: true,
            shards: None,
            timeout: None,
            retries: 0,
            journal: None,
            preloaded: Vec::new(),
        }
    }
}

/// Host parallelism (the `--jobs` default).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One access-stream oracle comparison: a cell's captured trace against
/// the baseline cell's trace for the same workload. The comparison is
/// *structural* only — per-wavefront access kind/address/size/ordering
/// must match, while cycle timing is expected to differ across
/// protocols (that difference is the sweep's whole point).
#[derive(Clone)]
pub struct OracleCheck {
    pub workload: String,
    pub config: String,
    pub baseline: String,
    pub matched: bool,
    /// Human-readable evidence: record count when matched, the first
    /// diverging record (or the missing-trace reason) when not.
    pub detail: String,
}

/// A finished campaign: the spec plus one result per cell, in spec order.
pub struct CampaignResult {
    pub spec: CampaignSpec,
    pub jobs: usize,
    pub cells: Vec<CellResult>,
    /// Access-stream oracle comparisons (`oracle = access-stream`
    /// specs); empty when the spec declares no oracle.
    pub oracle: Vec<OracleCheck>,
}

impl CampaignResult {
    /// Cell lookup by config label (see `Cell::config_label`) + workload.
    pub fn get(&self, config: &str, workload: &str) -> Option<&CellResult> {
        self.cells
            .iter()
            .find(|c| c.cell.config_label == config && c.cell.workload == workload)
    }

    /// Every cell finished and passed its checks.
    pub fn all_passed(&self) -> bool {
        self.cells.iter().all(|c| c.passed())
    }

    /// Some cell hit the watchdog (the partial-result exit code 4).
    pub fn any_timed_out(&self) -> bool {
        self.cells.iter().any(|c| matches!(c.outcome, CellOutcome::TimedOut { .. }))
    }

    /// Every oracle comparison matched (vacuously true without an
    /// oracle). A mismatch is its own failure class: the cells may all
    /// pass their checks while two protocols disagree on the stream.
    pub fn oracle_ok(&self) -> bool {
        self.oracle.iter().all(|o| o.matched)
    }

    /// Panicking metrics lookup for consumers that know the cell exists
    /// (the figure benches address their grids by construction).
    pub fn expect_metrics(&self, config: &str, workload: &str) -> &RunMetrics {
        self.get(config, workload)
            .and_then(|c| c.metrics())
            .unwrap_or_else(|| panic!("missing cell {config}/{workload}"))
    }
}

type Slot = Mutex<Option<(CellOutcome, CellExec)>>;

/// Expand `spec` and run every cell on up to `opts.jobs` threads.
/// Errors only on an invalid spec, a bad `--resume` preload or an
/// internal executor fault; per-cell failures are recorded in the
/// result, not propagated.
pub fn run_campaign(spec: &CampaignSpec, opts: &ExecOptions) -> Result<CampaignResult, String> {
    let cells = spec.cells()?;
    let total = cells.len();
    let slots: Vec<Slot> = (0..total).map(|_| Mutex::new(None)).collect();

    // Divergence oracle (docs/PROTOCOLS.md): capture every cell's access
    // stream for the post-pool comparison. Traces are never journaled,
    // so resumed campaigns cannot honor the oracle — refuse up front
    // (the CLI rejects `--resume` for oracle specs with the same words).
    let capture = spec.oracle.is_some();
    if capture && !opts.preloaded.is_empty() {
        return Err(
            "oracle campaigns cannot resume: access-stream traces are not journaled".into(),
        );
    }
    let trace_slots: Vec<Mutex<Option<Trace>>> = (0..total).map(|_| Mutex::new(None)).collect();

    // Preload resumed outcomes; only the remaining cells run.
    let mut filled = vec![false; total];
    for (i, outcome, exec) in &opts.preloaded {
        if *i >= total {
            return Err(format!(
                "resume: cell index {i} out of range (the grid has {total} cells)"
            ));
        }
        if std::mem::replace(&mut filled[*i], true) {
            return Err(format!("resume: cell index {i} appears twice in the journal"));
        }
        *lock_slot(&slots[*i], *i)? = Some((outcome.clone(), *exec));
    }
    let todo: Vec<usize> = (0..total).filter(|&i| !filled[i]).collect();

    let mut jobs = opts.jobs.max(1).min(todo.len().max(1));
    // When cells run multi-shard, every job spawns that many engine
    // threads: cap jobs x shards at the host parallelism instead of
    // oversubscribing (8 jobs x 4 shards on an 8-core box would
    // thrash). Both knobs clamp — shards down to the core count (thread
    // count never changes results), then jobs to cores / shards.
    let cores = default_jobs();
    let shards_per_cell = opts
        .shards
        .unwrap_or_else(|| {
            cells
                .iter()
                .map(|c| c.config().map_or(1, |cfg| cfg.shards as usize))
                .max()
                .unwrap_or(1)
        })
        .clamp(1, cores);
    if shards_per_cell > 1 {
        jobs = jobs.min((cores / shards_per_cell).max(1));
    }

    // Journal the starting state (all unfinished cells pending) before
    // any worker runs, so even an immediately-killed campaign leaves a
    // resumable file behind.
    let journal_lock = Mutex::new(());
    if let Some(path) = &opts.journal {
        write_journal(path, spec, jobs, &cells, &slots)?;
    }

    // Warm-start forking (docs/SNAPSHOT.md): with a `warmup` prefix
    // declared, cells fork from per-fingerprint snapshots instead of
    // replaying the first `warmup` cycles on every run.
    let fork = spec.warmup.map(|at| ForkCtx::new(at, opts.journal.as_deref()));

    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(total - todo.len());

    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let t = next.fetch_add(1, Ordering::Relaxed);
                if t >= todo.len() {
                    break;
                }
                let i = todo[t];
                let cell = &cells[i];
                let (outcome, exec, trace) =
                    run_cell_guarded(cell, opts, cores, fork.as_ref(), capture);
                if opts.progress {
                    let n = done.fetch_add(1, Ordering::Relaxed) + 1;
                    progress_line(n, total, cell, &outcome);
                }
                if let Ok(mut slot) = trace_slots[i].lock() {
                    *slot = trace;
                }
                if let Ok(mut slot) = slots[i].lock() {
                    *slot = Some((outcome, exec));
                }
                if let Some(path) = &opts.journal {
                    // Serialize writers: the temp file is shared, and
                    // interleaved write+rename pairs would corrupt it.
                    let _guard = journal_lock.lock();
                    if let Err(e) = write_journal(path, spec, jobs, &cells, &slots) {
                        eprintln!("warning: journal {}: {e}", path.display());
                    }
                }
            });
        }
    });

    // Oracle comparison happens after the pool drains (it needs the
    // baseline cell's trace, which may finish last) but before `cells`
    // is consumed into results.
    let oracle = if capture {
        let mut traces: Vec<Option<Trace>> = Vec::with_capacity(total);
        for (i, slot) in trace_slots.into_iter().enumerate() {
            traces.push(slot.into_inner().map_err(|_| {
                format!("cell {i}: a worker panicked while filling its trace slot")
            })?);
        }
        oracle_checks(spec, &cells, &traces)
    } else {
        Vec::new()
    };

    let mut results = Vec::with_capacity(total);
    for (cell, slot) in cells.into_iter().zip(slots) {
        let i = cell.index;
        let (outcome, exec) = slot
            .into_inner()
            .map_err(|_| format!("cell {i}: a worker panicked while filling its result slot"))?
            .ok_or_else(|| format!("cell {i}: worker pool exited with an unfilled slot"))?;
        results.push(CellResult { cell, outcome, exec });
    }
    Ok(CampaignResult { spec: spec.clone(), jobs, cells: results, oracle })
}

/// Compare every non-baseline cell's access stream against the baseline
/// config's cell for the same workload. Cells without a trace (failed,
/// timed out) count as mismatches — an oracle that silently skipped
/// broken cells would report a clean table over an unchecked grid.
fn oracle_checks(
    spec: &CampaignSpec,
    cells: &[Cell],
    traces: &[Option<Trace>],
) -> Vec<OracleCheck> {
    let baseline = spec
        .baseline
        .clone()
        .or_else(|| cells.first().map(|c| c.config_label.clone()))
        .unwrap_or_default();
    let mut checks = Vec::new();
    for cell in cells {
        if cell.config_label == baseline {
            continue;
        }
        let base_trace = cells
            .iter()
            .find(|c| c.config_label == baseline && c.workload == cell.workload)
            .and_then(|b| traces[b.index].as_ref());
        let (matched, detail) = match (base_trace, traces[cell.index].as_ref()) {
            (Some(b), Some(t)) => {
                let rep = crate::metrics::divergence::diff_traces(b, t);
                if rep.structural_identical() {
                    (true, format!("{} records identical", rep.compared))
                } else if let Some(shape) = rep.shape_mismatch {
                    (false, shape)
                } else {
                    let first = rep.first_structural.unwrap_or_default();
                    (false, format!(
                        "{} of {} records diverge; first: {first}",
                        rep.structural_mismatches, rep.compared
                    ))
                }
            }
            (None, _) => (
                false,
                format!("baseline cell {baseline}/{} produced no trace", cell.workload),
            ),
            (_, None) => (false, "cell produced no trace (failed or timed out)".into()),
        };
        checks.push(OracleCheck {
            workload: cell.workload.clone(),
            config: cell.config_label.clone(),
            baseline: baseline.clone(),
            matched,
            detail,
        });
    }
    checks
}

fn lock_slot<'a>(
    slot: &'a Slot,
    i: usize,
) -> Result<std::sync::MutexGuard<'a, Option<(CellOutcome, CellExec)>>, String> {
    slot.lock().map_err(|_| format!("cell {i}: result slot mutex poisoned"))
}

/// Warm-start fork state shared by one campaign's workers
/// (docs/SNAPSHOT.md): snapshots of the warmup prefix keyed by config
/// fingerprint. The first run of each fingerprint fills its entry (and
/// mirrors it to disk when a journal directory exists); retries of the
/// same cell and re-runs of the campaign into the same directory then
/// fork from the snapshot instead of replaying the prefix.
struct ForkCtx {
    /// Snapshot cycle (the spec's `warmup`).
    at: u64,
    cache: Mutex<HashMap<u64, Arc<Vec<u8>>>>,
    /// On-disk mirror (`<journal-dir>/snapshots/`); `None` keeps the
    /// forks purely in-memory.
    dir: Option<std::path::PathBuf>,
}

impl ForkCtx {
    fn new(at: u64, journal: Option<&std::path::Path>) -> Arc<ForkCtx> {
        let dir = journal.and_then(|j| {
            let d = j.parent().unwrap_or_else(|| std::path::Path::new(".")).join("snapshots");
            match std::fs::create_dir_all(&d) {
                Ok(()) => Some(d),
                Err(e) => {
                    eprintln!("warning: snapshot dir {}: {e}; forks stay in-memory", d.display());
                    None
                }
            }
        });
        Arc::new(ForkCtx { at, cache: Mutex::new(HashMap::new()), dir })
    }

    fn path(&self, fp: u64) -> Option<String> {
        self.dir.as_ref().map(|d| d.join(format!("{fp:016x}.snap")).display().to_string())
    }

    /// Snapshot bytes for `fp`: memory cache first, then the on-disk
    /// mirror (a previous campaign into the same directory). Unreadable
    /// files are treated as absent — the cell just runs cold.
    fn lookup(&self, fp: u64) -> Option<Arc<Vec<u8>>> {
        if let Ok(cache) = self.cache.lock() {
            if let Some(b) = cache.get(&fp) {
                return Some(b.clone());
            }
        }
        let path = self.path(fp)?;
        let bytes = Arc::new(crate::snapshot::read_file(&path).ok()?);
        if let Ok(mut cache) = self.cache.lock() {
            cache.entry(fp).or_insert_with(|| bytes.clone());
        }
        Some(bytes)
    }

    /// Record a freshly saved snapshot; the disk mirror goes through
    /// write-temp + atomic rename, so a kill mid-write never leaves a
    /// corrupt `.snap` under the final name.
    fn store(&self, fp: u64, bytes: Vec<u8>, cell: &Cell) {
        let bytes = Arc::new(bytes);
        if let Some(path) = self.path(fp) {
            if let Err(e) = crate::snapshot::write_file(&path, &bytes) {
                eprintln!(
                    "warning: cell {}/{}: snapshot {path}: {e}",
                    cell.config_label, cell.workload
                );
            }
        }
        if let Ok(mut cache) = self.cache.lock() {
            cache.insert(fp, bytes);
        }
    }
}

/// Snapshot the campaign-in-progress (unfinished cells `Pending`) and
/// atomically replace the journal file: write a sibling temp file, then
/// rename over the target, so a kill at any instant leaves either the
/// previous journal or the new one — never a torn write.
fn write_journal(
    path: &std::path::Path,
    spec: &CampaignSpec,
    jobs: usize,
    cells: &[Cell],
    slots: &[Slot],
) -> Result<(), String> {
    let mut snapshot = Vec::with_capacity(cells.len());
    for cell in cells {
        let (outcome, exec) = lock_slot(&slots[cell.index], cell.index)?
            .clone()
            .unwrap_or((CellOutcome::Pending, CellExec::default()));
        snapshot.push(CellResult { cell: cell.clone(), outcome, exec });
    }
    let result = CampaignResult { spec: spec.clone(), jobs, cells: snapshot, oracle: Vec::new() };
    let text = crate::sweep::report::to_json(&result);
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| format!("journal path '{}' has no file name", path.display()))?;
    let tmp = path.with_file_name(format!("{name}.tmp"));
    std::fs::write(&tmp, &text).map_err(|e| format!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("renaming {} -> {}: {e}", tmp.display(), path.display()))?;
    Ok(())
}

/// Run one cell with the watchdog and retry policy applied.
fn run_cell_guarded(
    cell: &Cell,
    opts: &ExecOptions,
    host_cores: usize,
    fork: Option<&Arc<ForkCtx>>,
    capture: bool,
) -> (CellOutcome, CellExec, Option<Trace>) {
    let mut exec = CellExec::default();
    loop {
        let start = Instant::now();
        let (outcome, trace) =
            run_cell_attempt(cell, opts.shards, host_cores, opts.timeout, fork, capture);
        exec.wall_seconds = start.elapsed().as_secs_f64();
        if matches!(outcome, CellOutcome::TimedOut { .. }) {
            exec.timed_out = true;
        }
        let failed = matches!(outcome, CellOutcome::Failed { .. } | CellOutcome::TimedOut { .. });
        if !failed || exec.retries >= opts.retries {
            return (outcome, exec, trace);
        }
        // Exponential backoff, capped: the sim is deterministic, so a
        // retry only helps when the *host* was the problem — give it a
        // moment to recover.
        let backoff = (200u64 << exec.retries.min(5)).min(5_000);
        std::thread::sleep(Duration::from_millis(backoff));
        exec.retries += 1;
    }
}

/// One attempt, watchdogged when a timeout is set. The cell runs on a
/// *detached* thread on purpose: a scoped (joined) thread would block
/// forever on exactly the hang the watchdog exists to catch. On timeout
/// the thread is abandoned — it keeps running, its result drops with
/// the dead channel.
fn run_cell_attempt(
    cell: &Cell,
    shards: Option<usize>,
    host_cores: usize,
    timeout: Option<u64>,
    fork: Option<&Arc<ForkCtx>>,
    capture: bool,
) -> (CellOutcome, Option<Trace>) {
    let Some(secs) = timeout else {
        return run_cell(cell, shards, host_cores, fork.map(Arc::as_ref), capture);
    };
    let (tx, rx) = mpsc::channel();
    let owned = cell.clone();
    let owned_fork = fork.cloned();
    let spawned = std::thread::Builder::new()
        .name(format!("cell-{}", owned.index))
        .spawn(move || {
            let _ = tx.send(run_cell(&owned, shards, host_cores, owned_fork.as_deref(), capture));
        });
    if let Err(e) = spawned {
        return (CellOutcome::Failed { error: format!("spawning cell worker: {e}") }, None);
    }
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(pair) => pair,
        Err(mpsc::RecvTimeoutError::Timeout) => (CellOutcome::TimedOut { seconds: secs }, None),
        Err(mpsc::RecvTimeoutError::Disconnected) => (
            CellOutcome::Failed { error: "cell worker exited without reporting a result".into() },
            None,
        ),
    }
}

fn run_cell(
    cell: &Cell,
    shards: Option<usize>,
    host_cores: usize,
    fork: Option<&ForkCtx>,
    capture: bool,
) -> (CellOutcome, Option<Trace>) {
    let mut cfg = match cell.config() {
        Ok(c) => c,
        Err(e) => return (CellOutcome::Failed { error: e }, None),
    };
    // Executor-level thread clamp: apply the --shards override and cap
    // at the host cores. Never recorded in the spec/artifact — thread
    // count cannot change results, only wall-clock.
    cfg.shards = shards
        .unwrap_or(cfg.shards as usize)
        .clamp(1, host_cores.max(1)) as u32;
    // The simulator runs artifact-free here (the PJRT runtime is not
    // thread-shareable); Rust reference checks still verify every cell.
    // The default panic hook stays installed, so a failing cell also
    // prints its raw panic line to stderr — swapping the hook is
    // process-global and would race concurrent tests.
    if capture {
        // Oracle path. Trace capture cannot combine with snapshots, and
        // spec validation rejects `oracle` + `warmup`, so `fork` is
        // always None here — the traced cold run covers every oracle
        // cell. Captured traces are shard-invariant, so the --shards
        // clamp above never perturbs the comparison.
        return match panic::catch_unwind(AssertUnwindSafe(|| {
            run_workload_traced(&cfg, &cell.workload, None, true)
        })) {
            Ok((res, trace)) => {
                (CellOutcome::Finished { metrics: res.metrics, checks: res.checks }, trace)
            }
            Err(payload) => (CellOutcome::Failed { error: panic_message(payload) }, None),
        };
    }
    let Some(fork) = fork else {
        let outcome = match panic::catch_unwind(AssertUnwindSafe(|| {
            run_workload(&cfg, &cell.workload, None)
        })) {
            Ok(res) => CellOutcome::Finished { metrics: res.metrics, checks: res.checks },
            Err(payload) => CellOutcome::Failed { error: panic_message(payload) },
        };
        return (outcome, None);
    };
    // Warm-start path. The fingerprint excludes `shards` by design, so
    // a snapshot saved at one thread count forks at any other; warm and
    // cold runs of a cell are byte-identical (`tests/snapshot_warmstart`).
    let fp = crate::snapshot::config_fingerprint(&cfg, &cell.workload);
    if let Some(bytes) = fork.lookup(fp) {
        let snap = SnapMode::Warm { bytes };
        match panic::catch_unwind(AssertUnwindSafe(|| {
            try_run_workload_snap(&cfg, &cell.workload, None, false, snap)
        })) {
            Ok(Ok((res, _, _))) => {
                return (
                    CellOutcome::Finished { metrics: res.metrics, checks: res.checks },
                    None,
                )
            }
            // A stale or corrupt snapshot is never fatal: warn and fall
            // through to a cold run (which refreshes the stored bytes).
            Ok(Err(e)) => eprintln!(
                "warning: cell {}/{}: warm start failed ({e}); running cold",
                cell.config_label, cell.workload
            ),
            Err(payload) => {
                return (CellOutcome::Failed { error: panic_message(payload) }, None)
            }
        }
    }
    // Cold run, snapshotting the warmup prefix for later forks. A run
    // that drains before the warmup cycle yields no snapshot — fine,
    // there is nothing left to skip on a re-run either.
    let snap = SnapMode::Save { at: fork.at };
    let outcome = match panic::catch_unwind(AssertUnwindSafe(|| {
        try_run_workload_snap(&cfg, &cell.workload, None, false, snap)
    })) {
        Ok(Ok((res, _, snap_bytes))) => {
            if let Some(bytes) = snap_bytes {
                fork.store(fp, bytes, cell);
            }
            CellOutcome::Finished { metrics: res.metrics, checks: res.checks }
        }
        Ok(Err(e)) => CellOutcome::Failed { error: e },
        Err(payload) => CellOutcome::Failed { error: panic_message(payload) },
    };
    (outcome, None)
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

fn progress_line(n: usize, total: usize, cell: &Cell, outcome: &CellOutcome) {
    match outcome {
        CellOutcome::Finished { metrics, checks } => eprintln!(
            "[{n}/{total}] {:<28} {:<8} {:>12} cycles  {}  ({:.2}s)",
            cell.config_label,
            cell.workload,
            metrics.cycles,
            if checks.iter().all(|c| c.passed) { "ok" } else { "CHECKS FAILED" },
            metrics.host_seconds,
        ),
        CellOutcome::Failed { error } => eprintln!(
            "[{n}/{total}] {:<28} {:<8} FAILED: {error}",
            cell.config_label, cell.workload,
        ),
        CellOutcome::TimedOut { seconds } => eprintln!(
            "[{n}/{total}] {:<28} {:<8} TIMEOUT after {seconds}s (thread abandoned)",
            cell.config_label, cell.workload,
        ),
        CellOutcome::Pending => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::CampaignSpec;

    fn tiny_spec(workloads: &str) -> CampaignSpec {
        CampaignSpec::parse(&format!(
            "name = t\n\
             presets = SM-WT-C-HALCONE\n\
             workloads = {workloads}\n\
             set.n_gpus = 2\n\
             set.cus_per_gpu = 2\n\
             set.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\n\
             set.stacks_per_gpu = 2\n\
             set.gpu_mem_bytes = 67108864\n\
             set.scale = 0.05\n"
        ))
        .unwrap()
    }

    #[test]
    fn runs_cells_and_indexes_results_in_spec_order() {
        let spec = tiny_spec("rl,fir");
        let opts = ExecOptions { jobs: 4, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.cells.len(), 2);
        assert!(res.all_passed(), "smoke cells failed");
        for (i, c) in res.cells.iter().enumerate() {
            assert_eq!(c.cell.index, i);
            assert_eq!(c.status(), "ok");
            assert!(c.metrics().unwrap().cycles > 0);
            assert!(c.exec.wall_seconds > 0.0);
            assert_eq!(c.exec.retries, 0);
            assert!(!c.exec.timed_out);
            assert!(!c.exec.resumed);
        }
        assert!(res.get("SM-WT-C-HALCONE", "fir").is_some());
        assert!(res.get("SM-WT-C-HALCONE", "nope").is_none());
    }

    #[test]
    fn a_panicking_cell_fails_alone() {
        // A 4 KB GPU partition is exhausted by the very first array
        // allocation (the bump allocator starts at offset 0x1000 and
        // asserts), so that cell must record an error while its healthy
        // sibling completes.
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-C-HALCONE\n\
             workloads = rl\n\
             axis.gpu_mem_bytes = 4096,67108864\n\
             set.n_gpus = 2\n\
             set.cus_per_gpu = 2\n\
             set.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\n\
             set.stacks_per_gpu = 2\n\
             set.scale = 0.05\n",
        )
        .unwrap();
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.cells.len(), 2);
        let broken = res.get("SM-WT-C-HALCONE+gpu_mem_bytes=4096", "rl").unwrap();
        assert_eq!(broken.status(), "error");
        assert!(broken.error().is_some());
        let healthy = res.get("SM-WT-C-HALCONE+gpu_mem_bytes=67108864", "rl").unwrap();
        assert_eq!(healthy.status(), "ok");
        assert!(!res.all_passed());
    }

    #[test]
    fn access_stream_oracle_matches_across_protocols() {
        // Every timestamp protocol must observe the identical access
        // stream: the coherence policy changes timing and hit rates,
        // never which accesses the wavefronts issue.
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-C-HALCONE,SM-WT-C-TARDIS,SM-WT-C-HLC\n\
             workloads = rl\n\
             baseline = SM-WT-C-HALCONE\n\
             oracle = access-stream\n\
             set.n_gpus = 2\n\
             set.cus_per_gpu = 2\n\
             set.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\n\
             set.stacks_per_gpu = 2\n\
             set.gpu_mem_bytes = 67108864\n\
             set.scale = 0.05\n",
        )
        .unwrap();
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert!(res.all_passed());
        assert_eq!(res.oracle.len(), 2, "one comparison per non-baseline config");
        for o in &res.oracle {
            assert_eq!(o.baseline, "SM-WT-C-HALCONE");
            assert!(o.matched, "{}/{} diverged: {}", o.config, o.workload, o.detail);
            assert!(o.detail.contains("identical"));
        }
        assert!(res.oracle_ok());
        // Traces are never journaled, so resume + oracle is refused.
        let resumed = ExecOptions {
            progress: false,
            preloaded: vec![(0, CellOutcome::Pending, CellExec::default())],
            ..Default::default()
        };
        assert!(run_campaign(&spec, &resumed).is_err());
    }

    #[test]
    fn a_traceless_cell_is_an_oracle_mismatch() {
        // The 4 KB cell panics before producing a trace; the oracle must
        // flag it rather than silently shrink the comparison set.
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-C-HALCONE\n\
             workloads = rl\n\
             axis.gpu_mem_bytes = 4096,67108864\n\
             baseline = SM-WT-C-HALCONE+gpu_mem_bytes=67108864\n\
             oracle = access-stream\n\
             set.n_gpus = 2\n\
             set.cus_per_gpu = 2\n\
             set.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\n\
             set.stacks_per_gpu = 2\n\
             set.scale = 0.05\n",
        )
        .unwrap();
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.oracle.len(), 1);
        assert!(!res.oracle[0].matched);
        assert!(res.oracle[0].detail.contains("no trace"));
        assert!(!res.oracle_ok());
    }

    #[test]
    fn jobs_larger_than_grid_is_fine() {
        let spec = tiny_spec("rl");
        let opts = ExecOptions { jobs: 64, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.cells.len(), 1);
        assert!(res.all_passed());
    }

    #[test]
    fn a_generous_watchdog_leaves_results_untouched() {
        // Same cells with and without the watchdog must produce the
        // same outcomes (the detached-thread path changes nothing but
        // the failure mode on hangs).
        let spec = tiny_spec("rl");
        let plain = run_campaign(
            &spec,
            &ExecOptions { jobs: 1, progress: false, ..Default::default() },
        )
        .unwrap();
        let dogged = run_campaign(
            &spec,
            &ExecOptions { jobs: 1, progress: false, timeout: Some(600), ..Default::default() },
        )
        .unwrap();
        assert!(dogged.all_passed());
        assert!(!dogged.any_timed_out());
        assert_eq!(
            plain.cells[0].metrics().unwrap().cycles,
            dogged.cells[0].metrics().unwrap().cycles,
        );
    }

    #[test]
    fn failed_cells_retry_and_record_the_attempt_count() {
        // The 4 KB cell panics deterministically: each retry fails
        // again, so the attempt budget is fully consumed and recorded.
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-C-HALCONE\n\
             workloads = rl\n\
             set.gpu_mem_bytes = 4096\n\
             set.n_gpus = 2\n\
             set.cus_per_gpu = 2\n\
             set.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\n\
             set.stacks_per_gpu = 2\n\
             set.scale = 0.05\n",
        )
        .unwrap();
        let opts = ExecOptions { jobs: 1, progress: false, retries: 2, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.cells[0].status(), "error");
        assert_eq!(res.cells[0].exec.retries, 2);
    }

    #[test]
    fn preloaded_cells_are_not_rerun_and_keep_their_outcome() {
        let spec = tiny_spec("rl,fir");
        let full = run_campaign(
            &spec,
            &ExecOptions { jobs: 2, progress: false, ..Default::default() },
        )
        .unwrap();
        // Preload cell 0 with a sentinel error: if the executor re-ran
        // it, the outcome would be "ok" instead.
        let opts = ExecOptions {
            jobs: 2,
            progress: false,
            preloaded: vec![(
                0,
                CellOutcome::Failed { error: "sentinel".into() },
                CellExec { resumed: true, ..Default::default() },
            )],
            ..Default::default()
        };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.cells[0].status(), "error");
        assert_eq!(res.cells[0].error(), Some("sentinel"));
        assert!(res.cells[0].exec.resumed);
        assert_eq!(res.cells[1].status(), "ok");
        assert_eq!(
            res.cells[1].metrics().unwrap().cycles,
            full.cells[1].metrics().unwrap().cycles,
        );
        // Out-of-range and duplicate preloads are spec-level errors.
        let bad = ExecOptions {
            progress: false,
            preloaded: vec![(9, CellOutcome::Pending, CellExec::default())],
            ..Default::default()
        };
        assert!(run_campaign(&spec, &bad).is_err());
        let dup = ExecOptions {
            progress: false,
            preloaded: vec![
                (0, CellOutcome::Pending, CellExec::default()),
                (0, CellOutcome::Pending, CellExec::default()),
            ],
            ..Default::default()
        };
        assert!(run_campaign(&spec, &dup).is_err());
    }

    #[test]
    fn journal_is_written_atomically_after_every_cell() {
        let dir = std::env::temp_dir().join(format!("halcone-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("campaign.json");
        let spec = tiny_spec("rl,fir");
        let opts = ExecOptions {
            jobs: 1,
            progress: false,
            journal: Some(path.clone()),
            ..Default::default()
        };
        let res = run_campaign(&spec, &opts).unwrap();
        // The last journal write is the complete artifact: re-parse it
        // and check every cell reached a terminal status.
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::sweep::json::parse(&text).unwrap();
        let cells = doc.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        for c in cells {
            assert_eq!(c.get("status").unwrap().as_str(), Some("ok"));
            assert!(c.get("exec").is_some(), "journal carries host exec info");
        }
        // No temp file left behind.
        assert!(!dir.join("campaign.json.tmp").exists());
        assert!(res.all_passed());
        std::fs::remove_dir_all(&dir).ok();
    }
}
