//! Parallel campaign executor.
//!
//! Cells are independent deterministic simulations (see
//! `deterministic_across_runs` in `coordinator::runner`), so the grid is
//! embarrassingly parallel: a pool of `std::thread::scope` workers pulls
//! cell indices off a shared atomic counter (work stealing degenerates
//! to work *sharing* with a single queue, which is optimal here — cells
//! are coarse, milliseconds to minutes each). Each cell runs under
//! `catch_unwind`, so a deadlocked or asserting simulation fails that
//! cell and the campaign keeps draining. Results land in per-cell slots
//! indexed by expansion order, which keeps every artifact byte-stable
//! regardless of `--jobs` (the determinism contract in
//! `tests/sweep_campaign.rs`).

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::runner::run_workload;
use crate::coordinator::verify::CheckOutcome;
use crate::metrics::RunMetrics;
use crate::sweep::spec::{CampaignSpec, Cell};

/// What happened to one cell.
pub enum CellOutcome {
    /// Simulation finished (checks may still have failed).
    Finished { metrics: RunMetrics, checks: Vec<CheckOutcome> },
    /// The simulation panicked (deadlock assert, bad config interaction).
    Failed { error: String },
}

/// One cell plus its outcome.
pub struct CellResult {
    pub cell: Cell,
    pub outcome: CellOutcome,
}

impl CellResult {
    pub fn metrics(&self) -> Option<&RunMetrics> {
        match &self.outcome {
            CellOutcome::Finished { metrics, .. } => Some(metrics),
            CellOutcome::Failed { .. } => None,
        }
    }

    pub fn checks(&self) -> &[CheckOutcome] {
        match &self.outcome {
            CellOutcome::Finished { checks, .. } => checks,
            CellOutcome::Failed { .. } => &[],
        }
    }

    pub fn error(&self) -> Option<&str> {
        match &self.outcome {
            CellOutcome::Failed { error } => Some(error),
            CellOutcome::Finished { .. } => None,
        }
    }

    /// Finished with every check green.
    pub fn passed(&self) -> bool {
        matches!(&self.outcome, CellOutcome::Finished { checks, .. }
                 if checks.iter().all(|c| c.passed))
    }

    /// Artifact status tag: `ok` | `checks_failed` | `error`.
    pub fn status(&self) -> &'static str {
        match &self.outcome {
            CellOutcome::Failed { .. } => "error",
            CellOutcome::Finished { checks, .. } => {
                if checks.iter().all(|c| c.passed) {
                    "ok"
                } else {
                    "checks_failed"
                }
            }
        }
    }
}

/// Executor knobs.
pub struct ExecOptions {
    /// Worker threads (clamped to the cell count; min 1).
    pub jobs: usize,
    /// Stream one line per finished cell to stderr.
    pub progress: bool,
    /// Engine worker threads per cell (`--shards`): overrides every
    /// cell's `shards` config key without touching the spec, so the
    /// campaign artifact stays byte-identical across `--shards` levels
    /// (`tests/shard_determinism.rs`). `None` keeps the cells' own
    /// settings.
    pub shards: Option<usize>,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { jobs: default_jobs(), progress: true, shards: None }
    }
}

/// Host parallelism (the `--jobs` default).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A finished campaign: the spec plus one result per cell, in spec order.
pub struct CampaignResult {
    pub spec: CampaignSpec,
    pub jobs: usize,
    pub cells: Vec<CellResult>,
}

impl CampaignResult {
    /// Cell lookup by config label (see `Cell::config_label`) + workload.
    pub fn get(&self, config: &str, workload: &str) -> Option<&CellResult> {
        self.cells
            .iter()
            .find(|c| c.cell.config_label == config && c.cell.workload == workload)
    }

    /// Every cell finished and passed its checks.
    pub fn all_passed(&self) -> bool {
        self.cells.iter().all(|c| c.passed())
    }

    /// Panicking metrics lookup for consumers that know the cell exists
    /// (the figure benches address their grids by construction).
    pub fn expect_metrics(&self, config: &str, workload: &str) -> &RunMetrics {
        self.get(config, workload)
            .and_then(|c| c.metrics())
            .unwrap_or_else(|| panic!("missing cell {config}/{workload}"))
    }
}

/// Expand `spec` and run every cell on up to `opts.jobs` threads.
/// Errors only on an invalid spec; per-cell failures are recorded in the
/// result, not propagated.
pub fn run_campaign(spec: &CampaignSpec, opts: &ExecOptions) -> Result<CampaignResult, String> {
    let cells = spec.cells()?;
    let total = cells.len();
    let mut jobs = opts.jobs.max(1).min(total.max(1));
    // When cells run multi-shard, every job spawns that many engine
    // threads: cap jobs x shards at the host parallelism instead of
    // oversubscribing (8 jobs x 4 shards on an 8-core box would
    // thrash). Both knobs clamp — shards down to the core count (thread
    // count never changes results), then jobs to cores / shards.
    let cores = default_jobs();
    let shards_per_cell = opts
        .shards
        .unwrap_or_else(|| {
            cells
                .iter()
                .map(|c| c.config().map_or(1, |cfg| cfg.shards as usize))
                .max()
                .unwrap_or(1)
        })
        .clamp(1, cores);
    if shards_per_cell > 1 {
        jobs = jobs.min((cores / shards_per_cell).max(1));
    }
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<CellOutcome>>> = (0..total).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let cell = &cells[i];
                let outcome = run_cell(cell, opts.shards, cores);
                if opts.progress {
                    let n = done.fetch_add(1, Ordering::Relaxed) + 1;
                    progress_line(n, total, cell, &outcome);
                }
                *slots[i].lock().unwrap() = Some(outcome);
            });
        }
    });

    let results = cells
        .into_iter()
        .zip(slots)
        .map(|(cell, slot)| CellResult {
            cell,
            outcome: slot
                .into_inner()
                .unwrap()
                .expect("worker pool exited with an unfilled cell slot"),
        })
        .collect();
    Ok(CampaignResult { spec: spec.clone(), jobs, cells: results })
}

fn run_cell(cell: &Cell, shards: Option<usize>, host_cores: usize) -> CellOutcome {
    let mut cfg = match cell.config() {
        Ok(c) => c,
        Err(e) => return CellOutcome::Failed { error: e },
    };
    // Executor-level thread clamp: apply the --shards override and cap
    // at the host cores. Never recorded in the spec/artifact — thread
    // count cannot change results, only wall-clock.
    cfg.shards = shards
        .unwrap_or(cfg.shards as usize)
        .clamp(1, host_cores.max(1)) as u32;
    // The simulator runs artifact-free here (the PJRT runtime is not
    // thread-shareable); Rust reference checks still verify every cell.
    // The default panic hook stays installed, so a failing cell also
    // prints its raw panic line to stderr — swapping the hook is
    // process-global and would race concurrent tests.
    match panic::catch_unwind(AssertUnwindSafe(|| run_workload(&cfg, &cell.workload, None))) {
        Ok(res) => CellOutcome::Finished { metrics: res.metrics, checks: res.checks },
        Err(payload) => CellOutcome::Failed { error: panic_message(payload) },
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

fn progress_line(n: usize, total: usize, cell: &Cell, outcome: &CellOutcome) {
    match outcome {
        CellOutcome::Finished { metrics, checks } => eprintln!(
            "[{n}/{total}] {:<28} {:<8} {:>12} cycles  {}  ({:.2}s)",
            cell.config_label,
            cell.workload,
            metrics.cycles,
            if checks.iter().all(|c| c.passed) { "ok" } else { "CHECKS FAILED" },
            metrics.host_seconds,
        ),
        CellOutcome::Failed { error } => eprintln!(
            "[{n}/{total}] {:<28} {:<8} FAILED: {error}",
            cell.config_label, cell.workload,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::CampaignSpec;

    fn tiny_spec(workloads: &str) -> CampaignSpec {
        CampaignSpec::parse(&format!(
            "name = t\n\
             presets = SM-WT-C-HALCONE\n\
             workloads = {workloads}\n\
             set.n_gpus = 2\n\
             set.cus_per_gpu = 2\n\
             set.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\n\
             set.stacks_per_gpu = 2\n\
             set.gpu_mem_bytes = 67108864\n\
             set.scale = 0.05\n"
        ))
        .unwrap()
    }

    #[test]
    fn runs_cells_and_indexes_results_in_spec_order() {
        let spec = tiny_spec("rl,fir");
        let opts = ExecOptions { jobs: 4, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.cells.len(), 2);
        assert!(res.all_passed(), "smoke cells failed");
        for (i, c) in res.cells.iter().enumerate() {
            assert_eq!(c.cell.index, i);
            assert_eq!(c.status(), "ok");
            assert!(c.metrics().unwrap().cycles > 0);
        }
        assert!(res.get("SM-WT-C-HALCONE", "fir").is_some());
        assert!(res.get("SM-WT-C-HALCONE", "nope").is_none());
    }

    #[test]
    fn a_panicking_cell_fails_alone() {
        // A 4 KB GPU partition is exhausted by the very first array
        // allocation (the bump allocator starts at offset 0x1000 and
        // asserts), so that cell must record an error while its healthy
        // sibling completes.
        let spec = CampaignSpec::parse(
            "name = t\n\
             presets = SM-WT-C-HALCONE\n\
             workloads = rl\n\
             axis.gpu_mem_bytes = 4096,67108864\n\
             set.n_gpus = 2\n\
             set.cus_per_gpu = 2\n\
             set.wavefronts_per_cu = 2\n\
             set.l2_banks = 2\n\
             set.stacks_per_gpu = 2\n\
             set.scale = 0.05\n",
        )
        .unwrap();
        let opts = ExecOptions { jobs: 2, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.cells.len(), 2);
        let broken = res.get("SM-WT-C-HALCONE+gpu_mem_bytes=4096", "rl").unwrap();
        assert_eq!(broken.status(), "error");
        assert!(broken.error().is_some());
        let healthy = res.get("SM-WT-C-HALCONE+gpu_mem_bytes=67108864", "rl").unwrap();
        assert_eq!(healthy.status(), "ok");
        assert!(!res.all_passed());
    }

    #[test]
    fn jobs_larger_than_grid_is_fine() {
        let spec = tiny_spec("rl");
        let opts = ExecOptions { jobs: 64, progress: false, ..Default::default() };
        let res = run_campaign(&spec, &opts).unwrap();
        assert_eq!(res.cells.len(), 1);
        assert!(res.all_passed());
    }
}
