//! Minimal JSON tree, writer and parser (the offline registry has no
//! serde — DESIGN.md S16 applies the same constraint to artifacts).
//!
//! The writer pretty-prints with one key per line so host-dependent
//! fields (`host_seconds`) can be diffed away line-wise, and object keys
//! keep insertion order so output is byte-deterministic. The parser
//! accepts anything the writer emits plus ordinary hand-written JSON;
//! all numbers are read as `f64` (campaign counters stay far below
//! 2^53, where that is lossless).

/// One JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn u64(x: u64) -> Value {
        Value::Num(x as f64)
    }

    pub fn f64(x: f64) -> Value {
        Value::Num(x)
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation (no trailing newline).
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        write_value(self, 0, &mut out);
        out
    }
}

fn pad(indent: usize, out: &mut String) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => write_num(*x, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(xs) => {
            if xs.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push_str("[\n");
            for (i, x) in xs.iter().enumerate() {
                pad(indent + 1, out);
                write_value(x, indent + 1, out);
                if i + 1 < xs.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(indent, out);
            out.push(']');
        }
        Value::Obj(kvs) => {
            if kvs.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, x)) in kvs.iter().enumerate() {
                pad(indent + 1, out);
                write_str(k, out);
                out.push_str(": ");
                write_value(x, indent + 1, out);
                if i + 1 < kvs.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(indent, out);
            out.push('}');
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no inf/NaN; `null` keeps the document well-formed.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse one JSON document.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek().ok_or_else(|| "unexpected end of input".to_string())? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("bad number '{s}' at byte {start}: {e}"))
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|e| e.to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|e| format!("bad \\u escape '{s}': {e}"))?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.i;
            while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\' {
                self.i += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
            );
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(_) => {
                    self.i += 1; // backslash
                    let e = self.peek().ok_or_else(|| "unterminated escape".to_string())?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad low surrogate".into());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| "bad \\u codepoint".to_string())?,
                            );
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_nested_document() {
        let v = Value::Obj(vec![
            ("name".into(), Value::str("fig7")),
            ("n".into(), Value::u64(123456)),
            ("x".into(), Value::f64(0.125)),
            ("flag".into(), Value::Bool(true)),
            ("none".into(), Value::Null),
            (
                "arr".into(),
                Value::Arr(vec![Value::u64(1), Value::str("a\"b\\c\nd"), Value::Obj(vec![])]),
            ),
        ]);
        let text = v.to_pretty();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn writer_is_deterministic_and_line_structured() {
        let v = Value::Obj(vec![
            ("cycles".into(), Value::u64(42)),
            ("host_seconds".into(), Value::f64(0.5)),
        ]);
        let text = v.to_pretty();
        assert_eq!(text, v.to_pretty());
        assert!(text.lines().any(|l| l.trim() == "\"host_seconds\": 0.5"));
        assert!(text.lines().any(|l| l.trim_start().starts_with("\"cycles\": 42")));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Value::u64(0).to_pretty(), "0");
        assert_eq!(Value::u64(1 << 40).to_pretty(), format!("{}", 1u64 << 40));
        assert_eq!(Value::f64(2.5).to_pretty(), "2.5");
        assert_eq!(Value::f64(f64::NAN).to_pretty(), "null");
    }

    #[test]
    fn parses_plain_json() {
        let v = parse(r#" {"a": [1, 2.5, -3e2], "b": "x\u0041y\n", "c": null} "#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("xAy\n"));
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse("01x").is_err());
    }
}
