//! Configuration system (DESIGN.md S16): Table 2 geometry, §4.1 link
//! budgets, the five named MGPU configurations, a key=value config-file
//! parser and CLI-style overrides.
//!
//! The offline environment has no serde/toml; the format is a minimal
//! `key = value` subset (one per line, `#` comments), which covers
//! everything the experiments need.

use crate::coherence::tsproto::{TsPolicy, PROTOCOL_NAMES};
use crate::coherence::WritePolicy;
use crate::faults::FaultSpec;
use crate::mem::addr::Topology;
use crate::mem::AddrMap;
use crate::tsu::Leases;
use crate::workloads::WorkloadParams;

/// Interconnect fabric partitioning for the sharded engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Fabric {
    /// Per-GPU fabric ports: each GPU shard owns the MCs/TSUs for its
    /// HBM stacks plus a local port switch; ports are connected by
    /// explicit inter-port links and the hub shard holds only the
    /// driver/kernel-scheduler.
    #[default]
    Ports,
    /// Pre-partition layout: one central switch complex and (on SM) all
    /// MCs/TSUs on the hub shard. Kept as the before/after comparator
    /// for the hub-split bench rows.
    Hub,
}

/// Coherence protocol selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Coherence {
    /// No hardware coherence (fences flush/invalidate).
    None,
    /// The paper's protocol. `carry_warpts` re-adds CU-level timestamp
    /// traffic (G-TSC ablation, E10).
    Halcone { leases: Leases, carry_warpts: bool },
    /// HMG-style VI + directory (RDMA topologies only).
    Hmg,
    /// Tardis-style timestamp rival: stable per-line write timestamps,
    /// renewable read leases, no invalidation broadcasts
    /// (docs/PROTOCOLS.md, arXiv 1501.04504).
    Tardis { leases: Leases },
    /// Hybrid physical+logical per-cache clocks with leases expressed in
    /// hybrid time (docs/PROTOCOLS.md).
    Hlc { leases: Leases },
}

impl Coherence {
    /// The timestamp-protocol policy this setting selects (`None` for
    /// the non-timestamp protocols, which bypass the TSU entirely).
    pub fn ts_policy(&self) -> Option<TsPolicy> {
        match self {
            Coherence::Halcone { .. } => Some(TsPolicy::Halcone),
            Coherence::Tardis { .. } => Some(TsPolicy::Tardis),
            Coherence::Hlc { .. } => Some(TsPolicy::Hlc),
            Coherence::None | Coherence::Hmg => None,
        }
    }

    /// Lease table of a timestamp protocol.
    pub fn leases(&self) -> Option<Leases> {
        match self {
            Coherence::Halcone { leases, .. }
            | Coherence::Tardis { leases }
            | Coherence::Hlc { leases } => Some(*leases),
            Coherence::None | Coherence::Hmg => None,
        }
    }

    fn leases_mut(&mut self) -> Option<&mut Leases> {
        match self {
            Coherence::Halcone { leases, .. }
            | Coherence::Tardis { leases }
            | Coherence::Hlc { leases } => Some(leases),
            Coherence::None | Coherence::Hmg => None,
        }
    }
}

/// Full system configuration (defaults = paper Table 2 + §4.1).
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub name: String,
    pub topology: Topology,
    pub n_gpus: u32,
    pub cus_per_gpu: u32,
    pub wavefronts_per_cu: u32,
    pub l2_policy: WritePolicy,
    pub coherence: Coherence,

    // Geometry (Table 2).
    pub l1_bytes: u64,
    pub l1_ways: u32,
    pub l2_banks: u32,
    pub l2_bank_bytes: u64,
    pub l2_ways: u32,
    pub stacks_per_gpu: u32,
    pub gpu_mem_bytes: u64,

    // Latencies (cycles @ 1 GHz).
    pub l1_lat: u64,
    pub l2_lat: u64,
    pub mc_lat: u64,
    pub alu_lat: u64,
    pub onchip_lat: u64,
    pub swc_lat: u64,
    pub pcie_lat: u64,

    // Bandwidths (bytes/cycle @ 1 GHz: 1 B/cy = 1 GB/s).
    pub gpu_uplink_bw: u64,
    pub hbm_bw: u64,
    pub pcie_bw: u64,

    // Structures.
    pub mshr_l1: usize,
    pub mshr_l2: usize,
    pub tsu_entries: u64,

    /// Workload problem-size scale (DESIGN.md scaling note).
    pub scale: f64,

    /// Host worker threads for the sharded parallel engine (`--shards`).
    /// Purely an execution knob: the logical partition (one shard per
    /// GPU plus a hub) is fixed by the topology, so every value produces
    /// byte-identical results — see `sim::shard`.
    pub shards: u32,

    /// Fabric partitioning (`fabric` key): per-GPU ports (default) or
    /// the legacy central hub. Simulation-affecting — part of the
    /// canonical configuration and snapshot fingerprint.
    pub fabric: Fabric,

    /// Profile-guided shard grouping (`shard_groups` key): entry `i`
    /// maps GPU `i` to a logical shard group. Empty = identity (one
    /// shard per GPU). Produced by `coordinator::topology::plan_shard_groups`
    /// from recorded per-shard occupancy. Simulation-affecting: the
    /// grouping changes the event partition, so it is canonical.
    pub shard_groups: Vec<u32>,

    /// Deterministic fault-injection schedule (`faults` key /
    /// `--faults`; docs/ROBUSTNESS.md). `None` = perfect hardware.
    /// Part of the simulated configuration — recorded in campaign
    /// artifacts so gate re-runs replay the exact same faults.
    pub faults: Option<FaultSpec>,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            name: "SM-WT-C-HALCONE".into(),
            topology: Topology::SharedMem,
            n_gpus: 4,
            cus_per_gpu: 32,
            wavefronts_per_cu: 8,
            l2_policy: WritePolicy::WriteThrough,
            coherence: Coherence::Halcone { leases: Leases::default(), carry_warpts: false },
            l1_bytes: 16 << 10,
            l1_ways: 4,
            l2_banks: 8,
            l2_bank_bytes: 256 << 10,
            l2_ways: 16,
            stacks_per_gpu: 8,
            gpu_mem_bytes: 4 << 30, // 8 x 512 MB HBM per GPU
            l1_lat: 1,
            l2_lat: 10,
            mc_lat: 100,
            alu_lat: 1,
            onchip_lat: 5,
            swc_lat: 20,
            pcie_lat: 300,
            gpu_uplink_bw: 256, // 256 GB/s per-GPU L2<->MM (§4.1)
            hbm_bw: 341,        // 341 GB/s per stack (§4.1)
            pcie_bw: 32,        // PCIe 4.0 switch (§4.1)
            mshr_l1: 64,
            mshr_l2: 1024,
            tsu_entries: 1 << 16,
            scale: 1.0,
            shards: 1,
            fabric: Fabric::Ports,
            shard_groups: Vec::new(),
            faults: None,
        }
    }
}

impl SystemConfig {
    /// The paper's five evaluated configurations (§4.1).
    pub fn preset(name: &str) -> SystemConfig {
        let mut c = SystemConfig::default();
        c.name = name.into();
        match name {
            "RDMA-WB-NC" => {
                c.topology = Topology::Rdma;
                c.l2_policy = WritePolicy::WriteBack;
                c.coherence = Coherence::None;
            }
            "RDMA-WB-C-HMG" => {
                c.topology = Topology::Rdma;
                c.l2_policy = WritePolicy::WriteBack;
                c.coherence = Coherence::Hmg;
            }
            "SM-WB-NC" => {
                c.topology = Topology::SharedMem;
                c.l2_policy = WritePolicy::WriteBack;
                c.coherence = Coherence::None;
            }
            "SM-WT-NC" => {
                c.topology = Topology::SharedMem;
                c.l2_policy = WritePolicy::WriteThrough;
                c.coherence = Coherence::None;
            }
            "SM-WT-C-HALCONE" => {
                c.topology = Topology::SharedMem;
                c.l2_policy = WritePolicy::WriteThrough;
                c.coherence =
                    Coherence::Halcone { leases: Leases::default(), carry_warpts: false };
            }
            "SM-WT-C-TARDIS" => {
                c.topology = Topology::SharedMem;
                c.l2_policy = WritePolicy::WriteThrough;
                c.coherence = Coherence::Tardis { leases: Leases::default() };
            }
            "SM-WT-C-HLC" => {
                c.topology = Topology::SharedMem;
                c.l2_policy = WritePolicy::WriteThrough;
                c.coherence = Coherence::Hlc { leases: Leases::default() };
            }
            other => panic!("unknown preset '{other}' (see §4.1 names)"),
        }
        c
    }

    /// Non-panicking [`SystemConfig::preset`] (campaign specs validate
    /// user-supplied names before expansion).
    pub fn try_preset(name: &str) -> Result<SystemConfig, String> {
        if Self::PRESETS.contains(&name) {
            Ok(Self::preset(name))
        } else {
            Err(format!("unknown preset '{name}' (see §4.1 names: {:?})", Self::PRESETS))
        }
    }

    /// Every named configuration: the paper's five §4.1 systems followed
    /// by the timestamp-rival protocols (docs/PROTOCOLS.md).
    pub const PRESETS: [&str; 7] = [
        "RDMA-WB-NC",
        "RDMA-WB-C-HMG",
        "SM-WB-NC",
        "SM-WT-NC",
        "SM-WT-C-HALCONE",
        "SM-WT-C-TARDIS",
        "SM-WT-C-HLC",
    ];

    /// The paper's five evaluated configurations only (§4.1, in the
    /// paper's order) — the figure-reproduction campaigns (fig7/fig8)
    /// pin to these so their grids match the published plots.
    pub const PAPER_PRESETS: [&str; 5] = [
        "RDMA-WB-NC",
        "RDMA-WB-C-HMG",
        "SM-WB-NC",
        "SM-WT-NC",
        "SM-WT-C-HALCONE",
    ];

    pub fn addr_map(&self) -> AddrMap {
        AddrMap::new(
            self.topology,
            self.n_gpus,
            self.stacks_per_gpu,
            self.l2_banks,
            self.gpu_mem_bytes,
        )
    }

    pub fn workload_params(&self) -> WorkloadParams {
        WorkloadParams {
            n_gpus: self.n_gpus,
            cus_per_gpu: self.cus_per_gpu,
            wavefronts_per_cu: self.wavefronts_per_cu,
            map: self.addr_map(),
            scale: self.scale,
        }
    }

    /// Apply one `key=value` override; errors on unknown keys/bad values.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let uerr = |e: &dyn std::fmt::Display| format!("{key}={value}: {e}");
        macro_rules! num {
            ($field:expr, $t:ty) => {
                $field = value.parse::<$t>().map_err(|e| uerr(&e))?
            };
        }
        match key {
            "name" => self.name = value.into(),
            "topology" => {
                self.topology = match value {
                    "sm" | "shared" | "sharedmem" => Topology::SharedMem,
                    "rdma" => Topology::Rdma,
                    v => return Err(format!("topology={v}: want sm|rdma")),
                }
            }
            "n_gpus" => num!(self.n_gpus, u32),
            "cus_per_gpu" => num!(self.cus_per_gpu, u32),
            "wavefronts_per_cu" => num!(self.wavefronts_per_cu, u32),
            "l2_policy" => {
                self.l2_policy = match value {
                    "wt" => WritePolicy::WriteThrough,
                    "wb" => WritePolicy::WriteBack,
                    v => return Err(format!("l2_policy={v}: want wt|wb")),
                }
            }
            "coherence" => {
                self.coherence = match value {
                    "none" => Coherence::None,
                    "halcone" => {
                        Coherence::Halcone { leases: Leases::default(), carry_warpts: false }
                    }
                    "gtsc" => {
                        Coherence::Halcone { leases: Leases::default(), carry_warpts: true }
                    }
                    "hmg" => Coherence::Hmg,
                    "tardis" => Coherence::Tardis { leases: Leases::default() },
                    "hlc" => Coherence::Hlc { leases: Leases::default() },
                    v => {
                        return Err(format!(
                            "unknown coherence protocol '{v}': valid names are \
                             {PROTOCOL_NAMES:?} (gtsc = halcone + the G-TSC warpts \
                             wire ablation; see docs/PROTOCOLS.md)"
                        ))
                    }
                }
            }
            "rd_lease" | "wr_lease" => {
                let v: u64 = value.parse().map_err(|e| uerr(&e))?;
                if let Some(leases) = self.coherence.leases_mut() {
                    if key == "rd_lease" {
                        leases.rd = v;
                    } else {
                        leases.wr = v;
                    }
                } else {
                    return Err(format!(
                        "{key} only applies to timestamp protocols \
                         (coherence=halcone|gtsc|tardis|hlc)"
                    ));
                }
            }
            "l1_bytes" => num!(self.l1_bytes, u64),
            "l1_ways" => num!(self.l1_ways, u32),
            "l2_banks" => num!(self.l2_banks, u32),
            "l2_bank_bytes" => num!(self.l2_bank_bytes, u64),
            "l2_ways" => num!(self.l2_ways, u32),
            "stacks_per_gpu" => num!(self.stacks_per_gpu, u32),
            "gpu_mem_bytes" => num!(self.gpu_mem_bytes, u64),
            "l1_lat" => num!(self.l1_lat, u64),
            "l2_lat" => num!(self.l2_lat, u64),
            "mc_lat" => num!(self.mc_lat, u64),
            "alu_lat" => num!(self.alu_lat, u64),
            "onchip_lat" => num!(self.onchip_lat, u64),
            "swc_lat" => num!(self.swc_lat, u64),
            "pcie_lat" => num!(self.pcie_lat, u64),
            "gpu_uplink_bw" => num!(self.gpu_uplink_bw, u64),
            "hbm_bw" => num!(self.hbm_bw, u64),
            "pcie_bw" => num!(self.pcie_bw, u64),
            "mshr_l1" => num!(self.mshr_l1, usize),
            "mshr_l2" => num!(self.mshr_l2, usize),
            "tsu_entries" => num!(self.tsu_entries, u64),
            "scale" => num!(self.scale, f64),
            "shards" => {
                let v: u32 = value.parse().map_err(|e| uerr(&e))?;
                if v == 0 {
                    return Err("shards=0: need at least one engine worker thread".into());
                }
                self.shards = v;
            }
            "fabric" => {
                self.fabric = match value {
                    "ports" => Fabric::Ports,
                    "hub" => Fabric::Hub,
                    v => return Err(format!("fabric={v}: want ports|hub")),
                }
            }
            "shard_groups" => {
                if matches!(value, "" | "none" | "identity") {
                    self.shard_groups = Vec::new();
                } else {
                    self.shard_groups = value
                        .split(',')
                        .map(|t| t.trim().parse::<u32>().map_err(|e| uerr(&e)))
                        .collect::<Result<_, _>>()?;
                }
            }
            "faults" => self.faults = FaultSpec::parse(value)?,
            other => return Err(format!("unknown config key '{other}'")),
        }
        Ok(())
    }

    /// Parse a config file body (`key = value`, `#` comments, blank lines).
    /// A `preset = NAME` line switches the baseline preset first.
    pub fn parse(text: &str) -> Result<SystemConfig, String> {
        let mut cfg = SystemConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key=value", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim());
            if k == "preset" {
                let scale = cfg.scale;
                cfg = SystemConfig::try_preset(v)
                    .map_err(|e| format!("line {}: {e}", lineno + 1))?;
                cfg.scale = scale;
            } else {
                cfg.set(k, v).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            }
        }
        Ok(cfg)
    }

    /// Apply every non-`preset` line of a config-file body on top of
    /// `self`. This is how `compare` honors `--config FILE` across its
    /// preset columns: each column starts from its own preset, then
    /// takes the file's overrides (a `preset =` line would make every
    /// column identical, so it is ignored here). Lease keys are skipped
    /// on columns without a timestamp protocol — a file tuned for a
    /// lease-bearing column must not abort the NC/HMG columns, where
    /// leases are meaningless.
    pub fn apply_overrides(&mut self, text: &str) -> Result<(), String> {
        // Lease lines are deferred until every other key has applied, so
        // their applicability depends on the *final* coherence setting —
        // not on where a `coherence = halcone` line sits in the file.
        let mut leases: Vec<(usize, &str, &str)> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key=value", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim());
            if k == "preset" {
                continue;
            }
            if matches!(k, "rd_lease" | "wr_lease") {
                leases.push((lineno, k, v));
                continue;
            }
            self.set(k, v).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        if self.coherence.ts_policy().is_some() {
            for (lineno, k, v) in leases {
                self.set(k, v).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            }
        }
        Ok(())
    }

    /// Render Table 2-style configuration summary (E2 / `print-config`).
    pub fn describe(&self) -> String {
        let coher = match self.coherence {
            Coherence::None => "NC".to_string(),
            Coherence::Halcone { leases, carry_warpts } => format!(
                "HALCONE (RdLease={}, WrLease={}{})",
                leases.rd,
                leases.wr,
                if carry_warpts { ", +warpts wire ablation" } else { "" }
            ),
            Coherence::Hmg => "HMG (VI + directory)".to_string(),
            Coherence::Tardis { leases } => {
                format!("TARDIS (RdLease={}, WrLease={})", leases.rd, leases.wr)
            }
            Coherence::Hlc { leases } => format!(
                "HLC (RdLease={}, WrLease={}, phys=cycle>>{})",
                leases.rd,
                leases.wr,
                crate::coherence::tsproto::HLC_SHIFT
            ),
        };
        let faults = match &self.faults {
            None => "none".to_string(),
            Some(f) => f.to_string(),
        };
        format!(
            "config {name}\n\
             topology            {topo:?}\n\
             GPUs                {gpus} x {cus} CUs @ 1.0 GHz ({wf} wavefronts/CU)\n\
             L1 vector cache     {l1} KB {l1w}-way, 64 B lines, {ml1} MSHRs\n\
             L2 cache            {banks} x {l2} KB {l2w}-way per GPU, {ml2} MSHRs\n\
             DRAM                {stacks} x {dram} MB HBM per GPU ({hbm} GB/s/stack)\n\
             L2<->MM uplink      {up} GB/s per GPU\n\
             PCIe switch         {pcie} GB/s, {plat} cy\n\
             MC latency          {mc} cy, TSU {tsu} entries\n\
             L2 policy           {pol:?}\n\
             coherence           {coher}\n\
             faults              {faults}",
            name = self.name,
            topo = self.topology,
            gpus = self.n_gpus,
            cus = self.cus_per_gpu,
            wf = self.wavefronts_per_cu,
            l1 = self.l1_bytes >> 10,
            l1w = self.l1_ways,
            ml1 = self.mshr_l1,
            banks = self.l2_banks,
            l2 = self.l2_bank_bytes >> 10,
            l2w = self.l2_ways,
            ml2 = self.mshr_l2,
            stacks = self.stacks_per_gpu,
            dram = (self.gpu_mem_bytes / self.stacks_per_gpu as u64) >> 20,
            hbm = self.hbm_bw,
            up = self.gpu_uplink_bw,
            pcie = self.pcie_bw,
            plat = self.pcie_lat,
            mc = self.mc_lat,
            tsu = self.tsu_entries,
            pol = self.l2_policy,
            coher = coher,
            faults = faults,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_table() {
        let h = SystemConfig::preset("SM-WT-C-HALCONE");
        assert_eq!(h.topology, Topology::SharedMem);
        assert_eq!(h.l2_policy, WritePolicy::WriteThrough);
        assert!(matches!(h.coherence, Coherence::Halcone { .. }));

        let r = SystemConfig::preset("RDMA-WB-NC");
        assert_eq!(r.topology, Topology::Rdma);
        assert_eq!(r.l2_policy, WritePolicy::WriteBack);
        assert_eq!(r.coherence, Coherence::None);

        let g = SystemConfig::preset("RDMA-WB-C-HMG");
        assert_eq!(g.coherence, Coherence::Hmg);
    }

    #[test]
    fn default_is_table2() {
        let c = SystemConfig::default();
        assert_eq!(c.cus_per_gpu, 32);
        assert_eq!(c.l1_bytes, 16 << 10);
        assert_eq!(c.l1_ways, 4);
        assert_eq!(c.l2_banks, 8);
        assert_eq!(c.l2_bank_bytes, 256 << 10);
        assert_eq!(c.l2_ways, 16);
        assert_eq!(c.stacks_per_gpu, 8);
    }

    #[test]
    fn parse_file_with_preset_and_overrides() {
        let cfg = SystemConfig::parse(
            "# experiment\npreset = SM-WT-C-HALCONE\nn_gpus = 8\nrd_lease = 20\nscale=0.5\n",
        )
        .unwrap();
        assert_eq!(cfg.n_gpus, 8);
        assert_eq!(cfg.scale, 0.5);
        match cfg.coherence {
            Coherence::Halcone { leases, .. } => assert_eq!(leases.rd, 20),
            _ => panic!(),
        }
    }

    #[test]
    fn try_preset_rejects_unknown_names() {
        assert!(SystemConfig::try_preset("SM-WT-NC").is_ok());
        assert!(SystemConfig::try_preset("MESI").is_err());
    }

    #[test]
    fn apply_overrides_keeps_base_preset() {
        let mut cfg = SystemConfig::preset("SM-WB-NC");
        cfg.apply_overrides("preset = SM-WT-C-HALCONE # ignored\nn_gpus = 8\nscale = 0.5\n")
            .unwrap();
        assert_eq!(cfg.coherence, Coherence::None); // preset line skipped
        assert_eq!(cfg.n_gpus, 8);
        assert_eq!(cfg.scale, 0.5);
        assert!(cfg.apply_overrides("bogus = 1\n").is_err());
    }

    #[test]
    fn apply_overrides_skips_leases_off_halcone_but_applies_them_on() {
        // A lease-tuning file must not abort non-HALCONE compare columns.
        let mut nc = SystemConfig::preset("SM-WT-NC");
        nc.apply_overrides("rd_lease = 20\nwr_lease = 10\nn_gpus = 8\n").unwrap();
        assert_eq!(nc.n_gpus, 8);
        let mut hc = SystemConfig::preset("SM-WT-C-HALCONE");
        hc.apply_overrides("rd_lease = 20\n").unwrap();
        match hc.coherence {
            Coherence::Halcone { leases, .. } => assert_eq!(leases.rd, 20),
            _ => panic!(),
        }
        // A lease line before `coherence = halcone` still applies: only
        // the final coherence decides lease applicability.
        let mut flipped = SystemConfig::preset("SM-WT-NC");
        flipped.apply_overrides("rd_lease = 20\ncoherence = halcone\n").unwrap();
        match flipped.coherence {
            Coherence::Halcone { leases, .. } => assert_eq!(leases.rd, 20),
            _ => panic!(),
        }
    }

    #[test]
    fn unknown_key_is_an_error() {
        assert!(SystemConfig::parse("bogus = 1\n").is_err());
        // A preset typo in a --config file is a clean error, not a panic.
        assert!(SystemConfig::parse("preset = SM-WT-NCC\n").is_err());
        let mut c = SystemConfig::default();
        assert!(c.set("coherence", "mesi").is_err());
        assert!(c.set("topology", "ring").is_err());
    }

    #[test]
    fn lease_override_requires_a_timestamp_protocol() {
        let mut c = SystemConfig::preset("SM-WT-NC");
        assert!(c.set("rd_lease", "5").is_err());
        let mut c = SystemConfig::preset("RDMA-WB-C-HMG");
        assert!(c.set("wr_lease", "5").is_err());
    }

    #[test]
    fn rival_presets_build_timestamp_protocols_with_tunable_leases() {
        let mut t = SystemConfig::preset("SM-WT-C-TARDIS");
        assert_eq!(t.topology, Topology::SharedMem);
        assert_eq!(t.coherence.ts_policy(), Some(TsPolicy::Tardis));
        t.set("rd_lease", "20").unwrap();
        assert_eq!(t.coherence.leases().unwrap().rd, 20);

        let mut h = SystemConfig::preset("SM-WT-C-HLC");
        assert_eq!(h.coherence.ts_policy(), Some(TsPolicy::Hlc));
        h.set("wr_lease", "7").unwrap();
        assert_eq!(h.coherence.leases().unwrap().wr, 7);

        // Both rivals ride the all-presets constant; the paper grids
        // stay pinned to the original five.
        assert_eq!(SystemConfig::PRESETS.len(), 7);
        assert_eq!(SystemConfig::PAPER_PRESETS.len(), 5);
        for p in SystemConfig::PAPER_PRESETS {
            assert!(SystemConfig::PRESETS.contains(&p));
        }
    }

    #[test]
    fn unknown_protocol_error_lists_every_valid_name() {
        let mut c = SystemConfig::default();
        let err = c.set("coherence", "mesi").unwrap_err();
        for name in PROTOCOL_NAMES {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }

    #[test]
    fn shards_key_requires_at_least_one_thread() {
        let mut c = SystemConfig::default();
        assert_eq!(c.shards, 1);
        c.set("shards", "4").unwrap();
        assert_eq!(c.shards, 4);
        assert!(c.set("shards", "0").is_err());
        assert!(c.set("shards", "x").is_err());
    }

    #[test]
    fn fabric_and_shard_groups_keys_parse() {
        let mut c = SystemConfig::default();
        assert_eq!(c.fabric, Fabric::Ports);
        c.set("fabric", "hub").unwrap();
        assert_eq!(c.fabric, Fabric::Hub);
        c.set("fabric", "ports").unwrap();
        assert_eq!(c.fabric, Fabric::Ports);
        assert!(c.set("fabric", "mesh").is_err());

        assert!(c.shard_groups.is_empty());
        c.set("shard_groups", "0, 0, 1, 1").unwrap();
        assert_eq!(c.shard_groups, vec![0, 0, 1, 1]);
        c.set("shard_groups", "identity").unwrap();
        assert!(c.shard_groups.is_empty());
        assert!(c.set("shard_groups", "0,x").is_err());
    }

    #[test]
    fn faults_key_parses_clears_and_rejects() {
        let mut c = SystemConfig::default();
        assert!(c.faults.is_none());
        c.set("faults", "seed=7;degrade=0.2;ts_bits=12").unwrap();
        let f = c.faults.unwrap();
        assert_eq!((f.seed, f.ts_bits), (7, 12));
        c.set("faults", "none").unwrap();
        assert!(c.faults.is_none());
        assert!(c.set("faults", "degrade=2").is_err());
        assert!(c.set("faults", "nonsense").is_err());
    }

    #[test]
    fn describe_mentions_key_parameters() {
        let d = SystemConfig::default().describe();
        assert!(d.contains("32 CUs"));
        assert!(d.contains("16 KB 4-way"));
        assert!(d.contains("HALCONE"));
    }
}
