//! GPU front-end: compute units executing wavefront micro-programs
//! (DESIGN.md S14).
//!
//! Instead of emulating the GCN3 ISA, workloads are compiled (by
//! `workloads/*`) into tiny register-machine programs over f32 values.
//! The data flowing through the simulated cache hierarchy is *real*: a
//! store writes the value computed from previously loaded ones, so the
//! final memory image is checkable against the XLA golden model.

pub mod cu;

pub use cu::{Cu, CuOp, CuStats};
