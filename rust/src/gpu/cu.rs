//! Compute Unit: in-order wavefronts with memory-level parallelism.
//!
//! Timing model: each CU runs `W` wavefront contexts (paper-style latency
//! hiding). A wavefront executes its op list strictly in order; **loads
//! block until the value returns** (one outstanding load per wavefront),
//! while **stores are fire-and-forget** under the GPUs' weak consistency
//! model (§2 of the paper): the wavefront continues immediately and the
//! phase only completes once every store has been acknowledged. A credit
//! cap bounds outstanding stores per CU so the L1 MSHR cannot overflow;
//! wavefronts park when credits run out and resume on acks. ALU ops and
//! explicit delays accumulate issue latency between memory ops.
//! Memory-level parallelism therefore comes from both the wavefront count
//! and store pipelining — a deliberately simple stand-in for GCN3's
//! 40-wavefront occupancy that preserves the memory-bound vs
//! compute-bound distinction the paper's Table 3 relies on.

use crate::mem::LineBuf;
use crate::metrics::CacheCtrlStats;
use crate::sim::msg::{MemReq, MemRsp};
use crate::sim::{CompId, Component, Ctx, Cycle, Msg, ReqKind};
use crate::trace::{TraceKind, TraceOp};

/// Lanes per wavefront vector register. A full vector memory op covers
/// exactly one 64-byte cache line (16 x f32) — the coalesced access
/// granularity MGPUSim (and real GCN3 hardware) issues for contiguous
/// lane addresses.
pub const LANES: usize = 16;

/// One micro-op of a wavefront program. Registers are 16-lane f32 vectors
/// (SIMT): scalar loads/immediates broadcast across lanes, ALU ops are
/// lanewise.
#[derive(Clone, Debug, PartialEq)]
pub enum CuOp {
    /// Scalar load: f32 at `addr`, broadcast into all lanes of `reg`.
    Ld { reg: u8, addr: u64 },
    /// Coalesced vector load: `n` consecutive f32 starting at `addr` into
    /// lanes 0..n of `reg` (remaining lanes zeroed). Must not cross a
    /// cache-line boundary (one memory transaction).
    LdV { reg: u8, addr: u64, n: u8 },
    /// Scalar store: lane 0 of `reg` to `addr`.
    St { addr: u64, reg: u8 },
    /// Coalesced vector store: lanes 0..n of `reg` to `addr` (one
    /// transaction; must not cross a line boundary).
    StV { addr: u64, reg: u8, n: u8 },
    /// reg\[dst\] = broadcast(imm).
    MovImm { dst: u8, imm: f32 },
    /// Lanewise reg\[dst\] = reg\[a\] + reg\[b\].
    Add { dst: u8, a: u8, b: u8 },
    /// Lanewise reg\[dst\] = reg\[a\] - reg\[b\].
    Sub { dst: u8, a: u8, b: u8 },
    /// Lanewise reg\[dst\] = reg\[a\] * reg\[b\].
    Mul { dst: u8, a: u8, b: u8 },
    /// Lanewise reg\[dst\] = min(reg\[a\], reg\[b\]).
    Min { dst: u8, a: u8, b: u8 },
    /// Lanewise reg\[dst\] = max(reg\[a\], reg\[b\]).
    Max { dst: u8, a: u8, b: u8 },
    /// Cross-lane reduction: all lanes of `dst` = sum of lanes of `src`
    /// (dot-product style accumulate).
    Red { dst: u8, src: u8 },
    /// reg\[dst\]\[lane\] = reg\[src\]\[0\] (pack scalars into a vector for
    /// a later coalesced store).
    Pack { dst: u8, lane: u8, src: u8 },
    /// Busy compute for `cycles` (models non-f32 work, e.g. AES rounds).
    Delay { cycles: u32 },
}

pub const NREGS: usize = 16;

/// A vector register value.
pub type VReg = [f32; LANES];

/// Execution counters for one CU.
#[derive(Clone, Copy, Debug, Default)]
pub struct CuStats {
    pub loads: u64,
    pub stores: u64,
    pub alu: u64,
    pub delay_cycles: u64,
}

#[derive(Debug)]
struct Wavefront {
    /// Program counter into `Cu::program[phase][wf]` (ops are not copied
    /// per phase — cloning programs showed up in perf, §Perf log).
    pc: usize,
    regs: [VReg; NREGS],
    done: bool,
    /// Issue-latency cycles accumulated since the last memory op — the
    /// compute gap the trace recorder consumes (see [`crate::trace`]).
    /// Maintained unconditionally (one add per ALU op); read only while
    /// capture is enabled.
    gap: Cycle,
}

/// Pending destination of an outstanding memory request.
#[derive(Clone, Copy, Debug)]
enum Dest {
    /// Store ack (no payload).
    Ack,
    /// Scalar load: broadcast into register.
    Scalar(u8),
    /// Vector load: lanes 0..n of register.
    Vector(u8, u8),
}

/// A compute unit component.
pub struct Cu {
    name: String,
    l1: CompId,
    driver: CompId,
    /// `[phase][wavefront]` op lists for this CU.
    program: Vec<Vec<Vec<CuOp>>>,
    wavefronts: Vec<Wavefront>,
    /// Outstanding memory requests: (id, wavefront, destination).
    /// A small linear-scanned vec — outstanding counts are bounded by
    /// wavefronts + store credits (~32), and the SipHash of a HashMap
    /// showed up at ~5% of total runtime in perf (EXPERIMENTS.md §Perf).
    outstanding: Vec<(u64, usize, Dest)>,
    next_id: u64,
    /// Per-ALU-op issue latency.
    alu_lat: Cycle,
    active: usize,
    phase: u32,
    /// Outstanding (unacknowledged) stores.
    stores_in_flight: u32,
    /// Store credits remaining (cap on stores_in_flight).
    store_credits: u32,
    /// Wavefronts parked waiting for a store credit.
    parked: Vec<usize>,
    /// Captured memory-op records (`Some` once capture is enabled). The
    /// buffer is CU-local, so the assembled trace is ordered by the
    /// simulation alone — identical at every `--shards` level.
    trace_buf: Option<Vec<TraceOp>>,
    pub stats: CuStats,
    /// Tenant owning each phase of a multi-tenant mix (see
    /// [`crate::tenancy`]). Empty on ordinary runs — the tenant tag then
    /// defaults to 0 and per-tenant accounting stays off the hot path.
    phase_tenants: Vec<u32>,
    /// Per-tenant issue counters, indexed by tenant id. Populated only
    /// when `phase_tenants` is set.
    pub tenant_stats: Vec<crate::metrics::tenancy::TenantCuStats>,
}

/// Default store-credit cap per CU (must stay below the L1 MSHR size).
pub const STORE_CREDITS: u32 = 24;

impl Cu {
    pub fn new(
        name: impl Into<String>,
        l1: CompId,
        driver: CompId,
        program: Vec<Vec<Vec<CuOp>>>,
        alu_lat: Cycle,
    ) -> Self {
        Cu {
            name: name.into(),
            l1,
            driver,
            program,
            wavefronts: Vec::new(),
            outstanding: Vec::with_capacity(64),
            next_id: 0,
            alu_lat,
            active: 0,
            phase: 0,
            stores_in_flight: 0,
            store_credits: STORE_CREDITS,
            parked: Vec::new(),
            trace_buf: None,
            stats: CuStats::default(),
            phase_tenants: Vec::new(),
            tenant_stats: Vec::new(),
        }
    }

    /// Declare which tenant owns each phase (multi-tenant mixes only).
    /// Turns on per-tenant issue accounting.
    pub fn set_phase_tenants(&mut self, tenants: Vec<u32>) {
        self.phase_tenants = tenants;
    }

    /// Tenant tag for the current phase (0 outside mix runs).
    fn cur_tenant(&self) -> u32 {
        self.phase_tenants.get(self.phase as usize).copied().unwrap_or(0)
    }

    /// Bump this CU's per-tenant counters (mix runs only).
    fn note_tenant_op(&mut self, tenant: u32, is_store: bool, bytes: u64) {
        if self.phase_tenants.is_empty() {
            return;
        }
        let slot = tenant as usize;
        if slot >= self.tenant_stats.len() {
            self.tenant_stats.resize_with(slot + 1, Default::default);
        }
        let s = &mut self.tenant_stats[slot];
        if is_store {
            s.stores += 1;
        } else {
            s.loads += 1;
        }
        s.bytes += bytes;
    }

    /// Start capturing issued memory operations (trace recording).
    pub fn enable_trace(&mut self) {
        self.trace_buf = Some(Vec::new());
    }

    /// Take the captured records, in this CU's issue order. Empty when
    /// capture was never enabled.
    pub fn take_trace(&mut self) -> Vec<TraceOp> {
        self.trace_buf.take().unwrap_or_default()
    }

    /// Append one record (no-op unless capture is enabled), consuming
    /// the wavefront's accumulated compute gap.
    fn record(&mut self, wf: usize, kind: TraceKind, addr: u64, size: u32, at: Cycle) {
        if self.trace_buf.is_none() {
            return;
        }
        let gap = std::mem::take(&mut self.wavefronts[wf].gap);
        let op = TraceOp { phase: self.phase, wf: wf as u32, kind, addr, size, gap, cycle: at };
        if let Some(buf) = &mut self.trace_buf {
            buf.push(op);
        }
    }

    /// All wavefronts retired and every store acknowledged?
    fn phase_complete(&self) -> bool {
        self.active == 0 && self.stores_in_flight == 0
    }

    fn start_phase(&mut self, phase: u32, ctx: &mut Ctx) {
        self.phase = phase;
        let n_wfs = self.program.get(phase as usize).map_or(0, |l| l.len());
        self.wavefronts = (0..n_wfs)
            .map(|_| Wavefront { pc: 0, regs: [[0.0; LANES]; NREGS], done: false, gap: 0 })
            .collect();
        self.active = 0;
        for (i, w) in self.wavefronts.iter_mut().enumerate() {
            if self.program[phase as usize][i].is_empty() {
                w.done = true;
            } else {
                self.active += 1;
            }
        }
        if self.active == 0 {
            let driver = self.driver;
            ctx.schedule(0, driver, Msg::PhaseDone { cu: ctx.self_id });
            return;
        }
        // Stagger wavefront starts by one cycle to avoid lockstep bursts.
        for i in 0..self.wavefronts.len() {
            self.step(i, i as Cycle, ctx);
        }
    }

    /// Advance wavefront `wf`, issuing at `now + extra` (stagger/replay).
    fn step(&mut self, wf: usize, extra: Cycle, ctx: &mut Ctx) {
        let mut delay = extra;
        let phase = self.phase as usize;
        loop {
            if self.wavefronts[wf].done {
                return;
            }
            let pc = self.wavefronts[wf].pc;
            let ops = &self.program[phase][wf];
            if pc >= ops.len() {
                self.wavefronts[wf].done = true;
                self.active -= 1;
                self.record(wf, TraceKind::End, 0, 0, ctx.now() + delay);
                if self.phase_complete() {
                    let driver = self.driver;
                    ctx.schedule(delay, driver, Msg::PhaseDone { cu: ctx.self_id });
                }
                return;
            }
            // Park on a store without credits (pc unchanged; resumed by an
            // ack in on_rsp).
            if matches!(ops[pc], CuOp::St { .. } | CuOp::StV { .. })
                && self.store_credits == 0
            {
                self.parked.push(wf);
                return;
            }
            let op = ops[pc].clone();
            let w = &mut self.wavefronts[wf];
            w.pc += 1;
            match op {
                CuOp::MovImm { dst, imm } => {
                    w.regs[dst as usize] = [imm; LANES];
                    self.stats.alu += 1;
                    w.gap += self.alu_lat;
                    delay += self.alu_lat;
                }
                CuOp::Add { dst, a, b } => {
                    let (a, b) = (w.regs[a as usize], w.regs[b as usize]);
                    for (l, d) in w.regs[dst as usize].iter_mut().enumerate() {
                        *d = a[l] + b[l];
                    }
                    self.stats.alu += 1;
                    w.gap += self.alu_lat;
                    delay += self.alu_lat;
                }
                CuOp::Sub { dst, a, b } => {
                    let (a, b) = (w.regs[a as usize], w.regs[b as usize]);
                    for (l, d) in w.regs[dst as usize].iter_mut().enumerate() {
                        *d = a[l] - b[l];
                    }
                    self.stats.alu += 1;
                    w.gap += self.alu_lat;
                    delay += self.alu_lat;
                }
                CuOp::Mul { dst, a, b } => {
                    let (a, b) = (w.regs[a as usize], w.regs[b as usize]);
                    for (l, d) in w.regs[dst as usize].iter_mut().enumerate() {
                        *d = a[l] * b[l];
                    }
                    self.stats.alu += 1;
                    w.gap += self.alu_lat;
                    delay += self.alu_lat;
                }
                CuOp::Min { dst, a, b } => {
                    let (a, b) = (w.regs[a as usize], w.regs[b as usize]);
                    for (l, d) in w.regs[dst as usize].iter_mut().enumerate() {
                        *d = a[l].min(b[l]);
                    }
                    self.stats.alu += 1;
                    w.gap += self.alu_lat;
                    delay += self.alu_lat;
                }
                CuOp::Max { dst, a, b } => {
                    let (a, b) = (w.regs[a as usize], w.regs[b as usize]);
                    for (l, d) in w.regs[dst as usize].iter_mut().enumerate() {
                        *d = a[l].max(b[l]);
                    }
                    self.stats.alu += 1;
                    w.gap += self.alu_lat;
                    delay += self.alu_lat;
                }
                CuOp::Red { dst, src } => {
                    let s: f32 = w.regs[src as usize].iter().sum();
                    w.regs[dst as usize] = [s; LANES];
                    self.stats.alu += 1;
                    w.gap += self.alu_lat;
                    delay += self.alu_lat;
                }
                CuOp::Pack { dst, lane, src } => {
                    let v = w.regs[src as usize][0];
                    w.regs[dst as usize][lane as usize] = v;
                    self.stats.alu += 1;
                    w.gap += self.alu_lat;
                    delay += self.alu_lat;
                }
                CuOp::Delay { cycles } => {
                    self.stats.delay_cycles += cycles as u64;
                    w.gap += cycles as Cycle;
                    delay += cycles as Cycle;
                }
                CuOp::Ld { reg, addr } => {
                    self.issue_load(wf, Dest::Scalar(reg), addr, 4, delay, ctx);
                    return;
                }
                CuOp::LdV { reg, addr, n } => {
                    debug_assert!(n as usize <= LANES);
                    debug_assert_eq!(
                        addr / 64,
                        (addr + 4 * n as u64 - 1) / 64,
                        "LdV crosses a line boundary"
                    );
                    self.issue_load(wf, Dest::Vector(reg, n), addr, 4 * n as u32, delay, ctx);
                    return;
                }
                CuOp::St { addr, reg } => {
                    let data = LineBuf::from_slice(&w.regs[reg as usize][0].to_le_bytes());
                    self.issue_store(wf, addr, data, delay, ctx);
                    delay += 1; // issue slot
                }
                CuOp::StV { addr, reg, n } => {
                    debug_assert!(n as usize <= LANES);
                    debug_assert_eq!(
                        addr / 64,
                        (addr + 4 * n as u64 - 1) / 64,
                        "StV crosses a line boundary"
                    );
                    let mut data = LineBuf::empty();
                    for l in 0..n as usize {
                        data.extend_from_slice(&w.regs[reg as usize][l].to_le_bytes());
                    }
                    self.issue_store(wf, addr, data, delay, ctx);
                    delay += 1;
                }
            }
        }
    }

    fn issue_load(
        &mut self,
        wf: usize,
        dest: Dest,
        addr: u64,
        size: u32,
        delay: Cycle,
        ctx: &mut Ctx,
    ) {
        self.stats.loads += 1;
        let tenant = self.cur_tenant();
        self.note_tenant_op(tenant, false, size as u64);
        self.record(wf, TraceKind::Load, addr, size, ctx.now() + delay);
        let id = self.next_id;
        self.next_id += 1;
        self.outstanding.push((id, wf, dest));
        let req = MemReq {
            id,
            kind: ReqKind::Read,
            addr,
            size,
            src: ctx.self_id,
            dst: self.l1,
            data: LineBuf::empty(),
            warpts: None,
            tenant,
        };
        let l1 = self.l1;
        let msg = ctx.req_msg(req);
        ctx.schedule(delay + 1, l1, msg);
    }

    fn issue_store(&mut self, wf: usize, addr: u64, data: LineBuf, delay: Cycle, ctx: &mut Ctx) {
        // Fire-and-forget under weak consistency: issue and keep
        // executing; the ack returns a credit.
        self.stats.stores += 1;
        let tenant = self.cur_tenant();
        self.note_tenant_op(tenant, true, data.len() as u64);
        self.record(wf, TraceKind::Store, addr, data.len() as u32, ctx.now() + delay);
        self.store_credits -= 1;
        self.stores_in_flight += 1;
        let id = self.next_id;
        self.next_id += 1;
        self.outstanding.push((id, wf, Dest::Ack));
        let req = MemReq {
            id,
            kind: ReqKind::Write,
            addr,
            size: data.len() as u32,
            src: ctx.self_id,
            dst: self.l1,
            data,
            warpts: None,
            tenant,
        };
        let l1 = self.l1;
        let msg = ctx.req_msg(req);
        ctx.schedule(delay + 1, l1, msg);
    }

    fn on_rsp(&mut self, rsp: MemRsp, ctx: &mut Ctx) {
        let idx = self
            .outstanding
            .iter()
            .position(|&(id, _, _)| id == rsp.id)
            .unwrap_or_else(|| panic!("{}: response for unknown request {}", self.name, rsp.id));
        let (_, wf, dest) = self.outstanding.swap_remove(idx);
        match dest {
            Dest::Scalar(reg) => {
                debug_assert_eq!(rsp.kind, ReqKind::Read);
                let v =
                    f32::from_le_bytes([rsp.data[0], rsp.data[1], rsp.data[2], rsp.data[3]]);
                self.wavefronts[wf].regs[reg as usize] = [v; LANES];
                self.step(wf, 0, ctx);
            }
            Dest::Vector(reg, n) => {
                debug_assert_eq!(rsp.kind, ReqKind::Read);
                let mut vals = [0.0f32; LANES];
                for (l, v) in vals.iter_mut().enumerate().take(n as usize) {
                    let b = &rsp.data[4 * l..4 * l + 4];
                    *v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
                self.wavefronts[wf].regs[reg as usize] = vals;
                self.step(wf, 0, ctx);
            }
            Dest::Ack => {
                // Store ack: return the credit, resume a parked wavefront.
                self.stores_in_flight -= 1;
                self.store_credits += 1;
                if let Some(parked_wf) = self.parked.pop() {
                    self.step(parked_wf, 0, ctx);
                } else if self.phase_complete() {
                    let driver = self.driver;
                    ctx.schedule(0, driver, Msg::PhaseDone { cu: ctx.self_id });
                }
            }
        }
    }
}

impl Component for Cu {
    crate::impl_component_any!();

    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match msg {
            Msg::StartPhase { phase } => self.start_phase(phase, ctx),
            Msg::Rsp(rsp) => {
                let rsp = ctx.reclaim_rsp(rsp);
                self.on_rsp(rsp, ctx);
            }
            other => panic!("{}: unexpected {:?}", self.name, other),
        }
    }

    // The program, phase->tenant map and wiring are rebuilt from config;
    // only the execution state is serialized.
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format::{put, put_bool, put_f32};
        if self.trace_buf.is_some() {
            return Err(format!(
                "{}: trace capture is enabled — traces cannot be snapshotted \
                 (run without --trace-out)",
                self.name
            ));
        }
        put(out, self.wavefronts.len() as u64);
        for w in &self.wavefronts {
            put(out, w.pc as u64);
            for reg in &w.regs {
                for v in reg {
                    put_f32(out, *v);
                }
            }
            put_bool(out, w.done);
            put(out, w.gap);
        }
        put(out, self.outstanding.len() as u64);
        for &(id, wf, dest) in &self.outstanding {
            put(out, id);
            put(out, wf as u64);
            match dest {
                Dest::Ack => out.push(0),
                Dest::Scalar(r) => {
                    out.push(1);
                    out.push(r);
                }
                Dest::Vector(r, n) => {
                    out.push(2);
                    out.push(r);
                    out.push(n);
                }
            }
        }
        put(out, self.next_id);
        put(out, self.active as u64);
        put(out, self.phase as u64);
        put(out, self.stores_in_flight as u64);
        put(out, self.store_credits as u64);
        put(out, self.parked.len() as u64);
        for &wf in &self.parked {
            put(out, wf as u64);
        }
        put(out, self.stats.loads);
        put(out, self.stats.stores);
        put(out, self.stats.alu);
        put(out, self.stats.delay_cycles);
        put(out, self.tenant_stats.len() as u64);
        for t in &self.tenant_stats {
            put(out, t.loads);
            put(out, t.stores);
            put(out, t.bytes);
        }
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        let n_wfs = cur.u64("CU wavefront count")? as usize;
        if n_wfs > cur.b.len() {
            return Err(format!("CU wavefront count {n_wfs} exceeds snapshot size"));
        }
        self.wavefronts.clear();
        for i in 0..n_wfs {
            let pc = cur.u64(&format!("wavefront {i} pc"))? as usize;
            let mut regs = [[0.0f32; LANES]; NREGS];
            for reg in &mut regs {
                for v in reg.iter_mut() {
                    *v = cur.f32(&format!("wavefront {i} register lane"))?;
                }
            }
            let done = cur.bool(&format!("wavefront {i} done flag"))?;
            let gap = cur.u64(&format!("wavefront {i} gap"))?;
            self.wavefronts.push(Wavefront { pc, regs, done, gap });
        }
        let n_out = cur.u64("CU outstanding count")? as usize;
        if n_out > cur.b.len() {
            return Err(format!("CU outstanding count {n_out} exceeds snapshot size"));
        }
        self.outstanding.clear();
        for i in 0..n_out {
            let id = cur.u64(&format!("outstanding {i} id"))?;
            let wf = cur.u64(&format!("outstanding {i} wavefront"))? as usize;
            if wf >= n_wfs {
                return Err(format!(
                    "outstanding request {i} targets wavefront {wf}, only {n_wfs} exist"
                ));
            }
            let dest = match cur.byte(&format!("outstanding {i} dest tag"))? {
                0 => Dest::Ack,
                1 => Dest::Scalar(cur.byte(&format!("outstanding {i} register"))?),
                2 => {
                    let r = cur.byte(&format!("outstanding {i} register"))?;
                    let n = cur.byte(&format!("outstanding {i} lane count"))?;
                    Dest::Vector(r, n)
                }
                t => return Err(format!("outstanding request {i} has unknown dest tag {t}")),
            };
            self.outstanding.push((id, wf, dest));
        }
        self.next_id = cur.u64("CU next_id")?;
        self.active = cur.u64("CU active count")? as usize;
        self.phase = cur.u64("CU phase")? as u32;
        self.stores_in_flight = cur.u64("CU stores in flight")? as u32;
        self.store_credits = cur.u64("CU store credits")? as u32;
        let n_parked = cur.u64("CU parked count")? as usize;
        if n_parked > n_wfs {
            return Err(format!("CU parks {n_parked} wavefronts, only {n_wfs} exist"));
        }
        self.parked.clear();
        for i in 0..n_parked {
            self.parked.push(cur.u64(&format!("parked wavefront {i}"))? as usize);
        }
        self.stats.loads = cur.u64("CU stat loads")?;
        self.stats.stores = cur.u64("CU stat stores")?;
        self.stats.alu = cur.u64("CU stat alu")?;
        self.stats.delay_cycles = cur.u64("CU stat delay_cycles")?;
        let n_ten = cur.u64("CU tenant stat count")? as usize;
        if n_ten > cur.b.len() {
            return Err(format!("CU tenant stat count {n_ten} exceeds snapshot size"));
        }
        self.tenant_stats.clear();
        for _ in 0..n_ten {
            self.tenant_stats.push(crate::metrics::tenancy::TenantCuStats {
                loads: cur.u64("tenant loads")?,
                stores: cur.u64("tenant stores")?,
                bytes: cur.u64("tenant bytes")?,
            });
        }
        Ok(())
    }
}

/// Convenience: total transactions a CU exchanged with its L1 (for the
/// Core-to-Cache traffic accounting of E10).
pub fn cu_l1_traffic(stats: &CuStats) -> CacheCtrlStats {
    CacheCtrlStats {
        reqs_down: stats.loads + stats.stores,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::GlobalMemory;

    /// Fake L1 that serves from a GlobalMemory after a fixed delay.
    struct FakeL1 {
        name: String,
        mem: crate::dram::SharedMemory,
        lat: Cycle,
        pub reqs: u64,
    }
    impl Component for FakeL1 {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, _now: Cycle, msg: Msg, ctx: &mut Ctx) {
            if let Msg::Req(req) = msg {
                let req = ctx.reclaim_req(req);
                self.reqs += 1;
                let mut mem = self.mem.borrow_mut();
                let rsp = match req.kind {
                    ReqKind::Read => MemRsp {
                        id: req.id,
                        kind: ReqKind::Read,
                        addr: req.addr,
                        dst: req.src,
                        data: LineBuf::from_slice(&mem.read_bytes(req.addr, req.size as usize)),
                        ts: None,
                    },
                    ReqKind::Write => {
                        mem.write_bytes(req.addr, &req.data);
                        MemRsp {
                            id: req.id,
                            kind: ReqKind::Write,
                            addr: req.addr,
                            dst: req.src,
                            data: LineBuf::empty(),
                            ts: None,
                        }
                    }
                };
                let msg = ctx.rsp_msg(rsp);
                ctx.schedule(self.lat, req.src, msg);
            }
        }
    }

    /// Driver stub that records PhaseDone times.
    struct FakeDriver {
        name: String,
        pub done_at: Vec<Cycle>,
    }
    impl Component for FakeDriver {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, _ctx: &mut Ctx) {
            if let Msg::PhaseDone { .. } = msg {
                self.done_at.push(now);
            }
        }
    }

    fn run_program(
        program: Vec<Vec<Vec<CuOp>>>,
        init: &[(u64, f32)],
    ) -> (crate::dram::SharedMemory, Cycle, CuStats, u64) {
        let mut e = crate::sim::Engine::new();
        let mem = GlobalMemory::new_shared();
        for &(a, v) in init {
            mem.borrow_mut().write_f32(a, v);
        }
        let cu_id = CompId(0);
        let l1_id = CompId(1);
        let drv_id = CompId(2);
        e.add(Box::new(Cu::new("cu0", l1_id, drv_id, program, 1)));
        e.add(Box::new(FakeL1 { name: "l1".into(), mem: mem.clone(), lat: 10, reqs: 0 }));
        e.add(Box::new(FakeDriver { name: "drv".into(), done_at: vec![] }));
        e.post(0, cu_id, Msg::StartPhase { phase: 0 });
        let end = e.run_to_completion();
        let stats = e.downcast::<Cu>(cu_id).stats;
        let reqs = e.downcast::<FakeL1>(l1_id).reqs;
        let done = e.downcast::<FakeDriver>(drv_id).done_at.len() as u64;
        assert_eq!(done, 1, "driver must get exactly one PhaseDone");
        (mem, end, stats, reqs)
    }

    #[test]
    fn vector_add_program_computes_sum() {
        // C[i] = A[i] + B[i] for 4 elements, one wavefront.
        let (a, b, c) = (0x100u64, 0x200u64, 0x300u64);
        let mut ops = vec![];
        for i in 0..4u64 {
            ops.push(CuOp::Ld { reg: 0, addr: a + 4 * i });
            ops.push(CuOp::Ld { reg: 1, addr: b + 4 * i });
            ops.push(CuOp::Add { dst: 2, a: 0, b: 1 });
            ops.push(CuOp::St { addr: c + 4 * i, reg: 2 });
        }
        let init: Vec<(u64, f32)> = (0..4u64)
            .flat_map(|i| [(a + 4 * i, i as f32), (b + 4 * i, 10.0)])
            .collect();
        let (mem, _, stats, _) = run_program(vec![vec![ops]], &init);
        for i in 0..4u64 {
            assert_eq!(mem.borrow_mut().read_f32(c + 4 * i), i as f32 + 10.0);
        }
        assert_eq!(stats.loads, 8);
        assert_eq!(stats.stores, 4);
        assert_eq!(stats.alu, 4);
    }

    #[test]
    fn wavefronts_overlap_memory_latency() {
        // 2 wavefronts each doing 4 dependent loads: with MLP=2 the total
        // time is much less than 2x a single wavefront's serial time.
        let prog_of = |base: u64| -> Vec<CuOp> {
            (0..4u64).map(|i| CuOp::Ld { reg: 0, addr: base + 4 * i }).collect()
        };
        let (_, t2, _, _) =
            run_program(vec![vec![prog_of(0x100), prog_of(0x200)]], &[]);
        let (_, t1, _, _) = run_program(vec![vec![prog_of(0x100)]], &[]);
        assert!(t2 < 2 * t1, "two wavefronts must overlap: {t2} vs 2x{t1}");
    }

    #[test]
    fn min_max_ops() {
        let ops = vec![
            CuOp::MovImm { dst: 0, imm: 3.0 },
            CuOp::MovImm { dst: 1, imm: -2.0 },
            CuOp::Min { dst: 2, a: 0, b: 1 },
            CuOp::Max { dst: 3, a: 0, b: 1 },
            CuOp::St { addr: 0x10, reg: 2 },
            CuOp::St { addr: 0x14, reg: 3 },
        ];
        let (mem, _, _, _) = run_program(vec![vec![ops]], &[]);
        assert_eq!(mem.borrow_mut().read_f32(0x10), -2.0);
        assert_eq!(mem.borrow_mut().read_f32(0x14), 3.0);
    }

    #[test]
    fn delay_op_adds_time_without_traffic() {
        let fast = vec![CuOp::St { addr: 0, reg: 0 }];
        let slow = vec![CuOp::Delay { cycles: 5000 }, CuOp::St { addr: 0, reg: 0 }];
        let (_, t_fast, _, reqs_fast) = run_program(vec![vec![fast]], &[]);
        let (_, t_slow, stats, reqs_slow) = run_program(vec![vec![slow]], &[]);
        assert!(t_slow >= t_fast + 5000);
        assert_eq!(reqs_fast, reqs_slow);
        assert_eq!(stats.delay_cycles, 5000);
    }

    #[test]
    fn empty_phase_reports_done_immediately() {
        let (_, t, _, reqs) = run_program(vec![vec![]], &[]);
        assert_eq!(reqs, 0);
        assert_eq!(t, 0);
    }

    #[test]
    fn trace_capture_records_ops_gaps_and_end_markers() {
        use crate::trace::{TraceKind, TraceOp};
        let ops = vec![
            CuOp::MovImm { dst: 0, imm: 1.0 },
            CuOp::Add { dst: 1, a: 0, b: 0 },
            CuOp::Ld { reg: 2, addr: 0x40 },
            CuOp::Delay { cycles: 7 },
            CuOp::StV { addr: 0x80, reg: 1, n: 4 },
            CuOp::Mul { dst: 3, a: 1, b: 1 },
        ];
        let mut e = crate::sim::Engine::new();
        let mem = GlobalMemory::new_shared();
        let cu_id = CompId(0);
        e.add(Box::new(Cu::new("cu0", CompId(1), CompId(2), vec![vec![ops]], 1)));
        e.add(Box::new(FakeL1 { name: "l1".into(), mem, lat: 10, reqs: 0 }));
        e.add(Box::new(FakeDriver { name: "drv".into(), done_at: vec![] }));
        e.downcast_mut::<Cu>(cu_id).enable_trace();
        e.post(0, cu_id, Msg::StartPhase { phase: 0 });
        e.run_to_completion();
        let rec = e.downcast_mut::<Cu>(cu_id).take_trace();
        let key = |o: &TraceOp| (o.kind, o.addr, o.size, o.gap);
        assert_eq!(
            rec.iter().map(key).collect::<Vec<_>>(),
            vec![
                (TraceKind::Load, 0x40, 4, 2),   // MovImm + Add = 2 cycles
                (TraceKind::Store, 0x80, 16, 7), // the explicit Delay
                (TraceKind::End, 0, 0, 1),       // trailing Mul
            ]
        );
        // Issue cycles are monotone within the wavefront.
        assert!(rec[0].cycle <= rec[1].cycle && rec[1].cycle <= rec[2].cycle);
        // Capture off => no records.
        assert!(e.downcast_mut::<Cu>(cu_id).take_trace().is_empty());
    }

    #[test]
    fn sub_and_mul() {
        let ops = vec![
            CuOp::MovImm { dst: 0, imm: 7.0 },
            CuOp::MovImm { dst: 1, imm: 4.0 },
            CuOp::Sub { dst: 2, a: 0, b: 1 },
            CuOp::Mul { dst: 3, a: 2, b: 1 },
            CuOp::St { addr: 0x20, reg: 3 },
        ];
        let (mem, _, _, _) = run_program(vec![vec![ops]], &[]);
        assert_eq!(mem.borrow_mut().read_f32(0x20), 12.0);
    }
}
