//! Parser for `artifacts/manifest.txt` (written by `python/compile/aot.py`).
//!
//! Line format: `name;in=float32[64x64];float32[64];out=float32[64]`
//! — `;`-separated specs, the first prefixed `in=`, the first of the
//! output group prefixed `out=`.

use anyhow::{bail, Context, Result};

/// Shape + dtype of one artifact argument.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    fn parse(s: &str) -> Result<TensorSpec> {
        let (dtype, rest) = s
            .split_once('[')
            .with_context(|| format!("bad tensor spec '{s}'"))?;
        let dims_s = rest.strip_suffix(']').with_context(|| format!("bad spec '{s}'"))?;
        let dims = if dims_s.is_empty() {
            vec![]
        } else {
            dims_s
                .split('x')
                .map(|d| d.parse::<usize>().with_context(|| format!("bad dim in '{s}'")))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { dtype: dtype.to_string(), dims })
    }
}

/// One artifact's signature.
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parse the whole manifest body.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSig>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        out.push(
            parse_line(line).with_context(|| format!("manifest line {}", lineno + 1))?,
        );
    }
    Ok(out)
}

fn parse_line(line: &str) -> Result<ArtifactSig> {
    let mut parts = line.split(';');
    let name = parts.next().context("missing name")?.to_string();
    let mut inputs = Vec::new();
    let mut outputs = Vec::new();
    let mut in_outputs = false;
    for p in parts {
        let spec_s = if let Some(rest) = p.strip_prefix("in=") {
            in_outputs = false;
            rest
        } else if let Some(rest) = p.strip_prefix("out=") {
            in_outputs = true;
            rest
        } else {
            p
        };
        let spec = TensorSpec::parse(spec_s)?;
        if in_outputs {
            outputs.push(spec);
        } else {
            inputs.push(spec);
        }
    }
    if inputs.is_empty() || outputs.is_empty() {
        bail!("artifact '{name}' needs at least one input and one output");
    }
    Ok(ArtifactSig { name, inputs, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_single_in_out() {
        let sigs =
            parse_manifest("relu_16384;in=float32[16384];out=float32[16384]\n").unwrap();
        assert_eq!(sigs.len(), 1);
        assert_eq!(sigs[0].name, "relu_16384");
        assert_eq!(sigs[0].inputs[0].dims, vec![16384]);
        assert_eq!(sigs[0].outputs[0].elements(), 16384);
    }

    #[test]
    fn parses_multi_arg_and_matrix() {
        let sigs = parse_manifest(
            "bicg_256;in=float32[256x256];float32[256];float32[256];out=float32[256];float32[256]",
        )
        .unwrap();
        let s = &sigs[0];
        assert_eq!(s.inputs.len(), 3);
        assert_eq!(s.outputs.len(), 2);
        assert_eq!(s.inputs[0].dims, vec![256, 256]);
        assert_eq!(s.inputs[0].elements(), 65536);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_manifest("x;in=foo;out=float32[4]").is_err());
        assert!(parse_manifest("x;in=float32[2]").is_err());
    }
}
