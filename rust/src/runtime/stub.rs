//! Runtime stand-in for builds without the `xla` feature.
//!
//! Presents the same surface as the PJRT-backed [`super::pjrt`]
//! implementation so callers compile unchanged; `open` always fails,
//! which the callers already treat as "artifacts unavailable".

use std::path::Path;

use anyhow::{bail, Result};

use super::ArtifactSig;

/// No-op artifact runtime (the `xla` feature is off).
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always fails: artifact execution needs the `xla` feature.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        bail!(
            "artifact runtime for {:?} unavailable: built without the `xla` feature",
            dir.as_ref()
        )
    }

    /// Artifact names available (none).
    pub fn artifacts(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn signature(&self, _name: &str) -> Option<&ArtifactSig> {
        None
    }

    /// Unreachable in practice (`open` never yields a stub `Runtime`).
    pub fn exec_f32(&mut self, name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        bail!("artifact '{name}': built without the `xla` feature")
    }
}
