//! PJRT runtime (DESIGN.md S18): loads the AOT-compiled JAX/Pallas HLO
//! artifacts and executes them as the functional golden model.
//!
//! The real implementation lives in [`pjrt`] and needs the vendored
//! `xla` crate, which the offline registry does not carry; it is gated
//! behind the `xla` cargo feature. Without the feature a [`stub`]
//! `Runtime` with the same API is compiled instead: `open` fails, so
//! artifact checks degrade to "skipped" while the Rust reference checks
//! keep running (see `coordinator::verify`).

pub mod manifest;

pub use manifest::{ArtifactSig, TensorSpec};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::Runtime;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::Runtime;
