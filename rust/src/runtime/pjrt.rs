//! PJRT-backed runtime implementation (requires the `xla` feature and
//! the vendored `xla` crate; see /opt/xla-example).
//!
//! Interchange is HLO *text* (never serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! python/compile/aot.py). Executables are compiled once and cached;
//! Python never runs at simulation time.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::manifest::{self, ArtifactSig};

/// Compile-once artifact cache over a PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    sigs: HashMap<String, ArtifactSig>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.txt` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts`"))?;
        let sigs = manifest::parse_manifest(&text)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir, sigs, exes: HashMap::new() })
    }

    /// Artifact names available.
    pub fn artifacts(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.sigs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn signature(&self, name: &str) -> Option<&ArtifactSig> {
        self.sigs.get(name)
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        if !self.sigs.contains_key(name) {
            bail!("unknown artifact '{name}' (not in manifest)");
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` on f32 inputs (flattened row-major); returns
    /// the flattened f32 outputs. Input lengths are validated against the
    /// manifest signature.
    pub fn exec_f32(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        let sig = self.sigs.get(name).unwrap().clone();
        if inputs.len() != sig.inputs.len() {
            bail!(
                "artifact '{name}' wants {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (vals, spec)) in inputs.iter().zip(&sig.inputs).enumerate() {
            if vals.len() != spec.elements() {
                bail!(
                    "artifact '{name}' input {i}: want {} elements ({:?}), got {}",
                    spec.elements(),
                    spec.dims,
                    vals.len()
                );
            }
            if spec.dtype != "float32" {
                bail!("artifact '{name}' input {i}: only float32 supported");
            }
            let lit = xla::Literal::vec1(vals);
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            let lit = if dims.len() == 1 {
                lit
            } else {
                lit.reshape(&dims).context("reshape input")?
            };
            literals.push(lit);
        }
        let exe = self.exes.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing '{name}'"))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple().context("untupling result")?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                sig.outputs.len()
            );
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        // Skip (don't fail) when artifacts haven't been generated.
        Runtime::open(artifacts_dir()).ok()
    }

    #[test]
    fn exec_vecadd_artifact() {
        let Some(mut rt) = runtime() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let x: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..4096).map(|i| 2.0 * i as f32).collect();
        let out = rt.exec_f32("vecadd_4096", &[x.clone(), y.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0][17], 3.0 * 17.0);
        assert_eq!(out[0].len(), 4096);
    }

    #[test]
    fn exec_sgemm_matches_naive() {
        let Some(mut rt) = runtime() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let n = 64usize;
        let a: Vec<f32> = (0..n * n).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let b: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
        let out = rt.exec_f32("sgemm_64", &[a.clone(), b.clone()]).unwrap();
        let mut want = vec![0.0f32; n * n];
        for i in 0..n {
            for k in 0..n {
                for j in 0..n {
                    want[i * n + j] += a[i * n + k] * b[k * n + j];
                }
            }
        }
        for (g, w) in out[0].iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
    }

    #[test]
    fn exec_validates_shapes() {
        let Some(mut rt) = runtime() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        assert!(rt.exec_f32("vecadd_4096", &[vec![1.0; 7], vec![1.0; 7]]).is_err());
        assert!(rt.exec_f32("nope", &[]).is_err());
    }

    #[test]
    fn multi_output_artifact_roundtrips() {
        let Some(mut rt) = runtime() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        // bicg_256 returns (s, q).
        let n = 256usize;
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let r: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let p: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let out = rt.exec_f32("bicg_256", &[a, r.clone(), p.clone()]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], r); // s = A^T r = r for identity
        assert_eq!(out[1], p); // q = A p = p
    }
}
