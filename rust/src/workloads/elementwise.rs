//! Streaming / elementwise workload models: `aes`, `fir`, `rl` (Table 3).

use crate::gpu::CuOp;
use crate::workloads::{
    chunk, empty_work, owners, vec_chunks, Alloc, Array, Phase, Rng, Verify, Workload,
    WorkloadParams,
};

/// AES (Hetero-Mark) — *compute-bound* streaming: each 16-byte block is
/// loaded, churned through 10 rounds of table lookups/xors (modelled as a
/// fixed compute delay; the f32 payload transform is `out = 1.5*in + 2.5`
/// so the result stays checkable), and stored.
pub fn aes(p: &WorkloadParams) -> Workload {
    let own = owners(p);
    let q = own.len() * p.wavefronts_per_cu as usize * 4;
    let n = p.scaled(65536, q);
    let mut alloc = Alloc::new(&p.map);
    let input = alloc.partitioned("pt", n, &own);
    let output = alloc.partitioned("ct", n, &own);

    let mut rng = Rng(0xAE5);
    let iv = rng.vec_f32(n);
    let init = init_of(&input, &iv);

    let per = n / own.len();
    let mut work = empty_work(p);
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        for (w, (ws, wl)) in chunk(per, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let mut ops = vec![
                CuOp::MovImm { dst: 4, imm: 1.5 },
                CuOp::MovImm { dst: 5, imm: 2.5 },
            ];
            let start = s * per + ws;
            for (oaddr, i, n) in vec_chunks(&output, start, wl) {
                // Each 16-byte block costs 10 rounds of table lookups/xors
                // (compute delay); a 64-byte coalesced access carries four
                // such blocks.
                ops.push(CuOp::LdV { reg: 0, addr: input.addr_of(i), n });
                ops.push(CuOp::Mul { dst: 1, a: 0, b: 4 });
                ops.push(CuOp::Add { dst: 2, a: 1, b: 5 });
                ops.push(CuOp::StV { addr: oaddr, reg: 2, n });
                ops.push(CuOp::Delay { cycles: 120 * n.div_ceil(4) as u32 });
            }
            work[gpu as usize][cu][w] = ops;
        }
    }

    Workload {
        name: "aes".into(),
        init,
        phases: vec![Phase { name: "encrypt".into(), work }],
        checks: vec![Verify::Rust {
            inputs: vec![input.clone()],
            outputs: vec![output.clone()],
            golden: Box::new(|ins| {
                vec![ins[0].iter().map(|x| 1.5 * x + 2.5).collect()]
            }),
            tol: 0.0,
        }],
        kind: "Compute",
    }
}

/// FIR (Hetero-Mark) — *memory-bound*: y[i] = sum_t h[t] * x[i+t] with 16
/// taps. Heavy spatial reuse on x; h is L1-resident.
pub fn fir(p: &WorkloadParams) -> Workload {
    const TAPS: usize = 16;
    let own = owners(p);
    let q = own.len() * p.wavefronts_per_cu as usize;
    let n = p.scaled(65536, q);
    let mut alloc = Alloc::new(&p.map);
    // Padded input is contiguous (sliding windows cross slice bounds).
    let x = Array::contiguous("x", alloc.on_gpu(0, n + TAPS - 1), n + TAPS - 1);
    let h = Array::contiguous("h", alloc.on_gpu(0, TAPS), TAPS);
    let y = alloc.partitioned("y", n, &own);

    let mut rng = Rng(0xF14);
    let xv = rng.vec_f32(n + TAPS - 1);
    let hv = rng.vec_f32(TAPS);
    let mut init = init_of(&x, &xv);
    init.extend(init_of(&h, &hv));

    let per = n / own.len();
    let mut work = empty_work(p);
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        for (w, (ws, wl)) in chunk(per, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let start = s * per + ws;
            let mut ops = Vec::with_capacity(wl * TAPS);
            // The sliding windows of neighbouring outputs overlap at
            // arbitrary offsets, so x reads stay scalar (they are L1 hits
            // after the first touch); outputs pack into coalesced stores.
            for (oaddr, i0, n) in vec_chunks(&y, start, wl) {
                for j in 0..n as usize {
                    let i = i0 + j;
                    ops.push(CuOp::MovImm { dst: 3, imm: 0.0 });
                    for t in 0..TAPS {
                        ops.push(CuOp::Ld { reg: 0, addr: x.addr_of(i + t) });
                        ops.push(CuOp::Ld { reg: 1, addr: h.addr_of(t) });
                        ops.push(CuOp::Mul { dst: 2, a: 0, b: 1 });
                        ops.push(CuOp::Add { dst: 3, a: 3, b: 2 });
                    }
                    ops.push(CuOp::Pack { dst: 5, lane: j as u8, src: 3 });
                }
                ops.push(CuOp::StV { addr: oaddr, reg: 5, n });
            }
            work[gpu as usize][cu][w] = ops;
        }
    }

    let mut checks = vec![Verify::Rust {
        inputs: vec![x.clone(), h.clone()],
        outputs: vec![y.clone()],
        golden: Box::new(move |ins| {
            let (xs, hs) = (&ins[0], &ins[1]);
            let n = xs.len() - TAPS + 1;
            let mut out = vec![0.0f32; n];
            for t in 0..TAPS {
                for (i, o) in out.iter_mut().enumerate() {
                    *o += hs[t] * xs[i + t];
                }
            }
            vec![out]
        }),
        tol: 1e-4,
    }];
    if n == 65536 {
        checks.push(Verify::Artifact {
            artifact: "fir_65536".into(),
            inputs: vec![x.clone(), h.clone()],
            outputs: vec![y.clone()],
            tol: 1e-4,
        });
    }

    Workload {
        name: "fir".into(),
        init,
        phases: vec![Phase { name: "filter".into(), work }],
        checks,
        kind: "Memory",
    }
}

/// ReLU (DNNMark `rl`) — *memory-bound* pure streaming: out = max(in, 0).
pub fn relu(p: &WorkloadParams) -> Workload {
    let own = owners(p);
    let q = own.len() * p.wavefronts_per_cu as usize;
    let n = p.scaled(65536, q);
    let mut alloc = Alloc::new(&p.map);
    let input = alloc.partitioned("in", n, &own);
    let output = alloc.partitioned("out", n, &own);

    let mut rng = Rng(0x4E1);
    let iv = rng.vec_f32(n);
    let init = init_of(&input, &iv);

    let per = n / own.len();
    let mut work = empty_work(p);
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        for (w, (ws, wl)) in chunk(per, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let start = s * per + ws;
            let mut ops = vec![CuOp::MovImm { dst: 1, imm: 0.0 }];
            for (oaddr, i, n) in vec_chunks(&output, start, wl) {
                ops.push(CuOp::LdV { reg: 0, addr: input.addr_of(i), n });
                ops.push(CuOp::Max { dst: 2, a: 0, b: 1 });
                ops.push(CuOp::StV { addr: oaddr, reg: 2, n });
            }
            work[gpu as usize][cu][w] = ops;
        }
    }

    let mut checks = vec![Verify::Rust {
        inputs: vec![input.clone()],
        outputs: vec![output.clone()],
        golden: Box::new(|ins| vec![ins[0].iter().map(|x| x.max(0.0)).collect()]),
        tol: 0.0,
    }];
    if n == 65536 {
        checks.push(Verify::Artifact {
            artifact: "relu_65536".into(),
            inputs: vec![input.clone()],
            outputs: vec![output.clone()],
            tol: 0.0,
        });
    }

    Workload {
        name: "rl".into(),
        init,
        phases: vec![Phase { name: "relu".into(), work }],
        checks,
        kind: "Memory",
    }
}

/// Build the init list for a (possibly sliced) array from logical values.
pub(crate) fn init_of(arr: &Array, vals: &[f32]) -> Vec<(u64, Vec<f32>)> {
    let mut out = Vec::new();
    let mut off = 0;
    for &(base, len) in &arr.slices {
        out.push((base, vals[off..off + len].to_vec()));
        off += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;

    fn params() -> WorkloadParams {
        WorkloadParams {
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, 2, 2, 2, 64 << 20),
            scale: 0.05,
        }
    }

    #[test]
    fn aes_is_compute_tagged_with_delays() {
        let w = aes(&params());
        assert_eq!(w.kind, "Compute");
        let has_delay = w.phases[0]
            .work
            .iter()
            .flatten()
            .flatten()
            .flatten()
            .any(|op| matches!(op, CuOp::Delay { .. }));
        assert!(has_delay);
    }

    #[test]
    fn fir_reads_overlap_windows() {
        let w = fir(&params());
        // Neighbouring outputs share x reads: count distinct Ld addresses
        // vs total Lds — reuse must be substantial.
        let mut lds = vec![];
        for op in w.phases[0].work.iter().flatten().flatten().flatten() {
            if let CuOp::Ld { addr, .. } = op {
                lds.push(*addr);
            }
        }
        let total = lds.len();
        lds.sort_unstable();
        lds.dedup();
        assert!(lds.len() * 2 < total, "expect >2x read reuse in FIR");
    }

    #[test]
    fn relu_golden_matches_ops_semantics() {
        let w = relu(&params());
        match &w.checks[0] {
            Verify::Rust { golden, .. } => {
                let out = golden(&[vec![-1.0, 2.0, -0.5, 3.0]]);
                assert_eq!(out[0], vec![0.0, 2.0, 0.0, 3.0]);
            }
            _ => panic!("expected rust check"),
        }
    }
}
