//! Dense linear-algebra workload models: `mm`, `atax`, `bicg` (Table 3).
//!
//! Placement mirrors the paper's RDMA pain point: the *shared* operand
//! (B for mm, A/x for atax/bicg) lives in GPU0's partition, so under RDMA
//! three of four GPUs stream it over the PCIe switch, while MGPU-SM reads
//! it from shared HBM.

use crate::gpu::CuOp;
use crate::workloads::elementwise::init_of;
use crate::workloads::{
    chunk, empty_work, owners, vec_chunks, Alloc, Array, Phase, Rng, Verify, Workload,
    WorkloadParams,
};

fn matmul_golden(n: usize) -> impl Fn(&[Vec<f32>]) -> Vec<Vec<f32>> {
    move |ins: &[Vec<f32>]| {
        let (a, b) = (&ins[0], &ins[1]);
        let mut c = vec![0.0f32; n * n];
        for i in 0..n {
            for k in 0..n {
                let aik = a[i * n + k];
                for j in 0..n {
                    c[i * n + j] += aik * b[k * n + j];
                }
            }
        }
        vec![c]
    }
}

/// Matrix multiplication (AMDAPPSDK `mm`) — *memory-bound* at our scales:
/// C = A @ B, row-blocks of C per GPU, naive inner product per output.
pub fn mm(p: &WorkloadParams) -> Workload {
    let n = p.scaled(256, 32);
    let own = owners(p);
    let mut alloc = Alloc::new(&p.map);
    // A row-blocks with their consumer GPU; B shared on GPU0.
    let a = Array::contiguous("A", alloc.on_gpu(0, n * n), n * n);
    let b = Array::contiguous("B", alloc.on_gpu(0, n * n), n * n);
    let c = Array::contiguous("C", alloc.on_gpu(0, n * n), n * n);

    let mut rng = Rng(0x33);
    let av = rng.vec_f32(n * n);
    let bv = rng.vec_f32(n * n);
    let mut init = init_of(&a, &av);
    init.extend(init_of(&b, &bv));

    let mut work = empty_work(p);
    let rows = chunk(n, own.len());
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        let (r0, rl) = rows[s];
        for (w, (wr, wl)) in chunk(rl, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let mut ops = Vec::new();
            // SIMT mapping: one lane per output column — each wavefront
            // chunk computes C[i, j..j+16] with broadcast A[i,k] and
            // coalesced B-row segments (the standard GPU gemm layout).
            // Rotate each row's k-loop start (classic GPU gemm trick):
            // without it every wavefront walks B in lockstep and all fills
            // convoy on one line at a time.
            for i in r0 + wr..r0 + wr + wl {
                let k0 = (i * 17) % n;
                for (caddr, c0, nn) in vec_chunks(&c, i * n, n) {
                    let j0 = c0 - i * n;
                    ops.push(CuOp::MovImm { dst: 3, imm: 0.0 });
                    for kk in 0..n {
                        let k = (k0 + kk) % n;
                        ops.push(CuOp::Ld { reg: 0, addr: a.addr_of(i * n + k) });
                        ops.push(CuOp::LdV { reg: 1, addr: b.addr_of(k * n + j0), n: nn });
                        ops.push(CuOp::Mul { dst: 2, a: 0, b: 1 });
                        ops.push(CuOp::Add { dst: 3, a: 3, b: 2 });
                    }
                    ops.push(CuOp::StV { addr: caddr, reg: 3, n: nn });
                }
            }
            work[gpu as usize][cu][w] = ops;
        }
    }

    let mut checks = vec![Verify::Rust {
        inputs: vec![a.clone(), b.clone()],
        outputs: vec![c.clone()],
        golden: Box::new(matmul_golden(n)),
        tol: 1e-3,
    }];
    if n == 256 {
        checks.push(Verify::Artifact {
            artifact: "sgemm_256".into(),
            inputs: vec![a.clone(), b.clone()],
            outputs: vec![c.clone()],
            tol: 1e-3,
        });
    }

    Workload {
        name: "mm".into(),
        init,
        phases: vec![Phase { name: "gemm".into(), work }],
        checks,
        kind: "Memory",
    }
}

/// PolyBench ATAX — y = A^T (A x): two kernels with a barrier between;
/// the second traverses A column-wise (strided, cache-hostile).
pub fn atax(p: &WorkloadParams) -> Workload {
    let n = p.scaled(512, 64);
    let own = owners(p);
    let mut alloc = Alloc::new(&p.map);
    let a = Array::contiguous("A", alloc.on_gpu(0, n * n), n * n);
    let x = Array::contiguous("x", alloc.on_gpu(0, n), n);
    let t = Array::contiguous("t", alloc.on_gpu(0, n), n);
    let y = Array::contiguous("y", alloc.on_gpu(0, n), n);

    let mut rng = Rng(0xA7A);
    let av = rng.vec_f32(n * n);
    let xv = rng.vec_f32(n);
    let mut init = init_of(&a, &av);
    init.extend(init_of(&x, &xv));

    // Phase 1: t[i] = sum_k A[i,k] x[k] — lanes over k, cross-lane
    // reduction per row (coalesced A-row and x reads).
    let mut work1 = empty_work(p);
    let rows = chunk(n, own.len());
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        let (r0, rl) = rows[s];
        for (w, (wr, wl)) in chunk(rl, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let mut ops = Vec::new();
            for i in r0 + wr..r0 + wr + wl {
                ops.push(CuOp::MovImm { dst: 3, imm: 0.0 });
                for (aaddr, a0, nn) in vec_chunks(&a, i * n, n) {
                    let k0 = a0 - i * n;
                    ops.push(CuOp::LdV { reg: 0, addr: aaddr, n: nn });
                    ops.push(CuOp::LdV { reg: 1, addr: x.addr_of(k0), n: nn });
                    ops.push(CuOp::Mul { dst: 2, a: 0, b: 1 });
                    ops.push(CuOp::Add { dst: 3, a: 3, b: 2 });
                }
                ops.push(CuOp::Red { dst: 4, src: 3 });
                ops.push(CuOp::St { addr: t.addr_of(i), reg: 4 });
            }
            work1[gpu as usize][cu][w] = ops;
        }
    }

    // Phase 2: y[j] = sum_i A[i,j] t[i] — lanes over j: every row of A is
    // re-streamed by every CU slice (the transposed phase's bandwidth
    // pain), t broadcast per row.
    let mut work2 = empty_work(p);
    let cols = chunk(n, own.len());
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        let (c0, cl) = cols[s];
        for (w, (wc, wl)) in chunk(cl, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let mut ops = Vec::new();
            for (yaddr, j0, nn) in vec_chunks(&y, c0 + wc, wl) {
                ops.push(CuOp::MovImm { dst: 3, imm: 0.0 });
                for i in 0..n {
                    ops.push(CuOp::Ld { reg: 1, addr: t.addr_of(i) });
                    ops.push(CuOp::LdV { reg: 0, addr: a.addr_of(i * n + j0), n: nn });
                    ops.push(CuOp::Mul { dst: 2, a: 0, b: 1 });
                    ops.push(CuOp::Add { dst: 3, a: 3, b: 2 });
                }
                ops.push(CuOp::StV { addr: yaddr, reg: 3, n: nn });
            }
            work2[gpu as usize][cu][w] = ops;
        }
    }

    let mut checks = vec![Verify::Rust {
        inputs: vec![a.clone(), x.clone()],
        outputs: vec![y.clone()],
        golden: Box::new(move |ins| {
            let (a, x) = (&ins[0], &ins[1]);
            let mut t = vec![0.0f32; n];
            for (i, ti) in t.iter_mut().enumerate() {
                for k in 0..n {
                    *ti += a[i * n + k] * x[k];
                }
            }
            let mut y = vec![0.0f32; n];
            for (j, yj) in y.iter_mut().enumerate() {
                for i in 0..n {
                    *yj += a[i * n + j] * t[i];
                }
            }
            vec![y]
        }),
        tol: 1e-3,
    }];
    if n == 512 {
        checks.push(Verify::Artifact {
            artifact: "atax_512".into(),
            inputs: vec![a.clone(), x.clone()],
            outputs: vec![y.clone()],
            tol: 1e-3,
        });
    }

    Workload {
        name: "atax".into(),
        init,
        phases: vec![
            Phase { name: "t=Ax".into(), work: work1 },
            Phase { name: "y=A^T t".into(), work: work2 },
        ],
        checks,
        kind: "Memory",
    }
}

/// PolyBench BICG — (s, q) = (A^T r, A p): two independent matvecs
/// (compute-tagged in Table 3; we add a small per-row compute delay).
pub fn bicg(p: &WorkloadParams) -> Workload {
    let n = p.scaled(512, 64);
    let own = owners(p);
    let mut alloc = Alloc::new(&p.map);
    let a = Array::contiguous("A", alloc.on_gpu(0, n * n), n * n);
    let r = Array::contiguous("r", alloc.on_gpu(0, n), n);
    let pv = Array::contiguous("p", alloc.on_gpu(0, n), n);
    let s_arr = Array::contiguous("s", alloc.on_gpu(0, n), n);
    let q_arr = Array::contiguous("q", alloc.on_gpu(0, n), n);

    let mut rng = Rng(0xB1C);
    let av = rng.vec_f32(n * n);
    let rv = rng.vec_f32(n);
    let pvv = rng.vec_f32(n);
    let mut init = init_of(&a, &av);
    init.extend(init_of(&r, &rv));
    init.extend(init_of(&pv, &pvv));

    // Phase 1: q = A p (rows; lanes over k, reduction per row).
    let mut work1 = empty_work(p);
    let rows = chunk(n, own.len());
    for (sl, &(gpu, cu)) in own.iter().enumerate() {
        let (r0, rl) = rows[sl];
        for (w, (wr, wl)) in chunk(rl, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let mut ops = Vec::new();
            for i in r0 + wr..r0 + wr + wl {
                ops.push(CuOp::MovImm { dst: 3, imm: 0.0 });
                for (aaddr, a0, nn) in vec_chunks(&a, i * n, n) {
                    let k0 = a0 - i * n;
                    ops.push(CuOp::LdV { reg: 0, addr: aaddr, n: nn });
                    ops.push(CuOp::LdV { reg: 1, addr: pv.addr_of(k0), n: nn });
                    ops.push(CuOp::Mul { dst: 2, a: 0, b: 1 });
                    ops.push(CuOp::Add { dst: 3, a: 3, b: 2 });
                }
                ops.push(CuOp::Red { dst: 4, src: 3 });
                ops.push(CuOp::Delay { cycles: 40 });
                ops.push(CuOp::St { addr: q_arr.addr_of(i), reg: 4 });
            }
            work1[gpu as usize][cu][w] = ops;
        }
    }

    // Phase 2: s = A^T r (lanes over j; A re-streamed row-wise).
    let mut work2 = empty_work(p);
    for (sl, &(gpu, cu)) in own.iter().enumerate() {
        let (c0, cl) = rows[sl];
        for (w, (wc, wl)) in chunk(cl, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let mut ops = Vec::new();
            for (saddr, j0, nn) in vec_chunks(&s_arr, c0 + wc, wl) {
                ops.push(CuOp::MovImm { dst: 3, imm: 0.0 });
                for i in 0..n {
                    ops.push(CuOp::Ld { reg: 1, addr: r.addr_of(i) });
                    ops.push(CuOp::LdV { reg: 0, addr: a.addr_of(i * n + j0), n: nn });
                    ops.push(CuOp::Mul { dst: 2, a: 0, b: 1 });
                    ops.push(CuOp::Add { dst: 3, a: 3, b: 2 });
                }
                ops.push(CuOp::Delay { cycles: 40 });
                ops.push(CuOp::StV { addr: saddr, reg: 3, n: nn });
            }
            work2[gpu as usize][cu][w] = ops;
        }
    }

    let mut checks = vec![Verify::Rust {
        inputs: vec![a.clone(), r.clone(), pv.clone()],
        outputs: vec![s_arr.clone(), q_arr.clone()],
        golden: Box::new(move |ins| {
            let (a, r, p) = (&ins[0], &ins[1], &ins[2]);
            let mut s = vec![0.0f32; n];
            let mut q = vec![0.0f32; n];
            for i in 0..n {
                for j in 0..n {
                    s[j] += a[i * n + j] * r[i];
                    q[i] += a[i * n + j] * p[j];
                }
            }
            vec![s, q]
        }),
        tol: 1e-3,
    }];
    if n == 512 {
        checks.push(Verify::Artifact {
            artifact: "bicg_512".into(),
            inputs: vec![a.clone(), r.clone(), pv.clone()],
            outputs: vec![s_arr.clone(), q_arr.clone()],
            tol: 1e-3,
        });
    }

    Workload {
        name: "bicg".into(),
        init,
        phases: vec![
            Phase { name: "q=Ap".into(), work: work1 },
            Phase { name: "s=A^T r".into(), work: work2 },
        ],
        checks,
        kind: "Compute",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;

    fn params() -> WorkloadParams {
        WorkloadParams {
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, 2, 2, 2, 64 << 20),
            scale: 0.25,
        }
    }

    #[test]
    fn mm_golden_small_identity() {
        let g = matmul_golden(2);
        let id = vec![1.0, 0.0, 0.0, 1.0];
        let m = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(g(&[id, m.clone()])[0], m);
    }

    #[test]
    fn atax_has_two_phases() {
        let w = atax(&params());
        assert_eq!(w.phases.len(), 2);
        assert_eq!(w.kind, "Memory");
    }

    #[test]
    fn bicg_golden_matches_definition() {
        let w = bicg(&params());
        let n = 128; // scale 0.25 of 512
        match &w.checks[0] {
            Verify::Rust { golden, .. } => {
                // A = I: s = r, q = p.
                let mut a = vec![0.0f32; n * n];
                for i in 0..n {
                    a[i * n + i] = 1.0;
                }
                let r: Vec<f32> = (0..n).map(|i| i as f32).collect();
                let p: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
                let out = golden(&[a, r.clone(), p.clone()]);
                assert_eq!(out[0], r);
                assert_eq!(out[1], p);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn mm_streams_b_rows_coalesced() {
        let w = mm(&params());
        // SIMT gemm: A elements broadcast via scalar Ld, B row segments via
        // coalesced LdV striding one row (n*4 bytes) per k step.
        let ops = w.phases[0].work[0][0][0].clone();
        let mut b_addrs = vec![];
        for op in &ops {
            if let CuOp::LdV { reg: 1, addr, .. } = op {
                b_addrs.push(*addr);
                if b_addrs.len() == 3 {
                    break;
                }
            }
        }
        let n = 64u64; // scale 0.25 of 256
        assert_eq!(b_addrs[1] - b_addrs[0], 4 * n);
        assert_eq!(b_addrs[2] - b_addrs[1], 4 * n);
        assert!(ops.iter().any(|o| matches!(o, CuOp::Ld { reg: 0, .. })));
        assert!(ops.iter().any(|o| matches!(o, CuOp::StV { .. })));
    }
}
