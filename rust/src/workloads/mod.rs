//! Workload models (DESIGN.md S15): the paper's 11 standard benchmarks
//! (Table 3) plus the three-benchmark synthetic **Xtreme** suite (§4.3.2).
//!
//! Substitution note (repro band 0/5): the paper drives MGPUSim with real
//! GCN3 kernels; we have neither the binaries nor an ISA emulator, so each
//! benchmark is modelled as the *memory-access pattern + compute intensity
//! + data-sharing structure* of its kernel, compiled to per-wavefront
//! register programs ([`crate::gpu::CuOp`]). Coherence-protocol behaviour
//! depends exactly on those properties, not on instruction semantics.
//! Every generator documents its pattern; data is real f32, so the final
//! memory image is verified against the XLA/Pallas golden model or a Rust
//! reference (DESIGN.md S19).

pub mod elementwise;
pub mod graph;
pub mod linalg;
pub mod sort;
pub mod stencil;
pub mod xtreme;

use crate::dram::SharedMemory;
use crate::gpu::CuOp;
use crate::mem::AddrMap;

/// A logical array laid out as one or more contiguous f32 slices.
#[derive(Clone, Debug)]
pub struct Array {
    pub name: String,
    /// (base address, element count) per slice, in logical order.
    pub slices: Vec<(u64, usize)>,
}

impl Array {
    pub fn contiguous(name: impl Into<String>, addr: u64, len: usize) -> Self {
        Array { name: name.into(), slices: vec![(addr, len)] }
    }

    pub fn len(&self) -> usize {
        self.slices.iter().map(|(_, n)| n).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Address of logical element `i`.
    pub fn addr_of(&self, mut i: usize) -> u64 {
        for &(base, n) in &self.slices {
            if i < n {
                return base + 4 * i as u64;
            }
            i -= n;
        }
        panic!("index {i} past end of {}", self.name);
    }

    /// Read the whole logical array from the functional memory.
    pub fn read(&self, mem: &SharedMemory) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len());
        let mut m = mem.borrow_mut();
        for &(base, n) in &self.slices {
            out.extend(m.read_f32_vec(base, n));
        }
        out
    }

    /// Write the whole logical array into the functional memory.
    pub fn write(&self, mem: &SharedMemory, data: &[f32]) {
        assert_eq!(data.len(), self.len());
        let mut m = mem.borrow_mut();
        let mut off = 0;
        for &(base, n) in &self.slices {
            m.write_f32_slice(base, &data[off..off + n]);
            off += n;
        }
    }
}

/// How a run's final memory image is checked (DESIGN.md S19).
pub enum Verify {
    /// Execute an AOT artifact via the PJRT runtime on the *initial*
    /// values of `inputs`; the result must match the *final* values of
    /// `outputs` (allclose for dot-product kernels, exact for elementwise).
    Artifact { artifact: String, inputs: Vec<Array>, outputs: Vec<Array>, tol: f32 },
    /// Rust golden function over the initial input values.
    Rust {
        inputs: Vec<Array>,
        outputs: Vec<Array>,
        golden: Box<dyn Fn(&[Vec<f32>]) -> Vec<Vec<f32>>>,
        tol: f32,
    },
    /// No functional check (pattern-only microbenchmarks).
    None,
}

/// One kernel launch: per-GPU, per-CU, per-wavefront op lists.
pub struct Phase {
    pub name: String,
    /// `[gpu][cu][wavefront]` — empty vectors mean "idle".
    pub work: Vec<Vec<Vec<Vec<CuOp>>>>,
}

/// A complete benchmark instance bound to a topology.
pub struct Workload {
    pub name: String,
    /// Initial memory image: (address, f32 values).
    pub init: Vec<(u64, Vec<f32>)>,
    pub phases: Vec<Phase>,
    pub checks: Vec<Verify>,
    /// Paper Table 3 type tag ("Compute" / "Memory") for reporting.
    pub kind: &'static str,
}

/// Parameters every generator receives.
#[derive(Clone, Debug)]
pub struct WorkloadParams {
    pub n_gpus: u32,
    pub cus_per_gpu: u32,
    pub wavefronts_per_cu: u32,
    pub map: AddrMap,
    /// Global problem-size scale in [0.25, 4]; 1.0 = DESIGN.md defaults.
    pub scale: f64,
}

impl WorkloadParams {
    pub fn total_cus(&self) -> usize {
        (self.n_gpus * self.cus_per_gpu) as usize
    }

    /// Scale a default problem size, keeping it a multiple of `quantum`.
    pub fn scaled(&self, default: usize, quantum: usize) -> usize {
        let n = ((default as f64 * self.scale) as usize).max(quantum);
        n.div_ceil(quantum) * quantum
    }
}

/// Bump allocator over GPU memory partitions. Under SharedMem the partition
/// choice only sets *logical* placement (pages interleave across all
/// stacks); under Rdma it decides locality, reproducing the paper's NUMA
/// effects.
pub struct Alloc {
    map: AddrMap,
    next: Vec<u64>,
}

impl Alloc {
    pub fn new(map: &AddrMap) -> Self {
        let next = (0..map.n_gpus)
            .map(|g| g as u64 * map.gpu_mem_bytes + 0x1000) // skip page 0
            .collect();
        Alloc { map: map.clone(), next }
    }

    /// Allocate `n` f32 slots in `gpu`'s partition (256-byte aligned).
    pub fn on_gpu(&mut self, gpu: u32, n: usize) -> u64 {
        let bytes = (n as u64 * 4).div_ceil(256) * 256;
        let base = self.next[gpu as usize];
        self.next[gpu as usize] += bytes;
        assert!(
            self.next[gpu as usize] <= (gpu as u64 + 1) * self.map.gpu_mem_bytes,
            "GPU {gpu} partition exhausted"
        );
        base
    }

    /// Allocate a partitioned array: one equal slice per (gpu, cu) owner in
    /// round-robin GPU order. Returns the logical array.
    pub fn partitioned(&mut self, name: &str, n: usize, owners: &[(u32, usize)]) -> Array {
        let per = n / owners.len();
        let mut rem = n % owners.len();
        let mut slices = Vec::new();
        for &(gpu, _cu) in owners {
            let mut len = per;
            if rem > 0 {
                len += 1;
                rem -= 1;
            }
            slices.push((self.on_gpu(gpu, len), len));
        }
        Array { name: name.into(), slices }
    }
}

/// Split the logical index range `[start, start+len)` of `arr` into
/// maximal runs that are contiguous in memory, stay within one 64-byte
/// cache line, and are at most [`crate::gpu::cu::LANES`] long — the units
/// a coalesced wavefront access (`LdV`/`StV`) can cover.
/// Returns `(addr, logical_start, n)` per run.
pub fn vec_chunks(arr: &Array, start: usize, len: usize) -> Vec<(u64, usize, u8)> {
    let lanes = crate::gpu::cu::LANES;
    let mut out = Vec::new();
    let mut i = start;
    while i < start + len {
        let addr = arr.addr_of(i);
        let mut n = 1usize;
        while i + n < start + len && n < lanes {
            let next = arr.addr_of(i + n);
            if next != addr + 4 * n as u64 || next / 64 != addr / 64 {
                break;
            }
            n += 1;
        }
        out.push((addr, i, n as u8));
        i += n;
    }
    out
}

/// Split `n` items into `parts` contiguous (start, len) ranges.
pub fn chunk(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Owner list [(gpu, cu)] in gpu-major order.
pub fn owners(p: &WorkloadParams) -> Vec<(u32, usize)> {
    (0..p.n_gpus)
        .flat_map(|g| (0..p.cus_per_gpu as usize).map(move |c| (g, c)))
        .collect()
}

/// Build an empty `[gpu][cu][wf]` work grid.
pub fn empty_work(p: &WorkloadParams) -> Vec<Vec<Vec<Vec<CuOp>>>> {
    (0..p.n_gpus)
        .map(|_| {
            (0..p.cus_per_gpu)
                .map(|_| vec![Vec::new(); p.wavefronts_per_cu as usize])
                .collect()
        })
        .collect()
}

/// Deterministic PRNG for synthetic data (splitmix64 -> f32 in [-1, 1]).
pub struct Rng(pub u64);

impl Rng {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits -> [-1, 1); exactly representable values keep
        // cross-checks bit-stable.
        let v = (self.next_u64() >> 40) as f32 / (1u64 << 23) as f32;
        v - 1.0
    }

    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f32()).collect()
    }

    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// Prefix of the trace-replay pseudo-workload form: `trace:<file>`
/// replays a recorded or synthetic trace (see `crate::trace`).
pub const TRACE_PREFIX: &str = "trace:";

/// Registry: build a workload by its paper abbreviation or the
/// `trace:<file>` replay form. Panics with the full valid-name list on
/// unknown names (campaign specs validate with [`validate_name`] first,
/// so sweeps fail fast instead of mid-campaign).
pub fn build(name: &str, p: &WorkloadParams) -> Workload {
    try_build(name, p).unwrap_or_else(|e| panic!("{e}"))
}

/// Non-panicking [`build`].
pub fn try_build(name: &str, p: &WorkloadParams) -> Result<Workload, String> {
    if crate::tenancy::is_mix(name) {
        // The composed mix drops its scheduling plan here; callers that
        // need the inter-kernel scheduler (the runner) go through
        // `tenancy::compose` directly and keep the plan.
        return crate::tenancy::compose(name, p)
            .map(|(wl, _)| wl)
            .map_err(|e| format!("workload '{name}': {e}"));
    }
    if let Some(path) = name.strip_prefix(TRACE_PREFIX) {
        // Loaded per call on purpose: campaign cells are independent,
        // panic-isolated simulations sharing no state, and a re-read per
        // cell keeps that contract (smoke-scale traces decode in
        // milliseconds).
        let t = crate::trace::load(path)?;
        return crate::trace::replay_workload(name, &t, p)
            .map_err(|e| format!("workload '{name}': {e}"));
    }
    Ok(match name {
        "aes" => elementwise::aes(p),
        "atax" => linalg::atax(p),
        "bfs" => graph::bfs_gather(p),
        "bicg" => linalg::bicg(p),
        "bs" => sort::bitonic(p),
        "fir" => elementwise::fir(p),
        "fws" => graph::floyd_warshall(p),
        "mm" => linalg::mm(p),
        "mp" => stencil::maxpool(p),
        "rl" => elementwise::relu(p),
        "conv" => stencil::conv3x3(p),
        "xtreme1" => xtreme::xtreme(p, 1),
        "xtreme2" => xtreme::xtreme(p, 2),
        "xtreme3" => xtreme::xtreme(p, 3),
        other => return Err(unknown_name_error(other)),
    })
}

fn unknown_name_error(name: &str) -> String {
    format!(
        "unknown workload '{name}': valid names are {STANDARD:?} (standard), \
         {XTREME:?} (xtreme), the replay form 'trace:<file>' for a \
         recorded/synthetic trace (docs/TRACE.md), or the multi-tenant mix \
         form 'mix:<spec>' (docs/TENANCY.md)"
    )
}

/// The paper's Table 3 standard suite.
pub const STANDARD: [&str; 11] =
    ["aes", "atax", "bfs", "bicg", "bs", "fir", "fws", "mm", "mp", "rl", "conv"];

/// The Xtreme synthetic suite (§4.3.2).
pub const XTREME: [&str; 3] = ["xtreme1", "xtreme2", "xtreme3"];

/// Whether `name` is *syntactically* a workload: a registry member or
/// the `trace:<file>` form (whose file is not probed here — use
/// [`validate_name`] for that).
pub fn is_known(name: &str) -> bool {
    STANDARD.contains(&name)
        || XTREME.contains(&name)
        || name.starts_with(TRACE_PREFIX)
        || crate::tenancy::is_mix(name)
}

/// Deep name validation: registry membership, or — for `trace:<file>` —
/// that the file exists and its header parses under a supported format
/// version. Campaign specs call this so a bad trace path fails at spec
/// time with a clear error instead of panicking mid-campaign.
pub fn validate_name(name: &str) -> Result<(), String> {
    if crate::tenancy::is_mix(name) {
        crate::tenancy::validate(name).map_err(|e| format!("workload '{name}': {e}"))
    } else if let Some(path) = name.strip_prefix(TRACE_PREFIX) {
        crate::trace::load_meta(path)
            .map(|_| ())
            .map_err(|e| format!("workload '{name}': {e}"))
    } else if STANDARD.contains(&name) || XTREME.contains(&name) {
        Ok(())
    } else {
        Err(unknown_name_error(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;

    fn params() -> WorkloadParams {
        WorkloadParams {
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, 2, 2, 2, 64 << 20),
            scale: 0.25,
        }
    }

    #[test]
    fn chunk_covers_everything_contiguously() {
        for (n, parts) in [(10, 3), (7, 7), (5, 8), (100, 1)] {
            let cs = chunk(n, parts);
            assert_eq!(cs.len(), parts);
            let mut next = 0;
            for (s, l) in &cs {
                assert_eq!(*s, next);
                next += l;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn alloc_respects_partitions() {
        let p = params();
        let mut a = Alloc::new(&p.map);
        let x = a.on_gpu(0, 100);
        let y = a.on_gpu(1, 100);
        assert_eq!(p.map.home_gpu(x), 0);
        assert_eq!(p.map.home_gpu(y), 1);
        let z = a.on_gpu(0, 4);
        assert!(z >= x + 400);
        assert_eq!(z % 256, 0);
    }

    #[test]
    fn partitioned_array_addresses_roundtrip() {
        let p = params();
        let mut a = Alloc::new(&p.map);
        let arr = a.partitioned("t", 10, &owners(&p));
        assert_eq!(arr.len(), 10);
        // addr_of walks slices in logical order.
        let mut seen = std::collections::HashSet::new();
        for i in 0..10 {
            assert!(seen.insert(arr.addr_of(i)));
        }
    }

    #[test]
    fn array_read_write_roundtrip() {
        let p = params();
        let mut a = Alloc::new(&p.map);
        let arr = a.partitioned("t", 9, &owners(&p));
        let mem = crate::dram::GlobalMemory::new_shared();
        let vals: Vec<f32> = (0..9).map(|i| i as f32 * 1.5).collect();
        arr.write(&mem, &vals);
        assert_eq!(arr.read(&mem), vals);
    }

    #[test]
    fn rng_is_deterministic_and_bounded() {
        let mut a = Rng(42);
        let mut b = Rng(42);
        let va = a.vec_f32(100);
        let vb = b.vec_f32(100);
        assert_eq!(va, vb);
        assert!(va.iter().all(|v| (-1.0..1.0).contains(v)));
        assert!(va.iter().any(|v| *v != va[0]), "values vary");
    }

    #[test]
    fn all_registry_names_build() {
        let p = params();
        for name in STANDARD.iter().chain(XTREME.iter()) {
            let w = build(name, &p);
            assert!(!w.phases.is_empty(), "{name} has phases");
            assert_eq!(w.name, *name);
            for ph in &w.phases {
                assert_eq!(ph.work.len(), p.n_gpus as usize, "{name} gpu dim");
                for cu_work in &ph.work {
                    assert_eq!(cu_work.len(), p.cus_per_gpu as usize, "{name} cu dim");
                }
            }
        }
    }

    #[test]
    fn name_validation_knows_the_trace_form() {
        assert!(is_known("fir"));
        assert!(is_known("trace:whatever.trc"));
        assert!(!is_known("nope"));
        validate_name("xtreme1").unwrap();
        let e = validate_name("nope").unwrap_err();
        assert!(e.contains("fir") && e.contains("trace:<file>"), "{e}");
        assert!(e.contains("mix:<spec>"), "{e}");
        let e = validate_name("trace:/definitely/missing.trc").unwrap_err();
        assert!(e.contains("missing.trc"), "{e}");
        let e = try_build("nope", &params()).unwrap_err();
        assert!(e.contains("trace:<file>"), "{e}");
    }

    #[test]
    fn name_validation_knows_the_mix_form() {
        assert!(is_known("mix:private+private"));
        validate_name("mix:read-mostly+false-sharing@64").unwrap();
        // Spec errors surface at validation time, never mid-campaign.
        let e = validate_name("mix:").unwrap_err();
        assert!(e.contains("mix:<pattern>") && e.contains("mix:<file>.mix"), "{e}");
        let e = validate_name("mix:trace:/definitely/missing.trc+private").unwrap_err();
        assert!(e.contains("missing.trc"), "{e}");
        // A valid mix composes through the ordinary registry path too.
        let wl = try_build("mix:private+private", &params()).unwrap();
        assert_eq!(wl.kind, "Mix");
    }

    #[test]
    fn scaled_respects_quantum() {
        let p = params(); // scale 0.25
        assert_eq!(p.scaled(16384, 64) % 64, 0);
        assert!(p.scaled(16384, 64) <= 16384);
        assert!(p.scaled(16, 16) >= 16);
    }
}
