//! The Xtreme synthetic suite (paper §4.3.2): C = A + B with enforced
//! read-write sharing, built to stress the coherence protocol.
//!
//! Slicing follows the paper: vectors A, B, C are split into one slice per
//! CU; slice `s` lives in the partition of the GPU owning that CU (so under
//! RDMA each CU's slice is local, like the paper's placement).
//!
//! * **Xtreme1** — every CU repeats `C_s = A_s + B_s` 10 times, then
//!   `A_s = C_s + B_s` 10 times. No sharing; the repeated writes push each
//!   cache's cts forward and self-invalidate previously read blocks.
//! * **Xtreme2** — after one `C = A + B` pass, CU0 of GPU0 repeatedly
//!   rewrites *CU1-of-GPU0's* slice (`A_1 = C_1 + B_1` x10): intra-GPU
//!   SWMR sharing. A final `C = A + B` pass rereads everything.
//! * **Xtreme3** — same, but the victim slice belongs to a CU of *another
//!   GPU*: inter-GPU sharing.

use crate::gpu::CuOp;
use crate::workloads::{
    chunk, empty_work, owners, vec_chunks, Alloc, Array, Phase, Rng, Verify, Workload,
    WorkloadParams,
};

/// Ops for `dst[i] = s1[i] + s2[i]` over logical range [start, start+len),
/// repeated `reps` times (the repetition is *inside* the kernel, as in the
/// paper's step (2)/(3) loops). Accesses are wavefront-coalesced: one
/// vector transaction per cache-line run (the three arrays are laid out
/// with identical intra-slice alignment, so one chunking serves all).
fn add_range(
    dst: &Array,
    s1: &Array,
    s2: &Array,
    start: usize,
    len: usize,
    reps: usize,
) -> Vec<CuOp> {
    let chunks = vec_chunks(dst, start, len);
    let mut ops = Vec::with_capacity(chunks.len() * reps * 4);
    for _ in 0..reps {
        for &(daddr, i, n) in &chunks {
            ops.push(CuOp::LdV { reg: 0, addr: s1.addr_of(i), n });
            ops.push(CuOp::LdV { reg: 1, addr: s2.addr_of(i), n });
            ops.push(CuOp::Add { dst: 2, a: 0, b: 1 });
            ops.push(CuOp::StV { addr: daddr, reg: 2, n });
        }
    }
    ops
}

/// Phase where every CU computes `dst_s = s1_s + s2_s` on its own slice.
fn all_cu_phase(
    p: &WorkloadParams,
    name: &str,
    dst: &Array,
    s1: &Array,
    s2: &Array,
    reps: usize,
) -> Phase {
    let own = owners(p);
    let per = dst.len() / own.len();
    let mut work = empty_work(p);
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        let slice_start = s * per;
        for (w, (ws, wl)) in chunk(per, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            work[gpu as usize][cu][w] =
                add_range(dst, s1, s2, slice_start + ws, wl, reps);
        }
    }
    Phase { name: name.into(), work }
}

/// Build Xtreme `variant` (1, 2 or 3).
pub fn xtreme(p: &WorkloadParams, variant: u8) -> Workload {
    let own = owners(p);
    // Paper sweeps 192 KB..96 MB per vector; default here is 64 KB/vector
    // (16384 f32), scaled by `p.scale` and rounded to a slice multiple.
    let n = {
        let q = own.len() * p.wavefronts_per_cu as usize;
        p.scaled(65536, q)
    };
    let per = n / own.len();
    let mut alloc = Alloc::new(&p.map);
    let a = alloc.partitioned("A", n, &own);
    let b = alloc.partitioned("B", n, &own);
    let c = alloc.partitioned("C", n, &own);

    let mut rng = Rng(0xA11CE + variant as u64);
    let av = rng.vec_f32(n);
    let bv = rng.vec_f32(n);
    let mut init = Vec::new();
    for (arr, vals) in [(&a, &av), (&b, &bv)] {
        let mut off = 0;
        for &(base, len) in &arr.slices {
            init.push((base, vals[off..off + len].to_vec()));
            off += len;
        }
    }

    // The victim slice for variants 2/3 (paper: CU_X1's slice for Xtreme2,
    // CU_Y1's for Xtreme3). The writer is always CU0 of GPU0.
    let victim_slice = match variant {
        2 => 1usize.min(own.len() - 1), // another CU on GPU0
        3 => (p.cus_per_gpu as usize + 1).min(own.len() - 1), // a CU on GPU1
        _ => 0,
    };

    let mut phases = Vec::new();
    let mut golden_a = av.clone();
    let golden_c: Vec<f32>;

    match variant {
        1 => {
            phases.push(all_cu_phase(p, "C=A+B x10", &c, &a, &b, 10));
            phases.push(all_cu_phase(p, "A=C+B x10", &a, &c, &b, 10));
            // Fixed point: C = A + B, then A = C + B = A + 2B.
            golden_c = av.iter().zip(&bv).map(|(x, y)| x + y).collect();
            golden_a = golden_c.iter().zip(&bv).map(|(x, y)| x + y).collect();
        }
        2 | 3 => {
            phases.push(all_cu_phase(p, "C=A+B", &c, &a, &b, 1));
            // CU0 of GPU0 rewrites the victim slice 10 times.
            let mut work = empty_work(p);
            let start = victim_slice * per;
            let chunks = chunk(per, p.wavefronts_per_cu as usize);
            for (w, (ws, wl)) in chunks.into_iter().enumerate() {
                work[0][0][w] = add_range(&a, &c, &b, start + ws, wl, 10);
            }
            phases.push(Phase { name: "A_v=C_v+B_v x10 (CU0.0)".into(), work });
            phases.push(all_cu_phase(p, "C=A+B (reread)", &c, &a, &b, 1));
            // Golden: A' = A + 2B on the victim slice; C' = A' + B.
            for i in start..start + per {
                golden_a[i] = av[i] + 2.0 * bv[i];
            }
            golden_c = golden_a.iter().zip(&bv).map(|(x, y)| x + y).collect();
        }
        other => panic!("xtreme variant {other}"),
    }

    let mut checks: Vec<Verify> = Vec::new();
    let (ga, gc) = (golden_a, golden_c);
    checks.push(Verify::Rust {
        inputs: vec![a.clone(), b.clone()],
        outputs: vec![a.clone(), c.clone()],
        golden: Box::new(move |_inputs| vec![ga.clone(), gc.clone()]),
        tol: 0.0,
    });
    if variant == 1 && n == 65536 {
        // Cross-check against the AOT Pallas artifact (end-to-end E2E path).
        checks.push(Verify::Artifact {
            artifact: "xtreme_round_65536".into(),
            inputs: vec![a.clone(), b.clone()],
            outputs: vec![a.clone(), c.clone()],
            tol: 0.0,
        });
    }

    Workload {
        name: format!("xtreme{variant}"),
        init,
        phases,
        checks,
        kind: "Synthetic",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;

    fn params() -> WorkloadParams {
        WorkloadParams {
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, 2, 2, 2, 64 << 20),
            scale: 0.05, // tiny
        }
    }

    #[test]
    fn xtreme1_has_two_phases_everyone_works() {
        let w = xtreme(&params(), 1);
        assert_eq!(w.phases.len(), 2);
        for ph in &w.phases {
            for gw in &ph.work {
                for cw in gw {
                    assert!(cw.iter().any(|wf| !wf.is_empty()), "all CUs busy");
                }
            }
        }
    }

    #[test]
    fn xtreme2_middle_phase_only_cu00() {
        let w = xtreme(&params(), 2);
        assert_eq!(w.phases.len(), 3);
        let mid = &w.phases[1];
        assert!(mid.work[0][0].iter().any(|wf| !wf.is_empty()));
        assert!(mid.work[0][1].iter().all(|wf| wf.is_empty()));
        assert!(mid.work[1][0].iter().all(|wf| wf.is_empty()));
    }

    #[test]
    fn xtreme3_victim_is_on_other_gpu() {
        let p = params();
        let w = xtreme(&p, 3);
        // The victim slice (cus_per_gpu + 1 = slice 3) belongs to GPU1;
        // the middle phase writer ops must touch GPU1's partition.
        let mid = &w.phases[1];
        let ops = &mid.work[0][0];
        let touches_gpu1 = ops.iter().flatten().any(|op| match op {
            CuOp::St { addr, .. } | CuOp::StV { addr, .. } => p.map.home_gpu(*addr) == 1,
            _ => false,
        });
        assert!(touches_gpu1, "xtreme3 middle phase must write a GPU1-homed slice");
    }

    #[test]
    fn repetition_multiplies_ops() {
        let a = Array::contiguous("a", 0x1000, 8);
        let b = Array::contiguous("b", 0x2000, 8);
        let c = Array::contiguous("c", 0x3000, 8);
        let once = add_range(&c, &a, &b, 0, 8, 1).len();
        let ten = add_range(&c, &a, &b, 0, 8, 10).len();
        assert_eq!(ten, 10 * once);
    }
}
