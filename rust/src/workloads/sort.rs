//! Bitonic sort (`bs`, AMDAPPSDK) — many small kernels (one per
//! compare-exchange pass), the paper's many-kernel-launch memory-bound
//! workload.

use crate::gpu::cu::LANES;
use crate::gpu::CuOp;
use crate::workloads::elementwise::init_of;
use crate::workloads::{
    chunk, empty_work, owners, Alloc, Array, Phase, Rng, Verify, Workload, WorkloadParams,
};

/// Full bitonic network over `n = 2^m` elements: `m*(m+1)/2` phases, each a
/// parallel compare-exchange pass over n/2 pairs.
pub fn bitonic(p: &WorkloadParams) -> Workload {
    // Problem size must be a power of two.
    let n = {
        let want = (16384.0 * p.scale) as usize;
        want.next_power_of_two().clamp(64, 1 << 20)
    };
    let own = owners(p);
    let mut alloc = Alloc::new(&p.map);
    let arr = Array::contiguous("arr", alloc.on_gpu(0, n), n);

    let mut rng = Rng(0xB170);
    let av = rng.vec_f32(n);
    let init = init_of(&arr, &av);

    let mut phases = Vec::new();
    let mut size = 2usize;
    while size <= n {
        let mut stride = size / 2;
        while stride >= 1 {
            // Collect the pass's pairs: (low index, partner, ascending),
            // then group them by the 16-element block of `i`. Work is
            // distributed in whole blocks: timestamp coherence is SWMR at
            // *block* granularity (true of the real protocol too), so two
            // CUs must never write disjoint words of one line within a
            // kernel — exactly how real GPU bitonic kernels assign
            // contiguous element ranges per wavefront.
            let pairs: Vec<(usize, usize, bool)> = (0..n)
                .filter_map(|i| {
                    let j = i ^ stride;
                    (j > i).then(|| (i, j, (i & size) == 0))
                })
                .collect();
            let mut blocks: Vec<Vec<(usize, usize, bool)>> = Vec::new();
            for pr in pairs {
                match blocks.last_mut() {
                    Some(b) if b[0].0 / LANES == pr.0 / LANES => b.push(pr),
                    _ => blocks.push(vec![pr]),
                }
            }
            let mut work = empty_work(p);
            let split = chunk(blocks.len(), own.len());
            let vectorized = stride >= LANES;
            for (s, &(gpu, cu)) in own.iter().enumerate() {
                let (p0, pl) = split[s];
                for (w, (wp, wl)) in
                    chunk(pl, p.wavefronts_per_cu as usize).into_iter().enumerate()
                {
                    let mut ops = Vec::new();
                    let my: Vec<(usize, usize, bool)> =
                        blocks[p0 + wp..p0 + wp + wl].concat();
                    let my = &my[..];
                    if vectorized {
                        // stride >= LANES: i-runs and partner-runs are both
                        // contiguous full/partial lines — coalesce LANES
                        // pairs per compare-exchange (direction is constant
                        // within a run because size > stride >= LANES).
                        let mut q = 0;
                        while q < my.len() {
                            let (i, j, asc) = my[q];
                            let mut nn = 1usize;
                            while q + nn < my.len()
                                && nn < LANES
                                && my[q + nn].0 == i + nn
                                && (i + nn) % LANES != 0
                            {
                                nn += 1;
                            }
                            let nn8 = nn as u8;
                            ops.push(CuOp::LdV { reg: 0, addr: arr.addr_of(i), n: nn8 });
                            ops.push(CuOp::LdV { reg: 1, addr: arr.addr_of(j), n: nn8 });
                            ops.push(CuOp::Min { dst: 2, a: 0, b: 1 });
                            ops.push(CuOp::Max { dst: 3, a: 0, b: 1 });
                            let (lo, hi) = if asc { (2, 3) } else { (3, 2) };
                            ops.push(CuOp::StV { addr: arr.addr_of(i), reg: lo, n: nn8 });
                            ops.push(CuOp::StV { addr: arr.addr_of(j), reg: hi, n: nn8 });
                            q += nn;
                        }
                    } else {
                        // Fine strides exchange within a line: scalar ops.
                        for &(i, j, asc) in my {
                            ops.push(CuOp::Ld { reg: 0, addr: arr.addr_of(i) });
                            ops.push(CuOp::Ld { reg: 1, addr: arr.addr_of(j) });
                            ops.push(CuOp::Min { dst: 2, a: 0, b: 1 });
                            ops.push(CuOp::Max { dst: 3, a: 0, b: 1 });
                            let (lo, hi) = if asc { (2, 3) } else { (3, 2) };
                            ops.push(CuOp::St { addr: arr.addr_of(i), reg: lo });
                            ops.push(CuOp::St { addr: arr.addr_of(j), reg: hi });
                        }
                    }
                    work[gpu as usize][cu][w] = ops;
                }
            }
            phases.push(Phase { name: format!("size{size}-stride{stride}"), work });
            stride /= 2;
        }
        size *= 2;
    }

    Workload {
        name: "bs".into(),
        init,
        phases,
        checks: vec![Verify::Rust {
            inputs: vec![arr.clone()],
            outputs: vec![arr.clone()],
            golden: Box::new(|ins| {
                let mut v = ins[0].clone();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                vec![v]
            }),
            tol: 0.0,
        }],
        kind: "Memory",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;

    fn params() -> WorkloadParams {
        WorkloadParams {
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, 2, 2, 2, 64 << 20),
            scale: 1.0 / 256.0, // n = 64
        }
    }

    #[test]
    fn phase_count_is_m_times_m_plus_1_over_2() {
        let w = bitonic(&params());
        // n = 64 -> m = 6 -> 21 phases.
        assert_eq!(w.phases.len(), 21);
    }

    #[test]
    fn network_sorts_when_executed_sequentially() {
        // Execute the compare-exchange ops functionally (phase by phase)
        // and check the result is sorted — validates the network itself.
        let p = params();
        let w = bitonic(&p);
        let mut mem = std::collections::HashMap::<u64, f32>::new();
        for (base, vals) in &w.init {
            for (i, v) in vals.iter().enumerate() {
                mem.insert(base + 4 * i as u64, *v);
            }
        }
        for ph in &w.phases {
            // Gather all ops in the phase; pairs are disjoint, so order
            // within a phase does not matter.
            for ops in ph.work.iter().flatten().flatten() {
                let mut regs = [[0.0f32; LANES]; 16];
                for op in ops {
                    match *op {
                        CuOp::Ld { reg, addr } => {
                            regs[reg as usize] = [*mem.get(&addr).unwrap_or(&0.0); LANES]
                        }
                        CuOp::LdV { reg, addr, n } => {
                            let mut v = [0.0f32; LANES];
                            for (l, vl) in v.iter_mut().enumerate().take(n as usize) {
                                *vl = *mem.get(&(addr + 4 * l as u64)).unwrap_or(&0.0);
                            }
                            regs[reg as usize] = v;
                        }
                        CuOp::St { addr, reg } => {
                            mem.insert(addr, regs[reg as usize][0]);
                        }
                        CuOp::StV { addr, reg, n } => {
                            for l in 0..n as usize {
                                mem.insert(addr + 4 * l as u64, regs[reg as usize][l]);
                            }
                        }
                        CuOp::Min { dst, a, b } => {
                            for l in 0..LANES {
                                regs[dst as usize][l] =
                                    regs[a as usize][l].min(regs[b as usize][l]);
                            }
                        }
                        CuOp::Max { dst, a, b } => {
                            for l in 0..LANES {
                                regs[dst as usize][l] =
                                    regs[a as usize][l].max(regs[b as usize][l]);
                            }
                        }
                        _ => {}
                    }
                }
            }
        }
        let base = w.init[0].0;
        let sorted: Vec<f32> = (0..64).map(|i| mem[&(base + 4 * i as u64)]).collect();
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "not sorted: {sorted:?}");
    }
}
