//! Stencil / pooling workload models: `conv`, `mp` (Table 3).

use crate::gpu::CuOp;
use crate::workloads::elementwise::init_of;
use crate::workloads::{
    chunk, empty_work, owners, vec_chunks, Alloc, Array, Phase, Rng, Verify, Workload,
    WorkloadParams,
};

/// Simple 3x3 'same' convolution (AMDAPPSDK `conv`) — *memory-bound*
/// stencil with spatial reuse; image rows are block-partitioned across
/// CUs, so halo rows are read-shared between neighbours.
pub fn conv3x3(p: &WorkloadParams) -> Workload {
    let n = p.scaled(256, 32);
    let own = owners(p);
    let mut alloc = Alloc::new(&p.map);
    let img = Array::contiguous("img", alloc.on_gpu(0, n * n), n * n);
    let k = Array::contiguous("k", alloc.on_gpu(0, 9), 9);
    let out = Array::contiguous("out", alloc.on_gpu(0, n * n), n * n);

    let mut rng = Rng(0xC0);
    let iv = rng.vec_f32(n * n);
    let kv = rng.vec_f32(9);
    let mut init = init_of(&img, &iv);
    init.extend(init_of(&k, &kv));

    let mut work = empty_work(p);
    let rows = chunk(n, own.len());
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        let (r0, rl) = rows[s];
        for (w, (wr, wl)) in chunk(rl, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let mut ops = Vec::new();
            // Halo taps are misaligned across rows, so image reads stay
            // scalar (heavy L1 reuse); outputs pack into coalesced stores.
            for i in r0 + wr..r0 + wr + wl {
                for (oaddr, o0, nn) in vec_chunks(&out, i * n, n) {
                    for lane in 0..nn as usize {
                        let j = o0 - i * n + lane;
                        ops.push(CuOp::MovImm { dst: 3, imm: 0.0 });
                        for di in 0..3usize {
                            for dj in 0..3usize {
                                let (ii, jj) = (i + di, j + dj);
                                // zero padding: skip out-of-bounds taps
                                if ii == 0 || jj == 0 || ii > n || jj > n {
                                    continue;
                                }
                                let (ii, jj) = (ii - 1, jj - 1);
                                ops.push(CuOp::Ld { reg: 0, addr: img.addr_of(ii * n + jj) });
                                ops.push(CuOp::Ld { reg: 1, addr: k.addr_of(di * 3 + dj) });
                                ops.push(CuOp::Mul { dst: 2, a: 0, b: 1 });
                                ops.push(CuOp::Add { dst: 3, a: 3, b: 2 });
                            }
                        }
                        ops.push(CuOp::Pack { dst: 5, lane: lane as u8, src: 3 });
                    }
                    ops.push(CuOp::StV { addr: oaddr, reg: 5, n: nn });
                }
            }
            work[gpu as usize][cu][w] = ops;
        }
    }

    let mut checks = vec![Verify::Rust {
        inputs: vec![img.clone(), k.clone()],
        outputs: vec![out.clone()],
        golden: Box::new(move |ins| {
            let (img, k) = (&ins[0], &ins[1]);
            let mut out = vec![0.0f32; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for di in 0..3usize {
                        for dj in 0..3usize {
                            let (ii, jj) = (i + di, j + dj);
                            if ii == 0 || jj == 0 || ii > n || jj > n {
                                continue;
                            }
                            acc += img[(ii - 1) * n + (jj - 1)] * k[di * 3 + dj];
                        }
                    }
                    out[i * n + j] = acc;
                }
            }
            vec![out]
        }),
        tol: 1e-4,
    }];
    if n == 256 {
        checks.push(Verify::Artifact {
            artifact: "conv3x3_256".into(),
            inputs: vec![img.clone(), k.clone()],
            outputs: vec![out.clone()],
            tol: 1e-4,
        });
    }

    Workload {
        name: "conv".into(),
        init,
        phases: vec![Phase { name: "conv3x3".into(), work }],
        checks,
        kind: "Memory",
    }
}

/// 2x2 max-pooling (DNNMark `mp`) — *compute-tagged* in Table 3 (pooling
/// layers sit between heavy compute; modelled with a per-output delay).
pub fn maxpool(p: &WorkloadParams) -> Workload {
    let n = p.scaled(256, 32); // input is n x n, output (n/2) x (n/2)
    let on = n / 2;
    let own = owners(p);
    let mut alloc = Alloc::new(&p.map);
    let input = Array::contiguous("in", alloc.on_gpu(0, n * n), n * n);
    let output = Array::contiguous("out", alloc.on_gpu(0, on * on), on * on);

    let mut rng = Rng(0x3B);
    let iv = rng.vec_f32(n * n);
    let init = init_of(&input, &iv);

    let mut work = empty_work(p);
    let rows = chunk(on, own.len());
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        let (r0, rl) = rows[s];
        for (w, (wr, wl)) in chunk(rl, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let mut ops = Vec::new();
            // 2x2 windows read even/odd lane pairs — scalar reads (L1-hot),
            // packed coalesced output stores.
            for oi in r0 + wr..r0 + wr + wl {
                for (oaddr, o0, nn) in vec_chunks(&output, oi * on, on) {
                    for lane in 0..nn as usize {
                        let oj = o0 - oi * on + lane;
                        let (i, j) = (2 * oi, 2 * oj);
                        ops.push(CuOp::Ld { reg: 0, addr: input.addr_of(i * n + j) });
                        ops.push(CuOp::Ld { reg: 1, addr: input.addr_of(i * n + j + 1) });
                        ops.push(CuOp::Max { dst: 0, a: 0, b: 1 });
                        ops.push(CuOp::Ld { reg: 1, addr: input.addr_of((i + 1) * n + j) });
                        ops.push(CuOp::Max { dst: 0, a: 0, b: 1 });
                        ops.push(CuOp::Ld { reg: 1, addr: input.addr_of((i + 1) * n + j + 1) });
                        ops.push(CuOp::Max { dst: 0, a: 0, b: 1 });
                        ops.push(CuOp::Delay { cycles: 20 });
                        ops.push(CuOp::Pack { dst: 5, lane: lane as u8, src: 0 });
                    }
                    ops.push(CuOp::StV { addr: oaddr, reg: 5, n: nn });
                }
            }
            work[gpu as usize][cu][w] = ops;
        }
    }

    let mut checks = vec![Verify::Rust {
        inputs: vec![input.clone()],
        outputs: vec![output.clone()],
        golden: Box::new(move |ins| {
            let x = &ins[0];
            let mut out = vec![0.0f32; on * on];
            for oi in 0..on {
                for oj in 0..on {
                    let (i, j) = (2 * oi, 2 * oj);
                    out[oi * on + oj] = x[i * n + j]
                        .max(x[i * n + j + 1])
                        .max(x[(i + 1) * n + j])
                        .max(x[(i + 1) * n + j + 1]);
                }
            }
            vec![out]
        }),
        tol: 0.0,
    }];
    if n == 256 {
        checks.push(Verify::Artifact {
            artifact: "maxpool_256".into(),
            inputs: vec![input.clone()],
            outputs: vec![output.clone()],
            tol: 0.0,
        });
    }

    Workload {
        name: "mp".into(),
        init,
        phases: vec![Phase { name: "maxpool".into(), work }],
        checks,
        kind: "Compute",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;

    fn params() -> WorkloadParams {
        WorkloadParams {
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, 2, 2, 2, 64 << 20),
            scale: 0.25,
        }
    }

    #[test]
    fn conv_golden_identity_kernel() {
        let w = conv3x3(&params());
        let n = 64usize;
        match &w.checks[0] {
            Verify::Rust { golden, .. } => {
                let img: Vec<f32> = (0..n * n).map(|i| i as f32).collect();
                let mut k = vec![0.0f32; 9];
                k[4] = 1.0; // center tap = identity
                let out = golden(&[img.clone(), k]);
                assert_eq!(out[0], img);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn maxpool_output_quarter_size() {
        let w = maxpool(&params());
        match &w.checks[0] {
            Verify::Rust { inputs, outputs, .. } => {
                assert_eq!(outputs[0].len() * 4, inputs[0].len());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn conv_interior_output_reads_nine_taps() {
        let w = conv3x3(&params());
        // Count Lds between consecutive MovImm markers for an interior row.
        let ops = &w.phases[0].work[0][0][1]; // second wavefront: interior
        let mut counts = vec![];
        let mut cur = 0;
        for op in ops.iter() {
            match op {
                CuOp::MovImm { .. } => {
                    if cur > 0 {
                        counts.push(cur);
                    }
                    cur = 0;
                }
                CuOp::Ld { .. } => cur += 1,
                _ => {}
            }
        }
        // Interior outputs read 9 image taps + 9 kernel taps = 18 loads.
        assert!(counts.iter().any(|&c| c == 18), "counts: {counts:?}");
    }
}
