//! Irregular-access workload models: `bfs` (SHOC) and `fws`
//! (Floyd-Warshall, AMDAPPSDK) — Table 3's graph workloads.

use crate::gpu::CuOp;
use crate::workloads::elementwise::init_of;
use crate::workloads::{
    chunk, empty_work, owners, vec_chunks, Alloc, Array, Phase, Rng, Verify, Workload,
    WorkloadParams,
};

/// BFS stand-in — "graph gather": `out[i] = sum_k in[nbr(i,k)]` over a
/// synthetic degree-4 random graph.
///
/// Substitution note: real BFS needs data-dependent control flow our
/// register machine deliberately omits; what the coherence protocol sees —
/// the *irregular, low-locality read stream* of frontier expansion — is
/// preserved exactly (SHOC's bfs is dominated by random neighbour reads).
/// The neighbour table is generated deterministically, so a Rust golden
/// recomputes the same gather.
pub fn bfs_gather(p: &WorkloadParams) -> Workload {
    const DEG: usize = 4;
    let own = owners(p);
    let q = own.len() * p.wavefronts_per_cu as usize;
    let n = p.scaled(32768, q);
    let mut alloc = Alloc::new(&p.map);
    let levels = alloc.partitioned("levels", n, &own);
    let out = alloc.partitioned("out", n, &own);

    let mut rng = Rng(0xBF5);
    let lv = rng.vec_f32(n);
    let init = init_of(&levels, &lv);

    // Deterministic random neighbours.
    let mut nbr_rng = Rng(0x6E1);
    let nbrs: Vec<usize> =
        (0..n * DEG).map(|_| nbr_rng.below(n as u64) as usize).collect();

    let per = n / own.len();
    let mut work = empty_work(p);
    for (s, &(gpu, cu)) in own.iter().enumerate() {
        for (w, (ws, wl)) in chunk(per, p.wavefronts_per_cu as usize).into_iter().enumerate() {
            let start = s * per + ws;
            let mut ops = Vec::new();
            // Gather reads are inherently uncoalesced (the point of the
            // workload); only the output stores coalesce.
            for (oaddr, i0, nn) in vec_chunks(&out, start, wl) {
                for lane in 0..nn as usize {
                    let i = i0 + lane;
                    ops.push(CuOp::MovImm { dst: 3, imm: 0.0 });
                    for k in 0..DEG {
                        ops.push(CuOp::Ld { reg: 0, addr: levels.addr_of(nbrs[i * DEG + k]) });
                        ops.push(CuOp::Add { dst: 3, a: 3, b: 0 });
                    }
                    ops.push(CuOp::Pack { dst: 5, lane: lane as u8, src: 3 });
                }
                ops.push(CuOp::StV { addr: oaddr, reg: 5, n: nn });
            }
            work[gpu as usize][cu][w] = ops;
        }
    }

    let nb = nbrs.clone();
    Workload {
        name: "bfs".into(),
        init,
        phases: vec![Phase { name: "gather".into(), work }],
        checks: vec![Verify::Rust {
            inputs: vec![levels.clone()],
            outputs: vec![out.clone()],
            golden: Box::new(move |ins| {
                let lv = &ins[0];
                let n = lv.len();
                let mut o = vec![0.0f32; n];
                for (i, oi) in o.iter_mut().enumerate() {
                    for k in 0..DEG {
                        *oi += lv[nb[i * DEG + k]];
                    }
                }
                vec![o]
            }),
            tol: 1e-5,
        }],
        kind: "Memory",
    }
}

/// Floyd-Warshall all-pairs shortest paths — n kernel launches with heavy
/// read-sharing: in iteration k, *every* CU reads row k and column k.
///
/// Weights are non-negative, so within-iteration in-place updates are
/// benign (row/column k are fixed points of iteration k) — the standard
/// GPU formulation.
pub fn floyd_warshall(p: &WorkloadParams) -> Workload {
    let n = p.scaled(96, 16);
    let own = owners(p);
    let mut alloc = Alloc::new(&p.map);
    let d = Array::contiguous("d", alloc.on_gpu(0, n * n), n * n);

    // Non-negative weights in [0, 1).
    let mut rng = Rng(0xF5);
    let dv: Vec<f32> = (0..n * n).map(|_| (rng.next_f32() + 1.0) / 2.0).collect();
    let init = init_of(&d, &dv);

    let rows = chunk(n, own.len());
    let mut phases = Vec::new();
    for k in 0..n {
        let mut work = empty_work(p);
        for (s, &(gpu, cu)) in own.iter().enumerate() {
            let (r0, rl) = rows[s];
            for (w, (wr, wl)) in
                chunk(rl, p.wavefronts_per_cu as usize).into_iter().enumerate()
            {
                let mut ops = Vec::new();
                // Lanes over j: row i and the shared row k stream
                // coalesced; d[i,k] broadcasts.
                for i in r0 + wr..r0 + wr + wl {
                    for (daddr, d0, nn) in vec_chunks(&d, i * n, n) {
                        let j0 = d0 - i * n;
                        ops.push(CuOp::LdV { reg: 0, addr: daddr, n: nn });
                        ops.push(CuOp::Ld { reg: 1, addr: d.addr_of(i * n + k) });
                        ops.push(CuOp::LdV { reg: 2, addr: d.addr_of(k * n + j0), n: nn });
                        ops.push(CuOp::Add { dst: 3, a: 1, b: 2 });
                        ops.push(CuOp::Min { dst: 4, a: 0, b: 3 });
                        ops.push(CuOp::StV { addr: daddr, reg: 4, n: nn });
                    }
                }
                work[gpu as usize][cu][w] = ops;
            }
        }
        phases.push(Phase { name: format!("k={k}"), work });
    }

    Workload {
        name: "fws".into(),
        init,
        phases,
        checks: vec![Verify::Rust {
            inputs: vec![d.clone()],
            outputs: vec![d.clone()],
            golden: Box::new(move |ins| {
                let mut d = ins[0].clone();
                for k in 0..n {
                    for i in 0..n {
                        for j in 0..n {
                            let via = d[i * n + k] + d[k * n + j];
                            if via < d[i * n + j] {
                                d[i * n + j] = via;
                            }
                        }
                    }
                }
                vec![d]
            }),
            tol: 1e-5,
        }],
        kind: "Memory",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;

    fn params() -> WorkloadParams {
        WorkloadParams {
            n_gpus: 2,
            cus_per_gpu: 2,
            wavefronts_per_cu: 2,
            map: AddrMap::new(Topology::SharedMem, 2, 2, 2, 64 << 20),
            scale: 0.25,
        }
    }

    #[test]
    fn fws_has_n_phases() {
        let w = floyd_warshall(&params());
        assert_eq!(w.phases.len(), 32); // scale 0.25 of 96, rounded up to quantum 16
    }

    #[test]
    fn fws_golden_triangle() {
        let w = floyd_warshall(&params());
        match &w.checks[0] {
            Verify::Rust { golden, .. } => {
                // n=32 matrix (scale 0.25 of 96, quantum-rounded) where the
                // direct path 0->1 is long but 0->2->1 is short.
                let n = 32;
                let mut d = vec![10.0f32; n * n];
                for i in 0..n {
                    d[i * n + i] = 0.0;
                }
                d[1] = 9.0; // 0 -> 1 direct
                d[2] = 1.0; // 0 -> 2
                d[2 * n + 1] = 1.0; // 2 -> 1
                let out = golden(&[d]);
                assert_eq!(out[0][1], 2.0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn bfs_reads_are_irregular() {
        let w = bfs_gather(&params());
        // Consecutive neighbour loads must not be sequential addresses.
        let ops = &w.phases[0].work[0][0][0];
        let lds: Vec<u64> = ops
            .iter()
            .filter_map(|op| match op {
                CuOp::Ld { addr, .. } => Some(*addr),
                _ => None,
            })
            .take(16)
            .collect();
        let sequential = lds.windows(2).filter(|w| w[1] == w[0] + 4).count();
        assert!(sequential < lds.len() / 2, "reads should be scattered");
    }
}
