//! Shared timestamp-protocol machinery (docs/PROTOCOLS.md).
//!
//! HALCONE, Tardis and HLC follow one skeleton — leases granted by the
//! memory-side TSU, per-cache logical clocks advanced by responses,
//! self-invalidation on lease expiry, finite-width `ts_bits` epoch
//! rollovers — and differ only in how the TSU stamps a line and how a
//! cache folds a response's timestamp pair into its clock. This module
//! carries that variation as data ([`TsPolicy`]), so the HALCONE L1/L2
//! controllers and the TSU serve every timestamp protocol from one
//! implementation instead of three parallel stacks.

use crate::coherence::TsMeta;
use crate::sim::msg::TsPair;
use crate::sim::Cycle;

/// Which timestamp protocol a controller/TSU instance speaks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TsPolicy {
    /// The paper's protocol: the TSU's `memts` advances on every access
    /// and the cache merge bumps `rts` past the response `wts`, so a
    /// validity check is a plain `cts <= rts`.
    #[default]
    Halcone,
    /// Tardis-style leases (arXiv 1501.04504): each line keeps a *stable*
    /// write timestamp; reads extend the read frontier (`rts`) without
    /// moving `wts`, writes jump `wts` past the frontier. No
    /// invalidation traffic — an expired lease simply re-fetches, which
    /// renews it at the owning TSU.
    Tardis,
    /// Hybrid logical clocks: the TSU's `memts` and every cache clock are
    /// floored by coarse physical time (`now >> HLC_SHIFT`), so leases
    /// are expressed in hybrid time and the logical/physical skew stays
    /// bounded by one lease plus one physical tick.
    Hlc,
}

impl TsPolicy {
    /// Canonical protocol name (config value, artifact labels).
    pub fn name(&self) -> &'static str {
        match self {
            TsPolicy::Halcone => "halcone",
            TsPolicy::Tardis => "tardis",
            TsPolicy::Hlc => "hlc",
        }
    }
}

/// Every valid `coherence` config value, in presentation order. The
/// unknown-value error lists these (mirroring the workloads registry);
/// `gtsc` is HALCONE plus the G-TSC warpts wire ablation.
pub const PROTOCOL_NAMES: [&str; 6] = ["none", "halcone", "gtsc", "hmg", "tardis", "hlc"];

/// Physical-time granularity of the HLC protocol: one hybrid tick per
/// `1 << HLC_SHIFT` simulated cycles. Coarse enough that the logical
/// component does the fine ordering, fine enough to bound skew.
pub const HLC_SHIFT: u32 = 8;

/// The physical component of a hybrid timestamp at simulated time `now`.
/// Deterministic by construction: simulated time is identical at every
/// `--shards`/`--jobs` level.
pub fn hlc_phys(now: Cycle) -> u64 {
    now >> HLC_SHIFT
}

/// Fold a response's TSU timestamp pair into a cache's view of the line.
pub fn merge_ts(policy: TsPolicy, cts: u64, rsp: TsPair) -> TsMeta {
    match policy {
        // Paper Alg. 2: wts catches the cache clock up; rts always lands
        // strictly past the write, so the filling cache's own check
        // (`cts <= rts` after advancing to wts) is satisfiable.
        TsPolicy::Halcone | TsPolicy::Hlc => {
            TsMeta { wts: cts.max(rsp.wts), rts: (rsp.wts + 1).max(rsp.rts) }
        }
        // Tardis keeps the TSU's stamps verbatim: wts is the line's
        // stable version, rts the granted lease end (>= wts always).
        TsPolicy::Tardis => TsMeta { wts: rsp.wts, rts: rsp.rts },
    }
}

/// Advance a logical clock to `to`, reporting whether the move crossed a
/// finite-width epoch boundary (`ts_bits` rollover, docs/ROBUSTNESS.md).
/// On `true` the caller must flush its cache array and count the flush;
/// `ts_bits == 0` (infinite-width counters) never crosses.
pub fn clock_advance(cts: &mut u64, to: u64, ts_bits: u32) -> bool {
    if to <= *cts {
        return false;
    }
    let crossed = ts_bits > 0
        && crate::faults::epoch_of(to, ts_bits) != crate::faults::epoch_of(*cts, ts_bits);
    *cts = to;
    crossed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halcone_merge_bumps_rts_past_the_write() {
        let m = merge_ts(TsPolicy::Halcone, 5, TsPair { rts: 3, wts: 7 });
        assert_eq!(m, TsMeta { wts: 7, rts: 8 });
        // A stale response never drags the clock backwards.
        let m = merge_ts(TsPolicy::Halcone, 9, TsPair { rts: 3, wts: 2 });
        assert_eq!(m, TsMeta { wts: 9, rts: 3 });
    }

    #[test]
    fn tardis_merge_is_verbatim() {
        let m = merge_ts(TsPolicy::Tardis, 99, TsPair { rts: 12, wts: 4 });
        assert_eq!(m, TsMeta { wts: 4, rts: 12 });
    }

    #[test]
    fn hlc_merge_matches_halcone_shape() {
        assert_eq!(
            merge_ts(TsPolicy::Hlc, 5, TsPair { rts: 3, wts: 7 }),
            merge_ts(TsPolicy::Halcone, 5, TsPair { rts: 3, wts: 7 }),
        );
    }

    #[test]
    fn hlc_phys_is_coarse_monotonic() {
        assert_eq!(hlc_phys(0), 0);
        assert_eq!(hlc_phys((1 << HLC_SHIFT) - 1), 0);
        assert_eq!(hlc_phys(1 << HLC_SHIFT), 1);
        assert!(hlc_phys(10_000) <= 10_000 >> HLC_SHIFT);
    }

    #[test]
    fn clock_advance_reports_epoch_crossings() {
        let mut cts = 0;
        assert!(!clock_advance(&mut cts, 10, 0)); // infinite width
        assert_eq!(cts, 10);
        assert!(!clock_advance(&mut cts, 5, 4)); // no retreat
        assert_eq!(cts, 10);
        assert!(!clock_advance(&mut cts, 15, 4)); // same 16-cycle epoch
        assert!(clock_advance(&mut cts, 16, 4)); // epoch 0 -> 1
        assert_eq!(cts, 16);
        assert!(clock_advance(&mut cts, 48, 4)); // multi-epoch jump
    }

    #[test]
    fn protocol_names_cover_every_policy() {
        for p in [TsPolicy::Halcone, TsPolicy::Tardis, TsPolicy::Hlc] {
            assert!(PROTOCOL_NAMES.contains(&p.name()));
        }
    }
}
