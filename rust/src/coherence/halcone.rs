//! The HALCONE protocol controllers (paper §3.2, Algorithms 1–5).
//!
//! Key mechanics, as implemented:
//!
//! * Each L1\$ and each L2\$ bank owns a logical clock `cts`. A resident
//!   block is *valid* iff `cts <= rts(block)` (Alg. 1/2 hit condition); a
//!   tag match with an expired lease is a **coherency miss** and re-fetches
//!   from the level below with fresh timestamps — unlike G-TSC there is no
//!   wts-match lease-extension shortcut, which is what removes the CU-level
//!   timestamp (`warpts`) from every request.
//! * Writes are write-through at both levels; L1 is no-write-allocate
//!   (§2.2), L2 allocates on write (Alg. 5 `WriteBlockToCache`). A written
//!   block is **locked** (MSHR `WriteLock`) from the local write until the
//!   level below returns timestamps (Alg. 4/5); accesses arriving in the
//!   window queue behind the lock and replay in order.
//! * On a fill/write response carrying `(Mrts, Mwts)`:
//!   `Bwts = max(cts, Mwts)`, `Brts = max(Mwts + 1, Mrts)`; **writes**
//!   additionally advance the clock, `cts = max(cts, Bwts)`. Reads do not
//!   advance `cts` (Alg. 1/2).
//! * Timestamps originate at the per-stack TSU (`tsu::Tsu`), which advances
//!   the block's `memts` by RdLease/WrLease per access (Alg. 3).
//! * Kernel-boundary fences advance `cts` to `logical_max + 1` computed by
//!   the driver over all caches' clocks (DESIGN.md §6): every stale copy's
//!   lease provably expires, while untouched data at worst re-fetches.
//!
//! The `carry_warpts` flag reproduces G-TSC-style CU-level-timestamp
//! traffic for the E10 ablation (affects wire bytes only).
//!
//! §Perf: all payloads are inline [`LineBuf`]s, `MemReq`/`MemRsp` boxes
//! recycle through the engine pool (`ctx.req_msg`/`ctx.reclaim_req`), and
//! line bytes live in the cache array's flat backing — the memory-
//! transaction path allocates nothing in steady state (see docs/PERF.md).

use crate::coherence::tsproto::{self, TsPolicy};
use crate::coherence::{L1Routes, L2Routes, TsMeta};
use crate::mem::cache::{CacheArray, CacheParams};
use crate::mem::fxhash::FxHashMap;
use crate::mem::mshr::{Mshr, MshrKind};
use crate::mem::LineBuf;
use crate::metrics::CacheCtrlStats;
use crate::sim::msg::{MemReq, MemRsp, TsPair};
use crate::sim::{CompId, Component, Ctx, Cycle, Msg, ReqKind};

/// Snapshot serializers for the per-line timestamp metadata
/// (docs/SNAPSHOT.md).
pub(crate) fn put_ts_meta(m: &TsMeta, out: &mut Vec<u8>) {
    crate::snapshot::format::put(out, m.wts);
    crate::snapshot::format::put(out, m.rts);
}

pub(crate) fn read_ts_meta(cur: &mut crate::snapshot::format::Cur) -> Result<TsMeta, String> {
    Ok(TsMeta { wts: cur.u64("line wts")?, rts: cur.u64("line rts")? })
}

/// Per-CU private L1 vector cache controller.
pub struct HalconeL1 {
    name: String,
    routes: L1Routes,
    cache: CacheArray<TsMeta>,
    mshr: Mshr,
    /// The cache's logical clock (replaces G-TSC's per-CU warpts).
    pub cts: u64,
    /// Hit/lookup latency in cycles.
    lat: Cycle,
    /// G-TSC ablation: carry a CU-level timestamp in every request.
    carry_warpts: bool,
    /// Write-combining buffer: same-line writes arriving while the line is
    /// write-locked coalesce here and flush as one combined write at
    /// unlock. Their CU acks are withheld until the flush lands (so phase
    /// completion implies durability at the level below).
    coalesce: FxHashMap<u64, Vec<(u64, LineBuf)>>,
    /// Coalesced requests awaiting their flush's completion.
    pending_acks: FxHashMap<u64, Vec<MemReq>>,
    pub stats: CacheCtrlStats,
    /// Per-tenant mirror of the CU-request hit/miss/coherency-miss bumps
    /// (mix runs; single-tenant traffic lands in slot 0).
    pub tstats: crate::metrics::tenancy::TenantTraffic,
    line: u64,
    /// Finite timestamp width (docs/ROBUSTNESS.md); 0 = unbounded.
    ts_bits: u32,
    /// Conservative full flushes forced by `cts` epoch crossings.
    pub rollover_flushes: u64,
    /// Which timestamp protocol this controller speaks (docs/PROTOCOLS.md).
    policy: TsPolicy,
}

/// Merge buffered (addr, bytes) writes into maximal contiguous runs.
/// All entries target one cache line, so a run never exceeds line size.
pub(crate) fn coalesce_runs(mut buf: Vec<(u64, LineBuf)>) -> Vec<(u64, LineBuf)> {
    buf.sort_by_key(|(a, _)| *a);
    let mut runs: Vec<(u64, LineBuf)> = Vec::new();
    for (addr, bytes) in buf {
        match runs.last_mut() {
            Some((ra, rb)) if *ra + rb.len() as u64 == addr => rb.extend_from_slice(&bytes),
            Some((ra, rb)) if addr < *ra + rb.len() as u64 => {
                // Overwrite within the run (later write wins).
                let off = (addr - *ra) as usize;
                let end = off + bytes.len();
                if end > rb.len() {
                    rb.resize(end, 0);
                }
                rb[off..end].copy_from_slice(&bytes);
            }
            _ => runs.push((addr, bytes)),
        }
    }
    runs
}

impl HalconeL1 {
    pub fn new(
        name: impl Into<String>,
        routes: L1Routes,
        params: CacheParams,
        mshr_entries: usize,
        lat: Cycle,
        carry_warpts: bool,
    ) -> Self {
        let line = params.line;
        HalconeL1 {
            name: name.into(),
            routes,
            cache: CacheArray::new(params),
            mshr: Mshr::new(mshr_entries),
            cts: 0,
            lat,
            carry_warpts,
            coalesce: FxHashMap::default(),
            pending_acks: FxHashMap::default(),
            stats: CacheCtrlStats::default(),
            tstats: crate::metrics::tenancy::TenantTraffic::default(),
            line,
            ts_bits: 0,
            rollover_flushes: 0,
            policy: TsPolicy::Halcone,
        }
    }

    /// Select the timestamp protocol (builder-style; default HALCONE).
    pub fn with_policy(mut self, policy: TsPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Enable the finite-width timestamp model (see
    /// [`HalconeL1::advance_cts`]).
    pub fn set_ts_bits(&mut self, bits: u32) {
        self.ts_bits = bits;
    }

    /// Advance the cache clock. Under an N-bit counter, crossing a 2^N
    /// epoch boundary conservatively flushes the whole array — every
    /// timestamp protocol here is write-through, so every resident line
    /// is clean and the flush can never lose data, only force refetches.
    /// Timestamps stay monotonic `u64`s so cross-epoch comparisons remain
    /// well-defined while the rollover's perf cost is charged.
    fn advance_cts(&mut self, to: u64) {
        if tsproto::clock_advance(&mut self.cts, to, self.ts_bits) {
            self.cache.clear();
            self.rollover_flushes += 1;
        }
    }

    /// Tardis/HLC: a read observes the line's version, so the cache clock
    /// must catch up to its write timestamp before ordering later
    /// accesses. HALCONE reads leave `cts` untouched (Alg. 1) — its merge
    /// already lifts `rts` past the clock instead.
    fn observe_read(&mut self, line_wts: u64) {
        if self.policy != TsPolicy::Halcone {
            self.advance_cts(line_wts);
        }
    }

    fn line_base(&self, addr: u64) -> u64 {
        addr & !(self.line - 1)
    }

    fn respond_word(&mut self, req: &MemReq, line_data: &[u8], ctx: &mut Ctx) {
        let off = (req.addr - self.line_base(req.addr)) as usize;
        let data = LineBuf::from_slice(&line_data[off..off + req.size as usize]);
        self.respond_sliced(req, data, ctx);
    }

    /// Respond with already-sliced payload bytes.
    fn respond_sliced(&mut self, req: &MemReq, data: LineBuf, ctx: &mut Ctx) {
        let rsp = MemRsp {
            id: req.id,
            kind: ReqKind::Read,
            addr: req.addr,
            dst: req.src,
            data,
            ts: None,
        };
        self.stats.rsps_out += 1;
        let msg = ctx.rsp_msg(rsp);
        ctx.schedule(self.lat, req.src, msg);
    }

    fn respond_write_ack(&mut self, req: &MemReq, ctx: &mut Ctx) {
        let rsp = MemRsp {
            id: req.id,
            kind: ReqKind::Write,
            addr: req.addr,
            dst: req.src,
            data: LineBuf::empty(),
            ts: None,
        };
        self.stats.rsps_out += 1;
        let msg = ctx.rsp_msg(rsp);
        ctx.schedule(self.lat, req.src, msg);
    }

    fn send_down(&mut self, down: MemReq, ctx: &mut Ctx) {
        let (link, next, _) = self.routes.route(down.addr);
        self.stats.reqs_down += 1;
        self.stats.bytes_down += down.wire_bytes();
        let bytes = down.wire_bytes();
        let msg = ctx.req_msg(down);
        ctx.send(link, next, bytes, msg);
    }

    fn on_cu_req(&mut self, now: Cycle, req: MemReq, ctx: &mut Ctx) {
        // HLC: the cache clock is floored by coarse physical time, so
        // leases expire in hybrid time even on an idle clock.
        if self.policy == TsPolicy::Hlc {
            self.advance_cts(tsproto::hlc_phys(now));
        }
        let la = self.line_base(req.addr);
        if let Some(entry) = self.mshr.get(la) {
            // Write arriving while the line is write-locked: coalesce into
            // the combining buffer; ack once the combined flush lands.
            if entry.kind == MshrKind::WriteLock && req.kind == ReqKind::Write {
                if let Some(line) = self.cache.lookup(req.addr) {
                    let off = (req.addr - la) as usize;
                    line.data[off..off + req.data.len()].copy_from_slice(&req.data);
                }
                self.coalesce.entry(la).or_default().push((req.addr, req.data));
                self.pending_acks.entry(la).or_default().push(req);
                return;
            }
            // Otherwise queue behind the in-flight entry.
            self.stats.mshr_merges += 1;
            self.mshr.merge(la, req);
            return;
        }
        match req.kind {
            ReqKind::Read => {
                let cts = self.cts;
                let off = (req.addr - la) as usize;
                let mut hit_data = None;
                if let Some(line) = self.cache.lookup(req.addr) {
                    if cts <= line.meta.rts {
                        // Copy only the requested bytes (hits are the
                        // hottest path; cloning whole lines showed in perf).
                        // The wts copy rides along for the Tardis/HLC
                        // clock catch-up below (the borrow ends here and
                        // `advance_cts` may flush the array).
                        hit_data = Some((
                            LineBuf::from_slice(&line.data[off..off + req.size as usize]),
                            line.meta.wts,
                        ));
                    } else {
                        // Tag hit, lease expired: coherency miss (Alg. 1).
                        self.stats.coherency_misses += 1;
                        self.tstats.slot(req.tenant).coherency_misses += 1;
                    }
                } else {
                    self.stats.misses += 1;
                    self.tstats.slot(req.tenant).misses += 1;
                }
                if let Some((data, line_wts)) = hit_data {
                    self.cache.record(true);
                    self.stats.hits += 1;
                    self.tstats.slot(req.tenant).hits += 1;
                    self.observe_read(line_wts);
                    self.respond_sliced(&req, data, ctx);
                    return;
                }
                self.cache.record(false);
                let fill = MemReq {
                    id: req.id,
                    kind: ReqKind::Read,
                    addr: la,
                    size: self.line as u32,
                    src: ctx.self_id,
                    dst: self.routes.route(la).2,
                    data: LineBuf::empty(),
                    warpts: self.carry_warpts.then_some(self.cts),
                    tenant: req.tenant,
                };
                self.mshr.allocate(la, MshrKind::Fill, req);
                self.send_down(fill, ctx);
            }
            ReqKind::Write => {
                // WT + no-write-allocate: forward the word regardless;
                // update the local copy only on a lease-valid hit (Alg. 4).
                let cts = self.cts;
                let mut hit = false;
                let mut expired = false;
                if let Some(line) = self.cache.lookup(req.addr) {
                    if cts <= line.meta.rts {
                        hit = true;
                        let off = (req.addr - la) as usize;
                        line.data[off..off + req.data.len()].copy_from_slice(&req.data);
                    } else {
                        expired = true;
                    }
                }
                if expired {
                    // Tag match with an expired lease: the resident data is
                    // stale and no-write-allocate will not refresh it — drop
                    // it so the retire path cannot revalidate stale bytes.
                    self.cache.invalidate(la);
                    self.stats.coherency_misses += 1;
                    self.tstats.slot(req.tenant).coherency_misses += 1;
                }
                self.cache.record(hit);
                if hit {
                    self.stats.hits += 1;
                    self.tstats.slot(req.tenant).hits += 1;
                } else {
                    self.stats.misses += 1;
                    self.tstats.slot(req.tenant).misses += 1;
                }
                let down = MemReq {
                    id: req.id,
                    kind: ReqKind::Write,
                    addr: req.addr,
                    size: req.size,
                    src: ctx.self_id,
                    dst: self.routes.route(req.addr).2,
                    data: req.data,
                    warpts: self.carry_warpts.then_some(self.cts),
                    tenant: req.tenant,
                };
                // Lock the block until timestamps return (Alg. 4).
                self.mshr.allocate(la, MshrKind::WriteLock, req);
                self.send_down(down, ctx);
            }
        }
    }

    fn on_down_rsp(&mut self, now: Cycle, rsp: MemRsp, ctx: &mut Ctx) {
        self.stats.rsps_down += 1;
        let la = self.line_base(rsp.addr);
        let entry = self.mshr.retire(la);
        let ts = rsp.ts.expect("timestamp-protocol response must carry timestamps");
        let meta = tsproto::merge_ts(self.policy, self.cts, ts);
        match entry.kind {
            MshrKind::Fill => {
                debug_assert_eq!(rsp.data.len() as u64, self.line);
                // Clean insert (WT lines are never dirty); evictions drop.
                self.cache.insert(la, &rsp.data, false, meta);
                self.observe_read(meta.wts);
                self.respond_word(&entry.primary, &rsp.data, ctx);
            }
            MshrKind::WriteLock => {
                if let Some(line) = self.cache.lookup(la) {
                    *line.meta = meta;
                }
                // Writes advance the cache's clock (Alg. 4).
                self.advance_cts(meta.wts);
                let primary = entry.primary;
                if primary.src != CompId::NONE {
                    self.respond_write_ack(&primary, ctx);
                }
                // Flush one coalesced run, re-locking the line; queued
                // waiters re-merge behind it so ordering is preserved.
                if let Some(buf) = self.coalesce.remove(&la) {
                    let mut runs = coalesce_runs(buf);
                    let (addr, data) = runs.remove(0);
                    if !runs.is_empty() {
                        // Fragmented runs flush back-to-back.
                        self.coalesce.insert(la, runs);
                    }
                    let down = MemReq {
                        id: crate::coherence::FLUSH_REQ_ID,
                        kind: ReqKind::Write,
                        addr,
                        size: data.len() as u32,
                        src: ctx.self_id,
                        dst: self.routes.route(addr).2,
                        data,
                        warpts: self.carry_warpts.then_some(self.cts),
                        tenant: primary.tenant,
                    };
                    let synthetic = MemReq { src: CompId::NONE, ..down };
                    self.mshr.allocate(la, MshrKind::WriteLock, synthetic);
                    for w in entry.waiters {
                        self.mshr.merge(la, w);
                    }
                    self.send_down(down, ctx);
                    return;
                }
                // No further flushes: release the held coalesced acks.
                if let Some(acks) = self.pending_acks.remove(&la) {
                    for r in acks {
                        self.respond_write_ack(&r, ctx);
                    }
                }
            }
        }
        for w in entry.waiters {
            self.on_cu_req(now, w, ctx);
        }
    }
}

impl Component for HalconeL1 {
    crate::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match msg {
            Msg::Req(req) => {
                self.stats.reqs_in += 1;
                let req = ctx.reclaim_req(req);
                self.on_cu_req(now, req, ctx);
            }
            Msg::Rsp(rsp) => {
                let rsp = ctx.reclaim_rsp(rsp);
                self.on_down_rsp(now, rsp, ctx);
            }
            Msg::FenceQuery { reply_to } => {
                let cts = self.cts;
                ctx.schedule(0, reply_to, Msg::FenceInfo { from: ctx.self_id, cts });
            }
            Msg::FenceApply { reply_to, logical_max } => {
                debug_assert!(self.mshr.is_empty(), "fence with in-flight requests");
                self.advance_cts(logical_max);
                ctx.schedule(0, reply_to, Msg::FenceDone { from: ctx.self_id });
            }
            other => panic!("{}: unexpected {:?}", self.name, other),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format as f;
        f::put(out, self.cts);
        f::put(out, self.rollover_flushes);
        self.cache.save_with(out, put_ts_meta);
        self.mshr.save_state(out);
        let mut keys: Vec<u64> = self.coalesce.keys().copied().collect();
        keys.sort_unstable();
        f::put(out, keys.len() as u64);
        for la in keys {
            f::put(out, la);
            let buf = &self.coalesce[&la];
            f::put(out, buf.len() as u64);
            for (addr, bytes) in buf {
                f::put(out, *addr);
                f::put_buf(out, bytes);
            }
        }
        let mut keys: Vec<u64> = self.pending_acks.keys().copied().collect();
        keys.sort_unstable();
        f::put(out, keys.len() as u64);
        for la in keys {
            f::put(out, la);
            let acks = &self.pending_acks[&la];
            f::put(out, acks.len() as u64);
            for r in acks {
                f::put_req(out, r);
            }
        }
        self.stats.save_state(out);
        self.tstats.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        use crate::snapshot::format as f;
        self.cts = cur.u64("l1 cts")?;
        self.rollover_flushes = cur.u64("l1 rollover_flushes")?;
        self.cache.load_with(cur, read_ts_meta)?;
        self.mshr.load_state(cur)?;
        let n = cur.u64("l1 coalesce count")? as usize;
        self.coalesce.clear();
        for _ in 0..n {
            let la = cur.u64("l1 coalesce line")?;
            let m = cur.u64("l1 coalesce run count")? as usize;
            if m > cur.b.len() {
                return Err(format!("coalesce run count {m} exceeds the input size"));
            }
            let mut buf = Vec::with_capacity(m);
            for _ in 0..m {
                let addr = cur.u64("l1 coalesce addr")?;
                buf.push((addr, f::read_buf(cur, "l1 coalesce bytes")?));
            }
            if self.coalesce.insert(la, buf).is_some() {
                return Err(format!("snapshot repeats coalesce line {la:#x}"));
            }
        }
        let n = cur.u64("l1 pending-ack count")? as usize;
        self.pending_acks.clear();
        for _ in 0..n {
            let la = cur.u64("l1 pending-ack line")?;
            let m = cur.u64("l1 pending-ack req count")? as usize;
            if m > cur.b.len() {
                return Err(format!("pending-ack req count {m} exceeds the input size"));
            }
            let mut acks = Vec::with_capacity(m);
            for _ in 0..m {
                acks.push(f::read_req(cur, "l1 pending ack")?);
            }
            if self.pending_acks.insert(la, acks).is_some() {
                return Err(format!("snapshot repeats pending-ack line {la:#x}"));
            }
        }
        self.stats.load_state(cur)?;
        self.tstats.load_state(cur)?;
        Ok(())
    }
}

/// One distributed shared L2 bank controller.
pub struct HalconeL2 {
    name: String,
    routes: L2Routes,
    cache: CacheArray<TsMeta>,
    mshr: Mshr,
    pub cts: u64,
    lat: Cycle,
    carry_warpts: bool,
    pub stats: CacheCtrlStats,
    line: u64,
    /// Finite timestamp width (docs/ROBUSTNESS.md); 0 = unbounded.
    ts_bits: u32,
    /// Conservative full flushes forced by `cts` epoch crossings.
    pub rollover_flushes: u64,
    /// Which timestamp protocol this controller speaks (docs/PROTOCOLS.md).
    policy: TsPolicy,
}

impl HalconeL2 {
    pub fn new(
        name: impl Into<String>,
        routes: L2Routes,
        params: CacheParams,
        mshr_entries: usize,
        lat: Cycle,
        carry_warpts: bool,
    ) -> Self {
        let line = params.line;
        HalconeL2 {
            name: name.into(),
            routes,
            cache: CacheArray::new(params),
            mshr: Mshr::new(mshr_entries),
            cts: 0,
            lat,
            carry_warpts,
            stats: CacheCtrlStats::default(),
            line,
            ts_bits: 0,
            rollover_flushes: 0,
            policy: TsPolicy::Halcone,
        }
    }

    /// Select the timestamp protocol (builder-style; default HALCONE).
    pub fn with_policy(mut self, policy: TsPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Enable the finite-width timestamp model (see
    /// [`HalconeL2::advance_cts`]).
    pub fn set_ts_bits(&mut self, bits: u32) {
        self.ts_bits = bits;
    }

    /// Advance the bank clock; under an N-bit counter an epoch crossing
    /// conservatively flushes the (write-through, all-clean) array —
    /// the same model as [`HalconeL1::advance_cts`].
    fn advance_cts(&mut self, to: u64) {
        if tsproto::clock_advance(&mut self.cts, to, self.ts_bits) {
            self.cache.clear();
            self.rollover_flushes += 1;
        }
    }

    /// Tardis/HLC read-side clock catch-up; see
    /// [`HalconeL1::observe_read`].
    fn observe_read(&mut self, line_wts: u64) {
        if self.policy != TsPolicy::Halcone {
            self.advance_cts(line_wts);
        }
    }

    fn line_base(&self, addr: u64) -> u64 {
        addr & !(self.line - 1)
    }

    fn respond_up(&mut self, req: &MemReq, data: LineBuf, meta: TsMeta, ctx: &mut Ctx) {
        let rsp = MemRsp {
            id: req.id,
            kind: req.kind,
            addr: req.addr,
            dst: req.src,
            data,
            ts: Some(TsPair { rts: meta.rts, wts: meta.wts }),
        };
        self.stats.rsps_out += 1;
        self.stats.bytes_up += rsp.wire_bytes();
        let (link, next) = self.routes.route_up(req.src);
        let bytes = rsp.wire_bytes();
        let msg = ctx.rsp_msg(rsp);
        ctx.send_delayed(self.lat, link, next, bytes, msg);
    }

    fn send_mm(&mut self, down: MemReq, ctx: &mut Ctx) {
        let (link, next, _) = self.routes.route_mm(down.addr);
        self.stats.reqs_down += 1;
        self.stats.bytes_down += down.wire_bytes();
        let bytes = down.wire_bytes();
        let msg = ctx.req_msg(down);
        ctx.send(link, next, bytes, msg);
    }

    fn on_l1_req(&mut self, now: Cycle, req: MemReq, ctx: &mut Ctx) {
        // HLC: floor the bank clock by coarse physical time (see
        // `HalconeL1::on_cu_req`).
        if self.policy == TsPolicy::Hlc {
            self.advance_cts(tsproto::hlc_phys(now));
        }
        let la = self.line_base(req.addr);
        if self.mshr.get(la).is_some() {
            self.stats.mshr_merges += 1;
            self.mshr.merge(la, req);
            return;
        }
        match req.kind {
            ReqKind::Read => {
                let cts = self.cts;
                let mut hit = None;
                if let Some(line) = self.cache.lookup(req.addr) {
                    if cts <= line.meta.rts {
                        hit = Some((LineBuf::from_slice(line.data), *line.meta));
                    } else {
                        self.stats.coherency_misses += 1;
                    }
                } else {
                    self.stats.misses += 1;
                }
                if let Some((data, meta)) = hit {
                    self.cache.record(true);
                    self.stats.hits += 1;
                    self.observe_read(meta.wts);
                    self.respond_up(&req, data, meta, ctx);
                    return;
                }
                self.cache.record(false);
                let fill = MemReq {
                    id: req.id,
                    kind: ReqKind::Read,
                    addr: la,
                    size: self.line as u32,
                    src: ctx.self_id,
                    dst: self.routes.route_mm(la).2,
                    data: LineBuf::empty(),
                    warpts: self.carry_warpts.then_some(self.cts),
                    tenant: req.tenant,
                };
                self.mshr.allocate(la, MshrKind::Fill, req);
                self.send_mm(fill, ctx);
            }
            ReqKind::Write => {
                let cts = self.cts;
                let mut hit = false;
                if let Some(line) = self.cache.lookup(req.addr) {
                    if cts <= line.meta.rts {
                        hit = true;
                        let off = (req.addr - la) as usize;
                        line.data[off..off + req.data.len()].copy_from_slice(&req.data);
                    }
                }
                self.cache.record(hit);
                if hit {
                    self.stats.hits += 1;
                } else {
                    self.stats.misses += 1;
                }
                let down = MemReq {
                    id: req.id,
                    kind: ReqKind::Write,
                    addr: req.addr,
                    size: req.size,
                    src: ctx.self_id,
                    dst: self.routes.route_mm(req.addr).2,
                    data: req.data,
                    warpts: self.carry_warpts.then_some(self.cts),
                    tenant: req.tenant,
                };
                self.mshr.allocate(la, MshrKind::WriteLock, req);
                self.send_mm(down, ctx);
            }
        }
    }

    fn on_mm_rsp(&mut self, now: Cycle, rsp: MemRsp, ctx: &mut Ctx) {
        self.stats.rsps_down += 1;
        let la = self.line_base(rsp.addr);
        let entry = self.mshr.retire(la);
        let ts = rsp.ts.expect("timestamp-protocol MM response must carry timestamps");
        let meta = tsproto::merge_ts(self.policy, self.cts, ts);
        match entry.kind {
            MshrKind::Fill => {
                self.cache.insert(la, &rsp.data, false, meta);
                self.observe_read(meta.wts);
                self.respond_up(&entry.primary, rsp.data, meta, ctx);
            }
            MshrKind::WriteLock => {
                // Write-allocate with the MM's merged line (Alg. 5
                // `WriteBlockToCache`): a same-tag insert also *replaces*
                // any tag-matched-but-expired stale copy with fresh bytes.
                debug_assert_eq!(rsp.data.len() as u64, self.line);
                self.cache.insert(la, &rsp.data, false, meta);
                self.advance_cts(meta.wts);
                self.respond_up(&entry.primary, LineBuf::empty(), meta, ctx);
            }
        }
        for w in entry.waiters {
            self.on_l1_req(now, w, ctx);
        }
    }
}

impl Component for HalconeL2 {
    crate::impl_component_any!();
    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match msg {
            Msg::Req(req) => {
                self.stats.reqs_in += 1;
                let req = ctx.reclaim_req(req);
                self.on_l1_req(now, req, ctx);
            }
            Msg::Rsp(rsp) => {
                let rsp = ctx.reclaim_rsp(rsp);
                self.on_mm_rsp(now, rsp, ctx);
            }
            Msg::FenceQuery { reply_to } => {
                let cts = self.cts;
                ctx.schedule(0, reply_to, Msg::FenceInfo { from: ctx.self_id, cts });
            }
            Msg::FenceApply { reply_to, logical_max } => {
                debug_assert!(self.mshr.is_empty(), "fence with in-flight requests");
                self.advance_cts(logical_max);
                ctx.schedule(0, reply_to, Msg::FenceDone { from: ctx.self_id });
            }
            other => panic!("{}: unexpected {:?}", self.name, other),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format as f;
        f::put(out, self.cts);
        f::put(out, self.rollover_flushes);
        self.cache.save_with(out, put_ts_meta);
        self.mshr.save_state(out);
        self.stats.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        self.cts = cur.u64("l2 cts")?;
        self.rollover_flushes = cur.u64("l2 rollover_flushes")?;
        self.cache.load_with(cur, read_ts_meta)?;
        self.mshr.load_state(cur)?;
        self.stats.load_state(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::{GlobalMemory, MemCtrl, SharedMemory};
    use crate::interconnect::Switch;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;
    use crate::sim::{Engine, Link};
    use crate::tsu::{Leases, Tsu};
    use std::collections::HashMap;

    /// Scripted CU stand-in: issues requests at fixed times, records
    /// responses.
    struct Prober {
        name: String,
        l1: CompId,
        script: Vec<(Cycle, MemReq)>,
        pub responses: Vec<(Cycle, MemRsp)>,
    }

    impl Component for Prober {
    crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
            match msg {
                Msg::Tick => {
                    for (t, req) in std::mem::take(&mut self.script) {
                        let mut r = req;
                        r.src = ctx.self_id;
                        ctx.schedule(t - now, self.l1, Msg::Req(Box::new(r)));
                    }
                }
                Msg::Rsp(rsp) => self.responses.push((now, *rsp)),
                _ => {}
            }
        }
    }

    /// Test rig: `n_gpus` x (Prober + L1 + single L2 bank), one MC+TSU
    /// behind a switch.
    struct Rig {
        engine: Engine,
        mem: SharedMemory,
        probers: Vec<CompId>,
        l1s: Vec<CompId>,
        l2s: Vec<CompId>,
        #[allow(dead_code)]
        mc: CompId,
    }

    fn rd(id: u64, addr: u64) -> MemReq {
        MemReq {
            id,
            kind: ReqKind::Read,
            addr,
            size: 4,
            src: CompId::NONE,
            dst: CompId::NONE,
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        }
    }

    fn wr(id: u64, addr: u64, v: f32) -> MemReq {
        MemReq {
            id,
            kind: ReqKind::Write,
            addr,
            size: 4,
            src: CompId::NONE,
            dst: CompId::NONE,
            data: LineBuf::from_slice(&v.to_le_bytes()),
            warpts: None,
            tenant: 0,
        }
    }

    fn f32_of(rsp: &MemRsp) -> f32 {
        f32::from_le_bytes([rsp.data[0], rsp.data[1], rsp.data[2], rsp.data[3]])
    }

    fn build(
        n_gpus: u32,
        leases: Leases,
        carry_warpts: bool,
        scripts: Vec<Vec<(Cycle, MemReq)>>,
    ) -> Rig {
        build_policy(TsPolicy::Halcone, n_gpus, leases, carry_warpts, scripts)
    }

    fn build_policy(
        policy: TsPolicy,
        n_gpus: u32,
        leases: Leases,
        carry_warpts: bool,
        scripts: Vec<Vec<(Cycle, MemReq)>>,
    ) -> Rig {
        let mut e = Engine::new();
        let mem = GlobalMemory::new_shared();
        let map = AddrMap::new(Topology::SharedMem, n_gpus, 1, 1, 1 << 20);

        // Component ids (assigned in insertion order):
        // per gpu g: prober = 3g, l1 = 3g+1, l2 = 3g+2; then switch, mc.
        let n = n_gpus as usize;
        let prober_ids: Vec<CompId> = (0..n).map(|g| CompId(3 * g as u32)).collect();
        let l1_ids: Vec<CompId> = (0..n).map(|g| CompId(3 * g as u32 + 1)).collect();
        let l2_ids: Vec<CompId> = (0..n).map(|g| CompId(3 * g as u32 + 2)).collect();
        let sw_id = CompId(3 * n_gpus);
        // One MC per HBM stack (total_stacks = n_gpus * 1 in this rig).
        let mc_ids: Vec<CompId> =
            (0..map.total_stacks()).map(|s| CompId(3 * n_gpus + 1 + s)).collect();

        let mut sw = Switch::new("sw");
        for g in 0..n {
            // Links per gpu: l1->l2, l2->l1, l2->sw, sw->l2.
            let l1_l2 = e.add_link(Link::wire(format!("g{g}.l1->l2"), 5));
            let l2_l1 = e.add_link(Link::wire(format!("g{g}.l2->l1"), 5));
            let l2_sw = e.add_link(Link::new(format!("g{g}.l2->sw"), 20, 256));
            let sw_l2 = e.add_link(Link::new(format!("sw->g{g}.l2"), 20, 256));
            sw.add_route(l2_ids[g], (sw_l2, l2_ids[g]));

            let routes1 = L1Routes {
                map: map.clone(),
                gpu: g as u32,
                local_links: vec![l1_l2],
                local_banks: vec![l2_ids[g]],
                remote_hop: None,
                all_banks: vec![],
            };
            let mut up = HashMap::new();
            up.insert(l1_ids[g], l2_l1);
            let routes2 = L2Routes {
                map: map.clone(),
                gpu: g as u32,
                mm_hop: (l2_sw, sw_id),
                mcs: mc_ids.clone(),
                up_routes: up,
                up_default: None,
                peer_hop: None,
                all_banks: vec![],
            };
            e.add(Box::new(Prober {
                name: format!("cu{g}"),
                l1: l1_ids[g],
                script: scripts[g].clone(),
                responses: vec![],
            }));
            e.add(Box::new(
                HalconeL1::new(
                    format!("g{g}.l1"),
                    routes1,
                    CacheParams::new(16 << 10, 4),
                    64,
                    1,
                    carry_warpts,
                )
                .with_policy(policy),
            ));
            e.add(Box::new(
                HalconeL2::new(
                    format!("g{g}.l2"),
                    routes2,
                    CacheParams::new(256 << 10, 16),
                    256,
                    10,
                    carry_warpts,
                )
                .with_policy(policy),
            ));
        }
        let mut mc_links = Vec::new();
        for (s, &mc_id) in mc_ids.iter().enumerate() {
            let mc_sw = e.add_link(Link::new(format!("mc{s}->sw"), 20, 341));
            let sw_mc = e.add_link(Link::new(format!("sw->mc{s}"), 20, 341));
            sw.add_route(mc_id, (sw_mc, mc_id));
            mc_links.push(mc_sw);
        }
        e.add(Box::new(sw));
        for (s, &_mc_id) in mc_ids.iter().enumerate() {
            e.add(Box::new(MemCtrl::new(
                format!("mm{s}"),
                mem.clone(),
                (mc_links[s], sw_id),
                100,
                Some(Tsu::new(1 << 16, leases).with_policy(policy)),
            )));
        }
        for &p in &prober_ids {
            e.post(0, p, Msg::Tick);
        }
        Rig { engine: e, mem, probers: prober_ids, l1s: l1_ids, l2s: l2_ids, mc: mc_ids[0] }
    }

    fn responses(rig: &Rig, gpu: usize) -> &Vec<(Cycle, MemRsp)> {
        &rig.engine.downcast::<Prober>(rig.probers[gpu]).responses
    }

    fn l1_stats(rig: &Rig, gpu: usize) -> CacheCtrlStats {
        rig.engine.downcast::<HalconeL1>(rig.l1s[gpu]).stats
    }

    fn l2_stats(rig: &Rig, gpu: usize) -> CacheCtrlStats {
        rig.engine.downcast::<HalconeL2>(rig.l2s[gpu]).stats
    }

    #[test]
    fn read_miss_fills_then_hits() {
        let mut rig = build(
            1,
            Leases::default(),
            false,
            vec![vec![(0, rd(1, 0x100)), (2000, rd(2, 0x104))]],
        );
        rig.mem.borrow_mut().write_f32(0x104, 42.0);
        rig.engine.run_to_completion();
        let rsps = responses(&rig, 0);
        assert_eq!(rsps.len(), 2);
        assert_eq!(f32_of(&rsps[1].1), 42.0);
        let s = l1_stats(&rig, 0);
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(s.reqs_down, 1); // second read hit locally
        // First response took the full path; second only the L1 latency.
        assert!(rsps[0].0 > 100);
        assert!(rsps[1].0 - 2000 < 10);
    }

    #[test]
    fn write_through_reaches_memory_and_advances_cts() {
        let mut rig = build(1, Leases::default(), false, vec![vec![(0, wr(1, 0x200, 7.5))]]);
        rig.engine.run_to_completion();
        assert_eq!(rig.mem.borrow_mut().read_f32(0x200), 7.5);
        let rsps = responses(&rig, 0);
        assert_eq!(rsps.len(), 1);
        assert_eq!(rsps[0].1.kind, ReqKind::Write);
        // First write to a fresh block: Mwts = 0, so cts stays 0 at L1;
        // but the L2 allocated the line (write-allocate).
        let s2 = l2_stats(&rig, 0);
        assert_eq!(s2.reqs_down, 1);
        assert_eq!(s2.rsps_down, 1);
    }

    #[test]
    fn repeated_writes_self_invalidate_reads() {
        // Xtreme1's mechanism: writes advance cts; a block read earlier
        // (lease rts=10) expires once cts passes its rts.
        let script = vec![
            (0, rd(1, 0x100)),     // lease [0,10] on block 0x100
            (3000, wr(2, 0x200, 1.0)), // memts(0x200): 0->5
            (6000, wr(3, 0x200, 2.0)), // 5->10, Mwts=5  => cts=5
            (9000, wr(4, 0x200, 3.0)), // 10->15, Mwts=10 => cts=10
            (12000, wr(5, 0x200, 4.0)), // 15->20, Mwts=15 => cts=15
            (15000, rd(6, 0x100)),  // cts=15 > rts=10: coherency miss
        ];
        let mut rig = build(1, Leases::default(), false, vec![script]);
        rig.mem.borrow_mut().write_f32(0x100, 9.0);
        rig.engine.run_to_completion();
        let s1 = l1_stats(&rig, 0);
        assert!(
            s1.coherency_misses >= 1,
            "expected a coherency miss, got {s1:?}"
        );
        // Data still correct after refetch.
        let rsps = responses(&rig, 0);
        let last = rsps.iter().find(|(_, r)| r.id == 6).unwrap();
        assert_eq!(f32_of(&last.1), 9.0);
    }

    #[test]
    fn litmus_fig5_inter_gpu_write_becomes_visible() {
        // CU0@GPU0: R X, W Y, R X       (I0-1, I0-2, I0-3)
        // CU0@GPU1: R Y, W X, W X, R Y  (I1-1, I1-2, +extra write, I1-3)
        // The extra write pushes GPU1's cts beyond Y's read lease, so I1-3
        // must coherency-miss and observe CU0's write of Y (paper Fig. 5b).
        let x = 0x1000u64;
        let y = 0x2000u64;
        let s0 = vec![(0, rd(10, x)), (3000, wr(11, y, 5.0)), (9000, rd(12, x))];
        let s1 = vec![
            (0, rd(20, y)),
            (4000, wr(21, x, 7.0)),
            (6000, wr(22, x, 8.0)),
            (12000, rd(23, y)),
        ];
        let mut rig = build(2, Leases::default(), false, vec![s0, s1]);
        {
            let mut m = rig.mem.borrow_mut();
            m.write_f32(x, 1.0);
            m.write_f32(y, 2.0);
        }
        rig.engine.run_to_completion();

        // I0-3: GPU0's cts after W Y is Mwts(Y)=10 (read lease) -> within
        // X's lease [.,10] at its L1: hit, old value (logically ordered
        // before GPU1's writes of X).
        let r0 = responses(&rig, 0);
        let i0_3 = r0.iter().find(|(_, r)| r.id == 12).unwrap();
        assert_eq!(f32_of(&i0_3.1), 1.0, "I0-3 must see the pre-write X");

        // I1-3: GPU1's cts after two W X is 15 > rts(Y)=10: refetch; MM has
        // CU0's write (WT), so the new value must be visible.
        let r1 = responses(&rig, 1);
        let i1_3 = r1.iter().find(|(_, r)| r.id == 23).unwrap();
        assert_eq!(f32_of(&i1_3.1), 5.0, "I1-3 must observe CU0's write of Y");

        let s1stats = l1_stats(&rig, 1);
        assert!(s1stats.coherency_misses >= 1);
    }

    #[test]
    fn fence_expires_stale_copies_across_gpus() {
        // GPU1 reads X; GPU0 writes X; after a fence with logical_max+1,
        // GPU1's re-read must miss and see the new value — even though
        // GPU1 itself never wrote (its cts would otherwise stay 0).
        let x = 0x3000u64;
        let s0 = vec![(0, wr(1, x, 3.25))];
        let s1 = vec![(0, rd(2, x))];
        let mut rig = build(2, Leases::default(), false, vec![s0, s1]);
        rig.mem.borrow_mut().write_f32(x, 1.0);
        rig.engine.run_to_completion();

        // Manual two-phase fence (the driver does this in production code).
        // Writer cts: Mwts(X) after read+write order depends on event
        // interleave; query then apply max+1.
        let cts_vals: Vec<u64> = (0..2)
            .flat_map(|g| {
                let l1 = rig.engine.downcast::<HalconeL1>(rig.l1s[g]).cts;
                let l2 = rig.engine.downcast::<HalconeL2>(rig.l2s[g]).cts;
                [l1, l2]
            })
            .collect();
        let logical_max = cts_vals.iter().max().unwrap() + 1;
        for g in 0..2 {
            rig.engine.post(
                1_000_000,
                rig.l1s[g],
                Msg::FenceApply { reply_to: rig.probers[g], logical_max },
            );
            rig.engine.post(
                1_000_000,
                rig.l2s[g],
                Msg::FenceApply { reply_to: rig.probers[g], logical_max },
            );
        }
        // Re-read on GPU1 after the fence.
        rig.engine.post(1_100_000, rig.probers[1], Msg::Tick);
        rig.engine.downcast_mut::<Prober>(rig.probers[1]).script = vec![(1_200_000, rd(9, x))];
        rig.engine.run_to_completion();
        let r1 = responses(&rig, 1);
        let reread = r1.iter().find(|(_, r)| r.id == 9).unwrap();
        assert_eq!(f32_of(&reread.1), 3.25, "post-fence read must see the write");
    }

    #[test]
    fn mshr_merges_concurrent_same_line_reads() {
        let script = vec![(0, rd(1, 0x500)), (1, rd(2, 0x504)), (2, rd(3, 0x508))];
        let mut rig = build(1, Leases::default(), false, vec![script]);
        rig.engine.run_to_completion();
        let s = l1_stats(&rig, 0);
        assert_eq!(s.reqs_down, 1, "same-line reads must merge");
        assert_eq!(s.mshr_merges, 2);
        assert_eq!(responses(&rig, 0).len(), 3);
    }

    #[test]
    fn warpts_ablation_increases_request_bytes() {
        let script = || vec![(0, rd(1, 0x100)), (3000, wr(2, 0x200, 1.0))];
        let mut a = build(1, Leases::default(), false, vec![script()]);
        a.engine.run_to_completion();
        let mut b = build(1, Leases::default(), true, vec![script()]);
        b.engine.run_to_completion();
        let (sa, sb) = (l1_stats(&a, 0), l1_stats(&b, 0));
        assert_eq!(sa.reqs_down, sb.reqs_down, "same protocol behaviour");
        assert!(
            sb.bytes_down > sa.bytes_down,
            "warpts must add request bytes: {} vs {}",
            sb.bytes_down,
            sa.bytes_down
        );
    }

    #[test]
    fn write_lock_queues_subsequent_reads() {
        // A read issued 1 cycle after a write to the same line must wait
        // for the lock and then return the written value.
        let script = vec![(0, wr(1, 0x700, 6.5)), (1, rd(2, 0x700))];
        let mut rig = build(1, Leases::default(), false, vec![script]);
        rig.engine.run_to_completion();
        let rsps = responses(&rig, 0);
        let read = rsps.iter().find(|(_, r)| r.id == 2).unwrap();
        assert_eq!(f32_of(&read.1), 6.5);
        // The read was replayed after the lock: it must not have produced
        // a *second* L2 fill before the write completed.
        let s = l1_stats(&rig, 0);
        assert_eq!(s.mshr_merges, 1);
    }

    #[test]
    fn tardis_writes_expire_remote_leases_without_broadcasts() {
        // Same shape as `repeated_writes_self_invalidate_reads`: under
        // Tardis each write hit bumps the line's stable wts past the read
        // frontier and the writer's clock follows, so an earlier read
        // lease on another line expires and the re-read self-invalidates
        // — no invalidation message ever crosses the fabric.
        let script = vec![
            (0, rd(1, 0x100)),
            (3000, wr(2, 0x200, 1.0)),
            (6000, wr(3, 0x200, 2.0)),
            (9000, wr(4, 0x200, 3.0)),
            (12000, wr(5, 0x200, 4.0)),
            (15000, rd(6, 0x100)),
        ];
        let mut rig =
            build_policy(TsPolicy::Tardis, 1, Leases::default(), false, vec![script]);
        rig.mem.borrow_mut().write_f32(0x100, 9.0);
        rig.engine.run_to_completion();
        let s1 = l1_stats(&rig, 0);
        assert!(s1.coherency_misses >= 1, "expected a coherency miss, got {s1:?}");
        let rsps = responses(&rig, 0);
        let last = rsps.iter().find(|(_, r)| r.id == 6).unwrap();
        assert_eq!(f32_of(&last.1), 9.0);
    }

    #[test]
    fn hlc_physical_time_expires_idle_leases() {
        // Two reads of one block, far apart in simulated time and with no
        // intervening writes. HALCONE's purely logical clock never moves,
        // so the second read hits; HLC's hybrid clock is floored by
        // physical time, so the lease expires and the read re-fetches.
        let script = || vec![(0, rd(1, 0x100)), (1_000_000, rd(2, 0x100))];
        let mut h = build(1, Leases::default(), false, vec![script()]);
        h.mem.borrow_mut().write_f32(0x100, 3.0);
        h.engine.run_to_completion();
        assert_eq!(l1_stats(&h, 0).coherency_misses, 0);

        let mut hl =
            build_policy(TsPolicy::Hlc, 1, Leases::default(), false, vec![script()]);
        hl.mem.borrow_mut().write_f32(0x100, 3.0);
        hl.engine.run_to_completion();
        let s = l1_stats(&hl, 0);
        assert!(s.coherency_misses >= 1, "hybrid time must expire the lease: {s:?}");
        let rsps = responses(&hl, 0);
        let last = rsps.iter().find(|(_, r)| r.id == 2).unwrap();
        assert_eq!(f32_of(&last.1), 3.0);
    }

    #[test]
    fn coalesce_runs_merges_and_overwrites() {
        let b = |xs: &[u8]| LineBuf::from_slice(xs);
        // Contiguous runs merge; overlapping later writes win.
        let runs = coalesce_runs(vec![
            (8, b(&[3, 4])),
            (4, b(&[1, 2, 9, 9])),
            (6, b(&[7, 8])),
            (20, b(&[5])),
        ]);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].0, 4);
        assert_eq!(&runs[0].1[..], &[1, 2, 7, 8, 3, 4]);
        assert_eq!(runs[1].0, 20);
        assert_eq!(&runs[1].1[..], &[5]);
    }
}
