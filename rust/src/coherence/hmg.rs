//! HMG-style hierarchical VI coherence for RDMA MGPU systems
//! (the paper's strongest comparator, RDMA-WB-C-HMG; Ren et al., HPCA'20,
//! as described by HALCONE §1/§4.1/§6).
//!
//! Model implemented here (simplifications documented in DESIGN.md):
//!
//! * Every line has a **home** L2 bank: the bank of the GPU owning the
//!   address partition. The home bank is the ordering point and keeps a
//!   **directory** of remote sharer banks.
//! * Remote banks may cache **clean** copies (state V) filled from the
//!   home over the PCIe fabric; L2 hits on remote data are HMG's headline
//!   win over plain RDMA (the paper: "brings the cache blocks from a
//!   remote GPU in its L2\$ instead of its L1\$").
//! * Writes are performed at the home: a remote writer invalidates its own
//!   copy and forwards the word; the home first invalidates every other
//!   sharer (Inv/InvAck over PCIe) and only then performs the write
//!   (write-back, dirty-at-home).
//! * L1s are software-coherent (dropped at kernel-boundary fences), as in
//!   HMG's scoped-consistency model.
//!
//! Invalidation latency and traffic ride the same bandwidth-modelled PCIe
//! links as data, so sharing-heavy workloads pay HMG's coherence cost in
//! both time and bytes — the effect HALCONE's evaluation exploits.

use std::collections::HashSet;

use crate::coherence::L2Routes;
use crate::mem::cache::{CacheArray, CacheParams};
use crate::mem::fxhash::{FxHashMap, FxHashSet};
use crate::mem::mshr::{Mshr, MshrKind};
use crate::mem::LineBuf;
use crate::metrics::CacheCtrlStats;
use crate::sim::msg::{MemReq, MemRsp};
use crate::sim::{CompId, Component, Ctx, Cycle, Msg, ReqKind};

const WB_ID_BASE: u64 = 1 << 62;

/// A home-side write waiting for sharer invalidation acks.
#[derive(Debug)]
struct PendingInv {
    remaining: usize,
    req: MemReq,
    waiters: Vec<MemReq>,
}

/// A fill stalled behind its victim's write-back (home side, WB).
#[derive(Debug)]
struct StalledFill {
    line_addr: u64,
}

/// HMG L2 bank: home directory + remote V-cache in one controller.
pub struct HmgL2 {
    name: String,
    routes: L2Routes,
    gpu: u32,
    bank: u32,
    cache: CacheArray<()>,
    mshr: Mshr,
    lat: Cycle,
    /// Home only: line -> remote sharer banks.
    directory: FxHashMap<u64, Vec<CompId>>,
    /// Home only: writes blocked on invalidation acks.
    pending_inv: FxHashMap<u64, PendingInv>,
    /// Peer bank component ids (to distinguish peer requests from L1s).
    peer_banks: HashSet<CompId>,
    evict_wait: FxHashMap<u64, StalledFill>,
    fire_and_forget: FxHashSet<u64>,
    next_wb_id: u64,
    fence_pending: u64,
    fence_reply: Option<CompId>,
    pub stats: CacheCtrlStats,
    line: u64,
}

impl HmgL2 {
    pub fn new(
        name: impl Into<String>,
        routes: L2Routes,
        gpu: u32,
        bank: u32,
        params: CacheParams,
        mshr_entries: usize,
        lat: Cycle,
    ) -> Self {
        let line = params.line;
        let peer_banks: HashSet<CompId> = routes
            .all_banks
            .iter()
            .enumerate()
            .filter(|(g, _)| *g as u32 != gpu)
            .flat_map(|(_, banks)| banks.iter().copied())
            .collect();
        HmgL2 {
            name: name.into(),
            routes,
            gpu,
            bank,
            cache: CacheArray::new(params),
            mshr: Mshr::new(mshr_entries),
            lat,
            directory: FxHashMap::default(),
            pending_inv: FxHashMap::default(),
            peer_banks,
            evict_wait: FxHashMap::default(),
            fire_and_forget: FxHashSet::default(),
            next_wb_id: WB_ID_BASE,
            fence_pending: 0,
            fence_reply: None,
            stats: CacheCtrlStats::default(),
            line,
        }
    }

    fn line_base(&self, addr: u64) -> u64 {
        addr & !(self.line - 1)
    }

    fn is_home(&self, addr: u64) -> bool {
        self.routes.map.home_gpu(addr) == self.gpu
    }

    fn home_bank_of(&self, addr: u64) -> CompId {
        let g = self.routes.map.home_gpu(addr) as usize;
        let b = self.routes.map.l2_bank_of(addr) as usize;
        self.routes.all_banks[g][b]
    }

    fn respond_up(&mut self, req: &MemReq, data: LineBuf, ctx: &mut Ctx) {
        let rsp = MemRsp {
            id: req.id,
            kind: req.kind,
            addr: req.addr,
            dst: req.src,
            data,
            ts: None,
        };
        self.stats.rsps_out += 1;
        self.stats.bytes_up += rsp.wire_bytes();
        let (link, next) = self.routes.route_up(req.src);
        let bytes = rsp.wire_bytes();
        let msg = ctx.rsp_msg(rsp);
        ctx.send_delayed(self.lat, link, next, bytes, msg);
    }

    fn send_mm(&mut self, down: MemReq, ctx: &mut Ctx) {
        let (link, next, _) = self.routes.route_mm(down.addr);
        self.stats.reqs_down += 1;
        self.stats.bytes_down += down.wire_bytes();
        let bytes = down.wire_bytes();
        let msg = ctx.req_msg(down);
        ctx.send(link, next, bytes, msg);
    }

    fn send_home(&mut self, mut req: MemReq, ctx: &mut Ctx) {
        let home = self.home_bank_of(req.addr);
        req.dst = home;
        let (link, sw) = self.routes.peer_hop.expect("HMG needs peer routing");
        self.stats.reqs_down += 1;
        self.stats.bytes_down += req.wire_bytes();
        let bytes = req.wire_bytes();
        let msg = ctx.req_msg(req);
        ctx.send(link, sw, bytes, msg);
    }

    fn writeback(&mut self, addr: u64, data: LineBuf, ctx: &mut Ctx) -> u64 {
        let id = self.next_wb_id;
        self.next_wb_id += 1;
        self.stats.writebacks += 1;
        let wb = MemReq {
            id,
            kind: ReqKind::Write,
            addr,
            size: data.len() as u32,
            src: ctx.self_id,
            dst: self.routes.route_mm(addr).2,
            data,
            warpts: None,
            tenant: 0,
        };
        self.send_mm(wb, ctx);
        id
    }

    fn send_fill(&mut self, la: u64, id: u64, ctx: &mut Ctx) {
        let fill = MemReq {
            id,
            kind: ReqKind::Read,
            addr: la,
            size: self.line as u32,
            src: ctx.self_id,
            dst: self.routes.route_mm(la).2,
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        };
        self.send_mm(fill, ctx);
    }

    fn insert_wb_safe(&mut self, la: u64, data: &[u8], dirty: bool, ctx: &mut Ctx) {
        if let Some(ev) = self.cache.insert(la, data, dirty, ()) {
            if ev.dirty {
                let id = self.writeback(ev.addr, ev.data, ctx);
                self.fire_and_forget.insert(id);
            }
        }
    }

    fn start_fill(&mut self, la: u64, id: u64, ctx: &mut Ctx) {
        if let Some(ev) = self.cache.take_dirty_victim(la) {
            let wb_id = self.writeback(ev.addr, ev.data, ctx);
            self.evict_wait.insert(wb_id, StalledFill { line_addr: la });
            return;
        }
        self.send_fill(la, id, ctx);
    }

    fn record_sharer(&mut self, la: u64, requester: CompId) {
        if self.peer_banks.contains(&requester) {
            let sharers = self.directory.entry(la).or_default();
            if !sharers.contains(&requester) {
                sharers.push(requester);
            }
        }
    }

    /// Perform a write at the home bank (sharers already invalidated).
    fn perform_home_write(&mut self, req: MemReq, ctx: &mut Ctx) {
        let la = self.line_base(req.addr);
        let mut hit = false;
        if let Some(line) = self.cache.lookup(req.addr) {
            hit = true;
            *line.dirty = true;
            let off = (req.addr - la) as usize;
            line.data[off..off + req.data.len()].copy_from_slice(&req.data);
        }
        self.cache.record(hit);
        if hit {
            self.stats.hits += 1;
            self.respond_up(&req, LineBuf::empty(), ctx);
            return;
        }
        self.stats.misses += 1;
        // Write-allocate at home: fill, then merge (handled at retire).
        if self.mshr.get(la).is_some() {
            self.stats.mshr_merges += 1;
            self.mshr.merge(la, req);
            return;
        }
        let id = req.id;
        self.mshr.allocate(la, MshrKind::Fill, req);
        self.start_fill(la, id, ctx);
    }

    fn home_handle(&mut self, now: Cycle, req: MemReq, ctx: &mut Ctx) {
        let la = self.line_base(req.addr);
        if let Some(p) = self.pending_inv.get_mut(&la) {
            p.waiters.push(req);
            return;
        }
        match req.kind {
            ReqKind::Read => {
                if self.mshr.get(la).is_some() {
                    self.stats.mshr_merges += 1;
                    self.mshr.merge(la, req);
                    return;
                }
                let mut hit_data = None;
                if let Some(line) = self.cache.lookup(req.addr) {
                    hit_data = Some(LineBuf::from_slice(line.data));
                }
                if let Some(data) = hit_data {
                    self.cache.record(true);
                    self.stats.hits += 1;
                    self.record_sharer(la, req.src);
                    let full = req.size as u64 == self.line;
                    let payload = if full {
                        data
                    } else {
                        let off = (req.addr - la) as usize;
                        LineBuf::from_slice(&data[off..off + req.size as usize])
                    };
                    self.respond_up(&req, payload, ctx);
                    return;
                }
                self.cache.record(false);
                self.stats.misses += 1;
                let id = req.id;
                self.mshr.allocate(la, MshrKind::Fill, req);
                self.start_fill(la, id, ctx);
            }
            ReqKind::Write => {
                // Invalidate every sharer except the writer itself.
                let sharers: Vec<CompId> = self
                    .directory
                    .remove(&la)
                    .unwrap_or_default()
                    .into_iter()
                    .filter(|&s| s != req.src)
                    .collect();
                if sharers.is_empty() {
                    self.perform_home_write(req, ctx);
                    return;
                }
                let (link, sw) = self.routes.peer_hop.expect("HMG needs peer routing");
                self.stats.invalidations += sharers.len() as u64;
                let n = sharers.len();
                for sharer in sharers {
                    ctx.send(
                        link,
                        sw,
                        16,
                        Msg::Inv { addr: la, dir: ctx.self_id, dst: sharer },
                    );
                }
                self.pending_inv
                    .insert(la, PendingInv { remaining: n, req, waiters: Vec::new() });
            }
        }
        let _ = now;
    }

    fn remote_handle(&mut self, _now: Cycle, req: MemReq, ctx: &mut Ctx) {
        let la = self.line_base(req.addr);
        if self.mshr.get(la).is_some() {
            self.stats.mshr_merges += 1;
            self.mshr.merge(la, req);
            return;
        }
        match req.kind {
            ReqKind::Read => {
                let mut hit_data = None;
                if let Some(line) = self.cache.lookup(req.addr) {
                    let off = (req.addr - la) as usize;
                    hit_data = Some(LineBuf::from_slice(
                        &line.data[off..off + req.size as usize],
                    ));
                }
                if let Some(data) = hit_data {
                    self.cache.record(true);
                    self.stats.hits += 1;
                    self.respond_up(&req, data, ctx);
                    return;
                }
                self.cache.record(false);
                self.stats.misses += 1;
                // Fetch the full line from the home bank.
                let fill = MemReq {
                    id: req.id,
                    kind: ReqKind::Read,
                    addr: la,
                    size: self.line as u32,
                    src: ctx.self_id,
                    dst: CompId::NONE, // set by send_home
                    data: LineBuf::empty(),
                    warpts: None,
                    tenant: req.tenant,
                };
                self.mshr.allocate(la, MshrKind::Fill, req);
                self.send_home(fill, ctx);
            }
            ReqKind::Write => {
                // VI: drop the local copy, write through to the home.
                self.cache.invalidate(la);
                let down = MemReq {
                    id: req.id,
                    kind: ReqKind::Write,
                    addr: req.addr,
                    size: req.size,
                    src: ctx.self_id,
                    dst: CompId::NONE,
                    data: req.data,
                    warpts: None,
                    tenant: req.tenant,
                };
                self.mshr.allocate(la, MshrKind::WriteLock, req);
                self.send_home(down, ctx);
            }
        }
    }

    fn on_rsp(&mut self, now: Cycle, rsp: MemRsp, ctx: &mut Ctx) {
        if self.fire_and_forget.remove(&rsp.id) {
            return;
        }
        if let Some(stalled) = self.evict_wait.remove(&rsp.id) {
            let id = self
                .mshr
                .get(stalled.line_addr)
                .expect("stalled fill lost its MSHR entry")
                .primary
                .id;
            self.send_fill(stalled.line_addr, id, ctx);
            return;
        }
        if rsp.id >= WB_ID_BASE {
            if self.fence_pending > 0 {
                self.fence_pending -= 1;
                if self.fence_pending == 0 {
                    if let Some(reply) = self.fence_reply.take() {
                        ctx.schedule(0, reply, Msg::FenceDone { from: ctx.self_id });
                    }
                }
            }
            return;
        }

        self.stats.rsps_down += 1;
        let la = self.line_base(rsp.addr);
        let entry = self.mshr.retire(la);
        match entry.kind {
            MshrKind::Fill => {
                debug_assert_eq!(rsp.data.len() as u64, self.line);
                let mut data = rsp.data;
                let primary = entry.primary;
                match primary.kind {
                    ReqKind::Read => {
                        // Home fill from MM, or remote fill from home:
                        // cache a clean copy and respond.
                        self.insert_wb_safe(la, &data, false, ctx);
                        if self.is_home(la) {
                            self.record_sharer(la, primary.src);
                        }
                        let payload = if primary.size as u64 == self.line {
                            data
                        } else {
                            let off = (primary.addr - la) as usize;
                            LineBuf::from_slice(&data[off..off + primary.size as usize])
                        };
                        self.respond_up(&primary, payload, ctx);
                    }
                    ReqKind::Write => {
                        // Home write-allocate: merge + dirty.
                        let off = (primary.addr - la) as usize;
                        data[off..off + primary.data.len()].copy_from_slice(&primary.data);
                        self.insert_wb_safe(la, &data, true, ctx);
                        self.respond_up(&primary, LineBuf::empty(), ctx);
                    }
                }
            }
            MshrKind::WriteLock => {
                // Remote write acknowledged by the home.
                self.respond_up(&entry.primary, LineBuf::empty(), ctx);
            }
        }
        for w in entry.waiters {
            self.on_req(now, w, ctx);
        }
    }

    fn on_req(&mut self, now: Cycle, req: MemReq, ctx: &mut Ctx) {
        if self.is_home(req.addr) {
            self.home_handle(now, req, ctx);
        } else {
            self.remote_handle(now, req, ctx);
        }
    }

    fn on_inv_ack(&mut self, now: Cycle, addr: u64, ctx: &mut Ctx) {
        let la = self.line_base(addr);
        let done = {
            let p = self
                .pending_inv
                .get_mut(&la)
                .unwrap_or_else(|| panic!("{}: stray InvAck for {la:#x}", self.name));
            p.remaining -= 1;
            p.remaining == 0
        };
        if done {
            let p = self.pending_inv.remove(&la).unwrap();
            self.perform_home_write(p.req, ctx);
            for w in p.waiters {
                self.on_req(now, w, ctx);
            }
        }
    }

    fn on_fence(&mut self, reply_to: CompId, ctx: &mut Ctx) {
        debug_assert!(self.mshr.is_empty(), "fence with in-flight requests");
        debug_assert!(self.pending_inv.is_empty(), "fence with pending invals");
        self.directory.clear();
        let drained = self.cache.drain();
        let mut pending = 0;
        for ev in drained {
            if ev.dirty {
                self.writeback(ev.addr, ev.data, ctx);
                pending += 1;
            }
        }
        if pending == 0 {
            ctx.schedule(0, reply_to, Msg::FenceDone { from: ctx.self_id });
        } else {
            self.fence_pending = pending;
            self.fence_reply = Some(reply_to);
        }
    }

    /// Bank index (used by topology builders; also silences dead-code).
    pub fn bank(&self) -> u32 {
        self.bank
    }
}

impl Component for HmgL2 {
    crate::impl_component_any!();

    fn name(&self) -> &str {
        &self.name
    }

    fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
        match msg {
            Msg::Req(req) => {
                self.stats.reqs_in += 1;
                let req = ctx.reclaim_req(req);
                self.on_req(now, req, ctx);
            }
            Msg::Rsp(rsp) => {
                let rsp = ctx.reclaim_rsp(rsp);
                self.on_rsp(now, rsp, ctx);
            }
            Msg::Inv { addr, dir, .. } => {
                // This bank is a sharer: drop the (clean) copy and ack.
                self.cache.invalidate(addr);
                self.stats.invalidations += 1;
                let (link, sw) = self.routes.peer_hop.expect("HMG needs peer routing");
                ctx.send(link, sw, 8, Msg::InvAck { addr, from: ctx.self_id, dst: dir });
            }
            Msg::InvAck { addr, .. } => self.on_inv_ack(now, addr, ctx),
            Msg::FenceQuery { reply_to } => {
                ctx.schedule(0, reply_to, Msg::FenceInfo { from: ctx.self_id, cts: 0 });
            }
            Msg::FenceApply { reply_to, .. } => self.on_fence(reply_to, ctx),
            other => panic!("{}: unexpected {:?}", self.name, other),
        }
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        use crate::snapshot::format as f;
        self.cache.save_with(out, |_, _| {});
        self.mshr.save_state(out);
        // Directory: keys sorted for determinism; each sharer Vec kept in
        // its recorded order verbatim — it fixes the home's Inv send order.
        let mut keys: Vec<u64> = self.directory.keys().copied().collect();
        keys.sort_unstable();
        f::put(out, keys.len() as u64);
        for la in keys {
            f::put(out, la);
            let sharers = &self.directory[&la];
            f::put(out, sharers.len() as u64);
            for s in sharers {
                f::put(out, s.0 as u64);
            }
        }
        let mut keys: Vec<u64> = self.pending_inv.keys().copied().collect();
        keys.sort_unstable();
        f::put(out, keys.len() as u64);
        for la in keys {
            f::put(out, la);
            let p = &self.pending_inv[&la];
            f::put(out, p.remaining as u64);
            f::put_req(out, &p.req);
            f::put(out, p.waiters.len() as u64);
            for w in &p.waiters {
                f::put_req(out, w);
            }
        }
        let mut ids: Vec<u64> = self.evict_wait.keys().copied().collect();
        ids.sort_unstable();
        f::put(out, ids.len() as u64);
        for id in ids {
            f::put(out, id);
            f::put(out, self.evict_wait[&id].line_addr);
        }
        let mut ids: Vec<u64> = self.fire_and_forget.iter().copied().collect();
        ids.sort_unstable();
        f::put(out, ids.len() as u64);
        for id in ids {
            f::put(out, id);
        }
        f::put(out, self.next_wb_id);
        f::put(out, self.fence_pending);
        f::put_bool(out, self.fence_reply.is_some());
        if let Some(reply) = self.fence_reply {
            f::put(out, reply.0 as u64);
        }
        self.stats.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, cur: &mut crate::snapshot::format::Cur) -> Result<(), String> {
        use crate::snapshot::format as f;
        self.cache.load_with(cur, |_| Ok(()))?;
        self.mshr.load_state(cur)?;
        let n = cur.u64("hmg directory count")? as usize;
        self.directory.clear();
        for _ in 0..n {
            let la = cur.u64("hmg directory line")?;
            let m = cur.u64("hmg sharer count")? as usize;
            if m > cur.b.len() {
                return Err(format!("hmg sharer count {m} exceeds the input size"));
            }
            let mut sharers = Vec::with_capacity(m);
            for _ in 0..m {
                sharers.push(CompId(cur.u32("hmg sharer")?));
            }
            if self.directory.insert(la, sharers).is_some() {
                return Err(format!("snapshot repeats directory line {la:#x}"));
            }
        }
        let n = cur.u64("hmg pending-inv count")? as usize;
        self.pending_inv.clear();
        for _ in 0..n {
            let la = cur.u64("hmg pending-inv line")?;
            let remaining = cur.u64("hmg pending-inv remaining")? as usize;
            let req = f::read_req(cur, "hmg pending-inv req")?;
            let m = cur.u64("hmg pending-inv waiter count")? as usize;
            if m > cur.b.len() {
                return Err(format!("pending-inv waiter count {m} exceeds the input size"));
            }
            let mut waiters = Vec::with_capacity(m);
            for _ in 0..m {
                waiters.push(f::read_req(cur, "hmg pending-inv waiter")?);
            }
            if self.pending_inv.insert(la, PendingInv { remaining, req, waiters }).is_some() {
                return Err(format!("snapshot repeats pending-inv line {la:#x}"));
            }
        }
        let n = cur.u64("hmg evict-wait count")? as usize;
        self.evict_wait.clear();
        for _ in 0..n {
            let id = cur.u64("hmg evict-wait id")?;
            let line_addr = cur.u64("hmg evict-wait line")?;
            if self.evict_wait.insert(id, StalledFill { line_addr }).is_some() {
                return Err(format!("snapshot repeats evict-wait id {id}"));
            }
        }
        let n = cur.u64("hmg fire-and-forget count")? as usize;
        self.fire_and_forget.clear();
        for _ in 0..n {
            let id = cur.u64("hmg fire-and-forget id")?;
            if !self.fire_and_forget.insert(id) {
                return Err(format!("snapshot repeats fire-and-forget id {id}"));
            }
        }
        self.next_wb_id = cur.u64("hmg next_wb_id")?;
        self.fence_pending = cur.u64("hmg fence_pending")?;
        self.fence_reply = if cur.bool("hmg fence_reply flag")? {
            Some(CompId(cur.u32("hmg fence_reply")?))
        } else {
            None
        };
        self.stats.load_state(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coherence::none::PlainL1;
    use crate::coherence::L1Routes;
    use crate::dram::{GlobalMemory, MemCtrl, SharedMemory};
    use crate::interconnect::Switch;
    use crate::mem::addr::Topology;
    use crate::mem::AddrMap;
    use crate::sim::{Engine, Link, LinkId};
    use std::collections::HashMap as Map;

    struct Prober {
        name: String,
        l1: CompId,
        script: Vec<(Cycle, MemReq)>,
        pub responses: Vec<(Cycle, MemRsp)>,
    }
    impl Component for Prober {
        crate::impl_component_any!();
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, now: Cycle, msg: Msg, ctx: &mut Ctx) {
            match msg {
                Msg::Tick => {
                    for (t, req) in std::mem::take(&mut self.script) {
                        let mut r = req;
                        r.src = ctx.self_id;
                        ctx.schedule(t.saturating_sub(now), self.l1, Msg::Req(Box::new(r)));
                    }
                }
                Msg::Rsp(rsp) => self.responses.push((now, *rsp)),
                _ => {}
            }
        }
    }

    struct Rig {
        engine: Engine,
        mem: SharedMemory,
        probers: Vec<CompId>,
        l2s: Vec<CompId>,
        pcie_links: Vec<LinkId>,
    }

    fn rd(id: u64, addr: u64) -> MemReq {
        MemReq {
            id,
            kind: ReqKind::Read,
            addr,
            size: 4,
            src: CompId::NONE,
            dst: CompId::NONE,
            data: LineBuf::empty(),
            warpts: None,
            tenant: 0,
        }
    }

    fn wr(id: u64, addr: u64, v: f32) -> MemReq {
        MemReq {
            id,
            kind: ReqKind::Write,
            addr,
            size: 4,
            src: CompId::NONE,
            dst: CompId::NONE,
            data: LineBuf::from_slice(&v.to_le_bytes()),
            warpts: None,
            tenant: 0,
        }
    }

    fn f32_of(rsp: &MemRsp) -> f32 {
        f32::from_le_bytes([rsp.data[0], rsp.data[1], rsp.data[2], rsp.data[3]])
    }

    /// 2 GPUs x (Prober + PlainL1 + HmgL2 bank), per-GPU MC, PCIe switch.
    fn build(scripts: Vec<Vec<(Cycle, MemReq)>>) -> Rig {
        let mut e = Engine::new();
        let mem = GlobalMemory::new_shared();
        let map = AddrMap::new(Topology::Rdma, 2, 1, 1, 1 << 20);
        let n = 2usize;
        let probers: Vec<CompId> = (0..n).map(|g| CompId(4 * g as u32)).collect();
        let l1s: Vec<CompId> = (0..n).map(|g| CompId(4 * g as u32 + 1)).collect();
        let l2s: Vec<CompId> = (0..n).map(|g| CompId(4 * g as u32 + 2)).collect();
        let mcs: Vec<CompId> = (0..n).map(|g| CompId(4 * g as u32 + 3)).collect();
        let sw_id = CompId(4 * n as u32);
        let all_banks = vec![vec![l2s[0]], vec![l2s[1]]];
        let mut sw = Switch::new("pcie");
        let mut pcie_links = Vec::new();

        for g in 0..n {
            let l1_l2 = e.add_link(Link::wire(format!("g{g}.l1->l2"), 5));
            let l2_l1 = e.add_link(Link::wire(format!("g{g}.l2->l1"), 5));
            let l2_mc = e.add_link(Link::new(format!("g{g}.l2->mc"), 20, 256));
            let mc_l2 = e.add_link(Link::new(format!("g{g}.mc->l2"), 20, 341));
            // PCIe: 32 B/cycle, high latency.
            let l2_sw = e.add_link(Link::new(format!("g{g}.l2->pcie"), 300, 32));
            let sw_l2 = e.add_link(Link::new(format!("pcie->g{g}.l2"), 300, 32));
            sw.add_route(l2s[g], (sw_l2, l2s[g]));
            pcie_links.push(l2_sw);

            e.add(Box::new(Prober {
                name: format!("cu{g}"),
                l1: l1s[g],
                script: scripts[g].clone(),
                responses: vec![],
            }));
            e.add(Box::new(PlainL1::new(
                format!("g{g}.l1"),
                L1Routes {
                    map: map.clone(),
                    gpu: g as u32,
                    local_links: vec![l1_l2],
                    local_banks: vec![l2s[g]],
                    remote_hop: None, // HMG: L1 always goes to the local L2
                    all_banks: all_banks.clone(),
                },
                CacheParams::new(16 << 10, 4),
                64,
                1,
            )));
            let mut up = Map::new();
            up.insert(l1s[g], l2_l1);
            e.add(Box::new(HmgL2::new(
                format!("g{g}.l2"),
                L2Routes {
                    map: map.clone(),
                    gpu: g as u32,
                    mm_hop: (l2_mc, mcs[g]),
                    mcs: mcs.clone(),
                    up_routes: up,
                    up_default: Some((l2_sw, sw_id)),
                    peer_hop: Some((l2_sw, sw_id)),
                    all_banks: all_banks.clone(),
                },
                g as u32,
                0,
                CacheParams::new(256 << 10, 16),
                256,
                10,
            )));
            e.add(Box::new(MemCtrl::new(
                format!("mm{g}"),
                mem.clone(),
                (mc_l2, l2s[g]),
                100,
                None,
            )));
        }
        e.add(Box::new(sw));
        for &p in &probers {
            e.post(0, p, Msg::Tick);
        }
        Rig { engine: e, mem, probers, l2s, pcie_links }
    }

    #[test]
    fn remote_read_caches_in_local_l2() {
        // GPU1 reads an address homed at GPU0, twice: the second read must
        // hit GPU1's L2 (HMG's advantage over raw RDMA) — same PCIe message
        // count after both reads.
        let x = 0x100u64; // GPU0's partition
        let scripts = vec![vec![], vec![(0, rd(1, x)), (50_000, rd(2, x + 4))]];
        let mut rig = build(scripts);
        rig.mem.borrow_mut().write_f32(x + 4, 11.0);
        rig.engine.run_to_completion();
        let rsps = &rig.engine.downcast::<Prober>(rig.probers[1]).responses;
        assert_eq!(rsps.len(), 2);
        assert_eq!(f32_of(&rsps[1].1), 11.0);
        let remote_stats = rig.engine.downcast::<HmgL2>(rig.l2s[1]).stats;
        assert_eq!(remote_stats.reqs_down, 1, "one home fetch for two reads");
        // The second read's L1 missed (different word? same line) — it hit
        // L1 actually; what matters: the L2 holds a local copy.
        assert!(rig.engine.link(rig.pcie_links[1]).msgs_sent >= 1);
    }

    #[test]
    fn home_write_invalidates_remote_sharers() {
        // GPU1 reads x (becomes a sharer), GPU0 writes x (home invalidates
        // GPU1's L2 copy). GPU1 then performs a scoped acquire — HMG's
        // consistency model requires one before consuming another GPU's
        // write — modelled as an L1 fence, and re-reads: the L2 copy is
        // gone, so the home's new value must be fetched.
        let x = 0x200u64; // homed at GPU0
        let scripts = vec![
            vec![(200_000, wr(10, x, 5.0))],
            vec![(0, rd(1, x)), (400_000, rd(2, x))],
        ];
        let mut rig = build(scripts);
        rig.mem.borrow_mut().write_f32(x, 1.0);
        // Scoped acquire on GPU1's L1 between the write and the re-read.
        let l1_gpu1 = CompId(4 + 1);
        let p1 = rig.probers[1];
        rig.engine.post(300_000, l1_gpu1, Msg::FenceApply { reply_to: p1, logical_max: 0 });
        rig.engine.run_to_completion();
        let rsps = &rig.engine.downcast::<Prober>(rig.probers[1]).responses;
        let first = rsps.iter().find(|(_, r)| r.id == 1).unwrap();
        let second = rsps.iter().find(|(_, r)| r.id == 2).unwrap();
        assert_eq!(f32_of(&first.1), 1.0);
        assert_eq!(
            f32_of(&second.1),
            5.0,
            "read after invalidation + acquire must see the home's new value"
        );
        let home = rig.engine.downcast::<HmgL2>(rig.l2s[0]).stats;
        assert!(home.invalidations >= 1, "home must have sent an Inv");
    }

    #[test]
    fn remote_write_is_performed_at_home() {
        let x = 0x300u64; // homed at GPU0
        let scripts = vec![vec![], vec![(0, wr(1, x, 7.0))]];
        let mut rig = build(scripts);
        rig.engine.run_to_completion();
        // Dirty at home L2, not yet in MM (WB).
        assert_eq!(rig.mem.borrow_mut().read_f32(x), 0.0);
        // Fence at home drains it.
        let home = rig.l2s[0];
        let p0 = rig.probers[0];
        rig.engine.post(1_000_000, home, Msg::FenceApply { reply_to: p0, logical_max: 0 });
        rig.engine.run_to_completion();
        assert_eq!(rig.mem.borrow_mut().read_f32(x), 7.0);
    }

    #[test]
    fn directory_tracks_each_sharer_once() {
        let x = 0x400u64;
        let scripts = vec![
            vec![],
            vec![(0, rd(1, x)), (50_000, rd(2, x)), (100_000, rd(3, x))],
        ];
        let mut rig = build(scripts);
        rig.engine.run_to_completion();
        let home = rig.engine.downcast::<HmgL2>(rig.l2s[0]);
        let sharers = home.directory.get(&x).map(|v| v.len()).unwrap_or(0);
        assert!(sharers <= 1, "sharer recorded once, got {sharers}");
    }
}
